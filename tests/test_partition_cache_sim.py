"""Tests for logical partitioning, the compute-side cache, and the
event-level simulator (Plane A)."""

import numpy as np
import pytest

# optional-hypothesis shim: property tests skip individually when
# hypothesis is absent, plain tests keep running (tests/_hypothesis_compat)
from _hypothesis_compat import given, settings, st  # noqa: E402

from repro.core import baselines
from repro.core.cache import ComputeCache, CoolingMap
from repro.core.cost_model import analyze
from repro.core.nodes import KEY_MAX, KEY_MIN
from repro.core.partition import LogicalPartitions
from repro.core.sim import HostBTree, Simulator
from repro.data import ycsb


# ---------------------------------------------------------------------------
# LogicalPartitions
# ---------------------------------------------------------------------------


class TestPartitions:
    def test_equal_width_owners(self):
        p = LogicalPartitions.equal_width(4, 0, 1000)
        assert p.num_partitions == 4
        owners = p.owner_of(np.array([1, 260, 510, 760, 999]))
        assert owners.tolist() == [0, 1, 2, 3, 3]

    def test_shared_range_detection(self):
        p = LogicalPartitions.equal_width(4, 0, 1000)
        # a root-like node spanning everything is shared
        assert bool(p.is_shared_range([KEY_MIN], [KEY_MAX])[0])
        # a narrow range inside one partition is not
        assert not bool(p.is_shared_range([10], [20])[0])
        # crossing the first boundary is shared
        b = int(p.boundaries[1])
        assert bool(p.is_shared_range([b - 5], [b + 5])[0])

    def test_split_and_merge(self):
        p = LogicalPartitions.equal_width(2, 0, 100)
        p2 = p.split_partition(0, 10)
        assert p2.num_partitions == 3
        p3 = p2.merge_partitions(0)
        assert p3.num_partitions == 2

    def test_from_samples_balances_skew(self):
        rng = np.random.default_rng(0)
        keys = (rng.pareto(2.0, size=20_000) * 1000).astype(np.int64) + 1
        p = LogicalPartitions.from_samples(keys, 4)
        owners = p.owner_of(keys)
        counts = np.bincount(owners, minlength=4)
        assert counts.min() > 0.15 * keys.size  # roughly balanced

    def test_rebalance_moves_boundaries(self):
        p = LogicalPartitions.equal_width(2, 0, 1000)
        p2 = p.rebalance([9.0, 1.0])  # partition 0 overloaded
        # new boundary should move left of the old midpoint
        assert int(p2.boundaries[1]) < int(p.boundaries[1])
        assert p.assignment_diff(p2) > 0

    @settings(max_examples=40, deadline=None)
    @given(
        st.integers(2, 12),
        st.lists(st.floats(0.0, 1e6), min_size=2, max_size=12),
        st.data(),
    )
    def test_prop_rebalance_valid_and_count_preserving(self, nparts, raw,
                                                       data):
        """Any loads (skewed, zero, tiny) on any table: the result is a
        valid strictly-increasing table with the same partition count, and
        with a roomy key_range the boundaries stay inside the hull."""
        p = LogicalPartitions.equal_width(nparts, 0, 100_000)
        loads = (raw * nparts)[:nparts]
        lo = data.draw(st.integers(-(2**40), 2**40))
        hi = lo + data.draw(st.integers(4 * nparts, 2**41))
        p2 = p.rebalance(loads, key_range=(lo, hi))
        assert p2.num_partitions == nparts
        b = p2.boundaries
        assert b[0] == KEY_MIN and b[-1] == KEY_MAX
        assert np.all(np.diff(b.astype(object)) > 0)
        if sum(loads) > 0:
            # hull clamps to enclose the existing inner boundaries; the
            # count-preserving perturbation may spill past a degenerate
            # (near-zero-width) hull edge by at most num_partitions - 2
            hull_lo = min(lo, int(p.boundaries[1]))
            hull_hi = max(hi, int(p.boundaries[-2]))
            assert (b[1:-1] >= hull_lo).all()
            assert (b[1:-1] <= hull_hi + nparts).all()

    @settings(max_examples=20, deadline=None)
    @given(st.integers(2, 16), st.data())
    def test_prop_owner_in_range(self, nparts, data):
        p = LogicalPartitions.equal_width(nparts, 0, 10_000)
        keys = data.draw(
            st.lists(st.integers(-(2**50), 2**50), min_size=1, max_size=50)
        )
        owners = p.owner_of(np.array(keys, dtype=np.int64))
        assert ((owners >= 0) & (owners < p.num_partitions)).all()


# ---------------------------------------------------------------------------
# CoolingMap + ComputeCache
# ---------------------------------------------------------------------------


class TestCoolingMap:
    def test_fifo_eviction_within_bucket(self):
        cm = CoolingMap(1, slots=3)
        assert cm.insert(1) is None
        assert cm.insert(2) is None
        assert cm.insert(3) is None
        assert cm.insert(4) == 1  # oldest evicted

    def test_remove_second_chance(self):
        cm = CoolingMap(4, slots=2)
        cm.insert(10)
        assert cm.remove(10)
        assert not cm.remove(10)

    def test_pop_any(self):
        cm = CoolingMap(8, slots=2)
        for i in range(10):
            cm.insert(i)
        rng = np.random.default_rng(0)
        seen = set()
        while True:
            n = cm.pop_any(rng)
            if n is None:
                break
            seen.add(n)
        assert len(cm) == 0 and len(seen) > 0

    def test_lock_accounting_spreads(self):
        """The point of the cooling map: bucket locks spread the load."""
        central = CoolingMap(1, slots=10**9)
        spread = CoolingMap(64, slots=6)
        for i in range(3000):
            central.insert(i)
            spread.insert(i)
        assert central.lock_acquires.max() == 3000
        assert spread.lock_acquires.max() < 3000 * 0.2


def _mk_cache(capacity=32, **kw):
    # a tiny synthetic 2-level tree: parent -1 for roots 0..3, children 100+
    parents = {}
    for r in range(4):
        parents[r] = -1
        for c in range(8):
            parents[100 + r * 8 + c] = r
    return ComputeCache(
        capacity,
        parent_of=lambda n: parents.get(n, -1),
        is_leaf=lambda n: n >= 100,
        rng=np.random.default_rng(0),
        **kw,
    )


class TestComputeCache:
    def test_admit_requires_parent(self):
        c = _mk_cache(p_admit_leaf=1.0)
        assert not c.admit(100)          # parent 0 not cached
        assert c.admit(0)
        assert c.admit(100)
        assert c.lookup(100) == "hit"

    def test_lazy_leaf_admission(self):
        c = _mk_cache(p_admit_leaf=0.0)
        c.admit(0)
        assert not c.admit(100)          # P_A = 0 rejects leaves
        assert c.admit(1)                # inner always admitted

    def test_eviction_under_pressure(self):
        c = _mk_cache(capacity=6, p_admit_leaf=1.0)
        for r in range(4):
            c.admit(r)
        for leaf in range(100, 120):
            c.admit(leaf)
        assert c.num_cached() <= 6
        assert c.stats.evictions > 0

    def test_path_aware_delegation(self):
        """Cooling a parent with HOT swizzled children must delegate downward
        (§5.3): the parent stays HOT, a descendant transitions to COOLING.
        (The invariant is soft overall — second-chance restores can re-heat a
        child under a cooling parent, the paper's "in most cases".)"""
        from repro.core.cache import COOLING, HOT

        c = _mk_cache(capacity=40, p_admit_leaf=1.0)
        c.admit(0)
        for leaf in range(100, 108):
            c.admit(leaf)
        c._cool(0)  # sample lands on the parent
        assert c.stats.delegations >= 1
        assert c.state[0] == HOT, "parent must not cool while children are hot"
        assert any(
            c.state.get(leaf) == COOLING for leaf in range(100, 108)
        ), "a swizzled child should have received the cooling command"

    def test_dirty_flush(self):
        c = _mk_cache(p_admit_leaf=1.0)
        c.admit(0)
        c.admit(100, dirty=True)
        assert c.is_dirty(100)
        n = c.flush_dirty()
        assert n == 1 and not c.is_dirty(100)

    def test_invalidate(self):
        c = _mk_cache(p_admit_leaf=1.0)
        c.admit(0)
        c.admit(100)
        assert c.invalidate(100)
        assert c.lookup(100) == "miss"


# ---------------------------------------------------------------------------
# HostBTree + Simulator
# ---------------------------------------------------------------------------


def _tree(n=20_000, seed=0, **kw):
    data = ycsb.make_dataset(n, seed=seed)
    return data, HostBTree(data, **kw)


class TestHostBTree:
    def test_get_after_build(self):
        data, t = _tree(5000)
        for k in data[::97]:
            assert t.get(int(k)) == int(k)
        assert t.get(int(data.max()) + 12345) is None

    def test_insert_with_splits(self):
        data, t = _tree(2000, fill=1.0)
        rng = np.random.default_rng(1)
        fresh = []
        for k in data[:300]:
            nk = int(k) + 1
            if t.get(nk) is None:
                t.insert(nk, nk * 2)
                fresh.append(nk)
        assert t.splits > 0
        for nk in fresh:
            assert t.get(nk) == nk * 2
        # originals intact
        for k in data[::53]:
            assert t.get(int(k)) == int(k)

    def test_root_split_grows_height(self):
        keys = np.arange(1, 64 * 64 + 1, dtype=np.int64)
        t = HostBTree(keys, fill=1.0, level_m=1)
        h0 = t.height
        for k in range(10**6, 10**6 + 5000):
            t.insert(k, k)
        assert t.height >= h0
        assert t.get(10**6 + 100) == 10**6 + 100

    def test_delete(self):
        data, t = _tree(3000)
        for k in data[::17]:
            assert t.delete(int(k))
        for k in data[::17]:
            assert t.get(int(k)) is None

    def test_scan_hops(self):
        data, t = _tree(4000)
        start = int(data[100])
        hops = t.scan(start, 100)
        got = [k for _, ks in hops for k in ks]
        expect = data[data >= start][:100].tolist()
        assert got == expect

    def test_subtree_placement(self):
        data, t = _tree(30_000, level_m=2, n_mem_servers=4)
        # every node at level <= M shares its subtree root's server
        for nid in range(t.num_nodes):
            if t.LV[nid] < 0 or t.LV[nid] > t.level_m:
                continue
            root = t.subtree_root_of(nid)
            assert t.server[nid] == t.server[root]


class TestSimulator:
    def test_dex_beats_baselines_on_reads(self):
        data, _ = _tree(50_000)
        wl = ycsb.generate("read-only", data, 8000, seed=3)
        results = {}
        for name in ["dex", "sherman", "p-sherman", "naive"]:
            tree = HostBTree(data, level_m=3, n_mem_servers=4)
            cfg = baselines.ALL[name](cache_bytes=(tree.num_nodes // 3) * 1024)
            sim = Simulator(tree, cfg, seed=7)
            sim.run(wl.ops, wl.keys)
            results[name] = sim.totals().per_op()
        # DEX must do far fewer remote reads (the paper's core claim)
        assert results["dex"]["reads"] < 0.6 * results["p-sherman"]["reads"]
        assert results["p-sherman"]["reads"] < results["sherman"]["reads"]
        assert results["sherman"]["reads"] < results["naive"]["reads"]

    def test_partitioning_eliminates_atomics(self):
        data, _ = _tree(30_000)
        wl = ycsb.generate("write-intensive", data, 6000, seed=4)
        tree = HostBTree(data, level_m=3, n_mem_servers=4)
        sim = Simulator(tree, baselines.dex(), seed=1)
        sim.run(wl.ops, wl.keys)
        assert sim.totals().per_op()["atomics"] == 0.0
        tree2 = HostBTree(data, level_m=3, n_mem_servers=4)
        sim2 = Simulator(tree2, baselines.sherman_like(), seed=1)
        sim2.run(wl.ops, wl.keys)
        assert sim2.totals().per_op()["atomics"] > 0.2

    def test_offload_engages_with_tiny_cache(self):
        data, _ = _tree(50_000)
        wl = ycsb.generate("read-only", data, 8000, seed=5)
        tree = HostBTree(data, level_m=3, n_mem_servers=4)
        cfg = baselines.dex(cache_bytes=64 * 1024)  # 64 frames: ~1% cache
        sim = Simulator(tree, cfg, seed=2)
        sim.run(wl.ops, wl.keys)
        assert sim.totals().per_op()["two_sided"] > 0.01

    def test_simulation_correctness_of_results(self):
        """Protocol bookkeeping must not corrupt the index itself."""
        data, _ = _tree(10_000)
        wl = ycsb.generate("insert-intensive", data, 4000, seed=6)
        tree = HostBTree(data, level_m=2, n_mem_servers=2)
        sim = Simulator(tree, baselines.dex(), seed=3)
        sim.run(wl.ops, wl.keys)
        # every inserted key must be retrievable
        ins = wl.keys[wl.ops == ycsb.OP_INSERT]
        for k in ins[:200]:
            assert tree.get(int(k)) is not None

    def test_repartition_flushes_and_rebalances(self):
        data, _ = _tree(20_000)
        wl = ycsb.generate("write-intensive", data, 5000, seed=8)
        tree = HostBTree(data, level_m=3, n_mem_servers=4)
        sim = Simulator(tree, baselines.dex(), seed=4)
        sim.run(wl.ops, wl.keys)
        newp = LogicalPartitions.equal_width(
            8, int(data.min()), int(data.max()) + 1
        )
        rep = sim.repartition(newp)
        assert rep["dirty_pages_flushed"] >= 0
        assert rep["fraction_keyspace_moved"] > 0
        assert sim.partitions.num_partitions == 8

    def test_cost_model_produces_finite_throughput(self):
        data, _ = _tree(20_000)
        wl = ycsb.generate("read-intensive", data, 5000, seed=9)
        tree = HostBTree(data, level_m=3, n_mem_servers=4)
        sim = Simulator(tree, baselines.dex(), seed=5)
        sim.run(wl.ops, wl.keys)
        rep = analyze(sim)
        assert 0 < rep.ops_per_sec < 1e10
        assert rep.bottleneck in rep.caps
