"""On-mesh SMO engine tests (core/smo.py): device-side leaf splits vs
``HostBTree`` replay, successor-chain scans across split leaves, warm-cache
survival (no global version reset), the inner-split pass at level_m=2, the
free-list-exhaustion fallback through ``drain_splits``, and a hypothesis
property test interleaving insert/update/lookup batches with on-mesh splits
(``importorskip``, matching tests/test_write.py style).

Multi-device split parity (8 devices, poisoned stale cached rows) lives in
tests/mesh_check.py, exercised via the ``slow`` subprocess test in
tests/test_dex_mesh.py.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import dex as dex_mod
from repro.core import pool as pool_mod
from repro.core import scan as scan_mod
from repro.core import smo as smo_mod
from repro.core import write as write_mod
from repro.core.nodes import FANOUT, KEY_MAX, KEY_MIN
from repro.compat import make_mesh_compat
from repro.core.sim import HostBTree


def _dataset(n, seed=0, space=None):
    rng = np.random.default_rng(seed)
    space = space or 16 * n
    return np.sort(rng.choice(space, size=n, replace=False).astype(np.int64) + 1)


def _setup(keys, *, level_m=1, headroom=0.5, p_admit_leaf_pct=10,
           cache_sets=128):
    vals = keys * 5
    pool, meta = pool_mod.build_pool(keys, vals, level_m=level_m, fill=0.7,
                                     n_shards=1, headroom=headroom)
    mesh = make_mesh_compat((1, 1), ("data", "model"))
    cfg = dex_mod.DexMeshConfig(
        n_route=1, n_memory=1, cache_sets=cache_sets, cache_ways=4,
        p_admit_leaf_pct=p_admit_leaf_pct, route_capacity_factor=2.0,
        policy="fetch",
    )
    bounds = np.array([KEY_MIN, KEY_MAX], np.int64)
    state = dex_mod.init_state(pool, meta, cfg, bounds)
    host = HostBTree(keys, vals, fill=0.7)
    return state, meta, cfg, mesh, host, bounds


def _ops(meta, cfg, mesh):
    return (
        jax.jit(dex_mod.make_dex_lookup(meta, cfg, mesh)),
        jax.jit(write_mod.make_dex_update(meta, cfg, mesh)),
        jax.jit(write_mod.make_dex_insert(meta, cfg, mesh)),
        jax.jit(smo_mod.make_dex_smo(meta, cfg, mesh)),
    )


def _check_against_host(lookup, state, host, probe):
    state, found, vals, _ = lookup(state, jnp.asarray(probe))
    found, vals = np.asarray(found), np.asarray(vals)
    for i, k in enumerate(probe):
        hv = host.get(int(k))
        assert bool(found[i]) == (hv is not None), (i, int(k))
        if hv is not None:
            assert int(vals[i]) == hv, (i, int(k), int(vals[i]), hv)
    return state


def _overflow_burst(keys, rng=None, width=FANOUT):
    """Fresh keys all targeting the first leaf: guaranteed overflow."""
    lo = int(keys[0])
    burst = np.arange(lo + 1, lo + 1 + width, dtype=np.int64)
    return burst[~np.isin(burst, keys)][: width - 8]


class TestOnMeshLeafSplit:
    def test_split_applies_without_rebuild_and_matches_host(self):
        keys = _dataset(3000, seed=1)
        state, meta, cfg, mesh, host, bounds = _setup(keys)
        lookup, _, insert, smo = _ops(meta, cfg, mesh)
        burst = _overflow_burst(keys)
        iv = burst * 3
        state, res = insert(state, jnp.asarray(burst), jnp.asarray(iv))
        res = np.asarray(res)
        assert (res == write_mod.STATUS_SPLIT).all()
        state, meta2, info = smo_mod.settle_splits(
            state, meta, cfg, smo, host, burst, iv, bounds
        )
        assert meta2 is meta, "on-mesh split must not rebuild the pool"
        assert not info["drained"]
        assert info["onmesh"] == burst.size
        assert host.splits > 0  # settle replayed the inserts into the host
        stats = np.asarray(state.stats).sum(axis=0)
        assert stats[dex_mod.STAT_SMO_SPLITS] >= 1
        assert stats[dex_mod.STAT_DRAINS] == 0
        # free-list watermark moved exactly by the executed splits
        n_alloc = np.asarray(state.n_alloc)
        assert (
            int((n_alloc - meta.base_cap).sum())
            == int(stats[dex_mod.STAT_SMO_SPLITS])
        )
        _check_against_host(lookup, state, host, burst)
        _check_against_host(lookup, state, host, keys[:256])

    def test_scan_follows_successor_chain_across_split(self):
        keys = _dataset(3000, seed=2)
        state, meta, cfg, mesh, host, bounds = _setup(keys)
        _, _, insert, smo = _ops(meta, cfg, mesh)
        scan = jax.jit(scan_mod.make_dex_scan(meta, cfg, mesh, max_count=64))
        burst = _overflow_burst(keys)
        state, res = insert(state, jnp.asarray(burst), jnp.asarray(burst * 3))
        shed = np.asarray(res) == write_mod.STATUS_SPLIT
        state, meta, info = smo_mod.settle_splits(
            state, meta, cfg, smo, host, burst[shed], burst[shed] * 3, bounds
        )
        assert not info["drained"]
        # scans starting before, inside and after the split leaf's range
        lo = int(keys[0])
        starts = np.array([lo, lo + 3, int(burst[-1]), int(keys[50])],
                          np.int64)
        cnts = np.array([64, 64, 40, 30], np.int64)
        state, sk, sv, tk = scan(state, jnp.asarray(starts), jnp.asarray(cnts))
        sk, sv, tk = np.asarray(sk), np.asarray(sv), np.asarray(tk)
        for i in range(starts.size):
            expect = [
                kk for _, ks in host.scan(int(starts[i]), int(cnts[i]))
                for kk in ks
            ][: int(cnts[i])]
            got = sk[i][sk[i] != KEY_MAX].tolist()
            assert got == expect, (i, got[:6], expect[:6])
            assert int(tk[i]) == len(expect)
            for j, kk in enumerate(expect):
                assert int(sv[i, j]) == host.get(int(kk)), (i, j)

    def test_unrelated_cached_rows_survive_split(self):
        """The drain path colds every cache; the SMO engine must bump only
        the split leaf and its touched ancestors, so warm rows elsewhere
        keep serving hits (no global version reset)."""
        keys = _dataset(3000, seed=3)
        state, meta, cfg, mesh, host, bounds = _setup(
            keys, p_admit_leaf_pct=100
        )
        lookup, _, insert, smo = _ops(meta, cfg, mesh)
        probe = keys[-256:]  # far from the burst region (first leaf)
        state, _, _, _ = lookup(state, jnp.asarray(probe))  # warm
        burst = _overflow_burst(keys)
        state, res = insert(state, jnp.asarray(burst), jnp.asarray(burst * 3))
        shed = np.asarray(res) == write_mod.STATUS_SPLIT
        assert shed.any()
        state, meta, info = smo_mod.settle_splits(
            state, meta, cfg, smo, host, burst[shed], burst[shed] * 3, bounds
        )
        assert not info["drained"]
        # only the split leaf + sibling + ancestors were version-bumped
        vers = np.asarray(state.versions)[0]
        assert 0 < int((vers > 0).sum()) <= 4 * meta.levels_in_subtree
        before = np.asarray(state.stats).sum(axis=0)
        state, f, v, _ = lookup(state, jnp.asarray(probe))
        after = np.asarray(state.stats).sum(axis=0)
        assert bool(np.asarray(f).all())
        np.testing.assert_array_equal(np.asarray(v), probe * 5)
        # the warm rows must keep serving from cache: at least the leaf
        # level of every probe lane hits (no refetch)
        d_hits = int(after[dex_mod.STAT_HITS] - before[dex_mod.STAT_HITS])
        assert d_hits >= probe.size, d_hits

    def test_inner_split_at_level_m2(self):
        """Hammering one key region at level_m=2 fills the leaves' shared
        level-1 parent; the dense inner pass must split it device-side
        (no host rebuild) and keep parity with the host replay."""
        rng = np.random.default_rng(4)
        keys = _dataset(30_000, seed=4, space=4_000_000)
        state, meta, cfg, mesh, host, bounds = _setup(keys, level_m=2)
        lookup, _, insert, smo = _ops(meta, cfg, mesh)
        assert meta.levels_in_subtree == 3
        lo, hi = int(keys[500]), int(keys[900])
        drained = 0
        smo_before = int(
            np.asarray(state.stats).sum(axis=0)[dex_mod.STAT_SMO_SPLITS]
        )
        for _ in range(8):
            fresh = np.unique(
                rng.integers(lo, hi, size=256).astype(np.int64)
            )
            fresh = fresh[~np.isin(fresh, keys)]
            pad = 256 - fresh.size
            ik = np.concatenate([fresh, np.full(pad, KEY_MAX, np.int64)])
            iv = np.where(ik != KEY_MAX, ik * 3, 0)
            state, res = insert(state, jnp.asarray(ik), jnp.asarray(iv))
            res = np.asarray(res)
            okm = (res == write_mod.STATUS_OK) & (ik != KEY_MAX)
            for kk in ik[okm]:
                host.insert(int(kk), int(kk) * 3)
            shed = res == write_mod.STATUS_SPLIT
            state, meta, info = smo_mod.settle_splits(
                state, meta, cfg, smo, host, ik[shed], iv[shed], bounds
            )
            drained += int(info["drained"])
            if info["drained"]:
                lookup, _, insert, smo = _ops(meta, cfg, mesh)
            keys = np.union1d(keys, ik[okm])
        stats = np.asarray(state.stats).sum(axis=0)
        assert int(stats[dex_mod.STAT_SMO_SPLITS]) - smo_before > 1
        assert drained == 0, "level-2 headroom must absorb this burst"
        hk, hv = write_mod.host_items(host)
        idx = rng.choice(hk.size, size=512, replace=False)
        _check_against_host(lookup, state, host, hk[idx])

    def test_exhausted_free_list_falls_back_to_drain(self):
        keys = _dataset(3000, seed=5)
        state, meta, cfg, mesh, host, bounds = _setup(keys, headroom=0.0)
        lookup, _, insert, smo = _ops(meta, cfg, mesh)
        assert meta.subtree_cap == meta.base_cap  # no slack at all
        burst = _overflow_burst(keys)
        state, res = insert(state, jnp.asarray(burst), jnp.asarray(burst * 3))
        shed = np.asarray(res) == write_mod.STATUS_SPLIT
        assert shed.any()
        state, meta2, info = smo_mod.settle_splits(
            state, meta, cfg, smo, host, burst[shed], burst[shed] * 3, bounds
        )
        assert info["drained"] and info["onmesh"] == 0
        assert meta2 is not meta  # pool rebuilt by the fallback
        lookup, _, insert, smo = _ops(meta2, cfg, mesh)
        stats = np.asarray(state.stats).sum(axis=0)
        assert stats[dex_mod.STAT_DRAINS] == 1
        assert stats[dex_mod.STAT_SMO_SPLITS] == 0
        _check_against_host(lookup, state, host, burst)
        _check_against_host(lookup, state, host, keys[:200])

    def test_zero_shed_drain_is_a_noop(self):
        keys = _dataset(2000, seed=6)
        state, meta, cfg, mesh, host, bounds = _setup(keys)
        empty = np.zeros((0,), np.int64)
        state2, meta2 = write_mod.drain_splits(
            state, meta, cfg, host, empty, empty, bounds
        )
        assert state2 is state and meta2 is meta
        stats = np.asarray(state2.stats).sum(axis=0)
        assert stats[dex_mod.STAT_DRAINS] == 0
        _, _, _, smo = _ops(meta, cfg, mesh)
        state3, meta3, info = smo_mod.settle_splits(
            state, meta, cfg, smo, host, empty, empty, bounds
        )
        assert state3 is state and meta3 is meta
        assert info == {"onmesh": 0, "residual": 0, "rounds": 0,
                        "drained": False}


# ---------------------------------------------------------------------------
# property test: interleaved batches + on-mesh splits == sequential replay
# ---------------------------------------------------------------------------


class TestInterleavedSmoPropertyHypothesis:
    def test_interleaved_batches_with_onmesh_splits_match_host(self):
        pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis "
                   "(optional [test] dep; CI's hyp-installed legs run them)",
        )
        from hypothesis import given, settings, strategies as st

        base = _dataset(800, seed=9, space=20_000)

        @settings(max_examples=10, deadline=None)
        @given(st.data())
        def scenario(data):
            # headroom 0.05: early splits run on-mesh, sustained pressure
            # exhausts the free-list; headroom 0.0: the free-list is born
            # exhausted, so every shed crosses the drain fallback — both
            # must stay bit-identical to the host replay
            headroom = data.draw(
                st.sampled_from([0.05, 0.0]), label="headroom"
            )
            state, meta, cfg, mesh, host, bounds = _setup(
                base, headroom=headroom
            )
            lookup, update, insert, smo = _ops(meta, cfg, mesh)
            n_rounds = data.draw(st.integers(1, 3), label="rounds")
            for rnd in range(n_rounds):
                b = 64
                op_kind = data.draw(
                    st.lists(st.integers(0, 2), min_size=b, max_size=b),
                    label=f"ops{rnd}",
                )
                # narrow key range: one-two leaves serve it, so a couple of
                # rounds of inserts reliably overflow one (leaf slack is
                # FANOUT - per_node = 20) and exercise the SMO engine
                raw = data.draw(
                    st.lists(
                        st.integers(0, 1_500), min_size=b, max_size=b
                    ),
                    label=f"keys{rnd}",
                )
                kind = np.asarray(op_kind)
                karr = np.asarray(raw, np.int64) + 1
                varr = (karr * 7 + rnd).astype(np.int64)
                lk = np.where(kind == 0, karr, KEY_MAX)
                uk = np.where(kind == 1, karr, KEY_MAX)
                ik = np.where(kind == 2, karr, KEY_MAX)
                state, found, vals, _ = lookup(state, jnp.asarray(lk))
                found, vals = np.asarray(found), np.asarray(vals)
                for i in np.where(kind == 0)[0]:
                    hv = host.get(int(karr[i]))
                    assert bool(found[i]) == (hv is not None)
                    if hv is not None:
                        assert int(vals[i]) == hv
                state, ru = update(state, jnp.asarray(uk), jnp.asarray(varr))
                ru = np.asarray(ru)
                for i in np.where(kind == 1)[0]:
                    did = host.update(int(karr[i]), int(varr[i]))
                    assert (ru[i] == write_mod.STATUS_OK) == did
                state, ri = insert(state, jnp.asarray(ik), jnp.asarray(varr))
                ri = np.asarray(ri)
                ins_lanes = kind == 2
                for i in np.where(ins_lanes)[0]:
                    if ri[i] == write_mod.STATUS_OK:
                        host.insert(int(karr[i]), int(varr[i]))
                assert not (ri[ins_lanes] == write_mod.STATUS_SHED).any()
                shed = ins_lanes & (ri == write_mod.STATUS_SPLIT)
                if shed.any():
                    # on-mesh SMO first (settle replays applied lanes into
                    # the host mirror), drain fallback for the residue
                    state, meta, info = smo_mod.settle_splits(
                        state, meta, cfg, smo, host, karr[shed],
                        varr[shed], bounds,
                    )
                    assert info["onmesh"] + info["residual"] == int(
                        shed.sum()
                    )
                    if info["drained"]:
                        lookup, update, insert, smo = _ops(meta, cfg, mesh)
            probe = np.unique(np.concatenate([base[:128]]))
            _check_against_host(lookup, state, host, probe)

        scenario()
