"""Write-path tests: ``make_dex_update`` / ``make_dex_insert`` (Plane B)
vs ``HostBTree`` replay, write-through-and-invalidate cache coherence with
per-leaf versions, shed-insert replay through ``drain_splits``, and a
hypothesis property test interleaving update/insert/lookup batches.

Multi-device write parity (two route partitions, four memory columns,
cross-partition stale-cache rejection) lives in tests/mesh_check.py,
exercised via the ``slow`` subprocess test in tests/test_dex_mesh.py.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import dex as dex_mod
from repro.core import pool as pool_mod
from repro.core import write as write_mod
from repro.core.nodes import FANOUT, KEY_MAX, KEY_MIN
from repro.compat import make_mesh_compat
from repro.core.sim import HostBTree


def _dataset(n, seed=0, space=None):
    rng = np.random.default_rng(seed)
    space = space or 16 * n
    return np.sort(rng.choice(space, size=n, replace=False).astype(np.int64) + 1)


def _setup(keys, *, level_m=1, p_admit_leaf_pct=10, cache_sets=128):
    vals = keys * 5
    pool, meta = pool_mod.build_pool(keys, vals, level_m=level_m, fill=0.7,
                                     n_shards=1)
    mesh = make_mesh_compat((1, 1), ("data", "model"))
    cfg = dex_mod.DexMeshConfig(
        n_route=1, n_memory=1, cache_sets=cache_sets, cache_ways=4,
        p_admit_leaf_pct=p_admit_leaf_pct, route_capacity_factor=2.0,
        policy="fetch",   # exercise the cached one-sided path (writes never
                          # offload; offload-policy lookups are covered in
                          # tests/mesh_check.py)
    )
    bounds = np.array([KEY_MIN, KEY_MAX], np.int64)
    state = dex_mod.init_state(pool, meta, cfg, bounds)
    host = HostBTree(keys, vals, fill=0.7)
    return state, meta, cfg, mesh, host, bounds


def _ops(meta, cfg, mesh, **kw):
    return (
        jax.jit(dex_mod.make_dex_lookup(meta, cfg, mesh)),
        jax.jit(write_mod.make_dex_update(meta, cfg, mesh, **kw)),
        jax.jit(write_mod.make_dex_insert(meta, cfg, mesh, **kw)),
    )


def _check_against_host(lookup, state, host, probe):
    state, found, vals, _ = lookup(state, jnp.asarray(probe))
    found, vals = np.asarray(found), np.asarray(vals)
    for i, k in enumerate(probe):
        hv = host.get(int(k))
        assert bool(found[i]) == (hv is not None), (i, int(k))
        if hv is not None:
            assert int(vals[i]) == hv, (i, int(k), int(vals[i]), hv)
    return state


class TestMeshUpdate:
    def test_parity_with_host_including_batch_duplicates(self):
        keys = _dataset(4000, seed=1)
        state, meta, cfg, mesh, host, _ = _setup(keys)
        lookup, update, _ = _ops(meta, cfg, mesh)
        rng = np.random.default_rng(2)
        uk = rng.choice(keys, size=256).astype(np.int64)
        uk[::7] += 1                      # misses: update is a no-op
        uk[10:14] = uk[10]                # duplicate writers of one key
        uv = rng.integers(0, 1 << 40, size=256).astype(np.int64)
        state, res = update(state, jnp.asarray(uk), jnp.asarray(uv))
        res = np.asarray(res)
        exists = np.isin(uk, keys)
        assert (res[exists] == write_mod.STATUS_OK).all()
        assert (res[~exists] == write_mod.STATUS_MISS).all()
        # sequential replay on the host: last writer in batch order wins
        for k, v in zip(uk, uv):
            host.update(int(k), int(v))
        _check_against_host(lookup, state, host, uk)
        stats = np.asarray(state.stats).sum(axis=0)
        assert stats[dex_mod.STAT_WRITES] == int(exists.sum())
        assert stats[dex_mod.STAT_SPLITS] == 0

    def test_write_through_keeps_own_cache_fresh(self):
        keys = _dataset(3000, seed=3)
        # P_A = 100%: every leaf fetch is admitted, so the target leaf is
        # definitely cached before the update
        state, meta, cfg, mesh, host, _ = _setup(keys, p_admit_leaf_pct=100)
        lookup, update, _ = _ops(meta, cfg, mesh)
        uk = keys[:128].astype(np.int64)
        state, _, _, _ = lookup(state, jnp.asarray(uk))   # warm the cache
        uv = (uk * 13 + 1).astype(np.int64)
        state, res = update(state, jnp.asarray(uk), jnp.asarray(uv))
        assert (np.asarray(res) == write_mod.STATUS_OK).all()
        before = np.asarray(state.stats).sum(axis=0)
        state, found, vals, _ = lookup(state, jnp.asarray(uk))
        after = np.asarray(state.stats).sum(axis=0)
        assert bool(np.asarray(found).all())
        np.testing.assert_array_equal(np.asarray(vals), uv)
        # the refreshed rows must serve from cache, not refetch: the leaf
        # level contributes hits, so hit count grows by at least the batch
        assert after[dex_mod.STAT_HITS] - before[dex_mod.STAT_HITS] >= 128


class TestMeshInsert:
    def test_parity_fresh_and_duplicate_keys(self):
        keys = _dataset(4000, seed=4)
        state, meta, cfg, mesh, host, bounds = _setup(keys)
        lookup, _, insert = _ops(meta, cfg, mesh)
        rng = np.random.default_rng(5)
        ik = (rng.choice(keys[:-1], size=256)
              + rng.integers(1, 3, size=256)).astype(np.int64)
        ik[:40] = rng.choice(keys, size=40)               # dups -> updates
        iv = rng.integers(0, 1 << 40, size=256).astype(np.int64)
        state, res = insert(state, jnp.asarray(ik), jnp.asarray(iv))
        res = np.asarray(res)
        assert (res != write_mod.STATUS_SHED).all()
        for k, v, r in zip(ik, iv, res):
            if r == write_mod.STATUS_OK:
                host.insert(int(k), int(v))
        shed = res == write_mod.STATUS_SPLIT
        if shed.any():
            state, meta = write_mod.drain_splits(
                state, meta, cfg, host, ik[shed], iv[shed], bounds
            )
            lookup, _, insert = _ops(meta, cfg, mesh)
        _check_against_host(lookup, state, host, ik)
        _check_against_host(lookup, state, host, keys[:256])

    def test_overflow_sheds_with_split_status_then_drains(self):
        keys = _dataset(3000, seed=6)
        state, meta, cfg, mesh, host, bounds = _setup(keys)
        lookup, _, insert = _ops(meta, cfg, mesh)
        # burst of fresh keys all targeting the first leaf: guaranteed to
        # exceed its slack (fill 0.7 leaves ~0.3 * FANOUT free slots)
        lo, hi = int(keys[0]), int(keys[1])
        burst = np.arange(lo + 1, lo + 1 + FANOUT, dtype=np.int64)
        burst = burst[~np.isin(burst, keys)][: FANOUT - 8]
        iv = burst * 3
        state, res = insert(state, jnp.asarray(burst), jnp.asarray(iv))
        res = np.asarray(res)
        assert (res == write_mod.STATUS_SPLIT).all(), res
        stats = np.asarray(state.stats).sum(axis=0)
        assert stats[dex_mod.STAT_SPLITS] == burst.size
        # none of the shed keys may have been half-applied
        state, found, _, _ = lookup(state, jnp.asarray(burst))
        assert not np.asarray(found)[~np.isin(burst, keys)].any()
        # drain through the host SMO path and verify everything lands
        state, meta = write_mod.drain_splits(
            state, meta, cfg, host, burst, iv, bounds
        )
        assert host.splits > 0
        lookup, _, insert = _ops(meta, cfg, mesh)
        _check_against_host(lookup, state, host, burst)
        _check_against_host(lookup, state, host, keys[:200])

    def test_insert_invalidates_own_cached_row(self):
        keys = _dataset(3000, seed=7)
        state, meta, cfg, mesh, host, _ = _setup(keys, p_admit_leaf_pct=100)
        lookup, _, insert = _ops(meta, cfg, mesh)
        probe = keys[:64].astype(np.int64)
        state, _, _, _ = lookup(state, jnp.asarray(probe))  # cache leaf rows
        # insert fresh keys adjacent to the cached leaves' keys
        fresh = probe + 1
        fresh = np.where(np.isin(fresh, keys), probe - 1, fresh)
        fresh = fresh[~np.isin(fresh, keys)]
        state, res = insert(state, jnp.asarray(fresh), jnp.asarray(fresh * 9))
        ok = np.asarray(res) == write_mod.STATUS_OK
        for k in fresh[ok]:
            host.insert(int(k), int(k) * 9)
        # the (invalidated) rows must be refetched and show the new keys
        _check_against_host(lookup, state, host, fresh[ok])
        _check_against_host(lookup, state, host, probe)


class TestStaleVersionRejection:
    def test_bumped_version_forces_refetch(self):
        """A cached row whose per-leaf version is behind the version table
        must be ignored — the mesh refetches the authoritative row.  This is
        the single-device probe of the cross-chip invalidation that
        tests/mesh_check.py exercises on 8 devices."""
        keys = _dataset(2000, seed=8)
        state, meta, cfg, mesh, host, _ = _setup(keys, p_admit_leaf_pct=100)
        lookup, _, _ = _ops(meta, cfg, mesh)
        probe = keys[:64].astype(np.int64)
        state, found, vals, _ = lookup(state, jnp.asarray(probe))
        assert bool(np.asarray(found).all())
        # corrupt every cached value row (pretend the rows went stale)...
        poisoned = state._replace(
            cache=state.cache._replace(
                values=jnp.zeros_like(state.cache.values) - 77
            )
        )
        # ...control: WITHOUT a version bump the poison is served from cache
        _, f2, v2, _ = lookup(poisoned, jnp.asarray(probe))
        assert (np.asarray(v2)[np.asarray(f2)] == -77).any()
        # ...with the version table bumped, every stale row is rejected and
        # the refetched values are correct again
        bumped = poisoned._replace(versions=poisoned.versions + 1)
        st3, f3, v3, _ = lookup(bumped, jnp.asarray(probe))
        assert bool(np.asarray(f3).all())
        np.testing.assert_array_equal(np.asarray(v3), probe * 5)


# ---------------------------------------------------------------------------
# property test: interleaved mixed batches == sequential host replay
# ---------------------------------------------------------------------------


class TestInterleavedPropertyHypothesis:
    def test_interleaved_batches_match_host_replay(self):
        pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis "
                   "(optional [test] dep; CI's hyp-installed legs run them)",
        )
        from hypothesis import given, settings, strategies as st

        base = _dataset(800, seed=9, space=20_000)

        @settings(max_examples=15, deadline=None)
        @given(st.data())
        def scenario(data):
            state, meta, cfg, mesh, host, bounds = _setup(base)
            lookup, update, insert = _ops(meta, cfg, mesh)
            n_rounds = data.draw(st.integers(1, 3), label="rounds")
            for rnd in range(n_rounds):
                b = 64
                op_kind = data.draw(
                    st.lists(st.integers(0, 2), min_size=b, max_size=b),
                    label=f"ops{rnd}",
                )
                raw = data.draw(
                    st.lists(
                        st.integers(0, 25_000), min_size=b, max_size=b
                    ),
                    label=f"keys{rnd}",
                )
                kind = np.asarray(op_kind)
                karr = np.asarray(raw, np.int64) + 1
                varr = (karr * 7 + rnd).astype(np.int64)
                lk = np.where(kind == 0, karr, KEY_MAX)
                uk = np.where(kind == 1, karr, KEY_MAX)
                ik = np.where(kind == 2, karr, KEY_MAX)
                state, found, vals, _ = lookup(state, jnp.asarray(lk))
                found, vals = np.asarray(found), np.asarray(vals)
                for i in np.where(kind == 0)[0]:
                    hv = host.get(int(karr[i]))
                    assert bool(found[i]) == (hv is not None)
                    if hv is not None:
                        assert int(vals[i]) == hv
                state, ru = update(state, jnp.asarray(uk), jnp.asarray(varr))
                ru = np.asarray(ru)
                for i in np.where(kind == 1)[0]:
                    did = host.update(int(karr[i]), int(varr[i]))
                    assert (ru[i] == write_mod.STATUS_OK) == did
                state, ri = insert(state, jnp.asarray(ik), jnp.asarray(varr))
                ri = np.asarray(ri)
                ins_lanes = kind == 2
                for i in np.where(ins_lanes)[0]:
                    if ri[i] == write_mod.STATUS_OK:
                        host.insert(int(karr[i]), int(varr[i]))
                assert not (ri[ins_lanes] == write_mod.STATUS_SHED).any()
                shed = ins_lanes & (ri == write_mod.STATUS_SPLIT)
                if shed.any():
                    state, meta = write_mod.drain_splits(
                        state, meta, cfg, host, karr[shed], varr[shed],
                        bounds,
                    )
                    lookup, update, insert = _ops(meta, cfg, mesh)
            # final audit over every key ever touched
            probe = np.unique(np.concatenate([base[:128]]))
            _check_against_host(lookup, state, host, probe)

        scenario()
