"""Per-architecture smoke tests: a REDUCED config of the same family runs
one forward + one train-grad step + one decode step on CPU, asserting output
shapes and the absence of NaNs.  Full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS, get_config
from repro.models import model as M
from repro.models.config import SHAPES, cell_applicable

ARCH_IDS = sorted(ARCHS)


def _batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    tokens = rng.integers(0, cfg.vocab, size=(b, s)).astype(np.int32)
    labels = np.roll(tokens, -1, axis=1).astype(np.int32)
    batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
    if cfg.encdec:
        batch["enc_emb"] = jnp.asarray(
            rng.standard_normal((b, 32, cfg.d_model)), jnp.dtype(cfg.dtype)
        )
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch_id):
        cfg = get_config(arch_id).reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        batch = _batch(cfg)
        logits, aux = M.forward(cfg, params, batch["tokens"],
                                enc_emb=batch.get("enc_emb"))
        assert logits.shape == (2, 16, cfg.vocab)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), "NaN/inf logits"
        assert bool(jnp.isfinite(aux)), "NaN aux"

    def test_train_grad_step(self, arch_id):
        cfg = get_config(arch_id).reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(1))
        batch = _batch(cfg, seed=1)

        def loss(p):
            l, _ = M.loss_fn(cfg, p, batch)
            return l

        val, grads = jax.jit(jax.value_and_grad(loss))(params)
        assert bool(jnp.isfinite(val)), "NaN loss"
        flat = jax.tree.leaves(grads)
        assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all()) for g in flat), \
            "NaN gradient"
        # loss magnitude sanity: near ln(vocab) at init
        assert 0.5 * np.log(cfg.vocab) < float(val) < 3 * np.log(cfg.vocab)

    def test_decode_step(self, arch_id):
        cfg = get_config(arch_id).reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(2))
        b, max_len = 2, 32
        cache = M.init_decode_cache(cfg, b, max_len, enc_len=32)
        if cfg.encdec:
            rng = np.random.default_rng(3)
            enc_emb = jnp.asarray(
                rng.standard_normal((b, 32, cfg.d_model)), jnp.dtype(cfg.dtype)
            )
            cache = M.prefill_cross_kv(cfg, params, enc_emb, cache)
        tok = jnp.zeros((b, 1), jnp.int32)
        step = jax.jit(lambda p, t, c, pos: M.decode_step(cfg, p, t, c, pos))
        logits, cache = step(params, tok, cache, jnp.int32(0))
        assert logits.shape == (b, cfg.vocab)
        assert bool(jnp.isfinite(logits).all())
        # a second step must consume the updated cache without shape drift
        logits2, cache2 = step(params, tok, cache, jnp.int32(1))
        assert logits2.shape == (b, cfg.vocab)
        assert bool(jnp.isfinite(logits2).all())

    def test_decode_matches_prefill(self, arch_id):
        """Token-by-token decode must reproduce the teacher-forced forward
        pass (cache correctness)."""
        cfg = get_config(arch_id).reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(4))
        b, s = 1, 8
        batch = _batch(cfg, b=b, s=s, seed=7)
        logits_full, _ = M.forward(cfg, params, batch["tokens"],
                                   enc_emb=batch.get("enc_emb"))
        cache = M.init_decode_cache(cfg, b, max_len=s, enc_len=32)
        if cfg.encdec:
            cache = M.prefill_cross_kv(cfg, params, batch["enc_emb"], cache)
        outs = []
        for t in range(s):
            tok = batch["tokens"][:, t : t + 1]
            lg, cache = M.decode_step(cfg, params, tok, cache, jnp.int32(t))
            outs.append(lg)
        dec = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(dec, np.float32),
            np.asarray(logits_full, np.float32),
            atol=5e-2, rtol=5e-2,
        )


def test_all_archs_have_param_counts():
    for arch_id, cfg in ARCHS.items():
        n = cfg.param_count()
        assert n > 0
        na = cfg.active_param_count()
        assert 0 < na <= n


def test_cell_applicability_rules():
    skips = []
    for arch_id, cfg in ARCHS.items():
        for shape in SHAPES:
            ok, why = cell_applicable(cfg, shape)
            if not ok:
                skips.append((arch_id, shape.name, why))
    skipped_archs = {a for a, s, _ in skips if s == "long_500k"}
    # exactly the 8 pure full-attention archs skip long_500k
    assert skipped_archs == set(ARCHS) - {"zamba2-2.7b", "falcon-mamba-7b"}
