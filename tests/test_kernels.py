"""Per-kernel validation: sweep shapes/dtypes, assert_allclose vs ref.py
oracles (kernels run in interpret mode on CPU)."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import pool as pool_mod
from repro.core.nodes import FANOUT, KEY_MAX
from repro.kernels import ops, ref


def _keys(n, seed=0, hi=None):
    rng = np.random.default_rng(seed)
    hi = hi or 8 * n
    return np.sort(rng.choice(hi, size=n, replace=False).astype(np.int64) + 1)


# ---------------------------------------------------------------------------
# node_search
# ---------------------------------------------------------------------------


class TestNodeSearch:
    @pytest.mark.parametrize("b", [1, 17, 256, 300])
    def test_matches_ref(self, b):
        rng = np.random.default_rng(b)
        rows = np.sort(
            rng.integers(1, 2**62, size=(b, FANOUT), dtype=np.int64), axis=1
        )
        vals = rng.integers(0, 2**62, size=(b, FANOUT), dtype=np.int64)
        # half the queries hit exactly, half fall between keys
        q = rows[np.arange(b), rng.integers(0, FANOUT, size=b)].copy()
        q[::2] = q[::2] + 1
        slot, found, value = ops.node_search(rows, q, vals)
        rslot, rfound, rvalue = ref.node_search_ref(rows, q, vals)
        np.testing.assert_array_equal(np.asarray(slot), np.asarray(rslot))
        np.testing.assert_array_equal(np.asarray(found), np.asarray(rfound))
        np.testing.assert_array_equal(np.asarray(value), np.asarray(rvalue))

    def test_extreme_keys(self):
        # keys spanning the full signed 64-bit range, incl. negatives
        rows = np.sort(
            np.array([[-(2**62), -5, 0, 3, 2**62] + [2**63 - 2] * (FANOUT - 5)]),
            axis=1,
        ).astype(np.int64)
        vals = np.arange(FANOUT, dtype=np.int64)[None] * 7
        for q in [-(2**62), -5, -4, 0, 3, 2**62, 2**62 + 9]:
            qa = np.array([q], dtype=np.int64)
            s, f, v = ops.node_search(rows, qa, vals)
            rs, rf, rv = ref.node_search_ref(rows, qa, vals)
            assert int(s[0]) == int(rs[0]), q
            assert bool(f[0]) == bool(rf[0]), q
            assert int(v[0]) == int(rv[0]), q


# ---------------------------------------------------------------------------
# subtree_walk
# ---------------------------------------------------------------------------


class TestSubtreeWalk:
    @pytest.mark.parametrize("level_m,n", [(1, 2000), (2, 20_000)])
    def test_matches_ref_per_subtree(self, level_m, n):
        keys = _keys(n, seed=level_m)
        pool, meta = pool_mod.build_pool(keys, keys * 5, level_m=level_m)
        rng = np.random.default_rng(3)
        q = rng.choice(keys, size=256).astype(np.int64)
        q[::3] += 1  # misses
        st = np.asarray(pool_mod.top_walk(pool, meta, jnp.asarray(q)))
        s0 = int(st[0])
        qs = q[st == s0]
        f_k, v_k = ops.subtree_walk(
            pool.pool_keys[s0],
            pool.pool_children[s0],
            pool.pool_values[s0],
            qs,
            levels=meta.levels_in_subtree,
        )
        f_r, v_r = ref.subtree_walk_ref(
            pool.pool_keys[s0],
            pool.pool_children[s0],
            pool.pool_values[s0],
            qs,
            levels=meta.levels_in_subtree,
        )
        np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_r))
        np.testing.assert_array_equal(
            np.asarray(v_k)[np.asarray(f_r)], np.asarray(v_r)[np.asarray(f_r)]
        )

    def test_small_batch_padding(self):
        keys = _keys(500, seed=9)
        pool, meta = pool_mod.build_pool(keys, keys, level_m=1)
        q = keys[:5]
        f, v = ops.subtree_walk(
            pool.pool_keys[0], pool.pool_children[0], pool.pool_values[0],
            q, levels=meta.levels_in_subtree,
        )
        st = np.asarray(pool_mod.top_walk(pool, meta, jnp.asarray(q)))
        mask = st == 0
        assert bool(np.all(np.asarray(f)[mask]))


# ---------------------------------------------------------------------------
# leaf_write
# ---------------------------------------------------------------------------


class TestLeafWrite:
    def _case(self, q, s, seed):
        """Random leaf rows plus staged updates (distinct slots) and staged
        inserts (sorted, distinct from the row, within slack) — the caller
        contract that core/write.py enforces."""
        rng = np.random.default_rng(seed)
        k = np.full((q, FANOUT), KEY_MAX, np.int64)
        v = np.zeros((q, FANOUT), np.int64)
        us = np.full((q, s), -1, np.int32)
        uv = np.zeros((q, s), np.int64)
        ik = np.full((q, s), KEY_MAX, np.int64)
        iv = np.zeros((q, s), np.int64)
        for i in range(q):
            occ = int(rng.integers(0, FANOUT - s + 1))
            keys = np.sort(
                rng.choice(1 << 30, size=occ, replace=False).astype(np.int64)
            ) * 2 + 2                          # even keys
            k[i, :occ] = keys
            v[i, :occ] = keys * 3
            nu = int(rng.integers(0, min(occ, s) + 1))
            if nu:
                us[i, :nu] = rng.choice(occ, size=nu, replace=False)
                uv[i, :nu] = rng.integers(0, 1 << 40, size=nu)
            ni = int(rng.integers(0, min(s, FANOUT - occ) + 1))
            if ni:
                newk = np.sort(
                    rng.choice(1 << 30, size=ni, replace=False).astype(np.int64)
                ) * 2 + 1                      # odd: distinct from the row
                ik[i, :ni] = newk
                iv[i, :ni] = newk * 5
        return map(jnp.asarray, (k, v, us, uv, ik, iv))

    @pytest.mark.parametrize("q", [1, 8, 37, 130])
    def test_matches_ref(self, q):
        args = list(self._case(q, s=16, seed=q))
        got = ops.leaf_write(*args)
        want = ref.leaf_write_ref(*args)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    def test_full_width_staging(self):
        # staged width == FANOUT: a completely empty row filled in one batch
        k = np.full((2, FANOUT), KEY_MAX, np.int64)
        v = np.zeros((2, FANOUT), np.int64)
        us = np.full((2, FANOUT), -1, np.int32)
        uv = np.zeros((2, FANOUT), np.int64)
        ik = np.full((2, FANOUT), KEY_MAX, np.int64)
        iv = np.zeros((2, FANOUT), np.int64)
        ik[0] = np.arange(1, FANOUT + 1, dtype=np.int64) * 7
        iv[0] = ik[0] * 11
        args = list(map(jnp.asarray, (k, v, us, uv, ik, iv)))
        gk, gv, gocc = ops.leaf_write(*args)
        rk, rv, rocc = ref.leaf_write_ref(*args)
        np.testing.assert_array_equal(np.asarray(gk), np.asarray(rk))
        np.testing.assert_array_equal(np.asarray(gv), np.asarray(rv))
        np.testing.assert_array_equal(np.asarray(gocc), np.asarray(rocc))
        assert np.asarray(gocc).tolist() == [FANOUT, 0]
        np.testing.assert_array_equal(np.asarray(gk)[0], ik[0])

    def test_negative_and_extreme_keys(self):
        k = np.full((1, FANOUT), KEY_MAX, np.int64)
        v = np.zeros((1, FANOUT), np.int64)
        k[0, :4] = [-(2**62), -7, 0, 2**62]
        v[0, :4] = [1, 2, 3, 4]
        us = np.array([[1, -1]], np.int32)
        uv = np.array([[99, 0]], np.int64)
        ik = np.array([[-(2**61), 2**61]], np.int64)
        iv = np.array([[5, 6]], np.int64)
        args = list(map(jnp.asarray, (k, v, us, uv, ik, iv)))
        gk, gv, gocc = ops.leaf_write(*args)
        rk, rv, rocc = ref.leaf_write_ref(*args)
        np.testing.assert_array_equal(np.asarray(gk), np.asarray(rk))
        np.testing.assert_array_equal(np.asarray(gv), np.asarray(rv))
        assert int(gocc[0]) == 6
        assert np.asarray(gk)[0, :6].tolist() == [
            -(2**62), -(2**61), -7, 0, 2**61, 2**62
        ]
        assert np.asarray(gv)[0, :6].tolist() == [1, 5, 99, 3, 6, 4]


# ---------------------------------------------------------------------------
# leaf_split
# ---------------------------------------------------------------------------


class TestLeafSplit:
    def _case(self, q, s, seed, *, force_overflow=False):
        """Random leaf rows plus staged inserts (sorted, distinct from the
        row) — the core/smo.py caller contract.  ``force_overflow`` draws
        occupancy + staging so every lane must split."""
        rng = np.random.default_rng(seed)
        k = np.full((q, FANOUT), KEY_MAX, np.int64)
        v = np.zeros((q, FANOUT), np.int64)
        ik = np.full((q, s), KEY_MAX, np.int64)
        iv = np.zeros((q, s), np.int64)
        for i in range(q):
            if force_overflow:
                occ = FANOUT
                ni = int(rng.integers(1, s + 1))
            else:
                occ = int(rng.integers(0, FANOUT + 1))
                ni = int(rng.integers(0, s + 1))
            keys = np.sort(
                rng.choice(1 << 30, size=occ, replace=False).astype(np.int64)
            ) * 2 + 2                          # even keys
            k[i, :occ] = keys
            v[i, :occ] = keys * 3
            if ni:
                newk = np.sort(
                    rng.choice(1 << 30, size=ni, replace=False).astype(np.int64)
                ) * 2 + 1                      # odd: distinct from the row
                ik[i, :ni] = newk
                iv[i, :ni] = newk * 5
        return list(map(jnp.asarray, (k, v, ik, iv)))

    @pytest.mark.parametrize("q", [1, 8, 37, 130])
    def test_matches_ref(self, q):
        args = self._case(q, s=FANOUT, seed=q)
        got = ops.leaf_split(*args)
        want = ref.leaf_split_ref(*args)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    def test_overflow_always_splits_in_halves(self):
        q = 16
        args = self._case(q, s=FANOUT, seed=3, force_overflow=True)
        lk, lv, rk, rv, occl, occr, sep, did = map(
            np.asarray, ops.leaf_split(*args)
        )
        wk = np.asarray(args[0])
        wik = np.asarray(args[2])
        assert (did == 1).all()
        for i in range(q):
            merged = np.sort(np.concatenate(
                [wk[i][wk[i] != KEY_MAX], wik[i][wik[i] != KEY_MAX]]
            ))
            m = merged.size
            assert int(occl[i]) == m // 2
            assert int(occr[i]) == m - m // 2
            np.testing.assert_array_equal(lk[i][: m // 2], merged[: m // 2])
            np.testing.assert_array_equal(rk[i][: m - m // 2], merged[m // 2:])
            assert int(sep[i]) == int(merged[m // 2])
            # left/right key sets partition around the separator
            assert (lk[i][lk[i] != KEY_MAX] < sep[i]).all()
            assert (rk[i][rk[i] != KEY_MAX] >= sep[i]).all()

    def test_no_overflow_is_plain_merge(self):
        # m <= FANOUT must reproduce leaf_write's merge in the left row
        q = 9
        rng = np.random.default_rng(11)
        k = np.full((q, FANOUT), KEY_MAX, np.int64)
        v = np.zeros((q, FANOUT), np.int64)
        ik = np.full((q, FANOUT), KEY_MAX, np.int64)
        iv = np.zeros((q, FANOUT), np.int64)
        for i in range(q):
            occ = int(rng.integers(0, FANOUT - 4))
            ni = int(rng.integers(0, FANOUT - occ + 1))
            keys = np.sort(
                rng.choice(1 << 20, size=occ, replace=False).astype(np.int64)
            ) * 2 + 2
            k[i, :occ] = keys
            v[i, :occ] = keys * 3
            if ni:
                newk = np.sort(
                    rng.choice(1 << 20, size=ni, replace=False).astype(np.int64)
                ) * 2 + 1
                ik[i, :ni] = newk
                iv[i, :ni] = newk * 5
        args = list(map(jnp.asarray, (k, v, ik, iv)))
        lk, lv, rk, rv, occl, occr, sep, did = ops.leaf_split(*args)
        us = np.full((q, FANOUT), -1, np.int32)
        uv = np.zeros((q, FANOUT), np.int64)
        mk, mv, mocc = ref.leaf_write_ref(
            args[0], args[1], jnp.asarray(us), jnp.asarray(uv), args[2], args[3]
        )
        assert (np.asarray(did) == 0).all()
        np.testing.assert_array_equal(np.asarray(lk), np.asarray(mk))
        np.testing.assert_array_equal(np.asarray(lv), np.asarray(mv))
        np.testing.assert_array_equal(np.asarray(occl), np.asarray(mocc))
        assert (np.asarray(occr) == 0).all()
        assert (np.asarray(rk) == KEY_MAX).all()
        assert (np.asarray(sep) == KEY_MAX).all()

    def test_negative_and_extreme_keys(self):
        k = np.full((1, FANOUT), KEY_MAX, np.int64)
        v = np.zeros((1, FANOUT), np.int64)
        keys = np.sort(np.concatenate([
            np.array([-(2**62), -7, 0, 2**61], np.int64),
            np.arange(2, 2 * (FANOUT - 4) + 1, 2, dtype=np.int64),
        ]))
        k[0] = keys
        v[0] = np.arange(FANOUT, dtype=np.int64) + 1
        ik = np.full((1, 8), KEY_MAX, np.int64)
        iv = np.zeros((1, 8), np.int64)
        ik[0, :3] = [-(2**61), 3, 2**62]
        iv[0, :3] = [7, 8, 9]
        args = list(map(jnp.asarray, (k, v, ik, iv)))
        got = ops.leaf_split(*args)
        want = ref.leaf_split_ref(*args)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
        assert int(got[7][0]) == 1  # FANOUT + 3 merged records must split


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


class TestFlashAttention:
    @pytest.mark.parametrize(
        "b,h,hkv,s,d",
        [(1, 4, 4, 128, 64), (2, 8, 2, 256, 64), (1, 4, 1, 128, 128)],
    )
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_matches_ref(self, b, h, hkv, s, d, dtype):
        rng = np.random.default_rng(42)
        q = jnp.asarray(rng.standard_normal((b, h, s, d)), dtype)
        k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), dtype)
        v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), dtype)
        out = ops.flash_attention(q, k, v, causal=True)
        expect = ref.flash_attention_ref(q, k, v, causal=True)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(
            np.asarray(out, np.float32),
            np.asarray(expect, np.float32),
            atol=tol, rtol=tol,
        )

    def test_non_causal(self):
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
        out = ops.flash_attention(q, k, v, causal=False)
        expect = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(expect), atol=2e-5, rtol=2e-5
        )

    def test_cross_lengths_causal_offset(self):
        """Decode-style: Sq < Sk with causal alignment at the end."""
        rng = np.random.default_rng(1)
        q = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, 2, 384, 64)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, 2, 384, 64)), jnp.float32)
        out = ops.flash_attention(q, k, v, causal=True)
        expect = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(expect), atol=2e-5, rtol=2e-5
        )


# ---------------------------------------------------------------------------
# paged_attention
# ---------------------------------------------------------------------------


class TestPagedAttention:
    @pytest.mark.parametrize("b,h,hkv,d,page,ppr", [(2, 8, 2, 64, 16, 4),
                                                    (1, 4, 4, 128, 32, 2)])
    def test_matches_ref(self, b, h, hkv, d, page, ppr):
        rng = np.random.default_rng(5)
        n_pages = b * ppr + 3
        q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
        kp = jnp.asarray(rng.standard_normal((n_pages, page, hkv, d)), jnp.float32)
        vp = jnp.asarray(rng.standard_normal((n_pages, page, hkv, d)), jnp.float32)
        table = rng.permutation(n_pages)[: b * ppr].reshape(b, ppr).astype(np.int32)
        seq_lens = rng.integers(1, ppr * page + 1, size=b).astype(np.int32)
        out = ops.paged_attention(q, kp, vp, jnp.asarray(table), jnp.asarray(seq_lens))
        expect = ref.paged_attention_ref(q, kp, vp, jnp.asarray(table),
                                         jnp.asarray(seq_lens))
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(expect), atol=3e-5, rtol=3e-5
        )


# ---------------------------------------------------------------------------
# mamba_scan
# ---------------------------------------------------------------------------


class TestMambaScan:
    @pytest.mark.parametrize("b,l,d,n", [(1, 32, 128, 16), (2, 64, 256, 16)])
    def test_matches_ref(self, b, l, d, n):
        rng = np.random.default_rng(11)
        delta = jnp.asarray(np.abs(rng.standard_normal((b, l, d))) * 0.1 + 0.01,
                            jnp.float32)
        A = jnp.asarray(-np.abs(rng.standard_normal((d, n))) - 0.1, jnp.float32)
        Bm = jnp.asarray(rng.standard_normal((b, l, n)), jnp.float32)
        C = jnp.asarray(rng.standard_normal((b, l, n)), jnp.float32)
        x = jnp.asarray(rng.standard_normal((b, l, d)), jnp.float32)
        out = ops.mamba_scan(delta, A, Bm, C, x)
        expect = ref.mamba_scan_ref(delta, A, Bm, C, x)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(expect), atol=1e-4, rtol=1e-4
        )
