"""Unit + property tests for the flat array B+-tree (core/btree.py)."""

import numpy as np
import pytest

# optional-hypothesis shim: property tests skip individually when
# hypothesis is absent, plain tests keep running (tests/_hypothesis_compat)
from _hypothesis_compat import given, settings, st  # noqa: E402

from repro.core import btree
from repro.core.nodes import FANOUT, KEY_MAX


def make_keys(n, seed=0, lo=0, hi=None):
    rng = np.random.default_rng(seed)
    hi = hi if hi is not None else max(4 * n, 1024)
    keys = rng.choice(np.arange(lo + 1, lo + hi, dtype=np.int64), size=n, replace=False)
    return np.sort(keys)


class TestBulkBuild:
    def test_single_leaf(self):
        keys = np.arange(1, 10, dtype=np.int64)
        tree, meta = btree.bulk_build(keys)
        assert meta.height == 1
        btree.validate(tree, meta)
        k, v = btree.tree_items(tree)
        np.testing.assert_array_equal(k, keys)
        np.testing.assert_array_equal(v, keys)

    @pytest.mark.parametrize("n", [1, 7, 44, 45, 1000, 20_000])
    def test_sizes(self, n):
        keys = make_keys(n, seed=n)
        tree, meta = btree.bulk_build(keys, values=keys * 3)
        btree.validate(tree, meta)
        k, v = btree.tree_items(tree)
        np.testing.assert_array_equal(k, keys)
        np.testing.assert_array_equal(v, keys * 3)

    def test_rejects_unsorted(self):
        with pytest.raises(ValueError):
            btree.bulk_build(np.array([3, 1, 2], dtype=np.int64))

    def test_rejects_dupes(self):
        with pytest.raises(ValueError):
            btree.bulk_build(np.array([1, 1, 2], dtype=np.int64))

    @pytest.mark.parametrize("fill", [0.5, 0.7, 1.0])
    def test_fill_factors(self, fill):
        keys = make_keys(500, seed=2)
        tree, meta = btree.bulk_build(keys, fill=fill)
        btree.validate(tree, meta)
        assert meta.keys_per_leaf == max(2, int(FANOUT * fill))


class TestLookup:
    def test_hits_and_misses(self):
        keys = make_keys(5000, seed=1)
        tree, meta = btree.bulk_build(keys, values=keys + 7)
        probe_hit = keys[::17]
        found, vals = btree.bulk_lookup(tree, probe_hit, height=meta.height)
        assert bool(np.all(found))
        np.testing.assert_array_equal(np.asarray(vals), probe_hit + 7)

        all_set = set(keys.tolist())
        miss = np.array(
            [k for k in range(1, 40000, 997) if k not in all_set], dtype=np.int64
        )
        found, _ = btree.bulk_lookup(tree, miss, height=meta.height)
        assert not bool(np.any(found))

    def test_path_shape(self):
        keys = make_keys(5000, seed=3)
        tree, meta = btree.bulk_build(keys)
        q = keys[:32]
        found, vals, path = btree.bulk_lookup(
            tree, q, height=meta.height, with_path=True
        )
        assert path.shape == (32, meta.height)
        # first column is the root for every query
        assert bool(np.all(np.asarray(path[:, 0]) == int(tree.root)))
        # last column is a leaf
        lv = np.asarray(tree.level)
        assert bool(np.all(lv[np.asarray(path[:, -1])] == 0))


class TestUpdate:
    def test_update_existing(self):
        keys = make_keys(3000, seed=4)
        tree, meta = btree.bulk_build(keys, values=keys)
        q = keys[100:200]
        tree, ok = btree.bulk_update(tree, q, q * 10, height=meta.height)
        assert bool(np.all(ok))
        _, vals = btree.bulk_lookup(tree, q, height=meta.height)
        np.testing.assert_array_equal(np.asarray(vals), q * 10)
        # untouched keys unchanged
        other = keys[500:550]
        _, vals = btree.bulk_lookup(tree, other, height=meta.height)
        np.testing.assert_array_equal(np.asarray(vals), other)

    def test_update_missing_is_noop(self):
        keys = make_keys(100, seed=5, hi=10_000)
        tree, meta = btree.bulk_build(keys, values=keys)
        missing = np.setdiff1d(
            np.arange(1, 200, dtype=np.int64), keys
        )[:16]
        tree, ok = btree.bulk_update(tree, missing, missing * 2, height=meta.height)
        assert not bool(np.any(ok))
        k, v = btree.tree_items(tree)
        np.testing.assert_array_equal(k, keys)
        np.testing.assert_array_equal(v, keys)


class TestInsert:
    def test_fast_path_no_overflow(self):
        keys = make_keys(2000, seed=6, hi=100_000)
        tree, meta = btree.bulk_build(keys)
        new = np.setdiff1d(make_keys(300, seed=7, hi=100_000), keys)
        tree, meta, ok = btree.batch_insert(tree, meta, new, new * 2)
        assert bool(np.all(ok))
        found, vals = btree.bulk_lookup(tree, new, height=meta.height)
        assert bool(np.all(found))
        np.testing.assert_array_equal(np.asarray(vals), new * 2)
        # old keys intact
        found, _ = btree.bulk_lookup(tree, keys, height=meta.height)
        assert bool(np.all(found))

    def test_insert_triggers_split(self):
        # full-fill build so any insert overflows a leaf
        keys = np.arange(1, 2001, dtype=np.int64) * 10
        tree, meta = btree.bulk_build(keys, fill=1.0)
        new = keys[:256] + 1  # interleave
        tree, meta, ok = btree.batch_insert(tree, meta, new, new)
        assert bool(np.all(ok))
        btree.validate(tree, meta)
        found, _ = btree.bulk_lookup(tree, np.concatenate([keys, new]), height=meta.height)
        assert bool(np.all(found))

    def test_insert_duplicate_updates_value(self):
        keys = make_keys(500, seed=8)
        tree, meta = btree.bulk_build(keys, values=keys)
        dup = keys[10:20]
        tree, meta, ok = btree.batch_insert(tree, meta, dup, dup * 5)
        assert bool(np.all(ok))
        _, vals = btree.bulk_lookup(tree, dup, height=meta.height)
        np.testing.assert_array_equal(np.asarray(vals), dup * 5)
        k, _ = btree.tree_items(tree)
        assert k.size == keys.size  # no new keys


class TestDelete:
    def test_delete_some(self):
        keys = make_keys(3000, seed=9)
        tree, meta = btree.bulk_build(keys, values=keys)
        gone = keys[::13]
        tree, ok = btree.bulk_delete(tree, gone, height=meta.height)
        assert bool(np.all(ok))
        found, _ = btree.bulk_lookup(tree, gone, height=meta.height)
        assert not bool(np.any(found))
        remain = np.setdiff1d(keys, gone)
        found, vals = btree.bulk_lookup(tree, remain, height=meta.height)
        assert bool(np.all(found))
        np.testing.assert_array_equal(np.asarray(vals), remain)

    def test_delete_missing(self):
        keys = make_keys(200, seed=10, hi=5000)
        tree, meta = btree.bulk_build(keys)
        missing = np.setdiff1d(np.arange(1, 400, dtype=np.int64), keys)[:8]
        tree, ok = btree.bulk_delete(tree, missing, height=meta.height)
        assert not bool(np.any(ok))
        k, _ = btree.tree_items(tree)
        np.testing.assert_array_equal(k, keys)

    def test_delete_same_leaf_multiple(self):
        keys = np.arange(1, 100, dtype=np.int64)
        tree, meta = btree.bulk_build(keys)
        gone = np.array([5, 6, 7, 8, 9], dtype=np.int64)  # same leaf
        tree, ok = btree.bulk_delete(tree, gone, height=meta.height)
        assert bool(np.all(ok))
        k, _ = btree.tree_items(tree)
        np.testing.assert_array_equal(k, np.setdiff1d(keys, gone))


class TestScan:
    def test_scan_100(self):
        keys = make_keys(5000, seed=11)
        tree, meta = btree.bulk_build(keys, values=keys * 2)
        starts = keys[[0, 100, 2345, 4990]]
        out_k, out_v = btree.bulk_scan(tree, starts, height=meta.height, count=100)
        for i, s in enumerate(starts):
            expect = keys[keys >= s][:100]
            got = np.asarray(out_k[i])
            got = got[got != KEY_MAX]
            np.testing.assert_array_equal(got, expect)
            gv = np.asarray(out_v[i])[: got.size]
            np.testing.assert_array_equal(gv, expect * 2)

    def test_scan_from_nonexistent_start(self):
        keys = (np.arange(1, 1001, dtype=np.int64)) * 10
        tree, meta = btree.bulk_build(keys)
        starts = np.array([15, 995], dtype=np.int64)  # between keys
        out_k, _ = btree.bulk_scan(tree, starts, height=meta.height, count=10)
        got = np.asarray(out_k[0])
        np.testing.assert_array_equal(got[got != KEY_MAX], keys[keys >= 15][:10])

    def test_scan_past_end(self):
        keys = make_keys(100, seed=12)
        tree, meta = btree.bulk_build(keys)
        starts = keys[-3:]
        out_k, _ = btree.bulk_scan(tree, starts, height=meta.height, count=50)
        got = np.asarray(out_k[-1])
        np.testing.assert_array_equal(got[got != KEY_MAX], keys[keys >= starts[-1]])


# ---------------------------------------------------------------------------
# Property-based tests
# ---------------------------------------------------------------------------

key_sets = st.sets(
    st.integers(min_value=1, max_value=2**40), min_size=2, max_size=400
)


@settings(max_examples=25, deadline=None)
@given(ks=key_sets)
def test_prop_build_lookup_roundtrip(ks):
    keys = np.array(sorted(ks), dtype=np.int64)
    tree, meta = btree.bulk_build(keys, values=keys ^ 0xABCD)
    btree.validate(tree, meta)
    found, vals = btree.bulk_lookup(tree, keys, height=meta.height)
    assert bool(np.all(found))
    np.testing.assert_array_equal(np.asarray(vals), keys ^ 0xABCD)


@settings(max_examples=25, deadline=None)
@given(
    ks=key_sets,
    ins=st.sets(st.integers(min_value=1, max_value=2**40), min_size=1, max_size=100),
)
def test_prop_insert_then_all_present(ks, ins):
    keys = np.array(sorted(ks), dtype=np.int64)
    tree, meta = btree.bulk_build(keys, values=keys)
    new = np.array(sorted(ins), dtype=np.int64)
    tree, meta, _ = btree.batch_insert(tree, meta, new, new + 1)
    union = np.union1d(keys, new)
    found, _ = btree.bulk_lookup(tree, union, height=meta.height)
    assert bool(np.all(found))
    # model check: values match a dict model
    model = {int(k): int(k) for k in keys}
    model.update({int(k): int(k) + 1 for k in new})
    k, v = btree.tree_items(tree)
    assert {int(a): int(b) for a, b in zip(k, v)} == model


@settings(max_examples=25, deadline=None)
@given(ks=key_sets, data=st.data())
def test_prop_delete_subset(ks, data):
    keys = np.array(sorted(ks), dtype=np.int64)
    tree, meta = btree.bulk_build(keys, values=keys)
    n_del = data.draw(st.integers(min_value=1, max_value=len(keys)))
    idx = data.draw(
        st.lists(
            st.integers(min_value=0, max_value=len(keys) - 1),
            min_size=n_del,
            max_size=n_del,
            unique=True,
        )
    )
    gone = keys[np.array(idx)]
    tree, ok = btree.bulk_delete(tree, gone, height=meta.height)
    assert bool(np.all(ok))
    k, _ = btree.tree_items(tree)
    np.testing.assert_array_equal(k, np.setdiff1d(keys, gone))


@settings(max_examples=20, deadline=None)
@given(ks=key_sets, start=st.integers(min_value=0, max_value=2**40), n=st.integers(1, 64))
def test_prop_scan_matches_sorted_slice(ks, start, n):
    keys = np.array(sorted(ks), dtype=np.int64)
    tree, meta = btree.bulk_build(keys, values=keys)
    out_k, _ = btree.bulk_scan(
        tree, np.array([start], dtype=np.int64), height=meta.height, count=n
    )
    got = np.asarray(out_k[0])
    got = got[got != KEY_MAX]
    np.testing.assert_array_equal(got, keys[keys >= start][:n])
