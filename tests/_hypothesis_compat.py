"""Optional-hypothesis shim shared by the property-test modules.

``from _hypothesis_compat import given, settings, st`` gives the real
hypothesis API when it is installed, and inert stand-ins otherwise: the
``given``-decorated tests skip individually while every plain test in the
module keeps running — a module-level ``pytest.importorskip`` would hide
them all on the no-hypothesis CI leg.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised by the no-hypothesis leg
    HAVE_HYPOTHESIS = False

    class _InertStrategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _InertStrategies()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        return lambda f: pytest.mark.skip(
            reason="property tests need hypothesis"
        )(f)
