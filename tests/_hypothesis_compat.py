"""Optional-hypothesis shim shared by the property-test modules.

``from _hypothesis_compat import given, settings, st`` gives the real
hypothesis API when it is installed, and inert stand-ins otherwise: the
``given``-decorated tests skip individually while every plain test in the
module keeps running — a module-level ``pytest.importorskip`` would hide
them all on the no-hypothesis CI leg.

Skip audit (2026-08): every tier-1 skip (9 as of this writing — 4 in
test_btree.py, 2 in test_partition_cache_sim.py, and one each in
test_engine.py / test_smo.py / test_write.py) routes through this shim or
the matching ``pytest.importorskip("hypothesis")`` guards.  None is a
disabled-because-broken test: hypothesis is an optional ``[test]`` extra
that CI's hyp-installed tier-1 legs do install and run; environments
without it (like CI's deliberate hyp-absent leg) exercise the skip path.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised by the no-hypothesis leg
    HAVE_HYPOTHESIS = False

    class _InertStrategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _InertStrategies()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        return lambda f: pytest.mark.skip(
            reason="property tests need hypothesis "
                   "(optional [test] dep; CI's hyp-installed legs run them)"
        )(f)
