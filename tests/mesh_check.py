"""Multi-device exercise of the Plane-B mesh DEX.  Run as a subprocess by
tests/test_dex_mesh.py so the main pytest session keeps a single device."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import dex as dex_mod  # noqa: E402
from repro.core import pool as pool_mod  # noqa: E402
from repro.core import scan as scan_mod  # noqa: E402
from repro.core import write as write_mod  # noqa: E402
from repro.compat import make_mesh_compat  # noqa: E402
from repro.core.nodes import FANOUT, KEY_MAX, KEY_MIN  # noqa: E402
from repro.core.sim import HostBTree  # noqa: E402


def main() -> None:
    mesh = make_mesh_compat((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    keys = np.sort(
        rng.choice(1_000_000, size=20_000, replace=False).astype(np.int64) + 1
    )
    vals = keys * 7
    pool, meta = pool_mod.build_pool(keys, vals, level_m=1, fill=0.7, n_shards=4)

    bounds = np.array([KEY_MIN, 500_000, KEY_MAX], dtype=np.int64)
    B = 512
    qk = rng.choice(keys, size=B).astype(np.int64)
    qk[::13] = qk[::13] + 1  # inject misses
    expect = np.isin(qk, keys)

    for policy in ("fetch", "offload", "auto"):
        cfg = dex_mod.DexMeshConfig(
            route_axes=("data",),
            memory_axis="model",
            n_route=2,
            n_memory=4,
            cache_sets=64,
            cache_ways=4,
            policy=policy,
            route_capacity_factor=4.0,
        )
        state = dex_mod.init_state(pool, meta, cfg, bounds)
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, dex_mod.state_shardings(mesh, cfg)
        )
        qk_dev = jax.device_put(
            jnp.asarray(qk), NamedSharding(mesh, P(("data", "model")))
        )
        lk = jax.jit(dex_mod.make_dex_lookup(meta, cfg, mesh))
        s2, found, values, _ = lk(state, qk_dev)
        found, values = np.asarray(found), np.asarray(values)
        assert (found == expect).all(), f"{policy}: found mismatch"
        assert (values[expect] == qk[expect] * 7).all(), f"{policy}: value mismatch"
        assert int(np.asarray(s2.stats)[:, dex_mod.STAT_DROPS].sum()) == 0
        if policy == "fetch":
            # second batch must produce cache hits
            s3, f3, _, _ = lk(s2, qk_dev)
            hits = int(np.asarray(s3.stats)[:, dex_mod.STAT_HITS].sum())
            assert hits > 0, "no cache hits on repeat batch"
            assert (np.asarray(f3) == expect).all()
        if policy == "offload":
            offs = int(np.asarray(s2.stats)[:, dex_mod.STAT_OFFLOADS].sum())
            assert offs == B, f"expected {B} offloads, got {offs}"

    # ---- batched range scans (core/scan.py) vs HostBTree.scan --------------
    host = HostBTree(keys, vals, fill=0.7)
    cfg = dex_mod.DexMeshConfig(
        route_axes=("data",),
        memory_axis="model",
        n_route=2,
        n_memory=4,
        cache_sets=64,
        cache_ways=4,
        route_capacity_factor=4.0,
    )
    state = dex_mod.init_state(pool, meta, cfg, bounds)
    state = jax.tree.map(
        lambda x, s: jax.device_put(x, s), state, dex_mod.state_shardings(mesh, cfg)
    )
    MC = 64
    scan = jax.jit(scan_mod.make_dex_scan(meta, cfg, mesh, max_count=MC))
    BS = 512
    starts = rng.choice(keys, size=BS).astype(np.int64)
    starts[::7] = starts[::7] + 1               # start keys not in the index
    starts[0] = keys[-1] + 100                  # empty-result scan
    # scans straddling the partition boundary at 500_000
    below = keys[(keys > 480_000) & (keys < 500_000)]
    starts[1 : 1 + min(8, below.size)] = below[-8:]
    counts = rng.integers(1, MC + 1, size=BS).astype(np.int64)
    counts[2] = 0
    sharding = NamedSharding(mesh, P(("data", "model")))
    s_scan, out_k, out_v, taken = scan(
        state,
        jax.device_put(jnp.asarray(starts), sharding),
        jax.device_put(jnp.asarray(counts), sharding),
    )
    out_k, out_v, taken = np.asarray(out_k), np.asarray(out_v), np.asarray(taken)
    for i in range(BS):
        expect_keys = [
            k for _, ks in host.scan(int(starts[i]), int(counts[i])) for k in ks
        ][: int(counts[i])] if counts[i] > 0 else []
        got = out_k[i][out_k[i] != KEY_MAX].tolist()
        assert got == expect_keys, f"scan {i}: {got[:4]} != {expect_keys[:4]}"
        assert int(taken[i]) == len(expect_keys), f"scan {i}: taken mismatch"
        assert (out_v[i][: len(expect_keys)]
                == np.asarray(expect_keys, np.int64) * 7).all(), f"scan {i}: values"
    assert int(np.asarray(s_scan.stats)[:, dex_mod.STAT_DROPS].sum()) == 0
    # repeat batch must hit the warmed cache
    s_scan2, k2, _, t2 = scan(
        s_scan,
        jax.device_put(jnp.asarray(starts), sharding),
        jax.device_put(jnp.asarray(counts), sharding),
    )
    np.testing.assert_array_equal(np.asarray(k2), out_k)
    np.testing.assert_array_equal(np.asarray(t2), taken)
    d_hits = (np.asarray(s_scan2.stats)[:, dex_mod.STAT_HITS].sum()
              - np.asarray(s_scan.stats)[:, dex_mod.STAT_HITS].sum())
    assert d_hits > 0, "no cache hits on repeat scan batch"

    # ---- batched writes (core/write.py): update/insert across 2 route ----
    # partitions x 4 memory columns, with cross-partition stale-cache
    # rejection via the per-leaf version table
    cfg_w = dex_mod.DexMeshConfig(
        route_axes=("data",),
        memory_axis="model",
        n_route=2,
        n_memory=4,
        cache_sets=256,
        cache_ways=4,
        policy="fetch",
        p_admit_leaf_pct=100,   # make every leaf cacheable: the staleness
                                # check below needs rows cached on all chips
        route_capacity_factor=4.0,
    )
    host_w = HostBTree(keys, vals, fill=0.7)
    state = dex_mod.init_state(pool, meta, cfg_w, bounds)
    state = jax.tree.map(
        lambda x, s: jax.device_put(x, s), state,
        dex_mod.state_shardings(mesh, cfg_w)
    )
    lk = jax.jit(dex_mod.make_dex_lookup(meta, cfg_w, mesh))
    up = jax.jit(write_mod.make_dex_update(meta, cfg_w, mesh))
    ins = jax.jit(write_mod.make_dex_insert(meta, cfg_w, mesh))
    scan_w = jax.jit(scan_mod.make_dex_scan(meta, cfg_w, mesh, max_count=MC))

    BW = 512
    # scans crossing the partition boundary cache partition-1 leaves on
    # chips of BOTH route rows (start below 500_000, scan across)
    below = keys[(keys > 480_000) & (keys < 500_000)]
    sk = np.concatenate([below[-BW // 2:],
                         rng.choice(keys, size=BW - min(BW // 2, below.size))])
    sk = sk[:BW].astype(np.int64)
    counts = np.full(BW, MC, np.int64)
    state, pre_k, pre_v, pre_t = scan_w(
        state,
        jax.device_put(jnp.asarray(sk), sharding),
        jax.device_put(jnp.asarray(counts), sharding),
    )
    jax.block_until_ready(pre_t)

    # duplicate writers of the same keys land on different source chips;
    # batch-priority conflict resolution must make the last lane win
    wk = rng.choice(keys, size=BW).astype(np.int64)
    wk[: BW // 4] = wk[BW // 4 : BW // 2]   # cross-chip duplicate writers
    wv = rng.integers(0, 1 << 40, size=BW).astype(np.int64)
    state, res = up(
        state,
        jax.device_put(jnp.asarray(wk), sharding),
        jax.device_put(jnp.asarray(wv), sharding),
    )
    res = np.asarray(res)
    assert (res == write_mod.STATUS_OK).all(), "update lanes failed"
    for k, v in zip(wk, wv):
        host_w.update(int(k), int(v))

    # lookups (all chips) must see the new values — any chip still holding
    # the pre-update row must reject it via the version check
    s2, f2, v2, _ = lk(
        state, jax.device_put(jnp.asarray(wk), sharding)
    )
    f2, v2 = np.asarray(f2), np.asarray(v2)
    assert f2.all(), "updated keys must be found"
    for i in range(BW):
        assert int(v2[i]) == host_w.get(int(wk[i])), f"stale value at {i}"
    state = s2

    # scans from the *other* partition over the written leaves must also
    # see fresh values (their cached copies are version-stale)
    state, k3, v3, t3 = scan_w(
        state,
        jax.device_put(jnp.asarray(sk), sharding),
        jax.device_put(jnp.asarray(counts), sharding),
    )
    k3, v3, t3 = np.asarray(k3), np.asarray(v3), np.asarray(t3)
    for i in range(BW):
        if t3[i] < 0:
            continue
        expect = [kk for _, ks in host_w.scan(int(sk[i]), int(counts[i]))
                  for kk in ks][: int(counts[i])]
        got = k3[i][k3[i] != KEY_MAX].tolist()
        assert got == expect, f"post-write scan keys diverge at {i}"
        for j, kk in enumerate(expect):
            assert int(v3[i, j]) == host_w.get(int(kk)), (
                f"post-write scan value stale at {i},{j}"
            )

    # inserts: fresh keys spread over both partitions; applied on the mesh,
    # shed leaves replayed via the host SMO path
    ik = (rng.choice(keys[:-1], size=BW) + 1).astype(np.int64)
    ik = np.unique(ik[~np.isin(ik, keys)])
    ik = ik[: (ik.size // 8) * 8]
    iv = ik * 3
    meta_w = meta
    state, ri = ins(
        state,
        jax.device_put(jnp.asarray(ik), sharding),
        jax.device_put(jnp.asarray(iv), sharding),
    )
    ri = np.asarray(ri)
    assert (ri != write_mod.STATUS_SHED).all()
    for k, v, r in zip(ik, iv, ri):
        if r == write_mod.STATUS_OK:
            host_w.insert(int(k), int(v))
    shed = ri == write_mod.STATUS_SPLIT
    if shed.any():
        state, meta_w = write_mod.drain_splits(
            state, meta, cfg_w, host_w, ik[shed], iv[shed], bounds
        )
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state,
            dex_mod.state_shardings(mesh, cfg_w)
        )
        lk = jax.jit(dex_mod.make_dex_lookup(meta_w, cfg_w, mesh))
    s4, f4, v4, _ = lk(
        state, jax.device_put(jnp.asarray(ik[: (ik.size // 8) * 8]), sharding)
    )
    f4, v4 = np.asarray(f4), np.asarray(v4)
    probe = ik[: (ik.size // 8) * 8]
    for i in range(probe.size):
        hv = host_w.get(int(probe[i]))
        assert bool(f4[i]) == (hv is not None), f"insert missing at {i}"
        if hv is not None:
            assert int(v4[i]) == hv, f"insert value wrong at {i}"

    # ---- on-mesh SMO engine (core/smo.py): 8-device split round trip -----
    # leaf overflows on two different memory columns split device-side; the
    # split leaf/sibling/parent versions bump (poisoned stale cached rows
    # must be rejected) while every other warm row survives untouched — no
    # global version reset, no pool rebuild
    from repro.core import smo as smo_mod  # noqa: E402

    cfg_m = dex_mod.DexMeshConfig(
        route_axes=("data",),
        memory_axis="model",
        n_route=2,
        n_memory=4,
        cache_sets=256,
        cache_ways=4,
        policy="fetch",
        p_admit_leaf_pct=100,   # deterministic warm rows for the poison check
        route_capacity_factor=4.0,
    )
    host_m = HostBTree(keys, vals, fill=0.7)
    state = dex_mod.init_state(pool, meta, cfg_m, bounds)
    shardings_m = dex_mod.state_shardings(mesh, cfg_m)
    state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, shardings_m)
    lk_m = jax.jit(dex_mod.make_dex_lookup(meta, cfg_m, mesh))
    ins_m = jax.jit(write_mod.make_dex_insert(meta, cfg_m, mesh))
    scan_m = jax.jit(scan_mod.make_dex_scan(meta, cfg_m, mesh, max_count=MC))
    smo_m = jax.jit(smo_mod.make_dex_smo(meta, cfg_m, mesh))

    def put_m(x):
        return jax.device_put(jnp.asarray(x), sharding)

    def pad512(x):
        return np.concatenate(
            [x, np.full(512 - x.size, KEY_MAX, np.int64)]
        )

    # warm rows far from the burst regions on every chip (both partitions)
    far = np.concatenate([keys[2000:2256], keys[-256:]]).astype(np.int64)
    state, f_far, v_far, _ = lk_m(state, put_m(far))
    assert bool(np.asarray(f_far).all())
    # warm the to-be-split leaves too, so stale copies exist to poison
    near = pad512(np.concatenate([keys[:32], keys[-40:-8]]).astype(np.int64))
    state, _, _, _ = lk_m(state, put_m(near))

    # overflow bursts on two memory columns: around the smallest keys
    # (partition 0 / column 0) and the largest (partition 1 / last column)
    b_lo = np.arange(int(keys[0]) + 1, int(keys[0]) + 1 + FANOUT, dtype=np.int64)
    b_lo = b_lo[~np.isin(b_lo, keys)][: FANOUT - 8]
    b_hi = np.arange(int(keys[-2]) + 1, int(keys[-2]) + 1 + FANOUT,
                     dtype=np.int64)
    b_hi = b_hi[~np.isin(b_hi, keys)][: FANOUT - 8]
    burst = pad512(np.concatenate([b_lo, b_hi]))
    bvals = np.where(burst != KEY_MAX, burst * 3, 0)
    state, ri_m = ins_m(state, put_m(burst), put_m(bvals))
    ri_m = np.asarray(ri_m)
    live_b = burst != KEY_MAX
    for kk, rr in zip(burst[live_b], ri_m[live_b]):
        if rr == write_mod.STATUS_OK:
            host_m.insert(int(kk), int(kk) * 3)
    shed_m = live_b & (ri_m == write_mod.STATUS_SPLIT)
    assert shed_m.sum() > 0, "bursts must overflow their leaves"
    state, meta_m, info = smo_mod.settle_splits(
        state, meta, cfg_m, smo_m, host_m,
        np.where(shed_m, burst, KEY_MAX), np.where(shed_m, bvals, 0), bounds,
    )
    assert meta_m is meta, "on-mesh SMO must not rebuild the pool"
    assert not info["drained"] and info["residual"] == 0
    assert info["onmesh"] == int(shed_m.sum())
    stats_m = np.asarray(state.stats).sum(axis=0)
    assert int(stats_m[dex_mod.STAT_SMO_SPLITS]) >= 2  # one per column
    assert int(stats_m[dex_mod.STAT_DRAINS]) == 0

    # surgical invalidation: only the split leaves + siblings + ancestors
    # bumped; every cached copy of a bumped node is poisoned on every chip
    # and must be re-fetched, never served
    vers_m = np.asarray(state.versions)
    assert (vers_m == vers_m[:1]).all(), "version table must be pmax-synced"
    bumped = np.where(vers_m[0] > 0)[0]
    assert 0 < bumped.size <= 8 * meta.levels_in_subtree, bumped.size
    tags_m = np.asarray(state.cache.tags)
    hitm = np.isin(tags_m, bumped)
    assert hitm.any(), "warm caches must hold a stale copy of a split node"
    pois = np.asarray(state.cache.values).copy()
    pois[hitm] = -424242
    state = state._replace(cache=state.cache._replace(
        values=jax.device_put(jnp.asarray(pois), shardings_m.cache.values)
    ))
    probe = pad512(np.concatenate([b_lo, b_hi, keys[:16], keys[-16:]]))
    state, f_p, v_p, _ = lk_m(state, put_m(probe))
    f_p, v_p = np.asarray(f_p), np.asarray(v_p)
    for i in np.where(probe != KEY_MAX)[0]:
        hv = host_m.get(int(probe[i]))
        assert bool(f_p[i]) == (hv is not None), f"smo lookup {i}"
        if hv is not None:
            assert int(v_p[i]) == hv, f"poisoned stale row served at {i}"

    # unmoved warm rows survive the splits: the far probe repeats entirely
    # from cache (hits grow by at least the batch) with identical results
    before_m = np.asarray(state.stats).sum(axis=0)
    state, f_far2, v_far2, _ = lk_m(state, put_m(far))
    after_m = np.asarray(state.stats).sum(axis=0)
    np.testing.assert_array_equal(np.asarray(f_far2), np.asarray(f_far))
    np.testing.assert_array_equal(np.asarray(v_far2), np.asarray(v_far))
    assert (
        after_m[dex_mod.STAT_HITS] - before_m[dex_mod.STAT_HITS]
        >= far.size
    ), "far-region cached rows must survive an on-mesh split"

    # scans across both split leaves follow the successor chain (multi-hop
    # across the relocated sibling) and stay bit-identical to the host
    starts_m = pad512(np.array(
        [int(keys[0]), int(b_lo[0]), int(keys[-2]), int(b_hi[0])], np.int64
    ))
    cnts_m = np.where(starts_m != KEY_MAX, 48, 0).astype(np.int64)
    state, sk_m, sv_m, tk_m = scan_m(state, put_m(starts_m), put_m(cnts_m))
    sk_m, sv_m, tk_m = np.asarray(sk_m), np.asarray(sv_m), np.asarray(tk_m)
    for i in np.where(starts_m != KEY_MAX)[0]:
        expect = [
            kk for _, ks in host_m.scan(int(starts_m[i]), int(cnts_m[i]))
            for kk in ks
        ][: int(cnts_m[i])]
        got = sk_m[i][sk_m[i] != KEY_MAX].tolist()
        assert got == expect, f"post-split scan diverges at {i}"
        for j, kk in enumerate(expect):
            assert int(sv_m[i, j]) == host_m.get(int(kk)), (i, j)

    # ---- mixed-batch coherence (core/engine.py): an update and an insert
    # of the SAME leaf land in ONE engine batch from different source
    # chips.  The updater's chip must not keep a version-fresh cached row
    # whose keys plane misses the insert — the engine skips the
    # write-through refresh for leaves that took same-batch inserts, so
    # the stale row fails the version check and refetches.
    from repro.core import engine as engine_mod  # noqa: E402

    cfg_e = dex_mod.DexMeshConfig(
        route_axes=("data",),
        memory_axis="model",
        n_route=2,
        n_memory=4,
        cache_sets=256,
        cache_ways=4,
        policy="fetch",
        p_admit_leaf_pct=100,   # the warm lookup must cache the leaf
        route_capacity_factor=4.0,
    )
    host_e = HostBTree(keys, vals, fill=0.7)
    state = dex_mod.init_state(pool, meta, cfg_e, bounds)
    state = jax.tree.map(
        lambda x, s: jax.device_put(x, s), state,
        dex_mod.state_shardings(mesh, cfg_e)
    )
    eng_e = jax.jit(engine_mod.make_dex_engine(
        meta, cfg_e, mesh, ops=("lookup", "update", "insert"), max_count=1
    ))
    lk_e = jax.jit(dex_mod.make_dex_lookup(meta, cfg_e, mesh))

    def put_e(x):
        return jax.device_put(jnp.asarray(x), sharding)

    # an existing key and a fresh key guaranteed to share its leaf
    j = 5000
    while keys[j] + 1 >= keys[j + 1]:  # need a gap right above keys[j]
        j += 1
    k_upd = int(keys[j])
    k_ins = k_upd + 1
    # warm: every chip serves (and caches, P_A=100%) the target leaf
    warm_e = np.full(512, k_upd, np.int64)
    state, f_w, _, _ = lk_e(state, put_e(warm_e))
    assert bool(np.asarray(f_w).all())
    # one mixed batch: the update sources on chip 0, the insert on the
    # last chip (lane // 64 is the source device on the 8-device mesh)
    opc_e = np.zeros(512, np.int32)
    kk_e = np.full(512, KEY_MAX, np.int64)
    vv_e = np.zeros(512, np.int64)
    opc_e[3], kk_e[3], vv_e[3] = engine_mod.OP_UPDATE, k_upd, 777
    opc_e[460], kk_e[460], vv_e[460] = engine_mod.OP_INSERT, k_ins, 999
    state, r_e = eng_e(state, put_e(opc_e), put_e(kk_e), put_e(vv_e))
    st_e = np.asarray(r_e.status)
    assert st_e[3] == write_mod.STATUS_OK, st_e[3]
    assert st_e[460] == write_mod.STATUS_OK, st_e[460]
    host_e.update(k_upd, 777)
    host_e.insert(k_ins, 999)
    # lookups of both keys from EVERY chip must match the host: a chip
    # still serving a version-fresh pre-insert keys plane would miss k_ins
    probe_e = np.tile(np.array([k_upd, k_ins], np.int64), 256)
    state, f_e, v_e, _ = lk_e(state, put_e(probe_e))
    f_e, v_e = np.asarray(f_e), np.asarray(v_e)
    assert f_e.all(), "mixed-batch insert invisible on some chip"
    for i in range(512):
        assert int(v_e[i]) == host_e.get(int(probe_e[i])), (
            f"mixed-batch stale cached row served at lane {i}"
        )

    # ---- pipelined round trip (core/engine.py, pipeline=True) -----------
    # the same mixed traffic streamed through the continuous double-
    # buffered service on the 8-device mesh must be lane-for-lane
    # identical to the batch-synchronous engine AND to a phased HostBTree
    # replay — including deliberate cross-batch same-leaf conflicts
    # (even batches update the hot keys that odd batches read), which the
    # version check turns into counted two-sided stalls, never stale
    # answers.
    def fresh_state_e():
        st = dex_mod.init_state(pool, meta, cfg_e, bounds)
        return jax.tree.map(
            lambda x, s: jax.device_put(x, s), st,
            dex_mod.state_shardings(mesh, cfg_e)
        )

    NB, BP = 4, 512
    hot_p = keys[1000:1008].astype(np.int64)
    hot_lanes = np.arange(8) * (BP // 8) + 7    # one hot lane per chip
    fresh_p = np.unique(
        (rng.choice(keys[:-1], size=8 * NB * BP) + 1).astype(np.int64)
    )
    fresh_p = fresh_p[~np.isin(fresh_p, keys)]
    batches_p, fi = [], 0
    for bi in range(NB):
        pick = rng.integers(0, 3, size=BP)
        opc = np.where(
            pick == 0, engine_mod.OP_LOOKUP,
            np.where(pick == 1, engine_mod.OP_UPDATE, engine_mod.OP_INSERT),
        ).astype(np.int32)
        kk = np.empty(BP, np.int64)
        # disjoint key regions keep the host replay order-free: a lookup
        # never races a same-batch write and write keys are batch-unique
        kk[pick == 0] = rng.choice(
            keys[12_000:16_000], size=int((pick == 0).sum())
        )
        kk[pick == 1] = rng.choice(
            keys[8_000:12_000], size=int((pick == 1).sum()), replace=False
        )
        n_ins = int((pick == 2).sum())
        kk[pick == 2] = fresh_p[fi : fi + n_ins]
        fi += n_ins
        vv = rng.integers(1, 1 << 40, size=BP).astype(np.int64)
        if bi % 2 == 0:
            opc[hot_lanes] = engine_mod.OP_UPDATE
            kk[hot_lanes] = hot_p
            vv[hot_lanes] = hot_p ^ (1000 + bi)
        else:
            opc[hot_lanes] = engine_mod.OP_LOOKUP
            kk[hot_lanes] = hot_p
        batches_p.append((opc, kk, vv))

    st_s = fresh_state_e()
    res_s = []
    for opc, kk, vv in batches_p:
        st_s, r = eng_e(st_s, put_e(opc), put_e(kk), put_e(vv))
        res_s.append(jax.tree.map(np.asarray, r))

    pipe_m = engine_mod.make_dex_engine(
        meta, cfg_e, mesh, ops=("lookup", "update", "insert"), max_count=1,
        pipeline=True,
    )
    assert pipe_m.plan["pipeline"] is True
    assert pipe_m.plan["overlap_phases"] == ("pipe/front", "pipe/back")
    st_p, res_p = pipe_m.run(
        fresh_state_e(),
        [(put_e(o), put_e(k), put_e(v)) for o, k, v in batches_p],
    )
    assert len(res_p) == NB
    res_p = [jax.tree.map(np.asarray, r) for r in res_p]

    host_p = HostBTree(keys, vals, fill=0.7)
    for bi, ((opc, kk, vv), rs, rp) in enumerate(
        zip(batches_p, res_s, res_p)
    ):
        for name in ("found", "values", "status", "shed"):
            np.testing.assert_array_equal(
                getattr(rs, name), getattr(rp, name),
                err_msg=f"pipelined batch {bi} diverges on {name}",
            )
        assert not rs.shed.any(), f"batch {bi} shed under factor-4 capacity"
        for i in np.where(opc == engine_mod.OP_LOOKUP)[0]:
            hv = host_p.get(int(kk[i]))
            assert bool(rs.found[i]) == (hv is not None), (bi, i)
            if hv is not None:
                assert int(rs.values[i]) == hv, (
                    f"stale value served at batch {bi} lane {i}"
                )
        for i in np.where(opc == engine_mod.OP_UPDATE)[0]:
            assert rs.status[i] == write_mod.STATUS_OK, (bi, i)
            host_p.update(int(kk[i]), int(vv[i]))
        for i in np.where(opc == engine_mod.OP_INSERT)[0]:
            assert rs.status[i] != write_mod.STATUS_SHED, (bi, i)
            if rs.status[i] == write_mod.STATUS_OK:
                host_p.insert(int(kk[i]), int(vv[i]))
    # the drained pipeline index IS the synchronous one, bit for bit
    np.testing.assert_array_equal(
        np.asarray(st_s.pool.pool_keys), np.asarray(st_p.pool.pool_keys)
    )
    np.testing.assert_array_equal(
        np.asarray(st_s.pool.pool_values), np.asarray(st_p.pool.pool_values)
    )
    np.testing.assert_array_equal(
        np.asarray(st_s.versions), np.asarray(st_p.versions)
    )
    np.testing.assert_array_equal(
        np.asarray(st_s.occupancy), np.asarray(st_p.occupancy)
    )
    stalls_p = int(np.asarray(st_p.stats)[:, dex_mod.STAT_PIPE_STALLS].sum())
    stalls_s = int(np.asarray(st_s.stats)[:, dex_mod.STAT_PIPE_STALLS].sum())
    assert stalls_s == 0, "synchronous engine must never count pipe stalls"
    assert stalls_p > 0, "hot cross-batch writers must stall in the window"

    # ---- forced-offload round trip (policy="offload"): ALL op types ------
    # through the two-sided path on 8 devices — every lookup/update/insert
    # lane ships a tagged message in the engine's fused round and the
    # owning memory column walks its own block; scans stay one-sided (§7:
    # scans never offload).  Results must match a HostBTree replay and the
    # offloaded writes must be visible to offloaded lookups (version bumps
    # travel back through the fused responses).
    cfg_o = dex_mod.DexMeshConfig(
        route_axes=("data",),
        memory_axis="model",
        n_route=2,
        n_memory=4,
        cache_sets=256,
        cache_ways=4,
        policy="offload",
        route_capacity_factor=4.0,
    )
    host_o = HostBTree(keys, vals, fill=0.7)
    state = dex_mod.init_state(pool, meta, cfg_o, bounds)
    state = jax.tree.map(
        lambda x, s: jax.device_put(x, s), state,
        dex_mod.state_shardings(mesh, cfg_o)
    )
    lk_o = jax.jit(dex_mod.make_dex_lookup(meta, cfg_o, mesh))
    up_o = jax.jit(write_mod.make_dex_update(meta, cfg_o, mesh))
    ins_o = jax.jit(write_mod.make_dex_insert(meta, cfg_o, mesh))
    scan_o = jax.jit(scan_mod.make_dex_scan(meta, cfg_o, mesh, max_count=MC))

    def put_o(x):
        return jax.device_put(jnp.asarray(x), sharding)

    BO = 512
    qo = rng.choice(keys, size=BO).astype(np.int64)
    qo[::11] = qo[::11] + 1                     # misses through the RPC too
    state, f_o, v_o, sh_o = lk_o(state, put_o(qo))
    f_o, v_o, sh_o = np.asarray(f_o), np.asarray(v_o), np.asarray(sh_o)
    assert not sh_o.any()
    exp_o = np.isin(qo, keys)
    assert (f_o == exp_o).all(), "offloaded lookup found mismatch"
    assert (v_o[exp_o] == qo[exp_o] * 7).all(), "offloaded lookup values"

    uk_o = rng.choice(keys, size=BO).astype(np.int64)
    uk_o[: BO // 4] = uk_o[BO // 4 : BO // 2]   # cross-chip duplicate writers
    uv_o = rng.integers(0, 1 << 40, size=BO).astype(np.int64)
    state, ru_o = up_o(state, put_o(uk_o), put_o(uv_o))
    ru_o = np.asarray(ru_o)
    assert (ru_o == write_mod.STATUS_OK).all(), "offloaded updates failed"
    for k, v in zip(uk_o, uv_o):
        host_o.update(int(k), int(v))
    state, f_u, v_u, _ = lk_o(state, put_o(uk_o))
    f_u, v_u = np.asarray(f_u), np.asarray(v_u)
    assert f_u.all()
    for i in range(BO):
        assert int(v_u[i]) == host_o.get(int(uk_o[i])), (
            f"offloaded update not visible at {i}"
        )

    io = (rng.choice(keys[:-1], size=BO) + 1).astype(np.int64)
    io = np.unique(io[~np.isin(io, keys)])
    io = io[: (io.size // 8) * 8]
    state, ri_o = ins_o(state, put_o(io), put_o(io * 13))
    ri_o = np.asarray(ri_o)
    assert (ri_o != write_mod.STATUS_SHED).all()
    for k, r in zip(io, ri_o):
        if r == write_mod.STATUS_OK:
            host_o.insert(int(k), int(k) * 13)
    # the SMO fallback rule: an offloaded insert that would split sheds
    # STATUS_SPLIT exactly like a fetched-path one (settled between batches)
    meta_o = meta
    shed_o = ri_o == write_mod.STATUS_SPLIT
    if shed_o.any():
        state, meta_o = write_mod.drain_splits(
            state, meta, cfg_o, host_o, io[shed_o], io[shed_o] * 13, bounds
        )
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state,
            dex_mod.state_shardings(mesh, cfg_o)
        )
        lk_o = jax.jit(dex_mod.make_dex_lookup(meta_o, cfg_o, mesh))
        scan_o = jax.jit(
            scan_mod.make_dex_scan(meta_o, cfg_o, mesh, max_count=MC)
        )
    state, f_i, v_i, _ = lk_o(state, put_o(io))
    f_i, v_i = np.asarray(f_i), np.asarray(v_i)
    for i in range(io.size):
        hv = host_o.get(int(io[i]))
        assert bool(f_i[i]) == (hv is not None), f"offloaded insert at {i}"
        if hv is not None:
            assert int(v_i[i]) == hv, f"offloaded insert value at {i}"

    # scans under the offload policy still run the one-sided path
    so = rng.choice(keys, size=BO).astype(np.int64)
    sc = np.full(BO, 24, np.int64)
    state, sk_o, sv_o, tk_o = scan_o(state, put_o(so), put_o(sc))
    sk_o, sv_o, tk_o = np.asarray(sk_o), np.asarray(sv_o), np.asarray(tk_o)
    for i in range(BO):
        if tk_o[i] < 0:
            continue
        exp = [kk for _, ks in host_o.scan(int(so[i]), 24) for kk in ks][:24]
        got = sk_o[i][sk_o[i] != KEY_MAX].tolist()
        assert got == exp, f"offload-policy scan diverges at {i}"
    stats_o = np.asarray(state.stats).sum(axis=0)
    n_off = int(stats_o[dex_mod.STAT_OFFLOADS])
    assert n_off > 0, "forced-offload must count offloaded messages"
    # every live lookup/update/insert lane went two-sided
    assert n_off >= BO + BO + io.size, (n_off, BO, io.size)
    assert int(stats_o[dex_mod.STAT_OFFLOAD_GROUPS]) > 0
    assert int(stats_o[dex_mod.STAT_FETCH_GROUPS]) == 0

    # ---- live logical repartitioning round trip (core/repartition.py) ----
    # a skewed batch sheds load under tight buckets; the controller moves
    # the boundary, results stay identical, drops strictly fall, and
    # version-stale cached rows of moved nodes are rejected, never served
    from repro.core.partition import LogicalPartitions  # noqa: E402
    from repro.core.repartition import (  # noqa: E402
        RepartitionConfig,
        RepartitionController,
        moved_intervals,
        node_key_ranges,
    )

    cfg_r = dex_mod.DexMeshConfig(
        route_axes=("data",),
        memory_axis="model",
        n_route=2,
        n_memory=4,
        cache_sets=256,
        cache_ways=4,
        policy="fetch",
        p_admit_leaf_pct=100,       # deterministic cache warm for the
                                    # stale-row poisoning check below
        route_capacity_factor=1.25,  # tight: skew must shed
    )
    state = dex_mod.init_state(pool, meta, cfg_r, bounds)
    state = jax.tree.map(
        lambda x, s: jax.device_put(x, s), state,
        dex_mod.state_shardings(mesh, cfg_r)
    )
    lkr = jax.jit(dex_mod.make_dex_lookup(meta, cfg_r, mesh))
    scan_r = jax.jit(scan_mod.make_dex_scan(meta, cfg_r, mesh, max_count=MC))

    BR = 512
    low = keys[keys < 500_000]
    qs = rng.choice(low, size=BR).astype(np.int64)   # all -> partition 0
    qs_dev = jax.device_put(jnp.asarray(qs), sharding)
    cnts = np.full(BR, 16, np.int64)
    cnts_dev = jax.device_put(jnp.asarray(cnts), sharding)

    def drops_of(st):
        return int(np.asarray(st.stats)[:, dex_mod.STAT_DROPS].sum())

    def fetches_of(st):
        return int(np.asarray(st.stats)[:, dex_mod.STAT_FETCHES].sum())

    s1, f1, v1, sh1 = lkr(state, qs_dev)
    f1, v1, sh1 = np.asarray(f1), np.asarray(v1), np.asarray(sh1)
    drops_skew = drops_of(s1)
    assert drops_skew > 0, "tight buckets under full skew must shed"
    assert f1[~sh1].all() and (v1[~sh1] == qs[~sh1] * 7).all()
    # warm repeat (also routes to partition 0; caches now hold the rows)
    s2, _, _, _ = lkr(s1, qs_dev)
    s2s, pre_k, pre_v, pre_t = scan_r(s2, qs_dev, cnts_dev)
    pre_k, pre_v, pre_t = np.asarray(pre_k), np.asarray(pre_v), np.asarray(pre_t)
    s2 = s2s

    ctl = RepartitionController(
        LogicalPartitions(bounds), n_memory=cfg_r.n_memory,
        cfg=RepartitionConfig(imbalance_threshold=1.2, min_ops=BR,
                              cooldown_batches=0),
    )
    ctl.observe(np.asarray(s2.stats), qs,
                demand=np.asarray(s2.route_demand))
    s3, report = ctl.maybe_repartition(s2, meta)
    assert report is not None, "skewed load must trigger a repartition"
    newp = LogicalPartitions(report.new_boundaries)
    assert newp.num_partitions == 2, "server count is fixed"
    assert report.nodes_invalidated > 0
    assert int(report.new_boundaries[1]) < 500_000  # boundary chased skew

    # poison every cached copy of a moved node on every chip: if the
    # version bump failed to invalidate them, lookups would serve garbage
    gids_all, lo_all, hi_all = node_key_ranges(
        np.asarray(state.pool.pool_keys), meta,
        np.asarray(state.pool.pool_children),
    )
    affected = np.zeros(gids_all.shape, bool)
    for a, b2 in moved_intervals(LogicalPartitions(bounds), newp):
        affected |= (lo_all.astype(object) < b2) & (hi_all.astype(object) > a)
    moved_gids = gids_all[affected]
    tags = np.asarray(s3.cache.tags)
    poisoned_vals = np.asarray(s3.cache.values).copy()
    hitmask = np.isin(tags, moved_gids)
    assert hitmask.any(), "warm caches must hold some moved rows"
    poisoned_vals[hitmask] = -12345
    s3 = s3._replace(cache=s3.cache._replace(
        values=jax.device_put(
            jnp.asarray(poisoned_vals),
            dex_mod.state_shardings(mesh, cfg_r).cache.values,
        )
    ))

    fetches_before = fetches_of(s3)
    s4r, f4r, v4r, sh4 = lkr(s3, qs_dev)
    f4r, v4r, sh4 = np.asarray(f4r), np.asarray(v4r), np.asarray(sh4)
    drops_after = drops_of(s4r) - drops_of(s3)
    assert drops_after < drops_skew, (
        f"repartitioning must strictly reduce drops: {drops_after} vs "
        f"{drops_skew}"
    )
    # identical results before/after the mid-stream boundary change
    both = ~sh1 & ~sh4
    assert (f4r[both] == f1[both]).all(), "found flipped across repartition"
    assert (v4r[both] == v1[both]).all(), "values drifted across repartition"
    assert f4r[~sh4].all() and (v4r[~sh4] == qs[~sh4] * 7).all(), (
        "stale cached rows of moved nodes were served"
    )
    assert fetches_of(s4r) > fetches_before, (
        "moved rows must re-fetch (version-stale), not serve from cache"
    )
    # scans across the moved boundary replay identically too
    s5, post_k, post_v, post_t = scan_r(s4r, qs_dev, cnts_dev)
    post_k, post_v, post_t = (
        np.asarray(post_k), np.asarray(post_v), np.asarray(post_t)
    )
    ok_scan = (pre_t >= 0) & (post_t >= 0)
    assert ok_scan.any()
    np.testing.assert_array_equal(post_k[ok_scan], pre_k[ok_scan])
    np.testing.assert_array_equal(post_v[ok_scan], pre_v[ok_scan])
    np.testing.assert_array_equal(post_t[ok_scan], pre_t[ok_scan])

    # ---- cooperative fleet caching (core/fleet_cache.py): 8-device -------
    # peer-peek round trip.  Under the divergent policy each chip's leaf
    # admission skews toward its own memory column's subtrees, so the four
    # siblings of a route row specialise on disjoint quarters of the hot
    # set; a local leaf miss for a foreign column is answered from the
    # sibling specialist's cache via a MSG_PEEK lane riding the engine's
    # existing fused all_to_all.  Then every cached row fleet-wide is
    # poisoned and version-bumped: a stale peer row must FAIL the peek's
    # version check (counted as a peer miss, answered by the owner's block
    # walk) — never served.
    from repro.core import fleet_cache  # noqa: E402

    cfg_f = dex_mod.DexMeshConfig(
        route_axes=("data",),
        memory_axis="model",
        n_route=2,
        n_memory=4,
        cache_sets=128,
        cache_ways=4,
        policy="fetch",
        p_admit_leaf_pct=50,
        route_capacity_factor=4.0,
    )
    pol_f = fleet_cache.divergent_policy(cfg_f, peek_budget=512)
    shardings_f = dex_mod.state_shardings(mesh, cfg_f)
    state = dex_mod.init_state(pool, meta, cfg_f, bounds)
    state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, shardings_f)
    eng_f = jax.jit(engine_mod.make_dex_engine(
        meta, cfg_f, mesh, ops=("lookup", "update"), max_count=1,
        cache_policy=pol_f,
    ))

    def put_f(x):
        return jax.device_put(jnp.asarray(x), sharding)

    def stat_sum(st):
        return np.asarray(st.stats).sum(axis=0)

    hot_f = keys[::40].astype(np.int64)          # spread over all 4 columns
    rng_f = np.random.default_rng(77)

    def lookup_batch(st):
        qf = rng_f.choice(hot_f, size=512).astype(np.int64)
        st, r = eng_f(st, put_f(np.zeros(512, np.int32)), put_f(qf),
                      put_f(np.zeros(512, np.int64)))
        assert not np.asarray(r.shed).any()
        assert np.asarray(r.found).all(), "hot fleet-cache lookup missed"
        assert (np.asarray(r.values) == qf * 7).all(), (
            "wrong/stale value served through the fleet cache"
        )
        return st

    for _ in range(5):                            # warm the specialists
        state = lookup_batch(state)
    before_f = stat_sum(state)
    state = lookup_batch(state)
    delta_f = stat_sum(state) - before_f
    assert int(delta_f[dex_mod.STAT_PEER_HITS]) > 0, (
        "warm divergent fleet must answer foreign-column misses via peeks"
    )

    # poison EVERY cached row on EVERY chip and bump EVERY node version:
    # all cached copies (local and peer alike) are now stale garbage; the
    # version check must reject each one.  Proven directly on the arrays
    # with the same `peer_answer` the fused round runs: every tagged row of
    # every chip answers freely before the poison and not at all after.
    def fleet_probe(st):
        cache_np = jax.tree.map(np.asarray, st.cache)
        vers_np = jnp.asarray(np.asarray(st.versions)[0])
        n_hits = n_rows = 0
        for d in range(cache_np.tags.shape[0]):
            cache_d = jax.tree.map(lambda a: jnp.asarray(a[d:d + 1]), cache_np)
            gids = np.unique(cache_np.tags[d][cache_np.tags[d] >= 0])
            if gids.size == 0:
                continue
            ph, _fnd, _val = fleet_cache.peer_answer(
                cache_d, cfg_f, vers_np, jnp.asarray(gids.astype(np.int64)),
                jnp.zeros(gids.size, jnp.int64), jnp.ones(gids.size, bool),
            )
            n_hits += int(np.asarray(ph).sum())
            n_rows += int(gids.size)
        return n_hits, n_rows

    fresh_hits, fresh_rows = fleet_probe(state)
    assert fresh_rows > 0 and fresh_hits > 0, (
        "warm fleet caches must answer peer probes before the poison"
    )
    pois_f = np.asarray(state.cache.values).copy()
    pois_f[:] = -777_777
    state = state._replace(
        cache=state.cache._replace(
            values=jax.device_put(jnp.asarray(pois_f),
                                  shardings_f.cache.values)
        ),
        versions=jax.device_put(jnp.asarray(state.versions) + 1,
                                shardings_f.versions),
    )
    stale_hits, stale_rows = fleet_probe(state)
    assert stale_rows >= fresh_rows and stale_hits == 0, (
        "a version-stale poisoned peer row survived the peek version check"
    )
    # engine-level: the batch right after the poison still returns correct
    # values everywhere (lookup_batch asserts them) and peeks the sibling
    # could not serve from a fresh row land as peer misses.  Peer hits may
    # legitimately reappear in the same batch: the fused round answers from
    # the post-descent cache, so a specialist that re-fetched (and
    # re-admitted) a hot leaf during this batch's own descent serves it
    # fresh — never the poisoned copy, which the probe above rejects.
    before_f = stat_sum(state)
    state = lookup_batch(state)
    delta_f = stat_sum(state) - before_f
    assert int(delta_f[dex_mod.STAT_PEER_MISSES]) > 0, (
        "stale-fleet peeks must be counted as peer misses"
    )
    # recovery: re-warmed specialists serve peeks again from fresh rows
    for _ in range(4):
        state = lookup_batch(state)
    before_f = stat_sum(state)
    state = lookup_batch(state)
    delta_f = stat_sum(state) - before_f
    assert int(delta_f[dex_mod.STAT_PEER_HITS]) > 0, (
        "fleet must recover peer hits after re-warming fresh rows"
    )
    print("MESH_CHECK_OK")


if __name__ == "__main__":
    main()
