"""Multi-device exercise of the Plane-B mesh DEX.  Run as a subprocess by
tests/test_dex_mesh.py so the main pytest session keeps a single device."""

import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import dex as dex_mod  # noqa: E402
from repro.core import pool as pool_mod  # noqa: E402
from repro.core.nodes import KEY_MAX, KEY_MIN  # noqa: E402


def main() -> None:
    mesh = jax.make_mesh(
        (2, 4), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2
    )
    rng = np.random.default_rng(0)
    keys = np.sort(
        rng.choice(1_000_000, size=20_000, replace=False).astype(np.int64) + 1
    )
    vals = keys * 7
    pool, meta = pool_mod.build_pool(keys, vals, level_m=1, fill=0.7, n_shards=4)

    bounds = np.array([KEY_MIN, 500_000, KEY_MAX], dtype=np.int64)
    B = 512
    qk = rng.choice(keys, size=B).astype(np.int64)
    qk[::13] = qk[::13] + 1  # inject misses
    expect = np.isin(qk, keys)

    for policy in ("fetch", "offload", "auto"):
        cfg = dex_mod.DexMeshConfig(
            route_axes=("data",),
            memory_axis="model",
            n_route=2,
            n_memory=4,
            cache_sets=64,
            cache_ways=4,
            policy=policy,
            route_capacity_factor=4.0,
        )
        state = dex_mod.init_state(pool, meta, cfg, bounds)
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, dex_mod.state_shardings(mesh, cfg)
        )
        qk_dev = jax.device_put(
            jnp.asarray(qk), NamedSharding(mesh, P(("data", "model")))
        )
        lk = jax.jit(dex_mod.make_dex_lookup(meta, cfg, mesh))
        s2, found, values = lk(state, qk_dev)
        found, values = np.asarray(found), np.asarray(values)
        assert (found == expect).all(), f"{policy}: found mismatch"
        assert (values[expect] == qk[expect] * 7).all(), f"{policy}: value mismatch"
        assert int(np.asarray(s2.stats)[:, dex_mod.STAT_DROPS].sum()) == 0
        if policy == "fetch":
            # second batch must produce cache hits
            s3, f3, _ = lk(s2, qk_dev)
            hits = int(np.asarray(s3.stats)[:, dex_mod.STAT_HITS].sum())
            assert hits > 0, "no cache hits on repeat batch"
            assert (np.asarray(f3) == expect).all()
        if policy == "offload":
            offs = int(np.asarray(s2.stats)[:, dex_mod.STAT_OFFLOADS].sum())
            assert offs == B, f"expected {B} offloads, got {offs}"
    print("MESH_CHECK_OK")


if __name__ == "__main__":
    main()
