"""Multi-device exercise of the Plane-B mesh DEX.  Run as a subprocess by
tests/test_dex_mesh.py so the main pytest session keeps a single device."""

import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import dex as dex_mod  # noqa: E402
from repro.core import pool as pool_mod  # noqa: E402
from repro.core import scan as scan_mod  # noqa: E402
from repro.compat import make_mesh_compat  # noqa: E402
from repro.core.nodes import KEY_MAX, KEY_MIN  # noqa: E402
from repro.core.sim import HostBTree  # noqa: E402


def main() -> None:
    mesh = make_mesh_compat((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    keys = np.sort(
        rng.choice(1_000_000, size=20_000, replace=False).astype(np.int64) + 1
    )
    vals = keys * 7
    pool, meta = pool_mod.build_pool(keys, vals, level_m=1, fill=0.7, n_shards=4)

    bounds = np.array([KEY_MIN, 500_000, KEY_MAX], dtype=np.int64)
    B = 512
    qk = rng.choice(keys, size=B).astype(np.int64)
    qk[::13] = qk[::13] + 1  # inject misses
    expect = np.isin(qk, keys)

    for policy in ("fetch", "offload", "auto"):
        cfg = dex_mod.DexMeshConfig(
            route_axes=("data",),
            memory_axis="model",
            n_route=2,
            n_memory=4,
            cache_sets=64,
            cache_ways=4,
            policy=policy,
            route_capacity_factor=4.0,
        )
        state = dex_mod.init_state(pool, meta, cfg, bounds)
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, dex_mod.state_shardings(mesh, cfg)
        )
        qk_dev = jax.device_put(
            jnp.asarray(qk), NamedSharding(mesh, P(("data", "model")))
        )
        lk = jax.jit(dex_mod.make_dex_lookup(meta, cfg, mesh))
        s2, found, values = lk(state, qk_dev)
        found, values = np.asarray(found), np.asarray(values)
        assert (found == expect).all(), f"{policy}: found mismatch"
        assert (values[expect] == qk[expect] * 7).all(), f"{policy}: value mismatch"
        assert int(np.asarray(s2.stats)[:, dex_mod.STAT_DROPS].sum()) == 0
        if policy == "fetch":
            # second batch must produce cache hits
            s3, f3, _ = lk(s2, qk_dev)
            hits = int(np.asarray(s3.stats)[:, dex_mod.STAT_HITS].sum())
            assert hits > 0, "no cache hits on repeat batch"
            assert (np.asarray(f3) == expect).all()
        if policy == "offload":
            offs = int(np.asarray(s2.stats)[:, dex_mod.STAT_OFFLOADS].sum())
            assert offs == B, f"expected {B} offloads, got {offs}"

    # ---- batched range scans (core/scan.py) vs HostBTree.scan --------------
    host = HostBTree(keys, vals, fill=0.7)
    cfg = dex_mod.DexMeshConfig(
        route_axes=("data",),
        memory_axis="model",
        n_route=2,
        n_memory=4,
        cache_sets=64,
        cache_ways=4,
        route_capacity_factor=4.0,
    )
    state = dex_mod.init_state(pool, meta, cfg, bounds)
    state = jax.tree.map(
        lambda x, s: jax.device_put(x, s), state, dex_mod.state_shardings(mesh, cfg)
    )
    MC = 64
    scan = jax.jit(scan_mod.make_dex_scan(meta, cfg, mesh, max_count=MC))
    BS = 512
    starts = rng.choice(keys, size=BS).astype(np.int64)
    starts[::7] = starts[::7] + 1               # start keys not in the index
    starts[0] = keys[-1] + 100                  # empty-result scan
    # scans straddling the partition boundary at 500_000
    below = keys[(keys > 480_000) & (keys < 500_000)]
    starts[1 : 1 + min(8, below.size)] = below[-8:]
    counts = rng.integers(1, MC + 1, size=BS).astype(np.int64)
    counts[2] = 0
    sharding = NamedSharding(mesh, P(("data", "model")))
    s_scan, out_k, out_v, taken = scan(
        state,
        jax.device_put(jnp.asarray(starts), sharding),
        jax.device_put(jnp.asarray(counts), sharding),
    )
    out_k, out_v, taken = np.asarray(out_k), np.asarray(out_v), np.asarray(taken)
    for i in range(BS):
        expect_keys = [
            k for _, ks in host.scan(int(starts[i]), int(counts[i])) for k in ks
        ][: int(counts[i])] if counts[i] > 0 else []
        got = out_k[i][out_k[i] != KEY_MAX].tolist()
        assert got == expect_keys, f"scan {i}: {got[:4]} != {expect_keys[:4]}"
        assert int(taken[i]) == len(expect_keys), f"scan {i}: taken mismatch"
        assert (out_v[i][: len(expect_keys)]
                == np.asarray(expect_keys, np.int64) * 7).all(), f"scan {i}: values"
    assert int(np.asarray(s_scan.stats)[:, dex_mod.STAT_DROPS].sum()) == 0
    # repeat batch must hit the warmed cache
    s_scan2, k2, _, t2 = scan(
        s_scan,
        jax.device_put(jnp.asarray(starts), sharding),
        jax.device_put(jnp.asarray(counts), sharding),
    )
    np.testing.assert_array_equal(np.asarray(k2), out_k)
    np.testing.assert_array_equal(np.asarray(t2), taken)
    d_hits = (np.asarray(s_scan2.stats)[:, dex_mod.STAT_HITS].sum()
              - np.asarray(s_scan.stats)[:, dex_mod.STAT_HITS].sum())
    assert d_hits > 0, "no cache hits on repeat scan batch"
    print("MESH_CHECK_OK")


if __name__ == "__main__":
    main()
