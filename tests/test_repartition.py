"""Tests for the fixed ``LogicalPartitions.rebalance`` edge cases and the
live repartition controller (core/repartition.py).

Multi-device behaviour (drop reduction + round-trip result parity on the
8-device mesh) lives in tests/mesh_check.py; everything here runs on a
single device.
"""

import numpy as np

from repro.core import dex as dex_mod
from repro.core import pool as pool_mod
from repro.core.nodes import KEY_MAX, KEY_MIN
from repro.core.partition import LogicalPartitions
from repro.core.repartition import (
    RepartitionConfig,
    RepartitionController,
    install_boundaries,
    moved_intervals,
    node_key_ranges,
)


# ---------------------------------------------------------------------------
# rebalance edge cases (the bugs this PR fixes)
# ---------------------------------------------------------------------------


class TestRebalanceEdgeCases:
    def test_heavy_skew_stays_in_data_hull(self):
        """Skewed loads must not emit boundaries in the int64 sentinel
        space (the old walk priced the KEY_MIN/KEY_MAX edge widths as
        populated and produced boundaries like -6.8e18 that own no real
        keys)."""
        p = LogicalPartitions.equal_width(4, 0, 1000)
        p2 = p.rebalance([100.0, 1.0, 1.0, 1.0])
        inner = p2.boundaries[1:-1]
        assert p2.num_partitions == 4
        assert (inner > -1000).all() and (inner < 2000).all()
        # with an explicit sampled key range the hull is exact
        p3 = p.rebalance([100.0, 1.0, 1.0, 1.0], key_range=(0, 999))
        assert (p3.boundaries[1:-1] >= 0).all()
        assert (p3.boundaries[1:-1] <= 999).all()

    def test_zero_load_preserves_partition_count(self):
        p = LogicalPartitions.equal_width(4, 0, 1000)
        p2 = p.rebalance([0.0, 0.0, 0.0, 0.0])
        # no signal: table unchanged, never collapsed 4 -> 1
        assert p2.num_partitions == 4
        np.testing.assert_array_equal(p2.boundaries, p.boundaries)

    def test_partial_zero_loads_preserve_partition_count(self):
        p = LogicalPartitions.equal_width(4, 0, 1000)
        p2 = p.rebalance([10.0, 0.0, 0.0, 0.0], key_range=(0, 999))
        assert p2.num_partitions == 4
        assert np.all(np.diff(p2.boundaries.astype(object)) > 0)

    def test_single_hot_partition_converges(self):
        """Iterated measure->rebalance must concentrate boundaries around a
        single hot range until the load spreads over all partitions."""
        parts = LogicalPartitions.equal_width(4, 0, 100_000)
        hot = np.arange(40_000, 50_000)
        for _ in range(6):
            loads = np.bincount(parts.owner_of(hot), minlength=4)
            parts = parts.rebalance(loads, key_range=(0, 99_999))
            assert parts.num_partitions == 4
        final = np.bincount(parts.owner_of(hot), minlength=4)
        assert final.max() < 0.3 * hot.size  # near-equal split of the range

    def test_equal_width_narrow_range_preserves_count(self):
        p = LogicalPartitions.equal_width(4, 0, 2)
        assert p.num_partitions == 4
        assert np.unique(p.boundaries).size == 5

    def test_from_samples_few_distinct_preserves_count(self):
        p = LogicalPartitions.from_samples(np.array([7, 7, 7, 7, 7]), 4)
        assert p.num_partitions == 4
        assert np.unique(p.boundaries).size == 5

    def test_single_partition_is_noop(self):
        p = LogicalPartitions(np.array([KEY_MIN, KEY_MAX], np.int64))
        p2 = p.rebalance([42.0])
        assert p2.num_partitions == 1


# ---------------------------------------------------------------------------
# controller primitives
# ---------------------------------------------------------------------------


def _small_state(n_route=2, n_memory=1, n_keys=2000):
    keys = np.arange(1, n_keys + 1, dtype=np.int64) * 10
    pool, meta = pool_mod.build_pool(keys, keys * 3, level_m=1, fill=0.7,
                                     n_shards=n_memory)
    cfg = dex_mod.DexMeshConfig(n_route=n_route, n_memory=n_memory)
    mid = int(keys[n_keys // 2])
    bounds = np.array([KEY_MIN, mid, KEY_MAX], np.int64)
    state = dex_mod.init_state(pool, meta, cfg, bounds)
    return keys, pool, meta, cfg, state, bounds


class TestNodeKeyRanges:
    def test_ranges_tile_each_level(self):
        keys, pool, meta, _, _, _ = _small_state()
        gids, lo, hi = node_key_ranges(np.asarray(pool.pool_keys), meta)
        assert (hi.astype(object) > lo.astype(object)).all()
        # leaves alone must tile [KEY_MIN, KEY_MAX) exactly once
        is_leaf = (gids % meta.subtree_cap) >= meta.leaf_start
        llo = np.sort(lo[is_leaf].astype(object))
        lhi = np.sort(hi[is_leaf].astype(object))
        assert llo[0] == KEY_MIN and lhi[-1] == KEY_MAX
        np.testing.assert_array_equal(llo[1:], lhi[:-1])

    def test_every_key_covered_by_one_leaf(self):
        keys, pool, meta, _, _, _ = _small_state()
        gids, lo, hi = node_key_ranges(np.asarray(pool.pool_keys), meta)
        is_leaf = (gids % meta.subtree_cap) >= meta.leaf_start
        lo_l, hi_l = lo[is_leaf], hi[is_leaf]
        probe = keys[:: 97]
        covered = (
            (lo_l[None, :].astype(object) <= probe[:, None])
            & (probe[:, None] < hi_l[None, :].astype(object))
        ).sum(axis=1)
        assert (covered == 1).all()


class TestMovedIntervals:
    def test_disjoint_and_exact(self):
        old = LogicalPartitions(np.array([KEY_MIN, 100, 200, KEY_MAX],
                                         np.int64))
        new = LogicalPartitions(np.array([KEY_MIN, 150, 200, KEY_MAX],
                                         np.int64))
        assert moved_intervals(old, new) == [(100, 150)]
        assert moved_intervals(old, old) == []

    def test_full_shift(self):
        old = LogicalPartitions(np.array([KEY_MIN, 100, KEY_MAX], np.int64))
        new = LogicalPartitions(np.array([KEY_MIN, 500, KEY_MAX], np.int64))
        assert moved_intervals(old, new) == [(100, 500)]


class TestInstallBoundaries:
    def test_bumps_only_moved_nodes(self):
        keys, pool, meta, cfg, state, bounds = _small_state()
        old = LogicalPartitions(bounds)
        new = old.rebalance([3.0, 1.0], key_range=(int(keys[0]),
                                                   int(keys[-1])))
        st2, n_inval, _, _ = install_boundaries(state, meta, old, new)
        assert n_inval > 0
        v = np.asarray(st2.versions)
        assert int((v > 0).sum()) == n_inval * v.shape[0]
        np.testing.assert_array_equal(
            np.asarray(st2.boundaries), new.boundaries
        )
        # nodes outside the moved interval keep version 0
        gids, lo, hi = node_key_ranges(np.asarray(pool.pool_keys), meta)
        (a, b), = moved_intervals(old, new)
        untouched = gids[(hi.astype(object) <= a) | (lo.astype(object) >= b)]
        assert (v[0, untouched] == 0).all()

    def test_noop_install_invalidates_nothing(self):
        _, _, meta, _, state, bounds = _small_state()
        old = LogicalPartitions(bounds)
        st2, n_inval, sb, sa = install_boundaries(state, meta, old, old)
        assert n_inval == 0 and sb == sa
        assert int(np.asarray(st2.versions).sum()) == 0


class TestController:
    def _stats(self, served, drops=0, n_memory=1):
        n_route = len(served)
        s = np.zeros((n_route * n_memory, dex_mod.N_STATS), np.int64)
        s[:, dex_mod.STAT_OPS] = np.repeat(served, n_memory)
        s[0, dex_mod.STAT_DROPS] = drops
        return s

    def test_trigger_needs_min_ops(self):
        parts = LogicalPartitions.equal_width(2, 0, 1000)
        ctl = RepartitionController(
            parts, n_memory=1,
            cfg=RepartitionConfig(imbalance_threshold=1.25, min_ops=1000),
        )
        ctl.observe(self._stats([400, 10]))
        assert not ctl.should_repartition()     # 410 ops < min_ops
        ctl.observe(self._stats([1200, 30]))    # cumulative counters
        assert ctl.should_repartition()

    def test_drop_fraction_triggers(self):
        parts = LogicalPartitions.equal_width(2, 0, 1000)
        ctl = RepartitionController(
            parts, n_memory=1,
            cfg=RepartitionConfig(imbalance_threshold=10.0, drop_frac=0.01,
                                  min_ops=100),
        )
        ctl.observe(self._stats([300, 290], drops=50))
        assert ctl.should_repartition()

    def test_balanced_load_never_triggers(self):
        parts = LogicalPartitions.equal_width(2, 0, 1000)
        ctl = RepartitionController(
            parts, n_memory=1,
            cfg=RepartitionConfig(imbalance_threshold=1.25, min_ops=100),
        )
        ctl.observe(self._stats([500, 500]))
        assert not ctl.should_repartition()

    def test_demand_signal_preferred_and_hull_tracked(self):
        parts = LogicalPartitions.equal_width(2, 0, 1000)
        ctl = RepartitionController(
            parts, n_memory=1,
            cfg=RepartitionConfig(imbalance_threshold=1.25, min_ops=100),
        )
        demand = np.array([[900, 0], [0, 100]], np.int64)
        keys = np.array([5, 400, 800, KEY_MAX], np.int64)
        ctl.observe(self._stats([100, 100]), keys, demand=demand)
        assert ctl.should_repartition()          # demand sees past the cap
        prop = ctl.propose()
        assert prop.num_partitions == 2
        assert 5 <= int(prop.boundaries[1]) <= 800   # hull from keys

    def test_maybe_repartition_installs_and_cools_down(self):
        keys, pool, meta, cfg, state, bounds = _small_state()
        ctl = RepartitionController(
            LogicalPartitions(bounds), n_memory=1,
            cfg=RepartitionConfig(imbalance_threshold=1.25, min_ops=100,
                                  cooldown_batches=2),
        )
        demand = np.array([[950, 0], [0, 50]], np.int64)
        ctl.observe(self._stats([500, 50], n_memory=1), keys, demand=demand)
        state2, report = ctl.maybe_repartition(state, meta)
        assert report is not None
        assert report.nodes_invalidated > 0
        assert LogicalPartitions(report.new_boundaries).num_partitions == 2
        np.testing.assert_array_equal(
            np.asarray(state2.boundaries), ctl.parts.boundaries
        )
        # cooldown: the next observe cannot immediately re-trigger
        ctl.observe(self._stats([500, 50]), keys,
                    demand=demand + demand)
        assert not ctl.should_repartition()
