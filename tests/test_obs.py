"""Unit tests for the telemetry plane (repro/obs): registry round-trip,
snapshot/delta math, batch timelines, Chrome trace export, drift checks.

These tests are deliberately mesh-free: the registry and drift modules are
numpy-only, and the timeline is fed host arrays shaped like the forced
8-device ``DexState.stats`` so the math is exact and fast.
"""

import dataclasses
import json

import numpy as np
import pytest

from repro.core import dex as dex_mod
from repro.core.sim import Counters, SimConfig
from repro.obs import drift, latency, registry, trace
from repro.obs.timeline import BatchTimeline, obs_phase, timed_call


# ---------------------------------------------------------------------------
# Registry round-trip
# ---------------------------------------------------------------------------


def test_every_stat_constant_derives_from_registry():
    consts = registry.stat_constants()
    assert len(consts) == registry.N_STATS
    for const_name, slot in consts.items():
        assert getattr(dex_mod, const_name) == slot
    assert dex_mod.N_STATS == registry.N_STATS


def test_mesh_slots_dense_and_unique():
    slots = [m.slot for m in registry.MESH_SLOTS]
    assert slots == list(range(registry.N_STATS))
    names = [m.name for m in registry.METRICS]
    assert len(names) == len(set(names))


def test_every_sim_counters_field_mapped_exactly_once():
    sim_fields = [m.sim_field for m in registry.METRICS if m.sim_field]
    assert len(sim_fields) == len(set(sim_fields)), "sim field mapped twice"
    counter_fields = {f.name for f in dataclasses.fields(Counters)}
    assert set(sim_fields) == counter_fields, (
        "registry sim_field set must cover sim.Counters exactly"
    )


def test_paired_metrics_live_on_both_planes():
    for m in registry.PAIRED:
        assert m.slot is not None and m.sim_field is not None
    # mesh-only metrics are the SPMD artifacts called out in the docstring
    mesh_only = {m.name for m in registry.MESH_SLOTS if m.sim_field is None}
    assert mesh_only == {"drops", "splits", "drains"}


def test_registry_validation_rejects_bad_metrics():
    with pytest.raises(ValueError):
        registry.Metric("x", "events", "nonsense")
    with pytest.raises(ValueError):
        registry.Metric("x", "ratio", "derived")  # derived without compute
    with pytest.raises(ValueError):
        registry.Metric("x", "events", "counter")  # maps to neither plane


# ---------------------------------------------------------------------------
# Snapshot / delta math on forced-8-device-shaped arrays
# ---------------------------------------------------------------------------


def _stats(n_dev=8, **named):
    arr = np.zeros((n_dev, registry.N_STATS), np.int64)
    for name, vec in named.items():
        arr[:, registry.SLOT_OF[name]] = vec
    return arr


def test_snapshot_fleet_and_derived():
    arr = _stats(ops=np.arange(8) * 100, hits=np.arange(8) * 50,
                 drops=np.full(8, 7))
    snap = registry.snapshot(arr)
    assert snap.n_devices == 8
    assert snap.fleet["ops"] == 2800
    assert snap.fleet["hits"] == 1400
    assert snap.derived["hit_rate"] == pytest.approx(0.5)
    assert snap.derived["drops_per_op"] == pytest.approx(56 / 2800)
    assert np.array_equal(snap.per_device["drops"], np.full(8, 7))
    # __getitem__ resolves counters and derived alike
    assert snap["ops"] == 2800
    assert snap["hit_rate"] == pytest.approx(0.5)


def test_snapshot_accepts_state_like_and_1d():
    class FakeState:
        stats = _stats(ops=np.full(8, 10))

    assert registry.snapshot(FakeState()).fleet["ops"] == 80
    one = registry.snapshot(np.zeros(registry.N_STATS, np.int64))
    assert one.n_devices == 1
    with pytest.raises(ValueError):
        registry.snapshot(np.zeros((8, registry.N_STATS + 3), np.int64))


def test_delta_recomputes_derived():
    before = registry.snapshot(_stats(ops=np.full(8, 100), hits=np.full(8, 90)))
    after = registry.snapshot(_stats(ops=np.full(8, 200), hits=np.full(8, 120)))
    d = registry.delta(after, before)
    assert d.fleet["ops"] == 800
    assert d.fleet["hits"] == 240
    assert d.derived["hit_rate"] == pytest.approx(240 / 800)


def test_sim_view_reads_counters_and_partial_fakes():
    c = Counters(ops=100, rdma_read=40, local_accesses=55, bytes=4096)
    named = registry.sim_view(c)
    assert named["ops"] == 100
    assert named["fetches"] == 40
    assert named["hits"] == 55
    assert named["bytes_per_op"] == pytest.approx(40.96)

    class Partial:
        rdma_write = 9

    assert registry.sim_view(Partial())["writes"] == 9


# ---------------------------------------------------------------------------
# Timeline
# ---------------------------------------------------------------------------


def _timeline_with_batches():
    tl = BatchTimeline("unit", meta={"devices": 8})
    tl.prime(_stats())
    for i in range(3):
        ob = tl.batch(f"b{i}")
        with ob:
            with ob.phase("engine") as ph:
                ph.fence(np.arange(4))
            with ob.phase("retry/r1"):
                pass
            ob.counters(_stats(ops=np.full(8, 100 * (i + 1)),
                               hits=np.full(8, 40 * (i + 1))))
            ob.retry("insert", i + 1)
    return tl


def test_timeline_counter_and_phase_totals():
    tl = _timeline_with_batches()
    assert len(tl.batches) == 3
    # per-batch deltas: 800, 800, 800 fleet ops
    for rec in tl.batches:
        assert rec.counters.fleet["ops"] == 800
        assert rec.counters.fleet["hits"] == 320
    totals = tl.counter_totals()
    assert totals["ops"] == 2400
    assert totals["hit_rate"] == pytest.approx(0.4)
    phases = tl.phase_totals()
    assert phases["engine"]["count"] == 3
    assert phases["retry/r1"]["count"] == 3
    rl = tl.retry_latency()
    assert rl["insert"]["count"] == 3
    assert rl["insert"]["mean_rounds"] == pytest.approx(2.0)
    assert rl["insert"]["max_rounds"] == 3


def test_timeline_json_roundtrip():
    tl = _timeline_with_batches()
    payload = json.loads(json.dumps(tl.to_json()))
    assert payload["name"] == "unit"
    assert payload["n_batches"] == 3
    assert len(payload["batches"]) == 3
    b0 = payload["batches"][0]
    assert b0["counters"]["ops"] == 800
    assert {p["name"] for p in b0["phases"]} == {"engine", "retry/r1"}
    assert b0["retries"] == {"insert": 1}


def test_instrument_wraps_state_returning_callable():
    tl = BatchTimeline("wrap")
    tl.prime(_stats())

    class FakeState:
        def __init__(self, n):
            self.stats = _stats(ops=np.full(8, n))

    def engine(state, n):
        return FakeState(n), "aux"

    engine.plan = {"phases": ("dex/route",)}
    wrapped = tl.instrument(engine, label="engine")
    assert wrapped.plan == {"phases": ("dex/route",)}
    out = wrapped(None, 50)
    assert out[1] == "aux"
    assert tl.batches[0].counters.fleet["ops"] == 400
    wrapped(None, 75)
    assert tl.batches[1].counters.fleet["ops"] == 200  # delta, not total


def test_timed_call_and_obs_phase_nullcontext():
    out, secs = timed_call(lambda x: x + 1, 41)
    assert out == 42 and secs >= 0.0
    with obs_phase(None, "anything"):
        pass  # no-op without an observer
    tl = BatchTimeline("hook")
    ob = tl.batch("b")
    with ob:
        with obs_phase(ob, "smo/drain"):
            pass
    assert tl.batches[0].phase_seconds().keys() == {"smo/drain"}


# ---------------------------------------------------------------------------
# Trace export
# ---------------------------------------------------------------------------


def test_trace_events_schema(tmp_path):
    tl = _timeline_with_batches()
    doc = trace.to_trace_events(tl)
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    assert events, "no events emitted"
    kinds = {e["ph"] for e in events}
    assert {"M", "X", "C"} <= kinds
    for e in events:
        assert isinstance(e["name"], str) and "pid" in e
        if e["ph"] == "X":
            assert e["ts"] >= 0 and e["dur"] >= 0 and "tid" in e
        if e["ph"] == "C":
            assert isinstance(e["args"], dict) and e["args"]
    # every batch contributes one top-level X span plus its phases
    batch_spans = [e for e in events
                   if e["ph"] == "X" and e.get("cat") == "batch"]
    assert len(batch_spans) == 3
    phase_spans = {e["name"] for e in events
                   if e["ph"] == "X" and e.get("cat") == "phase"}
    assert phase_spans == {"engine", "retry/r1"}
    # counter tracks cover the fleet-derived metrics
    counter_names = {e["name"] for e in events if e["ph"] == "C"}
    assert "hit_rate" in counter_names

    path = tmp_path / "unit.trace.json"
    trace.write_trace(tl, str(path))
    on_disk = json.loads(path.read_text())
    assert on_disk["traceEvents"]


def test_profiler_annotations_is_reentrant_noop_when_disabled():
    with trace.profiler_annotations("x", enabled=False):
        pass


# ---------------------------------------------------------------------------
# Drift
# ---------------------------------------------------------------------------


def test_drift_pass_and_fail_and_report_format(capsys):
    mesh = {"ops": 1000, "fetches": 410, "writes": 300}
    sim = {"ops": 1000, "fetches": 400, "writes": 300}
    rep = drift.assert_plane_agreement(
        mesh, sim,
        {"fetches": drift.rel(0.05), "writes": drift.rel(0.01)},
        label="unit",
    )
    assert rep.ok and not rep.failures
    out = capsys.readouterr().out
    assert "plane agreement [unit]: OK" in out
    assert "[ok  ]" in out

    with pytest.raises(drift.PlaneDriftError) as ei:
        drift.assert_plane_agreement(
            mesh, sim, {"fetches": drift.rel(0.01)}, label="unit",
            verbose=False,
        )
    report = ei.value.report
    assert not report.ok and len(report.failures) == 1
    assert "DRIFT" in report.format()
    assert "fetches" in str(ei.value)


def test_drift_per_op_normalisation():
    # 0.41 vs 0.40 fetches/op: 2.5% relative error despite 10x more mesh ops
    mesh = {"ops": 10_000, "fetches": 4100}
    sim = {"ops": 1_000, "fetches": 400}
    rep = drift.compare(mesh, sim, {"fetches": drift.rel(0.05, per_op=True)})
    assert rep.ok
    assert rep.entries[0].measured == pytest.approx(0.025)
    assert not drift.compare(
        mesh, sim, {"fetches": drift.rel(0.05)}
    ).ok, "without per_op the raw counts disagree 10x"


def test_drift_ratio_band_and_min_count_skip():
    rep = drift.compare({"smo_splits": 30}, {"smo_splits": 20},
                        {"smo_splits": drift.ratio(0.4, 2.5)})
    assert rep.ok and rep.entries[0].measured == pytest.approx(1.5)
    skipped = drift.compare({"smo_splits": 3}, {"smo_splits": 0},
                            {"smo_splits": drift.ratio(0.4, 2.5, min_count=10)})
    assert skipped.ok and skipped.entries[0].skipped
    assert "SKIP" in skipped.format()


def test_drift_absolute_gauge():
    rep = drift.compare({"moved_fraction": 0.31}, {"moved_fraction": 0.27},
                        {"moved_fraction": drift.absolute(0.10)})
    assert rep.ok and rep.entries[0].measured == pytest.approx(0.04)
    assert not drift.compare(
        {"moved_fraction": 0.31}, {"moved_fraction": 0.05},
        {"moved_fraction": drift.absolute(0.10)},
    ).ok


def test_drift_rejects_unregistered_metric():
    with pytest.raises(KeyError):
        drift.compare({"ops": 1}, {"ops": 1}, {"tpyo": drift.rel(0.1)})


def test_drift_coerces_all_counter_carriers():
    snap = registry.snapshot(_stats(ops=np.full(8, 50), hits=np.full(8, 25)))
    counters = Counters(ops=400, local_accesses=200)
    rep = drift.compare(snap, counters, {"hits": drift.rel(0.0, per_op=True)})
    assert rep.ok, rep.format()
    tl = _timeline_with_batches()
    rep2 = drift.compare(tl, {"ops": 2400}, {"ops": drift.rel(0.0)})
    assert rep2.ok

    class FakeState:
        stats = _stats(ops=np.full(8, 50))

    assert drift.compare(FakeState(), {"ops": 400},
                         {"ops": drift.rel(0.0)}).ok
    with pytest.raises(TypeError):
        drift._named(object())


# ---------------------------------------------------------------------------
# Latency ledger (obs/latency): bucket schema, percentiles, audit, timeline
# ---------------------------------------------------------------------------


def test_latency_constants_mirror_sim_config():
    # the ledger prices lanes with literal copies of the SimConfig defaults
    # (no import cycle); if either side moves, the planes silently diverge —
    # so this equality is load-bearing, not cosmetic
    cfg = SimConfig(name="unit")
    assert latency.T_CACHED == cfg.t_cached_access
    assert latency.T_READ == cfg.t_rdma_read
    assert latency.T_WRITE == cfg.t_rdma_write
    assert latency.T_RPC == cfg.t_rpc_base
    assert latency.T_MEM == cfg.t_mem_search
    assert latency.T_LOCAL == cfg.t_local_search


def test_latency_bucket_schema():
    edges = latency.bucket_edges()
    assert len(edges) == latency.N_BUCKETS + 1
    assert np.all(np.diff(edges) > 0)
    # underflow clamps to bucket 0, overflow to the last bucket
    assert latency.bucket_index(0.0) == 0
    assert latency.bucket_index(latency.T0 / 2) == 0
    assert latency.bucket_index(1.0) == latency.N_BUCKETS - 1
    # a bucket's left edge lands in that bucket (half-open intervals)
    for i in (0, 1, 5, latency.N_BUCKETS - 1):
        assert latency.bucket_index(float(edges[i])) == i
    # vectorised form agrees with scalars
    xs = np.array([0.0, latency.T0, 3e-6, 1.0])
    assert list(latency.bucket_index(xs)) == [
        int(latency.bucket_index(float(x))) for x in xs
    ]


def test_latency_percentile_from_bucket_cdf():
    assert latency.percentile(np.zeros(latency.N_BUCKETS), 99.0) == 0.0
    h = np.zeros(latency.N_BUCKETS)
    h[3] = 10
    mid = latency.T0 * 2.0**3 * 2.0**0.5
    assert latency.percentile(h, 50.0) == pytest.approx(mid)
    assert latency.percentile(h, 99.0) == pytest.approx(mid)
    # 90 lanes in bucket 2, 10 in bucket 9: p50 low, p99 in the tail
    h2 = np.zeros(latency.N_BUCKETS)
    h2[2], h2[9] = 90, 10
    assert latency.percentile(h2, 50.0) == pytest.approx(
        latency.T0 * 4 * 2**0.5)
    assert latency.percentile(h2, 99.0) == pytest.approx(
        latency.T0 * 512 * 2**0.5)


def test_latency_section_and_ledger_conservation():
    rng = np.random.default_rng(0)
    hist = rng.integers(
        0, 50,
        size=(latency.N_CLASSES, latency.N_PATHS, latency.N_BUCKETS))
    sec = latency.latency_section(hist)
    assert sec["total"] == int(hist.sum())
    assert sec["op_classes"] == list(latency.OP_CLASSES)
    assert sec["paths"] == list(latency.PATHS)
    nested = sum(sum(sum(cell) for cell in cls) for cls in sec["hist"])
    assert nested == sec["total"]
    for led in sec["ledger"].values():
        assert led["count"] == sum(
            led["paths"][p]["count"] for p in latency.PATHS)
        shares = sum(led["paths"][p]["share"] for p in latency.PATHS)
        assert shares == pytest.approx(1.0 if led["count"] else 0.0)


def test_audit_report_excludes_unrealized_cells():
    pred = np.array([[100.0, 50.0], [0.0, 7.0]])
    real = np.array([[200.0, 0.0], [0.0, 7.0]])
    rep = latency.audit_report(pred, real)
    # the (0,1) cell predicted bytes but realized none: reported in cells,
    # excluded from the fleet ratio (no fetch-side decision to audit)
    assert rep["predicted_bytes"] == pytest.approx(107.0)
    assert rep["realized_bytes"] == pytest.approx(207.0)
    assert rep["mispricing_ratio"] == pytest.approx(107.0 / 207.0)
    cells = {(c["column"], c["level"]) for c in rep["cells"]}
    assert cells == {(0, 0), (0, 1), (1, 1)}  # all-zero (1,0) dropped
    empty = latency.audit_report(np.zeros((1, 1)), np.zeros((1, 1)))
    assert empty["mispricing_ratio"] == 0.0 and empty["cells"] == []


def test_percentile_gauges_skip_empty_and_filter_classes():
    hist = np.zeros(
        (latency.N_CLASSES, latency.N_PATHS, latency.N_BUCKETS), np.int64)
    hist[0, 0, 2] = 5  # lookups only
    g = latency.percentile_gauges(hist)
    assert set(g) == {"lat_p50_lookup", "lat_p99_lookup"}
    hist[3, 1, 8] = 2  # scans now sampled too, but filtered out
    g2 = latency.percentile_gauges(hist, classes=("lookup",))
    assert set(g2) == {"lat_p50_lookup", "lat_p99_lookup"}
    # every gauge name must be drift-gateable
    for name in latency.percentile_gauges(hist):
        assert name in registry.BY_NAME


class _LatState:
    """Minimal DexState stand-in carrying the two latency planes."""

    def __init__(self, dev=2):
        self.lat_hist = np.zeros(
            (dev, latency.N_CLASSES, latency.N_PATHS, latency.N_BUCKETS),
            np.int64)
        self.lat_audit = np.zeros((dev, 2, 4, 3), np.float32)


def test_timeline_latency_prime_capture_delta():
    st = _LatState()
    st.lat_hist[:, 0, 0, 1] = 7  # warmup lanes, fenced out by prime
    st.lat_audit[:, 0, 0, 0] = 3.0
    tl = BatchTimeline("lat")
    tl.prime_latency(st)
    st.lat_hist[0, 1, 3, 4] += 11  # measured window
    st.lat_audit[1, 1, 2, 1] += 5.0
    hist = tl.capture_latency(st)
    assert hist.shape == (
        latency.N_CLASSES, latency.N_PATHS, latency.N_BUCKETS)
    assert int(hist.sum()) == 11 and hist[1, 3, 4] == 11
    summ = tl.summary()
    assert summ["latency"]["total"] == 11
    audit = summ["cost_audit"]
    assert audit["realized_bytes"] == pytest.approx(5.0)
    assert audit["predicted_bytes"] == pytest.approx(0.0)
    # never primed -> lifetime totals
    tl2 = BatchTimeline("lat2")
    assert int(tl2.capture_latency(st).sum()) == int(st.lat_hist.sum())


def test_timeline_capture_accepts_bare_histogram():
    hist = np.zeros(
        (latency.N_CLASSES, latency.N_PATHS, latency.N_BUCKETS), np.int64)
    hist[2, 1, 5] = 4
    tl = BatchTimeline("raw")
    assert int(tl.capture_latency(hist).sum()) == 4
    summ = tl.summary()
    assert summ["latency"]["total"] == 4
    assert "cost_audit" not in summ  # no audit plane on a bare histogram


def test_retry_latency_zero_retry_and_interleaving():
    tl = BatchTimeline("retries")
    tl.prime(_stats())
    with tl.batch("b0") as ob:
        ob.retry("insert", 3)
    with tl.batch("b1"):
        pass  # a batch where nothing shed
    with tl.batch("b2") as ob:
        ob.retry("insert", 1)
        ob.retry("scan", 2)
    rl = tl.retry_latency()
    # a class that never sheds is absent, not zero-filled
    assert "lookup" not in rl
    assert rl["insert"] == {"count": 2, "mean_rounds": 2.0, "max_rounds": 3}
    assert rl["scan"] == {"count": 1, "mean_rounds": 2.0, "max_rounds": 2}
    assert tl.batches[1].retries == {}


def test_trace_counter_tracks_on_empty_timeline():
    tl = BatchTimeline("empty")
    doc = trace.to_trace_events(tl)
    assert all(e["ph"] != "C" for e in doc["traceEvents"])
    # capturing a ledger on a zero-batch timeline anchors the latency
    # counter tracks at t=0 instead of crashing on max() of no spans
    hist = np.zeros(
        (latency.N_CLASSES, latency.N_PATHS, latency.N_BUCKETS), np.int64)
    hist[0, 1, 4] = 9
    tl.capture_latency(hist)
    tracks = [e for e in trace.to_trace_events(tl)["traceEvents"]
              if e["ph"] == "C" and e.get("cat") == "latency"]
    assert {e["name"] for e in tracks} == {"lat_p50_lookup",
                                           "lat_p99_lookup"}
    for e in tracks:
        assert e["ts"] == 0.0
        assert e["args"][e["name"]] > 0.0


def test_trace_emits_mispricing_track_with_audit():
    st = _LatState()
    st.lat_hist[0, 0, 1, 2] = 3
    st.lat_audit[0, 0, 0, 0] = 10.0  # predicted
    st.lat_audit[0, 1, 0, 0] = 5.0   # realized
    tl = BatchTimeline("aud")
    tl.capture_latency(st)
    ev = trace.to_trace_events(tl)["traceEvents"]
    g = {e["name"]: e["args"] for e in ev if e["ph"] == "C"}
    assert g["offload_mispricing"]["offload_mispricing"] == pytest.approx(
        2.0)


# ---------------------------------------------------------------------------
# Docs can't rot: DESIGN.md embeds the generated counter table
# ---------------------------------------------------------------------------


def test_design_md_counter_table_matches_registry():
    import pathlib

    design = pathlib.Path(__file__).resolve().parent.parent / "DESIGN.md"
    text = design.read_text()
    for line in registry.markdown_table().splitlines():
        assert line in text, f"DESIGN.md counter table is stale: {line!r}"
