"""Training substrate tests: optimizer, train loop, checkpointing,
fault tolerance, elastic reshard, data pipeline."""

import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.compat import make_mesh_compat, shard_map_compat
from repro.data.pipeline import TokenPipeline
from repro.launch.train import build_run, train
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import (
    FailureInjector, FatalError, RetryPolicy, StepWatchdog, TransientError,
)
from repro.train.optimizer import (
    OptConfig, adamw_update, compress_int8, decompress_int8, init_opt_state,
    schedule,
)


class TestOptimizer:
    def _setup(self):
        params = {
            "w": jnp.ones((4, 8), jnp.bfloat16),
            "stack": jnp.ones((3, 4, 8), jnp.bfloat16),  # layer-stacked
            "b": jnp.zeros((8,), jnp.float32),
        }
        grads = jax.tree.map(lambda p: jnp.full(p.shape, 0.1, p.dtype), params)
        cfg = OptConfig(lr=1e-2, warmup_steps=0, total_steps=100)
        return cfg, params, grads

    def test_update_moves_params(self):
        cfg, params, grads = self._setup()
        st = init_opt_state(params, cfg)
        new, st2, metrics = adamw_update(cfg, params, grads, st)
        assert int(st2.step) == 1
        assert float(metrics["grad_norm"]) > 0
        # positive grads => params decrease
        assert float(new["w"].astype(jnp.float32).mean()) < 1.0
        assert float(new["stack"].astype(jnp.float32).mean()) < 1.0

    def test_clip_norm(self):
        cfg, params, grads = self._setup()
        grads = jax.tree.map(lambda g: g * 1e6, grads)
        st = init_opt_state(params, cfg)
        new, _, m = adamw_update(cfg, params, grads, st)
        assert np.isfinite(float(new["w"].astype(jnp.float32).mean()))

    def test_schedule_warmup_and_decay(self):
        cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                        min_lr_ratio=0.1)
        lrs = [float(schedule(cfg, jnp.int32(s))) for s in [0, 5, 10, 50, 100]]
        assert lrs[0] < lrs[1] < lrs[2]          # warmup rises
        assert lrs[2] >= lrs[3] >= lrs[4]        # cosine decays
        assert lrs[4] >= 0.099                   # floor

    def test_int8_error_feedback_roundtrip(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
        err = jnp.zeros_like(g)
        # repeated compression with error feedback converges in the mean
        acc_q = jnp.zeros_like(g)
        for _ in range(8):
            q, scale, err = compress_int8(g, err)
            acc_q = acc_q + decompress_int8(q, scale)
        np.testing.assert_allclose(
            np.asarray(acc_q) / 8, np.asarray(g), atol=0.02
        )


class TestPipeline:
    def test_deterministic(self):
        cfg = get_config("minitron-4b").reduced()
        p1 = TokenPipeline(cfg=cfg, global_batch=4, seq_len=16, seed=3)
        p2 = TokenPipeline(cfg=cfg, global_batch=4, seq_len=16, seed=3)
        b1, b2 = p1.next_batch(), p2.next_batch()
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_snapshot_restore(self):
        cfg = get_config("minitron-4b").reduced()
        p = TokenPipeline(cfg=cfg, global_batch=4, seq_len=16, seed=3)
        p.next_batch(); p.next_batch()
        snap = p.snapshot()
        b3 = p.next_batch()
        q = TokenPipeline(cfg=cfg, global_batch=4, seq_len=16, seed=3)
        q.restore(snap)
        np.testing.assert_array_equal(q.next_batch()["tokens"], b3["tokens"])

    def test_reshard_preserves_determinism(self):
        cfg = get_config("minitron-4b").reduced()
        p = TokenPipeline(cfg=cfg, global_batch=8, seq_len=16, seed=3,
                          n_shards=2, shard=0)
        p2 = p.reshard(4, 1)
        assert p2.local_batch == 2
        b = p2.next_batch()
        assert b["tokens"].shape == (2, 16)


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        state = {
            "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "nested": {"b": jnp.ones((2,), jnp.bfloat16)},
        }
        mgr.save(5, state, extra={"pipeline": {"step": 7}})
        got, step, extra = mgr.restore(state)
        assert step == 5 and extra["pipeline"]["step"] == 7
        np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(state["a"]))

    def test_keep_k_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        state = {"x": jnp.zeros((2,))}
        for s in [1, 2, 3, 4]:
            mgr.save(s, state)
        assert mgr.all_steps() == [3, 4]

    def test_atomicity_partial_write_ignored(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        state = {"x": jnp.zeros((2,))}
        mgr.save(1, state)
        # a crashed writer leaves a .tmp dir: must be invisible to restore
        os.makedirs(tmp_path / "step_00000002.tmp" / "arrays")
        assert mgr.latest_step() == 1

    def test_namedtuple_state(self, tmp_path):
        cfg = OptConfig()
        params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
        opt = init_opt_state(params, cfg)
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, (params, opt))
        (p2, o2), _, _ = mgr.restore((params, opt))
        assert int(o2.step) == 0
        np.testing.assert_array_equal(
            np.asarray(p2["w"], np.float32), np.asarray(params["w"], np.float32)
        )


class TestFault:
    def test_watchdog_flags_stragglers(self):
        wd = StepWatchdog(straggler_factor=2.0)
        for _ in range(10):
            wd.observe(0.1)
        assert wd.observe(0.5) is True
        assert wd.straggler_rate > 0

    def test_retry_transient(self):
        calls = {"n": 0}

        def flaky():
            calls["n"] += 1
            if calls["n"] < 3:
                raise TransientError("blip")
            return "ok"

        assert RetryPolicy(max_retries=5, backoff_base=0).run(flaky) == "ok"

    def test_fatal_triggers_restore(self):
        restored = {"n": 0}

        def bad():
            if restored["n"] == 0:
                raise FatalError("device lost")
            return "recovered"

        def on_fatal():
            restored["n"] += 1

        out = RetryPolicy(max_retries=1, backoff_base=0).run(bad, on_fatal=on_fatal)
        assert out == "recovered" and restored["n"] == 1

    def test_injector(self):
        inj = FailureInjector({3: TransientError})
        inj.maybe_fail(2)
        with pytest.raises(TransientError):
            inj.maybe_fail(3)
        inj.maybe_fail(3)  # consumed


@pytest.mark.slow
class TestEndToEnd:
    def test_train_loss_decreases_and_resumes(self, tmp_path):
        run = build_run(
            "minitron-4b", reduce=True, batch=4, seq=32, steps=40,
            ckpt_dir=str(tmp_path),
        )
        injector = FailureInjector({15: TransientError})
        losses, wd = train(
            run, 40, ckpt_every=10, injector=injector, log_every=100,
        )
        assert losses[-1] < losses[0], "loss must decrease"
        assert run.ckpt.latest_step() == 40
        # resume from checkpoint: continues at the saved step
        run2 = build_run(
            "minitron-4b", reduce=True, batch=4, seq=32, steps=45,
            ckpt_dir=str(tmp_path),
        )
        losses2, _ = train(run2, 45, ckpt_every=100, log_every=100)
        assert run2.step == 45 and len(losses2) == 5

    def test_elastic_reshard_checkpoint(self, tmp_path):
        from repro.launch.elastic import reshard_checkpoint

        run = build_run(
            "minitron-4b", reduce=True, batch=4, seq=32, steps=10,
            ckpt_dir=str(tmp_path),
        )
        train(run, 5, ckpt_every=5, log_every=100)
        # restore onto a "different" mesh (1x1 here; geometry-independent API)
        mesh = make_mesh_compat((1, 1), ("data", "model"))
        (p2, o2), step, _ = reshard_checkpoint(
            run.ckpt, (run.params, run.opt_state), mesh, run.cfg
        )
        assert step == 5
        # params match bit-exact after the round trip
        a = jax.tree.leaves(run.params)[0]
        b = jax.tree.leaves(p2)[0]
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )

    def test_grad_compression_distributes(self):
        """int8 EF all-reduce inside shard_map matches f32 psum closely."""
        if len(jax.devices()) < 1:
            pytest.skip("no devices")
        from repro.train.optimizer import compressed_psum
        mesh = make_mesh_compat((1,), ("data",))
        g = jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)),
                        jnp.float32)
        err = jnp.zeros_like(g)

        def f(g, err):
            return compressed_psum(g, err, "data")

        out, new_err = jax.jit(
            shard_map_compat(
                f, mesh=mesh,
                in_specs=(jax.sharding.PartitionSpec(),) * 2,
                out_specs=(jax.sharding.PartitionSpec(),) * 2,
            )
        )(g, err)
        np.testing.assert_allclose(np.asarray(out), np.asarray(g), atol=0.05)
