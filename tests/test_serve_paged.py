"""DEX-paged serving tests: page lifecycle through the index, paged decode
equivalence against the dense-cache decoder."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.models import model as M
from repro.serve.kv_cache import PAGE_BITS, PagedKVCache, page_key
from repro.serve.serve_step import paged_decode_step


def small_cfg(**kw):
    return get_config("minitron-4b").reduced(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, **kw
    )


class TestPagedKVCache:
    def test_admit_resolve_release(self):
        cfg = small_cfg()
        kv = PagedKVCache(cfg=cfg, n_pages=32, page_size=8, max_batch=4)
        req = np.array([5, 9])
        kv.admit_request(5, prompt_len=20)   # 3 pages
        kv.admit_request(9, prompt_len=8)    # 1 page
        t = np.asarray(kv.resolve_tables(req, pages_per_req=3))
        assert t.shape == (2, 3)
        # all of request 5's pages distinct and valid
        assert len(set(t[0].tolist())) == 3
        freed = kv.release_request(5)
        assert freed == 3
        freed = kv.release_request(9)
        assert freed == 1
        assert len(kv.free) == 32

    def test_extend_allocates_on_boundary(self):
        cfg = small_cfg()
        kv = PagedKVCache(cfg=cfg, n_pages=8, page_size=4, max_batch=1)
        kv.admit_request(1, prompt_len=0)
        pages = []
        for i in range(9):
            p = kv.extend_request(1)
            if p is not None:
                pages.append(p)
        # tokens 1..9 with page 0 pre-allocated: new pages at len 4 and 8
        assert len(pages) == 2

    def test_pool_exhaustion(self):
        cfg = small_cfg()
        kv = PagedKVCache(cfg=cfg, n_pages=2, page_size=4, max_batch=1)
        kv.admit_request(1, prompt_len=8)
        with pytest.raises(MemoryError):
            kv.admit_request(2, prompt_len=8)

    def test_page_key_layout(self):
        k = page_key(3, 7)
        assert (int(k) >> PAGE_BITS) == 3 and (int(k) & ((1 << PAGE_BITS) - 1)) == 7


class TestPagedDecode:
    def test_matches_dense_decode(self):
        """Paged decode must reproduce the dense-cache decoder exactly."""
        cfg = small_cfg()
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        b, steps, page = 2, 10, 4
        rng = np.random.default_rng(1)
        toks = rng.integers(0, cfg.vocab, size=(b, steps)).astype(np.int32)

        # dense reference
        dense = M.init_decode_cache(cfg, b, max_len=steps)
        ref_logits = []
        for t in range(steps):
            lg, dense = M.decode_step(
                cfg, params, jnp.asarray(toks[:, t : t + 1]), dense, jnp.int32(t)
            )
            ref_logits.append(np.asarray(lg))

        # paged path
        kv = PagedKVCache(cfg=cfg, n_pages=16, page_size=page, max_batch=b)
        req = np.array([11, 22])
        for r in req:
            kv.admit_request(int(r), prompt_len=0)
        ppr = (steps + page - 1) // page
        got = []
        for t in range(steps):
            for r in req:
                kv.extend_request(int(r))
            table = kv.resolve_tables(req, ppr)
            seq_lens = kv.batch_seq_lens(req)
            logits, k_new, v_new = paged_decode_step(
                cfg, params, jnp.asarray(toks[:, t : t + 1]),
                kv.k_pages, kv.v_pages, table, seq_lens,
            )
            kv.append_tokens(req, k_new, v_new)
            got.append(np.asarray(logits))

        for t in range(steps):
            np.testing.assert_allclose(
                got[t], ref_logits[t], atol=2e-2, rtol=2e-2,
            )

    def test_paged_attention_kernel_path(self):
        """use_kernel=True (Pallas interpret) agrees with the jnp path."""
        cfg = small_cfg(head_dim=32)
        params = M.init_params(cfg, jax.random.PRNGKey(3))
        b, page, ppr = 2, 8, 2
        kv = PagedKVCache(cfg=cfg, n_pages=8, page_size=page, max_batch=b)
        req = np.array([1, 2])
        for r in req:
            kv.admit_request(int(r), prompt_len=0)
        rng = np.random.default_rng(4)
        tok = jnp.asarray(rng.integers(0, cfg.vocab, size=(b, 1)), jnp.int32)
        for t in range(5):
            for r in req:
                kv.extend_request(int(r))
            table = kv.resolve_tables(req, ppr)
            seq_lens = kv.batch_seq_lens(req)
            l1, k_new, v_new = paged_decode_step(
                cfg, params, tok, kv.k_pages, kv.v_pages, table, seq_lens,
                use_kernel=False,
            )
            l2, _, _ = paged_decode_step(
                cfg, params, tok, kv.k_pages, kv.v_pages, table, seq_lens,
                use_kernel=True,
            )
            kv.append_tokens(req, k_new, v_new)
            np.testing.assert_allclose(
                np.asarray(l1), np.asarray(l2), atol=1e-3, rtol=1e-3
            )
