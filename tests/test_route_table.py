"""Tests for the leaf-direct route table (core/route_table.py, DESIGN.md
§13) and the routing primitives it leans on.

Covers four planes:

* ``routing.hash64`` / ``routing.leaf_admit_dice`` — the cache-set hash
  and admission dice must actually be uniform (the set-conflict model the
  fig20 benchmark's fetch-pressure argument rests on);
* ``routing.route_owners`` edge behaviour — boundary-equal keys land in
  the upper partition on BOTH planes, pinned across ``install_boundaries``
  rounds (owners only change inside the moved intervals);
* the trainer itself — full coverage when slots suffice, demand-hottest
  keep when they don't, and segment predictions that match the leaves'
  fence ranges;
* the poisoned-predictor contract — a fully poisoned table is
  bit-identical to descent-only mode in the synchronous AND pipelined
  engines (every guess books a mispredict, none is mis-accepted), and the
  Plane-A simulator mirrors the same contract.
"""

import numpy as np

import jax
import jax.numpy as jnp

from repro.compat import make_mesh_compat
from repro.core import dex as dex_mod
from repro.core import engine as engine_mod
from repro.core import pool as pool_mod
from repro.core import route_table, routing
from repro.core.nodes import KEY_MAX, KEY_MIN
from repro.core.partition import LogicalPartitions
from repro.core.repartition import install_boundaries, moved_intervals
from repro.core.sim import HostBTree, SimConfig, Simulator
from repro.data import ycsb


# ---------------------------------------------------------------------------
# hash64 / leaf_admit_dice distribution
# ---------------------------------------------------------------------------


class TestHash64:
    def test_set_index_distribution_uniform(self):
        """Sequential gids must spread evenly over cache sets — the
        conflict-churn model behind the leaf-direct fetch savings assumes
        no systematic set bias."""
        n, sets = 1 << 17, 64
        h = np.asarray(routing.hash64(jnp.arange(n, dtype=jnp.int64)))
        counts = np.bincount(
            (h.astype(np.uint64) % sets).astype(np.int64), minlength=sets)
        mean = n / sets
        assert counts.min() > 0.85 * mean, counts.min()
        assert counts.max() < 1.15 * mean, counts.max()

    def test_avalanche(self):
        """Flipping one input bit flips ~half the output bits (SplitMix64
        finalizer property) — low/high input bits alike."""
        x = np.arange(1, 257, dtype=np.int64) * 0x9E3779B9
        hx = np.asarray(routing.hash64(jnp.asarray(x))).astype(np.uint64)
        for bit in (0, 7, 21, 40, 62):
            y = x ^ np.int64(1 << bit)
            hy = np.asarray(routing.hash64(jnp.asarray(y))).astype(np.uint64)
            flips = np.unpackbits((hx ^ hy).view(np.uint8)).sum() / x.size
            assert 24.0 < flips < 40.0, (bit, flips)

    def test_dice_extremes(self):
        gids = jnp.arange(4096, dtype=jnp.int64)
        assert not np.asarray(routing.leaf_admit_dice(gids, 0)).any()
        assert np.asarray(routing.leaf_admit_dice(gids, 100)).all()

    def test_dice_rate_matches_pct(self):
        gids = jnp.arange(200_000, dtype=jnp.int64)
        for pct in (10, 37, 80):
            frac = float(np.asarray(
                routing.leaf_admit_dice(gids, pct)).mean())
            assert abs(frac - pct / 100.0) < 0.02, (pct, frac)

    def test_salt_rerolls_fixed_gid(self):
        """The per-access salt re-rolls the dice for one node: across salts
        the admit rate matches pct, and both outcomes occur (a hot leaf
        that loses the flip is not frozen out)."""
        gid = jnp.full((50_000,), 12345, jnp.int64)
        salts = jnp.arange(50_000, dtype=jnp.int64)
        hits = np.asarray(routing.leaf_admit_dice(gid, 37, salt=salts))
        assert abs(hits.mean() - 0.37) < 0.02, hits.mean()
        assert hits.any() and not hits.all()

    def test_dice_deterministic(self):
        gids = jnp.arange(1000, dtype=jnp.int64)
        a = np.asarray(routing.leaf_admit_dice(gids, 50, salt=7))
        b = np.asarray(routing.leaf_admit_dice(gids, 50, salt=7))
        np.testing.assert_array_equal(a, b)
        c = np.asarray(routing.leaf_admit_dice(gids, 50, salt=8))
        assert (a != c).any()


# ---------------------------------------------------------------------------
# route_owners edge behaviour across repartition installs
# ---------------------------------------------------------------------------


def _mesh_owners(boundaries, keys, n_route):
    owner, demand = routing.route_owners(
        jnp.asarray(boundaries), jnp.asarray(keys), n_route)
    return np.asarray(owner), np.asarray(demand)


class TestRouteOwnersEdges:
    def test_boundary_equal_keys_take_upper_partition(self):
        """Both planes use half-open ``[lo, hi)`` partitions: a key equal
        to an inner boundary belongs to the partition that STARTS there."""
        b = np.array([KEY_MIN, 100, 200, KEY_MAX], np.int64)
        parts = LogicalPartitions(b)
        probe = np.array([KEY_MIN, KEY_MIN + 1, 99, 100, 101,
                          199, 200, 201, KEY_MAX - 1], np.int64)
        owner, _ = _mesh_owners(b, probe, 3)
        np.testing.assert_array_equal(owner, parts.owner_of(probe))
        assert owner[3] == 1 and owner[6] == 2  # boundary-equal -> upper

    def test_keymax_lanes_get_sentinel_and_no_demand(self):
        b = np.array([KEY_MIN, 100, KEY_MAX], np.int64)
        probe = np.array([50, KEY_MAX, 150, KEY_MAX], np.int64)
        owner, demand = _mesh_owners(b, probe, 2)
        np.testing.assert_array_equal(owner, [0, 2, 1, 2])
        np.testing.assert_array_equal(demand[0], [1, 1])

    def test_owner_parity_pinned_across_install_rounds(self):
        """Regression pin for the repartition path: after every
        ``install_boundaries`` round, the mesh formula agrees with the
        host partition table on dataset keys AND on every boundary-equal /
        boundary-adjacent key, and owners change ONLY inside the moved
        intervals."""
        keys = np.arange(1, 2001, dtype=np.int64) * 10
        pool, meta = pool_mod.build_pool(keys, keys * 3, level_m=1,
                                         fill=0.7, n_shards=1)
        cfg = dex_mod.DexMeshConfig(n_route=2, n_memory=1)
        bounds = np.array([KEY_MIN, int(keys[1000]), KEY_MAX], np.int64)
        state = dex_mod.init_state(pool, meta, cfg, bounds)
        parts = LogicalPartitions(bounds)
        for loads in ([3.0, 1.0], [1.0, 4.0], [2.0, 1.0]):
            new = parts.rebalance(loads, key_range=(int(keys[0]),
                                                    int(keys[-1])))
            state, _, _, _ = install_boundaries(state, meta, parts, new)
            inner = new.boundaries[1:-1]
            probe = np.unique(np.concatenate([
                keys[::37], inner, inner - 1, inner + 1,
                np.array([KEY_MIN, KEY_MAX - 1], np.int64),
            ]))
            got, _ = _mesh_owners(np.asarray(state.boundaries), probe, 2)
            np.testing.assert_array_equal(got, new.owner_of(probe))
            # owners move only inside the moved intervals
            before = parts.owner_of(probe)
            changed = before != got
            moved = moved_intervals(parts, new)
            in_moved = np.zeros(probe.shape, bool)
            for a, b in moved:
                in_moved |= (probe >= a) & (probe < b)
            assert not (changed & ~in_moved).any()
            parts = new

    def test_noop_install_keeps_every_owner(self):
        keys = np.arange(1, 501, dtype=np.int64) * 7
        pool, meta = pool_mod.build_pool(keys, keys, level_m=1, fill=0.7,
                                         n_shards=1)
        cfg = dex_mod.DexMeshConfig(n_route=2, n_memory=1)
        bounds = np.array([KEY_MIN, int(keys[250]), KEY_MAX], np.int64)
        state = dex_mod.init_state(pool, meta, cfg, bounds)
        parts = LogicalPartitions(bounds)
        st2, n_inval, _, _ = install_boundaries(state, meta, parts, parts)
        assert n_inval == 0
        a, _ = _mesh_owners(np.asarray(state.boundaries), keys, 2)
        b, _ = _mesh_owners(np.asarray(st2.boundaries), keys, 2)
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# trainer
# ---------------------------------------------------------------------------


def _setup(n_keys=4000, *, rt_slots=0, seed=0):
    rng = np.random.default_rng(seed)
    keys = np.sort(rng.choice(16 * n_keys, size=n_keys,
                              replace=False).astype(np.int64) + 1)
    pool, meta = pool_mod.build_pool(keys, keys * 5, level_m=1, fill=0.7,
                                     n_shards=1)
    mesh = make_mesh_compat((1, 1), ("data", "model"))
    cfg = dex_mod.DexMeshConfig(
        n_route=1, n_memory=1, cache_sets=128, cache_ways=4,
        p_admit_leaf_pct=10, route_capacity_factor=2.0, policy="fetch",
        route_table_slots=rt_slots,
    )
    bounds = np.array([KEY_MIN, KEY_MAX], np.int64)
    state = dex_mod.init_state(pool, meta, cfg, bounds)
    return keys, state, meta, cfg, mesh


class TestTrainRouteTable:
    def test_full_coverage_when_slots_suffice(self):
        keys, state, meta, cfg, _ = _setup(rt_slots=1024)
        assert not route_table.route_table_active(state)
        state = route_table.train_route_table(state, meta)
        assert route_table.route_table_active(state)
        gids, lo, hi = route_table.leaf_ranges(state, meta)
        live = np.asarray(state.rt_ver) >= 0
        assert int(live.sum()) == gids.size
        rt_keys = np.asarray(state.rt_keys)[live]
        rt_hi = np.asarray(state.rt_hi)[live]
        # segments are the sorted leaf fences, tiling [KEY_MIN, KEY_MAX)
        np.testing.assert_array_equal(rt_keys, lo)
        np.testing.assert_array_equal(rt_hi, hi)
        assert rt_keys[0] == KEY_MIN and rt_hi[-1] == KEY_MAX

    def test_predictions_match_leaf_fences(self):
        """For every dataset key the predicted (subtree, local) is the
        leaf whose fence range contains the key, and the key sits inside
        the predicted segment's bounds."""
        keys, state, meta, cfg, _ = _setup(rt_slots=1024)
        state = route_table.train_route_table(state, meta)
        gids, lo, hi = route_table.leaf_ranges(state, meta)
        probe = keys[::13]
        idx, sub, local = routing.rt_predict(
            state.rt_keys, state.rt_sub, state.rt_local, jnp.asarray(probe))
        idx, sub, local = (np.asarray(a) for a in (idx, sub, local))
        rt_keys = np.asarray(state.rt_keys)
        rt_hi = np.asarray(state.rt_hi)
        assert (rt_keys[idx] <= probe).all()
        assert (probe < rt_hi[idx]).all()
        true_leaf = gids[np.searchsorted(lo, probe, side="right") - 1]
        np.testing.assert_array_equal(
            sub, (true_leaf // meta.subtree_cap).astype(np.int32))
        np.testing.assert_array_equal(
            local, (true_leaf % meta.subtree_cap).astype(np.int32))

    def test_scarce_slots_keep_demand_hot_partition(self):
        keys = np.arange(1, 4001, dtype=np.int64) * 10
        pool, meta = pool_mod.build_pool(keys, keys * 3, level_m=1,
                                         fill=0.7, n_shards=1)
        cfg = dex_mod.DexMeshConfig(n_route=2, n_memory=1,
                                    route_table_slots=64)
        mid = int(keys[2000])
        bounds = np.array([KEY_MIN, mid, KEY_MAX], np.int64)
        state = dex_mod.init_state(pool, meta, cfg, bounds)
        n_leaves = route_table.leaf_ranges(state, meta)[0].size
        slots = max(8, n_leaves // 4)
        demand = np.zeros_like(np.asarray(state.route_demand))
        demand[..., 1] = 1000           # partition 1 is hot
        state = state._replace(route_demand=jnp.asarray(demand))
        state = route_table.train_route_table(state, meta, slots=slots)
        live = np.asarray(state.rt_ver) >= 0
        assert 0 < int(live.sum()) <= slots
        # every kept segment starts inside the hot partition's range
        assert (np.asarray(state.rt_keys)[live] >= mid).all()

    def test_poison_bumps_every_live_stamp(self):
        keys, state, meta, cfg, _ = _setup(rt_slots=1024)
        state = route_table.train_route_table(state, meta)
        before = np.asarray(state.rt_ver)
        state = route_table.poison_route_table(state)
        after = np.asarray(state.rt_ver)
        live = before >= 0
        # the bump is large so mid-trace writes can't re-arm an entry
        np.testing.assert_array_equal(after[live], before[live] + (1 << 20))
        np.testing.assert_array_equal(after[~live], before[~live])
        assert route_table.route_table_active(state)


# ---------------------------------------------------------------------------
# poisoned-predictor bit-identity (sync + pipelined engines)
# ---------------------------------------------------------------------------


def _mixed_batches(keys, rng, n, b):
    out = []
    for _ in range(n):
        opc = rng.integers(0, 3, size=b).astype(np.int32)
        kk = rng.choice(keys, size=b).astype(np.int64)
        ins = opc == engine_mod.OP_INSERT
        fresh = kk + rng.integers(1, 4, size=b)
        ok_f = ~np.isin(fresh, keys)
        kk[ins & ok_f] = fresh[ins & ok_f]
        vals = np.zeros(b, np.int64)
        upd = opc == engine_mod.OP_UPDATE
        vals[upd] = kk[upd] ^ 0x5A5A
        vals[ins] = kk[ins] * 7
        out.append((jnp.asarray(opc), jnp.asarray(kk), jnp.asarray(vals)))
    return out


OPS = ("lookup", "update", "insert")


class TestPoisonedBitIdentity:
    def _arms(self, rt_slots):
        keys, s0, meta, cfg0, mesh = _setup(seed=41)
        _, s1, _, cfg1, _ = _setup(seed=41, rt_slots=rt_slots)
        return keys, meta, mesh, (s0, cfg0), (s1, cfg1)

    def test_sync_engine_poisoned_matches_descent(self):
        keys, meta, mesh, (s_de, cfg_de), (s_rt, cfg_rt) = self._arms(512)
        eng_de = jax.jit(engine_mod.make_dex_engine(
            meta, cfg_de, mesh, ops=OPS, max_count=1))
        eng_rt = jax.jit(engine_mod.make_dex_engine(
            meta, cfg_rt, mesh, ops=OPS, max_count=1))
        s_rt = route_table.poison_route_table(
            route_table.train_route_table(s_rt, meta))
        rng = np.random.default_rng(42)
        for b, (opc, kk, vv) in enumerate(_mixed_batches(keys, rng, 4, 128)):
            s_de, r_de = eng_de(s_de, opc, kk, vv)
            s_rt, r_rt = eng_rt(s_rt, opc, kk, vv)
            for field in ("found", "values", "status", "shed"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(r_de, field)),
                    np.asarray(getattr(r_rt, field)),
                    err_msg=f"batch {b} {field}")
        np.testing.assert_array_equal(
            np.asarray(s_de.pool.pool_keys), np.asarray(s_rt.pool.pool_keys))
        np.testing.assert_array_equal(
            np.asarray(s_de.pool.pool_values),
            np.asarray(s_rt.pool.pool_values))
        np.testing.assert_array_equal(
            np.asarray(s_de.versions), np.asarray(s_rt.versions))
        st_de = np.asarray(s_de.stats).sum(axis=0)
        st_rt = np.asarray(s_rt.stats).sum(axis=0)
        # descent arm books nothing; poisoned arm books only mispredicts
        assert int(st_de[dex_mod.STAT_RT_SKIPS]) == 0
        assert int(st_de[dex_mod.STAT_RT_MISPREDICTS]) == 0
        assert int(st_rt[dex_mod.STAT_RT_SKIPS]) == 0
        assert int(st_rt[dex_mod.STAT_RT_MISPREDICTS]) > 0
        # remote-read decisions are identical, fetch for fetch
        assert int(st_de[dex_mod.STAT_FETCHES]) == int(
            st_rt[dex_mod.STAT_FETCHES])

    def test_sync_engine_trained_table_matches_descent(self):
        """The ACCEPTED path is exact too: a freshly trained (unpoisoned)
        table changes remote traffic, never results."""
        keys, meta, mesh, (s_de, cfg_de), (s_rt, cfg_rt) = self._arms(512)
        eng_de = jax.jit(engine_mod.make_dex_engine(
            meta, cfg_de, mesh, ops=OPS, max_count=1))
        eng_rt = jax.jit(engine_mod.make_dex_engine(
            meta, cfg_rt, mesh, ops=OPS, max_count=1))
        s_rt = route_table.train_route_table(s_rt, meta)
        rng = np.random.default_rng(43)
        for b, (opc, kk, vv) in enumerate(_mixed_batches(keys, rng, 3, 128)):
            s_de, r_de = eng_de(s_de, opc, kk, vv)
            s_rt, r_rt = eng_rt(s_rt, opc, kk, vv)
            for field in ("found", "values", "status", "shed"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(r_de, field)),
                    np.asarray(getattr(r_rt, field)),
                    err_msg=f"batch {b} {field}")
        np.testing.assert_array_equal(
            np.asarray(s_de.pool.pool_values),
            np.asarray(s_rt.pool.pool_values))
        np.testing.assert_array_equal(
            np.asarray(s_de.versions), np.asarray(s_rt.versions))
        assert int(np.asarray(s_rt.stats).sum(axis=0)[
            dex_mod.STAT_RT_SKIPS]) > 0

    def test_pipelined_engine_poisoned_matches_descent(self):
        keys, meta, mesh, (s_de, cfg_de), (s_rt, cfg_rt) = self._arms(512)
        pipe_de = engine_mod.make_dex_engine(
            meta, cfg_de, mesh, ops=OPS, max_count=1, pipeline=True)
        pipe_rt = engine_mod.make_dex_engine(
            meta, cfg_rt, mesh, ops=OPS, max_count=1, pipeline=True)
        s_rt = route_table.poison_route_table(
            route_table.train_route_table(s_rt, meta))
        rng = np.random.default_rng(44)
        batches = _mixed_batches(keys, rng, 4, 128)
        s_de, res_de = pipe_de.run(s_de, batches)
        s_rt, res_rt = pipe_rt.run(s_rt, batches)
        assert len(res_de) == len(res_rt) == len(batches)
        for b, (rd, rr) in enumerate(zip(res_de, res_rt)):
            for field in ("found", "values", "status", "shed"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(rd, field)),
                    np.asarray(getattr(rr, field)),
                    err_msg=f"batch {b} {field}")
        np.testing.assert_array_equal(
            np.asarray(s_de.pool.pool_keys), np.asarray(s_rt.pool.pool_keys))
        np.testing.assert_array_equal(
            np.asarray(s_de.pool.pool_values),
            np.asarray(s_rt.pool.pool_values))
        np.testing.assert_array_equal(
            np.asarray(s_de.versions), np.asarray(s_rt.versions))
        st_rt = np.asarray(s_rt.stats).sum(axis=0)
        assert int(st_rt[dex_mod.STAT_RT_SKIPS]) == 0
        assert int(st_rt[dex_mod.STAT_RT_MISPREDICTS]) > 0


# ---------------------------------------------------------------------------
# Plane-A simulator mirror
# ---------------------------------------------------------------------------


class TestSimRouteTableMirror:
    def _sim(self, slots):
        keys = ycsb.make_dataset(6000, seed=0)
        tree = HostBTree(keys, keys * 7, fill=0.7, level_m=1,
                         n_mem_servers=1)
        cfg = SimConfig(name="dex", n_compute=1, n_mem_servers=1,
                        level_m=1, write_through=True, offloading=False,
                        route_table_slots=slots)
        sim = Simulator(tree, cfg, seed=5)
        wl = ycsb.generate("read-intensive", keys, 4000, seed=7)
        return sim, wl

    def test_trained_table_books_skips(self):
        sim, wl = self._sim(1 << 14)
        sim.run(wl.ops[:1000], wl.keys[:1000])
        sim.reset_counters()
        sim.train_route_table()
        sim.run(wl.ops[1000:], wl.keys[1000:])
        t = sim.totals()
        assert t.rt_skips > 0

    def test_poisoned_table_all_mispredicts_same_reads(self):
        sim_de, wl = self._sim(0)
        sim_po, _ = self._sim(1 << 14)
        sim_de.run(wl.ops[:1000], wl.keys[:1000])
        sim_po.run(wl.ops[:1000], wl.keys[:1000])
        sim_de.reset_counters()
        sim_po.reset_counters()
        sim_po.train_route_table()
        sim_po.poison_route_table()
        sim_de.run(wl.ops[1000:], wl.keys[1000:])
        sim_po.run(wl.ops[1000:], wl.keys[1000:])
        t_de, t_po = sim_de.totals(), sim_po.totals()
        assert t_po.rt_skips == 0
        assert t_po.rt_mispredicts > 0
        assert t_de.rt_skips == 0 and t_de.rt_mispredicts == 0
        # the poisoned fallback is the same cached descent, read for read
        assert t_po.rdma_read == t_de.rdma_read
        assert t_po.local_accesses == t_de.local_accesses
