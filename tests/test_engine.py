"""Tests for the unified mixed-op execution engine (core/engine.py).

The per-op builders (``make_dex_lookup`` / ``make_dex_update`` /
``make_dex_insert`` / ``make_dex_scan``) are thin wrappers over the engine,
so the load-bearing checks here are (a) an all-one-opcode batch through the
*full* four-opcode engine is bit-identical to the specialized wrappers,
(b) opcode edge cases (empty batch, all-inactive batch, unknown opset),
and (c) interleaved mixed batches match a phased sequential HostBTree
replay — reads see the pre-batch index, then updates apply, then inserts
(the engine's phase-offset batch priority).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import dex as dex_mod
from repro.core import engine as engine_mod
from repro.core import pool as pool_mod
from repro.core import scan as scan_mod
from repro.core import write as write_mod
from repro.compat import make_mesh_compat
from repro.core.nodes import KEY_MAX, KEY_MIN
from repro.core.sim import HostBTree

MC = 32


def _dataset(n, seed=0, space=None):
    rng = np.random.default_rng(seed)
    space = space or 16 * n
    return np.sort(rng.choice(space, size=n, replace=False).astype(np.int64) + 1)


def _setup(keys, *, policy="fetch", p_admit_leaf_pct=10, cache_sets=128):
    vals = keys * 5
    pool, meta = pool_mod.build_pool(keys, vals, level_m=1, fill=0.7,
                                     n_shards=1)
    mesh = make_mesh_compat((1, 1), ("data", "model"))
    cfg = dex_mod.DexMeshConfig(
        n_route=1, n_memory=1, cache_sets=cache_sets, cache_ways=4,
        p_admit_leaf_pct=p_admit_leaf_pct, route_capacity_factor=2.0,
        policy=policy,
    )
    bounds = np.array([KEY_MIN, KEY_MAX], np.int64)
    state = dex_mod.init_state(pool, meta, cfg, bounds)
    host = HostBTree(keys, vals, fill=0.7)
    return state, meta, cfg, mesh, host, bounds


def _full_engine(meta, cfg, mesh):
    return jax.jit(engine_mod.make_dex_engine(
        meta, cfg, mesh, ops=engine_mod.ALL_OPS, max_count=MC
    ))


def _plane(op, keys):
    return jnp.full(keys.shape, op, jnp.int32), jnp.asarray(keys)


class TestSingleOpcodeParity:
    """All-one-opcode batches through the full mixed engine must be
    bit-identical to the specialized single-opcode wrappers."""

    def test_lookup_batch_matches_wrapper(self):
        keys = _dataset(4000, seed=1)
        state, meta, cfg, mesh, _, _ = _setup(keys)
        eng = _full_engine(meta, cfg, mesh)
        lookup = jax.jit(dex_mod.make_dex_lookup(meta, cfg, mesh))
        q = np.concatenate([keys[:300], keys[:100] + 1]).astype(np.int64)
        opc, kk = _plane(engine_mod.OP_LOOKUP, q)
        s_e, r = eng(state, opc, kk, jnp.zeros_like(kk))
        s_w, f, v, sh = lookup(state, kk)
        np.testing.assert_array_equal(np.asarray(r.found), np.asarray(f))
        np.testing.assert_array_equal(np.asarray(r.values), np.asarray(v))
        np.testing.assert_array_equal(np.asarray(r.shed), np.asarray(sh))
        # one more batch from each evolved state must also agree (the
        # engine's cache/EMA updates match the wrapper's)
        s_e2, r2 = eng(s_e, opc, kk, jnp.zeros_like(kk))
        s_w2, f2, v2, _ = lookup(s_w, kk)
        np.testing.assert_array_equal(np.asarray(r2.found), np.asarray(f2))
        np.testing.assert_array_equal(np.asarray(r2.values), np.asarray(v2))

    def test_update_batch_matches_wrapper(self):
        keys = _dataset(4000, seed=2)
        state, meta, cfg, mesh, _, _ = _setup(keys)
        eng = _full_engine(meta, cfg, mesh)
        update = jax.jit(write_mod.make_dex_update(meta, cfg, mesh))
        uk = np.concatenate([keys[:200], keys[:40] + 1]).astype(np.int64)
        uv = (uk * 13 + 1).astype(np.int64)
        opc, kk = _plane(engine_mod.OP_UPDATE, uk)
        s_e, r = eng(state, opc, kk, jnp.asarray(uv))
        s_w, res = update(state, kk, jnp.asarray(uv))
        np.testing.assert_array_equal(np.asarray(r.status), np.asarray(res))
        np.testing.assert_array_equal(
            np.asarray(s_e.pool.pool_values), np.asarray(s_w.pool.pool_values)
        )
        np.testing.assert_array_equal(
            np.asarray(s_e.versions), np.asarray(s_w.versions)
        )

    def test_insert_batch_matches_wrapper(self):
        keys = _dataset(4000, seed=3)
        state, meta, cfg, mesh, _, _ = _setup(keys)
        eng = _full_engine(meta, cfg, mesh)
        insert = jax.jit(write_mod.make_dex_insert(meta, cfg, mesh))
        rng = np.random.default_rng(4)
        ik = (rng.choice(keys[:-1], size=256)
              + rng.integers(1, 3, size=256)).astype(np.int64)
        iv = ik * 3
        opc, kk = _plane(engine_mod.OP_INSERT, ik)
        s_e, r = eng(state, opc, kk, jnp.asarray(iv))
        s_w, res = insert(state, kk, jnp.asarray(iv))
        np.testing.assert_array_equal(np.asarray(r.status), np.asarray(res))
        np.testing.assert_array_equal(
            np.asarray(s_e.pool.pool_keys), np.asarray(s_w.pool.pool_keys)
        )
        np.testing.assert_array_equal(
            np.asarray(s_e.occupancy), np.asarray(s_w.occupancy)
        )

    def test_scan_batch_matches_wrapper(self):
        keys = _dataset(4000, seed=5)
        state, meta, cfg, mesh, _, _ = _setup(keys)
        eng = _full_engine(meta, cfg, mesh)
        scan = jax.jit(scan_mod.make_dex_scan(meta, cfg, mesh, max_count=MC))
        rng = np.random.default_rng(6)
        starts = rng.choice(keys, size=128).astype(np.int64)
        starts[::7] = starts[::7] + 1
        cnts = rng.integers(0, MC + 1, size=128).astype(np.int64)
        opc, kk = _plane(engine_mod.OP_SCAN, starts)
        s_e, r = eng(state, opc, kk, jnp.asarray(cnts))
        s_w, sk, sv, tk = scan(state, kk, jnp.asarray(cnts))
        np.testing.assert_array_equal(np.asarray(r.scan_keys), np.asarray(sk))
        np.testing.assert_array_equal(np.asarray(r.scan_values), np.asarray(sv))
        np.testing.assert_array_equal(np.asarray(r.taken), np.asarray(tk))


class TestOpcodeEdgeCases:
    def test_all_inactive_batch_is_a_noop(self):
        keys = _dataset(2000, seed=7)
        state, meta, cfg, mesh, _, _ = _setup(keys)
        eng = _full_engine(meta, cfg, mesh)
        kk = jnp.full((64,), KEY_MAX, jnp.int64)
        opc = jnp.zeros((64,), jnp.int32)
        s2, r = eng(state, opc, kk, jnp.zeros((64,), jnp.int64))
        assert not np.asarray(r.found).any()
        assert (np.asarray(r.status) == write_mod.STATUS_MISS).all()
        assert not np.asarray(r.shed).any()
        assert (np.asarray(r.taken) == 0).all()
        stats = np.asarray(s2.stats).sum(axis=0)
        assert stats[dex_mod.STAT_OPS] == 0
        assert stats[dex_mod.STAT_DROPS] == 0
        np.testing.assert_array_equal(
            np.asarray(s2.pool.pool_keys), np.asarray(state.pool.pool_keys)
        )

    def test_empty_batch(self):
        keys = _dataset(2000, seed=8)
        state, meta, cfg, mesh, _, _ = _setup(keys)
        eng = engine_mod.make_dex_engine(meta, cfg, mesh, max_count=MC)
        s2, r = eng(state, jnp.zeros((0,), jnp.int32),
                    jnp.zeros((0,), jnp.int64), jnp.zeros((0,), jnp.int64))
        assert r.found.shape == (0,)
        assert r.scan_keys.shape == (0, MC)
        assert s2 is state

    def test_unknown_op_rejected(self):
        keys = _dataset(1000, seed=9)
        _, meta, cfg, mesh, _, _ = _setup(keys)
        with pytest.raises(ValueError):
            engine_mod.make_dex_engine(meta, cfg, mesh, ops=("delete",))

    def test_inactive_lanes_interleave_with_live_ones(self):
        keys = _dataset(3000, seed=10)
        state, meta, cfg, mesh, _, _ = _setup(keys)
        eng = _full_engine(meta, cfg, mesh)
        q = keys[:128].astype(np.int64).copy()
        q[::3] = KEY_MAX
        opc = np.full(q.shape, engine_mod.OP_LOOKUP, np.int32)
        s2, r = eng(state, jnp.asarray(opc), jnp.asarray(q),
                    jnp.zeros_like(jnp.asarray(q)))
        f = np.asarray(r.found)
        live = q != KEY_MAX
        assert f[live].all() and not f[~live].any()
        assert int(np.asarray(s2.stats).sum(axis=0)[dex_mod.STAT_OPS]) == int(
            live.sum()
        )


class TestMixedBatchPhasedReplay:
    """A mixed batch equals the phased sequential replay: lookups/scans see
    the pre-batch index, then updates, then inserts."""

    def test_mixed_batch_matches_host(self):
        keys = _dataset(6000, seed=11)
        state, meta, cfg, mesh, host, bounds = _setup(keys)
        eng = _full_engine(meta, cfg, mesh)
        rng = np.random.default_rng(12)
        b = 512
        opc = rng.integers(0, 4, size=b).astype(np.int32)
        kk = rng.choice(keys, size=b).astype(np.int64)
        ins = opc == engine_mod.OP_INSERT
        fresh = kk + rng.integers(1, 3, size=b)
        kk[ins] = np.where(np.isin(fresh[ins], keys), kk[ins], fresh[ins])
        vals = np.zeros(b, np.int64)
        vals[opc == engine_mod.OP_UPDATE] = kk[opc == engine_mod.OP_UPDATE] ^ 0x77
        vals[ins] = kk[ins] * 3
        cnt_mask = opc == engine_mod.OP_SCAN
        vals[cnt_mask] = rng.integers(1, MC + 1, size=int(cnt_mask.sum()))
        # one update and one insert of the SAME existing key in one batch:
        # phased replay applies the update first, so the insert's value
        # (a duplicate-key value update) must win
        opc[0], kk[0], vals[0] = engine_mod.OP_UPDATE, keys[100], 111
        opc[1], kk[1], vals[1] = engine_mod.OP_INSERT, keys[100], 222
        ins = opc == engine_mod.OP_INSERT
        cnt_mask = opc == engine_mod.OP_SCAN

        s2, r = eng(state, jnp.asarray(opc), jnp.asarray(kk), jnp.asarray(vals))
        found = np.asarray(r.found)
        got_v = np.asarray(r.values)
        status = np.asarray(r.status)
        sk = np.asarray(r.scan_keys)
        sv = np.asarray(r.scan_values)
        tk = np.asarray(r.taken)
        shed = np.asarray(r.shed)
        assert not shed.any()

        # phase 1: reads against the pre-batch host
        for i in np.where(opc == engine_mod.OP_LOOKUP)[0]:
            hv = host.get(int(kk[i]))
            assert bool(found[i]) == (hv is not None), i
            if hv is not None:
                assert int(got_v[i]) == hv, i
        for i in np.where(cnt_mask)[0]:
            exp = [k for _, ks in host.scan(int(kk[i]), int(vals[i]))
                   for k in ks][: int(vals[i])]
            got = sk[i][sk[i] != KEY_MAX].tolist()
            assert got == exp, i
            assert tk[i] == len(exp)
            for j, key in enumerate(exp):
                assert int(sv[i, j]) == host.get(int(key)), (i, j)
        # phase 2: updates, then phase 3: inserts
        for i in np.where(opc == engine_mod.OP_UPDATE)[0]:
            applied = host.update(int(kk[i]), int(vals[i]))
            assert (status[i] == write_mod.STATUS_OK) == applied, i
        for i in np.where(ins)[0]:
            if status[i] == write_mod.STATUS_OK:
                host.insert(int(kk[i]), int(vals[i]))
            else:
                assert status[i] == write_mod.STATUS_SPLIT, (i, status[i])
        # post-batch: every key now matches the replayed host
        lookup = jax.jit(dex_mod.make_dex_lookup(meta, cfg, mesh))
        probe = kk[: (kk.size // 8) * 8]
        s3, f3, v3, _ = lookup(s2, jnp.asarray(probe))
        f3, v3 = np.asarray(f3), np.asarray(v3)
        for i in range(probe.size):
            hv = host.get(int(probe[i]))
            assert bool(f3[i]) == (hv is not None), i
            if hv is not None:
                assert int(v3[i]) == hv, i
        # the same-key update+insert pair resolved in phase order
        assert host.get(int(keys[100])) == 222


def _mixed_batches(keys, rng, n, b, *, with_scan=False, hot=None):
    """Interleaved mixed-op batches; ``hot`` keys are woven into every
    batch (writes on even batches, reads on odd) so adjacent batches
    conflict on the same leaves — the overlap window's hard case."""
    out = []
    hi = 4 if with_scan else 3
    for bi in range(n):
        opc = rng.integers(0, hi, size=b).astype(np.int32)
        kk = rng.choice(keys, size=b).astype(np.int64)
        ins = opc == engine_mod.OP_INSERT
        fresh = kk + rng.integers(1, 4, size=b)
        ok_f = ~np.isin(fresh, keys)
        kk[ins & ok_f] = fresh[ins & ok_f]
        vals = np.zeros(b, np.int64)
        upd = opc == engine_mod.OP_UPDATE
        vals[upd] = kk[upd] ^ 0x5A5A
        vals[ins] = kk[ins] * 7
        if with_scan:
            scn = opc == engine_mod.OP_SCAN
            vals[scn] = rng.integers(1, MC + 1, size=int(scn.sum()))
        if hot is not None:
            h = len(hot)
            if bi % 2 == 0:
                opc[:h] = engine_mod.OP_UPDATE
                kk[:h] = hot
                vals[:h] = (hot ^ (100 + bi)).astype(np.int64)
            else:
                opc[:h] = (engine_mod.OP_SCAN if with_scan
                           else engine_mod.OP_LOOKUP)
                kk[:h] = hot
                vals[:h] = 8 if with_scan else 0
        out.append((opc, kk, vals))
    return out


class TestPipelinedEngine:
    """``pipeline=True``: the two-stage software pipeline must be
    bit-identical to the synchronous engine on interleaved mixed-op
    batches — including same-key cross-batch update/lookup conflicts
    (resolved by the version-check + forced two-sided fallback) and the
    drain tail — while scans stall-shed conservatively."""

    OPS = ("lookup", "update", "insert")

    def test_pipelined_matches_synchronous_mixed(self):
        keys = _dataset(4000, seed=21)
        state, meta, cfg, mesh, _, _ = _setup(keys)
        sync = jax.jit(engine_mod.make_dex_engine(
            meta, cfg, mesh, ops=self.OPS, max_count=1))
        pipe = engine_mod.make_dex_engine(
            meta, cfg, mesh, ops=self.OPS, max_count=1, pipeline=True)
        rng = np.random.default_rng(22)
        batches = _mixed_batches(keys, rng, 5, 128, hot=keys[40:48])

        s_sync = state
        sync_res = []
        for opc, kk, vals in batches:
            s_sync, r = sync(s_sync, jnp.asarray(opc), jnp.asarray(kk),
                             jnp.asarray(vals))
            sync_res.append(r)
        s_pipe, pipe_res = pipe.run(
            state,
            [(jnp.asarray(o), jnp.asarray(k), jnp.asarray(v))
             for o, k, v in batches],
        )
        assert len(pipe_res) == len(batches)
        for b, (rs, rp) in enumerate(zip(sync_res, pipe_res)):
            for field in ("found", "values", "status", "shed"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(rs, field)),
                    np.asarray(getattr(rp, field)),
                    err_msg=f"batch {b} {field}",
                )
        # the drained index is the synchronous one, bit for bit
        np.testing.assert_array_equal(
            np.asarray(s_sync.pool.pool_keys),
            np.asarray(s_pipe.pool.pool_keys))
        np.testing.assert_array_equal(
            np.asarray(s_sync.pool.pool_values),
            np.asarray(s_pipe.pool.pool_values))
        np.testing.assert_array_equal(
            np.asarray(s_sync.versions), np.asarray(s_pipe.versions))
        np.testing.assert_array_equal(
            np.asarray(s_sync.occupancy), np.asarray(s_pipe.occupancy))
        # the hot-key conflicts stalled lanes in the overlap window; the
        # synchronous engine never stalls
        st_p = np.asarray(s_pipe.stats).sum(axis=0)
        st_s = np.asarray(s_sync.stats).sum(axis=0)
        assert int(st_p[dex_mod.STAT_PIPE_STALLS]) > 0
        assert int(st_s[dex_mod.STAT_PIPE_STALLS]) == 0

    def test_pipelined_scans_stall_shed_conservatively(self):
        keys = _dataset(4000, seed=23)
        state, meta, cfg, mesh, _, _ = _setup(keys)
        sync = _full_engine(meta, cfg, mesh)
        pipe = engine_mod.make_dex_engine(
            meta, cfg, mesh, ops=engine_mod.ALL_OPS, max_count=MC,
            pipeline=True)
        rng = np.random.default_rng(24)
        batches = _mixed_batches(keys, rng, 4, 128, with_scan=True,
                                 hot=keys[40:48])
        s_sync = state
        sync_res = []
        for opc, kk, vals in batches:
            s_sync, r = sync(s_sync, jnp.asarray(opc), jnp.asarray(kk),
                             jnp.asarray(vals))
            sync_res.append(r)
        s_pipe, pipe_res = pipe.run(
            state,
            [(jnp.asarray(o), jnp.asarray(k), jnp.asarray(v))
             for o, k, v in batches],
        )
        any_scan_shed = False
        for b, (rs, rp) in enumerate(zip(sync_res, pipe_res)):
            shed_s = np.asarray(rs.shed)
            shed_p = np.asarray(rp.shed)
            # pipelining only ADDS sheds (stall-shed scans), never loses one
            assert not (shed_s & ~shed_p).any(), b
            stalled = shed_p & ~shed_s
            any_scan_shed = any_scan_shed or stalled.any()
            assert (np.asarray(rp.taken)[stalled] == -1).all(), b
            ok = ~shed_p
            for field in ("found", "values", "status",
                          "scan_keys", "scan_values", "taken"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(rs, field))[ok],
                    np.asarray(getattr(rp, field))[ok],
                    err_msg=f"batch {b} {field}",
                )
        # writes still applied identically despite the stall-shed scans
        np.testing.assert_array_equal(
            np.asarray(s_sync.pool.pool_values),
            np.asarray(s_pipe.pool.pool_values))
        np.testing.assert_array_equal(
            np.asarray(s_sync.versions), np.asarray(s_pipe.versions))
        assert any_scan_shed  # the hot write->scan weave must conflict

    def test_pipeline_protocol(self):
        keys = _dataset(2000, seed=25)
        state, meta, cfg, mesh, _, _ = _setup(keys)
        pipe = engine_mod.make_dex_engine(
            meta, cfg, mesh, ops=self.OPS, max_count=1, pipeline=True)
        b = 64
        opc = jnp.full((b,), engine_mod.OP_LOOKUP, jnp.int32)
        kk = jnp.asarray(keys[:b])
        vv = jnp.zeros((b,), jnp.int64)
        with pytest.raises(RuntimeError):
            pipe.push(opc, kk, vv)
        pipe.start(state)
        assert pipe.drain() is None          # nothing in flight
        assert pipe.push(opc, kk, vv) is None  # prologue primes
        with pytest.raises(ValueError):
            pipe.push(opc[: b // 2], kk[: b // 2], vv[: b // 2])
        r1 = pipe.push(opc, kk, vv)          # steady state: lag-one result
        assert r1 is not None and np.asarray(r1.found).all()
        rd = pipe.drain()                    # drain flushes the tail
        assert rd is not None and np.asarray(rd.found).all()
        assert pipe.drain() is None
        assert pipe.push(opc, kk, vv) is None  # re-primes after drain
        assert pipe.plan["pipeline"] is True
        assert pipe.plan["overlap_phases"] == ("pipe/front", "pipe/back")


class TestInterleavedPropertyHypothesis:
    def test_interleaved_mixed_batches_match_host_replay(self):
        pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis "
                   "(optional [test] dep; CI's hyp-installed legs run them)",
        )
        from hypothesis import given, settings, strategies as st

        keys = _dataset(3000, seed=13)
        state0, meta0, cfg, mesh, _, bounds = _setup(keys)

        @settings(max_examples=8, deadline=None)
        @given(st.data())
        def scenario(data):
            host = HostBTree(keys, keys * 5, fill=0.7)
            state, meta = state0, meta0
            eng = _full_engine(meta, cfg, mesh)
            lookup = jax.jit(dex_mod.make_dex_lookup(meta, cfg, mesh))
            rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
            for _ in range(data.draw(st.integers(1, 3))):
                b = 256
                opc = rng.integers(0, 4, size=b).astype(np.int32)
                kk = rng.choice(keys, size=b).astype(np.int64)
                ins = opc == engine_mod.OP_INSERT
                fresh = kk + rng.integers(1, 4, size=b)
                ok_f = ~np.isin(fresh, keys)
                kk[ins & ok_f] = fresh[ins & ok_f]
                vals = np.zeros(b, np.int64)
                upd = opc == engine_mod.OP_UPDATE
                vals[upd] = kk[upd] ^ 0x5A5A
                vals[ins] = kk[ins] * 7
                scn = opc == engine_mod.OP_SCAN
                vals[scn] = rng.integers(1, MC + 1, size=int(scn.sum()))
                s2, r = eng(state, jnp.asarray(opc), jnp.asarray(kk),
                            jnp.asarray(vals))
                found = np.asarray(r.found)
                got_v = np.asarray(r.values)
                status = np.asarray(r.status)
                sk = np.asarray(r.scan_keys)
                tk = np.asarray(r.taken)
                for i in np.where(opc == engine_mod.OP_LOOKUP)[0]:
                    hv = host.get(int(kk[i]))
                    assert bool(found[i]) == (hv is not None)
                    if hv is not None:
                        assert int(got_v[i]) == hv
                for i in np.where(scn)[0]:
                    if tk[i] < 0:
                        continue
                    exp = [k for _, ks in host.scan(int(kk[i]), int(vals[i]))
                           for k in ks][: int(vals[i])]
                    assert sk[i][sk[i] != KEY_MAX].tolist() == exp
                for i in np.where(upd)[0]:
                    applied = host.update(int(kk[i]), int(vals[i]))
                    assert (status[i] == write_mod.STATUS_OK) == applied
                shed_i = np.zeros(b, bool)
                for i in np.where(ins)[0]:
                    if status[i] == write_mod.STATUS_OK:
                        host.insert(int(kk[i]), int(vals[i]))
                    elif status[i] == write_mod.STATUS_SPLIT:
                        shed_i[i] = True
                state = s2
                if shed_i.any():
                    state, meta = write_mod.drain_splits(
                        state, meta, cfg, host, kk[shed_i], vals[shed_i],
                        bounds,
                    )
                    eng = _full_engine(meta, cfg, mesh)
                    lookup = jax.jit(dex_mod.make_dex_lookup(meta, cfg, mesh))
                probe = rng.choice(kk, size=64).astype(np.int64)
                s3, f3, v3, _ = lookup(state, jnp.asarray(probe))
                state = s3
                f3, v3 = np.asarray(f3), np.asarray(v3)
                for i in range(64):
                    hv = host.get(int(probe[i]))
                    assert bool(f3[i]) == (hv is not None)
                    if hv is not None:
                        assert int(v3[i]) == hv

        scenario()


# ---------------------------------------------------------------------------
# Fleet-cache policy layer (core/fleet_cache.py): the refactor must be
# invisible in uniform mode — golden digests captured from the pre-refactor
# engine pin the results plane, the pool/version/occupancy planes and the
# pre-existing stat slots bit-for-bit
# ---------------------------------------------------------------------------


def _digest(*arrays):
    import hashlib

    m = hashlib.sha256()
    for a in arrays:
        a = np.asarray(a)
        m.update(str(a.dtype).encode())
        m.update(str(a.shape).encode())
        m.update(np.ascontiguousarray(a).tobytes())
    return m.hexdigest()[:16]


#: digests captured from the pre-refactor engine (commit f10d0ee) on the
#: exact traces below; ``stats`` covers the first 12 slots — the append-only
#: registry grew STAT_PEER_HITS/STAT_PEER_MISSES behind them
GOLDEN_SYNC = {
    "results": "13a52c855d8bb34c",
    "state": "c15d2578f7089877",
    "stats12": "8360e212492d6683",
}
GOLDEN_PIPE = {
    "results": "9a0530fdcd963a29",
    "state": "94e5a79e074503c5",
    "stats12": "368a753978770c50",
}


class TestFleetCachePolicyGoldens:
    def _sync_digests(self, cache_policy):
        keys = _dataset(4000, seed=31)
        state, meta, cfg, mesh, _, _ = _setup(keys)
        eng = jax.jit(engine_mod.make_dex_engine(
            meta, cfg, mesh, ops=engine_mod.ALL_OPS, max_count=MC,
            cache_policy=cache_policy,
        ))
        rng = np.random.default_rng(32)
        batches = _mixed_batches(keys, rng, 4, 256, with_scan=True,
                                 hot=keys[40:48])
        import hashlib

        res_h = hashlib.sha256()
        for opc, kk, vals in batches:
            state, r = eng(state, jnp.asarray(opc), jnp.asarray(kk),
                           jnp.asarray(vals))
            res_h.update(_digest(r.found, r.values, r.status, r.shed,
                                 r.scan_keys, r.scan_values, r.taken)
                         .encode())
        stats = np.asarray(state.stats)
        return {
            "results": res_h.hexdigest()[:16],
            "state": _digest(state.pool.pool_keys, state.pool.pool_values,
                             state.versions, state.occupancy),
            "stats12": _digest(stats[:, :12]),
        }, stats

    def test_uniform_mode_bit_identical_to_pre_refactor(self):
        """``cache_policy=None`` reproduces the pre-refactor goldens:
        results lane-for-lane, pool/version/occupancy planes, and every
        pre-existing stat slot; the two new peer slots stay zero.  Run
        twice with the same trace+seed: bit-identical across runs."""
        d1, stats = self._sync_digests(None)
        assert d1 == GOLDEN_SYNC, d1
        assert (stats[:, dex_mod.STAT_PEER_HITS] == 0).all()
        assert (stats[:, dex_mod.STAT_PEER_MISSES] == 0).all()
        d2, _ = self._sync_digests(None)
        assert d2 == d1, "same trace+seed must be bit-identical across runs"

    def test_explicit_uniform_policy_matches_none(self):
        """An all-ones/zero-salt ``uniform_policy`` pytree is the SAME
        program as ``cache_policy=None`` — the policy layer's uniform
        branch defers to ``routing.leaf_admit_dice`` verbatim."""
        from repro.core import fleet_cache

        keys = _dataset(4000, seed=31)
        _, _, cfg, _, _, _ = _setup(keys)
        pol = fleet_cache.uniform_policy(cfg)
        assert fleet_cache.is_uniform(pol)
        assert not fleet_cache.peeks_enabled(pol)
        d, _ = self._sync_digests(pol)
        assert d == GOLDEN_SYNC, d

    def test_pipelined_uniform_mode_matches_goldens(self):
        keys = _dataset(4000, seed=33)
        state, meta, cfg, mesh, _, _ = _setup(keys)
        pipe = engine_mod.make_dex_engine(
            meta, cfg, mesh, ops=("lookup", "update", "insert"),
            max_count=1, pipeline=True,
        )
        rng = np.random.default_rng(34)
        batches = _mixed_batches(keys, rng, 5, 128, hot=keys[40:48])
        s_pipe, pipe_res = pipe.run(
            state,
            [(jnp.asarray(o), jnp.asarray(k), jnp.asarray(v))
             for o, k, v in batches],
        )
        import hashlib

        res_h = hashlib.sha256()
        for r in pipe_res:
            res_h.update(_digest(r.found, r.values, r.status, r.shed)
                         .encode())
        got = {
            "results": res_h.hexdigest()[:16],
            "state": _digest(s_pipe.pool.pool_keys, s_pipe.pool.pool_values,
                             s_pipe.versions, s_pipe.occupancy),
            "stats12": _digest(np.asarray(s_pipe.stats)[:, :12]),
        }
        assert got == GOLDEN_PIPE, got

    def test_golden_trace_matches_host_replay(self):
        """The golden trace itself replays against HostBTree — the pinned
        digests encode *correct* behaviour, not just frozen behaviour."""
        keys = _dataset(4000, seed=31)
        state, meta, cfg, mesh, host, bounds = _setup(keys)
        eng = _full_engine(meta, cfg, mesh)
        rng = np.random.default_rng(32)
        batches = _mixed_batches(keys, rng, 4, 256, with_scan=True,
                                 hot=keys[40:48])
        for opc, kk, vals in batches:
            state, r = eng(state, jnp.asarray(opc), jnp.asarray(kk),
                           jnp.asarray(vals))
            found = np.asarray(r.found)
            got_v = np.asarray(r.values)
            status = np.asarray(r.status)
            done = ~np.asarray(r.shed)
            for i in np.where(done & (opc == engine_mod.OP_LOOKUP))[0]:
                hv = host.get(int(kk[i]))
                assert bool(found[i]) == (hv is not None), int(kk[i])
                if hv is not None:
                    assert int(got_v[i]) == hv, int(kk[i])
            for i in np.where(done & (opc == engine_mod.OP_UPDATE))[0]:
                applied = host.update(int(kk[i]), int(vals[i]))
                assert (status[i] == write_mod.STATUS_OK) == applied
            for i in np.where(done & (opc == engine_mod.OP_INSERT))[0]:
                if status[i] == write_mod.STATUS_OK:
                    host.insert(int(kk[i]), int(vals[i]))


class TestSharedAdmissionConstant:
    def test_one_definition_of_the_leaf_admission_dice(self):
        """Both planes derive the leaf-admission probability from ONE
        definition: cache.DEFAULT_P_ADMIT_LEAF is the source of truth,
        fleet_cache.P_ADMIT_LEAF_PCT is its percent form, and the mesh
        config default plus the dex re-export point at it."""
        from repro.core import fleet_cache
        from repro.core.cache import DEFAULT_P_ADMIT_LEAF

        pct = int(round(DEFAULT_P_ADMIT_LEAF * 100))
        assert fleet_cache.P_ADMIT_LEAF_PCT == pct
        assert dex_mod.P_ADMIT_LEAF_PCT == pct
        assert dex_mod.DexMeshConfig().p_admit_leaf_pct == pct
