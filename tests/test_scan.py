"""Range-scan subsystem tests: the leaf_scan Pallas kernel vs its oracle,
and ``make_dex_scan`` (Plane B) vs ``HostBTree.scan`` / the event simulator
(Plane A) on uniform and zipfian start keys, including scans that cross
partition/subtree boundaries and empty-result scans.

Multi-device routing parity (n_route=2 across a partition boundary at the
mesh level) lives in tests/mesh_check.py, exercised via the ``slow``
subprocess test in tests/test_dex_mesh.py.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import dex as dex_mod
from repro.core import pool as pool_mod
from repro.core import scan as scan_mod
from repro.core.nodes import FANOUT, KEY_MAX, KEY_MIN
from repro.compat import make_mesh_compat
from repro.core.sim import HostBTree, SimConfig, Simulator
from repro.data import ycsb
from repro.kernels import ops, ref


def _dataset(n, seed=0, space=None):
    rng = np.random.default_rng(seed)
    space = space or 8 * n
    return np.sort(rng.choice(space, size=n, replace=False).astype(np.int64) + 1)


# ---------------------------------------------------------------------------
# leaf_scan kernel vs oracle
# ---------------------------------------------------------------------------


class TestLeafScanKernel:
    def _window(self, b, hops, seed, per_leaf=44):
        """Realistic leaf windows: sorted keys, KEY_MAX tails per leaf row."""
        rng = np.random.default_rng(seed)
        w = hops * FANOUT
        k = np.full((b, w), KEY_MAX, np.int64)
        v = np.zeros((b, w), np.int64)
        for i in range(b):
            base = rng.integers(1, 1 << 40)
            keys = base + np.cumsum(rng.integers(1, 9, size=hops * per_leaf))
            for h in range(hops):
                seg = keys[h * per_leaf : (h + 1) * per_leaf]
                k[i, h * FANOUT : h * FANOUT + per_leaf] = seg
                v[i, h * FANOUT : h * FANOUT + per_leaf] = seg * 3
        return k, v

    @pytest.mark.parametrize("b", [1, 7, 64, 130])
    def test_matches_ref(self, b):
        rng = np.random.default_rng(b)
        k, v = self._window(b, hops=3, seed=b)
        valid = k != KEY_MAX
        start = np.array(
            [row[va][rng.integers(0, va.sum())] for row, va in zip(k, valid)],
            np.int64,
        )
        start[::2] += 1  # fall between keys
        cnt = rng.integers(0, 70, size=b).astype(np.int32)
        got = ops.leaf_scan(jnp.asarray(k), jnp.asarray(v), jnp.asarray(start),
                            jnp.asarray(cnt), max_count=48)
        want = ref.leaf_scan_ref(jnp.asarray(k), jnp.asarray(v),
                                 jnp.asarray(start), jnp.asarray(cnt),
                                 max_count=48)
        for g, w_ in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w_))

    def test_edge_cases(self):
        k = np.full((4, FANOUT), KEY_MAX, np.int64)
        k[0, :5] = [-9, -3, 0, 4, 7]          # negative keys
        k[1, :3] = [10, 20, 30]
        v = np.arange(4 * FANOUT, dtype=np.int64).reshape(4, FANOUT)
        start = np.array([-10, 25, 1, KEY_MAX - 1], np.int64)
        cnt = np.array([3, 9, 5, 5], np.int32)  # [2]: empty window, [3]: above all
        ok, ov, taken = ops.leaf_scan(
            jnp.asarray(k), jnp.asarray(v), jnp.asarray(start),
            jnp.asarray(cnt), max_count=8)
        rk, rv, rt = ref.leaf_scan_ref(
            jnp.asarray(k), jnp.asarray(v), jnp.asarray(start),
            jnp.asarray(cnt), max_count=8)
        np.testing.assert_array_equal(np.asarray(ok), np.asarray(rk))
        np.testing.assert_array_equal(np.asarray(ov), np.asarray(rv))
        np.testing.assert_array_equal(np.asarray(taken), np.asarray(rt))
        assert np.asarray(taken).tolist() == [3, 1, 0, 0]
        assert np.asarray(ok)[0, :3].tolist() == [-9, -3, 0]

    def test_count_clipped_to_max_count(self):
        k, v = self._window(2, hops=2, seed=9)
        start = k[:, 0].copy()
        cnt = np.array([500, 500], np.int32)
        ok, _, taken = ops.leaf_scan(
            jnp.asarray(k), jnp.asarray(v), jnp.asarray(start),
            jnp.asarray(cnt), max_count=16)
        assert (np.asarray(taken) == 16).all()
        assert (np.asarray(ok) != KEY_MAX).all()


# ---------------------------------------------------------------------------
# make_dex_scan vs HostBTree.scan vs Simulator (single-device mesh)
# ---------------------------------------------------------------------------


def _mesh_scan_setup(keys, *, level_m=1, max_count=48, use_kernel=True):
    vals = keys * 5
    pool, meta = pool_mod.build_pool(keys, vals, level_m=level_m, fill=0.7,
                                     n_shards=1)
    mesh = make_mesh_compat((1, 1), ("data", "model"))
    cfg = dex_mod.DexMeshConfig(n_route=1, n_memory=1, cache_sets=128,
                                cache_ways=4, route_capacity_factor=2.0)
    state = dex_mod.init_state(
        pool, meta, cfg, np.array([KEY_MIN, KEY_MAX], np.int64))
    scan = jax.jit(scan_mod.make_dex_scan(
        meta, cfg, mesh, max_count=max_count, use_kernel=use_kernel))
    return state, scan


def _expected(host, start, count):
    if count <= 0:
        return []
    return [k for _, ks in host.scan(int(start), int(count)) for k in ks][:count]


def _assert_scan_parity(keys, starts, counts, *, level_m=1, max_count=48,
                        use_kernel=True):
    host = HostBTree(keys, keys * 5, fill=0.7)
    state, scan = _mesh_scan_setup(keys, level_m=level_m, max_count=max_count,
                                   use_kernel=use_kernel)
    state, ok, ov, taken = scan(state, jnp.asarray(starts), jnp.asarray(counts))
    ok, ov, taken = np.asarray(ok), np.asarray(ov), np.asarray(taken)
    for i in range(starts.size):
        exp = _expected(host, starts[i], int(counts[i]))
        got = ok[i][ok[i] != KEY_MAX].tolist()
        assert got == exp, (i, int(starts[i]), int(counts[i]))
        assert int(taken[i]) == len(exp)
        np.testing.assert_array_equal(
            ov[i][: len(exp)], np.asarray(exp, np.int64) * 5)
        assert (ov[i][len(exp):] == 0).all()
    return state


class TestMeshScanParity:
    @pytest.mark.parametrize("level_m", [0, 1, 2])
    def test_uniform_starts(self, level_m):
        keys = _dataset(4000, seed=level_m)
        rng = np.random.default_rng(level_m + 10)
        starts = rng.choice(keys, size=220).astype(np.int64)
        starts[::4] += 1                       # between-key starts
        counts = rng.integers(0, 49, size=220).astype(np.int64)
        _assert_scan_parity(keys, starts, counts, level_m=level_m)

    def test_zipfian_starts(self):
        keys = _dataset(4000, seed=3)
        z = ycsb.ZipfianGenerator(keys.size, theta=0.99, seed=5)
        idx = ycsb.scramble(z.draw_ranks(220), keys.size)
        starts = keys[idx]
        counts = np.full(220, 37, np.int64)
        _assert_scan_parity(keys, starts, counts)

    def test_empty_and_boundary_scans(self):
        keys = _dataset(2000, seed=4)
        starts = np.array([
            keys[-1],            # last key: partial result
            keys[-1] + 1,        # past the end: empty
            KEY_MAX - 1,         # far past the end: empty
            1 if keys[0] > 1 else keys[0],  # at/below the min
            keys[0] - 1 if keys[0] > 1 else keys[0],
        ], np.int64)
        counts = np.array([10, 10, 10, 10, 10], np.int64)
        _assert_scan_parity(keys, starts, counts)

    def test_subtree_crossing_long_scans(self):
        # counts large enough that every scan spans multiple leaves and
        # regularly crosses level-M subtree (memory-column) boundaries
        keys = _dataset(3000, seed=6)
        rng = np.random.default_rng(7)
        starts = rng.choice(keys, size=120).astype(np.int64)
        counts = np.full(120, 128, np.int64)
        _assert_scan_parity(keys, starts, counts, max_count=128)

    def test_ref_compaction_path(self):
        keys = _dataset(1500, seed=8)
        rng = np.random.default_rng(9)
        starts = rng.choice(keys, size=64).astype(np.int64)
        counts = rng.integers(1, 33, size=64).astype(np.int64)
        _assert_scan_parity(keys, starts, counts, use_kernel=False)

    def test_load_shedding_is_explicit_never_truncated(self):
        """Lanes whose routing/fetch buckets overflow must report taken == -1
        (and count in STAT_DROPS), not silently return partial results."""
        keys = _dataset(3000, seed=20)
        host = HostBTree(keys, keys * 5, fill=0.7)
        vals = keys * 5
        pool, meta = pool_mod.build_pool(keys, vals, level_m=1, fill=0.7,
                                         n_shards=1)
        mesh = make_mesh_compat((1, 1), ("data", "model"))
        # capacity factor < 1 forces both route- and fetch-bucket overflow
        cfg = dex_mod.DexMeshConfig(n_route=1, n_memory=1, cache_sets=128,
                                    cache_ways=4, route_capacity_factor=0.5)
        state = dex_mod.init_state(
            pool, meta, cfg, np.array([KEY_MIN, KEY_MAX], np.int64))
        scan = jax.jit(scan_mod.make_dex_scan(meta, cfg, mesh, max_count=32))
        rng = np.random.default_rng(21)
        starts = rng.choice(keys, size=128).astype(np.int64)
        counts = np.full(128, 20, np.int64)
        st2, ok, ov, taken = scan(state, jnp.asarray(starts), jnp.asarray(counts))
        ok, taken = np.asarray(ok), np.asarray(taken)
        shed = taken < 0
        assert shed.any(), "capacity 0.5 must shed some lanes"
        assert (~shed).any(), "some lanes must survive"
        # shed lanes: empty rows, explicit failure marker
        assert (ok[shed] == KEY_MAX).all()
        assert (np.asarray(ov)[shed] == 0).all()
        assert int(np.asarray(st2.stats)[:, dex_mod.STAT_DROPS].sum()) >= shed.sum()
        # surviving lanes are exactly correct
        for i in np.where(~shed)[0]:
            exp = _expected(host, starts[i], int(counts[i]))
            assert ok[i][ok[i] != KEY_MAX].tolist() == exp, i
            assert int(taken[i]) == len(exp)

    def test_repeat_batch_hits_cache_and_matches_simulator(self):
        keys = _dataset(3000, seed=12)
        rng = np.random.default_rng(13)
        starts = rng.choice(keys, size=128).astype(np.int64)
        counts = rng.integers(1, 40, size=128).astype(np.int64)
        state = _assert_scan_parity(keys, starts, counts)
        # warmed cache: a second pass must record hits and the same results
        host = HostBTree(keys, keys * 5, fill=0.7)
        _, scan = _mesh_scan_setup(keys)
        st2, ok2, _, t2 = scan(state, jnp.asarray(starts), jnp.asarray(counts))
        stats = np.asarray(st2.stats).sum(axis=0)
        assert stats[dex_mod.STAT_HITS] > 0
        assert stats[dex_mod.STAT_DROPS] == 0
        assert stats[dex_mod.STAT_OPS] == 2 * 128
        ok2 = np.asarray(ok2)
        for i in range(starts.size):
            exp = _expected(host, starts[i], int(counts[i]))
            assert ok2[i][ok2[i] != KEY_MAX].tolist() == exp

        # Plane A runs the identical ops through Simulator._op_scan against
        # the same ground-truth tree: the per-op record sets must agree
        sim = Simulator(host, SimConfig(n_compute=2, n_mem_servers=2), seed=1)
        ops_arr = np.full(starts.size, ycsb.OP_SCAN, np.int32)
        sim.run(ops_arr, starts, scan_lens=counts.astype(np.int32))
        assert sim.totals().ops == starts.size
        assert sim.totals().rdma_read > 0
        for i in range(starts.size):
            assert [k for _, ks in sim.tree.scan(int(starts[i]), int(counts[i]))
                    for k in ks][: int(counts[i])] == _expected(
                        host, starts[i], int(counts[i]))


# ---------------------------------------------------------------------------
# YCSB-E generation
# ---------------------------------------------------------------------------


class TestYcsbScanLens:
    def test_uniform_scan_lens(self):
        ds = _dataset(2000, seed=1)
        wl = ycsb.generate("ycsb-e", ds, 5000, seed=2, scan_len=100,
                           scan_len_dist="uniform")
        assert wl.scan_lens is not None and wl.scan_lens.shape == (5000,)
        assert wl.scan_lens.min() >= 1 and wl.scan_lens.max() <= 100
        frac_scan = float(np.mean(wl.ops == ycsb.OP_SCAN))
        assert 0.9 < frac_scan < 1.0           # 95% scans
        assert np.mean(wl.ops == ycsb.OP_INSERT) > 0.01

    def test_fixed_default_unchanged(self):
        ds = _dataset(1000, seed=2)
        wl = ycsb.generate("scan-intensive", ds, 1000, seed=3)
        assert wl.scan_lens is None and wl.scan_len == 100

    def test_bad_dist_rejected(self):
        ds = _dataset(100, seed=3)
        with pytest.raises(ValueError):
            ycsb.generate("ycsb-e", ds, 10, scan_len_dist="pareto")

    def test_simulator_consumes_per_op_lens(self):
        ds = _dataset(1500, seed=4)
        host = HostBTree(ds, fill=0.7)
        sim = Simulator(host, SimConfig(n_compute=2, n_mem_servers=2), seed=5)
        wl = ycsb.generate("ycsb-e", ds, 400, seed=6, scan_len=40,
                           scan_len_dist="uniform")
        sim.run(wl.ops, wl.keys, scan_lens=wl.scan_lens)
        assert sim.totals().ops == 400
