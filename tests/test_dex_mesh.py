"""Mesh-plane DEX tests.

The multi-device exercise runs in a subprocess (tests/mesh_check.py) because
device count is locked at first JAX init and the main pytest session must
keep a single device.  Single-device pool/reference tests run inline.
"""

import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.core import pool as pool_mod


def _dataset(n, seed=0):
    rng = np.random.default_rng(seed)
    return np.sort(rng.choice(8 * n, size=n, replace=False).astype(np.int64) + 1)


class TestSubtreePool:
    @pytest.mark.parametrize("level_m", [0, 1, 2])
    def test_build_and_ref_lookup(self, level_m):
        keys = _dataset(5000, seed=level_m)
        pool, meta = pool_mod.build_pool(keys, keys * 3, level_m=level_m, n_shards=4)
        assert meta.n_subtrees_padded % 4 == 0
        q = np.concatenate([keys[::11], keys[::17] + 1])
        found, vals = pool_mod.pool_lookup_ref(pool, meta, q)
        found, vals = np.asarray(found), np.asarray(vals)
        expect = np.isin(q, keys)
        np.testing.assert_array_equal(found, expect)
        np.testing.assert_array_equal(vals[expect], q[expect] * 3)

    def test_single_subtree(self):
        keys = np.arange(1, 30, dtype=np.int64)
        pool, meta = pool_mod.build_pool(keys, level_m=1, n_shards=1)
        assert meta.n_subtrees == 1
        found, vals = pool_mod.pool_lookup_ref(pool, meta, keys)
        assert bool(np.all(np.asarray(found)))

    def test_subtree_walk_ref_matches(self):
        keys = _dataset(3000, seed=5)
        pool, meta = pool_mod.build_pool(keys, level_m=1, n_shards=1)
        st = pool_mod.top_walk(pool, meta, keys[:256])
        st = np.asarray(st)
        # all queries routed to subtree holding them; walk block 0 queries
        q0 = keys[:256][st == 0]
        if q0.size:
            f, v = pool_mod.subtree_walk_ref(
                pool.pool_keys[0],
                pool.pool_children[0],
                pool.pool_values[0],
                q0,
                levels=meta.levels_in_subtree,
            )
            assert bool(np.all(np.asarray(f)))
            np.testing.assert_array_equal(np.asarray(v), q0)


@pytest.mark.slow
def test_mesh_dex_subprocess():
    """Full multi-device routing/cache/offload check on 8 fake devices."""
    here = pathlib.Path(__file__).parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(here.parent / "src") + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, str(here / "mesh_check.py")],
        env=env,
        capture_output=True,
        text=True,
        timeout=900,
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    assert "MESH_CHECK_OK" in res.stdout
