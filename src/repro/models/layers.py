"""Model building blocks: norms, RoPE, GQA/MLA attention, SwiGLU/GELU MLPs,
capacity-based MoE, and Mamba selective-scan blocks.

All functions are pure (params in, activations out).  Shardings are applied
at the jit boundary (train/sharding.py); layer code is sharding-agnostic.
Matmuls accumulate in f32 (``preferred_element_type``); params/activations
default to bf16.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig

F32 = jnp.float32


#: Optional tensor-parallel constraint context, set by launchers before
#: tracing (``set_tp_context``).  When set, layer intermediates (q/k/v heads,
#: MLP hidden) are pinned to model-axis shardings — left to itself GSPMD
#: replicates them (measured: +13 GiB of temps per chip in a 405B MLP).
_TP_CTX = None

#: roofline-probe hook: disable MoE token chunking so the dispatch loop is
#: counted exactly once with the full token count (compile-only probes).
MOE_FULL_CHUNK = False


def set_tp_context(mesh, data_axes):
    """Enable model-axis constraints on layer intermediates.  Pass
    ``mesh=None`` to disable (single-chip tests)."""
    global _TP_CTX
    _TP_CTX = None if mesh is None else (mesh, tuple(data_axes))


def _tp(x, *tail):
    """Constrain x to P(data_axes, *tail) under the TP context."""
    if _TP_CTX is None:
        return x
    mesh, data = _TP_CTX
    import jax.sharding as _s
    sizes = dict(mesh.shape)
    spec = []
    for dim, ax in enumerate([data, *tail]):
        if ax is None:
            spec.append(None)
            continue
        n = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            n *= sizes[a]
        spec.append(ax if x.shape[dim] % n == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, _s.NamedSharding(mesh, _s.PartitionSpec(*spec))
    )


def _dot(x, w):
    return jnp.dot(x, w, preferred_element_type=F32).astype(x.dtype)


def _dus(buf, update, at, axis: int):
    """dynamic_update_slice along one axis with int32 indices (x64-safe)."""
    idx = [jnp.int32(0)] * buf.ndim
    idx[axis] = jnp.asarray(at, jnp.int32)
    return jax.lax.dynamic_update_slice(buf, update.astype(buf.dtype), tuple(idx))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm(x, scale, eps):
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def layernorm(x, scale, bias, eps):
    xf = x.astype(F32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * scale + bias


def apply_norm(cfg: ArchConfig, x, p):
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rmsnorm(x, p["scale"], cfg.norm_eps)


def init_norm(cfg: ArchConfig, dim: int):
    p = {"scale": jnp.ones((dim,), _dtype(cfg))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((dim,), _dtype(cfg))
    return p


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(dim: int, theta: float, positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    inv = 1.0 / (theta ** (np.arange(0, dim, 2) / dim))
    ang = positions[..., None].astype(F32) * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: [..., S, H, Dh]; cos/sin broadcastable against [..., S, H, Dh/2]
    (callers pass ``cos[:, None, :]`` == [S, 1, Dh/2])."""
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention cores
# ---------------------------------------------------------------------------


NEG_INF = -1e30


def _fit_chunk(s: int, target: int) -> int:
    c = min(target, s)
    while s % c:
        c -= 1
    return c


def sdpa(q, k, v, *, causal: bool, q_offset: int = 0, scale=None,
         block_q: int = 1024, block_k: int = 1024):
    """Flash-style attention in pure XLA: double scan (q chunks x kv chunks)
    with online softmax, so no [Sq, Sk] score tensor is ever materialized —
    XLA does not fuse naive softmax-attention, and at 32k context the naive
    scores are hundreds of GB/chip.  The Pallas kernel
    (kernels/flash_attention.py) is the TPU-native fused form; this is the
    portable implementation with the same memory behaviour.

    q: [B, Sq, H, Dq]; k: [B, Sk, HKV, Dq]; v: [B, Sk, HKV, Dv].
    """
    b, sq, h, dq = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    group = h // hkv
    scale = float(scale) if scale is not None else 1.0 / float(np.sqrt(dq))
    bq = _fit_chunk(sq, block_q)
    bk = _fit_chunk(sk, block_k)
    nq, nk = sq // bq, sk // bk

    # keep operands in their input dtype; f32 appears only in chunk-local
    # score/accumulator tensors (full-tensor f32 copies of q/k/v were ~10 GB
    # of temps per chip at 32k prefill)
    qg = jnp.moveaxis(q.reshape(b, nq, bq, hkv, group, dq), 1, 0)
    kc = jnp.moveaxis(k.reshape(b, nk, bk, hkv, dq), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nk, bk, hkv, dv), 1, 0)

    def q_block(_, qi_and_q):
        qi, qb = qi_and_q                              # [], [B, bq, n, g, dq]

        def kv_block(carry, ki_and_kv):
            m, l, acc = carry
            ki, kb, vb = ki_and_kv
            s = jnp.einsum(
                "bqngd,bknd->bnqgk", qb, kb,
                preferred_element_type=F32,
            ) * scale                                       # [B,n,bq,g,bk]
            if causal:
                qpos = qi * bq + jnp.arange(bq) + q_offset
                kpos = ki * bk + jnp.arange(bk)
                msk = qpos[:, None] >= kpos[None, :]
                s = jnp.where(msk[None, None, :, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bnqgk,bknd->bnqgd", p.astype(qb.dtype), vb,
                preferred_element_type=F32,
            )
            return (m_new, l, acc), ()

        m0 = jnp.full((b, hkv, bq, group), NEG_INF, F32)
        l0 = jnp.zeros((b, hkv, bq, group), F32)
        a0 = jnp.zeros((b, hkv, bq, group, dv), F32)
        body = jax.checkpoint(kv_block)
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0), (jnp.arange(nk), kc, vc)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]       # [B,n,bq,g,dv]
        out = jnp.moveaxis(out, 2, 1)                      # [B,bq,n,g,dv]
        return (), out.reshape(b, bq, hkv * group, dv)

    _, o = jax.lax.scan(q_block, (), (jnp.arange(nq), qg))
    o = jnp.moveaxis(o, 0, 1).reshape(b, sq, h, dv)
    return o.astype(q.dtype)


def init_gqa(cfg: ArchConfig, rng) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    s = 1.0 / np.sqrt(d)
    dt = _dtype(cfg)
    p = {
        "wq": (jax.random.normal(k1, (d, h * hd)) * s).astype(dt),
        "wk": (jax.random.normal(k2, (d, hkv * hd)) * s).astype(dt),
        "wv": (jax.random.normal(k3, (d, hkv * hd)) * s).astype(dt),
        "wo": (jax.random.normal(k4, (h * hd, d)) * s / np.sqrt(2 * cfg.n_layers)).astype(dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((hkv * hd,), dt)
        p["bv"] = jnp.zeros((hkv * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def gqa_attention(
    cfg: ArchConfig,
    p: dict,
    x: jax.Array,                     # [B, S, D]
    positions: jax.Array,             # [S] absolute positions
    *,
    causal: bool = True,
    kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,  # ([B,Smax,HKV,Dh] k, v)
    cache_len: Optional[jax.Array] = None,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
):
    """Returns (out [B,S,D], new_kv_cache or None)."""
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    # head-parallel projections; single-token decode skips the constraint —
    # resharding a [B, 1, ...] tensor against a differently-sharded cache
    # costs a full-cache reshard
    tp = (lambda t: _tp(t, None, "model")) if s > 1 else (lambda t: t)
    q = tp(_dot(x, p["wq"]))
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(b, s, h, hd)
    if cross_kv is None:
        k = tp(_dot(x, p["wk"]))
        v = tp(_dot(x, p["wv"]))
        if cfg.qkv_bias:
            k, v = k + p["bk"], v + p["bv"]
        k = k.reshape(b, s, hkv, hd)
        v = v.reshape(b, s, hkv, hd)
    else:
        k, v = cross_kv
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        if cross_kv is None:
            k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if cross_kv is None and cfg.attention != "none":
        cos, sin = rope_freqs(hd, cfg.rope_theta, positions)
        q = apply_rope(q, cos[:, None, :], sin[:, None, :])
        k = apply_rope(k, cos[:, None, :], sin[:, None, :])

    new_cache = None
    if kv_cache is not None:
        ck, cv = kv_cache
        ck = _dus(ck, k, cache_len, axis=1)
        cv = _dus(cv, v, cache_len, axis=1)
        new_cache = (ck, cv)
        smax = ck.shape[1]
        kpos = jnp.arange(smax)
        keep = kpos < (cache_len + s)
        qf = q.reshape(b, s, hkv, h // hkv, hd).astype(F32) / float(np.sqrt(hd))
        sc = jnp.einsum("bqngd,bknd->bnqgk", qf, ck.astype(F32))
        sc = jnp.where(keep[None, None, None, None, :], sc, -jnp.inf)
        qpos = positions
        mask = qpos[:, None] >= kpos[None, :]
        sc = jnp.where(mask[None, None, :, None, :], sc, -jnp.inf)
        pr = jax.nn.softmax(sc, axis=-1)
        o = jnp.einsum("bnqgk,bknd->bqngd", pr, cv.astype(F32))
        o = o.reshape(b, s, h, hd).astype(x.dtype)
    else:
        o = sdpa(q, k, v, causal=causal and cross_kv is None,
                 q_offset=int(0))
    out = _dot(o.reshape(b, s, h * hd), p["wo"])
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, MiniCPM3 / DeepSeek style)
# ---------------------------------------------------------------------------


def init_mla(cfg: ArchConfig, rng) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    qlr, kvlr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, ropeD, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(rng, 6)
    s = 1.0 / np.sqrt(d)
    dt = _dtype(cfg)
    return {
        "wq_a": (jax.random.normal(ks[0], (d, qlr)) * s).astype(dt),
        "q_norm": jnp.ones((qlr,), dt),
        "wq_b": (jax.random.normal(ks[1], (qlr, h * (nope + ropeD))) / np.sqrt(qlr)).astype(dt),
        "wkv_a": (jax.random.normal(ks[2], (d, kvlr + ropeD)) * s).astype(dt),
        "kv_norm": jnp.ones((kvlr,), dt),
        "wkv_b": (jax.random.normal(ks[3], (kvlr, h * (nope + vd))) / np.sqrt(kvlr)).astype(dt),
        "wo": (jax.random.normal(ks[4], (h * vd, d)) * s / np.sqrt(2 * cfg.n_layers)).astype(dt),
    }


def mla_attention(
    cfg: ArchConfig,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    causal: bool = True,
    kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,  # (c_kv [B,S,kvlr], k_rope [B,S,ropeD])
    cache_len: Optional[jax.Array] = None,
):
    """MLA with the *compressed* KV cache (the technique's whole point: cache
    [kv_lora_rank + rope_dim] per token instead of 2*H*Dh)."""
    b, s, d = x.shape
    h = cfg.n_heads
    nope, ropeD, vd, kvlr = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank

    q = _dot(rmsnorm(_dot(x, p["wq_a"]), p["q_norm"], cfg.norm_eps), p["wq_b"])
    q = q.reshape(b, s, h, nope + ropeD)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    kv_a = _dot(x, p["wkv_a"])                      # [B,S,kvlr+ropeD]
    c_kv, k_rope = kv_a[..., :kvlr], kv_a[..., kvlr:]
    c_kv = rmsnorm(c_kv, p["kv_norm"], cfg.norm_eps)

    cos, sin = rope_freqs(ropeD, cfg.rope_theta, positions)
    q_rope = apply_rope(q_rope, cos[:, None, :], sin[:, None, :])
    k_rope = apply_rope(k_rope[:, :, None, :], cos[:, None, :], sin[:, None, :])[:, :, 0]

    new_cache = None
    if kv_cache is not None:
        cc, cr = kv_cache
        cc = _dus(cc, c_kv, cache_len, axis=1)
        cr = _dus(cr, k_rope, cache_len, axis=1)
        new_cache = (cc, cr)
        c_all, r_all = cc, cr
        smax = cc.shape[1]
    else:
        c_all, r_all = c_kv, k_rope
        smax = s

    kv = _dot(c_all, p["wkv_b"]).reshape(b, smax, h, nope + vd)
    k_nope, v = kv[..., :nope], kv[..., nope:]

    scale = 1.0 / float(np.sqrt(nope + ropeD))
    if kv_cache is None:
        # prefill/train: fold (nope | rope) into one head dim and use the
        # flash path — naive scores at 32k are hundreds of GB
        qh = jnp.concatenate([q_nope, q_rope], axis=-1)
        kh = jnp.concatenate(
            [k_nope, jnp.broadcast_to(r_all[:, :, None, :], (b, smax, h, ropeD))],
            axis=-1,
        )
        o = sdpa(qh, kh, v, causal=causal, scale=scale)
        o = o.astype(F32)
    else:
        # decode: linear-size scores over the compressed cache
        sc = (
            jnp.einsum("bqhd,bkhd->bhqk", q_nope.astype(F32), k_nope.astype(F32))
            + jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(F32), r_all.astype(F32))
        ) * scale
        kpos = jnp.arange(smax)
        mask = positions[:, None] >= kpos[None, :]
        mask = mask & (kpos[None, :] < cache_len + s)
        sc = jnp.where(mask[None, None], sc, -jnp.inf)
        pr = jax.nn.softmax(sc, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", pr, v.astype(F32))
    out = _dot(o.reshape(b, s, h * vd).astype(x.dtype), p["wo"])
    return out, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(cfg: ArchConfig, rng, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    ff = d_ff if d_ff is not None else cfg.d_ff
    k1, k2 = jax.random.split(rng)
    s = 1.0 / np.sqrt(d)
    dt = _dtype(cfg)
    width = 2 * ff if cfg.act == "swiglu" else ff
    return {
        "wi": (jax.random.normal(k1, (d, width)) * s).astype(dt),
        "wo": (jax.random.normal(k2, (ff, d)) / np.sqrt(ff) / np.sqrt(2 * cfg.n_layers)).astype(dt),
    }


def mlp(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    h = _dot(x, p["wi"])
    if x.shape[1] > 1:
        h = _tp(h, None, "model")                # col-parallel hidden
    if cfg.act == "swiglu":
        gate, up = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(gate.astype(F32)).astype(x.dtype) * up
    else:
        h = jax.nn.gelu(h.astype(F32)).astype(x.dtype)
    return _dot(h, p["wo"])                      # row-parallel (psum by GSPMD)


# ---------------------------------------------------------------------------
# MoE (capacity-based top-k dispatch; EP shards the expert axis)
# ---------------------------------------------------------------------------


def init_moe(cfg: ArchConfig, rng) -> dict:
    d, e, ffe = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    k1, k2, k3 = jax.random.split(rng, 3)
    dt = _dtype(cfg)
    s = 1.0 / np.sqrt(d)
    width = 2 * ffe if cfg.act == "swiglu" else ffe
    return {
        "router": (jax.random.normal(k1, (d, e)) * s).astype(jnp.float32),
        "wi": (jax.random.normal(k2, (e, d, width)) * s).astype(dt),
        "wo": (jax.random.normal(k3, (e, ffe, d)) / np.sqrt(ffe) / np.sqrt(2 * cfg.n_layers)).astype(dt),
    }


def moe_block(cfg: ArchConfig, p: dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Returns (out, aux_loss).  *Sort-based* capacity dispatch: (token,
    choice) pairs are bucketed per expert via argsort + rank-in-segment, so
    the working set is O(E * cap * D) gathers/scatters — never a
    [T, E, cap] one-hot (which is quadratic in tokens and measured in TBs at
    32k x 32-way prefill).  Expert-sharded weights turn the gather/scatter
    into all_to_alls under GSPMD (EP)."""
    b, s, d = x.shape
    t_full = b * s
    e, k = cfg.n_experts, cfg.top_k

    # token-chunked dispatch: bounds the sort/gather working set (and the
    # all_to_all payloads under EP) regardless of sequence length
    chunk = t_full if MOE_FULL_CHUNK else min(t_full, 8192)
    while t_full % chunk:
        chunk -= 1
    if chunk < t_full:
        xc = x.reshape(t_full // chunk, chunk, d)

        def one(carry, xi):
            o, a = moe_block(cfg, p, xi[None])
            return carry + a, o[0]

        body = jax.checkpoint(one)
        aux_sum, outs = jax.lax.scan(body, jnp.zeros((), F32), xc)
        return outs.reshape(b, s, d), aux_sum / (t_full // chunk)

    t = t_full
    xt = x.reshape(t, d)
    logits = jnp.dot(xt.astype(F32), p["router"])          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)               # [T, k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    cap = max(1, int(t * k / e * cfg.moe_capacity_factor))
    n = t * k
    dest = idx.reshape(n)                                  # expert per entry
    tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    gate = gate_vals.reshape(n).astype(F32)

    # rank of each entry within its expert's queue (stable by token order)
    order = jnp.argsort(dest, stable=True)
    sd = dest[order]
    new_seg = jnp.concatenate([jnp.ones((1,), bool), sd[1:] != sd[:-1]])
    seg_start = jax.lax.cummax(jnp.where(new_seg, jnp.arange(n), 0), axis=0)
    rank = jnp.arange(n) - seg_start                       # position in expert
    keep = rank < cap                                      # capacity drop
    # slot of every kept entry in the [E, cap] buffers
    slot_e = jnp.where(keep, sd, e)                        # e = OOB row
    slot_c = jnp.where(keep, rank, 0)

    tok_buf = jnp.full((e, cap), t, jnp.int32)             # t = OOB token
    tok_buf = tok_buf.at[slot_e, slot_c].set(tok[order], mode="drop")
    gate_buf = jnp.zeros((e, cap), F32)
    gate_buf = gate_buf.at[slot_e, slot_c].set(gate[order], mode="drop")

    # gather token activations per expert slot ([E, cap, D]; OOB -> 0)
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    expert_in = xt_pad[tok_buf]                            # [E, cap, D]

    h = jnp.einsum("ecd,edf->ecf", expert_in, p["wi"], preferred_element_type=F32)
    if cfg.act == "swiglu":
        gatep, up = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(gatep) * up
    else:
        h = jax.nn.gelu(h)
    h = h.astype(x.dtype)
    out_e = jnp.einsum("ecf,efd->ecd", h, p["wo"], preferred_element_type=F32)

    # combine: scatter-add gated expert outputs back to tokens
    weighted = out_e * gate_buf[..., None]                 # [E, cap, D]
    out = jnp.zeros((t + 1, d), F32)
    out = out.at[tok_buf.reshape(-1)].add(
        weighted.reshape(-1, d), mode="drop"
    )[:t]

    # load-balancing aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=F32), axis=0)
    aux = jnp.sum(me * ce) * e
    return out.astype(x.dtype).reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Mamba (selective scan, diagonal A; v2 = larger state + per-head A, see
# DESIGN.md for the SSD simplification note)
# ---------------------------------------------------------------------------


def init_mamba(cfg: ArchConfig, rng) -> dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    dt_rank = max(1, d // 16)
    ks = jax.random.split(rng, 6)
    dt = _dtype(cfg)
    s = 1.0 / np.sqrt(d)
    a_init = -(1.0 + jnp.arange(n, dtype=F32))[None, :] * jnp.ones((di, 1), F32) / n
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * di)) * s).astype(dt),
        "conv": (jax.random.normal(ks[1], (cfg.ssm_conv, di)) * 0.1).astype(dt),
        "conv_bias": jnp.zeros((di,), dt),
        "x_proj": (jax.random.normal(ks[2], (di, dt_rank + 2 * n)) / np.sqrt(di)).astype(dt),
        "dt_proj": (jax.random.normal(ks[3], (dt_rank, di)) / np.sqrt(dt_rank)).astype(dt),
        "dt_bias": jnp.full((di,), -4.0, F32),  # softplus ~= 0.018
        "A_log": jnp.log(-a_init),              # store log(-A) for stability
        "D_skip": jnp.ones((di,), F32),
        "out_proj": (jax.random.normal(ks[4], (di, d)) / np.sqrt(di) / np.sqrt(2 * cfg.n_layers)).astype(dt),
    }


def _ssm_chunked_scan(delta, A, bmat, cmat, xs, chunk: int):
    """Chunked selective scan producing y directly.

    ``h_t = exp(delta_t A) h_{t-1} + delta_t B_t x_t``; ``y_t = <h_t, C_t>``.
    Sequential over chunks (lax.scan carry = state), parallel cumsum/cumprod
    within a chunk.  Everything [B, L, Di, N]-sized lives only at chunk
    granularity — the full-sequence state tensor would be hundreds of GB at
    production shapes.

    delta: [B, L, Di] f32; A: [Di, N]; bmat/cmat: [B, L, N]; xs: [B, L, Di].
    Returns (y [B, L, Di] f32, final_state [B, Di, N]).
    """
    b, l, di = delta.shape
    n = A.shape[1]
    nc = l // chunk

    def split(t):
        return jnp.moveaxis(t.reshape(b, nc, chunk, *t.shape[2:]), 1, 0)

    d_c, b_c, c_c, x_c = split(delta), split(bmat.astype(F32)), split(
        cmat.astype(F32)
    ), split(xs.astype(F32))

    def one_chunk(h0, inp):
        d, bm, cm, xx = inp                          # [B, chunk, ...]
        dA = jnp.exp(d[..., None] * A[None, None])   # [B, chunk, Di, N]
        dBx = d[..., None] * bm[:, :, None, :] * xx[..., None]
        cum = jnp.cumprod(dA, axis=1)
        safe = jnp.maximum(cum, 1e-30)
        hs = cum * (h0[:, None] + jnp.cumsum(dBx / safe, axis=1))
        y = jnp.einsum("bldn,bln->bld", hs, cm)
        return hs[:, -1], y

    h0 = jnp.zeros((b, di, n), F32)
    body = jax.checkpoint(one_chunk)
    h_last, y = jax.lax.scan(body, h0, (d_c, b_c, c_c, x_c))
    y = jnp.moveaxis(y, 0, 1).reshape(b, l, di)
    return y, h_last


def mamba_block(
    cfg: ArchConfig,
    p: dict,
    x: jax.Array,                   # [B, S, D]
    *,
    ssm_state: Optional[jax.Array] = None,   # [B, Di, N] decode carry
    conv_state: Optional[jax.Array] = None,  # [B, conv-1, Di]
    chunk: int = 64,
):
    """Returns (out, new_ssm_state, new_conv_state)."""
    b, s, d = x.shape
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    dt_rank = max(1, d // 16)

    xz = _dot(x, p["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)               # [B, S, Di]

    # depthwise causal conv over time
    w = p["conv"]                                   # [K, Di]
    kk = w.shape[0]
    if conv_state is not None:
        ctx = jnp.concatenate([conv_state.astype(xs.dtype), xs], axis=1)
    else:
        ctx = jnp.pad(xs, ((0, 0), (kk - 1, 0), (0, 0)))
    new_conv_state = ctx[:, -(kk - 1):, :].astype(F32) if kk > 1 else None
    conv_out = sum(
        ctx[:, i : i + s, :].astype(F32) * w[i].astype(F32) for i in range(kk)
    ) + p["conv_bias"].astype(F32)
    xs = jax.nn.silu(conv_out).astype(x.dtype)

    x_dbl = _dot(xs, p["x_proj"])
    dt, bmat, cmat = jnp.split(
        x_dbl, [dt_rank, dt_rank + n], axis=-1
    )
    delta = jax.nn.softplus(
        jnp.dot(dt.astype(F32), p["dt_proj"].astype(F32)) + p["dt_bias"]
    )                                                # [B, S, Di] f32
    A = -jnp.exp(p["A_log"])                         # [Di, N]

    if s == 1 and ssm_state is not None:
        dA = jnp.exp(delta[:, 0, :, None] * A[None])          # [B, Di, N]
        dBx = (
            delta[:, 0, :, None]
            * bmat[:, 0, None, :].astype(F32)
            * xs[:, 0, :, None].astype(F32)
        )
        h = dA * ssm_state + dBx
        y = jnp.einsum("bdn,bn->bd", h, cmat[:, 0].astype(F32))[:, None]
        new_state = h
    else:
        c = min(chunk, s)
        while s % c:
            c -= 1
        y, new_state = _ssm_chunked_scan(delta, A, bmat, cmat, xs, c)

    y = y + p["D_skip"] * xs.astype(F32)
    y = y * jax.nn.silu(z.astype(F32))
    out = _dot(y.astype(x.dtype), p["out_proj"])
    return out, new_state, new_conv_state
