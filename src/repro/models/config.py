"""Unified architecture configuration covering all assigned families:
dense / MoE / SSM / hybrid / VLM / enc-dec audio backbones."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # attention
    attention: str = "gqa"           # gqa | mla | none
    head_dim: Optional[int] = None   # default d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False            # chameleon
    rope_theta: float = 10_000.0

    # MLA (MiniCPM3 / DeepSeek-style)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    moe_capacity_factor: float = 1.25

    # SSM (mamba1 / mamba2-style)
    ssm: bool = False
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    mamba_version: int = 1

    # hybrid (zamba2): one *shared* attention block applied every k layers
    hybrid_attn_every: int = 0

    # encoder-decoder (whisper): encoder layer count; frontend is a stub
    encdec: bool = False
    enc_layers: int = 0
    max_source_positions: int = 1500

    # misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "swiglu"              # swiglu | gelu
    norm: str = "rmsnorm"            # rmsnorm | layernorm

    # systems knobs
    dtype: str = "bfloat16"
    remat: bool = True
    use_flash_kernel: str = "auto"   # auto | always | never
    sub_quadratic: bool = False      # True for ssm/hybrid (long_500k eligible)

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))

    # -- derived -------------------------------------------------------------

    @property
    def kv_group(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def param_count(self) -> int:
        """Approximate parameter count N (for 6·N·D roofline terms)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        hd = self.head_dim
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d
        per_layer = 0
        if self.attention != "none":
            if self.attention == "mla":
                qd = self.q_lora_rank or d
                per_layer += d * self.q_lora_rank + self.q_lora_rank * self.n_heads * (
                    self.qk_nope_dim + self.qk_rope_dim
                ) if self.q_lora_rank else d * self.n_heads * (
                    self.qk_nope_dim + self.qk_rope_dim
                )
                per_layer += d * (self.kv_lora_rank + self.qk_rope_dim)
                per_layer += self.kv_lora_rank * self.n_heads * (
                    self.qk_nope_dim + self.v_head_dim
                )
                per_layer += self.n_heads * self.v_head_dim * d
            else:
                per_layer += d * self.n_heads * hd          # Q
                per_layer += 2 * d * self.n_kv_heads * hd   # K, V
                per_layer += self.n_heads * hd * d          # O
        if self.ssm:
            di = self.ssm_expand * d
            per_layer += d * 2 * di + di * d               # in/out proj
            per_layer += di * (2 * self.ssm_state + 2)     # B, C, dt, A
            per_layer += self.ssm_conv * di
        if self.moe:
            per_layer += d * self.n_experts                # router
            per_layer += self.n_experts * 3 * d * self.expert_d_ff
        elif ff > 0:
            mult = 3 if self.act == "swiglu" else 2
            per_layer += mult * d * ff
        n += self.n_layers * per_layer
        if self.encdec:
            enc_per = 4 * d * self.n_heads * hd // max(self.n_heads, 1) * self.n_heads
            enc_per = 4 * d * d + (2 if self.act == "gelu" else 3) * d * ff
            n += self.enc_layers * enc_per
            n += self.n_layers * 4 * d * d                 # cross attention
        return n

    def active_param_count(self) -> int:
        """MoE: only top-k experts are active per token."""
        if not self.moe:
            return self.param_count()
        total = self.param_count()
        inactive = self.n_layers * (self.n_experts - self.top_k) * 3 * self.d_model * self.expert_d_ff
        return total - inactive

    # -- reduced configs for CPU smoke tests ----------------------------------

    def reduced(self, **overrides) -> "ArchConfig":
        """Small same-family config: few layers, narrow width, tiny vocab."""
        small = dict(
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4 // max(self.n_heads, 1))),
            d_ff=0 if self.d_ff == 0 else 128,
            vocab=256,
            head_dim=16,
            remat=False,
            use_flash_kernel="never",
        )
        if self.attention == "mla":
            small.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=8,
                         qk_rope_dim=8, v_head_dim=16)
        if self.moe:
            # ample capacity: token dropping depends on batch composition, so
            # reduced-config decode-vs-prefill equivalence needs no-drop routing
            small.update(n_experts=4, top_k=2, expert_d_ff=32,
                         moe_capacity_factor=8.0)
        if self.ssm:
            small.update(ssm_state=8, ssm_expand=2, ssm_conv=4)
        if self.hybrid_attn_every:
            small.update(n_layers=4, hybrid_attn_every=2)
        if self.encdec:
            small.update(enc_layers=2, max_source_positions=64)
        small.update(overrides)
        return dataclasses.replace(self, **small)


# -- input shape cells (assigned to every architecture) -----------------------


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeCell:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def cell_applicable(cfg: ArchConfig, shape: ShapeCell) -> Tuple[bool, str]:
    """Whether an (arch x shape) dry-run cell runs, with the skip reason.

    Per the brief: ``long_500k`` needs sub-quadratic attention — skipped for
    pure full-attention archs (noted in DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "SKIP(full-attn): long_500k requires sub-quadratic attention"
    return True, ""
