"""Unified model: one init/forward/decode covering every assigned family.

Layer stacking uses ``jax.vmap`` over per-layer RNGs at init (stacked [L, ...]
leaves) and ``jax.lax.scan`` + ``jax.checkpoint`` at apply time, keeping the
HLO size O(1) in depth — essential for compiling 126-layer configs against
512 partitions quickly.

Families:
  dense / vlm      : pre-norm GQA (+ optional QKV bias / qk-norm) + SwiGLU
  mla              : MiniCPM3-style multi-head latent attention, compressed
                     KV cache (kv_lora_rank + rope_dim per token)
  moe              : GQA + capacity-based top-k expert MLPs
  ssm              : Mamba selective-scan blocks (attention-free)
  hybrid           : Mamba stack with one *shared* attention block applied
                     every k layers (Zamba2's weight-shared global block)
  audio (enc-dec)  : Whisper backbone; conv frontend is a stub — the batch
                     supplies precomputed frame embeddings (per the brief)
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map_compat
from repro.models import layers as L
from repro.models.config import ArchConfig

F32 = jnp.float32

#: roofline-probe hook: when set (int), the layer scans unroll by this
#: factor so XLA's cost_analysis counts every layer (loop bodies are counted
#: once otherwise).  Never set in production — compile-time only probes.
SCAN_UNROLL = None


def _unroll():
    return SCAN_UNROLL if SCAN_UNROLL else 1


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(cfg: ArchConfig, rng) -> Dict[str, Any]:
    """One decoder block (unstacked); vmapped for the full stack."""
    ks = jax.random.split(rng, 8)
    p: Dict[str, Any] = {}
    if cfg.ssm:
        p["ln1"] = L.init_norm(cfg, cfg.d_model)
        p["ssm"] = L.init_mamba(cfg, ks[0])
        return p
    p["ln1"] = L.init_norm(cfg, cfg.d_model)
    if cfg.attention == "mla":
        p["attn"] = L.init_mla(cfg, ks[0])
    else:
        p["attn"] = L.init_gqa(cfg, ks[0])
    p["ln2"] = L.init_norm(cfg, cfg.d_model)
    if cfg.moe:
        p["moe"] = L.init_moe(cfg, ks[1])
    else:
        p["mlp"] = L.init_mlp(cfg, ks[1])
    if cfg.encdec:
        p["lnx"] = L.init_norm(cfg, cfg.d_model)
        p["xattn"] = L.init_gqa(cfg, ks[2])
    return p


def init_params(cfg: ArchConfig, rng) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 8)
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model)) * 0.02).astype(dt),
        "final_norm": L.init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(ks[1], (cfg.d_model, cfg.vocab)) * 0.02
        ).astype(dt)

    if cfg.hybrid_attn_every:
        # mamba stack + one weight-shared attention block (zamba2)
        ssm_cfg = cfg
        block_keys = jax.random.split(ks[2], cfg.n_layers)
        params["blocks"] = jax.vmap(lambda k: _init_block(ssm_cfg, k))(block_keys)
        shared_cfg = cfg
        params["shared_attn"] = {
            "ln1": L.init_norm(cfg, cfg.d_model),
            "attn": L.init_gqa(shared_cfg, ks[3]),
            "ln2": L.init_norm(cfg, cfg.d_model),
            "mlp": L.init_mlp(cfg, ks[4]),
        }
    else:
        block_keys = jax.random.split(ks[2], cfg.n_layers)
        params["blocks"] = jax.vmap(lambda k: _init_block(cfg, k))(block_keys)

    if cfg.encdec:
        enc_keys = jax.random.split(ks[5], cfg.enc_layers)
        enc_cfg = cfg
        def _enc_block(k):
            k1, k2 = jax.random.split(k)
            return {
                "ln1": L.init_norm(enc_cfg, enc_cfg.d_model),
                "attn": L.init_gqa(enc_cfg, k1),
                "ln2": L.init_norm(enc_cfg, enc_cfg.d_model),
                "mlp": L.init_mlp(enc_cfg, k2),
            }
        params["encoder"] = {
            "pos": (jax.random.normal(ks[6], (cfg.max_source_positions, cfg.d_model))
                    * 0.02).astype(dt),
            "blocks": jax.vmap(_enc_block)(enc_keys),
            "final_norm": L.init_norm(cfg, cfg.d_model),
        }
    return params


# ---------------------------------------------------------------------------
# blocks (apply)
# ---------------------------------------------------------------------------


def _apply_block(cfg: ArchConfig, p, x, positions, enc_x=None):
    """One decoder block, training/prefill path.  Returns (x, aux)."""
    aux = jnp.zeros((), F32)
    if cfg.ssm:
        h, _, _ = L.mamba_block(cfg, p["ssm"], L.apply_norm(cfg, x, p["ln1"]))
        return x + h, aux
    if cfg.attention == "mla":
        h, _ = L.mla_attention(cfg, p["attn"], L.apply_norm(cfg, x, p["ln1"]), positions)
    else:
        h, _ = L.gqa_attention(cfg, p["attn"], L.apply_norm(cfg, x, p["ln1"]), positions)
    x = x + h
    if cfg.encdec and enc_x is not None:
        hkv, hd = cfg.n_kv_heads, cfg.head_dim
        bx = enc_x.shape[0]
        kx = L._dot(enc_x, p["xattn"]["wk"]).reshape(bx, -1, hkv, hd)
        vx = L._dot(enc_x, p["xattn"]["wv"]).reshape(bx, -1, hkv, hd)
        h, _ = L.gqa_attention(
            cfg, p["xattn"], L.apply_norm(cfg, x, p["lnx"]), positions,
            causal=False, cross_kv=(kx, vx),
        )
        x = x + h
    if cfg.moe:
        h, aux = L.moe_block(cfg, p["moe"], L.apply_norm(cfg, x, p["ln2"]))
    else:
        h = L.mlp(cfg, p["mlp"], L.apply_norm(cfg, x, p["ln2"]))
    return x + h, aux


def _shared_attn_block(cfg: ArchConfig, p, x, positions):
    h, _ = L.gqa_attention(cfg, p["attn"], L.apply_norm(cfg, x, p["ln1"]), positions)
    x = x + h
    h = L.mlp(cfg, p["mlp"], L.apply_norm(cfg, x, p["ln2"]))
    return x + h


def _encode(cfg: ArchConfig, params, enc_emb):
    """Whisper encoder over stub frame embeddings [B, T, D]."""
    t = enc_emb.shape[1]
    x = enc_emb + params["encoder"]["pos"][:t][None]
    positions = jnp.arange(t)

    def enc_block(x, p):
        h, _ = L.gqa_attention(
            cfg, p["attn"], L.apply_norm(cfg, x, p["ln1"]), positions, causal=False
        )
        x = x + h
        h = L.mlp(cfg, p["mlp"], L.apply_norm(cfg, x, p["ln2"]))
        return x + h, ()

    blk = enc_block
    if cfg.remat:
        blk = jax.checkpoint(enc_block)
    x, _ = jax.lax.scan(blk, x, params["encoder"]["blocks"], unroll=_unroll())
    return L.apply_norm(cfg, x, params["encoder"]["final_norm"])


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _constrain(x, spec):
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def _embed(cfg: ArchConfig, params, tokens, act_spec):
    """Token embedding lookup.

    With a mesh-aware ``act_spec`` (NamedSharding) the gather runs inside
    shard_map against the d_model-sharded table, so each chip gathers only
    its embedding slice — a naive gather makes GSPMD all-gather the whole
    table per chip (measured 4.25 GiB of temps at 128k x 16k), and its
    backward scatter trips the SPMD partitioner entirely."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    table = params["embed"]
    if not isinstance(act_spec, NamedSharding):
        return table[tokens].astype(jnp.dtype(cfg.dtype))
    mesh = act_spec.mesh
    data_sp = act_spec.spec[0]
    d_sharded = cfg.d_model % mesh.shape["model"] == 0
    tspec = P(None, "model") if d_sharded else P(None, None)
    ospec = P(data_sp, None, "model" if d_sharded else None)

    def local(tab, tok):
        return tab[tok]

    out = shard_map_compat(
        local, mesh=mesh, in_specs=(tspec, P(data_sp, None)), out_specs=ospec,
    )(table, tokens)
    return out.astype(jnp.dtype(cfg.dtype))


def forward(
    cfg: ArchConfig,
    params: Dict[str, Any],
    tokens: jax.Array,                       # [B, S] int32
    *,
    enc_emb: Optional[jax.Array] = None,     # [B, T, D] (audio stub)
    positions: Optional[jax.Array] = None,
    return_hidden: bool = False,
    act_spec=None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits [B, S, V] f32, moe_aux scalar) — or the final hidden
    states when ``return_hidden`` (callers then apply the head in chunks:
    materializing [B, S, V] f32 at production shapes is hundreds of GB).

    ``act_spec``: optional PartitionSpec pinned onto the residual stream
    between blocks (sequence parallelism for attention stacks, channel
    sharding for SSM stacks) — this bounds the scan-saved activations, the
    dominant training-memory term at 100+ layers."""
    b, s = tokens.shape
    x = _embed(cfg, params, tokens, act_spec)
    x = _constrain(x, act_spec)
    positions = positions if positions is not None else jnp.arange(s)
    enc_x = _encode(cfg, params, enc_emb) if cfg.encdec else None

    def block(carry, p):
        x, aux = carry
        x, a = _apply_block(cfg, p, x, positions, enc_x)
        return (_constrain(x, act_spec), aux + a), ()

    blk = jax.checkpoint(block) if cfg.remat else block

    if cfg.hybrid_attn_every:
        every = cfg.hybrid_attn_every
        n_groups = cfg.n_layers // every
        aux = jnp.zeros((), F32)
        blocks = params["blocks"]
        for g in range(n_groups):
            grp = jax.tree.map(lambda a: a[g * every : (g + 1) * every], blocks)
            (x, aux), _ = jax.lax.scan(blk, (x, aux), grp, unroll=_unroll())
            x = _shared_attn_block(cfg, params["shared_attn"], x, positions)
        rem = cfg.n_layers - n_groups * every
        if rem:
            grp = jax.tree.map(lambda a: a[-rem:], blocks)
            (x, aux), _ = jax.lax.scan(blk, (x, aux), grp, unroll=_unroll())
    else:
        (x, aux), _ = jax.lax.scan(blk, (x, jnp.zeros((), F32)), params["blocks"], unroll=_unroll())

    x = L.apply_norm(cfg, x, params["final_norm"])
    if return_hidden:
        return x, aux
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.dot(x, head, preferred_element_type=F32)
    return logits, aux


def _head_of(cfg: ArchConfig, params):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def chunked_ce(cfg: ArchConfig, params, hidden, labels, *, chunk: int = 512):
    """Cross entropy without materializing [B, S, V] f32: scan over sequence
    chunks, recomputing each chunk's logits (they are rematerialized in the
    backward pass too — the standard memory/compute trade at 100k+ vocabs).
    Returns (sum_nll, count)."""
    b, s, d = hidden.shape
    c = min(chunk, s)
    while s % c:
        c -= 1
    nc = s // c
    head = _head_of(cfg, params)
    hc = hidden.reshape(b, nc, c, d).swapaxes(0, 1)      # [nc, B, c, D]
    lc = labels.reshape(b, nc, c).swapaxes(0, 1)

    def one(carry, inp):
        nll_sum, cnt = carry
        h, lab = inp
        logits = jnp.dot(h, head, preferred_element_type=F32)   # [B, c, V]
        valid = lab != -100
        safe = jnp.where(valid, lab, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        nll_sum = nll_sum + jnp.sum((logz - gold) * valid)
        cnt = cnt + jnp.sum(valid).astype(jnp.int32)
        return (nll_sum, cnt), ()

    one = jax.checkpoint(one)
    (nll_sum, cnt), _ = jax.lax.scan(
        one, (jnp.zeros((), F32), jnp.zeros((), jnp.int32)), (hc, lc)
    )
    return nll_sum, cnt


def loss_fn(cfg: ArchConfig, params, batch, *, ce_chunk: int = 512,
            act_spec=None) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross entropy (+ MoE aux).  ``batch``: dict with
    ``tokens`` [B,S], ``labels`` [B,S] (-100 = ignore), optional ``enc_emb``."""
    hidden, aux = forward(
        cfg, params, batch["tokens"], enc_emb=batch.get("enc_emb"),
        return_hidden=True, act_spec=act_spec,
    )
    nll_sum, cnt = chunked_ce(cfg, params, hidden, batch["labels"], chunk=ce_chunk)
    denom = jnp.maximum(cnt, 1)
    ce = nll_sum / denom
    total = ce + 0.01 * aux
    return total, {"ce": ce, "moe_aux": aux, "tokens": denom}


# ---------------------------------------------------------------------------
# decode (serving)
# ---------------------------------------------------------------------------


def init_decode_cache(cfg: ArchConfig, batch: int, max_len: int,
                      enc_len: int = 0) -> Dict[str, Any]:
    """Dense (contiguous) decode cache; the DEX-paged variant lives in
    serve/kv_cache.py and replaces the ``kv`` entry with a page pool."""
    dt = jnp.dtype(cfg.dtype)
    cache: Dict[str, Any] = {}
    nl = cfg.n_layers
    if cfg.ssm or cfg.hybrid_attn_every:
        di = cfg.ssm_expand * cfg.d_model
        cache["ssm"] = jnp.zeros((nl, batch, di, cfg.ssm_state), F32)
        cache["conv"] = jnp.zeros((nl, batch, cfg.ssm_conv - 1, di), F32)
        if cfg.hybrid_attn_every:
            # the shared block shares WEIGHTS across its applications, but
            # every application sees different activations -> per-group caches
            n_groups = cfg.n_layers // cfg.hybrid_attn_every
            cache["shared_k"] = jnp.zeros(
                (n_groups, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dt
            )
            cache["shared_v"] = jnp.zeros_like(cache["shared_k"])
        return cache
    if cfg.attention == "mla":
        cache["c_kv"] = jnp.zeros((nl, batch, max_len, cfg.kv_lora_rank), dt)
        cache["k_rope"] = jnp.zeros((nl, batch, max_len, cfg.qk_rope_dim), dt)
        return cache
    cache["k"] = jnp.zeros((nl, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dt)
    cache["v"] = jnp.zeros_like(cache["k"])
    if cfg.encdec:
        cache["xk"] = jnp.zeros((nl, batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dt)
        cache["xv"] = jnp.zeros_like(cache["xk"])
    return cache


def prefill_cross_kv(cfg: ArchConfig, params, enc_emb, cache):
    """Whisper: run the encoder once, fill per-layer cross KV."""
    enc_x = _encode(cfg, params, enc_emb)
    b, t, _ = enc_x.shape
    hkv, hd = cfg.n_kv_heads, cfg.head_dim

    def per_layer(p):
        kx = L._dot(enc_x, p["xattn"]["wk"]).reshape(b, t, hkv, hd)
        vx = L._dot(enc_x, p["xattn"]["wv"]).reshape(b, t, hkv, hd)
        return kx, vx

    kx, vx = jax.vmap(per_layer)(params["blocks"])
    return dict(cache, xk=kx, xv=vx)


def decode_step(
    cfg: ArchConfig,
    params: Dict[str, Any],
    tokens: jax.Array,          # [B, 1]
    cache: Dict[str, Any],
    pos: jax.Array,             # scalar int32: current length
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One token for every sequence.  Returns (logits [B, V], cache')."""
    b = tokens.shape[0]
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))   # [B, 1, D]
    positions = jnp.full((1,), pos, jnp.int32)

    # NOTE on cache plumbing: caches travel in the scan CARRY (indexed with
    # dynamic_update_index_in_dim) rather than as scanned xs/ys — XLA aliases
    # loop carries in place, while stacked scan outputs double-buffer the
    # whole multi-GB cache (measured ~17 GiB of temps at decode_32k x 405B).
    if cfg.ssm or cfg.hybrid_attn_every:
        def blockfn(carry, inp):
            x, ssm_all, conv_all = carry
            p, idx = inp
            ssm_st = jax.lax.dynamic_index_in_dim(ssm_all, idx, 0, keepdims=False)
            conv_st = jax.lax.dynamic_index_in_dim(conv_all, idx, 0, keepdims=False)
            h, new_ssm, new_conv = L.mamba_block(
                cfg, p["ssm"], L.apply_norm(cfg, x, p["ln1"]),
                ssm_state=ssm_st, conv_state=conv_st,
            )
            ssm_all = jax.lax.dynamic_update_index_in_dim(ssm_all, new_ssm, idx, 0)
            conv_all = jax.lax.dynamic_update_index_in_dim(
                conv_all, new_conv.astype(conv_all.dtype), idx, 0
            )
            return (x + h, ssm_all, conv_all), ()

        if cfg.hybrid_attn_every:
            every = cfg.hybrid_attn_every
            n_groups = cfg.n_layers // every
            ssm_all, conv_all = cache["ssm"], cache["conv"]
            sk_all, sv_all = cache["shared_k"], cache["shared_v"]
            for g in range(n_groups):
                sl = slice(g * every, (g + 1) * every)
                grp = jax.tree.map(lambda a: a[sl], params["blocks"])
                idxs = jnp.arange(g * every, (g + 1) * every, dtype=jnp.int32)
                (x, ssm_all, conv_all), _ = jax.lax.scan(
                    blockfn, (x, ssm_all, conv_all), (grp, idxs), unroll=_unroll()
                )
                h, kv = L.gqa_attention(
                    cfg, params["shared_attn"]["attn"],
                    L.apply_norm(cfg, x, params["shared_attn"]["ln1"]),
                    positions,
                    kv_cache=(sk_all[g], sv_all[g]),
                    cache_len=pos,
                )
                sk_all = jax.lax.dynamic_update_index_in_dim(sk_all, kv[0], g, 0)
                sv_all = jax.lax.dynamic_update_index_in_dim(sv_all, kv[1], g, 0)
                x = x + h
                h = L.mlp(cfg, params["shared_attn"]["mlp"],
                          L.apply_norm(cfg, x, params["shared_attn"]["ln2"]))
                x = x + h
            cache = dict(cache, ssm=ssm_all, conv=conv_all,
                         shared_k=sk_all, shared_v=sv_all)
        else:
            idxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
            (x, ssm_all, conv_all), _ = jax.lax.scan(
                blockfn, (x, cache["ssm"], cache["conv"]),
                (params["blocks"], idxs), unroll=_unroll(),
            )
            cache = dict(cache, ssm=ssm_all, conv=conv_all)
    elif cfg.attention == "mla":
        def blockfn(carry, inp):
            x, cc_all, cr_all = carry
            p, idx = inp
            cc = jax.lax.dynamic_index_in_dim(cc_all, idx, 0, keepdims=False)
            cr = jax.lax.dynamic_index_in_dim(cr_all, idx, 0, keepdims=False)
            h, kv = L.mla_attention(
                cfg, p["attn"], L.apply_norm(cfg, x, p["ln1"]), positions,
                kv_cache=(cc, cr), cache_len=pos,
            )
            cc_all = jax.lax.dynamic_update_index_in_dim(cc_all, kv[0], idx, 0)
            cr_all = jax.lax.dynamic_update_index_in_dim(cr_all, kv[1], idx, 0)
            x = x + h
            h = L.mlp(cfg, p["mlp"], L.apply_norm(cfg, x, p["ln2"]))
            return (x + h, cc_all, cr_all), ()

        idxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
        (x, ncc, ncr), _ = jax.lax.scan(
            blockfn, (x, cache["c_kv"], cache["k_rope"]), (params["blocks"], idxs),
            unroll=_unroll(),
        )
        cache = dict(cache, c_kv=ncc, k_rope=ncr)
    else:
        def blockfn(carry, inp):
            x, k_all, v_all = carry
            p, idx = inp
            ck = jax.lax.dynamic_index_in_dim(k_all, idx, 0, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(v_all, idx, 0, keepdims=False)
            h, kv = L.gqa_attention(
                cfg, p["attn"], L.apply_norm(cfg, x, p["ln1"]), positions,
                kv_cache=(ck, cv), cache_len=pos,
            )
            k_all = jax.lax.dynamic_update_index_in_dim(k_all, kv[0], idx, 0)
            v_all = jax.lax.dynamic_update_index_in_dim(v_all, kv[1], idx, 0)
            x = x + h
            if cfg.encdec:
                xk = jax.lax.dynamic_index_in_dim(
                    cache["xk"], idx, 0, keepdims=False
                )
                xv = jax.lax.dynamic_index_in_dim(
                    cache["xv"], idx, 0, keepdims=False
                )
                h, _ = L.gqa_attention(
                    cfg, p["xattn"], L.apply_norm(cfg, x, p["lnx"]), positions,
                    causal=False, cross_kv=(xk, xv),
                )
                x = x + h
            if cfg.moe:
                h, _ = L.moe_block(cfg, p["moe"], L.apply_norm(cfg, x, p["ln2"]))
            else:
                h = L.mlp(cfg, p["mlp"], L.apply_norm(cfg, x, p["ln2"]))
            return (x + h, k_all, v_all), ()

        idxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
        (x, nk, nv), _ = jax.lax.scan(
            blockfn, (x, cache["k"], cache["v"]), (params["blocks"], idxs),
            unroll=_unroll(),
        )
        cache = dict(cache, k=nk, v=nv)

    x = L.apply_norm(cfg, x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.dot(x[:, 0], head, preferred_element_type=F32)
    return logits, cache
