"""Architecture registry: ``--arch <id>`` resolution for every launcher."""

from __future__ import annotations

from typing import Dict

from repro.models.config import ArchConfig

from repro.configs.qwen1_5_110b import CONFIG as _qwen
from repro.configs.minicpm3_4b import CONFIG as _minicpm
from repro.configs.llama3_405b import CONFIG as _llama
from repro.configs.minitron_4b import CONFIG as _minitron
from repro.configs.chameleon_34b import CONFIG as _chameleon
from repro.configs.whisper_small import CONFIG as _whisper
from repro.configs.granite_moe_1b import CONFIG as _granite
from repro.configs.grok_1_314b import CONFIG as _grok
from repro.configs.zamba2_2_7b import CONFIG as _zamba
from repro.configs.falcon_mamba_7b import CONFIG as _falcon

ARCHS: Dict[str, ArchConfig] = {
    "qwen1.5-110b": _qwen,
    "minicpm3-4b": _minicpm,
    "llama3-405b": _llama,
    "minitron-4b": _minitron,
    "chameleon-34b": _chameleon,
    "whisper-small": _whisper,
    "granite-moe-1b-a400m": _granite,
    "grok-1-314b": _grok,
    "zamba2-2.7b": _zamba,
    "falcon-mamba-7b": _falcon,
}


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; options: {sorted(ARCHS)}")
    return ARCHS[arch_id]
