"""llama3-405b [dense] — 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256.  [arXiv:2407.21783; unverified]"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab=128256,
    rope_theta=500_000.0,
)
