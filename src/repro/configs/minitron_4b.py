"""minitron-4b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000 (pruned nemotron).  [arXiv:2407.14679; hf]"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=9216,
    vocab=256000,
    head_dim=128,
)
