"""chameleon-34b [vlm] — 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536; early-fusion, VQ image tokens (frontend stub: image tokens are
ordinary vocabulary ids).  QK-norm per the paper.  [arXiv:2405.09818]"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=65536,
    qk_norm=True,
)
