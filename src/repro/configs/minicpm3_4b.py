"""minicpm3-4b [dense] — 62L d_model=2560 40H (kv=40) d_ff=6400 vocab=73448,
MLA (multi-head latent attention).  [hf:openbmb/MiniCPM3-4B; hf]"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab=73448,
    attention="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
)
