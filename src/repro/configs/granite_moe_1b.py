"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8) expert
d_ff=512 vocab=49155, 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    moe=True,
    n_experts=32,
    top_k=8,
    expert_d_ff=512,
    tie_embeddings=True,
)
