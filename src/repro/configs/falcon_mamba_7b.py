"""falcon-mamba-7b [ssm] — 64L d_model=4096 (attention-free) vocab=65024,
ssm_state=16, mamba1 architecture.  [arXiv:2410.05355; unverified]

DEX paging note (DESIGN.md §Arch-applicability): attention-free — decode
carries a fixed-size recurrent state, so the paged-KV index does not apply
to this arch's decode path."""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab=65024,
    attention="none",
    ssm=True,
    ssm_state=16,
    ssm_expand=2,
    mamba_version=1,
    sub_quadratic=True,
    tie_embeddings=True,
)
