"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000,
ssm_state=64; Mamba2 blocks + weight-shared attention block applied
periodically (the Zamba2 global shared block).  [arXiv:2411.15242; hf]"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm=True,
    ssm_state=64,
    ssm_expand=2,
    mamba_version=2,
    hybrid_attn_every=6,
    sub_quadratic=True,
    tie_embeddings=True,
)
