"""whisper-small [audio] — 12L d_model=768 12H (kv=12) d_ff=3072 vocab=51865;
enc-dec with conv frontend STUB (input_specs provides precomputed frame
embeddings).  [arXiv:2212.04356]"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    encdec=True,
    enc_layers=12,
    max_source_positions=1500,
    act="gelu",
    norm="layernorm",
    tie_embeddings=True,
)
