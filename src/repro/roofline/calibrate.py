"""Loop-aware roofline calibration.

XLA's ``cost_analysis()`` counts a while-loop body ONCE (verified
empirically — a scan of 8 matmuls reports 1), so the scan-over-layers
programs under-report FLOPs/bytes/collective bytes by ~n_layers x.  The
calibration probe recompiles the cell with:

  * the layer scans fully UNROLLED (``model.SCAN_UNROLL``) — every layer's
    matmuls and collectives appear in the HLO and are counted exactly;
  * microbatches=1 — same arithmetic, no grad-accumulation loop;
  * MoE token chunking disabled (``layers.MOE_FULL_CHUNK``) — the dispatch
    appears once with the full token count.

What remains inside loops after this is the collective-free inner compute of
the flash-attention kv-block scan, the SSM chunk scan and the chunked-CE
scan; those FLOPs are added analytically:

    attention: 4 * B * Sq * Sk * H * dh * (0.5 if causal square) per layer
    ssm:       ~9 * B * S * Di * N per layer
    CE head:   2 * B * S * D * V            (x3 for train fwd+bwd)

The probe is compile-only (nothing executes), so the unrolled HLO's memory
plan is irrelevant — only its op counts are read.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.models.config import ArchConfig, ShapeCell
from repro.roofline.analysis import collective_bytes


def _extract(compiled) -> Dict[str, float]:
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collective": float(sum(coll.values())),
    }


def analytic_inner_flops(cfg: ArchConfig, cell: ShapeCell) -> float:
    """Cluster-wide FLOPs hidden inside (collective-free) chunk loops."""
    b = cell.global_batch
    s = cell.seq_len if cell.kind in ("train", "prefill") else 1
    bwd = 3.0 if cell.kind == "train" else 1.0   # fwd + 2x bwd
    total = 0.0
    if cfg.attention != "none":
        h = cfg.n_heads
        dh = (cfg.qk_nope_dim + cfg.qk_rope_dim) if cfg.attention == "mla" \
            else cfg.head_dim
        sk = cell.seq_len if cell.kind == "decode" else s
        per_layer = 4.0 * b * s * sk * h * dh * (0.5 if s == sk else 1.0)
        n_attn = (
            cfg.n_layers // cfg.hybrid_attn_every
            if cfg.hybrid_attn_every
            else cfg.n_layers
        )
        total += per_layer * n_attn * bwd
        if cfg.encdec:
            t = cfg.max_source_positions
            total += 4.0 * b * t * t * h * dh * cfg.enc_layers * bwd
            total += 4.0 * b * s * t * h * dh * cfg.n_layers * bwd
    if cfg.ssm:
        di = cfg.ssm_expand * cfg.d_model
        total += 9.0 * b * s * di * cfg.ssm_state * cfg.n_layers * bwd
    if cell.kind == "train":
        total += 2.0 * b * s * cfg.d_model * cfg.vocab * bwd
    return total


def calibrated_terms(cfg: ArchConfig, cell: ShapeCell, mesh, mesh_name: str,
                     lower_fn) -> Dict[str, float]:
    """Unrolled probe -> per-chip step totals.

    ``lower_fn(cfg, cell, mesh, mesh_name)`` must return a compiled cell
    (launch/dryrun.lower_cell with microbatches=1)."""
    from repro.models import layers as LY
    from repro.models import model as M

    chips = int(np.prod(list(mesh.shape.values())))
    M.SCAN_UNROLL = max(cfg.n_layers, cfg.enc_layers or 1, 2)
    LY.MOE_FULL_CHUNK = True
    try:
        c = _extract(lower_fn(cfg, cell, mesh, mesh_name))
    finally:
        M.SCAN_UNROLL = None
        LY.MOE_FULL_CHUNK = False
    out = dict(c)
    out["flops"] += analytic_inner_flops(cfg, cell) / chips
    return out
