"""Roofline-term extraction from compiled dry-run artifacts.

Per (arch x shape x mesh) cell we derive (EXPERIMENTS.md §Roofline):

    compute term    = HLO_FLOPs_total / (chips * PEAK_FLOPS)
    memory term     = HLO_bytes_total / (chips * HBM_BW)
    collective term = collective_bytes_per_chip / LINK_BW

Sources: ``compiled.cost_analysis()`` for flops/bytes (XLA reports the
*per-partition* program under SPMD — one partition's flops; we multiply by
chip count for cluster totals and divide back for per-chip terms), and the
post-partitioning HLO text for collective operand bytes (cost_analysis does
not attribute collectives).

Hardware constants (v5e, per the brief): 197 bf16 TFLOP/s, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (per chip, one direction)

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(tok_dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(tok_dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op in (post-SPMD) HLO.

    These are per-partition programs, so the result is bytes moved per chip
    per step (the roofline denominator is per-chip link bandwidth)."""
    out = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        m = re.search(r"=\s*(.+?)\s+([a-z0-9\-]+)\(", stripped)
        if not m:
            continue
        opcode = m.group(2)
        if opcode.endswith("-start"):
            opcode = opcode[: -len("-start")]
        if opcode not in out:
            continue
        shapes = _SHAPE_RE.findall(m.group(1))
        out[opcode] += sum(_shape_bytes(dt, dims) for dt, dims in shapes)
    return out


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    collective_bytes_per_chip: float
    collective_breakdown: Dict[str, int]
    model_flops: float                 # 6*N*D (or 6*N_active*D for MoE)
    per_device_memory_bytes: float

    @property
    def compute_term(self) -> float:
        return self.hlo_flops_per_chip / PEAK_FLOPS

    @property
    def memory_term(self) -> float:
        return self.hlo_bytes_per_chip / HBM_BW

    @property
    def collective_term(self) -> float:
        return self.collective_bytes_per_chip / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_term,
            "memory": self.memory_term,
            "collective": self.collective_term,
        }
        return max(terms, key=lambda k: terms[k])

    @property
    def step_time_bound(self) -> float:
        """Lower bound on step time = max of the three terms (perfect
        overlap assumption)."""
        return max(self.compute_term, self.memory_term, self.collective_term)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / cluster HLO FLOPs: how much compiled compute is
        'useful' (catches remat/redundancy waste).  > 1 would mean XLA
        counts fewer flops than the analytic minimum (fused/elided ops)."""
        total = self.hlo_flops_per_chip * self.chips
        return self.model_flops / total if total else float("nan")

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the step-time bound:
        useful model FLOPs / (chips * peak * bound)."""
        bound = self.step_time_bound
        if bound <= 0:
            return float("nan")
        return self.model_flops / (self.chips * PEAK_FLOPS * bound)

    def to_dict(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_per_chip": self.hlo_flops_per_chip,
            "hlo_bytes_per_chip": self.hlo_bytes_per_chip,
            "collective_bytes_per_chip": self.collective_bytes_per_chip,
            "collective_breakdown": self.collective_breakdown,
            "model_flops": self.model_flops,
            "per_device_memory_bytes": self.per_device_memory_bytes,
            "compute_term_s": self.compute_term,
            "memory_term_s": self.memory_term,
            "collective_term_s": self.collective_term,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_for(cfg, shape_cell) -> float:
    """Analytic MODEL_FLOPS for the step: 6*N*D training, 2*N*D inference
    (forward only), with N_active for MoE."""
    n_active = cfg.active_param_count()
    tokens = shape_cell.global_batch * (
        shape_cell.seq_len if shape_cell.kind in ("train", "prefill") else 1
    )
    mult = 6.0 if shape_cell.kind == "train" else 2.0
    return mult * n_active * tokens


def build_terms(
    *, arch, shape_cell, mesh_name, chips, cost, mem_stats, hlo_text, cfg
) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0)) if cost else 0.0
    byts = float(cost.get("bytes accessed", 0.0)) if cost else 0.0
    coll = collective_bytes(hlo_text)
    per_dev_mem = (
        mem_stats.argument_size_in_bytes
        + mem_stats.output_size_in_bytes
        + mem_stats.temp_size_in_bytes
    )
    return RooflineTerms(
        arch=arch,
        shape=shape_cell.name,
        mesh=mesh_name,
        chips=chips,
        hlo_flops_per_chip=flops,
        hlo_bytes_per_chip=byts,
        collective_bytes_per_chip=float(sum(coll.values())),
        collective_breakdown=coll,
        model_flops=model_flops_for(cfg, shape_cell),
        per_device_memory_bytes=float(per_dev_mem),
    )
