"""Serving steps: dense prefill + paged decode (GQA families).

``paged_decode_step`` is the data-plane consumer of the DEX page table: one
new token per request, attention over the paged pool.  The attention math
runs through kernels/paged_attention (interpret on CPU, native on TPU) or
its jnp oracle; both read the page table resolved by the DEX index.
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models import layers as L
from repro.models import model as M
from repro.models.config import ArchConfig

F32 = jnp.float32


def prefill(cfg: ArchConfig, params, tokens, cache, *, enc_emb=None):
    """Teacher-forced prefill that fills a dense cache token-free via the
    training forward; used by examples to warm caches before decode."""
    logits, _ = M.forward(cfg, params, tokens, enc_emb=enc_emb)
    return logits


@functools.partial(jax.jit, static_argnames=("cfg", "use_kernel"))
def paged_decode_step(
    cfg: ArchConfig,
    params: Dict,
    tokens: jax.Array,       # [B, 1] current tokens
    k_pages: jax.Array,      # [L, P, page, HKV, Dh]
    v_pages: jax.Array,
    page_table: jax.Array,   # [B, ppr] int32 (resolved by the DEX index)
    seq_lens: jax.Array,     # [B] int32 (lengths INCLUDING current token)
    *,
    use_kernel: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step for GQA archs over the paged pool.

    Returns (logits [B, V], k_new [L, B, HKV, Dh], v_new [L, B, HKV, Dh]);
    the host control plane scatters k_new/v_new into the pool via
    ``PagedKVCache.append_tokens`` (the token attends to itself here, so the
    scatter may land after the step)."""
    b = tokens.shape[0]
    hkv, hd, h = cfg.n_kv_heads, cfg.head_dim, cfg.n_heads
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))   # [B, 1, D]
    positions = seq_lens - 1                                   # [B]

    def block(carry, inp):
        x = carry
        p, kp, vp = inp
        xin = L.apply_norm(cfg, x, p["ln1"])
        ap = p["attn"]
        q = L._dot(xin, ap["wq"])
        k = L._dot(xin, ap["wk"])
        v = L._dot(xin, ap["wv"])
        if cfg.qkv_bias:
            q, k, v = q + ap["bq"], k + ap["bk"], v + ap["bv"]
        q = q.reshape(b, 1, h, hd)
        k = k.reshape(b, 1, hkv, hd)
        v = v.reshape(b, 1, hkv, hd)
        if cfg.qk_norm:
            q = L.rmsnorm(q, ap["q_norm"], cfg.norm_eps)
            k = L.rmsnorm(k, ap["k_norm"], cfg.norm_eps)
        cos, sin = L.rope_freqs(hd, cfg.rope_theta, positions[:, None])  # [B,1,hd/2]
        q = L.apply_rope(q, cos[..., None, :], sin[..., None, :])
        k = L.apply_rope(k, cos[..., None, :], sin[..., None, :])

        # attend over pool pages + the fresh token (self-attention term)
        if use_kernel:
            o_hist = kops.paged_attention(
                q[:, 0], kp, vp, page_table, positions
            )
        else:
            o_hist = kref.paged_attention_ref(
                q[:, 0], kp, vp, page_table, positions
            )
        # combine history softmax with the current token analytically:
        # treat the fresh (k, v) as one extra key with its own logit.
        scale = 1.0 / float(np.sqrt(hd))
        qg = q[:, 0].reshape(b, hkv, h // hkv, hd).astype(F32) * scale
        s_self = jnp.einsum("bngd,bnd->bng", qg, k[:, 0].astype(F32))
        # history logsumexp is folded inside o_hist; recompute weights:
        # w_hist = L_hist / (L_hist + exp(s_self)), with L_hist implied.
        # For numerical simplicity recompute history logits' logsumexp:
        ppr, page = page_table.shape[1], kp.shape[1]
        kh = kp[page_table].reshape(b, ppr * page, hkv, hd)
        sh = jnp.einsum("bngd,bsnd->bngs", qg, kh.astype(F32))
        pos_ids = jnp.arange(ppr * page)[None]
        sh = jnp.where((pos_ids < positions[:, None])[:, None, None, :], sh, -jnp.inf)
        lse_hist = jax.nn.logsumexp(sh, axis=-1)                  # [B,n,g]
        denom = jnp.exp(lse_hist) + jnp.exp(s_self)
        w_hist = jnp.where(positions[:, None, None] > 0,
                           jnp.exp(lse_hist) / denom, 0.0)
        w_self = jnp.where(positions[:, None, None] > 0,
                           jnp.exp(s_self) / denom, 1.0)
        # positions == 0 means empty history: the softmax over -inf logits is
        # NaN there; it gets weight 0, so sanitize before the blend
        o_hist_g = jnp.nan_to_num(
            o_hist.reshape(b, hkv, h // hkv, hd).astype(F32)
        )
        v_self = v[:, 0].astype(F32)[:, :, None, :]               # [B,n,1,d]
        o = o_hist_g * w_hist[..., None] + v_self * w_self[..., None]
        o = o.reshape(b, 1, h * hd).astype(x.dtype)
        x = x + L._dot(o, ap["wo"])
        if cfg.moe:
            hmlp, _ = L.moe_block(cfg, p["moe"], L.apply_norm(cfg, x, p["ln2"]))
        else:
            hmlp = L.mlp(cfg, p["mlp"], L.apply_norm(cfg, x, p["ln2"]))
        return x + hmlp, (k[:, 0], v[:, 0])

    x, (k_new, v_new) = jax.lax.scan(block, x, (params["blocks"], k_pages, v_pages))
    x = L.apply_norm(cfg, x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.dot(x[:, 0], head, preferred_element_type=F32)
    return logits, k_new, v_new
