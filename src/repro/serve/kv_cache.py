"""DEX-paged KV cache: the paper's index as a first-class serving feature.

The KV pool is "disaggregated memory": a flat page pool (sharded over the
mesh in production) whose ownership map — ``(request, page_index) -> page`` —
is a DEX B+-tree.  The serving control plane (host) allocates/frees pages by
inserting/deleting keys; the data plane resolves page tables with batched
device lookups (``core.btree.bulk_lookup`` single-chip, ``core.dex`` on a
mesh) and attends with kernels/paged_attention.

Why an ordered index rather than a dense table (vLLM-style)?  Ranges:
  * freeing a request = one range delete (its whole key range);
  * prefix sharing / forking = range scan + copy-on-write bump;
  * elastic rebalancing of requests across serving replicas = DEX logical
    repartitioning of the request-id space (§4) — no page movement.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import btree
from repro.core.nodes import KEY_MAX
from repro.models.config import ArchConfig

#: key layout: (request id << PAGE_BITS) | page index
PAGE_BITS = 24


def page_key(req_id, page_idx):
    return (np.int64(req_id) << PAGE_BITS) | np.int64(page_idx)


@dataclasses.dataclass
class PagedKVCache:
    """Host-controlled paged pool with a DEX page-table index."""

    cfg: ArchConfig
    n_pages: int
    page_size: int
    max_batch: int

    def __post_init__(self):
        c = self.cfg
        dt = jnp.dtype(c.dtype)
        nl = c.n_layers
        self.k_pages = jnp.zeros(
            (nl, self.n_pages, self.page_size, c.n_kv_heads, c.head_dim), dt
        )
        self.v_pages = jnp.zeros_like(self.k_pages)
        self.free: List[int] = list(range(self.n_pages))[::-1]
        self.seq_lens: Dict[int, int] = {}
        self.allocated: Dict[int, int] = {}
        # the DEX page-table index, bootstrapped with a sentinel key
        keys = np.array([KEY_MAX - 1], dtype=np.int64)
        self.tree, self.meta = btree.bulk_build(keys, np.zeros(1, np.int64))
        self.lookups = 0

    # -- control plane (host): allocation via index inserts -------------------

    def pages_per_req(self, seq_len: int) -> int:
        return -(-seq_len // self.page_size)

    def admit_request(self, req_id: int, prompt_len: int) -> List[int]:
        n = self.pages_per_req(max(prompt_len, 1))
        if len(self.free) < n:
            raise MemoryError("page pool exhausted")
        pages = [self.free.pop() for _ in range(n)]
        keys = np.array([page_key(req_id, i) for i in range(n)], dtype=np.int64)
        vals = np.array(pages, dtype=np.int64)
        self.tree, self.meta, ok = btree.batch_insert(self.tree, self.meta, keys, vals)
        assert bool(np.all(ok))
        self.seq_lens[req_id] = prompt_len
        self.allocated[req_id] = n
        return pages

    def extend_request(self, req_id: int) -> Optional[int]:
        """Grow the request by one token; allocates (and index-inserts) a new
        page iff the new length spills past the allocated pages."""
        cur = self.seq_lens[req_id]
        self.seq_lens[req_id] = cur + 1
        needed = self.pages_per_req(cur + 1)
        if needed <= self.allocated[req_id]:
            return None
        if not self.free:
            raise MemoryError("page pool exhausted")
        page = self.free.pop()
        idx = needed - 1
        self.tree, self.meta, ok = btree.batch_insert(
            self.tree, self.meta,
            np.array([page_key(req_id, idx)], np.int64),
            np.array([page], np.int64),
        )
        assert bool(np.all(ok))
        self.allocated[req_id] = needed
        return page

    def release_request(self, req_id: int) -> int:
        """Range-delete the request's keys; returns pages reclaimed."""
        self.seq_lens.pop(req_id)
        n = self.allocated.pop(req_id)
        keys = np.array([page_key(req_id, i) for i in range(n)], dtype=np.int64)
        found, vals = btree.bulk_lookup(self.tree, jnp.asarray(keys),
                                        height=self.meta.height)
        pages = np.asarray(vals)[np.asarray(found)]
        self.tree, _ = btree.bulk_delete(self.tree, jnp.asarray(keys),
                                         height=self.meta.height)
        self.free.extend(int(p) for p in pages)
        return len(pages)

    # -- data plane (device): batched page-table resolution --------------------

    def resolve_tables(self, req_ids: np.ndarray, pages_per_req: int) -> jax.Array:
        """[B, ppr] page table via one batched DEX lookup."""
        b = len(req_ids)
        keys = (
            (req_ids.astype(np.int64)[:, None] << PAGE_BITS)
            | np.arange(pages_per_req, dtype=np.int64)[None, :]
        ).reshape(-1)
        found, vals = btree.bulk_lookup(
            self.tree, jnp.asarray(keys), height=self.meta.height
        )
        self.lookups += keys.size
        table = jnp.where(found, vals, 0).reshape(b, pages_per_req)
        return table.astype(jnp.int32)

    def batch_seq_lens(self, req_ids: np.ndarray) -> jax.Array:
        return jnp.asarray([self.seq_lens[int(r)] for r in req_ids], jnp.int32)

    # -- writes (append one token's KV for every layer) ------------------------

    def append_tokens(self, req_ids: np.ndarray, k_new: jax.Array, v_new: jax.Array):
        """k_new/v_new: [L, B, HKV, Dh] for the token at position seq_len-1
        (callers bump seq_lens via extend_request first)."""
        pos = np.array([self.seq_lens[int(r)] - 1 for r in req_ids])
        page_idx = pos // self.page_size
        offset = pos % self.page_size
        keys = (
            (req_ids.astype(np.int64) << PAGE_BITS) | page_idx.astype(np.int64)
        )
        found, vals = btree.bulk_lookup(
            self.tree, jnp.asarray(keys), height=self.meta.height
        )
        assert bool(np.all(np.asarray(found))), "page table hole"
        pages = np.asarray(vals).astype(np.int32)
        # advanced-index scatter: [L, B, HKV, Dh] -> (layer, page_b, offset_b)
        self.k_pages = self.k_pages.at[:, pages, offset].set(k_new)
        self.v_pages = self.v_pages.at[:, pages, offset].set(v_new)
        return pages
