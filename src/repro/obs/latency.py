"""Per-lane latency ledger: bucket schema, percentile estimation, and the
offload cost-model audit (DESIGN.md §12).

Both planes price a lane's trip through a batch with the *same* constants
(the ``SimConfig`` defaults mirrored below) and bin the modeled cost into
the *same* fixed log-scale histogram, so ``obs/drift.py`` can gate mesh
p50/p99 against simulator p50/p99 per op class exactly like it gates the
counter plane:

* the mesh engine (core/engine.py) accumulates a per-lane cost as the lane
  moves through route -> cached descent -> fused a2a -> apply, classifies
  the lane into one outcome path, and scatters it on-device into a
  ``[Dev, classes, paths, buckets]`` int64 plane (``DexState.lat_hist``) —
  a pure per-device scatter, zero added collectives;
* the simulator (core/sim.py) samples each op's ``op_clock`` delta (plus
  the service components ``op_clock`` books elsewhere) into the identical
  schema (``Simulator.lat_hist``).

Buckets are base-2 log-scale: bucket ``i`` covers ``[T0*2**i, T0*2**(i+1))``
seconds, with bucket 0 also catching anything below ``T0`` and the last
bucket catching overflow.  With ``T0 = 200ns`` and 16 buckets the schema
spans 200ns .. ~6.5ms — a cached lookup lands around bucket 1, a multi-level
remote fetch around buckets 3-5, an offload RPC around buckets 4-6.

Percentiles are estimated from the bucket CDF at the geometric midpoint of
the crossing bucket (``edge_lo * sqrt(2)`` for base-2 buckets), so a
mesh/sim percentile pair that lands in the same bucket compares exactly
equal and the drift band only needs one-bucket (2x) slack.

The cost-model audit compares, per (memory column, level), the offload
decision's *predicted* fetch bytes (``caps * miss_ema * NODE_ROW_BYTES *
offload_c`` — the per-group EMA rule in core/engine.py) against the
*realized* bytes (distinct nodes actually fetched that batch times
``NODE_ROW_BYTES``), and reports the mispricing ratio the perf gate bands.
"""

from __future__ import annotations

import math
from typing import Dict, Sequence

import numpy as np

# --------------------------------------------------------------------------
# bucket schema
# --------------------------------------------------------------------------

#: number of log-scale buckets per (op class, path) cell
N_BUCKETS = 16
#: left edge of bucket 0 in seconds (also the underflow catch-all)
T0 = 200e-9

#: op classes, indexed by engine opcode (OP_LOOKUP..OP_SCAN = 0..3); the
#: simulator maps its delete op onto the update class (same write path)
OP_CLASSES = ("lookup", "update", "insert", "scan")
#: outcome paths, mutually exclusive per lane, later entries win when a
#: lane qualifies for several (a shed lane that also missed is "shed")
PATHS = (
    "cache_hit",      # served from this chip's fresh cache at every level
    "remote_fetch",   # at least one level paid a remote node fetch
    "peer_peek",      # leaf resolved by a sibling chip's cache (MSG_PEEK)
    "offload",        # two-sided: shipped to the owning memory column
    "stale_forced",   # pipelined overlap caught a stale read; re-executed
    "shed",           # dropped by a routing/fused bucket; caller retries
)
N_CLASSES = len(OP_CLASSES)
N_PATHS = len(PATHS)

# --------------------------------------------------------------------------
# pricing constants — literal mirrors of the SimConfig defaults
# (core/sim.py).  Kept literal to avoid a sim <-> latency import cycle;
# tests/test_obs.py asserts they match SimConfig so the planes can never
# silently diverge.
# --------------------------------------------------------------------------

T_CACHED = 400e-9   # SimConfig.t_cached_access: 1KB cached page access
T_READ = 2e-6       # SimConfig.t_rdma_read: one-sided remote node fetch
T_WRITE = 2e-6      # SimConfig.t_rdma_write: write-through leaf write
T_RPC = 4e-6        # SimConfig.t_rpc_base: two-sided round-trip floor
T_MEM = 600e-9      # SimConfig.t_mem_search: per-node memory-side search
T_LOCAL = 150e-9    # SimConfig.t_local_search: compute-side leaf search


def bucket_edges() -> np.ndarray:
    """``[N_BUCKETS + 1]`` bucket edges in seconds (monotone, base-2)."""
    return T0 * np.exp2(np.arange(N_BUCKETS + 1, dtype=np.float64))


def bucket_index(x, xp=np):
    """Bucket index for cost(s) ``x`` in seconds; works for numpy scalars/
    arrays (``xp=np``) and traced jax arrays (``xp=jnp``)."""
    safe = xp.maximum(x, T0)
    idx = xp.floor(xp.log2(safe / T0))
    return xp.clip(idx, 0, N_BUCKETS - 1).astype(xp.int32 if xp is not np else np.int64)


# --------------------------------------------------------------------------
# percentile estimation from bucket CDFs
# --------------------------------------------------------------------------


def percentile(hist_1d: np.ndarray, q: float) -> float:
    """Estimate the ``q``-th percentile (0..100) from a 1-D bucket count
    vector: the geometric midpoint of the bucket where the CDF crosses the
    rank.  Returns 0.0 for an empty histogram."""
    h = np.asarray(hist_1d, dtype=np.float64)
    total = h.sum()
    if total <= 0:
        return 0.0
    rank = total * (q / 100.0)
    cdf = np.cumsum(h)
    i = int(np.searchsorted(cdf, rank, side="left"))
    i = min(i, N_BUCKETS - 1)
    return float(T0 * (2.0**i) * math.sqrt(2.0))


def class_percentiles(
    hist: np.ndarray, qs: Sequence[float] = (50.0, 95.0, 99.0)
) -> Dict[str, Dict[str, float]]:
    """Per-op-class percentiles from a ``[classes, paths, buckets]`` (or
    already path-summed ``[classes, buckets]``) histogram."""
    h = np.asarray(hist)
    if h.ndim == 3:
        h = h.sum(axis=1)
    out: Dict[str, Dict[str, float]] = {}
    for c, name in enumerate(OP_CLASSES):
        out[name] = {f"p{q:g}": percentile(h[c], q) for q in qs}
    return out


def ledger(hist: np.ndarray) -> Dict[str, Dict[str, object]]:
    """Per-(class, path) view of a ``[classes, paths, buckets]`` histogram:
    lane counts, path share within the class, and p50/p99 of each cell."""
    h = np.asarray(hist, dtype=np.int64)
    out: Dict[str, Dict[str, object]] = {}
    for c, cname in enumerate(OP_CLASSES):
        cls_total = int(h[c].sum())
        paths: Dict[str, object] = {}
        for p, pname in enumerate(PATHS):
            n = int(h[c, p].sum())
            paths[pname] = {
                "count": n,
                "share": (n / cls_total) if cls_total else 0.0,
                "p50_s": percentile(h[c, p], 50.0),
                "p99_s": percentile(h[c, p], 99.0),
            }
        out[cname] = {"count": cls_total, "paths": paths}
    return out


def latency_section(hist: np.ndarray) -> Dict[str, object]:
    """JSON-ready export of a fleet-summed ``[classes, paths, buckets]``
    histogram: schema + raw counts + percentiles + per-path ledger.  This is
    the shape ``BatchTimeline.summary()["latency"]`` carries and
    benchmarks/check_telemetry.py validates."""
    h = np.asarray(hist, dtype=np.int64)
    return {
        "bucket_edges_s": [float(e) for e in bucket_edges()],
        "op_classes": list(OP_CLASSES),
        "paths": list(PATHS),
        "hist": h.tolist(),
        "total": int(h.sum()),
        "percentiles": class_percentiles(h),
        "ledger": ledger(h),
    }


# --------------------------------------------------------------------------
# offload cost-model audit
# --------------------------------------------------------------------------


def audit_report(predicted: np.ndarray, realized: np.ndarray) -> Dict[str, object]:
    """Compare the offload rule's predicted fetch bytes against realized
    fetch bytes, both ``[n_memory, levels]`` accumulated over a run.

    ``mispricing_ratio`` is total predicted / total realized over the cells
    where the model made a fetch-side decision (realized > 0) — >1 means the
    EMA rule over-prices fetching (biasing toward offload), <1 under-prices
    it.  Cells with zero realized bytes (fully cached levels) are reported
    but excluded from the ratio."""
    pred = np.asarray(predicted, dtype=np.float64)
    real = np.asarray(realized, dtype=np.float64)
    active = real > 0
    tot_pred = float(pred[active].sum())
    tot_real = float(real[active].sum())
    ratio = (tot_pred / tot_real) if tot_real > 0 else 0.0
    cells = []
    n_mem, levels = pred.shape
    for col in range(n_mem):
        for lvl in range(levels):
            if pred[col, lvl] == 0 and real[col, lvl] == 0:
                continue
            cells.append({
                "column": col,
                "level": lvl,
                "predicted_bytes": float(pred[col, lvl]),
                "realized_bytes": float(real[col, lvl]),
                "ratio": (
                    float(pred[col, lvl] / real[col, lvl])
                    if real[col, lvl] > 0 else 0.0
                ),
            })
    return {
        "predicted_bytes": tot_pred,
        "realized_bytes": tot_real,
        "mispricing_ratio": ratio,
        "cells": cells,
    }


# --------------------------------------------------------------------------
# drift-gauge plumbing
# --------------------------------------------------------------------------


def percentile_gauges(hist: np.ndarray, classes: Sequence[str] = OP_CLASSES):
    """Flat ``{"lat_p50_lookup": ..., "lat_p99_lookup": ...}`` mapping for
    :func:`repro.obs.drift.assert_plane_agreement`; only classes with at
    least one sample are emitted (a gauge at 0.0 would force the drift band
    to special-case empties)."""
    h = np.asarray(hist)
    if h.ndim == 3:
        h = h.sum(axis=1)
    out: Dict[str, float] = {}
    for c, name in enumerate(OP_CLASSES):
        if name not in classes or h[c].sum() <= 0:
            continue
        out[f"lat_p50_{name}"] = percentile(h[c], 50.0)
        out[f"lat_p99_{name}"] = percentile(h[c], 99.0)
    return out
