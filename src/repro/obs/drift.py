"""Cross-plane drift checks: mesh (Plane B) counters vs simulator (Plane A).

Four mesh benchmarks used to hand-roll this comparison with four different
idioms (relative per-op error, raw ratio bands, absolute fraction gaps).
:func:`assert_plane_agreement` is the one shared helper: you hand it
anything counter-shaped from each plane plus per-metric tolerances, and it
returns a :class:`DriftReport` (raising :class:`PlaneDriftError` with the
readable report if any metric is out of tolerance).

Accepted "counter-shaped" inputs, resolved through the registry's names:

* a :class:`repro.obs.timeline.BatchTimeline` (summed per-batch deltas),
* a :class:`repro.obs.registry.Snapshot`,
* a ``repro.core.sim.Counters`` (any object carrying registered sim fields),
* a plain mapping of metric name -> value.

Tolerances (see the factory helpers):

* ``rel(limit, per_op=True)`` — relative error, optionally after dividing
  both sides by their own ``ops`` (fig6mesh's per-op read/write checks),
* ``ratio(lo, hi)`` — the raw mesh/sim ratio band (fig13engine's grouped
  offload check, fig14meshload's split-volume check),
* ``absolute(limit)`` — absolute difference (fig10meshrep's moved-fraction
  check).

``min_count`` on any tolerance skips the check when both planes saw fewer
events than that — quick-mode runs are too noisy for ratios on tiny counts,
and a skipped check is reported as skipped, never silently dropped.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional

from repro.obs import registry

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class Tolerance:
    kind: str  # "rel" | "ratio" | "abs"
    limit: float = 0.0  # for rel/abs
    lo: float = 0.0  # for ratio
    hi: float = 0.0  # for ratio
    per_op: bool = False  # normalise both sides by their own "ops" first
    min_count: float = 0.0  # skip when both planes are below this

    def describe(self) -> str:
        if self.kind == "rel":
            return f"rel<={self.limit:g}" + ("/op" if self.per_op else "")
        if self.kind == "ratio":
            return f"ratio in [{self.lo:g}, {self.hi:g}]"
        return f"abs<={self.limit:g}"


def rel(limit: float, *, per_op: bool = False, min_count: float = 0.0) -> Tolerance:
    return Tolerance("rel", limit=limit, per_op=per_op, min_count=min_count)


def ratio(lo: float, hi: float, *, min_count: float = 0.0) -> Tolerance:
    return Tolerance("ratio", lo=lo, hi=hi, min_count=min_count)


def absolute(limit: float, *, min_count: float = 0.0) -> Tolerance:
    return Tolerance("abs", limit=limit, min_count=min_count)


@dataclasses.dataclass(frozen=True)
class DriftEntry:
    name: str
    mesh: float
    sim: float
    tolerance: Tolerance
    measured: float  # the quantity the tolerance bounds (rel err / ratio / gap)
    ok: bool
    skipped: bool = False

    def format(self) -> str:
        status = "SKIP" if self.skipped else ("ok  " if self.ok else "DRIFT")
        return (
            f"  [{status}] {self.name:<24} mesh={self.mesh:>14.6g} "
            f"sim={self.sim:>14.6g}  {self.tolerance.describe():<20} "
            f"measured={self.measured:.4g}"
        )


@dataclasses.dataclass(frozen=True)
class DriftReport:
    label: str
    entries: List[DriftEntry]

    @property
    def ok(self) -> bool:
        return all(e.ok or e.skipped for e in self.entries)

    @property
    def failures(self) -> List[DriftEntry]:
        return [e for e in self.entries if not e.ok and not e.skipped]

    def format(self) -> str:
        head = f"plane agreement [{self.label}]: " + (
            "OK" if self.ok else f"{len(self.failures)} metric(s) out of tolerance"
        )
        return "\n".join([head] + [e.format() for e in self.entries])


class PlaneDriftError(AssertionError):
    def __init__(self, report: DriftReport):
        super().__init__(report.format())
        self.report = report


def _named(values: Any) -> Mapping[str, float]:
    """Coerce any supported counter carrier into a name -> value mapping."""
    if values is None:
        return {}
    if hasattr(values, "counter_totals"):  # BatchTimeline
        return values.counter_totals()
    if isinstance(values, registry.Snapshot):
        return values.as_dict()
    if isinstance(values, Mapping):
        return values
    if hasattr(values, "stats"):  # a DexState — snapshot it
        return registry.snapshot(values).as_dict()
    if any(hasattr(values, f) for f in registry.SIM_FIELDS):  # sim Counters
        return registry.sim_view(values)
    raise TypeError(f"cannot read counters from {type(values).__name__}")


def compare(
    mesh: Any,
    sim: Any,
    tolerances: Mapping[str, Tolerance],
    *,
    label: str = "",
) -> DriftReport:
    """Build the drift report without raising; see module docstring."""
    mesh_named = _named(mesh)
    sim_named = _named(sim)
    mesh_ops = float(mesh_named.get("ops", 0.0))
    sim_ops = float(sim_named.get("ops", 0.0))

    entries: List[DriftEntry] = []
    for name, tol in tolerances.items():
        if name not in registry.BY_NAME:
            raise KeyError(f"unregistered metric {name!r} in tolerances")
        m = float(mesh_named.get(name, 0.0))
        s = float(sim_named.get(name, 0.0))
        if max(abs(m), abs(s)) < tol.min_count:
            entries.append(DriftEntry(name, m, s, tol, 0.0, ok=True, skipped=True))
            continue
        mv, sv = m, s
        if tol.per_op:
            mv = m / mesh_ops if mesh_ops else 0.0
            sv = s / sim_ops if sim_ops else 0.0
        if tol.kind == "rel":
            measured = abs(mv - sv) / max(abs(sv), _EPS)
            ok = measured <= tol.limit
        elif tol.kind == "ratio":
            measured = mv / max(sv, _EPS)
            ok = tol.lo <= measured <= tol.hi
        else:  # abs
            measured = abs(mv - sv)
            ok = measured <= tol.limit
        entries.append(DriftEntry(name, m, s, tol, measured, ok=ok))
    return DriftReport(label=label, entries=entries)


def assert_plane_agreement(
    mesh: Any,
    sim: Any,
    tolerances: Mapping[str, Tolerance],
    *,
    label: str = "",
    verbose: bool = True,
) -> DriftReport:
    """Compare mesh vs sim counters; print the report, raise on drift."""
    report = compare(mesh, sim, tolerances, label=label)
    if verbose:
        print(report.format())
    if not report.ok:
        raise PlaneDriftError(report)
    return report
