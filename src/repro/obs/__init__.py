"""Unified telemetry plane shared by the mesh engine (Plane B), the event
simulator (Plane A) and the benchmark driver.

  * :mod:`repro.obs.registry` — ONE declarative metric schema: every mesh
    ``STAT_*`` counter slot and every simulator ``Counters`` field is
    declared here exactly once, with unit, kind, cross-plane mapping and
    paper-figure provenance.  ``core/dex.py`` derives its ``STAT_*``
    indices and ``N_STATS`` from it, so adding a counter can never
    silently alias an old slot.
  * :mod:`repro.obs.timeline` — per-batch phase-segmented wall-time
    instrumentation (``BatchTimeline``) wrapped around the mesh programs,
    with ``block_until_ready`` fencing and counter deltas piggybacked on
    the engine's existing psums (zero added collectives).
  * :mod:`repro.obs.trace` — Chrome trace-event JSON export of a timeline
    (viewable in Perfetto / chrome://tracing) plus the optional
    ``jax.profiler`` annotation hook.
  * :mod:`repro.obs.drift` — the mesh-vs-sim counter comparison
    (``assert_plane_agreement``) with per-metric tolerances and a readable
    drift report, replacing the ad-hoc checks the mesh benchmarks used to
    hand-roll.

Import surface is kept light: only the registry (pure numpy) loads here;
timeline/trace/drift import jax lazily so Plane-A-only users never pay
for it.
"""

from repro.obs import registry  # noqa: F401  (the always-safe core)

__all__ = ["registry"]
