"""Per-batch phase-segmented wall-time instrumentation for the mesh plane.

The mesh engine is ONE jitted ``shard_map`` program — its internal phases
(route, descent, fused all_to_all, apply) cannot be host-fenced without
splitting the program and destroying the fusion the benchmarks exist to
measure.  So the timeline works at two resolutions:

* **Host phases** — whole dispatches the driver already separates (engine
  call, shed-lane retry rounds, SMO settlement rounds, repartition install,
  scan probes).  Each is fenced with ``jax.block_until_ready`` on the FULL
  result tree, so async dispatch cannot leak work past the timer.
* **Device counters** — after each batch's fence we copy the ``[Dev,
  N_STATS]`` stats array to host and diff it against the previous batch
  (:func:`repro.obs.registry.delta`).  The counters are maintained by the
  engine's existing psums; reading them adds a host transfer, never a
  collective.  ``fig13engine`` proves this with trace-time collective
  counts (instrumented == bare).

Inside the jitted program, ``jax.named_scope`` annotations (added in
``core/engine.py``) label the phases for ``jax.profiler`` traces; they are
metadata only and cost nothing at run time.

Shed-lane retry latency is tracked per op class as *batches to completion*:
``record_retry("insert", rounds)`` after a retry loop.

The modeled-latency ledger (DESIGN.md §12) rides the same measure fences:
``prime_latency(state)`` after warmup snapshots the device histogram plane
(``DexState.lat_hist`` / ``lat_audit``, or a simulator's ``lat_hist``), and
``capture_latency(state)`` at the end of the measured window stores the
delta — ``summary()`` then carries a ``"latency"`` section (bucket schema,
counts, percentiles, per-path ledger) and, when the audit plane is present,
a ``"cost_audit"`` section (obs/latency.audit_report).
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.obs import latency, registry


def _latency_arrays(state_or_hist: Any) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """Fleet-summed ``[classes, paths, buckets]`` histogram plus the optional
    ``[2, n_memory, levels]`` audit plane, from a ``DexState`` (mesh: sums the
    device axis), a ``Simulator`` (already fleet-shaped), or a raw array."""
    hist = getattr(state_or_hist, "lat_hist", state_or_hist)
    hist = np.asarray(hist)
    if hist.ndim == 4:
        hist = hist.sum(axis=0)
    audit = getattr(state_or_hist, "lat_audit", None)
    if audit is not None:
        audit = np.asarray(audit, dtype=np.float64).sum(axis=0)
    return hist.astype(np.int64), audit


def fence(tree: Any) -> Any:
    """Block until every array in ``tree`` is ready; returns ``tree``."""
    import jax

    jax.block_until_ready(tree)
    return tree


def timed_call(fn: Callable, *args, **kwargs) -> Tuple[Any, float]:
    """Run ``fn`` and fence its FULL result tree; returns ``(result, secs)``."""
    t0 = time.perf_counter()
    out = fence(fn(*args, **kwargs))
    return out, time.perf_counter() - t0


@dataclasses.dataclass
class PhaseSpan:
    name: str
    t0: float  # seconds since the timeline epoch
    dur: float  # seconds


@dataclasses.dataclass
class BatchRecord:
    index: int
    label: str  # op class / workload label for this batch
    t0: float
    dur: float
    phases: List[PhaseSpan] = dataclasses.field(default_factory=list)
    #: per-batch counter increments (named; per-device + fleet)
    counters: Optional[registry.Snapshot] = None
    #: op class -> shed-lane rounds-to-completion observed this batch
    retries: Dict[str, int] = dataclasses.field(default_factory=dict)

    def phase_seconds(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for p in self.phases:
            out[p.name] = out.get(p.name, 0.0) + p.dur
        return out


class _Phase:
    """Context manager for one fenced phase inside a batch."""

    def __init__(self, batch: "_Batch", name: str):
        self._batch = batch
        self._name = name
        self._pending: Any = None

    def fence(self, tree: Any) -> Any:
        """Register ``tree`` to be fenced when the phase closes (and fence it
        now if the phase is being timed eagerly).  Returns ``tree``."""
        self._pending = tree
        return tree

    def __enter__(self) -> "_Phase":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None and self._pending is not None:
            fence(self._pending)
        dur = time.perf_counter() - self._t0
        if exc_type is None:
            self._batch.record.phases.append(
                PhaseSpan(self._name, self._t0 - self._batch.timeline.epoch, dur)
            )


class _Batch:
    """Context manager for one batch; hands out phases and counter capture."""

    def __init__(self, timeline: "BatchTimeline", label: str):
        self.timeline = timeline
        self.record = BatchRecord(
            index=len(timeline.batches), label=label, t0=0.0, dur=0.0
        )

    def phase(self, name: str) -> _Phase:
        return _Phase(self, name)

    def counters(self, state_or_stats: Any) -> registry.Snapshot:
        """Capture this batch's counter delta from a fenced ``DexState`` (or
        raw stats array).  Uses the timeline's running snapshot so repeated
        captures across batches yield per-batch increments.
        """
        snap = registry.snapshot(state_or_stats)
        prev = self.timeline._last_snap
        self.record.counters = registry.delta(snap, prev) if prev else snap
        self.timeline._last_snap = snap
        return self.record.counters

    def retry(self, op_class: str, rounds: int) -> None:
        self.record.retries[op_class] = int(rounds)

    # -- pipelined (cross-step) recording ---------------------------------
    # A pipelined batch's lifetime spans two engine steps (front half in
    # step s, back half in step s+1), so it cannot be a ``with`` block
    # around one dispatch: open it at push time, attach externally measured
    # spans, close it when its result lands.

    def open(self) -> "_Batch":
        """Begin the batch without a ``with`` block (see ``close``)."""
        self._t0 = time.perf_counter()
        self.record.t0 = self._t0 - self.timeline.epoch
        return self

    def add_span(self, name: str, t0: float, dur: float) -> None:
        """Attach a phase span measured externally — ``t0`` is an absolute
        ``time.perf_counter()`` stamp (it may predate ``open``; overlap
        windows legitimately interleave batches)."""
        self.record.phases.append(
            PhaseSpan(name, t0 - self.timeline.epoch, dur)
        )

    def close(self) -> BatchRecord:
        """Finalize an ``open``\\ ed batch and append it to the timeline."""
        self.record.dur = time.perf_counter() - self._t0
        self.timeline.batches.append(self.record)
        return self.record

    def __enter__(self) -> "_Batch":
        return self.open()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.record.dur = time.perf_counter() - self._t0
        if exc_type is None:
            self.timeline.batches.append(self.record)


class BatchTimeline:
    """Accumulates per-batch :class:`BatchRecord`\\ s for one benchmark run."""

    def __init__(self, name: str, meta: Optional[Mapping[str, Any]] = None):
        self.name = name
        self.meta: Dict[str, Any] = dict(meta or {})
        self.epoch = time.perf_counter()
        self.batches: List[BatchRecord] = []
        self._last_snap: Optional[registry.Snapshot] = None
        self._lat_base: Optional[Tuple[np.ndarray, Optional[np.ndarray]]] = None
        self._lat: Optional[Tuple[np.ndarray, Optional[np.ndarray]]] = None

    # -- recording --------------------------------------------------------

    def batch(self, label: str = "batch") -> _Batch:
        return _Batch(self, label)

    def open_batch(self, label: str = "batch") -> _Batch:
        """A batch whose lifetime the caller manages explicitly (pipelined
        execution: front and back halves land in different engine steps).
        Call ``close()`` on the returned batch to record it."""
        return _Batch(self, label).open()

    def prime(self, state_or_stats: Any) -> None:
        """Set the counter baseline (e.g. after warmup) so the first measured
        batch reports increments, not lifetime totals."""
        self._last_snap = registry.snapshot(state_or_stats)

    def prime_latency(self, state_or_hist: Any) -> None:
        """Latency-ledger analogue of :meth:`prime`: snapshot the histogram
        (and audit) plane at the measure fence so :meth:`capture_latency`
        reports the measured window only."""
        self._lat_base = _latency_arrays(state_or_hist)

    def capture_latency(self, state_or_hist: Any) -> np.ndarray:
        """Store the histogram/audit delta since :meth:`prime_latency` (or
        lifetime totals when never primed); returns the fleet-summed
        ``[classes, paths, buckets]`` histogram it recorded."""
        hist, audit = _latency_arrays(state_or_hist)
        if self._lat_base is not None:
            base_h, base_a = self._lat_base
            hist = hist - base_h
            if audit is not None and base_a is not None:
                audit = audit - base_a
        self._lat = (hist, audit)
        return hist

    def latency_arrays(self) -> Optional[Tuple[np.ndarray, Optional[np.ndarray]]]:
        """The captured ``(hist, audit)`` pair, or None before
        :meth:`capture_latency` ran (used by obs/trace.py counter tracks)."""
        return self._lat

    def instrument(
        self, engine: Callable, *, label: str = "engine"
    ) -> Callable:
        """Wrap a mesh engine (or any dispatch whose first result is a
        ``DexState``): every call becomes one recorded batch with a single
        fenced phase plus a counter-delta capture.  The wrapper is a plain
        host-side shim around the already-jitted callable — it cannot change
        the traced program, so collective counts are identical by
        construction (fig13engine asserts this anyway).
        """

        def wrapped(*args, **kwargs):
            with self.batch(label) as b:
                with b.phase(label) as ph:
                    out = engine(*args, **kwargs)
                    ph.fence(out)
                head = out[0] if isinstance(out, tuple) else out
                if hasattr(head, "stats"):
                    b.counters(head)
            return out

        if hasattr(engine, "plan"):
            wrapped.plan = engine.plan  # type: ignore[attr-defined]
        return wrapped

    # -- aggregation ------------------------------------------------------

    def phase_totals(self) -> Dict[str, Dict[str, float]]:
        acc: Dict[str, List[float]] = {}
        for rec in self.batches:
            for name, secs in rec.phase_seconds().items():
                acc.setdefault(name, []).append(secs)
        return {
            name: {
                "count": len(vals),
                "total_s": sum(vals),
                "mean_s": sum(vals) / len(vals),
                "max_s": max(vals),
            }
            for name, vals in acc.items()
        }

    def counter_totals(self) -> Dict[str, float]:
        fleet: Dict[str, int] = {}
        for rec in self.batches:
            if rec.counters is None:
                continue
            for name, val in rec.counters.fleet.items():
                fleet[name] = fleet.get(name, 0) + val
        named: Dict[str, float] = dict(fleet)
        for m in registry.METRICS:
            if m.kind == "derived":
                named[m.name] = float(m.compute(fleet))
        return named

    def retry_latency(self) -> Dict[str, Dict[str, float]]:
        """Shed-lane batches-to-completion per op class."""
        acc: Dict[str, List[int]] = {}
        for rec in self.batches:
            for opc, rounds in rec.retries.items():
                acc.setdefault(opc, []).append(rounds)
        return {
            opc: {
                "count": len(vals),
                "mean_rounds": sum(vals) / len(vals),
                "max_rounds": max(vals),
            }
            for opc, vals in acc.items()
        }

    def summary(self) -> Dict[str, Any]:
        out = {
            "name": self.name,
            "meta": self.meta,
            "n_batches": len(self.batches),
            "wall_s": sum(r.dur for r in self.batches),
            "phases": self.phase_totals(),
            "counters": self.counter_totals(),
            "retry_latency": self.retry_latency(),
        }
        if self._lat is not None:
            hist, audit = self._lat
            out["latency"] = latency.latency_section(hist)
            if audit is not None:
                out["cost_audit"] = latency.audit_report(audit[0], audit[1])
        return out

    def to_json(self) -> Dict[str, Any]:
        """JSON-serialisable dump (``metrics_timeline.json`` payload)."""
        return {
            **self.summary(),
            "batches": [
                {
                    "index": r.index,
                    "label": r.label,
                    "t0_s": r.t0,
                    "dur_s": r.dur,
                    "phases": [
                        {"name": p.name, "t0_s": p.t0, "dur_s": p.dur}
                        for p in r.phases
                    ],
                    "counters": (
                        r.counters.as_dict() if r.counters is not None else None
                    ),
                    "retries": r.retries,
                }
                for r in self.batches
            ],
        }


def obs_phase(obs: Optional[Any], name: str):
    """Phase hook used by core/smo.py and core/repartition.py: ``obs`` is a
    :class:`_Batch` (or anything with ``.phase``), or None for a no-op."""
    if obs is None:
        return contextlib.nullcontext()
    return obs.phase(name)
