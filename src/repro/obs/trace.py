"""Chrome trace-event export for :class:`repro.obs.timeline.BatchTimeline`.

Emits the JSON-object flavour of the Trace Event Format (``{"traceEvents":
[...]}``) viewable in Perfetto (ui.perfetto.dev) or chrome://tracing:

* pid 0, one tid per phase name — "X" (complete) events for every fenced
  host phase, batch-level "X" events on tid 0.
* one pid per mesh device — "C" (counter) tracks for per-batch hit rate,
  drops and ops, sampled at each batch's start time.
* fleet-level "C" tracks (hit_rate, drops_per_op, offload_fraction) on the
  host process.
* when the timeline captured the latency ledger (DESIGN.md §12), one
  session-level "C" sample per percentile gauge (``lat_p50_lookup`` ...)
  plus ``offload_mispricing``, stamped at the end of the last batch (ts 0
  on an empty timeline).
* "M" metadata events naming every process/thread.

Timestamps are microseconds from the timeline epoch, as the format requires.

Also provides :func:`profiler_annotations`, the optional ``jax.profiler``
hook: a context manager that opens a ``TraceAnnotation`` so the engine's
``jax.named_scope`` phase labels land in a profiler trace alongside the
host-side batches.
"""

from __future__ import annotations

import contextlib
import json
from typing import Any, Dict, List, Optional

from repro.obs import latency
from repro.obs.timeline import BatchTimeline

_US = 1e6  # trace-event timestamps are microseconds

#: per-device counter tracks emitted for each batch
_DEVICE_COUNTERS = ("ops", "hits", "drops")
#: fleet-level derived counter tracks
_FLEET_COUNTERS = ("hit_rate", "drops_per_op", "offload_fraction")

_HOST_PID = 0
_BATCH_TID = 0


def to_trace_events(timeline: BatchTimeline) -> Dict[str, Any]:
    """Render a timeline as a Chrome trace-event JSON object."""
    events: List[Dict[str, Any]] = []

    def meta(pid: int, tid: int, name: str, what: str = "thread_name") -> None:
        events.append(
            {
                "ph": "M",
                "name": what,
                "pid": pid,
                "tid": tid,
                "args": {"name": name},
            }
        )

    meta(_HOST_PID, 0, f"host:{timeline.name}", "process_name")
    meta(_HOST_PID, _BATCH_TID, "batches")

    # one tid per distinct phase name, stable order of first appearance
    phase_tids: Dict[str, int] = {}
    for rec in timeline.batches:
        for span in rec.phases:
            if span.name not in phase_tids:
                tid = len(phase_tids) + 1
                phase_tids[span.name] = tid
                meta(_HOST_PID, tid, f"phase:{span.name}")

    n_dev = 0
    for rec in timeline.batches:
        if rec.counters is not None:
            n_dev = max(n_dev, rec.counters.n_devices)
    for d in range(n_dev):
        meta(d + 1, 0, f"device {d}", "process_name")
        meta(d + 1, 0, "counters")

    for rec in timeline.batches:
        ts = rec.t0 * _US
        events.append(
            {
                "ph": "X",
                "name": f"batch[{rec.index}] {rec.label}",
                "cat": "batch",
                "pid": _HOST_PID,
                "tid": _BATCH_TID,
                "ts": ts,
                "dur": rec.dur * _US,
                "args": {
                    "label": rec.label,
                    **({"retries": rec.retries} if rec.retries else {}),
                },
            }
        )
        for span in rec.phases:
            events.append(
                {
                    "ph": "X",
                    "name": span.name,
                    "cat": "phase",
                    "pid": _HOST_PID,
                    "tid": phase_tids[span.name],
                    "ts": span.t0 * _US,
                    "dur": span.dur * _US,
                    "args": {"batch": rec.index},
                }
            )
        if rec.counters is None:
            continue
        for name in _FLEET_COUNTERS:
            events.append(
                {
                    "ph": "C",
                    "name": name,
                    "cat": "fleet",
                    "pid": _HOST_PID,
                    "tid": 0,
                    "ts": ts,
                    "args": {name: float(rec.counters.derived[name])},
                }
            )
        for d in range(rec.counters.n_devices):
            for name in _DEVICE_COUNTERS:
                events.append(
                    {
                        "ph": "C",
                        "name": name,
                        "cat": "device",
                        "pid": d + 1,
                        "tid": 0,
                        "ts": ts,
                        "args": {name: int(rec.counters.per_device[name][d])},
                    }
                )

    lat = timeline.latency_arrays() if hasattr(timeline, "latency_arrays") else None
    if lat is not None:
        hist, audit = lat
        ts_end = max((r.t0 + r.dur for r in timeline.batches), default=0.0) * _US
        gauges: Dict[str, float] = dict(latency.percentile_gauges(hist))
        if audit is not None:
            rep = latency.audit_report(audit[0], audit[1])
            gauges["offload_mispricing"] = float(rep["mispricing_ratio"])
        for name, val in gauges.items():
            events.append(
                {
                    "ph": "C",
                    "name": name,
                    "cat": "latency",
                    "pid": _HOST_PID,
                    "tid": 0,
                    "ts": ts_end,
                    "args": {name: float(val)},
                }
            )

    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "timeline": timeline.name,
            **{str(k): str(v) for k, v in timeline.meta.items()},
        },
    }


def write_trace(timeline: BatchTimeline, path: str) -> str:
    """Write the Perfetto-viewable trace JSON to ``path``; returns ``path``."""
    with open(path, "w") as f:
        json.dump(to_trace_events(timeline), f)
    return path


@contextlib.contextmanager
def profiler_annotations(label: str, enabled: bool = True):
    """Optional ``jax.profiler`` hook: annotate the enclosed dispatches so
    the engine's ``jax.named_scope`` phase labels show up under ``label`` in
    a profiler trace.  No-op (and jax-import-free) when disabled or when the
    profiler API is unavailable.
    """
    if not enabled:
        yield
        return
    try:
        import jax.profiler as _prof

        ctx = _prof.TraceAnnotation(label)
    except Exception:  # pragma: no cover - profiler backend missing
        yield
        return
    with ctx:
        yield
