"""Declarative metric registry — the single source of truth for counters.

Every mesh ``STAT_*`` slot in :mod:`repro.core.dex`, every simulator
``Counters`` field in :mod:`repro.core.sim`, and every derived figure-level
metric is declared here exactly once as a :class:`Metric`.  ``core/dex.py``
derives its ``STAT_*`` constants and ``N_STATS`` from :data:`MESH_SLOTS`, so
adding a counter appends a slot; it can never silently alias an old one.

The registry is deliberately dependency-light: it imports numpy only.  Any
helper that needs jax / dex / sim defers the import to function scope, so
``repro.obs.registry`` is safe to import from anywhere (including from
``core/dex.py`` itself — that is the point).

Cross-plane mapping
-------------------
A metric with both ``slot`` (mesh) and ``sim_field`` (simulator) set is
*paired*: the mesh counter and the simulator counter measure the same
physical event under the paper's cost model and may be compared by
``repro.obs.drift``.  Mesh-only metrics (``sim_field=None``) are artifacts
of the SPMD execution strategy (drops, splits-pending, drains); sim-only
metrics (``slot=None``) are costs the mesh plane absorbs into its
collectives (bytes, CAS, coherence) and cannot observe per-event.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping, Optional, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------

#: kinds: "counter" = monotone int64 event count; "derived" = computed from
#: counters at snapshot time (float); "gauge" = a figure-level quantity both
#: planes report directly (not a stats slot), registered so drift checks
#: share the counter namespace.
KINDS = ("counter", "derived", "gauge")


@dataclasses.dataclass(frozen=True)
class Metric:
    """One named metric.

    Attributes
    ----------
    name:        registry key, e.g. ``"fetches"``.
    unit:        human unit: "events", "ops", "rows", "bytes", "ratio", ...
    kind:        "counter" or "derived".
    slot:        mesh ``DexState.stats`` column index, or None if the mesh
                 plane does not track it.
    stat_const:  name of the ``STAT_*`` constant exported by ``core/dex.py``
                 for this slot (None for sim-only / derived metrics).
    sim_field:   field name on ``repro.core.sim.Counters``, or None if the
                 simulator does not track it.
    provenance:  which paper figure / table this metric reproduces.
    doc:         one-line description (also feeds the DESIGN.md table).
    compute:     for derived metrics: ``f(named_counters) -> float`` where
                 ``named_counters`` maps counter names to scalars.
    """

    name: str
    unit: str
    kind: str
    slot: Optional[int] = None
    stat_const: Optional[str] = None
    sim_field: Optional[str] = None
    provenance: str = ""
    doc: str = ""
    compute: Optional[Callable[[Mapping[str, float]], float]] = None

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"metric {self.name!r}: bad kind {self.kind!r}")
        if self.kind == "derived" and self.compute is None:
            raise ValueError(f"derived metric {self.name!r} needs compute=")
        if self.kind == "counter" and self.slot is None and self.sim_field is None:
            raise ValueError(f"counter {self.name!r} maps to neither plane")


def _ratio(num: str, den: str) -> Callable[[Mapping[str, float]], float]:
    def f(c: Mapping[str, float]) -> float:
        d = float(c.get(den, 0.0))
        return float(c.get(num, 0.0)) / d if d else 0.0

    return f


# ---------------------------------------------------------------------------
# The registry proper.
#
# MESH order is load-bearing: the tuple index IS the ``DexState.stats``
# column.  Append only; never reorder (checkpointed states index by slot).
# ---------------------------------------------------------------------------

_MESH = (
    Metric("ops", "ops", "counter", slot=0, stat_const="STAT_OPS",
           sim_field="ops", provenance="Fig. 8/13 (throughput denominators)",
           doc="operations admitted to the engine on this device"),
    Metric("hits", "events", "counter", slot=1, stat_const="STAT_HITS",
           sim_field="local_accesses", provenance="Fig. 11 (cache hit rate)",
           doc="descents resolved from the local cache, no remote read"),
    Metric("fetches", "events", "counter", slot=2, stat_const="STAT_FETCHES",
           sim_field="rdma_read", provenance="Table 2 / Fig. 8 (RDMA READ)",
           doc="remote row fetches (one-sided READ equivalent)"),
    Metric("offloads", "events", "counter", slot=3, stat_const="STAT_OFFLOADS",
           sim_field="two_sided", provenance="Fig. 12 (offload ratio)",
           doc="ops shipped to the owning memory column (two-sided RPC)"),
    Metric("drops", "events", "counter", slot=4, stat_const="STAT_DROPS",
           sim_field=None, provenance="shed-lane admission (mesh-only)",
           doc="ops shed to the retry lane this batch (re-admitted later)"),
    Metric("splits", "events", "counter", slot=5, stat_const="STAT_SPLITS",
           sim_field=None, provenance="§5 SMO (mesh-only)",
           doc="leaf splits requested and still pending settlement"),
    Metric("writes", "events", "counter", slot=6, stat_const="STAT_WRITES",
           sim_field="rdma_write", provenance="Table 2 (RDMA WRITE)",
           doc="write-through row updates (one-sided WRITE equivalent)"),
    Metric("smo_splits", "events", "counter", slot=7, stat_const="STAT_SMO_SPLITS",
           sim_field="smo_inserts", provenance="Fig. 10 (SMO volume)",
           doc="leaf splits settled by the on-mesh SMO engine"),
    Metric("drains", "events", "counter", slot=8, stat_const="STAT_DRAINS",
           sim_field=None, provenance="§5 SMO drain path (mesh-only)",
           doc="shed ops drained host-side instead of split on-mesh"),
    Metric("offload_groups", "groups", "counter", slot=9,
           stat_const="STAT_OFFLOAD_GROUPS", sim_field="offload_groups",
           provenance="Fig. 12 (grouped offload)",
           doc="contiguous same-leaf op groups coalesced into one offload"),
    Metric("fetch_groups", "groups", "counter", slot=10,
           stat_const="STAT_FETCH_GROUPS", sim_field="fetch_groups",
           provenance="Fig. 12 (grouped fetch)",
           doc="contiguous same-leaf op groups coalesced into one fetch"),
    Metric("pipeline_stalls", "events", "counter", slot=11,
           stat_const="STAT_PIPE_STALLS", sim_field="pipeline_stalls",
           provenance="§7 coherence under the pipelined overlap window",
           doc="lanes whose leaf version moved inside the overlap window: "
               "lookups/updates stale-forced two-sided, scans stall-shed "
               "(always 0 in batch-synchronous mode)"),
    Metric("peer_hits", "events", "counter", slot=12,
           stat_const="STAT_PEER_HITS", sim_field="peer_hits",
           provenance="§5.4 cooperative fleet caching (extend-dist, FlexKV)",
           doc="peer peeks answered from a sibling chip's version-fresh "
               "cached row (no memory-column walk needed)"),
    Metric("peer_misses", "events", "counter", slot=13,
           stat_const="STAT_PEER_MISSES", sim_field="peer_misses",
           provenance="§5.4 cooperative fleet caching (extend-dist, FlexKV)",
           doc="peer peeks the sibling could not serve from cache (stale or "
               "absent row); resolved by the owning column's block walk"),
    Metric("rt_skips", "events", "counter", slot=14,
           stat_const="STAT_RT_SKIPS", sim_field="rt_skips",
           provenance="§1 / Outback compute-side location resolution "
               "(leaf-direct route table, DESIGN.md §13)",
           doc="inner-level fetch rounds skipped by lanes whose leaf-direct "
               "route-table guess the version fence accepted"),
    Metric("rt_mispredicts", "events", "counter", slot=15,
           stat_const="STAT_RT_MISPREDICTS", sim_field="rt_mispredicts",
           provenance="§1 / Outback compute-side location resolution "
               "(leaf-direct route table, DESIGN.md §13)",
           doc="route-table guesses rejected by the fence-key bounds or the "
               "leaf version fence; the lane fell back to full cached descent"),
)

_SIM_ONLY = (
    Metric("rdma_small_read", "events", "counter", sim_field="rdma_small_read",
           provenance="Table 2 (small READ)",
           doc="sub-row one-sided reads (version probes, fence words)"),
    Metric("rdma_cas", "events", "counter", sim_field="rdma_cas",
           provenance="Table 2 (RDMA CAS)",
           doc="compare-and-swap ops (lock/version acquisition)"),
    Metric("bytes", "bytes", "counter", sim_field="bytes",
           provenance="Fig. 9 (network volume)",
           doc="total bytes moved over the fabric under the cost model"),
    Metric("offload_fallbacks", "events", "counter",
           sim_field="offload_fallbacks", provenance="Fig. 12",
           doc="offloads that fell back to one-sided reads (queue full)"),
    Metric("coherence_invalidations", "events", "counter",
           sim_field="coherence_invalidations", provenance="§4.3 coherence",
           doc="cache entries invalidated by remote writers"),
    Metric("refresh_from_root", "events", "counter",
           sim_field="refresh_from_root", provenance="§4.3 coherence",
           doc="full descents forced by a stale root after an SMO"),
)

_DERIVED = (
    Metric("hit_rate", "ratio", "derived", provenance="Fig. 11",
           doc="hits / ops — fraction of descents served from cache",
           compute=_ratio("hits", "ops")),
    Metric("drops_per_op", "ratio", "derived", provenance="shed-lane health",
           doc="drops / ops — shed-lane pressure per admitted op",
           compute=_ratio("drops", "ops")),
    Metric("offload_fraction", "ratio", "derived", provenance="Fig. 12",
           doc="offloads / ops — fraction of ops shipped to memory columns",
           compute=_ratio("offloads", "ops")),
    Metric("bytes_per_op", "bytes/op", "derived", provenance="Fig. 9",
           doc="bytes / ops — fabric volume per operation (sim plane)",
           compute=_ratio("bytes", "ops")),
    Metric("remote_reads_per_op", "reads/op", "derived",
           provenance="§1 (fewer remote accesses win) / Table 2",
           doc="fetches / ops — coalesced remote row reads per admitted op; "
               "paired cross-plane (mesh fetches vs sim rdma_read), gated by "
               "obs/drift in benchmarks/fig20_leaf_direct.py",
           compute=_ratio("fetches", "ops")),
)


def _latency_gauges() -> Tuple[Metric, ...]:
    """Per-op-class latency percentile gauges (DESIGN.md §12).  Both planes
    estimate them from the shared bucket schema in ``repro.obs.latency``
    (mesh: ``DexState.lat_hist``; sim: ``Simulator.lat_hist``), so drift
    checks can gate p50/p99 per op class like any paired counter."""
    out = []
    for cls in ("lookup", "update", "insert", "scan"):
        for q in (50, 99):
            out.append(Metric(
                f"lat_p{q}_{cls}", "seconds", "gauge",
                provenance="§6 latency breakdown / Outback per-op rounds",
                doc=f"modeled p{q} {cls} latency from the shared log-bucket "
                    "histogram (geometric bucket midpoint)",
            ))
    return tuple(out)


_GAUGES = (
    Metric("moved_fraction", "fraction", "gauge",
           provenance="Fig. 10 / §4 (live repartition)",
           doc="fraction of dataset keys whose owner a boundary install "
               "moved (both planes compute it from their own tables)"),
) + _latency_gauges() + (
    Metric("offload_mispricing", "ratio", "gauge",
           provenance="§6.1 offload cost rule (audited)",
           doc="predicted / realized fetch bytes over the offload decision's "
               "fetch-side cells (obs/latency.py audit_report)"),
)

METRICS: Tuple[Metric, ...] = _MESH + _SIM_ONLY + _DERIVED + _GAUGES

BY_NAME: Dict[str, Metric] = {m.name: m for m in METRICS}
if len(BY_NAME) != len(METRICS):  # pragma: no cover - registry authoring bug
    raise RuntimeError("duplicate metric name in registry")

#: Mesh counter slots in DexState.stats column order.
MESH_SLOTS: Tuple[Metric, ...] = tuple(sorted(_MESH, key=lambda m: m.slot))
for _i, _m in enumerate(MESH_SLOTS):  # pragma: no cover - authoring bug
    if _m.slot != _i:
        raise RuntimeError(f"mesh slots not dense at {_m.name!r}")

#: Width of the DexState.stats counter row — core/dex.py derives from this.
N_STATS: int = len(MESH_SLOTS)

#: name -> slot for the mesh plane.
SLOT_OF: Dict[str, int] = {m.name: m.slot for m in MESH_SLOTS}

#: Counter metrics tracked by the simulator, in Counters field order terms.
SIM_FIELDS: Dict[str, Metric] = {
    m.sim_field: m for m in METRICS if m.sim_field is not None
}

#: Paired metrics — present on both planes, comparable by obs.drift.
PAIRED: Tuple[Metric, ...] = tuple(
    m for m in MESH_SLOTS if m.sim_field is not None
)


def stat_constants() -> Dict[str, int]:
    """``{"STAT_OPS": 0, ...}`` — consumed by ``core/dex.py`` at import."""
    return {m.stat_const: m.slot for m in MESH_SLOTS}


# ---------------------------------------------------------------------------
# Named views over raw counter arrays
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """A named view over one ``DexState.stats`` array ``[Dev, N_STATS]``.

    ``per_device[name]`` is an int64 ``[Dev]`` vector; ``fleet[name]`` the
    cross-device sum; ``derived[name]`` the fleet-level derived metrics.
    """

    per_device: Dict[str, np.ndarray]
    fleet: Dict[str, int]
    derived: Dict[str, float]

    @property
    def n_devices(self) -> int:
        vec = next(iter(self.per_device.values()))
        return int(vec.shape[0])

    def __getitem__(self, name: str) -> float:
        if name in self.fleet:
            return self.fleet[name]
        return self.derived[name]

    def as_dict(self) -> Dict[str, float]:
        """Flat fleet view (counters + derived) for JSON emission."""
        out: Dict[str, float] = {k: int(v) for k, v in self.fleet.items()}
        out.update({k: float(v) for k, v in self.derived.items()})
        return out


def _to_host(stats) -> np.ndarray:
    arr = np.asarray(stats)
    if arr.ndim == 1:
        arr = arr[None, :]
    if arr.ndim != 2 or arr.shape[1] != N_STATS:
        raise ValueError(
            f"stats array has shape {arr.shape}, want [Dev, {N_STATS}]"
        )
    return arr


def snapshot(state_or_stats) -> Snapshot:
    """Named snapshot of mesh counters.

    Accepts a ``DexState`` (anything with a ``.stats`` attribute) or the raw
    ``[Dev, N_STATS]`` array.  Device transfer happens here — call once per
    batch, after the fence.
    """
    stats = getattr(state_or_stats, "stats", state_or_stats)
    arr = _to_host(stats)
    per_device = {m.name: arr[:, m.slot] for m in MESH_SLOTS}
    fleet = {name: int(vec.sum()) for name, vec in per_device.items()}
    derived = {m.name: float(m.compute(fleet)) for m in _DERIVED}
    return Snapshot(per_device=per_device, fleet=fleet, derived=derived)


def delta(after: Snapshot, before: Snapshot) -> Snapshot:
    """Per-batch counter increments: ``after - before`` (derived recomputed)."""
    per_device = {
        name: after.per_device[name] - before.per_device[name]
        for name in after.per_device
    }
    fleet = {name: int(vec.sum()) for name, vec in per_device.items()}
    derived = {m.name: float(m.compute(fleet)) for m in _DERIVED}
    return Snapshot(per_device=per_device, fleet=fleet, derived=derived)


def sim_view(counters) -> Dict[str, float]:
    """Named view over a ``repro.core.sim.Counters`` (or any object carrying
    the registered sim fields).  Unrecognised fields are ignored; missing
    ones read as 0 so partial fakes work in tests.
    """
    named: Dict[str, float] = {}
    for field, metric in SIM_FIELDS.items():
        named[metric.name] = float(getattr(counters, field, 0) or 0)
    for m in _DERIVED:
        named[m.name] = float(m.compute(named))
    return named


def collectives_per_batch(fn, *args, **kwargs) -> Dict[str, int]:
    """Trace-time collective counts for one engine dispatch — delegates to
    ``routing.trace_collective_counts`` (jax.eval_shape; nothing executes).
    Deferred import keeps the registry jax-free.
    """
    from repro.core.routing import trace_collective_counts

    return trace_collective_counts(fn, *args, **kwargs)


# ---------------------------------------------------------------------------
# Docs generation — DESIGN.md §7.1 is rendered from here so it can't rot.
# ---------------------------------------------------------------------------


def markdown_table() -> str:
    """The counter table for DESIGN.md, generated from the registry."""
    lines = [
        "| name | unit | mesh slot | sim field | paper provenance | meaning |",
        "|---|---|---|---|---|---|",
    ]
    for m in MESH_SLOTS + _SIM_ONLY + _DERIVED + _GAUGES:
        slot = str(m.slot) if m.slot is not None else "—"
        sim = f"`{m.sim_field}`" if m.sim_field else "—"
        if m.kind != "counter":
            slot = m.kind
        lines.append(
            f"| `{m.name}` | {m.unit} | {slot} | {sim} | {m.provenance} | {m.doc} |"
        )
    return "\n".join(lines)
