"""repro: DEX (VLDB'24) — scalable range indexing on disaggregated memory,
re-built as a TPU-native JAX framework.

The index plane uses 64-bit keys (paper: 8-byte keys), so x64 must be on
before any tracing happens.  Model code uses explicit bf16/f32 dtypes and is
unaffected by this flag.
"""

import jax

jax.config.update("jax_enable_x64", True)

__version__ = "0.1.0"
