"""Node layout, tagged pointers, and tree-array containers for DEX.

The paper (§3 "Node Layout and Addressing") lays each B+-tree node out as a
header (lock/version, fence keys, level) followed by a key array and a child
pointer array (inner) or value array (leaf), with 1KB nodes.  Remote nodes are
addressed by 64-bit tagged pointers ``[swizzled(1) | memory-server-id(15) |
address(48)]``.

On TPU we keep the same logical layout but in structure-of-arrays form so a
whole level of a batched traversal is one gather.  ``FANOUT = 64`` keys of 8
bytes + 64 children of 8 bytes ≈ 1KB, matching the paper's node size.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Constants
# ---------------------------------------------------------------------------

#: Keys per node.  64 × 8B keys + 64 × 8B pointers ≈ the paper's 1KB nodes.
FANOUT = 64

#: Sentinel for "minus infinity" (leftmost fence / leftmost separator).
KEY_MIN = np.int64(np.iinfo(np.int64).min)

#: Sentinel for "plus infinity" (empty key slots, rightmost fence).
KEY_MAX = np.int64(np.iinfo(np.int64).max)

#: Null node id.
NULL = np.int32(-1)

#: Default leaf fill factor for bulk loading (slack for future inserts).
DEFAULT_FILL = 0.7

# Tagged-pointer layout: [swizzled(1) | server-id(15) | address(48)].
_ADDR_BITS = 48
_SERVER_BITS = 15
_ADDR_MASK = (1 << _ADDR_BITS) - 1
_SERVER_MASK = (1 << _SERVER_BITS) - 1
SWIZZLED_BIT = 1 << 63


def tag_pointer(server_id, address, swizzled=False):
    """Pack a (server, address) pair into the paper's 64-bit tagged pointer."""
    ptr = (np.uint64(server_id & _SERVER_MASK) << np.uint64(_ADDR_BITS)) | np.uint64(
        address & _ADDR_MASK
    )
    if swizzled:
        ptr |= np.uint64(SWIZZLED_BIT)
    return ptr


def untag_pointer(ptr):
    """Unpack a tagged pointer -> (swizzled, server_id, address)."""
    ptr = np.uint64(ptr)
    swizzled = bool(ptr >> np.uint64(63))
    server = int((ptr >> np.uint64(_ADDR_BITS)) & np.uint64(_SERVER_MASK))
    address = int(ptr & np.uint64(_ADDR_MASK))
    return swizzled, server, address


# ---------------------------------------------------------------------------
# Tree arrays (device-friendly structure-of-arrays)
# ---------------------------------------------------------------------------


class TreeArrays(NamedTuple):
    """A B+-tree as a pytree of flat arrays.

    Semantics:
      * ``keys[n, i]`` is the smallest key reachable through slot ``i``
        ("separator = subtree min" convention); empty slots hold KEY_MAX and
        the leftmost slot of the leftmost node per level holds KEY_MIN.
      * Inner nodes: ``children[n, i]`` is a node id.  Leaves: ``values[n, i]``
        is the payload for ``keys[n, i]`` (exact-match semantics).
      * Headers mirror the paper: version (optimistic lock word), fence keys
        (``fence_lo <= k < fence_hi``) and level (0 = leaf).
    """

    keys: jax.Array       # [cap, FANOUT] int64
    children: jax.Array   # [cap, FANOUT] int32 (inner only)
    values: jax.Array     # [cap, FANOUT] int64 (leaf only)
    num_keys: jax.Array   # [cap] int32
    level: jax.Array      # [cap] int32, 0 = leaf, -1 = free
    fence_lo: jax.Array   # [cap] int64
    fence_hi: jax.Array   # [cap] int64
    version: jax.Array    # [cap] int32 (even = unlocked; odd = "locked")
    root: jax.Array       # [] int32
    height: jax.Array     # [] int32 (number of levels, >= 1)
    num_nodes: jax.Array  # [] int32 (allocated prefix; free list beyond)

    @property
    def capacity(self) -> int:
        return self.keys.shape[0]


def empty_tree(capacity: int) -> TreeArrays:
    """An empty tree with room for ``capacity`` nodes."""
    return TreeArrays(
        keys=jnp.full((capacity, FANOUT), KEY_MAX, dtype=jnp.int64),
        children=jnp.full((capacity, FANOUT), NULL, dtype=jnp.int32),
        values=jnp.zeros((capacity, FANOUT), dtype=jnp.int64),
        num_keys=jnp.zeros((capacity,), dtype=jnp.int32),
        level=jnp.full((capacity,), -1, dtype=jnp.int32),
        fence_lo=jnp.full((capacity,), KEY_MIN, dtype=jnp.int64),
        fence_hi=jnp.full((capacity,), KEY_MAX, dtype=jnp.int64),
        version=jnp.zeros((capacity,), dtype=jnp.int32),
        root=jnp.asarray(NULL, dtype=jnp.int32),
        height=jnp.asarray(0, dtype=jnp.int32),
        num_nodes=jnp.asarray(0, dtype=jnp.int32),
    )


@dataclasses.dataclass(frozen=True)
class TreeMeta:
    """Static (trace-time) facts about a tree build."""

    height: int
    num_nodes: int
    num_leaves: int
    capacity: int
    keys_per_leaf: int

    @property
    def levels(self) -> int:
        return self.height


def node_nbytes() -> int:
    """Approximate on-wire size of one node (the paper's 1KB unit)."""
    # keys + children/values + header (lock word, fences, level, count).
    return FANOUT * 8 + FANOUT * 8 + 8 + 16 + 4 + 4
