"""Analytic throughput model: counters -> ops/s (Plane A).

The simulator (core/sim.py) is single-threaded and mechanistic; this module
converts its per-op verb counts and critical-section counts into cluster
throughput under N compute threads, using a closed-system model with explicit
bottleneck caps:

  X(N) = min(  N / L_op                      -- thread-limited
             , n_servers * NIC_BW / B_op     -- NIC bandwidth (paper Fig. 8:
                                                "network bandwidth becomes the
                                                bottleneck again")
             , n_servers * MSG_RATE / M_op   -- NIC message rate
             , MEM_CPU / S_op                -- memory-side compute (Fig. 5/13)
             , 1 / (t_cs * C_op^max-bucket)  -- cooling-structure serialization
                                                (Fig. 4/9: FIFO queue collapse)
             , 1 / (t_retry * H_op)          -- hot-leaf optimistic-lock retries
                                                (Fig. 12b NUMA collapse)
            )

All constants are calibrated to the paper's §2.3 measurements (RDMA READ
2 µs, cached 1KB access 400 ns, 100 Gbps NICs) and are overridable.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

from repro.core.sim import Simulator


@dataclasses.dataclass
class HardwareModel:
    nic_bw: float = 12.5e9            # bytes/s per server (100 Gbps)
    nic_msg_rate: float = 60e6        # verbs/s per NIC
    t_bucket_cs: float = 120e-9       # cooling bucket lock+shift critical section
    #: cache-line ping-pong: each waiter adds a coherence transfer to the
    #: critical section (the Fig. 4 FIFO-queue collapse mechanism) — the
    #: effective section is t_cs * (1 + coherence_factor * contenders)
    coherence_factor: float = 0.05
    t_hot_retry: float = 250e-9       # optimistic-lock retry on a hot cached leaf
    op_cpu_overhead: float = 250e-9   # per-op application logic
    numa_penalty: float = 2.0         # cross-socket amplification of hot-lock cost


@dataclasses.dataclass
class ThroughputReport:
    ops_per_sec: float
    bottleneck: str
    caps: Dict[str, float]
    latency_per_op: float

    def mops(self) -> float:
        return self.ops_per_sec / 1e6


def analyze(
    sim: Simulator,
    *,
    threads_total: Optional[int] = None,
    hw: Optional[HardwareModel] = None,
    hot_leaf_write_fraction: float = 0.0,
    threads_per_socket: int = 18,
) -> ThroughputReport:
    """Convert a finished simulation into a throughput estimate.

    ``hot_leaf_write_fraction``: fraction of ops that contend on the single
    hottest leaf lock (drives the Fig. 12b local-contention collapse under
    skew; computed by the benchmark from the workload distribution).
    """
    hw = hw or HardwareModel()
    cfg = sim.cfg
    tot = sim.totals()
    n = max(tot.ops, 1)
    threads = (
        threads_total
        if threads_total is not None
        else cfg.n_compute * cfg.threads_per_compute
    )

    # --- per-op demand -------------------------------------------------------
    latency = sim.op_clock.sum() / n + hw.op_cpu_overhead
    bytes_op = tot.bytes / n
    msgs_op = (
        tot.rdma_read
        + tot.rdma_small_read
        + tot.rdma_write
        + tot.rdma_cas
        + 2.0 * tot.two_sided
    ) / n
    mem_cpu_op = sim.mem_busy.sum() / n      # seconds of memory-side CPU per op

    caps: Dict[str, float] = {}
    caps["threads"] = threads / latency

    n_srv = cfg.n_compute
    caps["nic_bandwidth"] = np.inf if bytes_op == 0 else n_srv * hw.nic_bw / bytes_op
    caps["nic_messages"] = np.inf if msgs_op == 0 else n_srv * hw.nic_msg_rate / msgs_op

    mem_capacity = cfg.n_mem_servers * cfg.mem_threads_per_server
    if mem_cpu_op > 0:
        caps["memory_cpu"] = mem_capacity / mem_cpu_op
        if not cfg.offload_always:
            # cost-aware offloading self-regulates (moving averages see the
            # queueing delay and stop offloading): the cap softens into extra
            # one-sided reads instead of a hard ceiling.
            caps["memory_cpu"] = max(
                caps["memory_cpu"], 0.85 * min(caps["threads"], caps["nic_messages"])
            )
    else:
        caps["memory_cpu"] = np.inf

    # --- cooling-structure serialization (Fig. 4 / Fig. 9) --------------------
    # The busiest bucket's acquire rate serializes; contending threads add
    # cache-line coherence transfers to every acquisition (ping-pong).
    worst = 0.0
    for cache, ctr in zip(sim.caches, sim.counters):
        if ctr.ops == 0:
            continue
        acq = cache.cooling.lock_acquires
        per_op = float(acq.max()) / ctr.ops if acq.size else 0.0
        worst = max(worst, per_op)
    if worst > 0:
        threads_per_srv = max(threads // max(cfg.n_compute, 1), 1)
        # contenders on the busiest bucket ~ threads * (its share of acquires)
        share = worst / max(
            sum(
                float(c.cooling.lock_acquires.sum()) / max(ct.ops, 1)
                for c, ct in zip(sim.caches, sim.counters)
            ) / max(cfg.n_compute, 1),
            1e-9,
        )
        contenders = min(threads_per_srv, max(1.0, threads_per_srv * share))
        t_eff = hw.t_bucket_cs * (1 + hw.coherence_factor * contenders)
        caps["cooling_lock"] = n_srv / (worst * t_eff)
    else:
        caps["cooling_lock"] = np.inf

    # --- hot-leaf optimistic lock (Fig. 12b) ----------------------------------
    if hot_leaf_write_fraction > 0:
        t = hw.t_hot_retry
        if threads > threads_per_socket:
            t *= hw.numa_penalty
        caps["hot_leaf_lock"] = 1.0 / (hot_leaf_write_fraction * t)
    else:
        caps["hot_leaf_lock"] = np.inf

    x = min(caps.values())
    bottleneck = min(caps, key=lambda k: caps[k])
    return ThroughputReport(
        ops_per_sec=float(x), bottleneck=bottleneck, caps=caps, latency_per_op=latency
    )


def throughput_curve(
    make_sim,
    workload,
    thread_counts: Sequence[int],
    *,
    threads_per_compute: int = 36,
    hw: Optional[HardwareModel] = None,
    hot_leaf_write_fraction: float = 0.0,
) -> Dict[int, ThroughputReport]:
    """Scalability curve: run the simulator once, then scale the thread count
    analytically (the verb mix per op does not depend on thread count; adding
    compute servers as threads exhaust existing ones, per §8.2)."""
    ops, keys = workload
    sim = make_sim()
    sim.run(ops, keys)
    out = {}
    for t in thread_counts:
        out[t] = analyze(
            sim,
            threads_total=t,
            hw=hw,
            hot_leaf_write_fraction=hot_leaf_write_fraction,
        )
    return out
