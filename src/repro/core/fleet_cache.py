"""Fleet cache-policy layer for the mesh plane (Plane B).

Every per-chip caching decision of the TPU mesh lives here: the
set-associative cache pytree (:class:`DexCache`), the version-checked
probe/admit machinery of the shared descent (:func:`cached_fetch_level`),
the single leaf-admission dice entry point (:func:`leaf_admit`), and the
:class:`CachePolicy` pytree that makes all of it *per-chip tunable*.
Before this module the same machinery was smeared across ``core/dex.py``
(probe/admit/fetch), ``core/engine.py`` (two inline admission-dice call
sites) and ``core/repartition.py`` (ad-hoc version-bump invalidation);
those duplicates are gone — ``engine.py``, the thin op wrappers and the
repartition install path all call through here.

Uniform vs. divergent policies
------------------------------
The default :func:`uniform_policy` reproduces the paper's §5.4 behaviour
bit-for-bit: every chip rolls the same ``p_admit_leaf_pct`` admission dice
(:func:`repro.core.routing.leaf_admit_dice`), so under broad traffic all
sibling caches converge on the same hot set and the fleet's aggregate
cache is barely bigger than one chip's.  :func:`divergent_policy` applies
the extend-dist observation ("Unlocking the Power of Diversity in Index
Tuning", PAPERS.md) to the cache layer:

* **column-affinity admission bias** — each chip multiplies its
  leaf-admission probability by ``admit_bias[dev, col]`` where ``col`` is
  the memory column owning the leaf's subtree.  The divergent constructor
  boosts the chip's *own* column coordinate and damps the others, so the
  ``n_memory`` siblings sharing one route partition specialize on disjoint
  subtree slices instead of converging.
* **demand bias** — the multiplier is further scaled by the chip's share
  of its own measured ``DexState.route_demand`` (clipped to
  ``[1/beta, beta]``): chips serving demand-hot partitions cache more
  aggressively.  Computed from the chip-local demand vector only — no
  extra collective.
* **eviction salt** — a per-chip constant folded into the dice salt so
  sibling chips stop rolling *correlated* admission dice for the same
  node.
* **peer peek** — a per-chip budget of ``MSG_PEEK`` messages: on a local
  leaf miss whose subtree another column owns, the engine skips the
  remote row fetch and instead asks the owning column's chip (the
  specialist for that slice under the affinity bias) to answer from *its*
  cache, version-checked like any cached row, falling back to that chip's
  local block walk.  The peek rides the engine's existing fused tagged
  ``all_to_all`` pair — zero extra collectives per batch.

Plane A mirrors the same two behaviours (``core/cache.py`` per-server
admission bias, ``core/sim.py`` peer-peek hop priced as a
compute-to-compute message) so ``obs/drift.py`` can assert mesh-vs-sim
agreement on the ``peer_hits`` / ``peer_misses`` registry slots.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import routing
from repro.core.cache import DEFAULT_P_ADMIT_LEAF
from repro.core.nodes import FANOUT, KEY_MAX
from repro.core.pool import PoolMeta, SubtreePool

#: Single source of truth for the paper's §5.4 leaf-admission probability
#: P_A: Plane A owns the fraction (``core/cache.py`` ``DEFAULT_P_ADMIT_LEAF``)
#: and the mesh plane's integer percent is derived from it here — the two
#: literals can no longer silently diverge (tests/test_engine.py asserts
#: the agreement).
P_ADMIT_LEAF_PCT: int = int(round(DEFAULT_P_ADMIT_LEAF * 100))


class DexCache(NamedTuple):
    """Per-chip set-associative node cache; axis 0 is the device axis."""

    tags: jax.Array      # [Dev, sets, ways] int64, -1 empty
    keys: jax.Array      # [Dev, sets, ways, FANOUT] int64
    children: jax.Array  # [Dev, sets, ways, FANOUT] int32
    values: jax.Array    # [Dev, sets, ways, FANOUT] int64
    fifo: jax.Array      # [Dev, sets] int32 (FIFO-within-set pointer)
    ver: jax.Array       # [Dev, sets, ways] int32 node version at admit time


def init_cache(cfg) -> DexCache:
    d, s, w = cfg.n_devices, cfg.cache_sets, cfg.cache_ways
    return DexCache(
        tags=jnp.full((d, s, w), -1, jnp.int64),
        keys=jnp.full((d, s, w, FANOUT), KEY_MAX, jnp.int64),
        children=jnp.zeros((d, s, w, FANOUT), jnp.int32),
        values=jnp.zeros((d, s, w, FANOUT), jnp.int64),
        fifo=jnp.zeros((d, s), jnp.int32),
        ver=jnp.zeros((d, s, w), jnp.int32),
    )


class CachePolicy(NamedTuple):
    """Per-chip cache-policy pytree consumed by the engine at build time.

    The arrays are tiny host-side constants (closed over inside the jitted
    program; each device indexes its own row by its linear device index),
    not sharded state — a policy is a *configuration*, chosen once when the
    engine is built.

    Attributes
    ----------
    admit_bias:  ``[Dev, n_memory]`` float — per-chip multiplier on the
                 leaf-admission probability, indexed by the memory column
                 owning the leaf's subtree (1.0 everywhere = uniform).
    evict_salt:  ``[Dev]`` int64 — per-chip constant folded into the
                 admission-dice salt (0 everywhere = uniform dice).
    peek_budget: ``[Dev]`` int32 — max peer peeks one chip may issue per
                 batch (0 everywhere disables the peek path entirely; the
                 engine then compiles no ``MSG_PEEK`` machinery).
    demand_beta: float — cap for the route-demand admission boost
                 (1.0 disables it).
    """

    admit_bias: np.ndarray
    evict_salt: np.ndarray
    peek_budget: np.ndarray
    demand_beta: float = 1.0


def uniform_policy(cfg) -> CachePolicy:
    """The pre-refactor behaviour: every chip rolls the same dice, nobody
    peeks.  An engine built with this policy (or ``cache_policy=None``) is
    bit-identical to the pre-policy-layer engine."""
    d = cfg.n_devices
    return CachePolicy(
        admit_bias=np.ones((d, cfg.n_memory), np.float32),
        evict_salt=np.zeros((d,), np.int64),
        peek_budget=np.zeros((d,), np.int32),
        demand_beta=1.0,
    )


def divergent_policy(cfg, *, col_affinity: float = 4.0,
                     demand_beta: float = 2.0,
                     peek_budget: int = 64) -> CachePolicy:
    """Cooperative fleet caching: the ``n_memory`` siblings sharing a route
    partition specialize on disjoint memory-column slices.

    Chip ``dev`` (device-linear, route-major: ``dev = r * n_memory + m``)
    boosts admission for leaves owned by its own column coordinate ``m`` by
    ``col_affinity`` and damps the others by ``1/col_affinity``; a per-chip
    salt decorrelates the dice; up to ``peek_budget`` missing leaves per
    batch are peeked from the owning column's cache instead of row-fetched.
    """
    d = cfg.n_devices
    bias = np.full((d, cfg.n_memory), 1.0 / col_affinity, np.float32)
    for dev in range(d):
        bias[dev, dev % cfg.n_memory] = col_affinity
    return CachePolicy(
        admit_bias=bias,
        evict_salt=np.arange(1, d + 1, dtype=np.int64),
        peek_budget=np.full((d,), peek_budget, np.int32),
        demand_beta=float(demand_beta),
    )


def is_uniform(policy: Optional[CachePolicy]) -> bool:
    """Host-side static check: does ``policy`` degenerate to the uniform
    dice?  Decided at engine-build time so the uniform program contains the
    *verbatim* pre-refactor dice call (bit-identity guarantee)."""
    if policy is None:
        return True
    return (
        bool(np.all(np.asarray(policy.admit_bias) == 1.0))
        and bool(np.all(np.asarray(policy.evict_salt) == 0))
        and float(policy.demand_beta) == 1.0
    )


def peeks_enabled(policy: Optional[CachePolicy]) -> bool:
    """Host-side static check: does any chip hold peek budget?"""
    return policy is not None and bool(
        np.any(np.asarray(policy.peek_budget) > 0)
    )


def demand_boost(policy: Optional[CachePolicy], cfg, demand: jax.Array,
                 r_lin: jax.Array) -> Optional[jax.Array]:
    """Per-chip scalar admission boost from this chip's *local* view of
    route demand: ``clip(n_route * share(own partition), 1/beta, beta)``.
    Chip-local by construction — adds no collective.  ``None`` when the
    policy does not use demand biasing."""
    if policy is None or float(policy.demand_beta) == 1.0:
        return None
    dem = demand[0].astype(jnp.float32)                  # [n_route]
    share = dem[r_lin] / jnp.maximum(jnp.sum(dem), 1.0)
    beta = float(policy.demand_beta)
    return jnp.clip(cfg.n_route * share, 1.0 / beta, beta)


def device_peek_budget(policy: CachePolicy, dev: jax.Array) -> jax.Array:
    """This chip's per-batch peek budget (int32 scalar)."""
    return jnp.asarray(np.asarray(policy.peek_budget), jnp.int32)[dev]


def leaf_admit(meta: PoolMeta, cfg, policy: Optional[CachePolicy],
               gid: jax.Array, salt, *, dev: jax.Array,
               boost: Optional[jax.Array] = None) -> jax.Array:
    """THE leaf-admission entry point — the only place the mesh plane rolls
    the §5.4 admission dice.  ``salt`` is the caller's access salt (op
    counter + lane index, re-rolled per access exactly like the inline
    call sites this replaced).

    Uniform policies take the verbatim pre-refactor path
    ``routing.leaf_admit_dice(gid, cfg.p_admit_leaf_pct, salt=salt)``.
    Divergent policies scale the percent by the chip's column-affinity
    bias for the leaf's owning column (and the optional demand ``boost``)
    and fold the chip's eviction salt into the dice salt.
    """
    if is_uniform(policy):
        return routing.leaf_admit_dice(gid, cfg.p_admit_leaf_pct, salt=salt)
    s_per = meta.n_subtrees_padded // cfg.n_memory
    col = ((gid // meta.subtree_cap) // s_per).astype(jnp.int32)
    bias = jnp.asarray(np.asarray(policy.admit_bias), jnp.float32)
    pct = jnp.float32(cfg.p_admit_leaf_pct) * bias[dev, col]
    if boost is not None:
        pct = pct * boost
    pct_i = jnp.clip(jnp.round(pct), 1, 100).astype(jnp.int32)
    esalt = jnp.asarray(np.asarray(policy.evict_salt), jnp.int64)[dev]
    # golden-ratio odd constant, wrapped to signed int64 (two's complement)
    phi64 = jnp.int64(np.uint64(0x9E3779B97F4A7C15).astype(np.int64))
    salt = jnp.int64(salt) + esalt * phi64
    return routing.leaf_admit_dice(gid, pct_i, salt=salt)


def cache_probe(cache: DexCache, cfg, versions: jax.Array, gid: jax.Array):
    """Probe the per-chip cache.  A tag match only counts as a hit when the
    entry's admit-time version still equals the node's current version
    (``versions`` is this chip's replicated per-node version table) — rows
    made stale by another chip's write are rejected and re-fetched.  Returns
    ``(hit, keys_row, children_row, values_row, set_idx, present)`` where
    ``present`` marks a tag match regardless of version (a stale copy that
    ``cache_admit`` will refresh in place)."""
    set_idx = (
        routing.hash64(gid) % jnp.uint64(cfg.cache_sets)
    ).astype(jnp.int32)
    tags = cache.tags[0, set_idx]                        # [B, W]
    tagged = tags == gid[:, None]
    fresh = cache.ver[0, set_idx] == versions[gid][:, None]
    eq = tagged & fresh
    hit = jnp.any(eq, axis=-1)
    present = jnp.any(tagged, axis=-1)  # tag match, possibly version-stale
    way = jnp.argmax(eq, axis=-1).astype(jnp.int32)
    k = cache.keys[0, set_idx, way]
    c = cache.children[0, set_idx, way]
    v = cache.values[0, set_idx, way]
    return hit, k, c, v, set_idx, present


def cache_admit(
    cache: DexCache,
    cfg,
    versions: jax.Array,
    gid: jax.Array,
    set_idx: jax.Array,
    admit: jax.Array,
    rows_k: jax.Array,
    rows_c: jax.Array,
    rows_v: jax.Array,
) -> DexCache:
    """FIFO-within-set insertion of fetched rows (cooling-map analogue).
    Admitted rows are stamped with the node's current version.  A row whose
    tag is already present (a version-stale copy being refetched) is
    *refreshed in place* — same way, no FIFO advance — so staleness heals
    without re-rolling the admission dice."""
    tagged = cache.tags[0, set_idx] == gid[:, None]
    present = jnp.any(tagged, axis=-1)
    pway = jnp.argmax(tagged, axis=-1).astype(jnp.int32)
    fway = (cache.fifo[0, set_idx] % cfg.cache_ways).astype(jnp.int32)
    way = jnp.where(present, pway, fway)
    # non-admitting lanes scatter out of bounds (dropped)
    sidx = jnp.where(admit, set_idx, cfg.cache_sets)
    tags = cache.tags.at[0, sidx, way].set(gid, mode="drop")
    keys = cache.keys.at[0, sidx, way].set(rows_k, mode="drop")
    children = cache.children.at[0, sidx, way].set(rows_c, mode="drop")
    values = cache.values.at[0, sidx, way].set(rows_v, mode="drop")
    fifo = cache.fifo.at[0, jnp.where(present, cfg.cache_sets, sidx)].add(
        1, mode="drop"
    )
    ver = cache.ver.at[0, sidx, way].set(versions[gid], mode="drop")
    return DexCache(tags=tags, keys=keys, children=children, values=values,
                    fifo=fifo, ver=ver)


def cached_fetch_level(
    pool: SubtreePool,
    meta: PoolMeta,
    cfg,
    cache: DexCache,
    versions: jax.Array,
    gid: jax.Array,
    want: jax.Array,
    admit_ok: jax.Array,
    peek_elig: Optional[jax.Array] = None,
    peek_budget: Optional[jax.Array] = None,
):
    """One level of the cached traversal, shared by lookup, scan and the
    write path: probe the per-chip cache for ``gid`` rows (rejecting entries
    whose admit-time version is stale against ``versions``), remote-fetch
    the misses, and admit fetched rows where ``admit_ok`` (a load-shed
    fetch's placeholder row is never admitted).  Returns ``(rows_k, rows_c,
    rows_v, hit, miss, shed, n_msgs, new_cache, peeked)`` with
    ``hit``/``miss`` already masked by ``want``; ``n_msgs`` counts the
    coalesced remote-read messages (duplicate same-node misses in a batch
    share one message).

    When the engine's policy enables peer peeks, ``peek_elig`` marks lanes
    that should *defer* a local miss to the owning column's cache instead
    of paying the remote row fetch here, and ``peek_budget`` caps how many
    do per batch.  ``peeked`` lanes fetch nothing and admit nothing at this
    level — the engine resolves them through a ``MSG_PEEK`` message in the
    fused round.  With peeks disabled (``peek_elig=None``) the dataflow is
    exactly the pre-refactor one and ``peeked`` is ``None``.
    """
    hit, ck, cc, cv, set_idx, present = cache_probe(cache, cfg, versions, gid)
    hit = hit & want
    miss = want & ~hit
    if peek_elig is None:
        peeked = None
        fetch_miss = miss
    else:
        cand = miss & peek_elig
        rank = jnp.cumsum(cand.astype(jnp.int32)) - 1
        peeked = cand & (rank < peek_budget)
        fetch_miss = miss & ~peeked
    fk, fc, fv, shed, n_msgs = routing.fetch_rows(pool, meta, cfg, gid,
                                                  fetch_miss)
    rows_k = jnp.where(hit[:, None], ck, fk)
    rows_c = jnp.where(hit[:, None], cc, fc)
    rows_v = jnp.where(hit[:, None], cv, fv)
    # version-stale tagged rows always refresh in place; the admission dice
    # only gates brand-new entries
    new_cache = cache_admit(
        cache, cfg, versions, gid, set_idx,
        fetch_miss & (admit_ok | present) & ~shed,
        rows_k, rows_c, rows_v,
    )
    return rows_k, rows_c, rows_v, hit, miss, shed, n_msgs, new_cache, peeked


def rt_accept(
    meta: PoolMeta,
    rt_keys: jax.Array,
    rt_hi: jax.Array,
    rt_sub: jax.Array,
    rt_local: jax.Array,
    rt_ver: jax.Array,
    versions: jax.Array,
    idx: jax.Array,
    subtree: jax.Array,
    keys: jax.Array,
    eligible: jax.Array,
):
    """Fence-verified acceptance of a leaf-direct route-table guess
    (DESIGN.md §13).  A guess is *produced* for an eligible lane whose
    segment slot is active (``rt_ver >= 0``); it is *accepted* only when

      1. the key lies inside the entry's trained fence range
         ``[rt_keys, rt_hi)``,
      2. the predicted subtree matches the replicated top-tree walk (a
         belt-and-braces structural check — free, since the walk already
         ran), and
      3. the leaf's current version still equals the train-time stamp:
         any insert, update, split or repartition move bumps the version
         (``invalidate_nodes`` / the engine's write round), so an unchanged
         version proves the leaf's fence range — and therefore the guess —
         is still exactly what a full descent would resolve.

    Returns ``(guess, accept, pred_gid)``; rejected guesses
    (``guess & ~accept``) are the ``rt_mispredicts`` counter and fall back
    to the normal cached descent, so prediction quality is a performance
    knob, never a correctness one."""
    lo = rt_keys[idx]
    hi = rt_hi[idx]
    tver = rt_ver[idx]
    sub = rt_sub[idx].astype(jnp.int32)
    loc = rt_local[idx].astype(jnp.int32)
    pred_gid = meta.node_gid(sub, loc)
    n_nodes = versions.shape[0]
    gsafe = jnp.clip(pred_gid, 0, n_nodes - 1)
    guess = eligible & (tver >= 0)
    accept = (
        guess
        & (keys >= lo)
        & (keys < hi)
        & (sub == subtree)
        & (versions[gsafe] == tver)
    )
    return guess, accept, pred_gid


def peer_answer(cache: DexCache, cfg, versions: jax.Array, gid: jax.Array,
                key: jax.Array, want: jax.Array):
    """Owner-side half of a ``MSG_PEEK``: probe *this* chip's cache for the
    requested leaf on behalf of a peeking sibling.  Version-checked like
    any probe — a stale (e.g. poisoned) row fails ``hit`` and the caller
    falls back to its local block walk.  Returns ``(peer_hit, found,
    value)`` where ``found``/``value`` are only meaningful under
    ``peer_hit``."""
    gsafe = jnp.where(want, gid, 0)
    hit, rows_k, _rows_c, rows_v, _sidx, _present = cache_probe(
        cache, cfg, versions, gsafe
    )
    peer_hit = hit & want
    eq = (rows_k == key[:, None]) & peer_hit[:, None]
    found = jnp.any(eq, axis=-1)
    value = jnp.sum(jnp.where(eq, rows_v, 0), axis=-1)
    return peer_hit, found, value


def invalidate_nodes(versions: jax.Array, gids: np.ndarray) -> jax.Array:
    """Bump the per-node version of every gid in ``gids`` by one — the
    fleet-wide cache-invalidation primitive.  Every chip's version-checked
    probe (:func:`cache_probe`) rejects its cached copy of a bumped node on
    the next access, mesh-wide, without touching any cache array.  Used by
    ``core/repartition.py`` when a boundary install moves subtrees between
    partitions (host-side ``gids``; returns the new replicated table)."""
    n_nodes = versions.shape[-1]
    bump = np.zeros((n_nodes,), np.int32)
    bump[np.asarray(gids)] = 1
    return versions + jnp.asarray(bump)[None, :]
