"""Competitor presets (paper §2.3, §8): Sherman, SMART, their partitioned
variants, the naive RDMA B+-tree, and the Offload-only policy.

Each preset is a :class:`~repro.core.sim.SimConfig` driving the same
mechanistic simulator, so the *only* differences are the protocol decisions
each system makes — mirroring how the paper isolates design choices.

Modeling notes (recorded per DESIGN.md §2.1):
  * Sherman/SMART are shared-everything: every node access pays RDMA-based
    optimistic synchronization (version+node+version reads) and leaf writes
    take RDMA CAS locks with immediate write-back.
  * Neither caches leaf nodes (their key trade-off, §2.3), so every op pays
    >= 1 remote read even with an infinite cache.
  * SMART is a trie with one record per "leaf": range scans degrade to one
    remote read per record (the 56.3x scan gap), its cache uses a
    centralized FIFO + counter (the Fig. 4/9 contention collapse), and its
    write-combining consolidates concurrent leaf writes (~8x fewer WRITEs,
    Table 2: 0.11 vs 0.99).
  * P-variants add DEX's logical partitioning only (the paper enables it for
    them "to better understand its benefits").
  * Offload-only caches nodes above level M and always pushes down (Fig. 5).
"""

from __future__ import annotations

from repro.core.sim import SimConfig


def dex(**kw) -> SimConfig:
    return SimConfig(name="dex", **kw)


def dex_cache_only(**kw) -> SimConfig:
    """DEX without opportunistic offloading (ablation middle bar, Fig. 8)."""
    return SimConfig(name="dex-cache", offloading=False, **kw)


def dex_write_through(**kw) -> SimConfig:
    """DEX with write-through leaf writes and no offloading: the exact
    protocol the mesh plane's write path (core/write.py) implements, used
    for counter-level cross-validation (benchmarks/fig6_mesh_mixed.py)."""
    return SimConfig(
        name="dex-wt", offloading=False, write_through=True, **kw
    )


def dex_partition_only(**kw) -> SimConfig:
    """Logical partitioning alone (ablation second bar, Fig. 8)."""
    return SimConfig(name="dex-partition", caching=False, offloading=False, **kw)


def naive_rdma_btree(**kw) -> SimConfig:
    """Baseline B+-tree of §2.2: no partitioning, no cache, no offloading;
    every node is fetched with RDMA optimistic reads."""
    return SimConfig(
        name="naive",
        logical_partitioning=False,
        caching=False,
        offloading=False,
        rdma_optimistic_reads=True,
        **kw,
    )


def sherman_like(**kw) -> SimConfig:
    return SimConfig(
        name="sherman",
        logical_partitioning=False,
        caching=True,
        cache_leaves=False,
        cache_top_inner_only=True,
        eager_admission=True,
        offloading=False,
        rdma_optimistic_reads=True,
        **kw,
    )


def p_sherman(**kw) -> SimConfig:
    """Sherman + DEX's logical partitioning: non-shared accesses skip the
    RDMA optimistic-read verification and leaf writes skip the lock."""
    return SimConfig(
        name="p-sherman",
        logical_partitioning=True,
        caching=True,
        cache_leaves=False,
        cache_top_inner_only=True,
        eager_admission=True,
        offloading=False,
        rdma_optimistic_reads=False,
        **kw,
    )


def smart_like(**kw) -> SimConfig:
    return SimConfig(
        name="smart",
        logical_partitioning=False,
        caching=True,
        cache_leaves=False,
        eager_admission=True,
        centralized_fifo=True,
        single_record_leaves=True,
        write_combining=True,
        offloading=False,
        rdma_optimistic_reads=True,
        **kw,
    )


def p_smart(**kw) -> SimConfig:
    return SimConfig(
        name="p-smart",
        logical_partitioning=True,
        caching=True,
        cache_leaves=False,
        eager_admission=True,
        centralized_fifo=True,
        single_record_leaves=True,
        write_combining=True,
        offloading=False,
        rdma_optimistic_reads=False,
        **kw,
    )


def offload_only(**kw) -> SimConfig:
    """Cache levels > M, always push the rest down (Fig. 5 'Offload-only')."""
    return SimConfig(
        name="offload-only",
        caching=True,
        cache_leaves=False,
        cache_above_m_only=True,
        offloading=True,
        offload_always=True,
        **kw,
    )


ALL = {
    "dex": dex,
    "dex-cache": dex_cache_only,
    "dex-wt": dex_write_through,
    "dex-partition": dex_partition_only,
    "naive": naive_rdma_btree,
    "sherman": sherman_like,
    "p-sherman": p_sherman,
    "smart": smart_like,
    "p-smart": p_smart,
    "offload-only": offload_only,
}
