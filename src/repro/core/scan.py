"""Batched range scans on the TPU mesh (Plane B): the paper's §7 Range Query
as SPMD collectives.

DEX keeps no leaf links on the memory servers; a multi-leaf scan is
*fence-key subdivided* — conceptually a sequence of root-to-leaf descents
whose next start key is the current leaf's upper fence.  In the blocked pool
layout (core/pool.py) "follow the fence key" degenerates to "read the next
leaf's gid from the replicated successor table" (``DexState.succ``, seeded
by ``pool.initial_succ`` and re-linked by on-mesh leaf splits in
core/smo.py) — one remote leaf READ per hop, without re-walking the upper
levels, which is exactly the traffic the paper counts for its scans (one
node READ per additional leaf, §7).  A lane issues hop ``h`` only while the
records it has already collected fall short of its count, so the read count
matches the host replay's leaf visits exactly even when splits leave leaves
half-full.

The dataflow — route round, version-checked cached descent to the start
leaf, successor-chain sibling hops, ``leaf_scan`` Pallas compaction — lives
in the unified mixed-op engine (:mod:`repro.core.engine`); this module is
the thin single-opcode wrapper.  Scans are never offloaded (§7:
memory-side CPUs would have to chase leaves too) and leave the offload
miss-EMA untouched.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import engine as engine_mod
from repro.core.dex import DexMeshConfig, DexState
from repro.core.engine import (  # noqa: F401  (scan_hops re-export: the
    DEFAULT_MAX_COUNT,           # static hop bound is part of this module's
    scan_hops,                   # documented contract)
)
from repro.core.pool import PoolMeta


def make_dex_scan(
    meta: PoolMeta,
    cfg: DexMeshConfig,
    mesh,
    *,
    max_count: int = DEFAULT_MAX_COUNT,
    use_kernel: bool = True,
    interpret: "bool | None" = None,
):
    """Build the sharded range scan:
    ``(state, start_keys, counts) -> (state, keys, values, taken)``.

    A thin single-opcode wrapper over the unified mixed-op engine
    (:func:`repro.core.engine.make_dex_engine`); scan lanes carry their
    record count in the engine's value plane.  ``start_keys``/``counts``
    are [B] globally sharded over all mesh axes; results come back in the
    caller's lane order as ``keys``/``values`` [B, max_count] (KEY_MAX / 0
    padded) and ``taken`` [B] int32.  Requests with ``counts[b] >
    max_count`` are clipped; start keys need not exist in the index (the
    scan begins at the smallest key >= start).  Wrap with ``jax.jit``.

    Load shedding: a lane whose request (or any of whose per-level remote
    fetches) exceeded a routing bucket's capacity returns ``taken == -1``
    with empty rows — never silently truncated data — and is counted in
    ``STAT_DROPS``; the caller retries (logical repartitioning is the
    systemic fix, §4).
    """
    eng = engine_mod.make_dex_engine(
        meta, cfg, mesh, ops=("scan",), max_count=max_count,
        use_kernel=use_kernel, interpret=interpret,
    )

    def scan(state: DexState, start_keys: jax.Array, counts: jax.Array):
        start_keys = start_keys.astype(jnp.int64)
        opcodes = jnp.full(start_keys.shape, engine_mod.OP_SCAN, jnp.int32)
        new_state, r = eng(state, opcodes, start_keys, counts.astype(jnp.int64))
        return new_state, r.scan_keys, r.scan_values, r.taken

    return scan
