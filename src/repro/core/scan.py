"""Batched range scans on the TPU mesh (Plane B): the paper's §7 Range Query
as SPMD collectives.

DEX keeps no leaf links on the memory servers; a multi-leaf scan is
*fence-key subdivided* — conceptually a sequence of root-to-leaf descents
whose next start key is the current leaf's upper fence.  In the blocked pool
layout (core/pool.py) "follow the fence key" degenerates to "read the next
leaf's gid from the replicated successor table" (``DexState.succ``, seeded
by ``pool.initial_succ`` and re-linked by on-mesh leaf splits in
core/smo.py) — one remote leaf READ per hop, without re-walking the upper
levels, which is exactly the traffic the paper counts for its scans (one
node READ per additional leaf, §7).  A lane issues hop ``h`` only while the
records it has already collected fall short of its count, so the read count
matches the host replay's leaf visits exactly even when splits leave leaves
half-full.

Dataflow per batch of ``(start_key, count)`` requests (DESIGN.md §3):

  1. route requests to the compute partition owning ``start_key`` — shared
     machinery with the point lookup (core/routing.py);
  2. walk the replicated top tree to the owning subtree, then descend the
     subtree's inner levels with per-chip cache probe/admit and remote
     fetches of missing rows (same per-level all_to_all over the memory axis
     as the lookup's one-sided path) to find the *start leaf*;
  3. iterate ``hops`` sibling leaves: probe the cache for each consecutive
     leaf, remote-read the misses, lazily admit with the leaf admission
     probability P_A (§5.4), and append the rows to a per-lane window;
  4. compact the window with the ``leaf_scan`` Pallas kernel (vectorized
     in-leaf lower bound + masked rank gather, kernels/leaf_scan.py);
  5. route results back to the requesting lanes.

Scans are never offloaded (§7: memory-side CPUs would have to chase leaves
too), so there is no offload branch and the miss EMA is left untouched.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import routing
from repro.core.dex import (
    N_STATS,
    STAT_DROPS,
    STAT_FETCHES,
    STAT_HITS,
    STAT_OPS,
    DexCache,
    DexMeshConfig,
    DexState,
    cached_fetch_level,
)
from repro.core.nodes import KEY_MAX
from repro.core.pool import PoolMeta, SubtreePool, top_walk
from repro.kernels.leaf_scan import leaf_scan
from repro.kernels.ops import use_interpret
from repro.kernels.ref import leaf_scan_ref

DEFAULT_MAX_COUNT = 128


def scan_hops(meta: PoolMeta, max_count: int) -> int:
    """Leaves that may contribute to a ``max_count``-record scan: the start
    leaf (which can contribute as little as nothing when the start key lies
    above its last record) plus enough minimally-filled leaves for the rest
    (``min_leaf_fill``: on-mesh splits can leave leaves half-full).  This is
    only the static loop bound — per-lane collected-count masking stops each
    lane's remote reads as soon as its count is covered."""
    return 1 + -(-max_count // meta.min_leaf_fill)


def make_dex_scan(
    meta: PoolMeta,
    cfg: DexMeshConfig,
    mesh,
    *,
    max_count: int = DEFAULT_MAX_COUNT,
    use_kernel: bool = True,
    interpret: "bool | None" = None,
):
    """Build the sharded range scan:
    ``(state, start_keys, counts) -> (state, keys, values, taken)``.

    ``start_keys``/``counts`` are [B] globally sharded over all mesh axes;
    results come back in the caller's lane order as ``keys``/``values``
    [B, max_count] (KEY_MAX / 0 padded) and ``taken`` [B] int32.  Requests
    with ``counts[b] > max_count`` are clipped; start keys need not exist in
    the index (the scan begins at the smallest key >= start).  Wrap with
    ``jax.jit``.

    Load shedding: a lane whose request (or any of whose per-level remote
    fetches) exceeded a routing bucket's capacity returns ``taken == -1``
    with empty rows — never silently truncated data — and is counted in
    ``STAT_DROPS``; the caller retries (logical repartitioning is the
    systemic fix, §4).
    """
    levels = meta.levels_in_subtree
    hops = scan_hops(meta, max_count)
    mc = max_count
    if interpret is None:
        interpret = use_interpret()  # compiled kernel on real TPU backends

    def local_fn(pool, cache, boundaries, stats, demand, versions, succ,
                 start_keys, counts):
        b = start_keys.shape[0]
        n_route = cfg.n_route
        vers = versions[0]
        succ_t = succ[0]

        # --- 1. route to the partition owning the start key ----------------
        owner, dem = routing.route_owners(boundaries, start_keys, n_route)
        new_demand = demand + dem
        cap = routing.route_capacity(b, n_route, cfg.route_capacity_factor)
        payload = jnp.stack(
            [start_keys, counts.astype(jnp.int64)], axis=-1
        )                                                   # [B, 2]
        buf, lane, dropped = routing.pack_by_dest(payload, owner, n_route, cap)
        # inactive lanes share the OOB sentinel bucket; its overflow is
        # meaningless (see routing.route_owners)
        dropped = dropped & (start_keys != KEY_MAX)
        routed = routing.route_exchange(buf, cfg, mesh)     # [n_route, cap, 2]
        q = routed[..., 0].reshape(-1)                      # [n_route*cap]
        cnt = routed[..., 1].reshape(-1)
        live = q != KEY_MAX
        cnt = jnp.clip(jnp.where(live, cnt, 0), 0, mc).astype(jnp.int32)

        # --- 2. top-tree walk + cached descent to the start leaf ------------
        subtree = top_walk(pool, meta, q)
        subtree = jnp.where(live, subtree, 0)
        local = jnp.full(q.shape, 0, jnp.int32)             # subtree root
        new_cache = cache
        n_fetch = jnp.int64(0)
        n_hit = jnp.int64(0)
        shed = jnp.zeros(q.shape, bool)   # lanes whose fetches were load-shed
        always = jnp.ones(q.shape, bool)  # inner nodes: admit unconditionally
        for _ in range(levels - 1):
            gid = meta.node_gid(subtree, local)
            rows_k, rows_c, _rows_v, hit, miss, f_drop, n_msgs, new_cache = (
                cached_fetch_level(
                    pool, meta, cfg, new_cache, vers, gid, live, always
                )
            )
            shed = shed | f_drop
            n_fetch = n_fetch + n_msgs
            n_hit = n_hit + jnp.sum(hit).astype(jnp.int64)
            slot = jnp.maximum(
                jnp.sum(rows_k <= q[:, None], axis=-1) - 1, 0
            ).astype(jnp.int32)
            local = jnp.take_along_axis(rows_c, slot[:, None], axis=-1)[:, 0]

        # gid of the start leaf (the successor chain starts here)
        gid_h = meta.node_gid(subtree, local)

        # --- 3. iterated sibling-leaf reads (fence-key subdivision) ---------
        # hop h+1 follows the successor table; a lane keeps reading only
        # while the records collected so far fall short of its count, so
        # remote leaf reads match the host replay's leaf visits exactly
        window_k = []
        window_v = []
        collected = jnp.zeros(q.shape, jnp.int32)
        in_range = live
        for h in range(hops):
            if h > 0:
                nxt = succ_t[jnp.where(in_range, gid_h, 0)]
                in_range = in_range & (collected < cnt) & (nxt >= 0)
                gid_h = jnp.where(in_range, nxt, gid_h)
            gid = jnp.where(in_range, gid_h, 0)
            # lazy leaf admission with P_A (§5.4), re-rolled per access
            p_ok = routing.leaf_admit_dice(
                gid, cfg.p_admit_leaf_pct,
                salt=stats[0, STAT_OPS] + h + jnp.arange(q.shape[0]),
            )
            rows_k, _rows_c, rows_v, hit, miss, f_drop, n_msgs, new_cache = (
                cached_fetch_level(
                    pool, meta, cfg, new_cache, vers, gid, in_range, p_ok
                )
            )
            shed = shed | f_drop
            rows_k = jnp.where(in_range[:, None], rows_k, KEY_MAX)
            rows_v = jnp.where(in_range[:, None], rows_v, 0)
            collected = collected + jnp.sum(
                ((rows_k != KEY_MAX) & (rows_k >= q[:, None])).astype(jnp.int32),
                axis=-1,
            )
            n_fetch = n_fetch + n_msgs
            n_hit = n_hit + jnp.sum(hit).astype(jnp.int64)
            window_k.append(rows_k)
            window_v.append(rows_v)
        wk = jnp.concatenate(window_k, axis=-1)             # [Q, hops*F]
        wv = jnp.concatenate(window_v, axis=-1)

        # --- 4. in-window lower bound + masked compaction (Pallas) ----------
        if use_kernel:
            out_k, out_v, taken = leaf_scan(
                wk, wv, q, cnt, max_count=mc, interpret=interpret
            )
        else:
            out_k, out_v, taken = leaf_scan_ref(wk, wv, q, cnt, max_count=mc)
        # shed lanes return an explicit failure, never truncated data
        shed = shed & live
        ok_lane = live & ~shed
        out_k = jnp.where(ok_lane[:, None], out_k, KEY_MAX)
        out_v = jnp.where(ok_lane[:, None], out_v, 0)
        taken = jnp.where(ok_lane, taken, jnp.where(shed, -1, 0))

        # --- 5. stats + results back to the requesting lanes ----------------
        upd = jnp.zeros((1, N_STATS), jnp.int64)
        upd = upd.at[0, STAT_OPS].set(jnp.sum(live).astype(jnp.int64))
        upd = upd.at[0, STAT_HITS].set(n_hit)
        upd = upd.at[0, STAT_FETCHES].set(n_fetch)
        upd = upd.at[0, STAT_DROPS].set(
            (jnp.sum(dropped) + jnp.sum(shed)).astype(jnp.int64)
        )
        new_stats = stats + upd

        resp = jnp.concatenate(
            [out_k, out_v, taken[:, None].astype(jnp.int64)], axis=-1
        )                                                   # [Q, 2*mc+1]
        resp = resp.reshape(n_route, cap, 2 * mc + 1)
        back = routing.route_exchange(resp, cfg, mesh, reverse=True)
        out = routing.unpack_to_lanes(back, lane, b, 0)     # [B, 2*mc+1]
        res_k = jnp.where(dropped[:, None], KEY_MAX, out[..., :mc])
        res_v = jnp.where(dropped[:, None], 0, out[..., mc : 2 * mc])
        res_taken = jnp.where(dropped, -1, out[..., 2 * mc]).astype(jnp.int32)
        return new_cache, new_stats, new_demand, res_k, res_v, res_taken

    dev = P(cfg.all_axes)
    pool_specs = SubtreePool(
        top_keys=P(),
        top_children=P(),
        pool_keys=P(cfg.memory_axis),
        pool_children=P(cfg.memory_axis),
        pool_values=P(cfg.memory_axis),
    )
    cache_specs = DexCache(tags=dev, keys=dev, children=dev, values=dev,
                           fifo=dev, ver=dev)

    sharded = routing.shard_map_compat(
        local_fn,
        mesh=mesh,
        in_specs=(pool_specs, cache_specs, P(), dev, dev, dev, dev, dev, dev),
        out_specs=(cache_specs, dev, dev, dev, dev, dev),
    )

    def scan(state: DexState, start_keys: jax.Array, counts: jax.Array):
        new_cache, new_stats, new_demand, keys, values, taken = sharded(
            state.pool,
            state.cache,
            state.boundaries,
            state.stats,
            state.route_demand,
            state.versions,
            state.succ,
            start_keys.astype(jnp.int64),
            counts.astype(jnp.int64),
        )
        new_state = state._replace(
            cache=new_cache, stats=new_stats, route_demand=new_demand
        )
        return new_state, keys, values, taken

    return scan
