"""Event-level simulator of DEX and its competitors (Plane A).

Executes the paper's protocols *per operation* against a host-resident
B+-tree, counting every remote verb (RDMA READ / small READ / WRITE / CAS /
two-sided RPC) and every cache event, exactly as the paper's Table 2 reports
them.  Latency/contention conversion to throughput lives in
``core/cost_model.py``; this module is purely mechanistic.

Fidelity notes (mapped to the paper):
  * Algorithm 1 traversal with cache lookup / remote_read / offload decision.
  * Shared nodes (fence range crossing a partition boundary) pay RDMA-based
    optimistic synchronization: version read + node read + version re-read
    (§4, lines 3–6); non-shared nodes are one READ (line 8).
  * Offloading only for non-shared subtrees rooted at level <= M, gated by
    the cost model `l_p < (L+1)(l_o+l_s)c` with moving averages and an
    ε-exploration of the contrary action (§6.1).
  * Offloaded writes that would split fall back to the normal path (§6).
  * Eager splits on the way down; splits of shared parents take the global
    lock, re-validate freshness, else refresh-from-root (§7 Insert).
  * Updates to cached non-shared leaves only dirty the cache; write-back
    happens at cooling/eviction (§4) — this is why DEX's WI write count is
    ~0.19 instead of ~1.

The simulator is single-threaded; thread-level contention (FIFO-queue locks,
memory-side CPU saturation) is modeled analytically downstream from the
counters collected here (DESIGN.md §2.1).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import btree as btree_mod
from repro.core.cache import ComputeCache, DEFAULT_P_ADMIT_LEAF
from repro.core.nodes import FANOUT, KEY_MAX, KEY_MIN, NULL
from repro.core.partition import LogicalPartitions
from repro.obs import latency as obs_latency

NODE_BYTES = 1024          # paper: 1KB nodes
SMALL_READ_BYTES = 8       # version word
RPC_BYTES = 64             # offload request/response payload

# constants of the mesh engine's per-group byte-cost model, mirrored here so
# ``SimConfig.group_offload`` prices the identical decision rule
# (core/engine.py; keep in sync with core/dex.py NODE_ROW_BYTES /
# OFFLOAD_REQ_BYTES / OFFLOAD_RESP_BYTES)
ENGINE_NODE_ROW_BYTES = FANOUT * 8 * 3
ENGINE_RPC_BYTES = 16 + 16


# ---------------------------------------------------------------------------
# Host B+-tree with true eager-split SMOs
# ---------------------------------------------------------------------------


class HostBTree:
    """Mutable numpy B+-tree used as 'the memory pool'.

    Same layout/semantics as core/btree.py plus parent pointers, in-place
    eager splits, and node->memory-server placement with level-M subtree
    grouping (paper §3 Index Placement).
    """

    def __init__(self, keys: np.ndarray, values: Optional[np.ndarray] = None,
                 *, fill: float = 0.7, level_m: int = 1, n_mem_servers: int = 1,
                 placement: str = "round_robin",
                 subtrees_per_server: Optional[int] = None):
        if placement not in ("round_robin", "blocked"):
            raise ValueError(f"unknown placement {placement!r}")
        self.placement = placement
        self.subtrees_per_server = subtrees_per_server
        tree, meta = btree_mod.bulk_build(keys, values, fill=fill)
        self.K = np.asarray(tree.keys).copy()
        self.C = np.asarray(tree.children).copy()
        self.V = np.asarray(tree.values).copy()
        self.NK = np.asarray(tree.num_keys).copy()
        self.LV = np.asarray(tree.level).copy()
        self.FLO = np.asarray(tree.fence_lo).copy()
        self.FHI = np.asarray(tree.fence_hi).copy()
        self.root = int(tree.root)
        self.height = meta.height
        self.num_nodes = meta.num_nodes
        self.level_m = level_m
        self.n_mem_servers = n_mem_servers
        self._next_free = meta.num_nodes
        self.parent = np.full((self.K.shape[0],), -1, dtype=np.int32)
        self._rebuild_parents()
        self.server = np.full((self.K.shape[0],), -1, dtype=np.int32)
        self._assign_placement()
        self.splits = 0
        self.merges = 0

    # -- storage management ---------------------------------------------------

    def _grow(self) -> None:
        cap = self.K.shape[0]
        new = cap * 2
        def g(a, fillv):
            out = np.full((new,) + a.shape[1:], fillv, dtype=a.dtype)
            out[:cap] = a
            return out
        self.K = g(self.K, KEY_MAX)
        self.C = g(self.C, NULL)
        self.V = g(self.V, 0)
        self.NK = g(self.NK, 0)
        self.LV = g(self.LV, -1)
        self.FLO = g(self.FLO, KEY_MIN)
        self.FHI = g(self.FHI, KEY_MAX)
        self.parent = g(self.parent, -1)
        self.server = g(self.server, -1)

    def _alloc(self) -> int:
        if self._next_free >= self.K.shape[0] - 1:
            self._grow()
        nid = self._next_free
        self._next_free += 1
        self.num_nodes += 1
        return nid

    def _rebuild_parents(self) -> None:
        self.parent[:] = -1
        inner = np.where(self.LV > 0)[0]
        for nid in inner:
            for i in range(int(self.NK[nid])):
                self.parent[self.C[nid, i]] = nid

    def _assign_placement(self) -> None:
        """Subtrees rooted at level M live wholly on one memory server.

        ``placement="round_robin"`` (the default) deals subtrees out in
        walk order; ``placement="blocked"`` assigns contiguous runs of
        ``subtrees_per_server`` subtrees to each server — the mesh pool's
        block sharding (``subtree // s_per``, core/pool.py), so the two
        planes agree on which "memory column" owns a key range (the
        per-group offload cross-validation relies on this,
        benchmarks/fig13_mesh_engine.py)."""
        m = self.level_m
        roots: List[int] = []
        def assign(nid: int, server: int):
            self.server[nid] = server
            if self.LV[nid] > 0:
                for i in range(int(self.NK[nid])):
                    assign(int(self.C[nid, i]), server)
        def walk(nid: int):
            lvl = int(self.LV[nid])
            if lvl <= m:
                roots.append(nid)
                return
            self.server[nid] = int(nid) % self.n_mem_servers
            for i in range(int(self.NK[nid])):
                walk(int(self.C[nid, i]))
        walk(self.root)
        if self.placement == "blocked":
            sps = self.subtrees_per_server or -(-len(roots) // self.n_mem_servers)
            for order, r in enumerate(roots):
                assign(r, min(order // sps, self.n_mem_servers - 1))
        else:
            for order, r in enumerate(roots):
                assign(r, order % self.n_mem_servers)

    def subtree_root_of(self, nid: int) -> int:
        """Ancestor at level M (or self when the tree is shorter)."""
        cur = nid
        while self.LV[cur] < self.level_m and self.parent[cur] >= 0:
            cur = int(self.parent[cur])
        return cur

    # -- queries ---------------------------------------------------------------

    def search_path(self, key: int) -> List[int]:
        """Root-to-leaf node ids for ``key``."""
        path = [self.root]
        nid = self.root
        while self.LV[nid] > 0:
            nk = int(self.NK[nid])
            row = self.K[nid, :nk]
            slot = int(np.searchsorted(row, key, side="right")) - 1
            slot = max(slot, 0)
            nid = int(self.C[nid, slot])
            path.append(nid)
        return path

    def get(self, key: int) -> Optional[int]:
        leaf = self.search_path(key)[-1]
        nk = int(self.NK[leaf])
        row = self.K[leaf, :nk]
        i = int(np.searchsorted(row, key))
        if i < nk and row[i] == key:
            return int(self.V[leaf, i])
        return None

    def fence_valid(self, nid: int, key: int) -> bool:
        return self.FLO[nid] <= key < self.FHI[nid]

    # -- mutations ---------------------------------------------------------------

    def update(self, key: int, value: int) -> bool:
        leaf = self.search_path(key)[-1]
        nk = int(self.NK[leaf])
        row = self.K[leaf, :nk]
        i = int(np.searchsorted(row, key))
        if i < nk and row[i] == key:
            self.V[leaf, i] = value
            return True
        return False

    def would_split(self, key: int) -> bool:
        """True if inserting ``key`` hits any full node on its path (the
        memory-side SMO check that triggers offload fallback)."""
        return any(int(self.NK[n]) >= FANOUT for n in self.search_path(key))

    def insert(self, key: int, value: int) -> Tuple[bool, List[int]]:
        """Eager-split insert.  Returns (is_new_key, split_node_ids)."""
        splits: List[int] = []
        nid = self.root
        if int(self.NK[nid]) >= FANOUT:
            nid = self._split_root()
            splits.append(nid)
        while self.LV[nid] > 0:
            nk = int(self.NK[nid])
            slot = max(int(np.searchsorted(self.K[nid, :nk], key, side="right")) - 1, 0)
            child = int(self.C[nid, slot])
            if int(self.NK[child]) >= FANOUT:
                self._split_child(nid, slot)
                splits.append(child)
                nk = int(self.NK[nid])
                slot = max(
                    int(np.searchsorted(self.K[nid, :nk], key, side="right")) - 1, 0
                )
                child = int(self.C[nid, slot])
            nid = child
        # leaf insert
        nk = int(self.NK[nid])
        row = self.K[nid, :nk]
        i = int(np.searchsorted(row, key))
        if i < nk and row[i] == key:
            self.V[nid, i] = value
            return False, splits
        assert nk < FANOUT, "leaf full despite eager splits"
        self.K[nid, i + 1 : nk + 1] = self.K[nid, i:nk]
        self.V[nid, i + 1 : nk + 1] = self.V[nid, i:nk]
        self.K[nid, i] = key
        self.V[nid, i] = value
        self.NK[nid] = nk + 1
        return True, splits

    def _split_root(self) -> int:
        old = self.root
        new_root = self._alloc()
        self.LV[new_root] = int(self.LV[old]) + 1
        self.K[new_root, 0] = KEY_MIN
        self.C[new_root, 0] = old
        self.NK[new_root] = 1
        self.FLO[new_root] = KEY_MIN
        self.FHI[new_root] = KEY_MAX
        self.parent[old] = new_root
        self.server[new_root] = new_root % self.n_mem_servers
        self.root = new_root
        self.height += 1
        self._split_child(new_root, 0)
        return new_root

    def _split_child(self, pnode: int, slot: int) -> int:
        """Split C[pnode, slot]; parent must have room (eager policy)."""
        child = int(self.C[pnode, slot])
        nk = int(self.NK[child])
        half = nk // 2
        sib = self._alloc()
        self.LV[sib] = self.LV[child]
        # sibling gets the upper half
        self.K[sib, : nk - half] = self.K[child, half:nk]
        self.V[sib, : nk - half] = self.V[child, half:nk]
        self.C[sib, : nk - half] = self.C[child, half:nk]
        self.NK[sib] = nk - half
        sep = int(self.K[child, half])
        self.K[child, half:nk] = KEY_MAX
        self.V[child, half:nk] = 0
        self.C[child, half:nk] = NULL
        self.NK[child] = half
        # fences
        self.FLO[sib] = sep
        self.FHI[sib] = self.FHI[child]
        self.FHI[child] = sep
        # parent pointers of moved children
        if self.LV[sib] > 0:
            for i in range(int(self.NK[sib])):
                self.parent[self.C[sib, i]] = sib
        # placement: sibling stays on the same memory server (subtree intact)
        self.server[sib] = self.server[child]
        # insert separator into parent
        pk = int(self.NK[pnode])
        assert pk < FANOUT, "parent full in eager split"
        self.K[pnode, slot + 2 : pk + 1] = self.K[pnode, slot + 1 : pk]
        self.C[pnode, slot + 2 : pk + 1] = self.C[pnode, slot + 1 : pk]
        self.K[pnode, slot + 1] = sep
        self.C[pnode, slot + 1] = sib
        self.NK[pnode] = pk + 1
        self.parent[sib] = pnode
        self.splits += 1
        return sib

    def delete(self, key: int) -> bool:
        """Logical delete with lazy structural merge (empty leaves are merged
        into the parent; full rebalance is out of scope for the simulator —
        the paper's merges propagate the same counters we track)."""
        path = self.search_path(key)
        leaf = path[-1]
        nk = int(self.NK[leaf])
        row = self.K[leaf, :nk]
        i = int(np.searchsorted(row, key))
        if not (i < nk and row[i] == key):
            return False
        self.K[leaf, i : nk - 1] = self.K[leaf, i + 1 : nk]
        self.V[leaf, i : nk - 1] = self.V[leaf, i + 1 : nk]
        self.K[leaf, nk - 1] = KEY_MAX
        self.V[leaf, nk - 1] = 0
        self.NK[leaf] = nk - 1
        if self.NK[leaf] == 0 and len(path) >= 2:
            self._remove_empty_child(path[-2], leaf)
        return True

    def _remove_empty_child(self, pnode: int, child: int) -> None:
        pk = int(self.NK[pnode])
        if pk <= 1:
            return  # keep degenerate chain; rare in workloads
        slot = None
        for i in range(pk):
            if int(self.C[pnode, i]) == child:
                slot = i
                break
        if slot is None:
            return
        # absorb fence into left neighbour when possible
        self.K[pnode, slot : pk - 1] = self.K[pnode, slot + 1 : pk]
        self.C[pnode, slot : pk - 1] = self.C[pnode, slot + 1 : pk]
        if slot == 0:
            self.K[pnode, 0] = self.FLO[pnode]
        self.K[pnode, pk - 1] = KEY_MAX
        self.C[pnode, pk - 1] = NULL
        self.NK[pnode] = pk - 1
        self.merges += 1

    def scan(self, key: int, count: int) -> List[Tuple[int, List[int]]]:
        """Fence-key subdivided scan: list of (leaf, collected_keys) hops."""
        hops = []
        cur = key
        got = 0
        while got < count:
            leaf = self.search_path(cur)[-1]
            nk = int(self.NK[leaf])
            row = self.K[leaf, :nk]
            take = row[row >= cur][: count - got]
            hops.append((leaf, [int(x) for x in take]))
            got += take.size
            nxt = int(self.FHI[leaf])
            if nxt == int(KEY_MAX):
                break
            cur = nxt
        return hops


# ---------------------------------------------------------------------------
# Remote-verb counters (Table 2 columns)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Counters:
    ops: int = 0
    rdma_read: float = 0.0        # node-sized READs
    rdma_small_read: float = 0.0  # 8B version READs
    rdma_write: float = 0.0
    rdma_cas: float = 0.0         # atomics
    two_sided: float = 0.0        # offload RPCs
    bytes: float = 0.0
    local_accesses: float = 0.0   # cached-node searches
    offload_fallbacks: int = 0
    coherence_invalidations: int = 0
    refresh_from_root: int = 0
    smo_inserts: int = 0          # inserts whose split ran memory-side
    #                               (SimConfig.onmesh_smo pricing)
    offload_groups: int = 0       # (window, memory server) groups the
    #                               per-group cost model sent two-sided
    #                               (SimConfig.group_offload; mirrors the
    #                               mesh's STAT_OFFLOAD_GROUPS)
    fetch_groups: int = 0         # groups that stayed one-sided
    #                               (STAT_FETCH_GROUPS analogue)
    pipeline_stalls: int = 0      # pipelined overlap window: lanes whose
    #                               leaf the previous window wrote — the
    #                               version check catches the stale descent
    #                               and the lane re-resolves two-sided
    #                               (STAT_PIPE_STALLS analogue)
    peer_hits: int = 0            # leaf misses answered from a sibling
    #                               cache's version-fresh copy via a peer
    #                               peek (STAT_PEER_HITS analogue)
    peer_misses: int = 0          # peer peeks the sibling could not serve
    #                               (stale/absent row; resolved by the
    #                               owning server's walk —
    #                               STAT_PEER_MISSES analogue)
    rt_skips: int = 0             # within-subtree inner reads skipped by
    #                               accepted leaf-direct route-table probes
    #                               (STAT_RT_SKIPS analogue)
    rt_mispredicts: int = 0       # route-table guesses rejected by the
    #                               fence bounds / leaf-freshness check;
    #                               the op falls back to full descent
    #                               (STAT_RT_MISPREDICTS analogue)

    def add_read(self, nbytes: int = NODE_BYTES) -> None:
        self.rdma_read += 1
        self.bytes += nbytes

    def add_small_read(self) -> None:
        self.rdma_small_read += 1
        self.bytes += SMALL_READ_BYTES

    def add_write(self, nbytes: int = NODE_BYTES) -> None:
        self.rdma_write += 1
        self.bytes += nbytes

    def add_cas(self) -> None:
        self.rdma_cas += 1
        self.bytes += 8

    def add_rpc(self) -> None:
        self.two_sided += 1
        self.bytes += RPC_BYTES

    def per_op(self) -> Dict[str, float]:
        n = max(self.ops, 1)
        return {
            "reads": (self.rdma_read + self.rdma_small_read) / n,
            "node_reads": self.rdma_read / n,
            "writes": self.rdma_write / n,
            "atomics": self.rdma_cas / n,
            "two_sided": self.two_sided / n,
            "traffic_bytes": self.bytes / n,
            "local_accesses": self.local_accesses / n,
        }


# ---------------------------------------------------------------------------
# Simulator configuration (DEX + all baselines via knobs)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SimConfig:
    """Protocol knobs.  Presets for the paper's competitors live in
    core/baselines.py."""

    name: str = "dex"
    n_compute: int = 4
    n_mem_servers: int = 4
    threads_per_compute: int = 36
    mem_threads_per_server: int = 4
    cache_bytes: int = 256 << 20           # per compute server (paper default)
    level_m: int = 3                        # subtree grouping level (paper: M=3)

    # --- technique toggles (Fig. 8 ablation) ---
    logical_partitioning: bool = True
    caching: bool = True
    offloading: bool = True
    route_dispersion: int = 1               # caches serving each partition;
                                            # > 1 models the mesh plane's
                                            # source-dispersed within-row
                                            # routing (fig6_mesh_mixed cross-
                                            # validation): an op lands on a
                                            # random one of the partition's
                                            # `route_dispersion` caches
    coherence_batch: int = 1                # ops per batch window when
                                            # pricing the mesh plane's
                                            # *batched* execution: repeated
                                            # misses of one node coalesce
                                            # into one read per window, and
                                            # write-staleness marks flush at
                                            # window boundaries (the pmax
                                            # version sync)
    pipeline_overlap: bool = False          # two-stage pipelined engine
                                            # (engine.py pipeline=True):
                                            # window N+1's descents overlap
                                            # window N's write round, so a
                                            # descent into a leaf the
                                            # previous window wrote is one
                                            # window stale — priced as a
                                            # forced two-sided re-resolution
                                            # (the conservative conflict
                                            # fallback; needs
                                            # coherence_batch > 1)

    # --- cache behaviour (Fig. 9) ---
    cache_leaves: bool = True               # False for Sherman/SMART-like
    cache_top_inner_only: bool = False      # Sherman: lowest inner + above
    p_admit_leaf: float = DEFAULT_P_ADMIT_LEAF
    eager_admission: bool = False
    fleet_col_affinity: float = 1.0         # divergent fleet policy
                                            # (core/fleet_cache.py
                                            # divergent_policy mirror): each
                                            # of a partition's
                                            # route_dispersion sibling caches
                                            # multiplies its leaf-admission
                                            # probability by this for leaves
                                            # whose memory server matches
                                            # its own sibling coordinate
                                            # (server % d == cache % d), and
                                            # by the reciprocal otherwise;
                                            # 1.0 keeps the uniform dice
    fleet_peek_budget: int = 0              # peer peeks one cache may issue
                                            # per coherence window: a leaf
                                            # miss whose subtree another
                                            # sibling specializes on asks
                                            # that sibling's cache (one
                                            # compute-to-compute message)
                                            # before paying the remote read;
                                            # 0 disables the peek path
    centralized_fifo: bool = False          # single-bucket cooling map baseline
    cooling_slots: int = 6
    route_table_slots: int = 0              # leaf-direct route table
                                            # (core/route_table.py mirror):
                                            # > 0 enables a host-trained
                                            # (lo, hi, leaf) fence-segment
                                            # table; an accepted non-scan op
                                            # probes the predicted leaf
                                            # directly, skipping the within-
                                            # subtree inner levels (counted
                                            # in Counters.rt_skips).  Any
                                            # write/split since the last
                                            # train marks the leaf dirty —
                                            # the mesh's leaf version fence —
                                            # so the entry rejects and the op
                                            # pays full descent
                                            # (Counters.rt_mispredicts).
                                            # 0 disables the table entirely.

    # --- synchronization style ---
    rdma_optimistic_reads: bool = False     # version+node+version for ALL reads
                                            # (shared-everything baselines)
    immediate_leaf_writeback: bool = True   # overridden by partitioning
    write_through: bool = False             # every leaf write goes home at
                                            # once (cached copy refreshed, no
                                            # dirty state) — the protocol the
                                            # mesh plane (core/write.py) uses,
                                            # enabling counter-level cross-
                                            # validation between the planes
    single_record_leaves: bool = False      # SMART-like trie: 1 record/leaf
    write_combining: bool = False           # SMART: consolidate concurrent
                                            # writes (Table 2: ~8x fewer)
    write_combine_factor: float = 0.11
    cache_above_m_only: bool = False        # Offload-only variant (Fig. 5)
    onmesh_smo: bool = False                # price structural splits as the
                                            # mesh plane's SMO engine does
                                            # (core/smo.py): the insert ships
                                            # one tiny two-sided message to
                                            # the owning memory server, which
                                            # runs the split next to the data
                                            # — instead of the compute-side
                                            # CAS + read + write-back per
                                            # split node (counted in
                                            # Counters.smo_inserts for
                                            # cross-plane validation,
                                            # benchmarks/fig14_mesh_load.py)

    # --- offload policy ---
    group_offload: bool = False             # per-(memory server, window)
                                            # byte-cost offload decision,
                                            # mirroring the mesh engine's
                                            # per-group cost model
                                            # (core/engine.py): a window's
                                            # live non-scan ops targeting a
                                            # server form one group whose
                                            # predicted fetch bytes (per-
                                            # level miss EMA x node bytes,
                                            # population-capped) are
                                            # compared against per-op RPC
                                            # bytes; counted in
                                            # Counters.offload_groups /
                                            # fetch_groups for cross-plane
                                            # validation
                                            # (benchmarks/fig13_mesh_engine)
    group_ema_decay: float = 0.98           # matches DexMeshConfig.ema_decay
    offload_always: bool = False            # Offload-only variant (Fig. 5)
    offload_epsilon: float = 0.01           # contrary-action probability (§6.1)
    offload_window: int = 50                # moving-average window (§6.1)
    offload_c: float = 1.3                  # cache-op coefficient c (>1, §6.1)

    # --- latency constants (paper §2.3 / §6.1), seconds ---
    t_cached_access: float = 400e-9         # T_c: 1KB cached page access
    t_rdma_read: float = 2e-6               # l_o
    t_rdma_small: float = 1.5e-6
    t_rdma_write: float = 2e-6
    t_rdma_cas: float = 2e-6
    t_rpc_base: float = 4e-6                # l_p floor (two-sided round trip)
    t_mem_search: float = 600e-9            # per-node search on memory-side CPU
    t_local_search: float = 150e-9          # l_s


@dataclasses.dataclass
class OffloadEstimator:
    """Moving-average latency estimates for l_p and l_o (§6.1)."""

    window: int
    l_o: float
    l_p: float

    def observe_read(self, v: float) -> None:
        self.l_o += (v - self.l_o) / self.window

    def observe_rpc(self, v: float) -> None:
        self.l_p += (v - self.l_p) / self.window


class Simulator:
    """Runs a workload against one protocol configuration."""

    def __init__(self, tree: HostBTree, cfg: SimConfig, *, seed: int = 0):
        self.tree = tree
        self.cfg = cfg
        self.rng = np.random.default_rng(seed)
        if cfg.n_compute % max(cfg.route_dispersion, 1):
            raise ValueError("n_compute must be a multiple of route_dispersion")
        n_parts = (
            cfg.n_compute // max(cfg.route_dispersion, 1)
            if cfg.logical_partitioning
            else 1
        )
        lo = int(np.min(tree.K[tree.LV == 0][tree.K[tree.LV == 0] != KEY_MAX]))
        hi = int(
            np.max(
                np.where(
                    tree.K[tree.LV == 0] == KEY_MAX, KEY_MIN, tree.K[tree.LV == 0]
                )
            )
        )
        parts = LogicalPartitions.equal_width(n_parts, lo, hi + 1)
        self.partitions = self._snap_to_leaf_fences(parts)
        cap_nodes = max(8, cfg.cache_bytes // NODE_BYTES)

        def _bias_for(i: int):
            # divergent fleet policy: cache i specializes on the memory
            # servers matching its sibling coordinate (i % d) — the Plane B
            # CachePolicy.admit_bias column-affinity mirror
            if cfg.fleet_col_affinity == 1.0:
                return None
            a = float(cfg.fleet_col_affinity)
            d = max(cfg.route_dispersion, 1)

            def bias(nid: int, _i=i, _a=a, _d=d) -> float:
                ms = int(tree.server[tree.subtree_root_of(nid)])
                return _a if ms % _d == _i % _d else 1.0 / _a

            return bias

        self.caches = [
            ComputeCache(
                cap_nodes,
                parent_of=lambda n: int(tree.parent[n]),
                is_leaf=lambda n: int(tree.LV[n]) == 0,
                p_admit_leaf=cfg.p_admit_leaf,
                eager_admission=cfg.eager_admission,
                n_cooling_buckets=(1 if cfg.centralized_fifo else None),
                cooling_slots=(
                    10**9 if cfg.centralized_fifo else cfg.cooling_slots
                ),
                rng=np.random.default_rng(seed + 17 * i + 1),
                admit_bias=_bias_for(i),
            )
            for i in range(cfg.n_compute)
        ]
        self.counters = [Counters() for _ in range(cfg.n_compute)]
        # write-through coherence state: nodes whose cached copy on server s
        # is version-stale (kept cached, refreshed in place on next access)
        self.stale = [set() for _ in range(cfg.n_compute)]
        # batched-execution state (coherence_batch > 1): per-server nodes
        # already fetched this window, and write-staleness marks deferred
        # to the next window boundary
        self._window_fetched = [set() for _ in range(cfg.n_compute)]
        # peer peeks already issued this window, per cache (budget mirror of
        # the mesh's per-batch CachePolicy.peek_budget)
        self._window_peeks = np.zeros((cfg.n_compute,), dtype=np.int64)
        self._pending_writes = []           # (writer server, leaf)
        # leaves written by the immediately-preceding window — the
        # pipelined overlap set (pipeline_overlap pricing)
        self._prev_window_writes = set()
        self._ops_in_window = 0
        self.mem_busy = np.zeros((cfg.n_mem_servers,), dtype=np.float64)
        self.mem_reqs = np.zeros((cfg.n_mem_servers,), dtype=np.int64)
        self.estimators = [
            OffloadEstimator(cfg.offload_window, cfg.t_rdma_read, cfg.t_rpc_base)
            for _ in range(cfg.n_compute)
        ]
        self.op_clock = np.zeros((cfg.n_compute,), dtype=np.float64)  # cpu-side work time
        self._rr = 0
        # per-op latency sampling into the mesh plane's bucket schema
        # (obs/latency.py): ``_dispatch`` snapshots the owning server's
        # op_clock around each op and adds ``_op_extra`` — the service
        # components op_clock books elsewhere (offload RPC + memory-side
        # walk, a peek sibling's access, a window-coalesced read repriced as
        # the remote fetch the mesh's per-lane ledger charges) — then bins
        # into (op class, outcome path, bucket)
        self.lat_hist = np.zeros(
            (obs_latency.N_CLASSES, obs_latency.N_PATHS,
             obs_latency.N_BUCKETS),
            dtype=np.int64,
        )
        self._op_extra = 0.0
        self._op_offl = False
        self._op_stall = False
        self._op_peek = False
        self._op_miss = False
        # per-group (mesh-engine) offload state: a per-(memory server, block
        # level) miss-rate EMA — the exact analogue of the mesh's
        # ``DexState.miss_ema`` — plus this window's observation
        # accumulators and the current per-server decisions (EMA starts at
        # 1, so like the mesh a cold index begins on the two-sided path)
        lv_blk = cfg.level_m + 1
        self._gema = np.ones((cfg.n_mem_servers, lv_blk), dtype=np.float64)
        self._gwin_miss = np.zeros((cfg.n_mem_servers, lv_blk), np.float64)
        self._gwin_live = np.zeros((cfg.n_mem_servers, lv_blk), np.float64)
        self._gdecision = np.ones((cfg.n_mem_servers,), dtype=bool)
        self._group_active = False
        self._group_obs_off = False
        # leaf-direct route table (route_table_slots > 0), trained host-side
        # by ``train_route_table``: fence segments sorted by low key, plus
        # the set of leaves touched since the last train — the sim's
        # stand-in for the mesh plane's per-leaf version fence
        self._rt_lo = np.zeros((0,), dtype=np.int64)
        self._rt_hi = np.zeros((0,), dtype=np.int64)
        self._rt_leaf = np.zeros((0,), dtype=np.int64)
        self._rt_dirty: set = set()

    # -- helpers ---------------------------------------------------------------

    def _snap_to_leaf_fences(self, parts: LogicalPartitions) -> LogicalPartitions:
        """Snap partition boundaries to leaf fence keys so every leaf is
        exclusively owned by one partition (paper §4: boundaries are picked
        from lowest-inner-node keys, i.e. leaf fence keys)."""
        b = parts.boundaries.copy()
        for i in range(1, b.size - 1):
            leaf = self.tree.search_path(int(b[i]))[-1]
            b[i] = int(self.tree.FLO[leaf])
        b = np.unique(b)
        if b.size < 2 or b[0] != KEY_MIN or b[-1] != KEY_MAX:
            b = np.concatenate([[KEY_MIN], b[(b > KEY_MIN) & (b < KEY_MAX)], [KEY_MAX]])
        return LogicalPartitions(np.asarray(b, dtype=np.int64))

    def reset_counters(self) -> None:
        """Zero all accounting after a warmup phase (paper §8.1: 10M warmup
        ops precede measurement)."""
        self.counters = [Counters() for _ in range(self.cfg.n_compute)]
        self.mem_busy[:] = 0.0
        self.mem_reqs[:] = 0
        self.op_clock[:] = 0.0
        self.lat_hist[:] = 0
        for cache in self.caches:
            cache.stats.reset()
            cache.cooling.lock_acquires[:] = 0

    def _owner(self, key: int) -> int:
        if self.cfg.logical_partitioning:
            p = int(self.partitions.owner_of(np.asarray([key]))[0])
            d = max(self.cfg.route_dispersion, 1)
            if d > 1:
                # one of the partition's d caches, chosen per op — the mesh
                # plane's within-row dispersion (requests reach the route
                # row's chips by source lane, not by key)
                return (p * d + int(self.rng.integers(d))) % self.cfg.n_compute
            return p % self.cfg.n_compute
        self._rr = (self._rr + 1) % self.cfg.n_compute
        return self._rr

    def _is_shared(self, nid: int) -> bool:
        if not self.cfg.logical_partitioning:
            return True  # shared-everything: every node is shared
        return bool(
            self.partitions.is_shared_range(
                np.asarray([self.tree.FLO[nid]]), np.asarray([self.tree.FHI[nid]])
            )[0]
        )

    def _write_coherence(self, server: int, nid: int, *,
                         drop_self: bool = False) -> None:
        """Write-through-and-invalidate (core/write.py): after a leaf write,
        every *other* cache serving the partition (``route_dispersion`` > 1)
        holds a version-stale copy — it stays cached but must pay one remote
        read to refresh on its next access.  The writer's own copy is
        refreshed in place (update) or dropped (insert: the key set
        shifted, ``drop_self``).  Under batched pricing
        (``coherence_batch`` > 1) sibling staleness flushes at the window
        boundary — the mesh's pmax version sync — so same-window writers
        of one leaf all end up fresh."""
        self.stale[server].discard(nid)
        if drop_self and self.caches[server].invalidate(nid):
            self.counters[server].coherence_invalidations += 1
        if self.cfg.coherence_batch > 1:
            self._pending_writes.append((server, nid))
            return
        # the version table is global: every other cache's copy goes stale,
        # not just the writer's dispersion group (scans cache across
        # partitions), matching _flush_window's batched flush
        for s in range(self.cfg.n_compute):
            if s != server and nid in self.caches[s]:
                self.stale[s].add(nid)
                self.counters[s].coherence_invalidations += 1

    def _flush_window(self) -> None:
        """Window boundary: publish deferred staleness (every cache that is
        not one of the window's writers of a leaf goes stale on it) and
        clear the per-window read-coalescing sets."""
        writers = {}
        for server, nid in self._pending_writes:
            writers.setdefault(nid, set()).add(server)
        for nid, ws in writers.items():
            for s in range(self.cfg.n_compute):
                if s not in ws and nid in self.caches[s]:
                    self.stale[s].add(nid)
                    self.counters[s].coherence_invalidations += 1
        # rotate the overlap set: the next window's descents overlap THIS
        # window's write round (pipeline_overlap pricing)
        self._prev_window_writes = {nid for _, nid in self._pending_writes}
        self._pending_writes.clear()
        for w in self._window_fetched:
            w.clear()
        self._window_peeks[:] = 0

    def _cacheable(self, nid: int) -> bool:
        cfg = self.cfg
        if not cfg.caching:
            return False
        lvl = int(self.tree.LV[nid])
        if cfg.cache_above_m_only:
            return lvl > cfg.level_m
        if lvl == 0:
            return cfg.cache_leaves
        return True

    def _shared_write(self, server: int) -> None:
        """Leaf write in shared-everything mode: RDMA CAS lock + write-back
        (optionally write-combined, SMART-style)."""
        cfg = self.cfg
        c = self.counters[server]
        f = cfg.write_combine_factor if cfg.write_combining else 1.0
        c.rdma_cas += f
        c.bytes += 8 * f
        c.rdma_write += f
        c.bytes += NODE_BYTES * f
        # lock release is an RDMA WRITE of the lock word (Ziegler et al. [49])
        c.rdma_write += f
        c.bytes += SMALL_READ_BYTES * f
        self.op_clock[server] += f * (
            cfg.t_rdma_cas + cfg.t_rdma_write + cfg.t_rdma_small
        )

    def _remote_read(self, server: int, nid: int, shared: bool) -> float:
        """One cache::remote_read (Algorithm 1, lines 1–10).  Returns latency."""
        c = self.counters[server]
        cfg = self.cfg
        lat = 0.0
        if shared or cfg.rdma_optimistic_reads:
            c.add_small_read()
            c.add_read()
            c.add_small_read()
            lat = cfg.t_rdma_read + 2 * cfg.t_rdma_small
        else:
            c.add_read()
            lat = cfg.t_rdma_read
        self.estimators[server].observe_read(cfg.t_rdma_read)
        self._op_miss = True
        return lat

    def _deserve_offload(self, server: int, levels_left: int) -> bool:
        cfg = self.cfg
        if cfg.offload_always:
            return True
        est = self.estimators[server]
        rdma_cost = levels_left * (est.l_o + cfg.t_local_search) * cfg.offload_c
        decision = est.l_p < rdma_cost
        if self.rng.random() < cfg.offload_epsilon:
            decision = not decision
        return decision

    def _offload(self, server: int, nid: int, levels_left: int) -> None:
        """Push the remaining traversal to the memory server (§6.2)."""
        cfg = self.cfg
        c = self.counters[server]
        c.add_rpc()
        ms = int(self.tree.server[nid])
        service = levels_left * cfg.t_mem_search
        self.mem_busy[ms] += service
        self.mem_reqs[ms] += 1
        self.estimators[server].observe_rpc(cfg.t_rpc_base + service)
        # the RPC round trip and the owner's walk never touch op_clock
        # (they run memory-side); the per-op latency sample still pays them
        self._op_extra += cfg.t_rpc_base + service
        self._op_offl = True

    # -- leaf-direct route table (core/route_table.py mirror) --------------------

    def _live_leaves(self) -> List[int]:
        """Leaves reachable from the root (delete's lazy merges can orphan
        array rows, so a plain LV == 0 scan over-collects)."""
        out: List[int] = []
        stack = [self.tree.root]
        while stack:
            nid = stack.pop()
            if int(self.tree.LV[nid]) == 0:
                out.append(nid)
            else:
                for i in range(int(self.tree.NK[nid])):
                    stack.append(int(self.tree.C[nid, i]))
        return out

    def train_route_table(self, slots: Optional[int] = None) -> int:
        """(Re)train the leaf-direct table from the host tree's live leaves,
        exactly as ``core/route_table.py`` trains from the mesh pool: fence
        segments sorted by low key; when leaves outnumber the slots, the
        leaves of the demand-hottest partitions are kept first (a
        partition's demand is the op count its caches have served — the
        ``DexState.route_demand`` analogue).  Returns the entry count."""
        r = int(self.cfg.route_table_slots if slots is None else slots)
        self._rt_lo = np.zeros((0,), dtype=np.int64)
        self._rt_hi = np.zeros((0,), dtype=np.int64)
        self._rt_leaf = np.zeros((0,), dtype=np.int64)
        self._rt_dirty = set()
        if r <= 0:
            return 0
        leaves = self._live_leaves()
        lo = np.array([int(self.tree.FLO[n]) for n in leaves], dtype=np.int64)
        order = np.argsort(lo, kind="stable")
        leaves = [leaves[i] for i in order]
        lo = lo[order]
        hi = np.array([int(self.tree.FHI[n]) for n in leaves], dtype=np.int64)
        if len(leaves) > r:
            d = max(self.cfg.route_dispersion, 1)
            part = self.partitions.owner_of(lo)
            demand = np.array(
                [
                    sum(
                        self.counters[(int(p) * d + j) % self.cfg.n_compute].ops
                        for j in range(d)
                    )
                    for p in part
                ],
                dtype=np.int64,
            )
            # hot partitions first; the stable sort keeps key order within a
            # partition so the kept prefix is a union of hot key ranges
            keep = np.sort(np.argsort(-demand, kind="stable")[:r])
            leaves = [leaves[i] for i in keep]
            lo, hi = lo[keep], hi[keep]
        self._rt_lo = lo
        self._rt_hi = hi
        self._rt_leaf = np.array(leaves, dtype=np.int64)
        return len(leaves)

    def poison_route_table(self) -> None:
        """Adversarial-table arm (``route_table.poison_route_table`` mirror):
        mark every entry's leaf dirty so the fence rejects every guess — the
        contract under test is bit-identical results to descent-only."""
        self._rt_dirty.update(int(n) for n in self._rt_leaf)

    def _rt_predict(self, key: int) -> int:
        """Leaf of the covering, fence-fresh entry for ``key``; -1 when the
        table rejects (the caller books the mispredict)."""
        n = self._rt_lo.size
        if n == 0:
            return -1
        i = min(
            max(int(np.searchsorted(self._rt_lo, key, side="right")) - 1, 0),
            n - 1,
        )
        leaf = int(self._rt_leaf[i])
        if (
            int(self._rt_lo[i]) <= key < int(self._rt_hi[i])
            and leaf not in self._rt_dirty
        ):
            return leaf
        return -1

    def _rt_touch(self, *nids: int) -> None:
        """Mark leaves written/split since the last train — the version bump
        the mesh's write path applies, which fences out their entries."""
        if self.cfg.route_table_slots > 0:
            self._rt_dirty.update(int(n) for n in nids)

    # -- operations --------------------------------------------------------------

    def run(
        self,
        ops: np.ndarray,
        keys: np.ndarray,
        scan_len: int = 100,
        scan_lens: Optional[np.ndarray] = None,
        *,
        group_policy: Optional[str] = None,
    ) -> None:
        """Execute a workload.  ``ops``: array of {0:lookup, 1:update,
        2:insert, 3:scan, 4:delete}; ``keys``: target keys.  ``scan_lens``
        (per-op record counts, e.g. YCSB-E's uniform lengths) overrides the
        fixed ``scan_len`` when given.

        With ``SimConfig.group_offload`` the stream executes in windows of
        ``coherence_batch`` ops (the mesh's batch): each window's live
        non-scan ops per memory server form one cost group, decided and
        counted *before* the window runs, exactly as the engine decides per
        batch (core/engine.py).  ``group_policy`` overrides the cost model
        for this call — ``"fetch"`` forces one-sided (and, like the mesh's
        ``policy="fetch"``, mints no groups), ``"offload"`` forces
        two-sided; ``None`` applies the byte-cost comparison."""
        if self.cfg.group_offload:
            w = max(self.cfg.coherence_batch, 1)
            self._group_active = True
            try:
                for lo in range(0, len(ops), w):
                    hi = min(lo + w, len(ops))
                    self._group_window_begin(
                        ops[lo:hi], keys[lo:hi], group_policy
                    )
                    for i in range(lo, hi):
                        self._dispatch(i, ops[i], keys[i], scan_len, scan_lens)
                    self._flush_window()
                    self._group_window_end()
            finally:
                self._group_active = False
            return
        for i, (op, key) in enumerate(zip(ops, keys)):
            self._dispatch(i, op, key, scan_len, scan_lens)
            if self.cfg.coherence_batch > 1:
                self._ops_in_window += 1
                if self._ops_in_window >= self.cfg.coherence_batch:
                    self._flush_window()
                    self._ops_in_window = 0

    def _dispatch(self, i, op, key, scan_len, scan_lens) -> None:
        key = int(key)
        server = self._owner(key)
        self.counters[server].ops += 1
        t0 = self.op_clock[server]
        self._op_extra = 0.0
        self._op_offl = self._op_stall = False
        self._op_peek = self._op_miss = False
        if op == 0:
            self._op_lookup(server, key)
        elif op == 1:
            self._op_update(server, key)
        elif op == 2:
            self._op_insert(server, key)
        elif op == 3:
            n = int(scan_lens[i]) if scan_lens is not None else scan_len
            self._op_scan(server, key, n)
        elif op == 4:
            self._op_delete(server, key)
        else:
            raise ValueError(f"bad op {op}")
        # latency sample: this server's clock delta plus the off-clock
        # service components; path priority mirrors the mesh ledger's
        # (stale_forced > offload > peer_peek > remote_fetch > cache_hit;
        # the simulator has no shed lane).  Deletes share the update class.
        lat = (self.op_clock[server] - t0) + self._op_extra
        cls = 1 if op == 4 else min(int(op), obs_latency.N_CLASSES - 1)
        if self._op_stall:
            path = obs_latency.PATHS.index("stale_forced")
        elif self._op_offl:
            path = obs_latency.PATHS.index("offload")
        elif self._op_peek:
            path = obs_latency.PATHS.index("peer_peek")
        elif self._op_miss:
            path = obs_latency.PATHS.index("remote_fetch")
        else:
            path = obs_latency.PATHS.index("cache_hit")
        self.lat_hist[cls, path, int(obs_latency.bucket_index(lat))] += 1

    # -- per-group offload machinery (SimConfig.group_offload) ----------------

    def _mem_server_of(self, key: int) -> int:
        """Memory server owning the level-M subtree of ``key``'s leaf."""
        leaf = self.tree.search_path(key)[-1]
        return int(self.tree.server[self.tree.subtree_root_of(leaf)])

    def _group_level_nodes(self) -> np.ndarray:
        """Per-(server, mesh level) block-node population; mesh level 0 is
        the subtree root (tree level M), the last is the leaves.  Caps the
        group cost model's predicted fetch bytes: a batch's coalesced reads
        never exceed a level's distinct nodes."""
        m = self.cfg.level_m
        lv = self.tree.LV
        sv = self.tree.server
        out = np.zeros((self.cfg.n_mem_servers, m + 1), np.float64)
        for l_mesh in range(m + 1):
            mask = (lv == m - l_mesh) & (sv >= 0)
            if mask.any():
                np.add.at(out, (sv[mask] % self.cfg.n_mem_servers, l_mesh), 1.0)
        return out

    def _group_window_begin(self, ops, keys, group_policy) -> None:
        """Decide (and count) this window's per-server cost groups from its
        live non-scan population — the sim-side mirror of the engine's
        per-(destination column) decision on psum'd live-lane counts."""
        cfg = self.cfg
        live = np.zeros((cfg.n_mem_servers,), np.int64)
        # the tree is static while a window's population is taken, and
        # skewed windows repeat keys heavily: memoize the per-key server to
        # avoid paying a second full tree walk per op
        servers: Dict[int, int] = {}
        for op, key in zip(ops, keys):
            if op == 3:          # scans never offload (§7)
                continue
            k = int(key)
            ms = servers.get(k)
            if ms is None:
                ms = servers[k] = self._mem_server_of(k)
            live[ms] += 1
        if group_policy == "fetch":
            # forced one-sided windows mint no groups (mesh policy="fetch")
            self._gdecision[:] = False
            return
        if group_policy == "offload":
            self._gdecision[:] = True
        else:
            caps = np.minimum(
                live[:, None].astype(np.float64), self._group_level_nodes()
            )
            fetch_cost = (
                (caps * self._gema).sum(axis=1)
                * ENGINE_NODE_ROW_BYTES * cfg.offload_c
            )
            rpc_cost = live.astype(np.float64) * ENGINE_RPC_BYTES
            self._gdecision = fetch_cost > rpc_cost
        c = self.counters[0]   # groups are index-global: count them once
        c.offload_groups += int((self._gdecision & (live > 0)).sum())
        c.fetch_groups += int((~self._gdecision & (live > 0)).sum())

    def _group_window_end(self) -> None:
        """Fold this window's per-(server, level) miss observations into the
        EMA (decay matches the mesh's ``DexMeshConfig.ema_decay``); servers
        whose window held no fetch-path ops keep their estimate, exactly
        like an offloaded mesh column."""
        obs = self._gwin_live > 0
        rate = np.where(
            obs, self._gwin_miss / np.maximum(self._gwin_live, 1.0), 0.0
        )
        d = self.cfg.group_ema_decay
        self._gema = np.where(obs, d * self._gema + (1 - d) * rate, self._gema)
        self._gwin_miss[:] = 0.0
        self._gwin_live[:] = 0.0

    def _gobs(self, nid: int, hit: bool) -> None:
        """One fetch-path block-level cache observation (scan traversals are
        excluded, as on the mesh)."""
        if not self._group_active or self._group_obs_off:
            return
        lvl = int(self.tree.LV[nid])
        if lvl > self.cfg.level_m:
            return
        ms = int(self.tree.server[nid]) % self.cfg.n_mem_servers
        self._gwin_live[ms, self.cfg.level_m - lvl] += 1
        if not hit:
            self._gwin_miss[ms, self.cfg.level_m - lvl] += 1

    # Traversal core: walk the ground-truth path, consulting the cache and
    # issuing remote verbs per the configured protocol.  Returns the list of
    # (node, was_cached) and whether the op was completed via offload.
    def _traverse(self, server: int, key: int, *, for_write: bool,
                  is_insert: bool = False,
                  peek_ok: bool = True,
                  rt_ok: bool = True) -> Tuple[List[Tuple[int, bool]], bool]:
        cfg = self.cfg
        cache = self.caches[server]
        c = self.counters[server]
        path = self.tree.search_path(key)
        height = len(path)
        visited: List[Tuple[int, bool]] = []
        group_tried = False
        # leaf-direct route table: predict once per op (scans are never
        # eligible, matching the mesh engine's eligibility mask); counters
        # are booked at the subtree boundary below so group-offloaded ops —
        # which the mesh excludes from eligibility — book nothing
        rt_guess = cfg.route_table_slots > 0 and rt_ok and self._rt_lo.size > 0
        rt_leaf = self._rt_predict(key) if rt_guess else -1
        rt_counted = False
        for depth, nid in enumerate(path):
            lvl = int(self.tree.LV[nid])
            if (
                cfg.pipeline_overlap
                and lvl == 0
                and nid in self._prev_window_writes
            ):
                # pipelined overlap window: this leaf was written by the
                # immediately-preceding window, so a descent that overlapped
                # that window's write round read it one batch stale.  The
                # version check catches it in the back half and the lane
                # re-resolves two-sided against the owning memory server —
                # the conservative conflict fallback (scans stall-shed and
                # retry at the same price)
                c.pipeline_stalls += 1
                self._op_stall = True
                self._offload(server, nid, 1)
                return visited, True
            if (
                self._group_active
                and cfg.offloading
                and not group_tried
                and lvl <= cfg.level_m
                and self._gdecision[int(self.tree.server[nid])
                                    % cfg.n_mem_servers]
            ):
                # per-group mode: the whole column's traffic goes two-sided
                # at the first block-level node, before any cache probe
                # (the mesh's offloaded lanes skip the descent entirely);
                # decided once per op.  Only inserts that would split fall
                # back to the one-sided path (§6 — on the mesh they shed
                # STATUS_SPLIT to core/smo.py; offloaded updates always
                # apply memory-side)
                group_tried = True
                if for_write and is_insert and self.tree.would_split(key):
                    c.offload_fallbacks += 1
                else:
                    self._offload(server, nid, lvl + 1)
                    return visited, True
            if rt_guess and lvl <= cfg.level_m and not rt_counted:
                # subtree boundary: the op survived the offload decision, so
                # it is rt-eligible — book the accept/reject outcome once
                rt_counted = True
                if rt_leaf < 0:
                    c.rt_mispredicts += 1
            if rt_leaf >= 0 and 1 <= lvl <= cfg.level_m:
                # accepted leaf-direct probe: the within-subtree inner
                # levels are never fetched — the lane lands straight on the
                # (fence-verified) leaf, which is processed normally below
                c.rt_skips += 1
                continue
            if cfg.caching and self._cacheable(nid):
                r = cache.lookup(nid)
                if r == "hit":
                    if nid in self.stale[server]:
                        # version-stale copy: one remote read refreshes it
                        # in place (no re-admission dice), mirroring the
                        # mesh's version-checked probe + in-place refresh
                        lat = self._remote_read(
                            server, nid, self._is_shared(nid)
                        )
                        self.op_clock[server] += lat
                        self.stale[server].discard(nid)
                        self._window_fetched[server].add(nid)
                        self._gobs(nid, False)
                        visited.append((nid, True))
                        continue
                    c.local_accesses += 1
                    self.op_clock[server] += cfg.t_cached_access
                    self._gobs(nid, True)
                    visited.append((nid, True))
                    continue
            if (
                cfg.coherence_batch > 1
                and nid in self._window_fetched[server]
            ):
                # batched read coalescing: this node was already fetched in
                # the current window — the row is on chip, no second read
                # (the mesh's duplicate-gid request combining); admission
                # still re-rolls its dice per access
                c.local_accesses += 1
                self.op_clock[server] += cfg.t_cached_access
                if cfg.caching and self._cacheable(nid):
                    cache.admit(nid, ignore_parent=(rt_leaf >= 0 and lvl == 0))
                # a window-coalesced read is still a cache-probe miss on the
                # mesh (duplicate lanes of one batch all miss, then share
                # one coalesced message) — the EMA counts the probe, and the
                # latency sample re-prices it as the remote read the mesh's
                # duplicate lane models (the clock above only paid a cached
                # access, but the lane still waited on the coalesced fetch)
                self._op_extra += cfg.t_rdma_read - cfg.t_cached_access
                self._op_miss = True
                self._gobs(nid, False)
                visited.append((nid, cfg.caching and nid in cache))
                continue
            shared = self._is_shared(nid)
            levels_left = lvl + 1  # nodes from here to leaf inclusive
            if (
                not self._group_active
                and cfg.offloading
                and not shared
                and lvl <= cfg.level_m
                and self._deserve_offload(server, levels_left)
            ):
                # SMO fallback: a write that would split cannot be offloaded
                if for_write and self.tree.would_split(key):
                    c.offload_fallbacks += 1
                else:
                    self._offload(server, nid, levels_left)
                    return visited, True
            if (
                cfg.fleet_peek_budget > 0
                and lvl == 0
                and peek_ok
                and not for_write
                and self._window_peeks[server] < cfg.fleet_peek_budget
            ):
                # peer peek (core/fleet_cache.py MSG_PEEK mirror): instead of
                # paying the remote row read, ask the sibling cache that
                # specializes on this leaf's memory server — one compute-to-
                # compute message riding the window's fused round.  A
                # version-fresh sibling copy answers; a stale or absent one
                # is a peer miss resolved by the owning server's walk next
                # to the data.  Peeked lanes fetch and admit nothing here.
                d = max(cfg.route_dispersion, 1)
                ms = int(self.tree.server[nid]) % cfg.n_mem_servers
                sib = (server // d) * d + ms % d
                if sib != server:
                    self._window_peeks[server] += 1
                    self._op_peek = True
                    c.bytes += RPC_BYTES
                    self.op_clock[server] += cfg.t_rpc_base
                    if nid in self.caches[sib] and nid not in self.stale[sib]:
                        c.peer_hits += 1
                        self.counters[sib].local_accesses += 1
                        self.op_clock[sib] += cfg.t_cached_access
                        # the sibling's lookup runs off this op's clock
                        self._op_extra += cfg.t_cached_access
                    else:
                        c.peer_misses += 1
                        service = (lvl + 1) * cfg.t_mem_search
                        self.mem_busy[ms] += service
                        self.mem_reqs[ms] += 1
                        self._op_extra += service
                    self._gobs(nid, False)
                    visited.append((nid, False))
                    continue
            lat = self._remote_read(server, nid, shared)
            self.op_clock[server] += lat
            if cfg.coherence_batch > 1:
                self._window_fetched[server].add(nid)
            if self._cacheable(nid):
                # a leaf reached through an accepted route-table probe has no
                # cached ancestors to swizzle under — the table entry IS the
                # path, so admission falls back to the dice alone
                cache.admit(nid, ignore_parent=(rt_leaf >= 0 and lvl == 0))
            self._gobs(nid, False)
            visited.append((nid, False))
        return visited, False

    def _op_lookup(self, server: int, key: int) -> Optional[int]:
        visited, offloaded = self._traverse(server, key, for_write=False)
        if offloaded:
            return self.tree.get(key)
        self.op_clock[server] += self.cfg.t_local_search
        return self.tree.get(key)

    def _op_update(self, server: int, key: int) -> bool:
        cfg = self.cfg
        cache = self.caches[server]
        c = self.counters[server]
        visited, offloaded = self._traverse(server, key, for_write=True)
        ok = self.tree.update(key, key ^ 0x5A5A)
        if offloaded:
            # memory-side update; invalidate any cached copies (rare: path-
            # aware caching means the subpath is usually uncached, §6.2)
            leaf = self.tree.search_path(key)[-1]
            self._rt_touch(leaf)
            if cache.invalidate(leaf):
                c.coherence_invalidations += 1
            return ok
        leaf, was_cached = visited[-1]
        self._rt_touch(leaf)
        shared = self._is_shared(leaf)
        if cfg.logical_partitioning and not shared:
            if cfg.write_through:
                c.add_write()                # write-through: always go home
                # pipelined engine: the leaf write-back rides the fused
                # round that overlaps the NEXT window's descents — the verb
                # still crosses the NIC (bandwidth / message-rate caps
                # unchanged) but its latency leaves the op's critical path
                # (cost_model thread cap)
                if not cfg.pipeline_overlap:
                    self.op_clock[server] += cfg.t_rdma_write
                self._write_coherence(server, leaf)
            elif was_cached or (self.cfg.caching and leaf in cache):
                cache.mark_dirty(leaf)       # deferred write-back
            else:
                c.add_write()                # not cached: write home now
                self.op_clock[server] += cfg.t_rdma_write
        else:
            # shared-everything: RDMA lock + write back + unlock
            self._shared_write(server)
        return ok

    def _op_insert(self, server: int, key: int) -> None:
        cfg = self.cfg
        cache = self.caches[server]
        c = self.counters[server]
        visited, offloaded = self._traverse(server, key, for_write=True,
                                            is_insert=True)
        if (
            cfg.onmesh_smo
            and not offloaded
            and self.tree.would_split(key)
        ):
            # the mesh SMO engine (core/smo.py): the insert ships one tiny
            # (key, value) message to the owning memory server, which runs
            # the split next to the data — no compute-side CAS/read/write
            # per split node, no pool rebuild; the writer's own cached leaf
            # copy drops (key set shifted) and siblings' copies go stale
            _, split_nodes = self.tree.insert(key, key)
            c.add_rpc()
            leaf = self.tree.search_path(key)[-1]
            self._rt_touch(leaf, *split_nodes)
            ms = int(self.tree.server[leaf])
            service = (len(split_nodes) + 1) * self.cfg.t_mem_search
            self.mem_busy[ms] += service
            self.mem_reqs[ms] += 1
            c.smo_inserts += 1
            self._write_coherence(server, leaf, drop_self=True)
            for snode in split_nodes:
                self._write_coherence(server, snode, drop_self=True)
            return
        _, split_nodes = self.tree.insert(key, key)
        if cfg.route_table_slots > 0:
            self._rt_touch(self.tree.search_path(key)[-1], *split_nodes)
        if offloaded:
            leaf = self.tree.search_path(key)[-1]
            if cache.invalidate(leaf):
                c.coherence_invalidations += 1
            return
        # split handling (§7 Insert)
        for snode in split_nodes:
            shared = self._is_shared(snode)
            if shared:
                # global lock + freshness check on the shared parent
                c.add_cas()
                c.add_read()
                c.add_write()
                self.op_clock[server] += (
                    cfg.t_rdma_cas + cfg.t_rdma_read + cfg.t_rdma_write
                )
            else:
                if cfg.caching and not cfg.write_through and snode in cache:
                    cache.mark_dirty(snode)
                else:
                    c.add_write()
                    self.op_clock[server] += cfg.t_rdma_write
        # leaf write itself
        leaf = self.tree.search_path(key)[-1]
        shared = self._is_shared(leaf)
        if cfg.logical_partitioning and not shared:
            if cfg.caching and not cfg.write_through and leaf in cache:
                cache.mark_dirty(leaf)
            else:
                c.add_write()
                # write-through + pipelined: the insert's leaf write rides
                # the overlapped fused round like an update's (latency off
                # the critical path, verb still counted)
                if not (cfg.write_through and cfg.pipeline_overlap):
                    self.op_clock[server] += cfg.t_rdma_write
                if cfg.write_through:
                    # an insert shifts the leaf's key set: the writer drops
                    # its own copy, siblings' copies go stale
                    self._write_coherence(server, leaf, drop_self=True)
        else:
            self._shared_write(server)

    def _op_delete(self, server: int, key: int) -> None:
        self._op_update(server, key)  # same remote-verb profile as update
        self.tree.delete(key)

    def _op_scan(self, server: int, key: int, count: int) -> None:
        """Fence-key-subdivided scan (§7 Range Query): repeated lookups, no
        offloading."""
        cfg = self.cfg
        cache = self.caches[server]
        c = self.counters[server]
        hops = self.tree.scan(key, count)
        if cfg.single_record_leaves:
            # SMART-like: every record is its own leaf -> one remote read per
            # record (minus cache hits on the radix path, approximated by the
            # inner-node hit rate)
            total = sum(len(ks) for _, ks in hops)
            for _ in range(total):
                c.add_read()
                self.op_clock[server] += cfg.t_rdma_read
            return
        first = True
        for leaf, _ks in hops:
            # each hop is a fresh root-to-leaf traversal; offloading disabled
            # and no group-EMA observations (scans leave the mesh EMA alone)
            save = self.cfg.offloading
            self.cfg.offloading = False
            self._group_obs_off = True
            self._traverse(server, int(self.tree.K[leaf, 0]) if not first else key,
                           for_write=False, peek_ok=False, rt_ok=False)
            self._group_obs_off = False
            self.cfg.offloading = save
            first = False
            self.op_clock[server] += cfg.t_local_search

    # -- reporting ---------------------------------------------------------------

    def totals(self) -> Counters:
        out = Counters()
        for c in self.counters:
            out.ops += c.ops
            out.rdma_read += c.rdma_read
            out.rdma_small_read += c.rdma_small_read
            out.rdma_write += c.rdma_write
            out.rdma_cas += c.rdma_cas
            out.two_sided += c.two_sided
            out.bytes += c.bytes
            out.local_accesses += c.local_accesses
            out.offload_fallbacks += c.offload_fallbacks
            out.coherence_invalidations += c.coherence_invalidations
            out.smo_inserts += c.smo_inserts
            out.offload_groups += c.offload_groups
            out.fetch_groups += c.fetch_groups
            out.pipeline_stalls += c.pipeline_stalls
            out.peer_hits += c.peer_hits
            out.peer_misses += c.peer_misses
            out.rt_skips += c.rt_skips
            out.rt_mispredicts += c.rt_mispredicts
        return out

    def cache_stats(self):
        return [c.stats for c in self.caches]

    def repartition(self, new_parts: LogicalPartitions) -> Dict[str, float]:
        """Logical repartitioning (§4, Fig. 10): flush dirty pages, adjust
        boundaries, drop caches of moved ranges.  Returns cost summary."""
        new_parts = self._snap_to_leaf_fences(new_parts)
        flushed = 0
        for cache in self.caches:
            flushed += cache.flush_dirty()
        moved = self.partitions.assignment_diff(new_parts)
        self.partitions = new_parts
        # moved ranges must re-warm: invalidate everything for simplicity
        for cache in self.caches:
            cache.drop_all()
        # the route table follows the caches: a boundary install bumps the
        # moved leaves' versions on the mesh, so conservatively drop every
        # entry here (the mesh controller retrains right after an install;
        # callers mirror that with train_route_table())
        self._rt_lo = self._rt_lo[:0]
        self._rt_hi = self._rt_hi[:0]
        self._rt_leaf = self._rt_leaf[:0]
        self._rt_dirty = set()
        flush_time = flushed * (NODE_BYTES / 12.5e9 + 2e-6)  # 100Gbps + per-op
        return {
            "dirty_pages_flushed": float(flushed),
            "flush_seconds_single_thread": float(flush_time),
            "fraction_keyspace_moved": float(moved),
        }
