"""Subtree-blocked memory pool (Plane B): the paper's level-M placement.

The paper stores every subtree rooted at level M on a single memory server
(§3 Index Placement) so offloaded traversals never chase pointers across
servers.  On a TPU mesh the equivalent is a *blocked* layout:

    pool_keys    : [n_subtrees, subtree_cap, FANOUT]   -- axis 0 sharded over
    pool_children: [n_subtrees, subtree_cap, FANOUT]      the `model` axis
    pool_values  : [n_subtrees, subtree_cap, FANOUT]

with all levels above M ("top tree") replicated on every chip — these are
the paper's root-side nodes that are effectively always cached.  Local node
ids inside a subtree are level-ordered (root = 0) so the offload executor
(and the Pallas ``subtree_walk`` kernel) can traverse entirely within one
VMEM-resident block.

**Free-list headroom (the on-mesh SMO allocation layer).**  Each block is
built ``headroom`` fraction larger than the bulk layout needs; the extra
slots ``[base_cap, subtree_cap)`` form a per-subtree bump free-list from
which the on-mesh SMO engine (core/smo.py) allocates sibling nodes for
device-side leaf/inner splits.  The watermark lives in
``DexState.n_alloc`` (one int per subtree, sharded with the pool); when a
subtree's watermark hits ``subtree_cap`` its splits fall back to the host
rebuild path (``core/write.py::drain_splits``).  Because splits relocate
leaves out of the dense bulk order, sibling-leaf iteration (core/scan.py)
follows the explicit successor table seeded by :func:`initial_succ` rather
than leaf-id arithmetic.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.nodes import FANOUT, KEY_MAX, KEY_MIN, NULL


class SubtreePool(NamedTuple):
    """Pool arrays.  ``top_*`` are replicated; ``pool_*`` shard on axis 0."""

    # top tree (levels > M), flat ids in build order, root last
    top_keys: jax.Array       # [T, FANOUT] int64
    top_children: jax.Array   # [T, FANOUT] int32; at level M+1 the entries
                              # are *subtree ids* (pool axis-0 indices)
    # subtree blocks (levels M..0)
    pool_keys: jax.Array      # [S, C, FANOUT] int64
    pool_children: jax.Array  # [S, C, FANOUT] int32 (subtree-local ids)
    pool_values: jax.Array    # [S, C, FANOUT] int64 (leaf payloads)


@dataclasses.dataclass(frozen=True)
class PoolMeta:
    level_m: int              # subtree root level (0 = leaves only)
    per_node: int             # fill-factor entries per node at build
    subtree_cap: int          # nodes per subtree block (incl. headroom)
    n_subtrees: int           # real subtrees (<= padded S)
    n_subtrees_padded: int
    top_height: int           # levels above M (0 => single-subtree tree)
    n_keys: int
    leaf_start: int           # local id of first leaf within a block
    base_cap: int = 0         # nodes per block used by the bulk layout;
    #                           [base_cap, subtree_cap) is SMO headroom
    subtree_leaves: int = 0   # leaves per block at build (0 = the dense
    #                           default per_node**level_m); smaller blocks
    #                           leave block roots separator room for splits

    @property
    def leaves_per_subtree(self) -> int:
        return self.subtree_leaves or self.per_node**self.level_m

    @property
    def levels_in_subtree(self) -> int:
        return self.level_m + 1

    @property
    def min_leaf_fill(self) -> int:
        """Smallest key count a *non-last* leaf can hold: bulk-built leaves
        carry ``per_node`` keys and an on-mesh split leaves each half with at
        least ``FANOUT // 2`` (core/smo.py splits only overflowing rows)."""
        return min(self.per_node, FANOUT // 2)

    @property
    def headroom_frac(self) -> float:
        """Free-list fraction this pool was built with (for rebuilds)."""
        if self.base_cap <= 0:
            return 0.0
        return (self.subtree_cap - self.base_cap) / self.base_cap

    def node_gid(self, subtree: jax.Array, local: jax.Array) -> jax.Array:
        """Global node id used as the cache tag."""
        return subtree.astype(jnp.int64) * self.subtree_cap + local


def _level_offsets(
    per_node: int, level_m: int, subtree_leaves: "int | None" = None
) -> np.ndarray:
    """Local-id offset of each subtree level: level M at 0, leaves last.

    ``subtree_leaves`` overrides the dense default of ``per_node**level_m``
    leaves per block — fewer leaves per subtree build the block's root with
    fewer children, leaving separator room for on-mesh splits (and spread a
    dataset over more subtrees / memory columns).
    """
    if subtree_leaves is None:
        subtree_leaves = per_node**level_m
    counts = [subtree_leaves]                      # level 0 (leaves) first
    for _ in range(level_m):
        counts.append(-(-counts[-1] // per_node))
    counts[-1] = 1                                 # block root
    sizes = counts[::-1]                           # level M..0 counts
    return np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)


DEFAULT_HEADROOM = 0.5


def build_pool(
    keys: np.ndarray,
    values: Optional[np.ndarray] = None,
    *,
    level_m: int = 1,
    fill: float = 0.7,
    n_shards: int = 1,
    headroom: float = DEFAULT_HEADROOM,
    subtree_leaves: Optional[int] = None,
) -> Tuple[SubtreePool, PoolMeta]:
    """Bulk-build the blocked pool from sorted unique keys.

    ``n_shards``: pad the subtree axis to a multiple of this (the `model`
    mesh axis size) so the arrays block-shard evenly.  ``headroom``: extra
    node slots per subtree block, as a fraction of the bulk layout's node
    count — the free-list the on-mesh SMO engine allocates split siblings
    from (0 disables device-side splits; every overflow then drains through
    the host rebuild).  ``subtree_leaves``: leaves per block (default the
    dense ``per_node**level_m``); smaller blocks build roomier block roots
    (more separator slack before a subtree overflows to the host path) and
    spread a dataset over more subtrees.
    """
    keys = np.asarray(keys, dtype=np.int64)
    if np.any(keys[1:] <= keys[:-1]):
        raise ValueError("keys must be sorted and unique")
    if values is None:
        values = keys.copy()
    values = np.asarray(values, dtype=np.int64)
    if headroom < 0:
        raise ValueError(f"headroom must be >= 0, got {headroom!r}")

    per_node = max(2, int(FANOUT * fill))
    n = keys.size
    n_leaves = -(-n // per_node)
    if subtree_leaves is None:
        subtree_leaves = per_node**level_m
    if not (1 <= subtree_leaves <= per_node**level_m):
        raise ValueError(
            f"subtree_leaves must be in [1, per_node**level_m], got "
            f"{subtree_leaves!r}"
        )
    leaves_per_subtree = int(subtree_leaves)
    n_subtrees = -(-n_leaves // leaves_per_subtree)
    S = -(-n_subtrees // n_shards) * n_shards
    offs = _level_offsets(per_node, level_m, leaves_per_subtree)
    base_cap = int(offs[-1])
    cap = base_cap + int(np.ceil(base_cap * headroom))
    leaf_start = int(offs[-2])

    PK = np.full((S, cap, FANOUT), KEY_MAX, dtype=np.int64)
    PC = np.full((S, cap, FANOUT), NULL, dtype=np.int32)
    PV = np.zeros((S, cap, FANOUT), dtype=np.int64)

    # pad keys to full leaves for reshaping
    pad = (-n) % per_node
    kp = np.concatenate([keys, np.full((pad,), KEY_MAX, np.int64)])
    vp = np.concatenate([values, np.zeros((pad,), np.int64)])
    leaf_k = kp.reshape(n_leaves, per_node)
    leaf_v = vp.reshape(n_leaves, per_node)

    subtree_mins = np.full((S,), KEY_MAX, dtype=np.int64)

    for s in range(n_subtrees):
        lk = leaf_k[s * leaves_per_subtree : (s + 1) * leaves_per_subtree]
        lv = leaf_v[s * leaves_per_subtree : (s + 1) * leaves_per_subtree]
        nl = lk.shape[0]
        # place leaves
        PK[s, leaf_start : leaf_start + nl, :per_node] = lk
        PV[s, leaf_start : leaf_start + nl, :per_node] = lv
        # routing minima for this subtree's leaves
        mins = lk[:, 0].copy()
        child_ids = np.arange(leaf_start, leaf_start + nl, dtype=np.int32)
        # build levels 1..M bottom-up
        for lvl in range(1, level_m + 1):
            lvl_off = int(offs[level_m - lvl])
            n_nodes = -(-child_ids.size // per_node)
            new_mins = np.empty((n_nodes,), np.int64)
            for i in range(n_nodes):
                cm = mins[i * per_node : (i + 1) * per_node]
                ch = child_ids[i * per_node : (i + 1) * per_node]
                nid = lvl_off + i
                PK[s, nid, : cm.size] = cm
                PC[s, nid, : ch.size] = ch
                new_mins[i] = cm[0]
            mins = new_mins
            child_ids = np.arange(lvl_off, lvl_off + n_nodes, dtype=np.int32)
        # note: no -inf sentinel is needed inside blocks — the in-node search
        # clamps slot 0, so queries below a block's min route leftmost anyway
        subtree_mins[s] = lk[0, 0] if s > 0 else KEY_MIN

    # ---- top tree over subtree minima --------------------------------------
    top_k_rows = []
    top_c_rows = []
    child_refs = np.arange(n_subtrees, dtype=np.int32)  # subtree ids
    mins = subtree_mins[:n_subtrees].copy()
    top_height = 0
    while child_refs.size > 1 or top_height == 0:
        n_nodes = -(-child_refs.size // per_node)
        if child_refs.size == 1 and top_height > 0:
            break
        new_refs = np.empty((n_nodes,), np.int32)
        new_mins = np.empty((n_nodes,), np.int64)
        for i in range(n_nodes):
            cm = mins[i * per_node : (i + 1) * per_node]
            ch = child_refs[i * per_node : (i + 1) * per_node]
            row_k = np.full((FANOUT,), KEY_MAX, np.int64)
            row_c = np.full((FANOUT,), NULL, np.int32)
            row_k[: cm.size] = cm
            row_c[: ch.size] = ch
            top_k_rows.append(row_k)
            top_c_rows.append(row_c)
            new_refs[i] = len(top_k_rows) - 1
            new_mins[i] = cm[0]
        child_refs, mins = new_refs, new_mins
        top_height += 1
        if n_nodes == 1:
            break

    TK = np.stack(top_k_rows) if top_k_rows else np.full((1, FANOUT), KEY_MAX, np.int64)
    TC = np.stack(top_c_rows) if top_c_rows else np.full((1, FANOUT), NULL, np.int32)

    pool = SubtreePool(
        top_keys=jnp.asarray(TK),
        top_children=jnp.asarray(TC),
        pool_keys=jnp.asarray(PK),
        pool_children=jnp.asarray(PC),
        pool_values=jnp.asarray(PV),
    )
    meta = PoolMeta(
        level_m=level_m,
        per_node=per_node,
        subtree_cap=cap,
        n_subtrees=n_subtrees,
        n_subtrees_padded=S,
        top_height=top_height,
        n_keys=n,
        leaf_start=leaf_start,
        base_cap=base_cap,
        subtree_leaves=leaves_per_subtree,
    )
    return pool, meta


def initial_succ(meta: PoolMeta) -> np.ndarray:
    """Leaf successor table over the bulk layout: ``succ[gid]`` is the next
    leaf's global node id in key order (``-1`` ends the chain; non-leaf
    slots are ``-1``).  On-mesh leaf splits (core/smo.py) link allocated
    siblings into this chain; range scans (core/scan.py) follow it instead
    of assuming leaves are consecutive in local-id order."""
    n_nodes = meta.n_subtrees_padded * meta.subtree_cap
    succ = np.full((n_nodes,), -1, dtype=np.int64)
    n_leaves = -(-meta.n_keys // meta.per_node)
    lps = meta.leaves_per_subtree
    g = np.arange(n_leaves, dtype=np.int64)
    gid = (g // lps) * meta.subtree_cap + meta.leaf_start + (g % lps)
    succ[gid[:-1]] = gid[1:]
    return succ


# ---------------------------------------------------------------------------
# Prefix-compressed separators (DESIGN.md §13)
# ---------------------------------------------------------------------------

# Suffixes keep at most 30 low bits so they fit a non-negative int32 lane
# with room for an unambiguous padding sentinel above every real value.
SEP_MAX_NBITS = 30
SEP_SUFFIX_SENTINEL = np.int32(0x7FFFFFFF)


class SepPlanes(NamedTuple):
    """Prefix-compressed separator planes for the pool's node rows.

    Within one node the separators share their high bits (a row spans a
    narrow key range), so each row stores one 8-byte common ``prefix`` (low
    ``nbits`` zeroed), the retained low-bit count ``nbits``, and FANOUT
    4-byte truncated suffixes — 8 + 4 + 4*FANOUT bytes against the
    canonical 8*FANOUT, i.e. roughly twice the separators per byte of
    fetched row.  ``nbits = -1`` marks an incompressible row (its span
    needs more than SEP_MAX_NBITS low bits — e.g. a block root over a
    sparse keyspace); searches fall back to the full key row there
    (kernels/node_search.py ``node_search_prefix``).  Padding suffix slots
    hold SEP_SUFFIX_SENTINEL, which is greater than any real (< 2**30)
    suffix, so a row's real separator count is recoverable from the plane
    alone."""

    prefix: jax.Array   # [S, C] int64 shared high bits (low nbits zeroed)
    nbits: jax.Array    # [S, C] int32 retained low bits; -1 = incompressible
    suffix: jax.Array   # [S, C, FANOUT] int32 truncated separators


def _bit_length(x: np.ndarray) -> np.ndarray:
    """Per-element ``int.bit_length`` of int64 bit patterns read as
    unsigned (a span crossing the sign bit must count all 64 bits)."""
    bl = np.frompyfunc(lambda v: int(v).bit_length(), 1, 1)
    return bl(x.astype(np.uint64).astype(object)).astype(np.int32)


def compress_rows(keys: np.ndarray):
    """Compress [N, FANOUT] separator rows (KEY_MAX padding) into
    ``(prefix [N], nbits [N], suffix [N, FANOUT])`` numpy planes.

    A row's retained-bit count is the bit length of ``min ^ max`` over its
    real keys: every key in between shares the bits above that, so the
    query-side comparison reduces to one prefix compare plus an int32
    suffix compare (``node_search_prefix_ref`` spells out the contract).
    Empty rows compress trivially (all-sentinel suffixes, count 0)."""
    keys = np.asarray(keys, np.int64)
    n, f = keys.shape
    real = keys != KEY_MAX
    any_real = real.any(axis=1)
    lo = np.where(any_real, np.min(np.where(real, keys, KEY_MAX), axis=1), 0)
    hi = np.where(any_real, np.max(np.where(real, keys, KEY_MIN), axis=1), 0)
    # xor of the row extremes: the keys differ only below its bit length
    nbits = _bit_length(lo ^ hi)
    good = any_real & (nbits <= SEP_MAX_NBITS)
    nbits = np.where(any_real, np.where(good, nbits, -1), 0).astype(np.int32)
    mask = np.where(good, (np.int64(1) << np.maximum(nbits, 0)) - 1, 0)
    prefix = np.where(good, lo & ~mask, 0)
    suffix = np.where(
        real & good[:, None],
        (keys & mask[:, None]).astype(np.int64),
        np.int64(SEP_SUFFIX_SENTINEL),
    ).astype(np.int32)
    return prefix, nbits, suffix


def compress_separators(pool: SubtreePool, meta: PoolMeta) -> SepPlanes:
    """Build the compressed separator planes for every pool row at load
    (host-side; core/smo.py ``refresh_sep_planes`` keeps them correct
    across on-mesh splits without a full rebuild)."""
    pk = np.asarray(pool.pool_keys)
    s, c, f = pk.shape
    prefix, nbits, suffix = compress_rows(pk.reshape(s * c, f))
    return SepPlanes(
        prefix=jnp.asarray(prefix.reshape(s, c)),
        nbits=jnp.asarray(nbits.reshape(s, c)),
        suffix=jnp.asarray(suffix.reshape(s, c, f)),
    )


def sep_compression_stats(sep: SepPlanes, meta: PoolMeta) -> dict:
    """Byte/fanout accounting for the compressed layout (fig16/fig20).

    ``effective_fanout`` is how many separators a canonical row's byte
    budget (8*FANOUT) holds under the compressed layout's per-row cost
    (8 + 4 + 4*FANOUT amortized per separator), i.e. the fanout a fetch of
    the same size could route over; ``modeled_depth`` is the within-subtree
    descent depth that fanout would need for the same leaf population."""
    nbits = np.asarray(sep.nbits).reshape(-1)
    suffix = np.asarray(sep.suffix)
    counts = (suffix != SEP_SUFFIX_SENTINEL).sum(axis=-1).reshape(-1)
    occupied = counts > 0
    n_rows = int(occupied.sum())
    compressible = int((occupied & (nbits >= 0)).sum())
    f = suffix.shape[-1]
    canon_bytes = 8 * f
    comp_bytes = 8 + 4 + 4 * f
    eff_fanout = f * canon_bytes / comp_bytes
    leaves = max(meta.leaves_per_subtree, 1)
    modeled_depth = int(np.ceil(np.log(max(leaves, 2)) / np.log(eff_fanout)))
    return {
        "rows": n_rows,
        "compressible_rows": compressible,
        "compressible_frac": compressible / max(n_rows, 1),
        "mean_nbits": float(nbits[occupied & (nbits >= 0)].mean())
        if compressible
        else 0.0,
        "canonical_row_bytes": canon_bytes,
        "compressed_row_bytes": comp_bytes,
        "effective_fanout": eff_fanout,
        "modeled_subtree_depth": modeled_depth,
        "baseline_subtree_depth": meta.level_m,
    }


# ---------------------------------------------------------------------------
# Pure-jnp traversal pieces (shared by Plane B and by kernel oracles)
# ---------------------------------------------------------------------------


def _slot(node_keys: jax.Array, q: jax.Array) -> jax.Array:
    cnt = jnp.sum(node_keys <= q[..., None], axis=-1)
    return jnp.maximum(cnt - 1, 0).astype(jnp.int32)


def top_walk(pool: SubtreePool, meta: PoolMeta, queries: jax.Array) -> jax.Array:
    """Walk the replicated top tree; returns the subtree id per query."""
    queries = queries.astype(jnp.int64)
    b = queries.shape[0]
    if meta.top_height == 0:
        return jnp.zeros((b,), jnp.int32)
    root = pool.top_keys.shape[0] - 1
    nodes = jnp.full((b,), root, jnp.int32)
    for _ in range(meta.top_height - 1):
        s = _slot(pool.top_keys[nodes], queries)
        nodes = pool.top_children[nodes, s]
    s = _slot(pool.top_keys[nodes], queries)
    return pool.top_children[nodes, s]  # subtree ids


def subtree_walk_ref(
    block_keys: jax.Array,      # [C, FANOUT] one subtree's nodes
    block_children: jax.Array,  # [C, FANOUT]
    block_values: jax.Array,    # [C, FANOUT]
    queries: jax.Array,         # [B]
    *,
    levels: int,
) -> Tuple[jax.Array, jax.Array]:
    """Walk one subtree block from its root (local id 0) to the leaves.
    Pure-jnp oracle for the Pallas ``subtree_walk`` kernel; also the
    offload executor's reference implementation.  Returns (found, values).
    """
    queries = queries.astype(jnp.int64)
    b = queries.shape[0]
    local = jnp.zeros((b,), jnp.int32)
    for _ in range(levels - 1):
        s = _slot(block_keys[local], queries)
        local = block_children[local, s]
    leaf_keys = block_keys[local]
    eq = leaf_keys == queries[..., None]
    found = jnp.any(eq, axis=-1)
    vals = jnp.sum(jnp.where(eq, block_values[local], 0), axis=-1)
    return found, vals


def pool_lookup_ref(
    pool: SubtreePool, meta: PoolMeta, queries: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Single-device reference lookup over the blocked layout (no mesh)."""
    st = top_walk(pool, meta, queries)
    queries = queries.astype(jnp.int64)
    b = queries.shape[0]
    local = jnp.zeros((b,), jnp.int32)
    for _ in range(meta.levels_in_subtree - 1):
        rows = pool.pool_keys[st, local]
        s = _slot(rows, queries)
        local = pool.pool_children[st, local, s]
    leaf_keys = pool.pool_keys[st, local]
    eq = leaf_keys == queries[..., None]
    found = jnp.any(eq, axis=-1)
    vals = jnp.sum(jnp.where(eq, pool.pool_values[st, local], 0), axis=-1)
    return found, vals
