"""Leaf-direct route-table trainer for the mesh plane (DESIGN.md §13).

DEX's central claim is that fewer remote accesses win on disaggregated
memory (paper §1), yet every mesh op pays a full cached inner descent
before touching a leaf.  Outback (PAPERS.md) resolves location
compute-side in ~one round with a learned mapping; this module is that
analogue for the subtree-blocked pool: a **piecewise-linear index over the
observed key hull** whose segments are the leaves' fence ranges.  The
trained table is four replicated arrays on :class:`~repro.core.dex.DexState`
(``rt_keys``/``rt_hi``/``rt_sub``/``rt_local``/``rt_ver``); predicting a
leaf is one ``searchsorted`` against ``rt_keys``
(:func:`repro.core.routing.rt_predict`) — no collective, no remote read.

Correctness never depends on the table: the engine accepts a guess only
under :func:`repro.core.fleet_cache.rt_accept`'s fence-key bounds + leaf
version fence, so the trainer is free to be approximate.  When the pool
holds more leaves than ``cfg.route_table_slots``, the trainer keeps the
leaves of the **demand-hottest partitions first** (``DexState.route_demand``
is the same source-side load signal the repartition controller uses), so
the table's capacity chases the workload like the paper's cooling map
chases cache capacity.

Training runs host-side between batches (exactly like the repartition
controller's decisions): bulk load, the controller's boundary installs
(``RepartitionController.maybe_repartition`` retrains automatically after
an install when the table is active) and explicit benchmark calls after a
hotspot shift.  A *stale* table needs no retraining for correctness —
every insert/update/split/repartition move bumps the leaf's version, so
the fence rejects moved entries and those lanes simply pay full descent
until the next train.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.dex import DexState
from repro.core.nodes import KEY_MAX
from repro.core.pool import PoolMeta
from repro.core.repartition import node_key_ranges


def route_table_active(state: DexState) -> bool:
    """Host-side check: does the state carry any trained (live) entry?"""
    return bool(np.any(np.asarray(state.rt_ver) >= 0))


def leaf_ranges(state: DexState, meta: PoolMeta):
    """Fence ranges of every real leaf: ``(gids, lo, hi)`` sorted by ``lo``
    (the children-graph walk of :func:`node_key_ranges` keeps working after
    on-mesh splits relocate leaves into free-list headroom)."""
    gids, lo, hi, lvl = node_key_ranges(
        np.asarray(state.pool.pool_keys), meta,
        np.asarray(state.pool.pool_children), with_levels=True,
    )
    keep = lvl == 0
    gids, lo, hi = gids[keep], lo[keep], hi[keep]
    order = np.argsort(lo, kind="stable")
    return gids[order], lo[order], hi[order]


def train_route_table(
    state: DexState,
    meta: PoolMeta,
    *,
    slots: Optional[int] = None,
    mesh=None,
) -> DexState:
    """(Re)train the leaf-direct route table from the current pool.

    Builds the piecewise-linear segment table over the leaves' fence
    ranges, stamps each entry with the leaf's *current* version (the
    fence the engine later verifies), and — when leaves outnumber
    ``slots`` — keeps the leaves of the demand-hottest route partitions
    (ties broken toward lower keys, so the kept set stays contiguous-ish
    and the searchsorted gaps reject cleanly).  Returns the new state;
    pass ``mesh`` to re-commit the replicated arrays with the same
    ``P()`` sharding ``state_shardings`` uses.
    """
    r = int(state.rt_keys.shape[0]) if slots is None else int(slots)
    gids, lo, hi = leaf_ranges(state, meta)
    if gids.size > r:
        boundaries = np.asarray(state.boundaries, np.int64)
        n_route = boundaries.shape[0] - 1
        demand = np.asarray(state.route_demand, np.int64).sum(axis=0)
        owner = np.clip(
            np.searchsorted(boundaries, lo, side="right") - 1, 0, n_route - 1
        )
        # hot partitions first; stable sort keeps key order within a
        # partition so the kept prefix is a union of hot key ranges
        hot = np.argsort(-demand[owner], kind="stable")[:r]
        keep = np.sort(hot)
        gids, lo, hi = gids[keep], lo[keep], hi[keep]
    vers = np.asarray(state.versions)[0]
    n = gids.size
    rt_keys = np.full((r,), KEY_MAX, np.int64)
    rt_hi = np.full((r,), KEY_MAX, np.int64)
    rt_sub = np.zeros((r,), np.int32)
    rt_local = np.zeros((r,), np.int32)
    rt_ver = np.full((r,), -1, np.int32)
    rt_keys[:n] = lo
    rt_hi[:n] = hi
    rt_sub[:n] = (gids // meta.subtree_cap).astype(np.int32)
    rt_local[:n] = (gids % meta.subtree_cap).astype(np.int32)
    rt_ver[:n] = vers[gids]
    arrs = dict(
        rt_keys=jnp.asarray(rt_keys),
        rt_hi=jnp.asarray(rt_hi),
        rt_sub=jnp.asarray(rt_sub),
        rt_local=jnp.asarray(rt_local),
        rt_ver=jnp.asarray(rt_ver),
    )
    if mesh is not None:
        rep = jax.sharding.NamedSharding(mesh, P())
        arrs = {k: jax.device_put(v, rep) for k, v in arrs.items()}
    return state._replace(**arrs)


def poison_route_table(state: DexState) -> DexState:
    """Adversarial-table helper for tests and the fig20 fallback arm: bump
    every live entry's train-time version stamp so the engine's version
    fence rejects **every** guess.  The contract under test: a fully
    poisoned table yields bit-identical results to descent-only mode (all
    guesses become ``rt_mispredicts``; no probe is ever mis-accepted).

    The bump is large so later writes cannot re-arm an entry mid-trace: a
    +1 bump aliases with the version bump of a single write to that leaf
    (a benign accept — the fence compares the CURRENT version — but it
    would break the all-mispredict contract tests pin)."""
    ver = np.asarray(state.rt_ver).copy()
    ver[ver >= 0] += 1 << 20
    return state._replace(rt_ver=jnp.asarray(ver))
