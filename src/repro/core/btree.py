"""Array-based B+-tree: bulk build (host) + batched device ops (jit).

This is the flat, single-address-space representation used by unit tests and
by the event-level simulator (Plane A in DESIGN.md §2).  The mesh-sharded,
subtree-blocked representation lives in ``core/pool.py`` / ``core/dex.py``.

Design notes
------------
* Traversal is *level-synchronous*: a batch of queries advances one tree
  level per step, so each level is a single gather over the node arrays —
  the TPU-native equivalent of the paper's per-node RDMA READ loop.
* Mutations follow a fast-path / SMO-fallback split that mirrors the paper's
  offload fallback (§6: "DEX will fall back to the normal path when an
  offloading attempt ... would trigger a structural modification operation"):
  batched inserts that fit in leaf slack are applied fully vectorized on
  device; overflowing leaves are handled on the host (the "memory server").
* Scatter safety: every vectorized mutation routes inactive batch lanes to
  the *scratch row* ``capacity - 1`` (guaranteed free by construction) so
  duplicate scatter indices never race with real writes.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.nodes import (
    DEFAULT_FILL,
    FANOUT,
    KEY_MAX,
    KEY_MIN,
    NULL,
    TreeArrays,
    TreeMeta,
)

# ---------------------------------------------------------------------------
# Bulk build (host side, numpy)
# ---------------------------------------------------------------------------


def bulk_build(
    keys: np.ndarray,
    values: Optional[np.ndarray] = None,
    *,
    fill: float = DEFAULT_FILL,
    capacity_slack: float = 1.5,
) -> Tuple[TreeArrays, TreeMeta]:
    """Build a B+-tree from sorted unique ``keys`` (int64, strictly inside
    (KEY_MIN, KEY_MAX)).

    ``fill`` is the bulk-load fill factor (nodes are loaded with slack so
    inserts do not immediately split).  Returns device arrays plus a static
    :class:`TreeMeta` used to fix trip counts at trace time.
    """
    keys = np.asarray(keys, dtype=np.int64)
    if keys.ndim != 1:
        raise ValueError("keys must be 1-D")
    if keys.size == 0:
        raise ValueError("cannot bulk build an empty tree")
    if np.any(keys[1:] <= keys[:-1]):
        raise ValueError("keys must be sorted and unique")
    if keys[0] <= KEY_MIN or keys[-1] >= KEY_MAX:
        raise ValueError("keys must be strictly inside (KEY_MIN, KEY_MAX)")
    if values is None:
        values = keys.copy()
    values = np.asarray(values, dtype=np.int64)
    if values.shape != keys.shape:
        raise ValueError("values must match keys")

    per_leaf = max(2, int(FANOUT * fill))
    n = keys.size
    n_leaves = -(-n // per_leaf)

    # ---- plan levels bottom-up -------------------------------------------
    level_sizes = [n_leaves]
    while level_sizes[-1] > 1:
        level_sizes.append(-(-level_sizes[-1] // per_leaf))
    height = len(level_sizes)
    num_nodes = int(sum(level_sizes))
    capacity = max(num_nodes + 8, int(num_nodes * capacity_slack))

    K = np.full((capacity, FANOUT), KEY_MAX, dtype=np.int64)
    C = np.full((capacity, FANOUT), NULL, dtype=np.int32)
    V = np.zeros((capacity, FANOUT), dtype=np.int64)
    NK = np.zeros((capacity,), dtype=np.int32)
    LV = np.full((capacity,), -1, dtype=np.int32)
    FLO = np.full((capacity,), KEY_MIN, dtype=np.int64)
    FHI = np.full((capacity,), KEY_MAX, dtype=np.int64)

    # ---- leaves -----------------------------------------------------------
    pad = (-n) % per_leaf
    kp = np.concatenate([keys, np.full((pad,), KEY_MAX, np.int64)]).reshape(
        n_leaves, per_leaf
    )
    vp = np.concatenate([values, np.zeros((pad,), np.int64)]).reshape(
        n_leaves, per_leaf
    )
    K[:n_leaves, :per_leaf] = kp
    V[:n_leaves, :per_leaf] = vp
    NK[:n_leaves] = np.minimum(per_leaf, n - per_leaf * np.arange(n_leaves))
    LV[:n_leaves] = 0
    mins = kp[:, 0].copy()
    mins[0] = KEY_MIN
    FLO[:n_leaves] = mins
    FHI[: n_leaves - 1] = mins[1:]
    FHI[n_leaves - 1] = KEY_MAX

    # ---- inner levels ------------------------------------------------------
    next_id = n_leaves
    child_ids = np.arange(n_leaves, dtype=np.int32)
    child_mins = mins
    for lvl in range(1, height):
        n_nodes = level_sizes[lvl]
        ids = np.arange(next_id, next_id + n_nodes, dtype=np.int32)
        next_id += n_nodes
        new_mins = np.empty((n_nodes,), dtype=np.int64)
        for i in range(n_nodes):
            ch = child_ids[i * per_leaf : (i + 1) * per_leaf]
            cm = child_mins[i * per_leaf : (i + 1) * per_leaf]
            nid = ids[i]
            K[nid, : cm.size] = cm
            C[nid, : ch.size] = ch
            NK[nid] = ch.size
            LV[nid] = lvl
            new_mins[i] = cm[0]
        FLO[ids] = new_mins
        FHI[ids[:-1]] = new_mins[1:]
        FHI[ids[-1]] = KEY_MAX
        child_ids, child_mins = ids, new_mins

    root = int(child_ids[0])
    tree = TreeArrays(
        keys=jnp.asarray(K),
        children=jnp.asarray(C),
        values=jnp.asarray(V),
        num_keys=jnp.asarray(NK),
        level=jnp.asarray(LV),
        fence_lo=jnp.asarray(FLO),
        fence_hi=jnp.asarray(FHI),
        version=jnp.zeros((capacity,), dtype=jnp.int32),
        root=jnp.asarray(root, dtype=jnp.int32),
        height=jnp.asarray(height, dtype=jnp.int32),
        num_nodes=jnp.asarray(num_nodes, dtype=jnp.int32),
    )
    meta = TreeMeta(
        height=height,
        num_nodes=num_nodes,
        num_leaves=n_leaves,
        capacity=capacity,
        keys_per_leaf=per_leaf,
    )
    return tree, meta


# ---------------------------------------------------------------------------
# Batched point lookups
# ---------------------------------------------------------------------------


def _search_slot(node_keys: jax.Array, q: jax.Array) -> jax.Array:
    """Branchless in-node lower-bound: index of rightmost separator <= q.

    Empty slots hold KEY_MAX (> q); the leftmost separator of a leftmost node
    is KEY_MIN (<= q), so the count is always >= 1 for routed queries.
    """
    cnt = jnp.sum(node_keys <= q[..., None], axis=-1)
    return jnp.maximum(cnt - 1, 0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("height", "with_path"))
def bulk_lookup(
    tree: TreeArrays,
    queries: jax.Array,
    *,
    height: int,
    with_path: bool = False,
):
    """Look up a batch of keys.  Returns ``(found, values)`` or, when
    ``with_path``, ``(found, values, path)`` with ``path[b, l]`` = node id at
    depth ``l`` (root first)."""
    queries = queries.astype(jnp.int64)
    b = queries.shape[0]
    nodes = jnp.broadcast_to(tree.root, (b,)).astype(jnp.int32)
    path = [nodes] if with_path else None
    for _ in range(height - 1):
        node_keys = tree.keys[nodes]                      # [B, F] gather
        slot = _search_slot(node_keys, queries)           # [B]
        nodes = tree.children[nodes, slot]
        if with_path:
            path.append(nodes)
    leaf_keys = tree.keys[nodes]
    eq = leaf_keys == queries[..., None]
    found = jnp.any(eq, axis=-1)
    vals = jnp.sum(jnp.where(eq, tree.values[nodes], 0), axis=-1)
    if with_path:
        return found, vals, jnp.stack(path, axis=1)
    return found, vals


@functools.partial(jax.jit, static_argnames=("height",))
def bulk_find_leaf(tree: TreeArrays, queries: jax.Array, *, height: int):
    """Route each query to its leaf id (no value fetch)."""
    queries = queries.astype(jnp.int64)
    b = queries.shape[0]
    nodes = jnp.broadcast_to(tree.root, (b,)).astype(jnp.int32)
    for _ in range(height - 1):
        slot = _search_slot(tree.keys[nodes], queries)
        nodes = tree.children[nodes, slot]
    return nodes


# ---------------------------------------------------------------------------
# Batched updates (write to existing keys)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("height",))
def bulk_update(
    tree: TreeArrays, queries: jax.Array, new_values: jax.Array, *, height: int
) -> Tuple[TreeArrays, jax.Array]:
    """Set ``value`` for every existing key in ``queries``; returns
    ``(tree', updated_mask)``.  Duplicate batch keys: one of them wins."""
    queries = queries.astype(jnp.int64)
    scratch = tree.capacity - 1
    leaves = bulk_find_leaf(tree, queries, height=height)
    leaf_keys = tree.keys[leaves]                         # [B, F]
    eq = leaf_keys == queries[..., None]
    slot = jnp.argmax(eq, axis=-1).astype(jnp.int32)
    found = jnp.any(eq, axis=-1)
    safe_leaf = jnp.where(found, leaves, scratch)
    safe_slot = jnp.where(found, slot, 0)
    vals = jnp.where(found, new_values.astype(jnp.int64), tree.values[scratch, 0])
    new_vals = tree.values.at[safe_leaf, safe_slot].set(vals)
    new_version = tree.version.at[safe_leaf].add(
        jnp.where(found, 2, 0).astype(jnp.int32)
    )
    return tree._replace(values=new_vals, version=new_version), found


# ---------------------------------------------------------------------------
# Segment machinery shared by vectorized mutations
# ---------------------------------------------------------------------------


def _leaf_segments(leaves: jax.Array, active: jax.Array, order_key: jax.Array):
    """Group batch lanes by target leaf.

    Returns ``(sort_idx, seg_id, pos_in_seg, seg_leaf, seg_active)`` where
    lanes are sorted by (active-leaf, order_key); each distinct active leaf
    becomes one segment; inactive lanes collect in a trailing dead segment.
    """
    b = leaves.shape[0]
    inactive_key = jnp.int64(1) << 40
    route = jnp.where(active, leaves.astype(jnp.int64), inactive_key)
    sort_idx = jnp.lexsort((order_key, route))
    sorted_route = route[sort_idx]
    new_seg = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_route[1:] != sorted_route[:-1]]
    )
    seg_id = jnp.cumsum(new_seg) - 1                      # [B]
    seg_start = jax.lax.cummax(jnp.where(new_seg, jnp.arange(b), 0), axis=0)
    pos_in_seg = jnp.arange(b) - seg_start
    seg_leaf = (
        jnp.zeros((b,), jnp.int32)
        .at[seg_id]
        .max(jnp.where(active[sort_idx], leaves[sort_idx], 0).astype(jnp.int32))
    )
    seg_active = jnp.zeros((b,), bool).at[seg_id].max(active[sort_idx])
    return sort_idx, seg_id, pos_in_seg, seg_leaf, seg_active


# ---------------------------------------------------------------------------
# Batched inserts: device fast path + host SMO fallback
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("height",))
def _insert_fast_path(tree: TreeArrays, keys: jax.Array, values: jax.Array, *, height: int):
    """Vectorized insert of a batch into leaf slack space.

    Returns ``(tree', handled_mask, overflow_mask)``.  ``handled`` covers new
    inserts applied on device plus duplicates (which become value updates).
    Keys routed to leaves that would exceed FANOUT are reported in
    ``overflow_mask`` for the host SMO path.
    """
    b = keys.shape[0]
    scratch = tree.capacity - 1
    keys = keys.astype(jnp.int64)
    values = values.astype(jnp.int64)
    leaves = bulk_find_leaf(tree, keys, height=height)

    # Existing keys -> value updates, not inserts.
    leaf_keys = tree.keys[leaves]                          # [B, F]
    is_dup = jnp.any(leaf_keys == keys[..., None], axis=-1)

    # Deduplicate within the batch (first occurrence wins).
    order = jnp.argsort(keys, stable=True)
    sk = keys[order]
    first = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]])
    is_first = jnp.zeros((b,), bool).at[order].set(first)

    eligible = (~is_dup) & is_first

    # Per-leaf incoming counts decide overflow.
    incoming = (
        jnp.zeros((tree.capacity,), jnp.int32)
        .at[leaves]
        .add(jnp.where(eligible, 1, 0).astype(jnp.int32))
    )
    leaf_overflow = (tree.num_keys + incoming) > FANOUT
    overflow = eligible & leaf_overflow[leaves]
    do_insert = eligible & ~leaf_overflow[leaves]

    sort_idx, seg_id, pos_in_seg, seg_leaf, seg_active = _leaf_segments(
        leaves, do_insert, keys
    )

    # Merge rows: [B, 2F] = existing leaf row ++ this segment's batch keys.
    tgt = jnp.where(seg_active, seg_leaf, scratch)
    merge_keys = jnp.full((b, 2 * FANOUT), KEY_MAX, dtype=jnp.int64)
    merge_vals = jnp.zeros((b, 2 * FANOUT), dtype=jnp.int64)
    merge_keys = merge_keys.at[:, :FANOUT].set(tree.keys[tgt])
    merge_vals = merge_vals.at[:, :FANOUT].set(tree.values[tgt])
    put = do_insert[sort_idx]
    col = FANOUT + jnp.minimum(pos_in_seg, FANOUT - 1)
    merge_keys = merge_keys.at[seg_id, col].set(
        jnp.where(put, keys[sort_idx], KEY_MAX)
    )
    merge_vals = merge_vals.at[seg_id, col].set(jnp.where(put, values[sort_idx], 0))

    sidx = jnp.argsort(merge_keys, axis=-1)
    merged_k = jnp.take_along_axis(merge_keys, sidx, axis=-1)[:, :FANOUT]
    merged_v = jnp.take_along_axis(merge_vals, sidx, axis=-1)[:, :FANOUT]

    # Scatter back; inactive rows rewrite the scratch row with its own
    # contents (identical writers -> deterministic no-op).
    out_k = jnp.where(seg_active[:, None], merged_k, tree.keys[tgt])
    out_v = jnp.where(seg_active[:, None], merged_v, tree.values[tgt])
    new_keys = tree.keys.at[tgt].set(out_k)
    new_values = tree.values.at[tgt].set(out_v)
    cnt = jnp.sum(out_k != KEY_MAX, axis=-1).astype(jnp.int32)
    new_num = tree.num_keys.at[tgt].set(
        jnp.where(seg_active, cnt, tree.num_keys[tgt])
    )
    new_version = tree.version.at[tgt].add(
        jnp.where(seg_active, 2, 0).astype(jnp.int32)
    )

    # Duplicates update values in place (scratch-routed when not dup).
    # Slots must be located in the *post-merge* key rows: the merge above may
    # have shifted keys within the leaf.
    dleaf = jnp.where(is_dup, leaves, scratch)
    dslot = jnp.where(
        is_dup,
        jnp.argmax(new_keys[dleaf] == keys[..., None], axis=-1),
        0,
    ).astype(jnp.int32)
    dval = jnp.where(is_dup, values, new_values[scratch, 0])
    new_values = new_values.at[dleaf, dslot].set(dval)

    tree = tree._replace(
        keys=new_keys, values=new_values, num_keys=new_num, version=new_version
    )
    return tree, do_insert | is_dup, overflow


def batch_insert(
    tree: TreeArrays,
    meta: TreeMeta,
    keys,
    values,
) -> Tuple[TreeArrays, TreeMeta, np.ndarray]:
    """Insert a batch.  Device fast path first; overflowing keys go through
    the host SMO path (splits via rebuild, possibly growing the tree).
    Returns ``(tree', meta', handled_mask)``."""
    keys = jnp.asarray(keys, dtype=jnp.int64)
    values = jnp.asarray(values, dtype=jnp.int64)
    tree, ok, overflow = _insert_fast_path(tree, keys, values, height=meta.height)
    overflow = np.asarray(overflow)
    ok = np.asarray(ok)
    if overflow.any():
        tree, meta = _host_insert_with_splits(
            tree, np.asarray(keys)[overflow], np.asarray(values)[overflow]
        )
        ok = ok | overflow
    return tree, meta, ok


def _host_insert_with_splits(
    tree: TreeArrays, keys: np.ndarray, values: np.ndarray
) -> Tuple[TreeArrays, TreeMeta]:
    """Host-side SMO path: rebuild the tree with the extra keys merged in.

    A rebuild keeps the bulk-load invariants (contiguous ids per level,
    uniform fill) that the sharded pool layout relies on; the simulator
    (Plane A) implements true in-place eager splits per the paper.
    """
    all_keys, all_vals = tree_items(tree)
    merged_keys = np.concatenate([all_keys, keys])
    merged_vals = np.concatenate([all_vals, values])
    order = np.argsort(merged_keys, kind="stable")
    merged_keys, merged_vals = merged_keys[order], merged_vals[order]
    # Later write wins for duplicates (new keys appended after existing).
    keep = np.concatenate([merged_keys[1:] != merged_keys[:-1], [True]])
    return bulk_build(merged_keys[keep], merged_vals[keep])


# ---------------------------------------------------------------------------
# Batched deletes (logical removal; structural merges live in the simulator)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("height",))
def bulk_delete(
    tree: TreeArrays, queries: jax.Array, *, height: int
) -> Tuple[TreeArrays, jax.Array]:
    """Remove keys, compacting each touched leaf row.  Returns
    ``(tree', deleted_mask)``."""
    queries = queries.astype(jnp.int64)
    b = queries.shape[0]
    scratch = tree.capacity - 1
    leaves = bulk_find_leaf(tree, queries, height=height)
    hit = tree.keys[leaves] == queries[..., None]          # [B, F]
    found = jnp.any(hit, axis=-1)
    slot = jnp.argmax(hit, axis=-1).astype(jnp.int32)

    # Scatter kill marks into a full-size mask (unique (leaf, slot) targets).
    kleaf = jnp.where(found, leaves, scratch)
    kslot = jnp.where(found, slot, 0)
    kill = (
        jnp.zeros((tree.capacity, FANOUT), bool)
        .at[kleaf, kslot]
        .set(found, mode="drop")
    )
    kill = kill.at[scratch].set(False)

    # Compact only the touched leaves, one segment per distinct leaf.
    _, seg_id, _, seg_leaf, seg_active = _leaf_segments(leaves, found, queries)
    tgt = jnp.where(seg_active, seg_leaf, scratch)
    rows_k = jnp.where(kill[tgt], KEY_MAX, tree.keys[tgt])
    rows_v = jnp.where(kill[tgt], 0, tree.values[tgt])
    sidx = jnp.argsort(rows_k, axis=-1)
    rows_k = jnp.take_along_axis(rows_k, sidx, axis=-1)
    rows_v = jnp.take_along_axis(rows_v, sidx, axis=-1)
    out_k = jnp.where(seg_active[:, None], rows_k, tree.keys[tgt])
    out_v = jnp.where(seg_active[:, None], rows_v, tree.values[tgt])
    new_keys = tree.keys.at[tgt].set(out_k)
    new_vals = tree.values.at[tgt].set(out_v)
    cnt = jnp.sum(out_k != KEY_MAX, axis=-1).astype(jnp.int32)
    new_num = tree.num_keys.at[tgt].set(
        jnp.where(seg_active, cnt, tree.num_keys[tgt])
    )
    new_version = tree.version.at[tgt].add(
        jnp.where(seg_active, 2, 0).astype(jnp.int32)
    )
    return (
        tree._replace(
            keys=new_keys, values=new_vals, num_keys=new_num, version=new_version
        ),
        found,
    )


# ---------------------------------------------------------------------------
# Range scans (paper §7: subdivided into repeated lookups via fence keys)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("height", "count", "max_hops"))
def bulk_scan(
    tree: TreeArrays,
    start_keys: jax.Array,
    *,
    height: int,
    count: int,
    max_hops: Optional[int] = None,
):
    """Scan up to ``count`` records in ascending order from each start key.

    Faithful to the paper: DEX keeps no leaf links, so a multi-leaf scan is
    subdivided into repeated root-to-leaf lookups whose next start key is the
    current leaf's *fence_hi*.  Returns ``(keys, values)``, each
    ``[B, count]``, KEY_MAX-padded.
    """
    start_keys = start_keys.astype(jnp.int64)
    b = start_keys.shape[0]
    hops = max_hops if max_hops is not None else max(2, count // (FANOUT // 2) + 2)

    out_k = jnp.full((b, hops * FANOUT), KEY_MAX, dtype=jnp.int64)
    out_v = jnp.zeros((b, hops * FANOUT), dtype=jnp.int64)
    cur = start_keys
    done = jnp.zeros((b,), bool)
    taken = jnp.zeros((b,), jnp.int32)
    for h in range(hops):
        leaves = bulk_find_leaf(tree, cur, height=height)   # fresh traversal
        lk = tree.keys[leaves]                              # [B, F]
        lv = tree.values[leaves]
        pre = (lk >= cur[:, None]) & (lk != KEY_MAX) & (~done[:, None])
        mask = pre & ((taken[:, None] + jnp.cumsum(pre, axis=-1)) <= count)
        out_k = jax.lax.dynamic_update_slice(
            out_k, jnp.where(mask, lk, KEY_MAX), (0, h * FANOUT)
        )
        out_v = jax.lax.dynamic_update_slice(
            out_v, jnp.where(mask, lv, 0), (0, h * FANOUT)
        )
        taken = taken + jnp.sum(mask, axis=-1).astype(jnp.int32)
        nxt = tree.fence_hi[leaves]
        done = done | (taken >= count) | (nxt == KEY_MAX)
        cur = jnp.where(done, cur, nxt)
    sidx = jnp.argsort(out_k, axis=-1)
    out_k = jnp.take_along_axis(out_k, sidx, axis=-1)[:, :count]
    out_v = jnp.take_along_axis(out_v, sidx, axis=-1)[:, :count]
    return out_k, out_v


# ---------------------------------------------------------------------------
# Validation + host helpers (used by property tests)
# ---------------------------------------------------------------------------


def validate(tree: TreeArrays, meta: TreeMeta) -> None:
    """Check structural invariants; raises AssertionError on violation."""
    K = np.asarray(tree.keys)
    C = np.asarray(tree.children)
    NK = np.asarray(tree.num_keys)
    LV = np.asarray(tree.level)
    FLO = np.asarray(tree.fence_lo)
    FHI = np.asarray(tree.fence_hi)
    root = int(tree.root)
    assert LV[root] == meta.height - 1, "root level mismatch"

    seen = set()

    def rec(nid: int, lo: int, hi: int, lvl: int):
        assert nid not in seen, "node visited twice"
        seen.add(nid)
        assert LV[nid] == lvl, f"level mismatch at {nid}"
        nk = int(NK[nid])
        assert 1 <= nk <= FANOUT
        row = K[nid]
        if lvl == 0:
            valid = row[row != KEY_MAX]
            assert valid.size == nk, f"leaf count mismatch at {nid}"
            assert np.all(np.diff(valid.astype(object)) > 0), f"unsorted leaf {nid}"
            assert np.all(
                (valid >= max(lo, int(KEY_MIN) + 1)) & (valid < hi)
            ), f"leaf keys outside fences at {nid}"
        else:
            srt = row[:nk]
            assert np.all(np.diff(srt.astype(object)) > 0), f"unsorted inner {nid}"
        assert FLO[nid] == lo and FHI[nid] == hi, f"fence mismatch at {nid}"
        if lvl == 0:
            return
        for i in range(nk):
            c = int(C[nid, i])
            assert c != NULL
            clo = int(row[i])
            chi = int(row[i + 1]) if i + 1 < nk else hi
            rec(c, clo, chi, lvl - 1)

    rec(root, int(KEY_MIN), int(KEY_MAX), meta.height - 1)
    assert len(seen) == int(tree.num_nodes), "reachable nodes != num_nodes"


def tree_items(tree: TreeArrays) -> Tuple[np.ndarray, np.ndarray]:
    """All (key, value) pairs in sorted order (host helper)."""
    K = np.asarray(tree.keys)
    V = np.asarray(tree.values)
    LV = np.asarray(tree.level)
    leaf = LV == 0
    k = K[leaf].reshape(-1)
    v = V[leaf].reshape(-1)
    m = k != KEY_MAX
    k, v = k[m], v[m]
    order = np.argsort(k, kind="stable")
    return k[order], v[order]
