"""DEX on a TPU mesh (Plane B): logical partitioning, per-chip caching and
opportunistic offloading expressed as SPMD collectives.

Mapping (DESIGN.md §2):

  compute server   -> a chip; key ranges are owned by rows of the
                      ``route`` axes (logical partitioning)
  memory server    -> a column of the ``memory`` axis; the subtree-blocked
                      pool (core/pool.py) block-shards over it, so a whole
                      level-M subtree lives on one column (paper §3)
  RDMA READ        -> request/response ``all_to_all`` over the memory axis
                      carrying 1KB node rows (one round per tree level)
  offload RPC      -> one request/response ``all_to_all`` carrying keys in
                      and values out; the owner walks its local block
  compute-side     -> per-chip set-associative arrays; FIFO-within-set is
  cache               the vectorized form of the paper's cooling map
                      (bucket == set), lazy admission via key-hash bits

The offload decision replaces the paper's per-op moving-average latency
estimates (which require wall-clock self-measurement, impossible in an
SPMD program) with running miss-rate EMAs and a byte-cost comparison —
the same ``l_p < (L+1) * (l_o + l_s) * c`` structure evaluated on
predicted bytes instead of measured latencies, made **per destination
memory column** by the unified engine: ``DexState.miss_ema`` tracks one
EMA per (column, level) and each batch's per-column lane groups choose
fetch or offload independently (core/engine.py, DESIGN.md §7).

This module holds the mesh plane's shared state (config, state pytree,
stat indices) and the thin lookup wrapper; the per-chip cache machinery
(``DexCache``, probe/admit, ``cached_fetch_level`` and the pluggable
``CachePolicy`` layer) lives in core/fleet_cache.py, and the execution
dataflow for all four ops in core/engine.py.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.fleet_cache import (  # noqa: F401  (re-exported compat names)
    P_ADMIT_LEAF_PCT,
    DexCache,
    cached_fetch_level,
    init_cache,
)
from repro.core.nodes import FANOUT, KEY_MAX
from repro.core.pool import PoolMeta, SubtreePool, initial_succ

NODE_ROW_BYTES = FANOUT * 8 * 3  # keys + children + values on the wire
OFFLOAD_REQ_BYTES = 16
OFFLOAD_RESP_BYTES = 16

# stat counter indices — derived from the declarative metric registry
# (repro/obs/registry.py), which owns slot order, units, sim-plane mapping
# and paper provenance.  Adding a counter means adding a Metric there; the
# constants below follow automatically and can never alias an old slot.
from repro.obs import latency as _latency
from repro.obs import registry as _metric_registry

_stat_consts = _metric_registry.stat_constants()
STAT_OPS = _stat_consts["STAT_OPS"]
STAT_HITS = _stat_consts["STAT_HITS"]
STAT_FETCHES = _stat_consts["STAT_FETCHES"]
STAT_OFFLOADS = _stat_consts["STAT_OFFLOADS"]
STAT_DROPS = _stat_consts["STAT_DROPS"]
STAT_SPLITS = _stat_consts["STAT_SPLITS"]
STAT_WRITES = _stat_consts["STAT_WRITES"]
STAT_SMO_SPLITS = _stat_consts["STAT_SMO_SPLITS"]
STAT_DRAINS = _stat_consts["STAT_DRAINS"]
STAT_OFFLOAD_GROUPS = _stat_consts["STAT_OFFLOAD_GROUPS"]
STAT_FETCH_GROUPS = _stat_consts["STAT_FETCH_GROUPS"]
STAT_PIPE_STALLS = _stat_consts["STAT_PIPE_STALLS"]
STAT_PEER_HITS = _stat_consts["STAT_PEER_HITS"]
STAT_PEER_MISSES = _stat_consts["STAT_PEER_MISSES"]
STAT_RT_SKIPS = _stat_consts["STAT_RT_SKIPS"]
STAT_RT_MISPREDICTS = _stat_consts["STAT_RT_MISPREDICTS"]
N_STATS = _metric_registry.N_STATS
del _stat_consts


@dataclasses.dataclass(frozen=True)
class DexMeshConfig:
    """Static configuration for the mesh plane."""

    route_axes: Tuple[str, ...] = ("data",)   # compute-partition axes
    memory_axis: str = "model"                # pool-shard axis
    n_route: int = 1                          # product of route axis sizes
    n_memory: int = 1                         # memory axis size
    cache_sets: int = 256
    cache_ways: int = 4
    # paper §5.4: P_A — derived from Plane A's DEFAULT_P_ADMIT_LEAF via
    # core/fleet_cache.py so the two planes can never silently diverge
    p_admit_leaf_pct: int = P_ADMIT_LEAF_PCT
    route_capacity_factor: float = 2.0        # all_to_all bucket slack
    policy: str = "auto"                      # fetch | offload | auto
    offload_c: float = 1.3                    # cost coefficient (§6.1)
    ema_decay: float = 0.98
    # leaf-direct route table capacity (DESIGN.md §13).  0 statically prunes
    # the predictor from the engine program — the compiled descent is the
    # verbatim pre-route-table one.  >0 reserves that many fence-verified
    # (key-range -> leaf) entries, trained host-side by core/route_table.py
    route_table_slots: int = 0

    @property
    def n_devices(self) -> int:
        return self.n_route * self.n_memory

    @property
    def all_axes(self) -> Tuple[str, ...]:
        return self.route_axes + (self.memory_axis,)


class DexState(NamedTuple):
    pool: SubtreePool
    cache: DexCache
    boundaries: jax.Array  # [n_route + 1] int64, replicated
    miss_ema: jax.Array    # [Dev, n_memory, levels] f32 per-(destination
    #                        memory column, level) miss-rate EMA — the input
    #                        of the engine's per-group offload cost model
    #                        (core/engine.py); psum-synchronized so every
    #                        chip prices a column identically
    stats: jax.Array       # [Dev, N_STATS] int64
    versions: jax.Array    # [Dev, n_nodes] int32 per-node write version
    occupancy: jax.Array   # [S, C] int32 keys per node (pool-aligned shard)
    route_demand: jax.Array  # [Dev, n_route] int64 routed requests per
    #                          partition measured at the *source* chip —
    #                          counts shed lanes too, so unlike the served
    #                          STAT_OPS it never saturates at bucket
    #                          capacity (the repartition controller's load
    #                          signal, core/repartition.py)
    succ: jax.Array        # [Dev, n_nodes] int64 leaf successor gid (-1
    #                        ends the chain; scans follow this instead of
    #                        leaf-id arithmetic — on-mesh splits relocate
    #                        leaves into the free-list headroom)
    n_alloc: jax.Array     # [S] int32 per-subtree free-list watermark
    #                        (pool-aligned shard): next free local node id;
    #                        subtree_cap means the block is out of headroom
    #                        and its splits drain through the host path
    lat_hist: jax.Array    # [Dev, classes, paths, buckets] int64 per-lane
    #                        modeled-latency histogram (obs/latency.py owns
    #                        the schema).  Pure per-device scatter — no
    #                        collective touches it; host-side readers sum
    #                        over Dev like they do for ``stats``
    lat_audit: jax.Array   # [Dev, 2, n_memory, levels] f32 offload
    #                        cost-model audit: plane 0 = predicted fetch
    #                        bytes (EMA rule, recorded on device 0 only —
    #                        the decision is mesh-global), plane 1 =
    #                        realized fetch bytes (per device, summed
    #                        host-side).  obs/latency.audit_report turns
    #                        the pair into a mispricing report
    # leaf-direct route table (DESIGN.md §13): R = max(route_table_slots, 1)
    # fence-verified entries, replicated like ``boundaries``.  Entry i says
    # "keys in [rt_keys[i], rt_hi[i]) lived in leaf (rt_sub[i], rt_local[i])
    # when versions[gid] was rt_ver[i]" — the engine accepts the guess only
    # while both the bounds and that version still hold, so a stale or
    # poisoned table degrades to full descent, never to wrong answers.
    # rt_ver == -1 marks an inactive slot (rt_keys KEY_MAX sorts it last).
    rt_keys: jax.Array     # [R] int64 sorted fence-low keys
    rt_hi: jax.Array       # [R] int64 exclusive fence-high keys
    rt_sub: jax.Array      # [R] int32 predicted subtree
    rt_local: jax.Array    # [R] int32 predicted leaf local id
    rt_ver: jax.Array      # [R] int32 leaf version at training time


def init_state(
    pool: SubtreePool,
    meta: PoolMeta,
    cfg: DexMeshConfig,
    boundaries: np.ndarray,
) -> DexState:
    levels = meta.levels_in_subtree
    n_nodes = meta.n_subtrees_padded * meta.subtree_cap
    succ0 = jnp.asarray(initial_succ(meta))
    base = meta.base_cap if meta.base_cap > 0 else meta.subtree_cap
    return DexState(
        pool=pool,
        cache=init_cache(cfg),
        boundaries=jnp.asarray(boundaries, jnp.int64),
        miss_ema=jnp.ones((cfg.n_devices, cfg.n_memory, levels), jnp.float32),
        stats=jnp.zeros((cfg.n_devices, N_STATS), jnp.int64),
        versions=jnp.zeros((cfg.n_devices, n_nodes), jnp.int32),
        occupancy=jnp.sum(pool.pool_keys != KEY_MAX, axis=-1).astype(jnp.int32),
        route_demand=jnp.zeros((cfg.n_devices, cfg.n_route), jnp.int64),
        succ=jnp.broadcast_to(succ0[None, :], (cfg.n_devices, n_nodes)),
        n_alloc=jnp.full((meta.n_subtrees_padded,), base, jnp.int32),
        lat_hist=jnp.zeros(
            (cfg.n_devices, _latency.N_CLASSES, _latency.N_PATHS,
             _latency.N_BUCKETS),
            jnp.int64,
        ),
        lat_audit=jnp.zeros(
            (cfg.n_devices, 2, cfg.n_memory, levels), jnp.float32
        ),
        rt_keys=jnp.full((max(cfg.route_table_slots, 1),), KEY_MAX, jnp.int64),
        rt_hi=jnp.full((max(cfg.route_table_slots, 1),), KEY_MAX, jnp.int64),
        rt_sub=jnp.zeros((max(cfg.route_table_slots, 1),), jnp.int32),
        rt_local=jnp.zeros((max(cfg.route_table_slots, 1),), jnp.int32),
        rt_ver=jnp.full((max(cfg.route_table_slots, 1),), -1, jnp.int32),
    )


def state_shardings(mesh, cfg: DexMeshConfig):
    """NamedShardings for a DexState on ``mesh``."""
    dev = P(cfg.all_axes)

    def ns(spec):
        return jax.sharding.NamedSharding(mesh, spec)

    pool_spec = SubtreePool(
        top_keys=ns(P()),
        top_children=ns(P()),
        pool_keys=ns(P(cfg.memory_axis)),
        pool_children=ns(P(cfg.memory_axis)),
        pool_values=ns(P(cfg.memory_axis)),
    )
    cache_spec = DexCache(
        tags=ns(dev), keys=ns(dev), children=ns(dev), values=ns(dev),
        fifo=ns(dev), ver=ns(dev),
    )
    return DexState(
        pool=pool_spec,
        cache=cache_spec,
        boundaries=ns(P()),
        miss_ema=ns(dev),
        stats=ns(dev),
        versions=ns(dev),
        occupancy=ns(P(cfg.memory_axis)),
        route_demand=ns(dev),
        succ=ns(dev),
        n_alloc=ns(P(cfg.memory_axis)),
        lat_hist=ns(dev),
        lat_audit=ns(dev),
        rt_keys=ns(P()),
        rt_hi=ns(P()),
        rt_sub=ns(P()),
        rt_local=ns(P()),
        rt_ver=ns(P()),
    )


# ---------------------------------------------------------------------------
# the sharded lookup (routing helpers shared with core/scan.py live in
# core/routing.py; the cache probe/admit/fetch machinery in
# core/fleet_cache.py)
# ---------------------------------------------------------------------------


def make_dex_lookup(meta: PoolMeta, cfg: DexMeshConfig, mesh):
    """Build the sharded lookup:
    ``(state, keys) -> (state, found, values, shed)``.

    A thin single-opcode wrapper over the unified mixed-op engine
    (:func:`repro.core.engine.make_dex_engine`): one route round, one
    version-checked cached descent, and — for columns whose per-group cost
    model picks the two-sided path — tagged offload messages in the fused
    ``all_to_all`` round.  ``keys`` is globally sharded over all mesh axes;
    results come back in the caller's lane order.  ``shed`` marks lanes
    that were load-shed by a routing bucket (their ``found``/``values`` are
    not answers — the caller retries them, and the repartition controller
    uses the drop counters to move partition boundaries so they stop
    happening).  Wrap with ``jax.jit`` (see serve/ and launch/).
    """
    from repro.core import engine as engine_mod  # deferred: engine imports us

    eng = engine_mod.make_dex_engine(meta, cfg, mesh, ops=("lookup",))

    def lookup(state: DexState, keys: jax.Array):
        keys = keys.astype(jnp.int64)
        opcodes = jnp.full(keys.shape, engine_mod.OP_LOOKUP, jnp.int32)
        new_state, r = eng(state, opcodes, keys, jnp.zeros_like(keys))
        return new_state, r.found, r.values, r.shed

    return lookup
