"""DEX on a TPU mesh (Plane B): logical partitioning, per-chip caching and
opportunistic offloading expressed as SPMD collectives.

Mapping (DESIGN.md §2):

  compute server   -> a chip; key ranges are owned by rows of the
                      ``route`` axes (logical partitioning)
  memory server    -> a column of the ``memory`` axis; the subtree-blocked
                      pool (core/pool.py) block-shards over it, so a whole
                      level-M subtree lives on one column (paper §3)
  RDMA READ        -> request/response ``all_to_all`` over the memory axis
                      carrying 1KB node rows (one round per tree level)
  offload RPC      -> one request/response ``all_to_all`` carrying keys in
                      and values out; the owner walks its local block
  compute-side     -> per-chip set-associative arrays; FIFO-within-set is
  cache               the vectorized form of the paper's cooling map
                      (bucket == set), lazy admission via key-hash bits

The batch-level offload decision replaces the paper's per-op moving-average
latency estimates (which require wall-clock self-measurement, impossible in
an SPMD program) with running per-level miss-rate EMAs and a byte-cost
comparison — the same ``l_p < (L+1) * (l_o + l_s) * c`` structure evaluated
on predicted bytes instead of measured latencies (DESIGN.md §2.1).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import routing
from repro.core.nodes import FANOUT, KEY_MAX
from repro.core.pool import PoolMeta, SubtreePool, initial_succ, top_walk
from repro.core.routing import (
    hash64 as _hash64,
    pack_by_dest as _pack_by_dest,
    unpack_to_lanes as _unpack_to_lanes,
)

NODE_ROW_BYTES = FANOUT * 8 * 3  # keys + children + values on the wire
OFFLOAD_REQ_BYTES = 16
OFFLOAD_RESP_BYTES = 16

# stat counter indices
(
    STAT_OPS,
    STAT_HITS,
    STAT_FETCHES,
    STAT_OFFLOADS,
    STAT_DROPS,
    STAT_SPLITS,      # inserts shed by an overflowing leaf (core/write.py);
    #                   resolved on-mesh by core/smo.py or drained to host
    STAT_WRITES,      # remote leaf-write messages (RDMA WRITE analogue)
    STAT_SMO_SPLITS,  # structural splits executed device-side (core/smo.py)
    STAT_DRAINS,      # host pool rebuilds (drain_splits fallback ladder)
    N_STATS,
) = range(10)


@dataclasses.dataclass(frozen=True)
class DexMeshConfig:
    """Static configuration for the mesh plane."""

    route_axes: Tuple[str, ...] = ("data",)   # compute-partition axes
    memory_axis: str = "model"                # pool-shard axis
    n_route: int = 1                          # product of route axis sizes
    n_memory: int = 1                         # memory axis size
    cache_sets: int = 256
    cache_ways: int = 4
    p_admit_leaf_pct: int = 10                # paper §5.4: P_A = 0.1
    route_capacity_factor: float = 2.0        # all_to_all bucket slack
    policy: str = "auto"                      # fetch | offload | auto
    offload_c: float = 1.3                    # cost coefficient (§6.1)
    ema_decay: float = 0.98

    @property
    def n_devices(self) -> int:
        return self.n_route * self.n_memory

    @property
    def all_axes(self) -> Tuple[str, ...]:
        return self.route_axes + (self.memory_axis,)


class DexCache(NamedTuple):
    """Per-chip set-associative node cache; axis 0 is the device axis."""

    tags: jax.Array      # [Dev, sets, ways] int64, -1 empty
    keys: jax.Array      # [Dev, sets, ways, FANOUT] int64
    children: jax.Array  # [Dev, sets, ways, FANOUT] int32
    values: jax.Array    # [Dev, sets, ways, FANOUT] int64
    fifo: jax.Array      # [Dev, sets] int32 (FIFO-within-set pointer)
    ver: jax.Array       # [Dev, sets, ways] int32 node version at admit time


class DexState(NamedTuple):
    pool: SubtreePool
    cache: DexCache
    boundaries: jax.Array  # [n_route + 1] int64, replicated
    miss_ema: jax.Array    # [Dev, levels] f32 per-level miss-rate EMA
    stats: jax.Array       # [Dev, N_STATS] int64
    versions: jax.Array    # [Dev, n_nodes] int32 per-node write version
    occupancy: jax.Array   # [S, C] int32 keys per node (pool-aligned shard)
    route_demand: jax.Array  # [Dev, n_route] int64 routed requests per
    #                          partition measured at the *source* chip —
    #                          counts shed lanes too, so unlike the served
    #                          STAT_OPS it never saturates at bucket
    #                          capacity (the repartition controller's load
    #                          signal, core/repartition.py)
    succ: jax.Array        # [Dev, n_nodes] int64 leaf successor gid (-1
    #                        ends the chain; scans follow this instead of
    #                        leaf-id arithmetic — on-mesh splits relocate
    #                        leaves into the free-list headroom)
    n_alloc: jax.Array     # [S] int32 per-subtree free-list watermark
    #                        (pool-aligned shard): next free local node id;
    #                        subtree_cap means the block is out of headroom
    #                        and its splits drain through the host path


def init_cache(cfg: DexMeshConfig) -> DexCache:
    d, s, w = cfg.n_devices, cfg.cache_sets, cfg.cache_ways
    return DexCache(
        tags=jnp.full((d, s, w), -1, jnp.int64),
        keys=jnp.full((d, s, w, FANOUT), KEY_MAX, jnp.int64),
        children=jnp.zeros((d, s, w, FANOUT), jnp.int32),
        values=jnp.zeros((d, s, w, FANOUT), jnp.int64),
        fifo=jnp.zeros((d, s), jnp.int32),
        ver=jnp.zeros((d, s, w), jnp.int32),
    )


def init_state(
    pool: SubtreePool,
    meta: PoolMeta,
    cfg: DexMeshConfig,
    boundaries: np.ndarray,
) -> DexState:
    levels = meta.levels_in_subtree
    n_nodes = meta.n_subtrees_padded * meta.subtree_cap
    succ0 = jnp.asarray(initial_succ(meta))
    base = meta.base_cap if meta.base_cap > 0 else meta.subtree_cap
    return DexState(
        pool=pool,
        cache=init_cache(cfg),
        boundaries=jnp.asarray(boundaries, jnp.int64),
        miss_ema=jnp.ones((cfg.n_devices, levels), jnp.float32),
        stats=jnp.zeros((cfg.n_devices, N_STATS), jnp.int64),
        versions=jnp.zeros((cfg.n_devices, n_nodes), jnp.int32),
        occupancy=jnp.sum(pool.pool_keys != KEY_MAX, axis=-1).astype(jnp.int32),
        route_demand=jnp.zeros((cfg.n_devices, cfg.n_route), jnp.int64),
        succ=jnp.broadcast_to(succ0[None, :], (cfg.n_devices, n_nodes)),
        n_alloc=jnp.full((meta.n_subtrees_padded,), base, jnp.int32),
    )


def state_shardings(mesh, cfg: DexMeshConfig):
    """NamedShardings for a DexState on ``mesh``."""
    dev = P(cfg.all_axes)

    def ns(spec):
        return jax.sharding.NamedSharding(mesh, spec)

    pool_spec = SubtreePool(
        top_keys=ns(P()),
        top_children=ns(P()),
        pool_keys=ns(P(cfg.memory_axis)),
        pool_children=ns(P(cfg.memory_axis)),
        pool_values=ns(P(cfg.memory_axis)),
    )
    cache_spec = DexCache(
        tags=ns(dev), keys=ns(dev), children=ns(dev), values=ns(dev),
        fifo=ns(dev), ver=ns(dev),
    )
    return DexState(
        pool=pool_spec,
        cache=cache_spec,
        boundaries=ns(P()),
        miss_ema=ns(dev),
        stats=ns(dev),
        versions=ns(dev),
        occupancy=ns(P(cfg.memory_axis)),
        route_demand=ns(dev),
        succ=ns(dev),
        n_alloc=ns(P(cfg.memory_axis)),
    )


# ---------------------------------------------------------------------------
# the sharded lookup (routing helpers shared with core/scan.py live in
# core/routing.py)
# ---------------------------------------------------------------------------


def _cache_probe(cache: DexCache, cfg: DexMeshConfig, versions: jax.Array,
                 gid: jax.Array):
    """Probe the per-chip cache.  A tag match only counts as a hit when the
    entry's admit-time version still equals the node's current version
    (``versions`` is this chip's replicated per-node version table) — rows
    made stale by another chip's write are rejected and re-fetched.  Returns
    ``(hit, keys_row, children_row, values_row, set_idx, present)`` where
    ``present`` marks a tag match regardless of version (a stale copy that
    ``_cache_admit`` will refresh in place)."""
    set_idx = (_hash64(gid) % jnp.uint64(cfg.cache_sets)).astype(jnp.int32)
    tags = cache.tags[0, set_idx]                        # [B, W]
    tagged = tags == gid[:, None]
    fresh = cache.ver[0, set_idx] == versions[gid][:, None]
    eq = tagged & fresh
    hit = jnp.any(eq, axis=-1)
    present = jnp.any(tagged, axis=-1)  # tag match, possibly version-stale
    way = jnp.argmax(eq, axis=-1).astype(jnp.int32)
    k = cache.keys[0, set_idx, way]
    c = cache.children[0, set_idx, way]
    v = cache.values[0, set_idx, way]
    return hit, k, c, v, set_idx, present


def _cache_admit(
    cache: DexCache,
    cfg: DexMeshConfig,
    versions: jax.Array,
    gid: jax.Array,
    set_idx: jax.Array,
    admit: jax.Array,
    rows_k: jax.Array,
    rows_c: jax.Array,
    rows_v: jax.Array,
) -> DexCache:
    """FIFO-within-set insertion of fetched rows (cooling-map analogue).
    Admitted rows are stamped with the node's current version.  A row whose
    tag is already present (a version-stale copy being refetched) is
    *refreshed in place* — same way, no FIFO advance — so staleness heals
    without re-rolling the admission dice."""
    tagged = cache.tags[0, set_idx] == gid[:, None]
    present = jnp.any(tagged, axis=-1)
    pway = jnp.argmax(tagged, axis=-1).astype(jnp.int32)
    fway = (cache.fifo[0, set_idx] % cfg.cache_ways).astype(jnp.int32)
    way = jnp.where(present, pway, fway)
    # non-admitting lanes scatter out of bounds (dropped)
    sidx = jnp.where(admit, set_idx, cfg.cache_sets)
    tags = cache.tags.at[0, sidx, way].set(gid, mode="drop")
    keys = cache.keys.at[0, sidx, way].set(rows_k, mode="drop")
    children = cache.children.at[0, sidx, way].set(rows_c, mode="drop")
    values = cache.values.at[0, sidx, way].set(rows_v, mode="drop")
    fifo = cache.fifo.at[0, jnp.where(present, cfg.cache_sets, sidx)].add(
        1, mode="drop"
    )
    ver = cache.ver.at[0, sidx, way].set(versions[gid], mode="drop")
    return DexCache(tags=tags, keys=keys, children=children, values=values,
                    fifo=fifo, ver=ver)


_fetch_rows = routing.fetch_rows  # re-export; shared with core/scan.py


def cached_fetch_level(
    pool: SubtreePool,
    meta: PoolMeta,
    cfg: DexMeshConfig,
    cache: DexCache,
    versions: jax.Array,
    gid: jax.Array,
    want: jax.Array,
    admit_ok: jax.Array,
):
    """One level of the cached traversal, shared by lookup, scan and the
    write path: probe the per-chip cache for ``gid`` rows (rejecting entries
    whose admit-time version is stale against ``versions``), remote-fetch
    the misses, and admit fetched rows where ``admit_ok`` (a load-shed
    fetch's placeholder row is never admitted).  Returns ``(rows_k, rows_c,
    rows_v, hit, miss, shed, n_msgs, new_cache)`` with ``hit``/``miss`` already
    masked by ``want``; ``n_msgs`` counts the coalesced remote-read messages
    (duplicate same-node misses in a batch share one message)."""
    hit, ck, cc, cv, set_idx, present = _cache_probe(cache, cfg, versions, gid)
    hit = hit & want
    miss = want & ~hit
    fk, fc, fv, shed, n_msgs = _fetch_rows(pool, meta, cfg, gid, miss)
    rows_k = jnp.where(hit[:, None], ck, fk)
    rows_c = jnp.where(hit[:, None], cc, fc)
    rows_v = jnp.where(hit[:, None], cv, fv)
    # version-stale tagged rows always refresh in place; the admission dice
    # only gates brand-new entries
    new_cache = _cache_admit(
        cache, cfg, versions, gid, set_idx,
        miss & (admit_ok | present) & ~shed,
        rows_k, rows_c, rows_v,
    )
    return rows_k, rows_c, rows_v, hit, miss, shed, n_msgs, new_cache


def _offload_walk(
    pool: SubtreePool,
    meta: PoolMeta,
    cfg: DexMeshConfig,
    queries: jax.Array,
    subtree: jax.Array,
    want: jax.Array,
):
    """Offload the remaining traversal to the owning memory column (§6):
    one request/response all_to_all; the owner walks its local block."""
    b = queries.shape[0]
    s_per = meta.n_subtrees_padded // cfg.n_memory
    owner = jnp.where(want, subtree // s_per, cfg.n_memory)
    cap = routing.route_capacity(b, cfg.n_memory, cfg.route_capacity_factor)
    payload = jnp.stack([queries, subtree.astype(jnp.int64)], axis=-1)  # [B, 2]
    buf, lane, dropped = _pack_by_dest(payload, owner.astype(jnp.int32), cfg.n_memory, cap)
    req = routing.a2a(buf, cfg.memory_axis)                # [n_mem, cap, 2]
    q = req[..., 0]
    st_global = req[..., 1]
    valid = q != KEY_MAX
    st = jnp.where(valid, st_global.astype(jnp.int32) % s_per, 0)
    # local walk, levels_in_subtree levels, entirely in the owner's block
    local = jnp.zeros(st.shape, jnp.int32)
    for _ in range(meta.levels_in_subtree - 1):
        rows = pool.pool_keys[st, local]                   # [n_mem, cap, F]
        cnt = jnp.sum(rows <= q[..., None], axis=-1)
        slot = jnp.maximum(cnt - 1, 0).astype(jnp.int32)
        local = jnp.take_along_axis(
            pool.pool_children[st, local], slot[..., None], axis=-1
        )[..., 0]
    rows = pool.pool_keys[st, local]
    eq = rows == q[..., None]
    found = jnp.any(eq, axis=-1) & valid
    vals = jnp.sum(jnp.where(eq, pool.pool_values[st, local], 0), axis=-1)
    resp = jnp.stack([found.astype(jnp.int64), vals], axis=-1)
    resp = routing.a2a(resp, cfg.memory_axis)
    out = _unpack_to_lanes(resp, lane, b, 0)
    # only lanes that sent a real request can be load-shed (OOB no-op lanes
    # share a sentinel bucket whose overflow is meaningless)
    return out[..., 0] != 0, out[..., 1], dropped & want


def make_dex_lookup(meta: PoolMeta, cfg: DexMeshConfig, mesh):
    """Build the sharded lookup:
    ``(state, keys) -> (state, found, values, shed)``.

    ``keys`` is globally sharded over all mesh axes; results come back in the
    caller's lane order.  ``shed`` marks lanes that were load-shed by a
    routing bucket (their ``found``/``values`` are not answers — the caller
    retries them, and the repartition controller uses the drop counters to
    move partition boundaries so they stop happening).  Wrap with
    ``jax.jit`` (see serve/ and launch/).
    """
    levels = meta.levels_in_subtree

    def local_fn(pool, cache, boundaries, miss_ema, stats, demand, versions,
                 keys):
        b = keys.shape[0]
        n_route = cfg.n_route
        vers = versions[0]

        # --- 1. route to the owning partition (logical partitioning, §4) ---
        owner, dem = routing.route_owners(boundaries, keys, n_route)
        new_demand = demand + dem
        cap = routing.route_capacity(b, n_route, cfg.route_capacity_factor)
        buf, lane, dropped_r = _pack_by_dest(keys, owner, n_route, cap)
        # inactive lanes share the OOB sentinel bucket; its overflow is
        # meaningless (see routing.route_owners)
        dropped_r = dropped_r & (keys != KEY_MAX)
        routed = routing.route_exchange(buf, cfg, mesh)
        q = routed.reshape(-1)                              # [n_route*cap]
        live = q != KEY_MAX

        # --- 2. replicated top-tree walk (always-cached upper levels) ------
        subtree = top_walk(pool, meta, q)
        subtree = jnp.where(live, subtree, 0)

        # --- 3. offload decision (batch-level cost model, §6.1) ------------
        # predicted one-sided cost: sum over levels of miss-EMA * node bytes
        fetch_bytes = jnp.sum(miss_ema[0]) * NODE_ROW_BYTES * cfg.offload_c
        offload_bytes = jnp.float32(OFFLOAD_REQ_BYTES + OFFLOAD_RESP_BYTES)
        want_offload = fetch_bytes > offload_bytes
        if cfg.policy == "fetch":
            want_offload = jnp.asarray(False)
        elif cfg.policy == "offload":
            want_offload = jnp.asarray(True)
        # uniform across devices: EMA is psum-synchronized below, and the
        # predicate depends only on replicated state
        want_offload = jnp.all(want_offload)

        # --- 4a. cached walk with per-level remote fetch (one-sided path) --
        def fetch_branch(cache):
            local = jnp.zeros(q.shape, jnp.int32)
            found = jnp.zeros(q.shape, bool)
            vals = jnp.zeros(q.shape, jnp.int64)
            new_cache = cache
            miss_counts = []
            n_fetch = jnp.int64(0)
            n_hit = jnp.int64(0)
            shed = jnp.zeros(q.shape, bool)  # lanes whose fetch was load-shed
            for lvl in range(levels):
                gid = meta.node_gid(subtree, local)
                # lazy admission: inner always, leaves with P_A (§5.4);
                # op counter + lane index re-roll the dice per access
                if lvl == levels - 1:
                    p_ok = routing.leaf_admit_dice(
                        gid, cfg.p_admit_leaf_pct,
                        salt=stats[0, STAT_OPS] + jnp.arange(q.shape[0]),
                    )
                else:
                    p_ok = jnp.ones(q.shape, bool)
                rows_k, rows_c, rows_v, hit, miss, f_drop, n_msgs, new_cache = (
                    cached_fetch_level(
                        pool, meta, cfg, new_cache, vers, gid, live, p_ok
                    )
                )
                shed = shed | f_drop
                miss_counts.append(jnp.sum(miss))
                n_fetch = n_fetch + n_msgs
                n_hit = n_hit + jnp.sum(hit).astype(jnp.int64)
                if lvl < levels - 1:
                    cnt = jnp.sum(rows_k <= q[:, None], axis=-1)
                    slot = jnp.maximum(cnt - 1, 0).astype(jnp.int32)
                    local = jnp.take_along_axis(rows_c, slot[:, None], axis=-1)[:, 0]
                else:
                    eq = rows_k == q[:, None]
                    found = jnp.any(eq, axis=-1) & live
                    vals = jnp.sum(jnp.where(eq, rows_v, 0), axis=-1)
            # a shed lane walked on placeholder rows: its result is garbage,
            # not a miss — report not-found and count it as load shed
            found = found & ~shed
            vals = jnp.where(shed, 0, vals)
            total = jnp.maximum(jnp.sum(live), 1)
            rates = jnp.stack(
                [m.astype(jnp.float32) / total.astype(jnp.float32)
                 for m in miss_counts]
            )
            return (found, vals, new_cache, rates, n_fetch, n_hit,
                    jnp.int64(0), shed)

        # --- 4b. offload the whole sub-path (two-sided path) ---------------
        def offload_branch(cache):
            found, vals, o_drop = _offload_walk(pool, meta, cfg, q, subtree, live)
            found = found & ~o_drop
            vals = jnp.where(o_drop, 0, vals)
            rates = miss_ema[0]  # unchanged estimate
            n_off = jnp.sum(live).astype(jnp.int64)
            return (found, vals, cache, rates, jnp.int64(0), jnp.int64(0),
                    n_off, o_drop & live)

        found, vals, new_cache, rates, n_fetch, n_hit, n_off, q_shed = jax.lax.cond(
            want_offload, offload_branch, fetch_branch, cache
        )
        q_shed = q_shed & live
        n_shed = jnp.sum(q_shed).astype(jnp.int64)

        # --- 5. EMA + stats -------------------------------------------------
        # synchronize the miss EMA across the full mesh so future decisions
        # are uniform
        g_rates = jax.lax.pmean(rates, cfg.all_axes)
        new_ema = cfg.ema_decay * miss_ema + (1 - cfg.ema_decay) * g_rates[None, :]
        ops = jnp.sum(live).astype(jnp.int64)
        upd = jnp.zeros((1, N_STATS), jnp.int64)
        upd = upd.at[0, STAT_OPS].set(ops)
        upd = upd.at[0, STAT_HITS].set(n_hit)
        upd = upd.at[0, STAT_FETCHES].set(n_fetch)
        upd = upd.at[0, STAT_OFFLOADS].set(n_off)
        upd = upd.at[0, STAT_DROPS].set(
            jnp.sum(dropped_r).astype(jnp.int64) + n_shed
        )
        new_stats = stats + upd

        # --- 6. results back to the requesting lanes ------------------------
        resp = jnp.stack(
            [found.astype(jnp.int64), vals, q_shed.astype(jnp.int64)], axis=-1
        )
        resp = resp.reshape(n_route, cap, 3)
        back = routing.route_exchange(resp, cfg, mesh, reverse=True)
        out = _unpack_to_lanes(back, lane, b, 0)
        out_found = (out[..., 0] != 0) & ~dropped_r
        out_vals = out[..., 1]
        out_shed = (out[..., 2] != 0) | dropped_r
        return (new_cache, new_ema, new_stats, new_demand, out_found,
                out_vals, out_shed)

    dev = P(cfg.all_axes)
    pool_specs = SubtreePool(
        top_keys=P(),
        top_children=P(),
        pool_keys=P(cfg.memory_axis),
        pool_children=P(cfg.memory_axis),
        pool_values=P(cfg.memory_axis),
    )
    cache_specs = DexCache(tags=dev, keys=dev, children=dev, values=dev,
                           fifo=dev, ver=dev)

    sharded = routing.shard_map_compat(
        local_fn,
        mesh=mesh,
        in_specs=(pool_specs, cache_specs, P(), dev, dev, dev, dev,
                  P(cfg.all_axes)),
        out_specs=(cache_specs, dev, dev, dev, P(cfg.all_axes),
                   P(cfg.all_axes), P(cfg.all_axes)),
    )

    def lookup(state: DexState, keys: jax.Array):
        new_cache, new_ema, new_stats, new_demand, found, vals, shed = sharded(
            state.pool, state.cache, state.boundaries, state.miss_ema,
            state.stats, state.route_demand, state.versions, keys,
        )
        new_state = state._replace(
            cache=new_cache, miss_ema=new_ema, stats=new_stats,
            route_demand=new_demand,
        )
        return new_state, found, vals, shed

    return lookup
