"""Unified mixed-op execution engine for the mesh plane (Plane B).

Before this module every mixed YCSB batch paid three separately-jitted
programs — ``make_dex_lookup``, ``make_dex_update``/``make_dex_insert`` and
``make_dex_scan`` — each with its own route round, its own cached descent
and its own request/response ``all_to_all`` machinery, and the offload
decision (§6.1) was a single batch-global, lookup-only gate.
:func:`make_dex_engine` collapses all of that into **one SPMD program** that
consumes a per-lane *opcode plane* (``OP_LOOKUP`` / ``OP_UPDATE`` /
``OP_INSERT`` / ``OP_SCAN``) next to the key and value planes and executes
the whole mixed batch through:

  1. **one shared route round** (``routing.route_owners`` + a single
     ``route_exchange`` pair) for every opcode;
  2. **one shared version-checked cached descent** — inner levels for all
     lanes, the leaf level for lookup/update/scan lanes (inserts stop above
     the leaf, exactly like the old write path), with the per-chip cache
     probe/admit and coalesced remote fetches of ``cached_fetch_level``;
  3. scan lanes only: the successor-chain sibling hops of core/scan.py;
  4. **one fused request/response ``all_to_all`` pair** over the memory
     axis carrying *tagged mixed-op messages* — CAS-style updates and
     slack-slot inserts from the fetched path next to offloaded
     lookup/update/insert walks — applied by the owning memory column in a
     single conflict-resolved batch (``write._apply_leaf_writes``).

``make_dex_lookup`` / ``make_dex_update`` / ``make_dex_insert`` /
``make_dex_scan`` are thin single-opcode wrappers over this engine (the
static ``ops=`` set prunes dead machinery at trace time, so a lookup-only
program is as lean as the old one).

**Per-group cost-aware offloading (§6.1, refined).**  The old gate compared
one predicted per-lane fetch cost against a *once-per-batch* RPC price and
forced the whole batch down one branch.  The engine decides **per
destination memory column**: ``DexState.miss_ema`` is now a per-(column,
level) miss-rate EMA, and each column's group of live non-scan lanes
compares

  ``fetch(g) = sum_l min(n_live(g), nodes_l) * ema[g, l] * NODE_ROW_BYTES * c``
  ``rpc(g)   = n_live(g) * (OFFLOAD_REQ_BYTES + OFFLOAD_RESP_BYTES)``

— the RPC side now scales with the group's live-lane count (the fused plane
sends per-lane tagged messages, so a mostly-inactive KEY_MAX batch no
longer sees a spuriously cheap once-per-batch RPC price), while the fetch
side is capped by the column's node population per level (coalesced reads
never exceed the distinct nodes).  A cold column (EMA near 1) offloads
while a warm one fetches *within the same batch*; scans never offload
(§7), and offloaded inserts that would split shed ``STATUS_SPLIT`` to
core/smo.py exactly like fetched-path ones (the paper's SMO fallback
rule).  Group decisions are made on mesh-global live counts (one tiny
psum), so they are uniform across devices and countable once per batch
(``STAT_OFFLOAD_GROUPS`` / ``STAT_FETCH_GROUPS``, cross-validated against
``Simulator`` group accounting in benchmarks/fig13_mesh_engine.py).

Batch semantics match the phased sequential replay the benchmarks and
tests use: reads (lookups, scans) observe the pre-batch index, then
updates apply, then inserts — enforced by a phase-offset batch priority in
the conflict resolution.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import routing
from repro.core.dex import (
    NODE_ROW_BYTES,
    N_STATS,
    OFFLOAD_REQ_BYTES,
    OFFLOAD_RESP_BYTES,
    STAT_DROPS,
    STAT_FETCH_GROUPS,
    STAT_FETCHES,
    STAT_HITS,
    STAT_OFFLOAD_GROUPS,
    STAT_OFFLOADS,
    STAT_OPS,
    STAT_SPLITS,
    STAT_WRITES,
    DexCache,
    DexMeshConfig,
    DexState,
    cached_fetch_level,
)
from repro.core.nodes import FANOUT, KEY_MAX
from repro.core.pool import PoolMeta, SubtreePool, top_walk
from repro.core.write import (
    STATUS_MISS,
    STATUS_OK,
    STATUS_SHED,
    STATUS_SPLIT,
    _apply_leaf_writes,
)
from repro.kernels.leaf_scan import leaf_scan
from repro.kernels.ops import use_interpret
from repro.kernels.ref import leaf_scan_ref

# engine opcodes == the YCSB trace opcodes (data/ycsb.py), so a generated
# mixed workload slice feeds the engine directly
OP_LOOKUP, OP_UPDATE, OP_INSERT, OP_SCAN = 0, 1, 2, 3

ALL_OPS = ("lookup", "update", "insert", "scan")
DEFAULT_MAX_COUNT = 128

# fused-plane message tags (field 0 of a request record)
MSG_NONE = 0          # no request from this lane (or bucket padding)
MSG_UPDATE = 1        # fetched-path CAS update: gid known from the descent
MSG_INSERT = 2        # fetched-path slack-slot insert: gid from the descent
MSG_OFF_LOOKUP = 3    # offloaded lookup: owner walks its block
MSG_OFF_UPDATE = 4    # offloaded update: owner walks, then CAS
MSG_OFF_INSERT = 5    # offloaded insert: owner walks, then slack merge
REQ_FIELDS = 6        # (tag, gid, subtree, key, value, prio)
RESP_HEAD = 4         # (status, value, gid, leaf-took-inserts flag) ahead
#                       of the merged value row


def scan_hops(meta: PoolMeta, max_count: int) -> int:
    """Leaves that may contribute to a ``max_count``-record scan: the start
    leaf (which can contribute as little as nothing when the start key lies
    above its last record) plus enough minimally-filled leaves for the rest
    (``min_leaf_fill``: on-mesh splits can leave leaves half-full).  This is
    only the static loop bound — per-lane collected-count masking stops each
    lane's remote reads as soon as its count is covered."""
    return 1 + -(-max_count // meta.min_leaf_fill)


class EngineResult(NamedTuple):
    """Per-lane results of one mixed batch, in the caller's lane order.

    ``found``/``values`` answer lookup lanes; ``status`` answers write lanes
    (``STATUS_OK``/``STATUS_MISS``/``STATUS_SHED``/``STATUS_SPLIT``);
    ``shed`` marks lanes load-shed anywhere along their path (retry them);
    ``scan_keys``/``scan_values``/``taken`` answer scan lanes and are
    ``None`` when the engine was built without ``"scan"`` in ``ops``."""

    found: jax.Array
    values: jax.Array
    status: jax.Array
    shed: jax.Array
    scan_keys: Optional[jax.Array] = None
    scan_values: Optional[jax.Array] = None
    taken: Optional[jax.Array] = None


def _empty_result(b, mc, has_scan):
    return EngineResult(
        found=jnp.zeros((b,), bool),
        values=jnp.zeros((b,), jnp.int64),
        status=jnp.full((b,), STATUS_MISS, jnp.int32),
        shed=jnp.zeros((b,), bool),
        scan_keys=jnp.full((b, mc), KEY_MAX, jnp.int64) if has_scan else None,
        scan_values=jnp.zeros((b, mc), jnp.int64) if has_scan else None,
        taken=jnp.zeros((b,), jnp.int32) if has_scan else None,
    )


def make_dex_engine(
    meta: PoolMeta,
    cfg: DexMeshConfig,
    mesh,
    *,
    ops: Tuple[str, ...] = ALL_OPS,
    max_count: int = DEFAULT_MAX_COUNT,
    use_kernel: bool = True,
    interpret: "bool | None" = None,
):
    """Build the unified mixed-op program:
    ``(state, opcodes, keys, values) -> (state, EngineResult)``.

    ``opcodes``/``keys``/``values`` are [B] lanes globally sharded over all
    mesh axes; ``keys == KEY_MAX`` lanes are inactive no-ops regardless of
    opcode.  The ``values`` plane is overloaded per opcode: update/insert
    lanes carry the write payload, scan lanes carry their record count
    (clipped to ``max_count``), lookup lanes ignore it.  ``ops`` statically
    prunes machinery: opcodes outside the set are treated as inactive, and
    e.g. a ``("lookup",)`` engine contains no write round or scan hops —
    this is how the thin per-op wrappers stay as lean as the programs they
    replaced.  Wrap with ``jax.jit``.

    The returned function carries a ``plan`` attribute — the static
    collective structure ``{"route_rounds", "fused_pairs",
    "descent_levels", "scan_hops"}`` — which benchmarks print next to the
    traced collective counts (``routing.trace_collective_counts``).
    """
    for o in ops:
        if o not in ALL_OPS:
            raise ValueError(f"unknown op {o!r}; options: {ALL_OPS}")
    has_lookup = "lookup" in ops
    has_update = "update" in ops
    has_insert = "insert" in ops
    has_scan = "scan" in ops
    has_writes = has_update or has_insert
    # lanes that can offload (scans never do, §7)
    has_offloadable = has_lookup or has_writes
    # policy="fetch" statically prunes every two-sided branch: no offload
    # tags, no owner-side block walk inside the fused round
    may_offload = has_offloadable and cfg.policy != "fetch"
    # the one-sided descent is dead weight only when every offloadable lane
    # is forced two-sided and no scan lanes exist
    do_descent = has_scan or (cfg.policy != "offload") or not has_offloadable
    # the leaf level of the descent serves lookup/update answers and scan
    # hop 0; insert lanes stop above it
    do_leaf = has_lookup or has_update or has_scan
    do_fused = has_writes or may_offload
    levels = meta.levels_in_subtree
    hops = scan_hops(meta, max_count) if has_scan else 0
    mc = max_count
    if interpret is None:
        interpret = use_interpret()
    s_per = meta.n_subtrees_padded // cfg.n_memory
    # per-level node population of one column's subtrees: the fetch side of
    # the group cost model is capped by it (coalesced reads never exceed
    # the distinct nodes of a level)
    level_nodes = [
        float(s_per * min(meta.per_node**lvl, meta.leaves_per_subtree))
        for lvl in range(levels)
    ]

    def local_fn(pool, occupancy, cache, boundaries, miss_ema, stats, demand,
                 versions, succ, opcodes, keys, values):
        b = keys.shape[0]
        n_route = cfg.n_route
        vers = versions[0]
        succ_t = succ[0]
        n_nodes_total = vers.shape[0]

        # --- 1. ONE shared route round for every opcode --------------------
        dev = routing.device_linear_index(cfg, mesh)
        lane_prio = dev.astype(jnp.int64) * b + jnp.arange(b, dtype=jnp.int64)
        # phase-offset priority: all updates replay before all inserts, the
        # phased batch order the host-mirror validation uses
        phase = jnp.where(
            opcodes == OP_INSERT, jnp.int64(cfg.n_devices) * b, jnp.int64(0)
        )
        prio0 = lane_prio + phase
        owner, dem = routing.route_owners(boundaries, keys, n_route)
        new_demand = demand + dem
        cap = routing.route_capacity(b, n_route, cfg.route_capacity_factor)
        payload = jnp.stack(
            [keys, values, opcodes.astype(jnp.int64), prio0], axis=-1
        )                                                   # [B, 4]
        buf, lane, dropped_r = routing.pack_by_dest(payload, owner, n_route, cap)
        # inactive lanes share the OOB sentinel bucket; its overflow is
        # meaningless (see routing.route_owners)
        dropped_r = dropped_r & (keys != KEY_MAX)
        with jax.named_scope("dex/route"):
            routed = routing.route_exchange(buf, cfg, mesh)  # [n_route, cap, 4]
        q = routed[..., 0].reshape(-1)                      # [Q]
        val = routed[..., 1].reshape(-1)
        opc = routed[..., 2].reshape(-1).astype(jnp.int32)
        pr = routed[..., 3].reshape(-1)
        live = q != KEY_MAX
        is_scan = live & (opc == OP_SCAN) if has_scan else jnp.zeros(q.shape, bool)

        # --- 2. replicated top-tree walk + per-group offload decision ------
        subtree = top_walk(pool, meta, q)
        subtree = jnp.where(live, subtree, 0)
        col = (subtree // s_per).astype(jnp.int32)
        ema = miss_ema[0]                                   # [n_mem, levels]
        if has_offloadable and cfg.policy == "auto":
            # group = destination memory column; live counts are psum'd so
            # the decision is uniform across devices (and countable once)
            offable = live & ~is_scan
            n_live_c = (
                jnp.zeros((cfg.n_memory,), jnp.int64)
                .at[col].add(offable.astype(jnp.int64))
            )
            n_live_c = jax.lax.psum(n_live_c, cfg.all_axes)
            nf = n_live_c.astype(jnp.float32)
            caps = jnp.minimum(
                nf[:, None], jnp.asarray(level_nodes, jnp.float32)[None, :]
            )                                               # [n_mem, levels]
            fetch_cost = (
                jnp.sum(caps * ema, axis=-1) * NODE_ROW_BYTES * cfg.offload_c
            )
            rpc_cost = nf * float(OFFLOAD_REQ_BYTES + OFFLOAD_RESP_BYTES)
            want_off_c = fetch_cost > rpc_cost              # [n_mem] bool
            grp_live = n_live_c > 0
        elif has_offloadable and cfg.policy == "offload":
            offable = live & ~is_scan
            n_live_c = (
                jnp.zeros((cfg.n_memory,), jnp.int64)
                .at[col].add(offable.astype(jnp.int64))
            )
            n_live_c = jax.lax.psum(n_live_c, cfg.all_axes)
            want_off_c = jnp.ones((cfg.n_memory,), bool)
            grp_live = n_live_c > 0
        else:
            want_off_c = jnp.zeros((cfg.n_memory,), bool)
            grp_live = jnp.zeros((cfg.n_memory,), bool)
        offl = want_off_c[col] & live & ~is_scan if has_offloadable else (
            jnp.zeros(q.shape, bool)
        )
        n_off_groups = jnp.sum(want_off_c & grp_live).astype(jnp.int64)
        n_fetch_groups = jnp.sum(~want_off_c & grp_live).astype(jnp.int64)

        # --- 3. ONE shared version-checked cached descent ------------------
        fetchable = live & ~offl
        local = jnp.zeros(q.shape, jnp.int32)
        new_cache = cache
        n_fetch = jnp.int64(0)
        n_hit = jnp.int64(0)
        shed = jnp.zeros(q.shape, bool)
        found_leaf = jnp.zeros(q.shape, bool)
        vals_leaf = jnp.zeros(q.shape, jnp.int64)
        rows_k_leaf = jnp.full(q.shape + (FANOUT,), KEY_MAX, jnp.int64)
        rows_v_leaf = jnp.zeros(q.shape + (FANOUT,), jnp.int64)
        miss_cl = jnp.zeros((cfg.n_memory, levels), jnp.float32)
        want_cl = jnp.zeros((cfg.n_memory, levels), jnp.float32)
        if do_descent:
            descent_levels = levels if do_leaf else levels - 1
            for lvl in range(descent_levels):
                leaf_lvl = lvl == levels - 1
                if leaf_lvl:
                    want = fetchable & (
                        (opc == OP_LOOKUP) | (opc == OP_UPDATE) | is_scan
                    )
                    p_ok = routing.leaf_admit_dice(
                        meta.node_gid(subtree, local), cfg.p_admit_leaf_pct,
                        salt=stats[0, STAT_OPS] + jnp.arange(q.shape[0]),
                    )
                else:
                    want = fetchable
                    p_ok = jnp.ones(q.shape, bool)
                gid = meta.node_gid(subtree, local)
                with jax.named_scope(f"dex/descent/l{lvl}"):
                    rows_k, rows_c, rows_v, hit, miss, f_drop, n_msgs, \
                        new_cache = cached_fetch_level(
                            pool, meta, cfg, new_cache, vers, gid, want, p_ok
                        )
                shed = shed | f_drop
                n_fetch = n_fetch + n_msgs
                n_hit = n_hit + jnp.sum(hit).astype(jnp.int64)
                # per-(column, level) miss observation; scan lanes leave the
                # EMA untouched (they never offload)
                obs = (want & ~is_scan).astype(jnp.float32)
                miss_cl = miss_cl.at[col, lvl].add(
                    miss.astype(jnp.float32) * obs
                )
                want_cl = want_cl.at[col, lvl].add(obs)
                if not leaf_lvl:
                    cnt = jnp.sum(rows_k <= q[:, None], axis=-1)
                    slot = jnp.maximum(cnt - 1, 0).astype(jnp.int32)
                    local = jnp.take_along_axis(
                        rows_c, slot[:, None], axis=-1
                    )[:, 0]
                else:
                    eq = rows_k == q[:, None]
                    found_leaf = jnp.any(eq, axis=-1) & want
                    vals_leaf = jnp.sum(jnp.where(eq, rows_v, 0), axis=-1)
                    rows_k_leaf, rows_v_leaf = rows_k, rows_v
        leaf_gid = meta.node_gid(subtree, local)

        # --- 4. scan lanes: successor-chain sibling hops -------------------
        if has_scan:
            cnt_s = jnp.clip(
                jnp.where(is_scan, val, 0), 0, mc
            ).astype(jnp.int32)
            window_k = [jnp.where(is_scan[:, None], rows_k_leaf, KEY_MAX)]
            window_v = [jnp.where(is_scan[:, None], rows_v_leaf, 0)]
            collected = jnp.sum(
                ((window_k[0] != KEY_MAX) & (window_k[0] >= q[:, None]))
                .astype(jnp.int32),
                axis=-1,
            )
            in_range = is_scan
            gid_h = leaf_gid
            for h in range(1, hops):
                nxt = succ_t[jnp.where(in_range, gid_h, 0)]
                in_range = in_range & (collected < cnt_s) & (nxt >= 0)
                gid_h = jnp.where(in_range, nxt, gid_h)
                gid = jnp.where(in_range, gid_h, 0)
                p_ok = routing.leaf_admit_dice(
                    gid, cfg.p_admit_leaf_pct,
                    salt=stats[0, STAT_OPS] + h + jnp.arange(q.shape[0]),
                )
                with jax.named_scope(f"dex/scan/h{h}"):
                    rows_k, _rows_c, rows_v, hit, miss, f_drop, n_msgs, \
                        new_cache = cached_fetch_level(
                            pool, meta, cfg, new_cache, vers, gid, in_range,
                            p_ok,
                        )
                shed = shed | f_drop
                n_fetch = n_fetch + n_msgs
                n_hit = n_hit + jnp.sum(hit).astype(jnp.int64)
                rows_k = jnp.where(in_range[:, None], rows_k, KEY_MAX)
                rows_v = jnp.where(in_range[:, None], rows_v, 0)
                collected = collected + jnp.sum(
                    ((rows_k != KEY_MAX) & (rows_k >= q[:, None]))
                    .astype(jnp.int32),
                    axis=-1,
                )
                window_k.append(rows_k)
                window_v.append(rows_v)
            wk = jnp.concatenate(window_k, axis=-1)
            wv = jnp.concatenate(window_v, axis=-1)
            if use_kernel:
                sc_k, sc_v, taken = leaf_scan(
                    wk, wv, q, cnt_s, max_count=mc, interpret=interpret
                )
            else:
                sc_k, sc_v, taken = leaf_scan_ref(wk, wv, q, cnt_s, max_count=mc)
            ok_scan = is_scan & ~shed
            sc_k = jnp.where(ok_scan[:, None], sc_k, KEY_MAX)
            sc_v = jnp.where(ok_scan[:, None], sc_v, 0)
            taken = jnp.where(
                ok_scan, taken, jnp.where(is_scan & shed, -1, 0)
            ).astype(jnp.int32)

        # --- 5. ONE fused tagged request/response all_to_all pair ----------
        rstat = jnp.zeros(q.shape, jnp.int32)
        rval = jnp.zeros(q.shape, jnp.int64)
        rgid = jnp.full(q.shape, KEY_MAX, jnp.int64)
        rrow_v = jnp.zeros(q.shape + (FANOUT,), jnp.int64)
        send = jnp.zeros(q.shape, bool)
        dropped_w = jnp.zeros(q.shape, bool)
        n_off_msgs = jnp.int64(0)
        n_write_msgs = jnp.int64(0)
        new_pk, new_pv, new_occ = (
            pool.pool_keys, pool.pool_values, occupancy
        )
        if do_fused:
            tag = jnp.zeros(q.shape, jnp.int64)
            ok_lane = live & ~shed
            if has_lookup and may_offload:
                tag = jnp.where(
                    ok_lane & (opc == OP_LOOKUP) & offl, MSG_OFF_LOOKUP, tag
                )
            if has_update:
                if may_offload:
                    tag = jnp.where(
                        ok_lane & (opc == OP_UPDATE) & offl,
                        MSG_OFF_UPDATE, tag,
                    )
                tag = jnp.where(
                    ok_lane & (opc == OP_UPDATE) & ~offl & found_leaf,
                    MSG_UPDATE, tag,
                )
            if has_insert:
                if may_offload:
                    tag = jnp.where(
                        ok_lane & (opc == OP_INSERT) & offl,
                        MSG_OFF_INSERT, tag,
                    )
                tag = jnp.where(
                    ok_lane & (opc == OP_INSERT) & ~offl, MSG_INSERT, tag
                )
            send = tag != MSG_NONE
            dest = jnp.where(send, col, cfg.n_memory)
            wcap = routing.route_capacity(
                q.shape[0], cfg.n_memory, cfg.route_capacity_factor
            )
            wpayload = jnp.stack(
                [
                    tag,
                    jnp.where(
                        (tag == MSG_UPDATE) | (tag == MSG_INSERT),
                        leaf_gid, KEY_MAX,
                    ),
                    subtree.astype(jnp.int64),
                    q,
                    val,
                    pr,
                ],
                axis=-1,
            )                                               # [Q, REQ_FIELDS]
            wbuf, wlane, dropped_w = routing.pack_by_dest(
                wpayload, dest, cfg.n_memory, wcap
            )
            dropped_w = dropped_w & send
            with jax.named_scope("dex/fused_a2a/request"):
                req = routing.a2a(wbuf, cfg.memory_axis)  # [n_mem, wcap, RF]
            if has_writes:
                # every route-replica of this memory column must apply the
                # identical write batch (pool replicas stay consistent)
                req = routing.gather_route(req, cfg)     # [R, n_mem, wcap, RF]
            flat = req.reshape(-1, REQ_FIELDS)
            tagf = flat[:, 0]
            gidf = flat[:, 1]
            stf = flat[:, 2]
            kf = flat[:, 3]
            vf = flat[:, 4]
            prf = flat[:, 5]
            wgid = jnp.where(
                (tagf == MSG_UPDATE) | (tagf == MSG_INSERT), gidf, KEY_MAX
            )
            resp_val = jnp.zeros(kf.shape, jnp.int64)
            o_found = jnp.zeros(kf.shape, bool)
            if may_offload:
                offf = (tagf >= MSG_OFF_LOOKUP) & (tagf <= MSG_OFF_INSERT)
                # owner-side block walk for offloaded lanes (§6): the whole
                # remaining traversal runs next to the data
                stl = jnp.where(offf, stf % s_per, 0).astype(jnp.int32)
                loc = jnp.zeros(kf.shape, jnp.int32)
                for _ in range(levels - 1):
                    rows = pool.pool_keys[stl, loc]
                    cnt = jnp.sum(rows <= kf[:, None], axis=-1)
                    slot = jnp.maximum(cnt - 1, 0).astype(jnp.int32)
                    loc = jnp.take_along_axis(
                        pool.pool_children[stl, loc], slot[:, None], axis=-1
                    )[:, 0]
                o_rows_k = pool.pool_keys[stl, loc]
                o_eq = o_rows_k == kf[:, None]
                o_found = jnp.any(o_eq, axis=-1) & offf
                o_val = jnp.sum(
                    jnp.where(o_eq, pool.pool_values[stl, loc], 0), axis=-1
                )
                gid_eff = meta.node_gid(stf, loc.astype(jnp.int64))
                wgid = jnp.where(
                    (tagf == MSG_OFF_UPDATE) | (tagf == MSG_OFF_INSERT),
                    gid_eff, wgid,
                )
                resp_val = jnp.where(tagf == MSG_OFF_LOOKUP, o_val, 0)
            if has_writes:
                allow_ins = tagf == MSG_INSERT
                if may_offload:
                    allow_ins = allow_ins | (tagf == MSG_OFF_INSERT)
                with jax.named_scope("dex/apply"):
                    (new_pk, new_pv, new_occ, wstat, rows_v_all,
                     ins_in_leaf) = _apply_leaf_writes(
                        pool.pool_keys, pool.pool_values, occupancy, meta,
                        cfg, wgid, kf, vf, prf, allow_ins,
                        use_kernel=use_kernel, interpret=interpret,
                    )
            else:
                wstat = jnp.zeros(kf.shape, jnp.int32)
                rows_v_all = jnp.zeros(kf.shape + (FANOUT,), jnp.int64)
                ins_in_leaf = jnp.zeros(kf.shape, bool)
            if may_offload:
                wstat = jnp.where(
                    tagf == MSG_OFF_LOOKUP,
                    jnp.where(o_found, STATUS_OK, STATUS_MISS),
                    wstat,
                )
            resp = jnp.concatenate(
                [
                    wstat[:, None].astype(jnp.int64),
                    resp_val[:, None],
                    wgid[:, None],
                    ins_in_leaf[:, None].astype(jnp.int64),
                    rows_v_all,
                ],
                axis=-1,
            )
            if has_writes:
                # respond only to this device's own route row
                r_lin = routing.route_linear_index(cfg, mesh)
                resp = jnp.take(
                    resp.reshape(
                        cfg.n_route, cfg.n_memory, wcap, RESP_HEAD + FANOUT
                    ),
                    r_lin, axis=0,
                )
            else:
                resp = resp.reshape(cfg.n_memory, wcap, RESP_HEAD + FANOUT)
            with jax.named_scope("dex/fused_a2a/response"):
                resp = routing.a2a(resp, cfg.memory_axis)
            back = routing.unpack_to_lanes(resp, wlane, q.shape[0], 0)
            rstat = back[..., 0].astype(jnp.int32)
            rval = back[..., 1]
            rgid = back[..., 2]
            r_ins = back[..., 3] != 0
            rrow_v = back[..., RESP_HEAD:]
            delivered = send & ~dropped_w
            is_off_lane = offl & send
            n_off_msgs = jnp.sum(delivered & is_off_lane).astype(jnp.int64)
            n_write_msgs = jnp.sum(
                delivered & ~is_off_lane & (opc != OP_LOOKUP)
            ).astype(jnp.int64)

        # --- 6. write-through-and-invalidate + version bump ----------------
        new_versions = versions
        if has_writes:
            delivered = send & ~dropped_w
            wrote_ok = (
                delivered
                & ((opc == OP_UPDATE) | (opc == OP_INSERT))
                & (rstat == STATUS_OK)
            )
            gsafe0 = jnp.where(wrote_ok, rgid, 0)
            nv = vers[gsafe0] + 1
            gsafe = jnp.where(wrote_ok, rgid, n_nodes_total)
            vers2 = vers.at[gsafe].max(nv, mode="drop")
            new_versions = jax.lax.pmax(vers2[None, :], cfg.all_axes)
            set_idx = (
                routing.hash64(rgid) % jnp.uint64(cfg.cache_sets)
            ).astype(jnp.int32)
            eqt = new_cache.tags[0, set_idx] == rgid[:, None]
            chit = jnp.any(eqt, axis=-1) & wrote_ok
            way = jnp.argmax(eqt, axis=-1).astype(jnp.int32)
            if has_update:
                # refresh the chip's own cached row with the authoritative
                # post-batch values, stamped with the bumped version — but
                # NOT when the leaf also took same-batch inserts (possibly
                # from another chip): the cached keys plane would be stale
                # under a current version stamp; leaving the old stamp makes
                # the version check refetch the whole row instead
                u_hit = chit & (opc == OP_UPDATE) & ~r_ins
                sidx = jnp.where(u_hit, set_idx, cfg.cache_sets)
                cvals = new_cache.values.at[0, sidx, way].set(
                    rrow_v, mode="drop"
                )
                cver = new_cache.ver.at[0, sidx, way].set(
                    jnp.where(u_hit, nv, 0), mode="drop"
                )
                new_cache = new_cache._replace(values=cvals, ver=cver)
            if has_insert:
                # drop the chip's own (now key-shifted) cached row
                i_hit = chit & (opc == OP_INSERT)
                sidx = jnp.where(i_hit, set_idx, cfg.cache_sets)
                ctags = new_cache.tags.at[0, sidx, way].set(-1, mode="drop")
                new_cache = new_cache._replace(tags=ctags)

        # --- 7. per-lane results + statuses --------------------------------
        out_found = jnp.zeros(q.shape, bool)
        out_val = jnp.zeros(q.shape, jnp.int64)
        if has_lookup:
            is_lk = live & (opc == OP_LOOKUP)
            out_found = jnp.where(
                offl,
                (rstat == STATUS_OK) & send & ~dropped_w,
                found_leaf & ~shed,
            ) & is_lk
            out_val = jnp.where(
                out_found, jnp.where(offl, rval, vals_leaf), 0
            )
        status = jnp.full(q.shape, STATUS_MISS, jnp.int32)
        if has_writes:
            is_w = live & ((opc == OP_UPDATE) | (opc == OP_INSERT))
            shed_w = is_w & (shed | dropped_w)
            status = jnp.where(
                is_w & send & ~dropped_w & ~shed,
                rstat,
                jnp.where(shed_w, STATUS_SHED, STATUS_MISS),
            )
        lane_shed = shed | (send & dropped_w)

        # --- 8. EMA + stats -------------------------------------------------
        g_miss = jax.lax.psum(miss_cl, cfg.all_axes)
        g_want = jax.lax.psum(want_cl, cfg.all_axes)
        rates = g_miss / jnp.maximum(g_want, 1.0)
        new_ema = jnp.where(
            g_want[None, :, :] > 0,
            cfg.ema_decay * miss_ema + (1 - cfg.ema_decay) * rates[None, :, :],
            miss_ema,
        )
        n_shed = jnp.sum(lane_shed & live).astype(jnp.int64)
        upd = jnp.zeros((1, N_STATS), jnp.int64)
        upd = upd.at[0, STAT_OPS].set(jnp.sum(live).astype(jnp.int64))
        upd = upd.at[0, STAT_HITS].set(n_hit)
        upd = upd.at[0, STAT_FETCHES].set(n_fetch)
        upd = upd.at[0, STAT_OFFLOADS].set(n_off_msgs)
        upd = upd.at[0, STAT_WRITES].set(n_write_msgs)
        upd = upd.at[0, STAT_DROPS].set(
            jnp.sum(dropped_r).astype(jnp.int64) + n_shed
        )
        upd = upd.at[0, STAT_SPLITS].set(
            jnp.sum(status == STATUS_SPLIT).astype(jnp.int64)
        )
        if has_offloadable:
            # group decisions are mesh-global: count them once, on the
            # first device
            first = (dev == 0).astype(jnp.int64)
            upd = upd.at[0, STAT_OFFLOAD_GROUPS].set(first * n_off_groups)
            upd = upd.at[0, STAT_FETCH_GROUPS].set(first * n_fetch_groups)
        new_stats = stats + upd

        # --- 9. results back to the requesting lanes ------------------------
        fields = [
            out_found.astype(jnp.int64)[:, None],
            out_val[:, None],
            status.astype(jnp.int64)[:, None],
            lane_shed.astype(jnp.int64)[:, None],
        ]
        if has_scan:
            fields += [taken.astype(jnp.int64)[:, None], sc_k, sc_v]
        resp_b = jnp.concatenate(fields, axis=-1)
        width = resp_b.shape[-1]
        resp_b = resp_b.reshape(n_route, cap, width)
        with jax.named_scope("dex/route_back"):
            back_b = routing.route_exchange(resp_b, cfg, mesh, reverse=True)
        out = routing.unpack_to_lanes(back_b, lane, b, 0)
        res_found = (out[..., 0] != 0) & ~dropped_r
        res_val = jnp.where(dropped_r, 0, out[..., 1])
        res_status = jnp.where(
            dropped_r, STATUS_SHED, out[..., 2].astype(jnp.int32)
        )
        if not has_writes:
            res_status = jnp.where(
                dropped_r & (keys != KEY_MAX), STATUS_SHED, STATUS_MISS
            ).astype(jnp.int32)
        res_shed = (out[..., 3] != 0) | dropped_r

        outs = [new_cache, new_ema, new_stats, new_demand,
                res_found, res_val, res_status, res_shed]
        if has_writes:
            outs = [new_pk, new_pv, new_occ, new_versions] + outs
        if has_scan:
            res_taken = jnp.where(
                dropped_r, -1, out[..., 4]
            ).astype(jnp.int32)
            res_k = jnp.where(
                dropped_r[:, None], KEY_MAX, out[..., 5 : 5 + mc]
            )
            res_v = jnp.where(
                dropped_r[:, None], 0, out[..., 5 + mc : 5 + 2 * mc]
            )
            outs += [res_k, res_v, res_taken]
        return tuple(outs)

    dev_spec = P(cfg.all_axes)
    pool_specs = SubtreePool(
        top_keys=P(),
        top_children=P(),
        pool_keys=P(cfg.memory_axis),
        pool_children=P(cfg.memory_axis),
        pool_values=P(cfg.memory_axis),
    )
    cache_specs = DexCache(
        tags=dev_spec, keys=dev_spec, children=dev_spec, values=dev_spec,
        fifo=dev_spec, ver=dev_spec,
    )
    mem = P(cfg.memory_axis)
    lanes = P(cfg.all_axes)

    out_specs = []
    if has_writes:
        out_specs += [mem, mem, mem, dev_spec]
    out_specs += [cache_specs, dev_spec, dev_spec, dev_spec,
                  lanes, lanes, lanes, lanes]
    if has_scan:
        out_specs += [lanes, lanes, lanes]

    sharded = routing.shard_map_compat(
        local_fn,
        mesh=mesh,
        in_specs=(pool_specs, mem, cache_specs, P(), dev_spec, dev_spec,
                  dev_spec, dev_spec, dev_spec, lanes, lanes, lanes),
        out_specs=tuple(out_specs),
    )

    enabled_codes = [
        code for flag, code in [
            (has_lookup, OP_LOOKUP), (has_update, OP_UPDATE),
            (has_insert, OP_INSERT), (has_scan, OP_SCAN),
        ] if flag
    ]

    def engine(state: DexState, opcodes: jax.Array, keys: jax.Array,
               values: jax.Array):
        if keys.shape[0] == 0:
            return state, _empty_result(0, mc, has_scan)
        opcodes = opcodes.astype(jnp.int32)
        keys = keys.astype(jnp.int64)
        # opcodes outside the static ``ops`` set are true no-ops: their
        # keys are masked before routing, so they consume no bucket
        # capacity, mint no demand/stats and return inactive results
        allowed = jnp.zeros(opcodes.shape, bool)
        for code in enabled_codes:
            allowed = allowed | (opcodes == code)
        keys = jnp.where(allowed, keys, KEY_MAX)
        res = sharded(
            state.pool, state.occupancy, state.cache, state.boundaries,
            state.miss_ema, state.stats, state.route_demand, state.versions,
            state.succ, opcodes, keys, values.astype(jnp.int64),
        )
        res = list(res)
        new_state = state
        if has_writes:
            new_pk, new_pv, new_occ, new_versions = res[:4]
            res = res[4:]
            new_state = new_state._replace(
                pool=state.pool._replace(pool_keys=new_pk, pool_values=new_pv),
                occupancy=new_occ,
                versions=new_versions,
            )
        new_cache, new_ema, new_stats, new_demand = res[:4]
        found, vals, status, shed = res[4:8]
        new_state = new_state._replace(
            cache=new_cache, miss_ema=new_ema, stats=new_stats,
            route_demand=new_demand,
        )
        result = EngineResult(found=found, values=vals, status=status,
                              shed=shed)
        if has_scan:
            sk, sv, tk = res[8:11]
            result = result._replace(scan_keys=sk, scan_values=sv, taken=tk)
        return new_state, result

    engine.plan = {
        "route_rounds": 1,
        "fused_pairs": 1 if do_fused else 0,
        "descent_levels": (levels if do_leaf else levels - 1)
        if do_descent else 0,
        "scan_hops": hops,
        # jax.named_scope labels annotating the jitted program for profiler
        # traces (repro/obs/trace.py profiler_annotations); metadata only —
        # they add no ops and no collectives
        "phases": ("dex/route", "dex/descent", "dex/scan", "dex/fused_a2a",
                   "dex/apply", "dex/route_back"),
    }
    return engine
