"""Unified mixed-op execution engine for the mesh plane (Plane B).

Before this module every mixed YCSB batch paid three separately-jitted
programs — ``make_dex_lookup``, ``make_dex_update``/``make_dex_insert`` and
``make_dex_scan`` — each with its own route round, its own cached descent
and its own request/response ``all_to_all`` machinery, and the offload
decision (§6.1) was a single batch-global, lookup-only gate.
:func:`make_dex_engine` collapses all of that into **one SPMD program** that
consumes a per-lane *opcode plane* (``OP_LOOKUP`` / ``OP_UPDATE`` /
``OP_INSERT`` / ``OP_SCAN``) next to the key and value planes and executes
the whole mixed batch through:

  1. **one shared route round** (``routing.route_owners`` + a single
     ``route_exchange`` pair) for every opcode;
  2. **one shared version-checked cached descent** — inner levels for all
     lanes, the leaf level for lookup/update/scan lanes (inserts stop above
     the leaf, exactly like the old write path), with the per-chip cache
     probe/admit and coalesced remote fetches of ``cached_fetch_level``;
  3. scan lanes only: the successor-chain sibling hops of core/scan.py;
  4. **one fused request/response ``all_to_all`` pair** over the memory
     axis carrying *tagged mixed-op messages* — CAS-style updates and
     slack-slot inserts from the fetched path next to offloaded
     lookup/update/insert walks — applied by the owning memory column in a
     single conflict-resolved batch (``write._apply_leaf_writes``).

``make_dex_lookup`` / ``make_dex_update`` / ``make_dex_insert`` /
``make_dex_scan`` are thin single-opcode wrappers over this engine (the
static ``ops=`` set prunes dead machinery at trace time, so a lookup-only
program is as lean as the old one).

**Per-group cost-aware offloading (§6.1, refined).**  The old gate compared
one predicted per-lane fetch cost against a *once-per-batch* RPC price and
forced the whole batch down one branch.  The engine decides **per
destination memory column**: ``DexState.miss_ema`` is now a per-(column,
level) miss-rate EMA, and each column's group of live non-scan lanes
compares

  ``fetch(g) = sum_l min(n_live(g), nodes_l) * ema[g, l] * NODE_ROW_BYTES * c``
  ``rpc(g)   = n_live(g) * (OFFLOAD_REQ_BYTES + OFFLOAD_RESP_BYTES)``

— the RPC side now scales with the group's live-lane count (the fused plane
sends per-lane tagged messages, so a mostly-inactive KEY_MAX batch no
longer sees a spuriously cheap once-per-batch RPC price), while the fetch
side is capped by the column's node population per level (coalesced reads
never exceed the distinct nodes).  A cold column (EMA near 1) offloads
while a warm one fetches *within the same batch*; scans never offload
(§7), and offloaded inserts that would split shed ``STATUS_SPLIT`` to
core/smo.py exactly like fetched-path ones (the paper's SMO fallback
rule).  Group decisions are made on mesh-global live counts (one tiny
psum), so they are uniform across devices and countable once per batch
(``STAT_OFFLOAD_GROUPS`` / ``STAT_FETCH_GROUPS``, cross-validated against
``Simulator`` group accounting in benchmarks/fig13_mesh_engine.py).

Batch semantics match the phased sequential replay the benchmarks and
tests use: reads (lookups, scans) observe the pre-batch index, then
updates apply, then inserts — enforced by a phase-offset batch priority in
the conflict resolution.

Continuous-service pipelining (``pipeline=True``)
-------------------------------------------------
The batch-synchronous program above is one blocking round trip: the mesh
idles through the fused ``all_to_all`` pair and the leaf apply of batch N
before batch N+1's route round may start.  Outback's observation — that
communication rounds, not compute, bound disaggregated-memory throughput —
says exactly this gap is the throughput ceiling.  ``make_dex_engine(...,
pipeline=True)`` therefore returns an :class:`EnginePipeline`: a two-stage
software pipeline over a batch queue in which **step s executes batch
B_s's front half (route round + version-checked cached descent + scan
hops) fused with batch B_{s-1}'s back half (fused request/response
``all_to_all`` + leaf apply + result return)** inside one jitted dispatch.
The collectives of B_{s-1}'s write round are hidden under B_s's descent.

Correctness over the one-batch overlap window:

* **Navigation is static within a pipeline run.**  The leaf apply mutates
  only leaf key/value rows and occupancy; splits shed ``STATUS_SPLIT`` to
  the SMO path (settled between pipeline flushes), so inner nodes, the top
  tree and leaf *identity* never move while batches are in flight.  A
  front-half descent therefore always lands on the correct leaf gid — only
  the leaf's *contents* can be one batch stale.
* **Version stamps detect the overlap.**  The front half stamps the leaf
  version (and each scan hop's version) it descended through into the
  carry.  When the back half runs one step later it re-reads the version
  table — which by then includes the overlapped batch's bumps — and any
  mismatch marks the lane *stale-forced*: lookups and updates are forced
  onto the two-sided offload tags (``MSG_OFF_LOOKUP``/``MSG_OFF_UPDATE``),
  so the owning memory column re-resolves them against the authoritative
  post-overlap pool.  Inserts never need forcing: ``MSG_INSERT`` carries
  only the (stable) leaf gid and the apply re-searches the leaf anyway.
* **Writers stay ordered.**  The phase-offset batch priorities already
  order conflicting writers *within* a batch; across the overlap window
  batches apply strictly in order (step s applies B_{s-1} before step s+1
  applies B_s), so the sequential batch order is preserved exactly.
* **Conservative conflict stall.**  Scan lanes whose window crossed a leaf
  whose version moved are stall-shed (``taken = -1``, ``shed``) onto the
  repo's standard shed-and-retry lane — the conservative fallback for the
  one shape whose partial window cannot be patched cheaply.

Stale-forced lanes and stall-shed scans are counted in
``STAT_PIPE_STALLS`` (always 0 in batch-synchronous mode).  Results, pool,
occupancy and version evolution are bit-identical to the synchronous
engine run batch-by-batch on the same inputs (modulo shed-and-retry lanes,
which both modes surface through ``EngineResult.shed``); per-chip cache
contents and hit/fetch counters may diverge inside the overlap window —
a performance artifact, not a correctness one.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import fleet_cache
from repro.core import routing
from repro.core.dex import (
    NODE_ROW_BYTES,
    N_STATS,
    OFFLOAD_REQ_BYTES,
    OFFLOAD_RESP_BYTES,
    STAT_DROPS,
    STAT_FETCH_GROUPS,
    STAT_FETCHES,
    STAT_HITS,
    STAT_OFFLOAD_GROUPS,
    STAT_OFFLOADS,
    STAT_OPS,
    STAT_PEER_HITS,
    STAT_PEER_MISSES,
    STAT_PIPE_STALLS,
    STAT_RT_MISPREDICTS,
    STAT_RT_SKIPS,
    STAT_SPLITS,
    STAT_WRITES,
    DexMeshConfig,
    DexState,
)
from repro.core.fleet_cache import DexCache, cached_fetch_level
from repro.core.nodes import FANOUT, KEY_MAX
from repro.core.pool import PoolMeta, SubtreePool, top_walk
from repro.core.write import (
    STATUS_MISS,
    STATUS_OK,
    STATUS_SHED,
    STATUS_SPLIT,
    _apply_leaf_writes,
)
from repro.kernels.leaf_scan import leaf_scan
from repro.kernels.ops import use_interpret
from repro.kernels.ref import leaf_scan_ref
from repro.obs import latency as obs_latency

# engine opcodes == the YCSB trace opcodes (data/ycsb.py), so a generated
# mixed workload slice feeds the engine directly
OP_LOOKUP, OP_UPDATE, OP_INSERT, OP_SCAN = 0, 1, 2, 3

ALL_OPS = ("lookup", "update", "insert", "scan")
DEFAULT_MAX_COUNT = 128

# fused-plane message tags (field 0 of a request record)
MSG_NONE = 0          # no request from this lane (or bucket padding)
MSG_UPDATE = 1        # fetched-path CAS update: gid known from the descent
MSG_INSERT = 2        # fetched-path slack-slot insert: gid from the descent
MSG_OFF_LOOKUP = 3    # offloaded lookup: owner walks its block
MSG_OFF_UPDATE = 4    # offloaded update: owner walks, then CAS
MSG_OFF_INSERT = 5    # offloaded insert: owner walks, then slack merge
MSG_PEEK = 6          # peer peek: owner answers a sibling's leaf miss from
#                       its own version-checked cache, else its block walk
REQ_FIELDS = 6        # (tag, gid, subtree, key, value, prio)
RESP_HEAD = 4         # (status, value, gid, leaf-took-inserts flag — the
#                       flag doubles as the peer-cache-hit bit for MSG_PEEK
#                       lanes) ahead of the merged value row


def scan_hops(meta: PoolMeta, max_count: int) -> int:
    """Leaves that may contribute to a ``max_count``-record scan: the start
    leaf (which can contribute as little as nothing when the start key lies
    above its last record) plus enough minimally-filled leaves for the rest
    (``min_leaf_fill``: on-mesh splits can leave leaves half-full).  This is
    only the static loop bound — per-lane collected-count masking stops each
    lane's remote reads as soon as its count is covered."""
    return 1 + -(-max_count // meta.min_leaf_fill)


class EngineResult(NamedTuple):
    """Per-lane results of one mixed batch, in the caller's lane order.

    ``found``/``values`` answer lookup lanes; ``status`` answers write lanes
    (``STATUS_OK``/``STATUS_MISS``/``STATUS_SHED``/``STATUS_SPLIT``);
    ``shed`` marks lanes load-shed anywhere along their path (retry them);
    ``scan_keys``/``scan_values``/``taken`` answer scan lanes and are
    ``None`` when the engine was built without ``"scan"`` in ``ops``."""

    found: jax.Array
    values: jax.Array
    status: jax.Array
    shed: jax.Array
    scan_keys: Optional[jax.Array] = None
    scan_values: Optional[jax.Array] = None
    taken: Optional[jax.Array] = None


def _empty_result(b, mc, has_scan):
    return EngineResult(
        found=jnp.zeros((b,), bool),
        values=jnp.zeros((b,), jnp.int64),
        status=jnp.full((b,), STATUS_MISS, jnp.int32),
        shed=jnp.zeros((b,), bool),
        scan_keys=jnp.full((b, mc), KEY_MAX, jnp.int64) if has_scan else None,
        scan_values=jnp.zeros((b, mc), jnp.int64) if has_scan else None,
        taken=jnp.zeros((b,), jnp.int32) if has_scan else None,
    )


class EnginePipeline:
    """Two-stage software pipeline over a batch queue (prologue /
    steady-state / drain).

    ``push(opcodes, keys, values)`` dispatches one fused step — the new
    batch's front half overlapped with the previous batch's back half —
    and returns the **previous** batch's :class:`EngineResult` (device
    futures; ``np.asarray`` them to block).  The first push primes the
    pipeline and returns ``None``; ``drain()`` pushes an inactive batch to
    flush the last in-flight back half and returns the final result.
    Every pushed batch must share one lane width.

    ``step_fn`` (the unjitted step) and ``init_carry(b)`` are exposed so
    benchmarks can run ``routing.trace_collective_counts`` over one steady
    -state step; ``plan`` carries the static collective structure like the
    synchronous engine's.
    """

    def __init__(self, step, init_carry, plan):
        self.step_fn = step
        self.init_carry = init_carry
        self.plan = plan
        self._step = jax.jit(step)
        self._state = None
        self._carry = None
        self._width = None
        self._primed = False

    @property
    def state(self):
        """Index state as of the last completed back half."""
        return self._state

    def start(self, state: DexState) -> "EnginePipeline":
        """Begin a pipeline run from ``state``; resets any prior carry."""
        self._state = state
        self._carry = None
        self._primed = False
        return self

    def push(self, opcodes, keys, values) -> Optional[EngineResult]:
        if self._state is None:
            raise RuntimeError("EnginePipeline.push before start(state)")
        b = int(keys.shape[0])
        if b == 0:
            raise ValueError("pipeline batches must be non-empty")
        if self._carry is None:
            self._width = b
            self._carry = self.init_carry(b)
        elif b != self._width:
            raise ValueError(
                f"pipeline batches must share one width: {b} != {self._width}"
            )
        was_primed = self._primed
        self._state, self._carry, result = self._step(
            self._state, self._carry, opcodes, keys, values
        )
        self._primed = True
        # the result lanes of the very first step answer the all-inactive
        # prologue carry, not a caller batch
        return result if was_primed else None

    def drain(self) -> Optional[EngineResult]:
        """Flush the in-flight batch; afterwards the next push re-primes."""
        if self._state is None or not self._primed:
            return None
        b = self._width
        self._state, self._carry, result = self._step(
            self._state,
            self._carry,
            jnp.zeros((b,), jnp.int32),
            jnp.full((b,), KEY_MAX, jnp.int64),
            jnp.zeros((b,), jnp.int64),
        )
        self._carry = None
        self._primed = False
        return result

    def run(self, state: DexState, batches):
        """Convenience: stream ``batches`` (an iterable of ``(opcodes,
        keys, values)``) through a full prologue/steady-state/drain cycle;
        returns ``(state, [EngineResult per batch, in order])``."""
        self.start(state)
        results = []
        for opc, kk, vv in batches:
            r = self.push(opc, kk, vv)
            if r is not None:
                results.append(r)
        r = self.drain()
        if r is not None:
            results.append(r)
        return self._state, results


def make_dex_engine(
    meta: PoolMeta,
    cfg: DexMeshConfig,
    mesh,
    *,
    ops: Tuple[str, ...] = ALL_OPS,
    max_count: int = DEFAULT_MAX_COUNT,
    use_kernel: bool = True,
    interpret: "bool | None" = None,
    pipeline: bool = False,
    cache_policy: "fleet_cache.CachePolicy | None" = None,
):
    """Build the unified mixed-op program:
    ``(state, opcodes, keys, values) -> (state, EngineResult)``.

    ``opcodes``/``keys``/``values`` are [B] lanes globally sharded over all
    mesh axes; ``keys == KEY_MAX`` lanes are inactive no-ops regardless of
    opcode.  The ``values`` plane is overloaded per opcode: update/insert
    lanes carry the write payload, scan lanes carry their record count
    (clipped to ``max_count``), lookup lanes ignore it.  ``ops`` statically
    prunes machinery: opcodes outside the set are treated as inactive, and
    e.g. a ``("lookup",)`` engine contains no write round or scan hops —
    this is how the thin per-op wrappers stay as lean as the programs they
    replaced.  Wrap with ``jax.jit``.

    With ``pipeline=True`` the same front/back machinery is recomposed as
    one fused *pipeline step* — batch N+1's front half next to batch N's
    back half — and an :class:`EnginePipeline` driver is returned instead
    of the synchronous callable (see the module docstring for the overlap
    -window correctness argument).

    The returned function carries a ``plan`` attribute — the static
    collective structure ``{"route_rounds", "fused_pairs",
    "descent_levels", "scan_hops"}`` — which benchmarks print next to the
    traced collective counts (``routing.trace_collective_counts``).

    ``cache_policy`` selects the per-chip fleet-cache policy
    (:mod:`repro.core.fleet_cache`).  ``None`` or a
    :func:`fleet_cache.uniform_policy` compiles the verbatim pre-policy
    program — bit-identical outputs; a :func:`fleet_cache.divergent_policy`
    enables column-affinity/demand-biased admission and peer peeks
    (``MSG_PEEK`` riding the existing fused round: zero extra collectives).
    """
    for o in ops:
        if o not in ALL_OPS:
            raise ValueError(f"unknown op {o!r}; options: {ALL_OPS}")
    has_lookup = "lookup" in ops
    has_update = "update" in ops
    has_insert = "insert" in ops
    has_scan = "scan" in ops
    has_writes = has_update or has_insert
    # lanes that can offload (scans never do, §7)
    has_offloadable = has_lookup or has_writes
    # the pipelined overlap window resolves stale lookup/update lanes by
    # forcing them onto the two-sided tags, so those branches must be
    # compiled even under policy="fetch" whenever forcing can occur
    needs_force = bool(pipeline) and has_writes and (has_lookup or has_update)
    # policy="fetch" statically prunes every two-sided branch: no offload
    # tags, no owner-side block walk inside the fused round
    may_offload = has_offloadable and (cfg.policy != "fetch" or needs_force)
    # the one-sided descent is dead weight only when every offloadable lane
    # is forced two-sided and no scan lanes exist
    do_descent = has_scan or (cfg.policy != "offload") or not has_offloadable
    # the leaf level of the descent serves lookup/update answers and scan
    # hop 0; insert lanes stop above it
    do_leaf = has_lookup or has_update or has_scan
    # peer peeks (MSG_PEEK) only exist for descent lookup lanes under a
    # policy with peek budget — statically pruned otherwise
    may_peek = (
        has_lookup and do_descent and do_leaf
        and fleet_cache.peeks_enabled(cache_policy)
    )
    # leaf-direct route table (DESIGN.md §13): statically pruned when the
    # config reserves no slots, so the default program is the verbatim
    # descent-only one (bit-identical outputs AND collective counts)
    use_rt = cfg.route_table_slots > 0 and do_descent
    do_fused = has_writes or may_offload or may_peek
    levels = meta.levels_in_subtree
    hops = scan_hops(meta, max_count) if has_scan else 0
    mc = max_count
    if interpret is None:
        interpret = use_interpret()
    s_per = meta.n_subtrees_padded // cfg.n_memory
    # per-level node population of one column's subtrees: the fetch side of
    # the group cost model is capped by it (coalesced reads never exceed
    # the distinct nodes of a level)
    level_nodes = [
        float(s_per * min(meta.per_node**lvl, meta.leaves_per_subtree))
        for lvl in range(levels)
    ]
    # carry leaves crossing a pipeline step, in fixed order (all lane-plane
    # sharded): the front half's routed batch, descent answers and version
    # stamps, consumed by the matching back half one step later
    carry_keys = [
        "q", "val", "opc", "pr", "subtree", "offl", "gid", "found", "vleaf",
        "shed", "vseen", "lane", "dropr", "cost", "fmiss",
    ]
    if may_peek:
        carry_keys += ["peek"]
    if has_scan:
        carry_keys += ["sck", "scv", "taken", "hgid", "hver"]

    def _run_front(pool, cache, boundaries, miss_ema, stats, demand,
                   versions, succ, rtk, rth, rts, rtl, rtv,
                   opcodes, keys, values, *, stamp):
        """Front half: route round, top walk + per-group offload decision,
        version-checked cached descent and scan hops.  ``stamp=True``
        (pipeline mode) records the version of every leaf (and scan hop)
        the descent observed, for the back half's overlap-window check."""
        b = keys.shape[0]
        n_route = cfg.n_route
        vers = versions[0]
        succ_t = succ[0]
        n_nodes_total = vers.shape[0]

        # --- 1. ONE shared route round for every opcode --------------------
        dev = routing.device_linear_index(cfg, mesh)
        lane_prio = dev.astype(jnp.int64) * b + jnp.arange(b, dtype=jnp.int64)
        # phase-offset priority: all updates replay before all inserts, the
        # phased batch order the host-mirror validation uses
        phase = jnp.where(
            opcodes == OP_INSERT, jnp.int64(cfg.n_devices) * b, jnp.int64(0)
        )
        prio0 = lane_prio + phase
        owner, dem = routing.route_owners(boundaries, keys, n_route)
        new_demand = demand + dem
        cap = routing.route_capacity(b, n_route, cfg.route_capacity_factor)
        payload = jnp.stack(
            [keys, values, opcodes.astype(jnp.int64), prio0], axis=-1
        )                                                   # [B, 4]
        buf, lane, dropped_r = routing.pack_by_dest(payload, owner, n_route, cap)
        # inactive lanes share the OOB sentinel bucket; its overflow is
        # meaningless (see routing.route_owners)
        dropped_r = dropped_r & (keys != KEY_MAX)
        with jax.named_scope("dex/route"):
            routed = routing.route_exchange(buf, cfg, mesh)  # [n_route, cap, 4]
        q = routed[..., 0].reshape(-1)                      # [Q]
        val = routed[..., 1].reshape(-1)
        opc = routed[..., 2].reshape(-1).astype(jnp.int32)
        pr = routed[..., 3].reshape(-1)
        live = q != KEY_MAX
        is_scan = live & (opc == OP_SCAN) if has_scan else jnp.zeros(q.shape, bool)

        # --- 2. replicated top-tree walk + per-group offload decision ------
        subtree = top_walk(pool, meta, q)
        subtree = jnp.where(live, subtree, 0)
        col = (subtree // s_per).astype(jnp.int32)
        ema = miss_ema[0]                                   # [n_mem, levels]
        if has_offloadable and cfg.policy == "auto":
            # group = destination memory column; live counts are psum'd so
            # the decision is uniform across devices (and countable once)
            offable = live & ~is_scan
            n_live_c = (
                jnp.zeros((cfg.n_memory,), jnp.int64)
                .at[col].add(offable.astype(jnp.int64))
            )
            n_live_c = jax.lax.psum(n_live_c, cfg.all_axes)
            nf = n_live_c.astype(jnp.float32)
            caps = jnp.minimum(
                nf[:, None], jnp.asarray(level_nodes, jnp.float32)[None, :]
            )                                               # [n_mem, levels]
            fetch_cost = (
                jnp.sum(caps * ema, axis=-1) * NODE_ROW_BYTES * cfg.offload_c
            )
            rpc_cost = nf * float(OFFLOAD_REQ_BYTES + OFFLOAD_RESP_BYTES)
            want_off_c = fetch_cost > rpc_cost              # [n_mem] bool
            grp_live = n_live_c > 0
        elif has_offloadable and cfg.policy == "offload":
            offable = live & ~is_scan
            n_live_c = (
                jnp.zeros((cfg.n_memory,), jnp.int64)
                .at[col].add(offable.astype(jnp.int64))
            )
            n_live_c = jax.lax.psum(n_live_c, cfg.all_axes)
            want_off_c = jnp.ones((cfg.n_memory,), bool)
            grp_live = n_live_c > 0
        else:
            want_off_c = jnp.zeros((cfg.n_memory,), bool)
            grp_live = jnp.zeros((cfg.n_memory,), bool)
        offl = want_off_c[col] & live & ~is_scan if has_offloadable else (
            jnp.zeros(q.shape, bool)
        )
        n_off_groups = jnp.sum(want_off_c & grp_live).astype(jnp.int64)
        n_fetch_groups = jnp.sum(~want_off_c & grp_live).astype(jnp.int64)

        # --- leaf-direct route-table probe (DESIGN.md §13) -----------------
        # one searchsorted over the replicated trained table maps the key
        # straight to a predicted leaf; the fence-key bounds + version fence
        # accept or reject the guess BEFORE any descent level runs.  An
        # accepted lane skips every inner-level fetch round and probes the
        # predicted leaf directly (under the same version-checked cache
        # machinery); a rejected lane falls back to the full cached descent
        # — so a stale, partial or poisoned table costs mispredict counts,
        # never answers.  Scans keep their full descent (their window
        # machinery consumes the descent's leaf row anyway).
        acc = jnp.zeros(q.shape, bool)
        n_rt_skips = jnp.int64(0)
        n_rt_mis = jnp.int64(0)
        if use_rt:
            ridx, p_sub, p_loc = routing.rt_predict(rtk, rts, rtl, q)
            elig = live & ~is_scan & ~offl
            rt_guess, acc, _pred_gid = fleet_cache.rt_accept(
                meta, rtk, rth, rts, rtl, rtv, vers, ridx, subtree, q, elig,
            )
            n_rt_mis = jnp.sum(rt_guess & ~acc).astype(jnp.int64)
            # an accepted lane skips all inner levels within the subtree
            n_rt_skips = jnp.sum(acc).astype(jnp.int64) * (levels - 1)

        # --- per-lane cost ledger + offload cost-model audit ----------------
        # (obs/latency.py, DESIGN.md §12).  ``cost`` accumulates the modeled
        # seconds each lane spends — priced by the same constants the
        # simulator's op_clock uses — and is binned on-device in the back
        # half; ``fmiss`` remembers whether any level paid a remote fetch
        # (the remote_fetch path bit).  The replicated top walk prices like
        # the simulator's warm top-tree cache hits.
        cost = live.astype(jnp.float32) * (
            obs_latency.T_CACHED * float(meta.top_height)
        )
        fmiss = jnp.zeros(q.shape, bool)
        audit = has_offloadable and cfg.policy == "auto"
        a_upd = jnp.zeros((2, cfg.n_memory, levels), jnp.float32)
        if audit:
            # predicted fetch bytes per (column, level) under the EMA rule,
            # recorded for the columns the model actually priced onto the
            # fetch side; the decision is mesh-global (psum'd counts), so
            # device 0 records it once
            pred_cl = caps * ema * NODE_ROW_BYTES * cfg.offload_c
            fetch_dec = (grp_live & ~want_off_c).astype(jnp.float32)
            a_upd = a_upd.at[0].set(
                (dev == 0).astype(jnp.float32) * fetch_dec[:, None] * pred_cl
            )
            # realized bytes count *distinct* fetched nodes per (column,
            # level) — the mesh coalesces duplicate gids into one message —
            # via a node bitmap reduced along the node -> column map
            node_col = (
                (jnp.arange(n_nodes_total) // meta.subtree_cap) // s_per
            ).astype(jnp.int32)

        # --- 3. ONE shared version-checked cached descent ------------------
        fetchable = live & ~offl
        local = jnp.zeros(q.shape, jnp.int32)
        new_cache = cache
        n_fetch = jnp.int64(0)
        n_hit = jnp.int64(0)
        shed = jnp.zeros(q.shape, bool)
        found_leaf = jnp.zeros(q.shape, bool)
        vals_leaf = jnp.zeros(q.shape, jnp.int64)
        rows_k_leaf = jnp.full(q.shape + (FANOUT,), KEY_MAX, jnp.int64)
        rows_v_leaf = jnp.zeros(q.shape + (FANOUT,), jnp.int64)
        miss_cl = jnp.zeros((cfg.n_memory, levels), jnp.float32)
        want_cl = jnp.zeros((cfg.n_memory, levels), jnp.float32)
        peeked_leaf = jnp.zeros(q.shape, bool)
        # divergent policies scale the admission dice by the chip's share of
        # its own measured route demand (chip-local; no collective)
        dboost = fleet_cache.demand_boost(
            cache_policy, cfg, demand, routing.route_linear_index(cfg, mesh)
        )
        if do_descent:
            descent_levels = levels if do_leaf else levels - 1
            for lvl in range(descent_levels):
                leaf_lvl = lvl == levels - 1
                peek_elig = peek_budget = None
                if leaf_lvl:
                    if use_rt:
                        # accepted lanes land directly on the predicted leaf
                        local = jnp.where(acc, p_loc, local)
                    want = fetchable & (
                        (opc == OP_LOOKUP) | (opc == OP_UPDATE) | is_scan
                    )
                    p_ok = fleet_cache.leaf_admit(
                        meta, cfg, cache_policy,
                        meta.node_gid(subtree, local),
                        stats[0, STAT_OPS] + jnp.arange(q.shape[0]),
                        dev=dev, boost=dboost,
                    )
                    if may_peek:
                        # a leaf miss whose subtree another column owns may
                        # ask that column's cache instead of row-fetching
                        my_col = jax.lax.axis_index(cfg.memory_axis)
                        peek_elig = (
                            want & (opc == OP_LOOKUP) & (col != my_col)
                        )
                        peek_budget = fleet_cache.device_peek_budget(
                            cache_policy, dev
                        )
                else:
                    # route-table-accepted lanes skip the inner fetch rounds
                    want = fetchable & ~acc if use_rt else fetchable
                    p_ok = jnp.ones(q.shape, bool)
                gid = meta.node_gid(subtree, local)
                with jax.named_scope(f"dex/descent/l{lvl}"):
                    rows_k, rows_c, rows_v, hit, miss, f_drop, n_msgs, \
                        new_cache, peeked = cached_fetch_level(
                            pool, meta, cfg, new_cache, vers, gid, want, p_ok,
                            peek_elig, peek_budget,
                        )
                if leaf_lvl and may_peek:
                    peeked_leaf = peeked
                # ledger: a fresh cache hit prices one cached access, a
                # served miss one remote read; peeked lanes fetch nothing
                # here (their two-sided trip prices in the back half) and
                # bucket-overflowed lanes got no row
                fetched = miss & ~f_drop
                if leaf_lvl and may_peek:
                    fetched = fetched & ~peeked
                cost = cost + (
                    hit.astype(jnp.float32) * obs_latency.T_CACHED
                    + fetched.astype(jnp.float32) * obs_latency.T_READ
                )
                fmiss = fmiss | fetched
                if audit:
                    nset = jnp.zeros((n_nodes_total,), jnp.float32).at[
                        jnp.where(fetched & ~is_scan, gid, n_nodes_total)
                    ].set(1.0, mode="drop")
                    cnt_c = jnp.zeros((cfg.n_memory,), jnp.float32).at[
                        node_col
                    ].add(nset)
                    a_upd = a_upd.at[1, :, lvl].add(
                        cnt_c * float(NODE_ROW_BYTES)
                    )
                shed = shed | f_drop
                n_fetch = n_fetch + n_msgs
                n_hit = n_hit + jnp.sum(hit).astype(jnp.int64)
                # per-(column, level) miss observation; scan lanes leave the
                # EMA untouched (they never offload)
                obs = (want & ~is_scan).astype(jnp.float32)
                miss_cl = miss_cl.at[col, lvl].add(
                    miss.astype(jnp.float32) * obs
                )
                want_cl = want_cl.at[col, lvl].add(obs)
                if not leaf_lvl:
                    cnt = jnp.sum(rows_k <= q[:, None], axis=-1)
                    slot = jnp.maximum(cnt - 1, 0).astype(jnp.int32)
                    local = jnp.take_along_axis(
                        rows_c, slot[:, None], axis=-1
                    )[:, 0]
                else:
                    eq = rows_k == q[:, None]
                    found_leaf = jnp.any(eq, axis=-1) & want
                    vals_leaf = jnp.sum(jnp.where(eq, rows_v, 0), axis=-1)
                    rows_k_leaf, rows_v_leaf = rows_k, rows_v
        if use_rt and not do_leaf:
            # insert-only engines stop above the leaf; accepted lanes still
            # land their MSG_INSERT on the predicted leaf
            local = jnp.where(acc, p_loc, local)
        leaf_gid = meta.node_gid(subtree, local)

        # --- 4. scan lanes: successor-chain sibling hops -------------------
        hop_gids = []
        hop_vers = []
        if has_scan:
            cnt_s = jnp.clip(
                jnp.where(is_scan, val, 0), 0, mc
            ).astype(jnp.int32)
            window_k = [jnp.where(is_scan[:, None], rows_k_leaf, KEY_MAX)]
            window_v = [jnp.where(is_scan[:, None], rows_v_leaf, 0)]
            collected = jnp.sum(
                ((window_k[0] != KEY_MAX) & (window_k[0] >= q[:, None]))
                .astype(jnp.int32),
                axis=-1,
            )
            in_range = is_scan
            gid_h = leaf_gid
            for h in range(1, hops):
                nxt = succ_t[jnp.where(in_range, gid_h, 0)]
                in_range = in_range & (collected < cnt_s) & (nxt >= 0)
                gid_h = jnp.where(in_range, nxt, gid_h)
                gid = jnp.where(in_range, gid_h, 0)
                if stamp:
                    hop_gids.append(
                        jnp.where(in_range, gid_h, -1).astype(jnp.int64)
                    )
                    hop_vers.append(jnp.where(in_range, vers[gid], 0))
                p_ok = fleet_cache.leaf_admit(
                    meta, cfg, cache_policy, gid,
                    stats[0, STAT_OPS] + h + jnp.arange(q.shape[0]),
                    dev=dev, boost=dboost,
                )
                with jax.named_scope(f"dex/scan/h{h}"):
                    rows_k, _rows_c, rows_v, hit, miss, f_drop, n_msgs, \
                        new_cache, _peeked = cached_fetch_level(
                            pool, meta, cfg, new_cache, vers, gid, in_range,
                            p_ok,
                        )
                shed = shed | f_drop
                n_fetch = n_fetch + n_msgs
                n_hit = n_hit + jnp.sum(hit).astype(jnp.int64)
                # ledger: each executed hop prices like one more leaf level
                # plus the per-hop local search the simulator books
                fetched_h = miss & ~f_drop
                cost = cost + (
                    hit.astype(jnp.float32) * obs_latency.T_CACHED
                    + fetched_h.astype(jnp.float32) * obs_latency.T_READ
                    + in_range.astype(jnp.float32) * obs_latency.T_LOCAL
                )
                fmiss = fmiss | fetched_h
                rows_k = jnp.where(in_range[:, None], rows_k, KEY_MAX)
                rows_v = jnp.where(in_range[:, None], rows_v, 0)
                collected = collected + jnp.sum(
                    ((rows_k != KEY_MAX) & (rows_k >= q[:, None]))
                    .astype(jnp.int32),
                    axis=-1,
                )
                window_k.append(rows_k)
                window_v.append(rows_v)
            wk = jnp.concatenate(window_k, axis=-1)
            wv = jnp.concatenate(window_v, axis=-1)
            if use_kernel:
                sc_k, sc_v, taken = leaf_scan(
                    wk, wv, q, cnt_s, max_count=mc, interpret=interpret
                )
            else:
                sc_k, sc_v, taken = leaf_scan_ref(wk, wv, q, cnt_s, max_count=mc)
            ok_scan = is_scan & ~shed
            sc_k = jnp.where(ok_scan[:, None], sc_k, KEY_MAX)
            sc_v = jnp.where(ok_scan[:, None], sc_v, 0)
            taken = jnp.where(
                ok_scan, taken, jnp.where(is_scan & shed, -1, 0)
            ).astype(jnp.int32)

        # ledger: compute-side leaf search — lookups that stayed one-sided,
        # plus a scan's first (descent) hop
        if has_lookup:
            cost = cost + (
                live & (opc == OP_LOOKUP) & ~offl
            ).astype(jnp.float32) * obs_latency.T_LOCAL
        if has_scan:
            cost = cost + is_scan.astype(jnp.float32) * obs_latency.T_LOCAL

        # --- front-half EMA + stats ----------------------------------------
        g_miss = jax.lax.psum(miss_cl, cfg.all_axes)
        g_want = jax.lax.psum(want_cl, cfg.all_axes)
        rates = g_miss / jnp.maximum(g_want, 1.0)
        new_ema = jnp.where(
            g_want[None, :, :] > 0,
            cfg.ema_decay * miss_ema + (1 - cfg.ema_decay) * rates[None, :, :],
            miss_ema,
        )
        f_upd = jnp.zeros((1, N_STATS), jnp.int64)
        f_upd = f_upd.at[0, STAT_OPS].set(jnp.sum(live).astype(jnp.int64))
        f_upd = f_upd.at[0, STAT_HITS].set(n_hit)
        f_upd = f_upd.at[0, STAT_FETCHES].set(n_fetch)
        f_upd = f_upd.at[0, STAT_DROPS].set(
            jnp.sum(dropped_r).astype(jnp.int64)
        )
        if has_offloadable:
            # group decisions are mesh-global: count them once, on the
            # first device
            first = (dev == 0).astype(jnp.int64)
            f_upd = f_upd.at[0, STAT_OFFLOAD_GROUPS].set(first * n_off_groups)
            f_upd = f_upd.at[0, STAT_FETCH_GROUPS].set(first * n_fetch_groups)
        if use_rt:
            f_upd = f_upd.at[0, STAT_RT_SKIPS].set(n_rt_skips)
            f_upd = f_upd.at[0, STAT_RT_MISPREDICTS].set(n_rt_mis)

        carry = {
            "q": q, "val": val, "opc": opc, "pr": pr, "subtree": subtree,
            "offl": offl, "gid": leaf_gid, "found": found_leaf,
            "vleaf": vals_leaf, "shed": shed, "lane": lane,
            "dropr": dropped_r, "cost": cost, "fmiss": fmiss,
        }
        if may_peek:
            carry["peek"] = peeked_leaf
        if stamp:
            gsafe = jnp.clip(leaf_gid, 0, n_nodes_total - 1)
            carry["vseen"] = jnp.where(live, vers[gsafe], 0)
        if has_scan:
            carry.update(sck=sc_k, scv=sc_v, taken=taken)
            if stamp:
                if hop_gids:
                    carry["hgid"] = jnp.stack(hop_gids, axis=-1)
                    carry["hver"] = jnp.stack(hop_vers, axis=-1)
                else:
                    carry["hgid"] = jnp.full(q.shape + (0,), -1, jnp.int64)
                    carry["hver"] = jnp.zeros(q.shape + (0,), vers.dtype)
        return carry, new_cache, new_ema, new_demand, f_upd, a_upd

    def _run_back(pool, occupancy, cache, versions, carry, b, *, check_stale):
        """Back half: overlap-window stale check (pipeline mode), the fused
        tagged request/response all_to_all pair, the conflict-resolved leaf
        apply, version bumps + cache write-through, and the reverse route
        exchange returning per-lane results."""
        n_route = cfg.n_route
        vers = versions[0]
        n_nodes_total = vers.shape[0]
        q = carry["q"]
        val = carry["val"]
        opc = carry["opc"]
        pr = carry["pr"]
        subtree = carry["subtree"]
        offl = carry["offl"]
        leaf_gid = carry["gid"]
        found_leaf = carry["found"]
        vals_leaf = carry["vleaf"]
        shed = carry["shed"]
        lane = carry["lane"]
        dropped_r = carry["dropr"]
        cost = carry["cost"]
        fmiss = carry["fmiss"]
        peek_c = carry["peek"] if may_peek else None
        cap = lane.shape[1]
        live = q != KEY_MAX
        is_scan = live & (opc == OP_SCAN) if has_scan else jnp.zeros(q.shape, bool)
        col = (subtree // s_per).astype(jnp.int32)
        if has_scan:
            sc_k, sc_v, taken = carry["sck"], carry["scv"], carry["taken"]

        # --- overlap-window stale check (pipeline back half only) ----------
        n_stalls = jnp.int64(0)
        stalled = jnp.zeros(q.shape, bool)
        if check_stale:
            gsafe = jnp.clip(leaf_gid, 0, n_nodes_total - 1)
            stale = live & (vers[gsafe] != carry["vseen"])
            # lookups/updates whose leaf the overlapped batch wrote re-run
            # two-sided against the authoritative post-overlap pool; inserts
            # never need forcing (the apply re-searches the leaf); already
            # -offloaded lanes are authoritative as-is
            force_off = (
                stale & ~offl & ~shed & ~is_scan
                & ((opc == OP_LOOKUP) | (opc == OP_UPDATE))
            ) if (has_lookup or has_update) else jnp.zeros(q.shape, bool)
            n_stalls = n_stalls + jnp.sum(force_off).astype(jnp.int64)
            if has_scan:
                # conservative conflict stall: a scan whose window crossed
                # any written leaf sheds to the retry lane
                hg, hv = carry["hgid"], carry["hver"]
                hvalid = hg >= 0
                hsafe = jnp.clip(hg, 0, n_nodes_total - 1)
                hstale = jnp.any(hvalid & (vers[hsafe] != hv), axis=-1)
                sc_stale = is_scan & ~shed & (stale | hstale)
                n_stalls = n_stalls + jnp.sum(sc_stale).astype(jnp.int64)
                sc_k = jnp.where(sc_stale[:, None], KEY_MAX, sc_k)
                sc_v = jnp.where(sc_stale[:, None], 0, sc_v)
                taken = jnp.where(sc_stale, -1, taken).astype(jnp.int32)
                shed = shed | sc_stale
                stalled = stalled | sc_stale
            stalled = stalled | force_off
            with (
                jax.named_scope("dex/lat/stale_forced"),
                routing.trace_phase("dex/lat"),
            ):
                # a stale-caught lane re-resolves two-sided at the leaf: the
                # simulator's stall site prices one RPC plus a single-level
                # memory-side walk (``_offload(server, leaf, 1)``)
                cost = cost + stalled.astype(jnp.float32) * (
                    obs_latency.T_RPC + obs_latency.T_MEM
                )
            offl_eff = offl | force_off
        else:
            offl_eff = offl

        # --- 5. ONE fused tagged request/response all_to_all pair ----------
        rstat = jnp.zeros(q.shape, jnp.int32)
        rval = jnp.zeros(q.shape, jnp.int64)
        rgid = jnp.full(q.shape, KEY_MAX, jnp.int64)
        rrow_v = jnp.zeros(q.shape + (FANOUT,), jnp.int64)
        send = jnp.zeros(q.shape, bool)
        dropped_w = jnp.zeros(q.shape, bool)
        sent_peek = jnp.zeros(q.shape, bool)
        n_off_msgs = jnp.int64(0)
        n_write_msgs = jnp.int64(0)
        n_peer_hits = jnp.int64(0)
        n_peer_misses = jnp.int64(0)
        new_pk, new_pv, new_occ = (
            pool.pool_keys, pool.pool_values, occupancy
        )
        new_cache = cache
        if do_fused:
            tag = jnp.zeros(q.shape, jnp.int64)
            ok_lane = live & ~shed
            if has_lookup and may_offload:
                tag = jnp.where(
                    ok_lane & (opc == OP_LOOKUP) & offl_eff, MSG_OFF_LOOKUP,
                    tag,
                )
            if may_peek:
                # a peeked leaf miss resolves two-sided at the owning column
                # (a stale-forced lane keeps its MSG_OFF_LOOKUP instead)
                tag = jnp.where(
                    ok_lane & (opc == OP_LOOKUP) & peek_c & ~offl_eff,
                    MSG_PEEK, tag,
                )
            if has_update:
                if may_offload:
                    tag = jnp.where(
                        ok_lane & (opc == OP_UPDATE) & offl_eff,
                        MSG_OFF_UPDATE, tag,
                    )
                tag = jnp.where(
                    ok_lane & (opc == OP_UPDATE) & ~offl_eff & found_leaf,
                    MSG_UPDATE, tag,
                )
            if has_insert:
                if may_offload:
                    tag = jnp.where(
                        ok_lane & (opc == OP_INSERT) & offl_eff,
                        MSG_OFF_INSERT, tag,
                    )
                tag = jnp.where(
                    ok_lane & (opc == OP_INSERT) & ~offl_eff, MSG_INSERT, tag
                )
            if may_peek:
                sent_peek = tag == MSG_PEEK
            send = tag != MSG_NONE
            dest = jnp.where(send, col, cfg.n_memory)
            wcap = routing.route_capacity(
                q.shape[0], cfg.n_memory, cfg.route_capacity_factor
            )
            wpayload = jnp.stack(
                [
                    tag,
                    jnp.where(
                        (tag == MSG_UPDATE) | (tag == MSG_INSERT)
                        | (tag == MSG_PEEK),
                        leaf_gid, KEY_MAX,
                    ),
                    subtree.astype(jnp.int64),
                    q,
                    val,
                    pr,
                ],
                axis=-1,
            )                                               # [Q, REQ_FIELDS]
            wbuf, wlane, dropped_w = routing.pack_by_dest(
                wpayload, dest, cfg.n_memory, wcap
            )
            dropped_w = dropped_w & send
            with jax.named_scope("dex/fused_a2a/request"):
                req = routing.a2a(wbuf, cfg.memory_axis)  # [n_mem, wcap, RF]
            if has_writes:
                # every route-replica of this memory column must apply the
                # identical write batch (pool replicas stay consistent)
                req = routing.gather_route(req, cfg)     # [R, n_mem, wcap, RF]
            flat = req.reshape(-1, REQ_FIELDS)
            tagf = flat[:, 0]
            gidf = flat[:, 1]
            stf = flat[:, 2]
            kf = flat[:, 3]
            vf = flat[:, 4]
            prf = flat[:, 5]
            wgid = jnp.where(
                (tagf == MSG_UPDATE) | (tagf == MSG_INSERT), gidf, KEY_MAX
            )
            resp_val = jnp.zeros(kf.shape, jnp.int64)
            o_found = jnp.zeros(kf.shape, bool)
            peekf = jnp.zeros(kf.shape, bool)
            if may_offload or may_peek:
                offf = (
                    (tagf >= MSG_OFF_LOOKUP) & (tagf <= MSG_OFF_INSERT)
                    if may_offload else jnp.zeros(kf.shape, bool)
                )
                if may_peek:
                    peekf = tagf == MSG_PEEK
                walkf = offf | peekf
                # owner-side block walk for offloaded (and peer-missed
                # peeked) lanes (§6): the whole remaining traversal runs
                # next to the data
                stl = jnp.where(walkf, stf % s_per, 0).astype(jnp.int32)
                loc = jnp.zeros(kf.shape, jnp.int32)
                for _ in range(levels - 1):
                    rows = pool.pool_keys[stl, loc]
                    cnt = jnp.sum(rows <= kf[:, None], axis=-1)
                    slot = jnp.maximum(cnt - 1, 0).astype(jnp.int32)
                    loc = jnp.take_along_axis(
                        pool.pool_children[stl, loc], slot[:, None], axis=-1
                    )[:, 0]
                o_rows_k = pool.pool_keys[stl, loc]
                o_eq = o_rows_k == kf[:, None]
                o_found = jnp.any(o_eq, axis=-1) & walkf
                o_val = jnp.sum(
                    jnp.where(o_eq, pool.pool_values[stl, loc], 0), axis=-1
                )
                if may_offload:
                    gid_eff = meta.node_gid(stf, loc.astype(jnp.int64))
                    wgid = jnp.where(
                        (tagf == MSG_OFF_UPDATE) | (tagf == MSG_OFF_INSERT),
                        gid_eff, wgid,
                    )
                peer_hit = jnp.zeros(kf.shape, bool)
                if may_peek:
                    # sibling-cache overlay: if this chip's own cache holds a
                    # version-fresh copy of the peeked leaf, answer from it —
                    # a stale or absent row falls back to the walk above
                    peer_hit, p_found, p_val = fleet_cache.peer_answer(
                        cache, cfg, vers, gidf, kf, peekf
                    )
                    o_found = jnp.where(peer_hit, p_found, o_found)
                    o_val = jnp.where(peer_hit, p_val, o_val)
                lk_tags = (
                    (tagf == MSG_OFF_LOOKUP) | peekf
                    if may_offload else peekf
                )
                resp_val = jnp.where(lk_tags, o_val, 0)
            if has_writes:
                allow_ins = tagf == MSG_INSERT
                if may_offload:
                    allow_ins = allow_ins | (tagf == MSG_OFF_INSERT)
                with jax.named_scope("dex/apply"):
                    (new_pk, new_pv, new_occ, wstat, rows_v_all,
                     ins_in_leaf) = _apply_leaf_writes(
                        pool.pool_keys, pool.pool_values, occupancy, meta,
                        cfg, wgid, kf, vf, prf, allow_ins,
                        use_kernel=use_kernel, interpret=interpret,
                    )
            else:
                wstat = jnp.zeros(kf.shape, jnp.int32)
                rows_v_all = jnp.zeros(kf.shape + (FANOUT,), jnp.int64)
                ins_in_leaf = jnp.zeros(kf.shape, bool)
            if may_offload or may_peek:
                wstat = jnp.where(
                    lk_tags,
                    jnp.where(o_found, STATUS_OK, STATUS_MISS),
                    wstat,
                )
            # field 3 doubles as the peer-cache-hit bit for MSG_PEEK lanes
            # (they are lookups, so the insert-path consumers never read it)
            ins_flag = (
                jnp.where(peekf, peer_hit, ins_in_leaf)
                if may_peek else ins_in_leaf
            )
            resp = jnp.concatenate(
                [
                    wstat[:, None].astype(jnp.int64),
                    resp_val[:, None],
                    wgid[:, None],
                    ins_flag[:, None].astype(jnp.int64),
                    rows_v_all,
                ],
                axis=-1,
            )
            if has_writes:
                # respond only to this device's own route row
                r_lin = routing.route_linear_index(cfg, mesh)
                resp = jnp.take(
                    resp.reshape(
                        cfg.n_route, cfg.n_memory, wcap, RESP_HEAD + FANOUT
                    ),
                    r_lin, axis=0,
                )
            else:
                resp = resp.reshape(cfg.n_memory, wcap, RESP_HEAD + FANOUT)
            with jax.named_scope("dex/fused_a2a/response"):
                resp = routing.a2a(resp, cfg.memory_axis)
            back = routing.unpack_to_lanes(resp, wlane, q.shape[0], 0)
            rstat = back[..., 0].astype(jnp.int32)
            rval = back[..., 1]
            rgid = back[..., 2]
            r_ins = back[..., 3] != 0
            rrow_v = back[..., RESP_HEAD:]
            delivered = send & ~dropped_w
            is_off_lane = offl_eff & send
            n_off_msgs = jnp.sum(delivered & is_off_lane).astype(jnp.int64)
            n_write_msgs = jnp.sum(
                delivered & ~is_off_lane & (opc != OP_LOOKUP)
            ).astype(jnp.int64)
            if may_peek:
                n_peer_hits = jnp.sum(
                    delivered & sent_peek & r_ins
                ).astype(jnp.int64)
                n_peer_misses = jnp.sum(
                    delivered & sent_peek & ~r_ins
                ).astype(jnp.int64)

        # --- 6. write-through-and-invalidate + version bump ----------------
        new_versions = versions
        if has_writes:
            delivered = send & ~dropped_w
            wrote_ok = (
                delivered
                & ((opc == OP_UPDATE) | (opc == OP_INSERT))
                & (rstat == STATUS_OK)
            )
            gsafe0 = jnp.where(wrote_ok, rgid, 0)
            nv = vers[gsafe0] + 1
            gsafe = jnp.where(wrote_ok, rgid, n_nodes_total)
            vers2 = vers.at[gsafe].max(nv, mode="drop")
            new_versions = jax.lax.pmax(vers2[None, :], cfg.all_axes)
            set_idx = (
                routing.hash64(rgid) % jnp.uint64(cfg.cache_sets)
            ).astype(jnp.int32)
            eqt = new_cache.tags[0, set_idx] == rgid[:, None]
            chit = jnp.any(eqt, axis=-1) & wrote_ok
            way = jnp.argmax(eqt, axis=-1).astype(jnp.int32)
            if has_update:
                # refresh the chip's own cached row with the authoritative
                # post-batch values, stamped with the bumped version — but
                # NOT when the leaf also took same-batch inserts (possibly
                # from another chip): the cached keys plane would be stale
                # under a current version stamp; leaving the old stamp makes
                # the version check refetch the whole row instead
                u_hit = chit & (opc == OP_UPDATE) & ~r_ins
                if check_stale:
                    # a stale-forced update resolved two-sided against a
                    # leaf the overlapped batch moved: the chip's cached
                    # keys plane is one batch behind the response's value
                    # row, so an in-place refresh would stitch a misaligned
                    # pair under a current version stamp.  Leave the old
                    # stamp; the bumped version forces a clean refetch.
                    u_hit = u_hit & ~force_off
                sidx = jnp.where(u_hit, set_idx, cfg.cache_sets)
                cvals = new_cache.values.at[0, sidx, way].set(
                    rrow_v, mode="drop"
                )
                cver = new_cache.ver.at[0, sidx, way].set(
                    jnp.where(u_hit, nv, 0), mode="drop"
                )
                new_cache = new_cache._replace(values=cvals, ver=cver)
            if has_insert:
                # drop the chip's own (now key-shifted) cached row
                i_hit = chit & (opc == OP_INSERT)
                sidx = jnp.where(i_hit, set_idx, cfg.cache_sets)
                ctags = new_cache.tags.at[0, sidx, way].set(-1, mode="drop")
                new_cache = new_cache._replace(tags=ctags)

        # --- 7. per-lane results + statuses --------------------------------
        out_found = jnp.zeros(q.shape, bool)
        out_val = jnp.zeros(q.shape, jnp.int64)
        if has_lookup:
            is_lk = live & (opc == OP_LOOKUP)
            # a lane resolved two-sided (offloaded, stale-forced, or peeked)
            # takes the owning column's answer; the rest keep the local
            # cached-descent result
            two_sided = (offl_eff | sent_peek) if may_peek else offl_eff
            out_found = jnp.where(
                two_sided,
                (rstat == STATUS_OK) & send & ~dropped_w,
                found_leaf & ~shed,
            ) & is_lk
            out_val = jnp.where(
                out_found, jnp.where(two_sided, rval, vals_leaf), 0
            )
        status = jnp.full(q.shape, STATUS_MISS, jnp.int32)
        if has_writes:
            is_w = live & ((opc == OP_UPDATE) | (opc == OP_INSERT))
            shed_w = is_w & (shed | dropped_w)
            status = jnp.where(
                is_w & send & ~dropped_w & ~shed,
                rstat,
                jnp.where(shed_w, STATUS_SHED, STATUS_MISS),
            )
        lane_shed = shed | (send & dropped_w)

        # --- 7b. per-lane back-half pricing + latency histogram ------------
        # (obs/latency.py).  Two-sided trips price the simulator's offload
        # rule (one RPC + the owner's per-level memory-side walk); peer
        # peeks the sibling's cached access (hit) or a one-level owner walk
        # (miss); fetched-path writes one write-through WRITE — suppressed
        # in pipelined mode, where the write rides the overlapped fused
        # round off the critical path (the simulator's pipeline_overlap
        # rule).  Each live routed lane then bins into exactly one
        # (op class, outcome path, bucket) cell — a pure per-device
        # scatter, so the plane adds zero collectives.
        delivered_l = send & ~dropped_w
        is_off = offl_eff & send
        with jax.named_scope("dex/lat/offload"), routing.trace_phase("dex/lat"):
            off_norm = delivered_l & is_off & ~stalled
            cost = cost + off_norm.astype(jnp.float32) * (
                obs_latency.T_RPC + float(levels) * obs_latency.T_MEM
            )
        if may_peek:
            with jax.named_scope("dex/lat/peer_peek"), routing.trace_phase("dex/lat"):
                pk = delivered_l & sent_peek
                cost = cost + pk.astype(jnp.float32) * (
                    obs_latency.T_RPC + jnp.where(
                        r_ins, obs_latency.T_CACHED, obs_latency.T_MEM
                    )
                )
        if has_writes and not check_stale:
            with (
                jax.named_scope("dex/lat/write_through"),
                routing.trace_phase("dex/lat"),
            ):
                wl = delivered_l & ~is_off & (
                    (opc == OP_UPDATE) | (opc == OP_INSERT)
                )
                cost = cost + wl.astype(jnp.float32) * obs_latency.T_WRITE
        with jax.named_scope("dex/lat/bin"), routing.trace_phase("dex/lat"):
            path = jnp.zeros(q.shape, jnp.int32)             # cache_hit
            path = jnp.where(fmiss, 1, path)                 # remote_fetch
            if may_peek:
                path = jnp.where(delivered_l & sent_peek, 2, path)
            path = jnp.where(delivered_l & is_off & ~stalled, 3, path)
            path = jnp.where(lane_shed, 5, path)             # shed
            if check_stale:
                path = jnp.where(stalled, 4, path)           # stale_forced
            cls = jnp.clip(opc, 0, obs_latency.N_CLASSES - 1)
            bkt = obs_latency.bucket_index(cost, xp=jnp)
            h_upd = jnp.zeros(
                (obs_latency.N_CLASSES, obs_latency.N_PATHS,
                 obs_latency.N_BUCKETS),
                jnp.int64,
            ).at[cls, path, bkt].add(live.astype(jnp.int64))

        # --- 8. back-half stats --------------------------------------------
        n_shed = jnp.sum(lane_shed & live).astype(jnp.int64)
        b_upd = jnp.zeros((1, N_STATS), jnp.int64)
        b_upd = b_upd.at[0, STAT_OFFLOADS].set(n_off_msgs)
        b_upd = b_upd.at[0, STAT_WRITES].set(n_write_msgs)
        b_upd = b_upd.at[0, STAT_DROPS].set(n_shed)
        b_upd = b_upd.at[0, STAT_SPLITS].set(
            jnp.sum(status == STATUS_SPLIT).astype(jnp.int64)
        )
        b_upd = b_upd.at[0, STAT_PIPE_STALLS].set(n_stalls)
        b_upd = b_upd.at[0, STAT_PEER_HITS].set(n_peer_hits)
        b_upd = b_upd.at[0, STAT_PEER_MISSES].set(n_peer_misses)

        # --- 9. results back to the requesting lanes ------------------------
        fields = [
            out_found.astype(jnp.int64)[:, None],
            out_val[:, None],
            status.astype(jnp.int64)[:, None],
            lane_shed.astype(jnp.int64)[:, None],
        ]
        if has_scan:
            fields += [taken.astype(jnp.int64)[:, None], sc_k, sc_v]
        resp_b = jnp.concatenate(fields, axis=-1)
        width = resp_b.shape[-1]
        resp_b = resp_b.reshape(n_route, cap, width)
        with jax.named_scope("dex/route_back"):
            back_b = routing.route_exchange(resp_b, cfg, mesh, reverse=True)
        out = routing.unpack_to_lanes(back_b, lane, b, 0)
        res_found = (out[..., 0] != 0) & ~dropped_r
        res_val = jnp.where(dropped_r, 0, out[..., 1])
        res_status = jnp.where(
            dropped_r, STATUS_SHED, out[..., 2].astype(jnp.int32)
        )
        if not has_writes:
            res_status = jnp.where(
                dropped_r & (q.shape[0] > 0), STATUS_SHED, STATUS_MISS
            ).astype(jnp.int32)
        res_shed = (out[..., 3] != 0) | dropped_r
        lane_out = [res_found, res_val, res_status, res_shed]
        if has_scan:
            res_taken = jnp.where(
                dropped_r, -1, out[..., 4]
            ).astype(jnp.int32)
            res_k = jnp.where(
                dropped_r[:, None], KEY_MAX, out[..., 5 : 5 + mc]
            )
            res_v = jnp.where(
                dropped_r[:, None], 0, out[..., 5 + mc : 5 + 2 * mc]
            )
            lane_out += [res_k, res_v, res_taken]
        return (new_pk, new_pv, new_occ, new_versions, new_cache, b_upd,
                h_upd, lane_out)

    def local_fn(pool, occupancy, cache, boundaries, miss_ema, stats, demand,
                 versions, succ, lat_hist, lat_audit, rtk, rth, rts, rtl,
                 rtv, opcodes, keys, values):
        b = keys.shape[0]
        carry, new_cache, new_ema, new_demand, f_upd, a_upd = _run_front(
            pool, cache, boundaries, miss_ema, stats, demand, versions, succ,
            rtk, rth, rts, rtl, rtv, opcodes, keys, values, stamp=False,
        )
        (new_pk, new_pv, new_occ, new_versions, new_cache, b_upd, h_upd,
         lane_out) = _run_back(
            pool, occupancy, new_cache, versions, carry, b, check_stale=False,
        )
        new_stats = stats + f_upd + b_upd
        new_hist = lat_hist + h_upd[None]
        new_audit = lat_audit + a_upd[None]
        outs = [new_cache, new_ema, new_stats, new_demand, new_hist,
                new_audit] + lane_out
        if has_writes:
            outs = [new_pk, new_pv, new_occ, new_versions] + outs
        return tuple(outs)

    def local_pipe(pool, occupancy, cache, boundaries, miss_ema, stats,
                   demand, versions, succ, lat_hist, lat_audit, rtk, rth,
                   rts, rtl, rtv, carry_in, opcodes, keys, values):
        # one pipeline step: the NEW batch's front half next to the CARRIED
        # batch's back half.  The back half probes the cache as returned by
        # this step's front (an elementwise composition — the two halves
        # share no collective data dependency, so XLA is free to overlap
        # the back half's all_to_all with the front half's fetch rounds).
        b = keys.shape[0]
        with jax.named_scope("pipe/front"), routing.trace_phase("pipe/front"):
            carry_out, cache_f, new_ema, new_demand, f_upd, a_upd = _run_front(
                pool, cache, boundaries, miss_ema, stats, demand, versions,
                succ, rtk, rth, rts, rtl, rtv, opcodes, keys, values,
                stamp=True,
            )
        carried = dict(zip(carry_keys, carry_in))
        with jax.named_scope("pipe/back"), routing.trace_phase("pipe/back"):
            (new_pk, new_pv, new_occ, new_versions, new_cache, b_upd, h_upd,
             lane_out) = _run_back(
                pool, occupancy, cache_f, versions, carried, b,
                check_stale=True,
            )
        new_stats = stats + f_upd + b_upd
        # the histogram lags STAT_OPS by one batch here (a lane bins when
        # its back half lands); the drain step closes the gap exactly
        new_hist = lat_hist + h_upd[None]
        new_audit = lat_audit + a_upd[None]
        outs = [new_cache, new_ema, new_stats, new_demand, new_hist,
                new_audit]
        outs += [carry_out[k] for k in carry_keys]
        outs += lane_out
        if has_writes:
            outs = [new_pk, new_pv, new_occ, new_versions] + outs
        return tuple(outs)

    dev_spec = P(cfg.all_axes)
    pool_specs = SubtreePool(
        top_keys=P(),
        top_children=P(),
        pool_keys=P(cfg.memory_axis),
        pool_children=P(cfg.memory_axis),
        pool_values=P(cfg.memory_axis),
    )
    cache_specs = DexCache(
        tags=dev_spec, keys=dev_spec, children=dev_spec, values=dev_spec,
        fifo=dev_spec, ver=dev_spec,
    )
    mem = P(cfg.memory_axis)
    lanes = P(cfg.all_axes)

    plan = {
        "route_rounds": 1,
        "fused_pairs": 1 if do_fused else 0,
        "descent_levels": (levels if do_leaf else levels - 1)
        if do_descent else 0,
        "scan_hops": hops,
        "pipeline": bool(pipeline),
        # jax.named_scope labels annotating the jitted program for profiler
        # traces (repro/obs/trace.py profiler_annotations); metadata only —
        # they add no ops and no collectives
        "phases": ("dex/route", "dex/descent", "dex/scan", "dex/fused_a2a",
                   "dex/apply", "dex/lat", "dex/route_back"),
    }

    if not pipeline:
        sharded = routing.shard_map_compat(
            local_fn,
            mesh=mesh,
            in_specs=(pool_specs, mem, cache_specs, P(), dev_spec, dev_spec,
                      dev_spec, dev_spec, dev_spec, dev_spec, dev_spec,
                      P(), P(), P(), P(), P(),
                      lanes, lanes, lanes),
            out_specs=tuple(
                ([mem, mem, mem, dev_spec] if has_writes else [])
                + [cache_specs, dev_spec, dev_spec, dev_spec, dev_spec,
                   dev_spec, lanes, lanes, lanes, lanes]
                + ([lanes, lanes, lanes] if has_scan else [])
            ),
        )

        enabled_codes = [
            code for flag, code in [
                (has_lookup, OP_LOOKUP), (has_update, OP_UPDATE),
                (has_insert, OP_INSERT), (has_scan, OP_SCAN),
            ] if flag
        ]

        def engine(state: DexState, opcodes: jax.Array, keys: jax.Array,
                   values: jax.Array):
            if keys.shape[0] == 0:
                return state, _empty_result(0, mc, has_scan)
            opcodes = opcodes.astype(jnp.int32)
            keys = keys.astype(jnp.int64)
            # opcodes outside the static ``ops`` set are true no-ops: their
            # keys are masked before routing, so they consume no bucket
            # capacity, mint no demand/stats and return inactive results
            allowed = jnp.zeros(opcodes.shape, bool)
            for code in enabled_codes:
                allowed = allowed | (opcodes == code)
            keys = jnp.where(allowed, keys, KEY_MAX)
            res = sharded(
                state.pool, state.occupancy, state.cache, state.boundaries,
                state.miss_ema, state.stats, state.route_demand,
                state.versions, state.succ, state.lat_hist, state.lat_audit,
                state.rt_keys, state.rt_hi, state.rt_sub, state.rt_local,
                state.rt_ver, opcodes, keys, values.astype(jnp.int64),
            )
            res = list(res)
            new_state = state
            if has_writes:
                new_pk, new_pv, new_occ, new_versions = res[:4]
                res = res[4:]
                new_state = new_state._replace(
                    pool=state.pool._replace(
                        pool_keys=new_pk, pool_values=new_pv
                    ),
                    occupancy=new_occ,
                    versions=new_versions,
                )
            new_cache, new_ema, new_stats, new_demand, new_hist, new_audit = (
                res[:6]
            )
            found, vals, status, shed = res[6:10]
            new_state = new_state._replace(
                cache=new_cache, miss_ema=new_ema, stats=new_stats,
                route_demand=new_demand, lat_hist=new_hist,
                lat_audit=new_audit,
            )
            result = EngineResult(found=found, values=vals, status=status,
                                  shed=shed)
            if has_scan:
                sk, sv, tk = res[10:13]
                result = result._replace(
                    scan_keys=sk, scan_values=sv, taken=tk
                )
            return new_state, result

        engine.plan = plan
        return engine

    # ---- pipeline=True: the fused two-stage step + host-side driver -------
    carry_specs = tuple(lanes for _ in carry_keys)
    sharded_pipe = routing.shard_map_compat(
        local_pipe,
        mesh=mesh,
        in_specs=(pool_specs, mem, cache_specs, P(), dev_spec, dev_spec,
                  dev_spec, dev_spec, dev_spec, dev_spec, dev_spec,
                  P(), P(), P(), P(), P(),
                  carry_specs, lanes, lanes, lanes),
        out_specs=tuple(
            ([mem, mem, mem, dev_spec] if has_writes else [])
            + [cache_specs, dev_spec, dev_spec, dev_spec, dev_spec, dev_spec]
            + list(carry_specs)
            + [lanes, lanes, lanes, lanes]
            + ([lanes, lanes, lanes] if has_scan else [])
        ),
    )

    enabled_codes = [
        code for flag, code in [
            (has_lookup, OP_LOOKUP), (has_update, OP_UPDATE),
            (has_insert, OP_INSERT), (has_scan, OP_SCAN),
        ] if flag
    ]
    lane_sharding = NamedSharding(mesh, lanes)

    def init_carry(b_global: int):
        """The all-inactive prologue carry for a global batch width: every
        routed slot holds the KEY_MAX sentinel, so the first step's back
        half is a structural no-op (no sends, no writes, no results)."""
        n_dev = cfg.n_devices
        if b_global % n_dev:
            raise ValueError(
                f"batch width {b_global} must divide over {n_dev} devices"
            )
        b_loc = b_global // n_dev
        cap0 = routing.route_capacity(
            b_loc, cfg.n_route, cfg.route_capacity_factor
        )
        q_g = n_dev * cfg.n_route * cap0
        h = max(hops - 1, 0)
        carry = {
            "q": jnp.full((q_g,), KEY_MAX, jnp.int64),
            "val": jnp.zeros((q_g,), jnp.int64),
            "opc": jnp.zeros((q_g,), jnp.int32),
            "pr": jnp.zeros((q_g,), jnp.int64),
            "subtree": jnp.zeros((q_g,), jnp.int32),
            "offl": jnp.zeros((q_g,), bool),
            "gid": jnp.zeros((q_g,), jnp.int64),
            "found": jnp.zeros((q_g,), bool),
            "vleaf": jnp.zeros((q_g,), jnp.int64),
            "shed": jnp.zeros((q_g,), bool),
            "vseen": jnp.zeros((q_g,), jnp.int32),
            "lane": jnp.zeros((n_dev * cfg.n_route, cap0), jnp.int32),
            "dropr": jnp.zeros((b_global,), bool),
            "cost": jnp.zeros((q_g,), jnp.float32),
            "fmiss": jnp.zeros((q_g,), bool),
        }
        if may_peek:
            carry["peek"] = jnp.zeros((q_g,), bool)
        if has_scan:
            carry.update(
                sck=jnp.full((q_g, mc), KEY_MAX, jnp.int64),
                scv=jnp.zeros((q_g, mc), jnp.int64),
                taken=jnp.zeros((q_g,), jnp.int32),
                hgid=jnp.full((q_g, h), -1, jnp.int64),
                hver=jnp.zeros((q_g, h), jnp.int32),
            )
        return tuple(
            jax.device_put(carry[k], lane_sharding) for k in carry_keys
        )

    def pipe_step(state: DexState, carry, opcodes, keys, values):
        opcodes = opcodes.astype(jnp.int32)
        keys = keys.astype(jnp.int64)
        allowed = jnp.zeros(opcodes.shape, bool)
        for code in enabled_codes:
            allowed = allowed | (opcodes == code)
        keys = jnp.where(allowed, keys, KEY_MAX)
        res = sharded_pipe(
            state.pool, state.occupancy, state.cache, state.boundaries,
            state.miss_ema, state.stats, state.route_demand, state.versions,
            state.succ, state.lat_hist, state.lat_audit, state.rt_keys,
            state.rt_hi, state.rt_sub, state.rt_local, state.rt_ver,
            tuple(carry), opcodes, keys, values.astype(jnp.int64),
        )
        res = list(res)
        new_state = state
        if has_writes:
            new_pk, new_pv, new_occ, new_versions = res[:4]
            res = res[4:]
            new_state = new_state._replace(
                pool=state.pool._replace(pool_keys=new_pk, pool_values=new_pv),
                occupancy=new_occ,
                versions=new_versions,
            )
        new_cache, new_ema, new_stats, new_demand, new_hist, new_audit = (
            res[:6]
        )
        res = res[6:]
        new_state = new_state._replace(
            cache=new_cache, miss_ema=new_ema, stats=new_stats,
            route_demand=new_demand, lat_hist=new_hist, lat_audit=new_audit,
        )
        carry_out = tuple(res[: len(carry_keys)])
        res = res[len(carry_keys):]
        found, vals, status, shed = res[:4]
        result = EngineResult(found=found, values=vals, status=status,
                              shed=shed)
        if has_scan:
            sk, sv, tk = res[4:7]
            result = result._replace(scan_keys=sk, scan_values=sv, taken=tk)
        return new_state, carry_out, result

    plan = dict(plan)
    plan.update(
        pipeline=True,
        stages=("front", "back"),
        overlap_phases=("pipe/front", "pipe/back"),
    )
    return EnginePipeline(pipe_step, init_carry, plan)
