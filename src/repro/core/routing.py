"""Shared request-routing layer for the mesh plane (Plane B).

Every sharded DEX operation — point lookup (core/dex.py) and range scan
(core/scan.py) — moves work between chips the same way:

  1. bucket a batch of requests by destination with bounded capacity
     (:func:`pack_by_dest`), the SPMD analogue of per-server send queues;
  2. exchange the buckets with ``all_to_all`` collectives, composing two
     exchanges when the compute partitions span two mesh axes
     (:func:`route_exchange`);
  3. serve, then exchange back and scatter responses to the originating
     lanes (:func:`unpack_to_lanes`).

:func:`fetch_rows` layers the RDMA-READ analogue on top of (1)–(3): a
request/response ``all_to_all`` over the memory axis carrying 1KB node rows,
one round per tree level (DESIGN.md §2).

All helpers are intended to run *inside* ``shard_map``; ``cfg`` is any object
with the :class:`repro.core.dex.DexMeshConfig` routing attributes
(``route_axes``, ``memory_axis``, ``n_memory``, ``route_capacity_factor``) —
duck-typed to keep this module import-light.
"""

from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map_compat  # noqa: F401  (re-export)
from repro.core.nodes import KEY_MAX
from repro.core.pool import PoolMeta, SubtreePool


def hash64(x: jax.Array) -> jax.Array:
    """SplitMix64 finalizer; used for cache set indexing and admission dice."""
    x = x.astype(jnp.uint64)
    x = (x ^ (x >> jnp.uint64(33))) * jnp.uint64(0xFF51AFD7ED558CCD)
    x = (x ^ (x >> jnp.uint64(33))) * jnp.uint64(0xC4CEB9FE1A85EC53)
    return x ^ (x >> jnp.uint64(33))


def leaf_admit_dice(gid: jax.Array, pct, salt=None) -> jax.Array:
    """Lazy leaf-admission coin flip (paper §5.4, P_A), deterministic per
    (node id, ``salt``).  Ops pass their chip's running op counter plus the
    lane index as the salt, so every *access* re-rolls the dice — a hot
    leaf that loses the flip can still be admitted on a later access, the
    same per-miss coin-flip semantics the paper (and the Plane-A simulator)
    uses, just derived from a hash instead of an RNG stream."""
    x = gid ^ jnp.int64(0x9E3779B9)
    if salt is not None:
        x = x ^ (jnp.int64(salt) * jnp.int64(0x5851F42D4C957F2D))
    luck = (hash64(x) % jnp.uint64(100)).astype(jnp.int32)
    return luck < pct


def rt_predict(rt_keys: jax.Array, rt_sub: jax.Array, rt_local: jax.Array,
               keys: jax.Array):
    """Leaf-direct route-table segment lookup (DESIGN.md §13).

    ``rt_keys`` is the sorted fence-low plane of the trained table — a
    piecewise-linear index over the observed key hull whose segment lookup
    is one ``searchsorted`` against replicated arrays (compute-side; no
    collective, no remote read).  Returns ``(idx, pred_subtree,
    pred_local)`` — the *guess*; the engine only acts on it after
    :func:`repro.core.fleet_cache.rt_accept` verifies the fence-key bounds
    and the leaf version fence, so a wrong guess costs one rejected
    prediction, never a wrong answer."""
    r = rt_keys.shape[0]
    idx = jnp.clip(
        jnp.searchsorted(rt_keys, keys, side="right") - 1, 0, r - 1
    ).astype(jnp.int32)
    return idx, rt_sub[idx].astype(jnp.int32), rt_local[idx].astype(jnp.int32)


def route_capacity(b: int, n_dest: int, factor: float) -> int:
    """Per-destination bucket capacity for a batch of ``b`` requests."""
    return int(np.ceil(b / n_dest * factor))


def route_owners(boundaries: jax.Array, keys: jax.Array, n_route: int):
    """Owning compute partition per lane (logical partitioning, §4), plus
    this batch's per-partition demand.

    Returns ``(owner [B] int32, demand [1, n_route] int64)``.  ``demand``
    counts every real lane *before* bucketing — shed lanes included — so it
    never saturates at bucket capacity the way served-op counters do (it
    accumulates into ``DexState.route_demand``, the repartition
    controller's load signal).  Inactive lanes (``KEY_MAX``, masked mixed
    batches) get the out-of-bounds sentinel destination ``n_route`` — they
    scatter nowhere in :func:`pack_by_dest` (``mode="drop"``), consume no
    bucket capacity and contribute no demand; callers must mask the
    returned ``dropped`` flags with their real-lane mask, since overflow of
    the sentinel run is meaningless (same contract as the offload path)."""
    owner = (
        jnp.searchsorted(boundaries, keys, side="right") - 1
    ).astype(jnp.int32)
    owner = jnp.clip(owner, 0, n_route - 1)
    demand = jnp.zeros((1, n_route), jnp.int64).at[0, owner].add(
        (keys != KEY_MAX).astype(jnp.int64)
    )
    owner = jnp.where(keys == KEY_MAX, n_route, owner)
    return owner, demand


def pack_by_dest(payload: jax.Array, dest: jax.Array, n_dest: int, cap: int):
    """Bucket ``payload`` rows by destination with bounded capacity.

    Returns ``(buf, lane_of_slot, dropped)``:
      * ``buf``: [n_dest, cap, ...] payload (KEY_MAX padding)
      * ``lane_of_slot``: [n_dest, cap] originating lane (B = OOB sentinel)
      * ``dropped``: [B] lanes that exceeded a bucket's capacity (these are
        load-shed, mirrored by a stats counter — the caller retries or
        reports; logical repartitioning is the systemic fix, §4)
    """
    b = dest.shape[0]
    order = jnp.argsort(dest, stable=True)
    sd = dest[order]
    new = jnp.concatenate([jnp.ones((1,), bool), sd[1:] != sd[:-1]])
    start = jax.lax.cummax(jnp.where(new, jnp.arange(b), 0), axis=0)
    rank = jnp.arange(b) - start
    ok = rank < cap
    pad_shape = (n_dest, cap) + payload.shape[1:]
    fill = KEY_MAX if payload.dtype == jnp.int64 else 0
    buf = jnp.full(pad_shape, fill, payload.dtype)
    buf = buf.at[sd, rank].set(payload[order], mode="drop")
    lane = jnp.full((n_dest, cap), b, jnp.int32)
    lane = lane.at[sd, rank].set(order.astype(jnp.int32), mode="drop")
    dropped = jnp.zeros((b,), bool).at[order].set(~ok)
    return buf, lane, dropped


def unpack_to_lanes(resp: jax.Array, lane_of_slot: jax.Array, b: int, fill):
    """Scatter [n_dest, cap, ...] responses back to [B, ...] lanes."""
    flat_lane = lane_of_slot.reshape(-1)
    flat = resp.reshape((-1,) + resp.shape[2:])
    out = jnp.full((b,) + resp.shape[2:], fill, resp.dtype)
    return out.at[flat_lane].set(flat, mode="drop")


# trace-time collective bookkeeping: every ``all_to_all`` issued while
# tracing a mesh program bumps these, so a benchmark can count the
# collective rounds of a jitted op without parsing HLO
# (:func:`trace_collective_counts`).  When a :func:`trace_phase` label is
# active the bump is also attributed to that phase — the pipelined engine
# labels its two stages so benchmarks can assert WHICH half of the step
# carries each collective (the overlap story: the fused write round rides
# in the back half, hidden under the next batch's descent).
_TRACE_COUNTS = {"all_to_all": 0, "route_exchange": 0}
_TRACE_PHASE: list = [None]
_TRACE_BY_PHASE: dict = {}


@contextlib.contextmanager
def trace_phase(label: str):
    """Attribute collectives issued inside this block to ``label`` during
    abstract tracing (metadata only — adds nothing to the program)."""
    prev = _TRACE_PHASE[0]
    _TRACE_PHASE[0] = label
    try:
        yield
    finally:
        _TRACE_PHASE[0] = prev


def _count_collective(kind: str) -> None:
    _TRACE_COUNTS[kind] += 1
    label = _TRACE_PHASE[0]
    if label is not None:
        per = _TRACE_BY_PHASE.setdefault(
            label, {"all_to_all": 0, "route_exchange": 0}
        )
        per[kind] += 1


def trace_collective_counts(fn, *args, by_phase: bool = False, **kwargs):
    """Abstractly trace ``fn(*args, **kwargs)`` and return how many
    ``all_to_all`` collectives and ``route_exchange`` invocations the traced
    program contains — the honest "communication rounds per batch" metric
    the engine benchmark asserts on (benchmarks/fig13_mesh_engine.py).

    With ``by_phase=True`` the result gains a ``"phases"`` entry splitting
    the counts by the :func:`trace_phase` labels active when each collective
    was issued (the pipelined engine labels ``pipe/front``/``pipe/back``)."""
    before = dict(_TRACE_COUNTS)
    before_phase = {k: dict(v) for k, v in _TRACE_BY_PHASE.items()}
    jax.eval_shape(fn, *args, **kwargs)
    out = {k: _TRACE_COUNTS[k] - before[k] for k in _TRACE_COUNTS}
    if by_phase:
        phases = {}
        for label, per in _TRACE_BY_PHASE.items():
            prev = before_phase.get(label, {})
            diff = {k: per[k] - prev.get(k, 0) for k in per}
            if any(diff.values()):
                phases[label] = diff
        out["phases"] = phases
    return out


def a2a(x: jax.Array, axis: str) -> jax.Array:
    """[n_axis, ...] per-destination buffers -> per-source buffers."""
    _count_collective("all_to_all")
    return jax.lax.all_to_all(x, axis, split_axis=0, concat_axis=0, tiled=False)


def route_exchange(buf: jax.Array, cfg, mesh, *, reverse: bool = False) -> jax.Array:
    """Exchange per-destination buckets across the compute-partition axes.

    ``buf`` is [n_route, cap, ...].  With one route axis this is a single
    ``all_to_all``; with two, the exchanges over each axis compose to the full
    permutation (and must be applied in the opposite order on the way back,
    ``reverse=True``).
    """
    _count_collective("route_exchange")
    if len(cfg.route_axes) == 1:
        return a2a(buf, cfg.route_axes[0])
    a0, a1 = cfg.route_axes
    s1 = mesh.shape[a1]
    r = buf.reshape((buf.shape[0] // s1, s1) + buf.shape[1:])

    def x0(r):
        _count_collective("all_to_all")
        return jax.lax.all_to_all(r, a0, split_axis=0, concat_axis=0)

    def x1(r):
        _count_collective("all_to_all")
        r = jnp.swapaxes(r, 0, 1)
        r = jax.lax.all_to_all(r, a1, split_axis=0, concat_axis=0)
        return jnp.swapaxes(r, 0, 1)

    r = x0(x1(r)) if reverse else x1(x0(r))
    return r.reshape(buf.shape)


def device_linear_index(cfg, mesh) -> jax.Array:
    """This device's linear position over *all* mesh axes (route-major),
    matching how ``P(cfg.all_axes)``-sharded batch dims are chunked.  Used to
    derive globally unique per-lane priorities for write conflict
    resolution."""
    idx = jnp.int32(0)
    for ax in cfg.all_axes:
        idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
    return idx


def route_linear_index(cfg, mesh) -> jax.Array:
    """This device's linear position along the composed route axes (matches
    the leading axis of :func:`gather_route`)."""
    idx = jnp.int32(0)
    for ax in cfg.route_axes:
        idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
    return idx


def gather_route(x: jax.Array, cfg) -> jax.Array:
    """All-gather ``x`` across the route axes: ``[...] -> [n_route, ...]``.

    The write path uses this to make every route-replica of a memory
    column's pool shard apply the *same* batch of writes: the pool is only
    sharded over the memory axis, so devices along the route axes hold
    replicas that must mutate identically (the SPMD analogue of "the memory
    server applies the write once, all compute servers see it")."""
    shape = x.shape
    for ax in reversed(cfg.route_axes):
        x = jax.lax.all_gather(x, ax, axis=0)
    return x.reshape((cfg.n_route,) + shape)


def fetch_rows(
    pool: SubtreePool,
    meta: PoolMeta,
    cfg,
    gid: jax.Array,
    want: jax.Array,
):
    """Remote-read node rows (the RDMA READ analogue): request/response
    all_to_all over the memory axis.  Lanes with ``want == False`` send a
    padded no-op request.

    Requests are *coalesced*: duplicate gids on this chip (a hot node
    missed by many lanes of one batch) collapse into a single remote read
    whose response fans back out to every requesting lane — fewer messages
    and far less routing-bucket pressure under zipfian skew.  Returns
    ``(keys, children, values, dropped, n_msgs)`` where ``n_msgs`` is the
    number of coalesced read messages actually served (the RDMA-READ count
    for stats)."""
    b = gid.shape[0]
    gidr = jnp.where(want, gid, KEY_MAX)
    order = jnp.argsort(gidr, stable=True)
    gs = gidr[order]
    head = jnp.concatenate([jnp.ones((1,), bool), gs[1:] != gs[:-1]])
    rep_sorted = jax.lax.cummax(
        jnp.where(head, jnp.arange(b), 0), axis=0
    )                                         # sorted-pos of my run's head
    rep = (
        jnp.zeros((b,), jnp.int32)
        .at[order].set(order[rep_sorted].astype(jnp.int32))
    )                                         # lane -> representative lane
    is_head = jnp.zeros((b,), bool).at[order].set(head)
    want_h = want & is_head                   # only representatives send

    s_per = meta.n_subtrees_padded // cfg.n_memory
    subtree = (gid // meta.subtree_cap).astype(jnp.int32)
    owner = jnp.where(want_h, subtree // s_per, cfg.n_memory)  # OOB if unused
    cap = route_capacity(b, cfg.n_memory, cfg.route_capacity_factor)
    buf, lane, dropped = pack_by_dest(gid, owner.astype(jnp.int32), cfg.n_memory, cap)
    req = a2a(buf, cfg.memory_axis)                        # [n_mem, cap]
    # serve locally: decode gid -> (local subtree, local node)
    st = (req // meta.subtree_cap).astype(jnp.int32) % s_per
    lo = (req % meta.subtree_cap).astype(jnp.int32)
    valid = req != KEY_MAX
    st = jnp.where(valid, st, 0)
    lo = jnp.where(valid, lo, 0)
    rk = pool.pool_keys[st, lo]                            # [n_mem, cap, F]
    rc = pool.pool_children[st, lo]
    rv = pool.pool_values[st, lo]
    rk = jnp.where(valid[..., None], rk, KEY_MAX)
    rc = jnp.where(valid[..., None], rc, 0)
    rv = jnp.where(valid[..., None], rv, 0)
    rk = a2a(rk, cfg.memory_axis)
    rc = a2a(rc, cfg.memory_axis)
    rv = a2a(rv, cfg.memory_axis)
    out_k = unpack_to_lanes(rk, lane, b, KEY_MAX)
    out_c = unpack_to_lanes(rc, lane, b, 0)
    out_v = unpack_to_lanes(rv, lane, b, 0)
    # fan the representative's response (and shed fate) out to duplicates;
    # only lanes that actually wanted a fetch can be load-shed: no-op lanes
    # share the OOB sentinel bucket, whose overflow is meaningless
    shed = dropped[rep] & want
    n_msgs = jnp.sum(want_h & ~dropped).astype(jnp.int64)
    return out_k[rep], out_c[rep], out_v[rep], shed, n_msgs
