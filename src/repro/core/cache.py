"""Compute-side caching (paper §5), event-level implementation (Plane A).

Implements the paper's cache machinery faithfully, per node, with statistics
for RDMA accounting and for the contention cost model:

  * mapping table: node id -> frame state (HOT / COOLING / IO) (§5.1)
  * pointer swizzling bookkeeping (parents know which children are cached)
  * cooling map: hash table of CPU-cacheline-sized FIFO arrays (§5.2);
    ``n_buckets=1`` degenerates to the centralized FIFO-queue baseline that
    Fig. 4/9 show cannot scale
  * path-aware cooling with delegation to the deepest swizzled child (§5.3)
  * selective/lazy admission: leaves with probability P_A, inner always,
    and a child is only admitted if its parent is cached (§5.4)
  * second chance: touching a COOLING node restores it to HOT (§5.1)

The TPU-plane cache (core/fleet_cache.py) keeps the same *idea* —
hash-distributed FIFO buckets == set-associative FIFO ways — in vectorized
form, and derives its integer admission percent from this module's
``DEFAULT_P_ADMIT_LEAF`` (the single source of truth for the paper's P_A).
Per-server divergent admission (``admit_bias``) mirrors that module's
``CachePolicy.admit_bias`` so the two planes' fleet-cache counters stay
drift-comparable.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Set

import numpy as np

HOT = 1
COOLING = 2
IO = 3

#: paper: each 64-byte bucket holds six FIFO slots
BUCKET_SLOTS = 6
#: paper: cooling map capacity is 10% of the cache
COOLING_FRACTION = 0.10
#: paper §5.4: default leaf admission probability
DEFAULT_P_ADMIT_LEAF = 0.10


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    second_chance_hits: int = 0
    misses: int = 0
    admissions: int = 0
    rejected_admissions: int = 0
    evictions: int = 0
    writebacks: int = 0          # dirty-page RDMA WRITEs caused by cooling/eviction
    cooling_ops: int = 0
    delegations: int = 0
    bucket_lock_acquires: int = 0     # critical sections on cooling structures
    mapping_ops: int = 0              # mapping-table critical sections
    io_flag_restarts: int = 0

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, 0)


class CoolingMap:
    """Hash table of fixed-size FIFO arrays (paper Fig. 3).

    Every mutation acquires exactly one bucket lock; with ``n_buckets == 1``
    this is the centralized FIFO-list baseline.  ``bucket_lock_acquires`` per
    bucket feed the contention model in ``cost_model.py``.
    """

    def __init__(self, n_buckets: int, slots: int = BUCKET_SLOTS):
        assert n_buckets >= 1
        self.n_buckets = n_buckets
        self.slots = slots
        self.buckets: List[List[int]] = [[] for _ in range(n_buckets)]
        self.where: Dict[int, int] = {}  # node -> bucket
        self.lock_acquires = np.zeros((n_buckets,), dtype=np.int64)

    def __len__(self) -> int:
        return len(self.where)

    def _bucket_of(self, node: int) -> int:
        # Fibonacci hash of node id
        return int((node * 11400714819323198485) % (2**64)) % self.n_buckets

    def insert(self, node: int) -> Optional[int]:
        """FIFO-insert ``node``; returns the evicted head if the bucket was
        full (that page leaves the cache; paper §5.2)."""
        b = self._bucket_of(node)
        self.lock_acquires[b] += 1
        bucket = self.buckets[b]
        evicted = None
        if len(bucket) >= self.slots:
            evicted = bucket.pop(0)
            del self.where[evicted]
        bucket.append(node)
        self.where[node] = b
        return evicted

    def remove(self, node: int) -> bool:
        """Second-chance restore: pull a node back out of cooling."""
        b = self.where.pop(node, None)
        if b is None:
            return False
        self.lock_acquires[b] += 1
        self.buckets[b].remove(node)
        return True

    def pop_any(self, rng: np.random.Generator) -> Optional[int]:
        """Evict the oldest page of a random non-empty bucket (free-page
        provisioning, §5.4)."""
        if not self.where:
            return None
        non_empty = [i for i, b in enumerate(self.buckets) if b]
        b = int(rng.choice(non_empty))
        self.lock_acquires[b] += 1
        node = self.buckets[b].pop(0)
        del self.where[node]
        return node


class ComputeCache:
    """Per-compute-server node cache (Plane A).

    The driver (core/sim.py) supplies tree topology callbacks so the cache
    can do path-aware delegation and swizzling bookkeeping without owning
    the tree:

      * ``parent_of(node) -> node | -1``
      * ``is_leaf(node) -> bool``
    """

    def __init__(
        self,
        capacity: int,
        *,
        parent_of: Callable[[int], int],
        is_leaf: Callable[[int], bool],
        p_admit_leaf: float = DEFAULT_P_ADMIT_LEAF,
        n_cooling_buckets: Optional[int] = None,
        cooling_slots: int = BUCKET_SLOTS,
        eager_admission: bool = False,
        rng: Optional[np.random.Generator] = None,
        admit_bias: Optional[Callable[[int], float]] = None,
    ):
        assert capacity >= 4
        self.capacity = capacity
        self.parent_of = parent_of
        self.is_leaf = is_leaf
        self.p_admit_leaf = 1.0 if eager_admission else p_admit_leaf
        # divergent fleet policy (core/fleet_cache.py CachePolicy.admit_bias
        # mirror): per-node multiplier on the leaf-admission probability;
        # None keeps the uniform §5.4 dice exactly
        self.admit_bias = admit_bias
        if n_cooling_buckets is None:
            n_cooling_buckets = max(
                1, int(capacity * COOLING_FRACTION / cooling_slots)
            )
        self.cooling = CoolingMap(n_cooling_buckets, cooling_slots)
        self.rng = rng or np.random.default_rng(0)
        self.stats = CacheStats()

        self.state: Dict[int, int] = {}          # node -> HOT/COOLING/IO
        self.dirty: Set[int] = set()
        self.pinned: Set[int] = set()
        self.swizzled_children: Dict[int, Set[int]] = {}
        self.free = capacity

    # -- basic queries -------------------------------------------------------

    def __contains__(self, node: int) -> bool:
        return self.state.get(node) in (HOT, COOLING)

    def num_cached(self) -> int:
        return self.capacity - self.free

    def is_dirty(self, node: int) -> bool:
        return node in self.dirty

    # -- mapping-table access (Algorithm 1 cache.lookup) ----------------------

    def lookup(self, node: int) -> str:
        """Probe the mapping table.  Returns 'hit', 'io' (restart from root),
        or 'miss'."""
        self.stats.mapping_ops += 1
        st = self.state.get(node)
        if st == HOT:
            self.stats.hits += 1
            return "hit"
        if st == COOLING:
            # second chance: restore to HOT, re-swizzle in parent
            self.cooling.remove(node)
            self.state[node] = HOT
            p = self.parent_of(node)
            if p >= 0 and p in self:
                self.swizzled_children.setdefault(p, set()).add(node)
            self.stats.second_chance_hits += 1
            self.stats.hits += 1
            return "hit"
        if st == IO:
            self.stats.io_flag_restarts += 1
            return "io"
        self.stats.misses += 1
        return "miss"

    # -- admission (§5.4) ------------------------------------------------------

    def admit(self, node: int, *, dirty: bool = False,
              ignore_parent: bool = False) -> bool:
        """Try to admit a freshly fetched node.  Returns True if cached.

        Applies (1) path-aware admission — parent must already be cached
        (root has no parent, always admissible); (2) lazy admission for
        leaves with probability P_A; (3) free-page provisioning through the
        cooling map.

        ``ignore_parent`` waives check (1) for leaves reached through the
        leaf-direct route table (core/sim.py): the table entry stands in
        for the cached ancestor path, matching the mesh fleet cache's
        dice-only leaf admission (core/fleet_cache.py ``leaf_admit``).
        """
        if node in self:
            if dirty:
                self.dirty.add(node)
            return True
        parent = self.parent_of(node)
        if not ignore_parent and parent >= 0 and parent not in self:
            self.stats.rejected_admissions += 1
            return False
        if self.is_leaf(node):
            p = self.p_admit_leaf
            if self.admit_bias is not None:
                p = min(1.0, p * self.admit_bias(node))
            if self.rng.random() > p:
                self.stats.rejected_admissions += 1
                return False

        if self.free <= 0 and not self._provision_free_page():
            self.stats.rejected_admissions += 1
            return False

        # mark I/O while "fetching" (concurrency bookkeeping), then admit
        self.stats.mapping_ops += 1
        self.state[node] = HOT
        self.free -= 1
        if dirty:
            self.dirty.add(node)
        if parent >= 0 and parent in self:
            self.swizzled_children.setdefault(parent, set()).add(node)
        self.stats.admissions += 1
        # keep the cooling map stocked (background sampling in LeanStore;
        # worker-driven here, per the paper)
        self._maybe_sample_cooling()
        return True

    # -- cooling & eviction (§5.2, §5.3) --------------------------------------

    def _maybe_sample_cooling(self) -> None:
        target = max(1, int(self.capacity * COOLING_FRACTION))
        # sampling only starts when free frames run low (paper §5.1: a thread
        # samples when its free-page set is empty); a mostly-empty cache must
        # not cool fresh admissions
        if self.free > target:
            return
        tries = 0
        while len(self.cooling) < target and tries < 2:
            tries += 1
            victim = self._sample_hot_node()
            if victim is None:
                return
            self._cool(victim)

    def _sample_hot_node(self) -> Optional[int]:
        hot = [n for n, s in self.state.items() if s == HOT and n not in self.pinned]
        if not hot:
            return None
        # random sampling of two; prefer non-root-ish nodes implicitly via
        # path-aware delegation afterwards
        pick = self.rng.choice(len(hot), size=min(2, len(hot)), replace=False)
        return int(hot[int(pick[0])])

    def _cool(self, node: int) -> None:
        """Transition ``node`` toward COOLING with path-aware delegation: the
        cooling command is recursively delegated to a swizzled child so a
        cached path stays contiguous from the root (§5.3)."""
        self.stats.cooling_ops += 1
        cur = node
        while True:
            kids = self.swizzled_children.get(cur)
            live = [k for k in kids if k in self and self.state.get(k) == HOT] if kids else []
            if not live:
                break
            self.stats.delegations += 1
            cur = int(self.rng.choice(live))
        if self.state.get(cur) != HOT or cur in self.pinned:
            return
        # proactively unswizzle from parent, write back if dirty
        p = self.parent_of(cur)
        if p >= 0 and p in self.swizzled_children:
            self.swizzled_children[p].discard(cur)
        if cur in self.dirty:
            self.dirty.discard(cur)
            self.stats.writebacks += 1
        self.state[cur] = COOLING
        evicted = self.cooling.insert(cur)
        if evicted is not None:
            self._finish_eviction(evicted)

    def _provision_free_page(self) -> bool:
        """Get a free frame by evicting the oldest page of a random cooling
        bucket; sample hot pages into cooling first if the map ran dry."""
        if not len(self.cooling):
            victim = self._sample_hot_node()
            if victim is None:
                return False
            self._cool(victim)
        node = self.cooling.pop_any(self.rng)
        if node is None:
            return False
        self._finish_eviction(node)
        return True

    def _finish_eviction(self, node: int) -> None:
        if self.state.get(node) != COOLING:
            # raced back to HOT via second chance; nothing to evict
            return
        del self.state[node]
        self.swizzled_children.pop(node, None)
        if node in self.dirty:  # defensive: cooling already wrote back
            self.dirty.discard(node)
            self.stats.writebacks += 1
        self.free += 1
        self.stats.evictions += 1

    # -- dirty handling / pinning (offloading + repartition support) ----------

    def mark_dirty(self, node: int) -> None:
        if node in self:
            self.dirty.add(node)

    def pin(self, node: int) -> None:
        self.pinned.add(node)

    def unpin(self, node: int) -> None:
        self.pinned.discard(node)

    def set_io(self, node: int) -> None:
        """Mark an in-progress fetch/offload (Algorithm fig.3 ②, §6.2)."""
        self.stats.mapping_ops += 1
        self.state[node] = IO

    def clear_io(self, node: int) -> None:
        if self.state.get(node) == IO:
            del self.state[node]

    def invalidate(self, node: int) -> bool:
        """Drop a (possibly stale) node; returns True if it was cached.
        Used for coherence after offloaded updates (§6.2) and fence-key
        mismatch refreshes (§4)."""
        st = self.state.get(node)
        if st is None:
            return False
        if st == COOLING:
            self.cooling.remove(node)
        p = self.parent_of(node)
        if p >= 0 and p in self.swizzled_children:
            self.swizzled_children[p].discard(node)
        if st in (HOT, COOLING):
            self.free += 1
        del self.state[node]
        self.dirty.discard(node)
        self.swizzled_children.pop(node, None)
        return True

    def flush_dirty(self) -> int:
        """Write back every dirty page (logical repartitioning, Fig. 10).
        Returns the number of pages flushed."""
        n = len(self.dirty)
        self.stats.writebacks += n
        self.dirty.clear()
        return n

    def drop_all(self) -> None:
        """Full reset (after repartition hand-off the new owner re-warms)."""
        self.state.clear()
        self.dirty.clear()
        self.pinned.clear()
        self.swizzled_children.clear()
        self.cooling = CoolingMap(self.cooling.n_buckets, self.cooling.slots)
        self.free = self.capacity
