"""On-mesh structural-modification engine (Plane B): device-side leaf
splits between batches, without rebuilding the pool.

``core/write.py`` sheds an insert whose leaf would overflow
(``STATUS_SPLIT``): an SPMD batch cannot take the paper's per-node latches,
so it refuses the structural change.  Until this module existed every shed
lane drained through :func:`repro.core.write.drain_splits`, which replays
on the host tree and rebuilds the *entire* blocked pool — restarting all
caches and versions cold.  That inverts the paper's economy (§6 falls back
to the normal path only for the SMO itself, not the whole index); FlexKV
and Outback both make the same point — keep structural maintenance next to
the data and ship only tiny fixed-size messages.

:func:`make_dex_smo` builds the collective SMO round that does exactly
that.  Per round:

  1. shed ``(key, value)`` lanes are routed to the memory column owning
     their level-M subtree (24B messages — the "tiny fixed-size" write of
     the disaggregated protocol) and all-gathered across the route axes so
     every pool replica applies the identical round;
  2. the owner walks its local block to each target leaf, groups lanes by
     leaf, resolves duplicate writers by global batch priority and turns
     already-present keys into value updates;
  3. each target leaf goes through the ``leaf_split`` Pallas kernel
     (kernels/leaf_split.py, oracle ``leaf_split_ref``): pending inserts
     are rank-merged and a leaf whose merged count exceeds FANOUT is cut
     into two half-full rows.  The sibling slot comes from the subtree's
     free-list headroom (``DexState.n_alloc`` watermark, capacity reserved
     at build time — core/pool.py), the leaf-successor table is re-linked
     so scans keep walking leaves in key order, and the separator is
     rank-merged into the parent row (reusing the ``leaf_write`` kernel
     with children as the value plane);
  4. full parents are split by a dense in-block pass (one split per parent
     per sweep, recursing toward the subtree root across sweeps/rounds).

Coherence reuses the write path's machinery: only the split leaf, its new
sibling and the touched ancestors get ``DexState.versions`` bumps, so
unrelated cached rows on every chip stay warm — versus the drain path's
global cold restart.  The fallback ladder is now graded the way the paper's
is: leaf split (device-side) -> subtree-block overflow / top-tree growth
(``drain_splits`` host rebuild, counted in ``STAT_DRAINS``) — and the host
replay remains the validation oracle.

Drivers: :func:`run_smo` iterates rounds until the pending set stops
shrinking; :func:`settle_splits` adds the host-mirror replay and the
``drain_splits`` fallback for whatever a bounded number of rounds could not
place (exhausted free-lists, subtree-root splits).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import routing
from repro.core.dex import (
    N_STATS,
    STAT_SMO_SPLITS,
    DexMeshConfig,
    DexState,
)
from repro.core.nodes import FANOUT, KEY_MAX, NULL
from repro.core.pool import (
    PoolMeta,
    SepPlanes,
    SubtreePool,
    compress_rows,
    top_walk,
)
from repro.core.write import (
    STATUS_MISS,
    STATUS_OK,
    STATUS_SPLIT,
    _seg_positions,
    drain_splits,
)
from repro.kernels.leaf_split import leaf_split
from repro.kernels.leaf_write import leaf_write
from repro.kernels.ops import use_interpret
from repro.kernels.ref import leaf_split_ref, leaf_write_ref


def _dense_parents(pool_children: jax.Array) -> jax.Array:
    """Per-node parent local id (-1 for roots/leaves' absent parents),
    derived from the children arrays of one pool shard [S, C, F]."""
    s, c, f = pool_children.shape
    node = jnp.broadcast_to(
        jnp.arange(c, dtype=jnp.int32)[None, :, None], (s, c, f)
    )
    row = jnp.broadcast_to(jnp.arange(s)[:, None, None], (s, c, f))
    valid = (pool_children != NULL) & (pool_children >= 0) & (
        pool_children < c
    )
    ch = jnp.where(valid, pool_children, c)  # OOB -> dropped
    return (
        jnp.full((s, c), -1, jnp.int32).at[row, ch].set(node, mode="drop")
    )


def make_dex_smo(
    meta: PoolMeta,
    cfg: DexMeshConfig,
    mesh,
    *,
    use_kernel: bool = True,
    interpret: "bool | None" = None,
):
    """Build one collective SMO round:
    ``(state, keys, values) -> (state, status)``.

    ``keys``/``values`` are [B] globally sharded over all mesh axes —
    normally the lanes a ``make_dex_insert`` batch returned with
    ``STATUS_SPLIT`` (``KEY_MAX`` lanes are inactive no-ops).  Each live
    lane comes back ``STATUS_OK`` (applied: split executed device-side, or
    the leaf meanwhile had room and the insert merged in place, or the key
    already existed and its value was updated) or ``STATUS_SPLIT`` (still
    pending: staging overflow, a full parent that split this round, or an
    exhausted subtree — retry with another round / fall back to
    ``drain_splits``).  Wrap with ``jax.jit``; drive with :func:`run_smo`
    or :func:`settle_splits`.
    """
    levels = meta.levels_in_subtree
    cap_nodes = meta.subtree_cap
    if interpret is None:
        interpret = use_interpret()
    SW = FANOUT  # staged inserts per leaf per round

    def local_fn(pool, occupancy, n_alloc, versions, succ, stats,
                 keys, values):
        b = keys.shape[0]
        s_per = meta.n_subtrees_padded // cfg.n_memory
        s_local = occupancy.shape[0]
        n_nodes_total = versions.shape[-1]
        vers = versions[0]
        succ_t = succ[0]

        # --- 1. route to the owning memory column, replicate the round ----
        dev = routing.device_linear_index(cfg, mesh)
        prio = dev.astype(jnp.int64) * b + jnp.arange(b, dtype=jnp.int64)
        live0 = keys != KEY_MAX
        st0 = top_walk(pool, meta, keys)
        owner = jnp.where(live0, st0 // s_per, cfg.n_memory)
        payload = jnp.stack([keys, values, prio], axis=-1)      # [B, 3]
        # bucket capacity = the full per-device batch: an SMO round is rare
        # (between batches) and must never load-shed its own repair work
        buf, lane, dropped = routing.pack_by_dest(
            payload, owner.astype(jnp.int32), cfg.n_memory, b
        )
        req = routing.a2a(buf, cfg.memory_axis)                 # [n_mem, b, 3]
        req_all = routing.gather_route(req, cfg)                # [R, n_mem, b, 3]
        flat = req_all.reshape(-1, 3)
        k = flat[:, 0]
        v = flat[:, 1]
        pr = flat[:, 2]
        n = k.shape[0]
        live = k != KEY_MAX

        # --- 2. walk the local block to the leaf, recording the path ------
        stg = jnp.where(live, top_walk(pool, meta, k), 0)       # global id
        st = (stg % s_per).astype(jnp.int32)                    # shard row
        plocals = [jnp.zeros((n,), jnp.int32)]
        local = jnp.zeros((n,), jnp.int32)
        for _ in range(levels - 1):
            rows = pool.pool_keys[st, local]
            slot = jnp.maximum(
                jnp.sum(rows <= k[:, None], axis=-1) - 1, 0
            ).astype(jnp.int32)
            local = pool.pool_children[st, local, slot]
            plocals.append(local)
        leaf_lo = plocals[-1]
        gid_leaf = meta.node_gid(stg, leaf_lo)

        # --- 3. conflict resolution + existing keys become value updates --
        row_k0 = pool.pool_keys[st, leaf_lo]
        eqk = row_k0 == k[:, None]
        exists = jnp.any(eqk, axis=-1) & live
        uslot = jnp.argmax(eqk, axis=-1).astype(jnp.int32)
        route_gid = jnp.where(live, gid_leaf, KEY_MAX)
        order = jnp.lexsort((pr, k, route_gid))
        g_s = route_gid[order]
        k_s = k[order]
        v_s = v[order]
        live_s = live[order]
        st_s = st[order]
        lo_s = leaf_lo[order]
        diff = (g_s[1:] != g_s[:-1]) | (k_s[1:] != k_s[:-1])
        new_run = jnp.concatenate([jnp.ones((1,), bool), diff])
        run_id = jnp.cumsum(new_run) - 1
        winner = jnp.concatenate([diff, jnp.ones((1,), bool)]) & live_s
        upd_w = winner & exists[order]
        ust = jnp.where(upd_w, st_s, s_local)                   # OOB drop
        new_pv = pool.pool_values.at[ust, lo_s, uslot[order]].set(
            v_s, mode="drop"
        )

        # --- 4. per-leaf staging of fresh inserts -------------------------
        new_seg = jnp.concatenate([jnp.ones((1,), bool), g_s[1:] != g_s[:-1]])
        seg_id = jnp.cumsum(new_seg) - 1
        ins_w = winner & ~exists[order]
        pos = _seg_positions(ins_w, new_seg)
        staged = ins_w & (pos < SW)
        ir = jnp.where(staged, seg_id, n)
        ic = jnp.where(staged, pos, SW)
        ins_key_st = (
            jnp.full((n, SW), KEY_MAX, jnp.int64)
            .at[ir, ic].set(k_s, mode="drop")
        )
        ins_val_st = (
            jnp.zeros((n, SW), jnp.int64).at[ir, ic].set(v_s, mode="drop")
        )
        n_staged = (
            jnp.zeros((n,), jnp.int32).at[seg_id].add(staged.astype(jnp.int32))
        )

        def seg_attr(x, fill=0):
            return (
                jnp.full((n,), fill, x.dtype)
                .at[seg_id].max(jnp.where(live_s, x, fill))
            )

        seg_st = seg_attr(st_s)
        seg_lo = seg_attr(lo_s)
        seg_stg = seg_attr(stg[order])
        par_lane = plocals[-2][order] if levels >= 2 else jnp.zeros(
            (n,), jnp.int32
        )
        seg_par = seg_attr(par_lane)
        seg_active = n_staged > 0
        occ_seg = occupancy[seg_st, seg_lo]
        m_seg = occ_seg + n_staged
        need_split = seg_active & (m_seg > FANOUT)
        merge_ok = seg_active & ~need_split

        # --- 5. split admission: parent room + free-list slack ------------
        if levels >= 2:
            cnt_par = (
                jnp.zeros((s_local, cap_nodes), jnp.int32)
                .at[seg_st, seg_par].add(need_split.astype(jnp.int32))
            )
            parent_room = (
                occupancy[seg_st, seg_par] + cnt_par[seg_st, seg_par]
            ) <= FANOUT
            allowed = need_split & parent_room
        else:
            # the leaf IS the subtree root: any split is subtree overflow
            parent_room = jnp.zeros((n,), bool)
            allowed = jnp.zeros((n,), bool)
        new_sub = jnp.concatenate(
            [jnp.ones((1,), bool), seg_st[1:] != seg_st[:-1]]
        )
        rank_sub = _seg_positions(allowed, new_sub)
        sib_lo = (n_alloc[seg_st] + rank_sub).astype(jnp.int32)
        can_split = allowed & (sib_lo < cap_nodes)
        apply_seg = merge_ok | can_split
        alloc_st = jnp.where(can_split, seg_st, s_local)
        new_alloc = n_alloc.at[alloc_st].add(1, mode="drop")

        # --- 6. leaf merge / split (Pallas kernel or oracle) --------------
        rows_k = pool.pool_keys[seg_st, seg_lo]
        rows_v = new_pv[seg_st, seg_lo]
        splitter = leaf_split if use_kernel else leaf_split_ref
        skw = {"interpret": interpret} if use_kernel else {}
        lk, lv, rk, rv, occ_l, occ_r, sep, _did = splitter(
            rows_k, rows_v, ins_key_st, ins_val_st, **skw
        )
        w_st = jnp.where(apply_seg, seg_st, s_local)
        out_pk = pool.pool_keys.at[w_st, seg_lo].set(lk, mode="drop")
        out_pv = new_pv.at[w_st, seg_lo].set(lv, mode="drop")
        out_occ = occupancy.at[w_st, seg_lo].set(occ_l, mode="drop")
        r_st = jnp.where(can_split, seg_st, s_local)
        out_pk = out_pk.at[r_st, sib_lo].set(rk, mode="drop")
        out_pv = out_pv.at[r_st, sib_lo].set(rv, mode="drop")
        out_occ = out_occ.at[r_st, sib_lo].set(occ_r, mode="drop")
        out_pc = pool.pool_children

        # successor chain: leaf -> sibling -> old successor
        gid_seg = meta.node_gid(seg_stg, seg_lo)
        gid_sib = meta.node_gid(seg_stg, sib_lo)
        old_nxt = succ_t[jnp.where(can_split, gid_seg, 0)]
        sidx_sib = jnp.where(can_split, gid_sib, n_nodes_total)
        sidx_leaf = jnp.where(can_split, gid_seg, n_nodes_total)
        succ_new = (
            succ_t.at[sidx_sib].set(old_nxt, mode="drop")
            .at[sidx_leaf].set(gid_sib, mode="drop")
        )

        # version bumps: updated leaves, applied leaves, siblings, parents
        def bump(varr, gids, mask):
            safe = jnp.where(mask, gids, n_nodes_total)
            return varr.at[safe].max(varr[jnp.where(mask, gids, 0)] + 1,
                                     mode="drop")

        # map the winner flag back to lane order for the lane-indexed gids
        upd_l = jnp.zeros((n,), bool).at[order].set(upd_w)
        vers2 = bump(vers, gid_leaf, upd_l)
        vers2 = bump(vers2, gid_seg, apply_seg)
        vers2 = bump(vers2, gid_sib, can_split)
        gid_par = meta.node_gid(seg_stg, seg_par)
        vers2 = bump(vers2, gid_par, can_split)

        # --- 7. merge separators into parent rows -------------------------
        n_leaf_splits = jnp.sum(can_split).astype(jnp.int64)
        if levels >= 2:
            pg_route = jnp.where(can_split, gid_par, KEY_MAX)
            order2 = jnp.lexsort((sep, pg_route))
            pg2 = pg_route[order2]
            sep2 = sep[order2]
            sib2 = sib_lo[order2].astype(jnp.int64)
            act2 = can_split[order2]
            new_seg2 = jnp.concatenate(
                [jnp.ones((1,), bool), pg2[1:] != pg2[:-1]]
            )
            seg2_id = jnp.cumsum(new_seg2) - 1
            pos2 = _seg_positions(act2, new_seg2)
            ir2 = jnp.where(act2, seg2_id, n)
            ic2 = jnp.where(act2, pos2, SW)
            ins_k2 = (
                jnp.full((n, SW), KEY_MAX, jnp.int64)
                .at[ir2, ic2].set(sep2, mode="drop")
            )
            ins_v2 = (
                jnp.zeros((n, SW), jnp.int64)
                .at[ir2, ic2].set(sib2, mode="drop")
            )

            def seg2_attr(x, fill=0):
                return (
                    jnp.full((n,), fill, x.dtype)
                    .at[seg2_id].max(jnp.where(act2, x, fill))
                )

            seg2_st = seg2_attr(seg_st[order2])
            seg2_lo = seg2_attr(seg_par[order2])
            seg2_active = (
                jnp.zeros((n,), bool).at[seg2_id].max(act2)
            )
            rows_pk = out_pk[seg2_st, seg2_lo]
            rows_pc = out_pc[seg2_st, seg2_lo].astype(jnp.int64)
            writer = leaf_write if use_kernel else leaf_write_ref
            wkw = {"interpret": interpret} if use_kernel else {}
            no_us = jnp.full((n, SW), -1, jnp.int32)
            no_uv = jnp.zeros((n, SW), jnp.int64)
            nk2, nc2, nocc2 = writer(
                rows_pk, rows_pc, no_us, no_uv, ins_k2, ins_v2, **wkw
            )
            w2 = jnp.where(seg2_active, seg2_st, s_local)
            out_pk = out_pk.at[w2, seg2_lo].set(nk2, mode="drop")
            out_pc = out_pc.at[w2, seg2_lo].set(
                nc2.astype(jnp.int32), mode="drop"
            )
            out_occ = out_occ.at[w2, seg2_lo].set(nocc2, mode="drop")

        # --- 8. dense inner pass: split full parents toward the root ------
        n_inner_splits = jnp.int64(0)
        if levels >= 3:
            flagged0 = need_split & ~parent_room & (m_seg > 0)
            f_st = jnp.where(flagged0, seg_st, s_local)
            flag = (
                jnp.zeros((s_local, cap_nodes), bool)
                .at[f_st, seg_par].set(True, mode="drop")
            )
            col_ix = jax.lax.axis_index(cfg.memory_axis).astype(jnp.int64)
            row_ix = jnp.broadcast_to(
                jnp.arange(s_local)[:, None], (s_local, cap_nodes)
            )
            lo_ix = jnp.broadcast_to(
                jnp.arange(cap_nodes, dtype=jnp.int32)[None, :],
                (s_local, cap_nodes),
            )
            gid_grid = (
                (col_ix * s_per + row_ix.astype(jnp.int64)) * cap_nodes
                + lo_ix.astype(jnp.int64)
            )
            colF = jnp.arange(FANOUT, dtype=jnp.int32)[None, None, :]
            alloc_g = new_alloc
            for _sweep in range(levels - 2):
                par = _dense_parents(out_pc)                # [S, C]
                par_safe = jnp.where(par >= 0, par, 0)
                par_occ = out_occ[row_ix, par_safe]
                can = flag & (lo_ix != 0) & (par >= 0)
                room = can & (par_occ < FANOUT)
                # one split per parent per sweep: lowest flagged child wins
                min_lo = (
                    jnp.full((s_local, cap_nodes), cap_nodes, jnp.int32)
                    .at[row_ix, jnp.where(room, par_safe, cap_nodes)]
                    .min(lo_ix, mode="drop")
                )
                m_g = out_occ
                win = room & (min_lo[row_ix, par_safe] == lo_ix) & (m_g >= 2)
                rank = jnp.cumsum(win.astype(jnp.int32), axis=1) - win
                sib_g = alloc_g[:, None] + rank
                ok = win & (sib_g < cap_nodes)
                left_n = m_g // 2
                idx = jnp.clip(colF + left_n[:, :, None], 0, FANOUT - 1)
                right_k = jnp.take_along_axis(out_pk, idx, axis=2)
                right_c = jnp.take_along_axis(out_pc, idx, axis=2)
                mask_r = colF < (m_g - left_n)[:, :, None]
                right_k = jnp.where(mask_r, right_k, KEY_MAX)
                right_c = jnp.where(mask_r, right_c, NULL)
                sep_g = jnp.take_along_axis(
                    out_pk, left_n[:, :, None], axis=2
                )[..., 0]
                left_mask = colF < left_n[:, :, None]
                okk = ok[:, :, None]
                out_pk = jnp.where(
                    okk, jnp.where(left_mask, out_pk, KEY_MAX), out_pk
                )
                out_pc = jnp.where(
                    okk, jnp.where(left_mask, out_pc, NULL), out_pc
                )
                out_occ = jnp.where(ok, left_n, out_occ)
                sib_safe = jnp.where(ok, sib_g, cap_nodes)
                out_pk = out_pk.at[row_ix, sib_safe].set(right_k, mode="drop")
                out_pc = out_pc.at[row_ix, sib_safe].set(right_c, mode="drop")
                out_occ = out_occ.at[row_ix, sib_safe].set(
                    m_g - left_n, mode="drop"
                )
                out_pv = out_pv.at[row_ix, sib_safe].set(
                    jnp.zeros((s_local, cap_nodes, FANOUT), jnp.int64),
                    mode="drop",
                )
                alloc_g = alloc_g + jnp.sum(ok.astype(jnp.int32), axis=1)
                # single separator insert into each winner's parent row
                psep = (
                    jnp.full((s_local, cap_nodes), KEY_MAX, jnp.int64)
                    .at[row_ix, jnp.where(ok, par_safe, cap_nodes)]
                    .set(sep_g, mode="drop")
                )
                pchild = (
                    jnp.full((s_local, cap_nodes), NULL, jnp.int32)
                    .at[row_ix, jnp.where(ok, par_safe, cap_nodes)]
                    .set(sib_g.astype(jnp.int32), mode="drop")
                )
                has = psep != KEY_MAX
                ppos = jnp.sum(
                    (out_pk < psep[:, :, None]).astype(jnp.int32), axis=2
                )
                shift = jnp.clip(
                    colF - (colF > ppos[:, :, None]).astype(jnp.int32),
                    0, FANOUT - 1,
                )
                base_k = jnp.take_along_axis(out_pk, shift, axis=2)
                base_c = jnp.take_along_axis(out_pc, shift, axis=2)
                ins_here = colF == ppos[:, :, None]
                new_k = jnp.where(ins_here, psep[:, :, None], base_k)
                new_c = jnp.where(ins_here, pchild[:, :, None], base_c)
                hask = has[:, :, None]
                out_pk = jnp.where(hask, new_k, out_pk)
                out_pc = jnp.where(hask, new_c, out_pc)
                out_occ = out_occ + has.astype(jnp.int32)
                # version bumps: split node, sibling, parent
                bump_grid = ok | has
                bump_grid = (
                    bump_grid.at[row_ix, sib_safe].set(True, mode="drop")
                )
                gflat = gid_grid.reshape(-1)
                bflat = bump_grid.reshape(-1)
                safe = jnp.where(bflat, gflat, n_nodes_total)
                vers2 = vers2.at[safe].max(
                    vers2[jnp.where(bflat, gflat, 0)] + 1, mode="drop"
                )
                n_inner_splits = n_inner_splits + jnp.sum(ok).astype(
                    jnp.int64
                )
                # parents that were full re-flag for the next sweep; losers
                # (multiple flagged children of one parent) retry next round
                nf_par = jnp.where(
                    can & (par_occ >= FANOUT), par_safe, cap_nodes
                )
                flag = (
                    jnp.zeros((s_local, cap_nodes), bool)
                    .at[row_ix, nf_par].set(True, mode="drop")
                )
            new_alloc = alloc_g

        # --- 9. statuses back to the requesting lanes ---------------------
        outcome_w = jnp.where(
            upd_w | (staged & apply_seg[seg_id]),
            STATUS_OK, STATUS_SPLIT,
        ).astype(jnp.int32)
        run_out = (
            jnp.zeros((n,), jnp.int32)
            .at[run_id].max(jnp.where(winner, outcome_w, 0))
        )
        status_s = jnp.where(live_s, run_out[run_id], STATUS_MISS)
        status = jnp.zeros((n,), jnp.int32).at[order].set(status_s)
        r_lin = routing.route_linear_index(cfg, mesh)
        status_own = jnp.take(
            status.reshape(cfg.n_route, cfg.n_memory, b), r_lin, axis=0
        )
        resp = routing.a2a(
            status_own[..., None].astype(jnp.int64), cfg.memory_axis
        )
        back = routing.unpack_to_lanes(resp, lane, b, 0)
        out_status = back[..., 0].astype(jnp.int32)
        out_status = jnp.where(
            dropped & live0, STATUS_SPLIT, out_status
        )
        out_status = jnp.where(live0, out_status, STATUS_MISS)

        # --- 10. sync replicated tables + stats ---------------------------
        new_versions = jax.lax.pmax(vers2[None, :], cfg.all_axes)
        succ_all = jax.lax.all_gather(succ_new, cfg.memory_axis, axis=0)
        owner_col = (
            jnp.arange(n_nodes_total) // meta.subtree_cap
        ) // s_per
        new_succ = jnp.take_along_axis(
            succ_all, owner_col[None, :], axis=0
        )
        # count splits once per memory column (route rows are replicas)
        n_splits = jnp.where(
            r_lin == 0, n_leaf_splits + n_inner_splits, 0
        )
        upd = jnp.zeros((1, N_STATS), jnp.int64)
        upd = upd.at[0, STAT_SMO_SPLITS].set(n_splits)
        new_stats = stats + upd

        return (out_pk, out_pc, out_pv, out_occ, new_alloc, new_versions,
                new_succ, new_stats, out_status)

    dev = P(cfg.all_axes)
    pool_specs = SubtreePool(
        top_keys=P(),
        top_children=P(),
        pool_keys=P(cfg.memory_axis),
        pool_children=P(cfg.memory_axis),
        pool_values=P(cfg.memory_axis),
    )
    mem = P(cfg.memory_axis)

    sharded = routing.shard_map_compat(
        local_fn,
        mesh=mesh,
        in_specs=(pool_specs, mem, mem, dev, dev, dev,
                  P(cfg.all_axes), P(cfg.all_axes)),
        out_specs=(mem, mem, mem, mem, mem, dev, dev, dev, P(cfg.all_axes)),
    )

    def smo(state: DexState, keys: jax.Array, values: jax.Array):
        (new_pk, new_pc, new_pv, new_occ, new_alloc, new_versions, new_succ,
         new_stats, status) = sharded(
            state.pool, state.occupancy, state.n_alloc, state.versions,
            state.succ, state.stats,
            keys.astype(jnp.int64), values.astype(jnp.int64),
        )
        new_pool = state.pool._replace(
            pool_keys=new_pk, pool_children=new_pc, pool_values=new_pv
        )
        new_state = state._replace(
            pool=new_pool,
            occupancy=new_occ,
            n_alloc=new_alloc,
            versions=new_versions,
            succ=new_succ,
            stats=new_stats,
        )
        return new_state, status

    return smo


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------


def run_smo(
    smo,
    state: DexState,
    keys: np.ndarray,
    values: np.ndarray,
    *,
    max_rounds: "int | None" = None,
    levels: int = 2,
    obs=None,
):
    """Drive bounded SMO rounds until every live lane settles or the pending
    set stops shrinking (exhausted free-list / subtree-root split).

    ``keys``/``values`` keep the originating batch's lane layout with
    non-pending lanes set to ``KEY_MAX`` (exactly how ``make_dex_insert``
    hands back ``STATUS_SPLIT`` lanes) — on a multi-device mesh the width
    must stay divisible by the device count, and reusing the batch width
    avoids a fresh compile per distinct shed count.  Returns ``(state,
    status [B] int32, rounds_run)`` — lanes still ``STATUS_SPLIT`` need the
    host fallback (:func:`settle_splits` wires that up)."""
    keys = np.asarray(keys, np.int64)
    values = np.asarray(values, np.int64)
    if max_rounds is None:
        # a worst-case chain defers one level per round (the leaf waits for
        # its full parent's split, the parent for the grandparent's, ...)
        # and a leaf with > FANOUT pending keys re-splits once per round;
        # scale with both, bounded so a stuck batch still exits promptly
        max_rounds = 2 * levels + 6
    pending = keys != KEY_MAX
    status = np.full(keys.shape, STATUS_MISS, np.int32)
    rounds = 0

    def splits_done(st):
        return int(np.asarray(st.stats)[:, STAT_SMO_SPLITS].sum())

    from repro.obs.timeline import obs_phase

    while pending.any() and rounds < max_rounds:
        before = splits_done(state)
        # obs is an optional telemetry batch (repro/obs/timeline.py); each
        # SMO round is a separate fenced host phase in the trace
        with obs_phase(obs, f"smo/round{rounds}"):
            state, st_r = smo(
                state,
                jnp.asarray(np.where(pending, keys, KEY_MAX)),
                jnp.asarray(np.where(pending, values, 0)),
            )
            st_np = np.asarray(st_r)
        rounds += 1
        settled = pending & (st_np != STATUS_SPLIT)
        status[settled] = st_np[settled]
        still = pending & (st_np == STATUS_SPLIT)
        # progress = lanes settled OR structural splits executed (a round
        # that only split a full parent settles nothing but unblocks the
        # deferred leaves for the next round); neither -> host fallback
        if still.sum() >= pending.sum() and splits_done(state) <= before:
            pending = still
            break
        pending = still
    status[pending] = STATUS_SPLIT
    return state, status, rounds


def settle_splits(
    state: DexState,
    meta: PoolMeta,
    cfg: DexMeshConfig,
    smo,
    host,
    shed_keys: np.ndarray,
    shed_values: np.ndarray,
    boundaries: np.ndarray,
    *,
    max_rounds: "int | None" = None,
    obs=None,
):
    """Resolve one batch of ``STATUS_SPLIT`` lanes: bounded on-mesh SMO
    rounds first, host ``drain_splits`` rebuild only for the residue.

    ``host`` is the caller's :class:`HostBTree` mirror; lanes the SMO engine
    applies are replayed into it here (keeping the mirror the validation
    oracle), and the residue goes through the host's true eager-split path.
    Returns ``(state, meta, info)`` — ``meta`` changes only when the drain
    fallback rebuilt the pool (rebuild ops against it then), and ``info``
    reports ``{"onmesh": lanes applied device-side, "residual": lanes
    drained, "rounds": smo rounds run, "drained": bool}``."""
    shed_keys = np.asarray(shed_keys, np.int64)
    shed_values = np.asarray(shed_values, np.int64)
    if shed_keys.size == 0:
        return state, meta, {
            "onmesh": 0, "residual": 0, "rounds": 0, "drained": False,
        }
    from repro.obs.timeline import obs_phase

    state, status, rounds = run_smo(
        smo, state, shed_keys, shed_values,
        max_rounds=max_rounds, levels=meta.levels_in_subtree, obs=obs,
    )
    ok = status == STATUS_OK
    for kk, vv in zip(shed_keys[ok], shed_values[ok]):
        host.insert(int(kk), int(vv))
    residual = status == STATUS_SPLIT
    drained = bool(residual.any())
    if drained:
        with obs_phase(obs, "smo/drain"):
            state, meta = drain_splits(
                state, meta, cfg, host,
                shed_keys[residual], shed_values[residual], boundaries,
            )
    return state, meta, {
        "onmesh": int(ok.sum()),
        "residual": int(residual.sum()),
        "rounds": rounds,
        "drained": drained,
    }


# ---------------------------------------------------------------------------
# compressed-separator maintenance (core/pool.py SepPlanes)
# ---------------------------------------------------------------------------


def refresh_sep_planes(
    sep: SepPlanes,
    state: DexState,
    meta: PoolMeta,
    old_versions,
) -> SepPlanes:
    """Incrementally re-compress the separator planes after on-mesh SMO
    rounds: every row a split touched (the split node, its new sibling, the
    ancestors the separator merged into) got a ``DexState.versions`` bump,
    so the version delta against ``old_versions`` names exactly the rows to
    recompute from the canonical key plane — no full rebuild.  Rows the
    rounds never touched come back bit-identical.  After a
    ``drain_splits`` host rebuild the pool geometry itself changes; rebuild
    from scratch with :func:`repro.core.pool.compress_separators` instead.
    """
    vers = np.asarray(state.versions)
    old = np.asarray(old_versions)
    if vers.ndim == 2:
        vers = vers[0]
    if old.ndim == 2:
        old = old[0]
    changed = np.nonzero(vers != old)[0]
    if changed.size == 0:
        return sep
    cap = meta.subtree_cap
    s_idx = changed // cap
    l_idx = changed % cap
    pk = np.asarray(state.pool.pool_keys)
    prefix = np.asarray(sep.prefix).copy()
    nbits = np.asarray(sep.nbits).copy()
    suffix = np.asarray(sep.suffix).copy()
    p, nb, sf = compress_rows(pk[s_idx, l_idx])
    prefix[s_idx, l_idx] = p
    nbits[s_idx, l_idx] = nb
    suffix[s_idx, l_idx] = sf
    return SepPlanes(
        prefix=jnp.asarray(prefix),
        nbits=jnp.asarray(nbits),
        suffix=jnp.asarray(suffix),
    )
