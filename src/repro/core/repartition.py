"""Live skew-adaptive logical repartitioning for the mesh plane (paper §4,
Fig. 10).

The mesh ops (core/dex.py, core/scan.py, core/write.py) route every request
to the compute partition owning its key and *load-shed* whatever overflows a
routing bucket — honest back-pressure, but a dead end under sustained skew:
the shed lanes retry into the same overloaded partition forever.  The
paper's systemic fix is logical repartitioning: the boundary table is
metadata, so moving boundaries toward the load costs one table update plus a
dirty-cache flush (< 2 s, Fig. 10), never a data move.

:class:`RepartitionController` closes that loop between batches:

1. **Accumulate** per-partition load from the ops' counters.  The primary
   signal is ``DexState.route_demand`` — routed requests per partition
   counted at the *source* chip before bucketing, so shed lanes count too
   and the signal never saturates at bucket capacity the way the served
   ``STAT_OPS`` does; ``STAT_DROPS`` (summed over the route-major device
   grid) feeds the trigger.  The controller also tracks the observed key
   hull (min/max routed key) so the rebalance walk stays inside real key
   space.
2. **Decide**: when the max/mean served-load imbalance crosses
   ``imbalance_threshold`` (or drops exceed ``drop_frac`` of ops) after at
   least ``min_ops`` accumulated, call the fixed
   :meth:`LogicalPartitions.rebalance` — count-preserving, hull-confined —
   for a new boundary table.
3. **Install** (:func:`install_boundaries`): swap the replicated boundary
   table inside :class:`DexState` (all ops read it per batch, so the next
   batch routes under the new table with no recompilation), bump the
   per-node version table for every pool node whose key range changed
   owner — the existing ``DexState.versions`` coherence machinery then
   rejects now-foreign cached rows on their next probe, exactly like a
   write-invalidate — and re-derive which nodes are *shared* (fence range
   crossing a boundary: cached everywhere, never owner-private) under the
   new table.

Because repartitioning is logical, the memory-side pool, occupancy and the
host mirror are untouched; results before and after a boundary change are
bit-identical (tests/mesh_check.py exercises the round trip).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import fleet_cache
from repro.core.dex import N_STATS, STAT_DROPS, STAT_OPS, DexState
from repro.core.nodes import KEY_MAX, KEY_MIN
from repro.core.partition import LogicalPartitions
from repro.core.pool import PoolMeta


@dataclasses.dataclass(frozen=True)
class RepartitionConfig:
    """Trigger policy for the controller."""

    imbalance_threshold: float = 1.25  # max/mean demand ratio
    drop_frac: float = 0.01            # drops / ops that force a trigger
    min_ops: int = 1024                # accumulate at least this many ops
    cooldown_batches: int = 1          # maybe_repartition() decisions to
    #                                    skip after an install


@dataclasses.dataclass
class RepartitionReport:
    """What one boundary install did (returned by ``maybe_repartition``)."""

    old_boundaries: np.ndarray
    new_boundaries: np.ndarray
    loads: np.ndarray                  # per-partition served ops this window
    drops: int                         # routing-bucket drops this window
    imbalance: float                   # max/mean of ``loads``
    fraction_keyspace_moved: float     # LogicalPartitions.assignment_diff
    nodes_invalidated: int             # pool nodes whose version was bumped
    shared_nodes_before: int           # boundary-crossing nodes, old table
    shared_nodes_after: int            # boundary-crossing nodes, new table


def node_key_ranges(
    pool_keys: np.ndarray, meta: PoolMeta,
    pool_children: "np.ndarray | None" = None,
    *,
    with_levels: bool = False,
):
    """Per-node fence ranges ``(gids, lo, hi)`` for every real pool node.

    Each node's range runs from its first key to the next node's first key
    at the same level (the leftmost node of a level covers from ``KEY_MIN``
    — the in-node search clamps slot 0 — and the rightmost to ``KEY_MAX``).
    Node levels are derived by walking the children graph from each block's
    root rather than from local-id offsets: on-mesh splits (core/smo.py)
    allocate siblings from the free-list headroom, so after the first split
    a node's level is no longer a function of its slot.  Pass
    ``pool_children`` whenever the pool may have seen on-mesh splits; when
    omitted, the dense bulk layout is assumed (bulk-built pools only).
    With ``with_levels=True`` a fourth array of per-node tree levels (0 =
    leaf) is returned — the leaf-direct route-table trainer
    (core/route_table.py) uses it to keep only leaf fence ranges.
    """
    pk0 = np.asarray(pool_keys[:, :, 0])              # [S, C] first keys
    n_sub, cap = pk0.shape
    lvl_of = np.full((n_sub, cap), -1, np.int32)
    lvl_of[:, 0] = meta.level_m                       # block roots
    if pool_children is not None:
        pc = np.asarray(pool_children)
        for lvl in range(meta.level_m, 0, -1):
            s_idx, c_idx = np.where(lvl_of == lvl)
            if s_idx.size == 0:
                break
            ch = pc[s_idx, c_idx]                     # [K, FANOUT]
            valid = (ch >= 0) & (ch < cap)
            s_rep = np.broadcast_to(s_idx[:, None], ch.shape)[valid]
            lvl_of[s_rep, ch[valid]] = lvl - 1
    else:
        from repro.core.pool import _level_offsets

        offs = _level_offsets(
            meta.per_node, meta.level_m, meta.leaves_per_subtree
        )
        for lvl in range(meta.level_m + 1):
            lvl_of[:, int(offs[lvl]) : int(offs[lvl + 1])] = (
                meta.level_m - lvl
            )
    base = np.arange(n_sub, dtype=np.int64) * meta.subtree_cap
    gid_grid = base[:, None] + np.arange(cap, dtype=np.int64)[None, :]
    all_gids: List[np.ndarray] = []
    all_lo: List[np.ndarray] = []
    all_hi: List[np.ndarray] = []
    all_lvl: List[np.ndarray] = []
    for lvl in range(meta.level_m, -1, -1):
        real = (lvl_of == lvl) & (pk0 != KEY_MAX)
        lo_r = pk0[real]
        gid_r = gid_grid[real]
        # global key order within the level: subtrees are key-ordered and
        # ranges within a level are disjoint, so first-key order is it
        order = np.argsort(lo_r, kind="stable")
        lo_r = lo_r[order]
        gid_r = gid_r[order]
        if lo_r.size:
            hi_r = np.concatenate([lo_r[1:], [KEY_MAX]])
            lo_r = lo_r.copy()
            lo_r[0] = KEY_MIN
        else:
            hi_r = np.zeros((0,), np.int64)
        all_gids.append(gid_r)
        all_lo.append(lo_r)
        all_hi.append(hi_r)
        all_lvl.append(np.full(gid_r.shape, lvl, np.int32))
    out = (
        np.concatenate(all_gids),
        np.concatenate(all_lo),
        np.concatenate(all_hi),
    )
    if with_levels:
        return out + (np.concatenate(all_lvl),)
    return out


def moved_intervals(
    old: LogicalPartitions, new: LogicalPartitions
) -> List[Tuple[int, int]]:
    """Key intervals ``[a, b)`` whose owning partition changes between the
    two tables (ownership is piecewise constant on the merged boundaries)."""
    pts = np.unique(
        np.concatenate([old.boundaries, new.boundaries]).astype(np.int64)
    )
    starts = pts[:-1]
    changed = old.owner_of(starts) != new.owner_of(starts)
    out: List[Tuple[int, int]] = []
    for i in np.where(changed)[0]:
        a, b = int(pts[i]), int(pts[i + 1])
        if out and out[-1][1] == a:
            out[-1] = (out[-1][0], b)      # coalesce adjacent intervals
        else:
            out.append((a, b))
    return out


def install_boundaries(
    state: DexState,
    meta: PoolMeta,
    old: LogicalPartitions,
    new: LogicalPartitions,
) -> Tuple[DexState, int, int, int]:
    """Install ``new`` boundaries into ``state`` (logical repartitioning).

    Swaps the replicated boundary table and bumps ``DexState.versions`` for
    every pool node whose fence range intersects a moved key interval, so
    each chip's cached copy of a now-foreign (or newly-owned) row fails the
    version check on its next probe and is re-fetched — the mesh analogue of
    the paper's dirty-flush + cache re-warm.  The pool itself never moves.
    Returns ``(new_state, nodes_invalidated, shared_before, shared_after)``.
    """
    gids, lo, hi = node_key_ranges(
        state.pool.pool_keys, meta, state.pool.pool_children
    )
    moved = moved_intervals(old, new)
    affected = np.zeros(gids.shape, dtype=bool)
    for a, b in moved:
        affected |= (lo < b) & (hi > a)
    shared_before = int(np.sum(np.asarray(old.is_shared_range(lo, hi))))
    shared_after = int(np.sum(np.asarray(new.is_shared_range(lo, hi))))
    new_state = state._replace(
        boundaries=jnp.asarray(new.boundaries, jnp.int64),
        versions=fleet_cache.invalidate_nodes(
            state.versions, gids[affected]
        ),
    )
    return new_state, int(affected.sum()), shared_before, shared_after


class RepartitionController:
    """Between-batch control loop turning load shedding into repartitioning.

    Usage (see ``benchmarks/fig10_mesh_repartition.py``)::

        ctl = RepartitionController(parts, n_memory=cfg.n_memory)
        for batch in trace:
            state, ... = op(state, batch_keys, ...)
            ctl.observe(np.asarray(state.stats), batch_keys)
            state, report = ctl.maybe_repartition(state, meta)
            # report is None unless boundaries moved this batch

    The controller never touches device state except through
    :func:`install_boundaries`, and survives ``drain_splits`` pool rebuilds
    (stats carry over; node ranges are re-derived from the current pool at
    install time).
    """

    def __init__(
        self,
        parts: LogicalPartitions,
        *,
        n_memory: int,
        cfg: Optional[RepartitionConfig] = None,
    ):
        self.parts = parts
        self.n_memory = int(n_memory)
        self.cfg = cfg or RepartitionConfig()
        self._last_stats: Optional[np.ndarray] = None
        self._last_demand: Optional[np.ndarray] = None
        self._loads = np.zeros((parts.num_partitions,), np.float64)
        self._drops = 0
        self._ops = 0
        self._cooldown = 0
        self._key_lo: Optional[int] = None
        self._key_hi: Optional[int] = None
        self.reports: List[RepartitionReport] = []

    # -- accumulation --------------------------------------------------------

    def observe(
        self,
        stats: np.ndarray,
        keys: Optional[np.ndarray] = None,
        demand: Optional[np.ndarray] = None,
    ):
        """Fold one batch's cumulative counters into the window.

        ``stats`` is ``DexState.stats`` (``[Dev, N_STATS]``); ``demand`` is
        ``DexState.route_demand`` (``[Dev, n_route]``), the preferred load
        signal — without it the controller falls back to the served
        ``STAT_OPS``, which saturates at bucket capacity under heavy skew.
        ``keys`` (the batch's routed keys) tightens the key hull used to
        confine the rebalance walk — always pass it when available: without
        an observed hull a two-partition table has no data-extent
        information at all and its boundary barely moves (see
        :meth:`LogicalPartitions.rebalance`).
        """
        stats = np.asarray(stats, dtype=np.int64)
        assert stats.ndim == 2 and stats.shape[1] == N_STATS
        if self._last_stats is None:
            delta = stats
        else:
            delta = stats - self._last_stats
        self._last_stats = stats.copy()
        n_route = self.parts.num_partitions
        per_dev = delta.reshape(n_route, self.n_memory, N_STATS)
        if demand is not None:
            demand = np.asarray(demand, dtype=np.int64)
            prev = (
                self._last_demand
                if self._last_demand is not None
                else np.zeros_like(demand)
            )
            d_delta = demand - prev
            self._last_demand = demand.copy()
            self._loads += d_delta.sum(axis=0).astype(np.float64)
            # gate the window on demand, not served ops: under heavy skew
            # the served count loses exactly the dropped lanes whose load
            # signal we are here to act on
            self._ops += int(d_delta.sum())
        else:
            self._loads += per_dev[:, :, STAT_OPS].sum(axis=1).astype(
                np.float64
            )
            self._ops += int(per_dev[:, :, STAT_OPS].sum())
        self._drops += int(per_dev[:, :, STAT_DROPS].sum())
        if keys is not None:
            keys = np.asarray(keys, dtype=np.int64)
            keys = keys[keys != KEY_MAX]                 # inactive lanes
            if keys.size:
                lo, hi = int(keys.min()), int(keys.max())
                self._key_lo = lo if self._key_lo is None else min(self._key_lo, lo)
                self._key_hi = hi if self._key_hi is None else max(self._key_hi, hi)

    @property
    def imbalance(self) -> float:
        """Max/mean served-load ratio of the current window."""
        if self._loads.sum() <= 0:
            return 1.0
        return float(self._loads.max() / self._loads.mean())

    def should_repartition(self) -> bool:
        if self._cooldown > 0 or self._ops < self.cfg.min_ops:
            return False
        if self.imbalance >= self.cfg.imbalance_threshold:
            return True
        return self._drops > self.cfg.drop_frac * max(self._ops, 1)

    # -- the decision + install ---------------------------------------------

    def propose(self) -> LogicalPartitions:
        """New boundary table for the accumulated window's loads."""
        key_range = (
            (self._key_lo, self._key_hi)
            if self._key_lo is not None and self._key_lo < self._key_hi
            else None
        )
        return self.parts.rebalance(self._loads, key_range=key_range)

    def maybe_repartition(
        self, state: DexState, meta: PoolMeta, *, obs=None
    ) -> Tuple[DexState, Optional[RepartitionReport]]:
        """Repartition if the trigger fires; returns the (possibly new)
        state and a report when boundaries actually moved.  The first
        ``cooldown_batches`` calls after an install are skipped (and spend
        the cooldown), so ``cooldown_batches=1`` skips exactly one
        decision.  ``obs`` is an optional telemetry batch
        (repro/obs/timeline.py); the boundary install becomes a fenced
        phase in the trace."""
        if self._cooldown > 0:
            self._cooldown -= 1
            return state, None
        if not self.should_repartition():
            return state, None
        new_parts = self.propose()
        if np.array_equal(new_parts.boundaries, self.parts.boundaries):
            self._reset_window()
            return state, None
        from repro.obs.timeline import obs_phase

        with obs_phase(obs, "repartition/install") as _ph:
            new_state, n_inval, sh_before, sh_after = install_boundaries(
                state, meta, self.parts, new_parts
            )
            # a boundary install bumps versions for every moved node, which
            # already fences off the leaf-direct route table's stale entries
            # (correctness); retraining here restores the *performance* of
            # the fast path under the new ownership without a separate
            # controller (DESIGN.md §13)
            from repro.core import route_table as _route_table

            if _route_table.route_table_active(new_state):
                new_state = _route_table.train_route_table(new_state, meta)
            if _ph is not None and hasattr(_ph, "fence"):
                _ph.fence(new_state.boundaries)
        report = RepartitionReport(
            old_boundaries=self.parts.boundaries.copy(),
            new_boundaries=new_parts.boundaries.copy(),
            loads=self._loads.copy(),
            drops=self._drops,
            imbalance=self.imbalance,
            fraction_keyspace_moved=self.parts.assignment_diff(new_parts),
            nodes_invalidated=n_inval,
            shared_nodes_before=sh_before,
            shared_nodes_after=sh_after,
        )
        self.reports.append(report)
        self.parts = new_parts
        self._reset_window()
        self._cooldown = self.cfg.cooldown_batches
        return new_state, report

    def _reset_window(self) -> None:
        self._loads = np.zeros_like(self._loads)
        self._drops = 0
        self._ops = 0
