"""Batched writes on the TPU mesh (Plane B): the paper's update/insert
protocols (§7) as SPMD collectives.

Two operations share the unified mixed-op engine's dataflow
(:mod:`repro.core.engine`: one route round, one version-checked cached
descent, one fused tagged ``all_to_all`` round); this module holds the thin
single-opcode builders, the owner-side apply (``_apply_leaf_writes``,
called from the engine's fused round) and the host-side SMO drain:

* ``make_dex_update`` — in-place value overwrite.  The engine routes each
  ``(key, value)`` to the partition owning the key, descends through the
  per-chip cache to the target leaf, and ships a tagged ``(leaf_gid, key,
  value, prio)`` record in the fused round.  The owning memory column
  applies it CAS-style — the authoritative leaf row is re-searched at
  apply time and the write lands at the key's current slot — conflicting
  writers of one key are resolved by batch priority (updates replay before
  inserts, last-in-batch wins within a phase, matching sequential replay),
  and the response carries the leaf's merged post-batch value row.
* ``make_dex_insert`` — append into leaf slack slots.  Same engine descent
  (inner levels only); the owning memory column groups incoming keys by
  target leaf, converts duplicates of existing keys into value updates, and
  merges fresh keys into the leaf's slack via the ``leaf_write`` Pallas
  kernel, bumping the per-leaf occupancy array.  **Leaves that would
  overflow are shed**: none of their staged inserts apply, the lanes come
  back with status ``STATUS_SPLIT`` and are counted in ``STAT_SPLITS`` —
  mirroring the scan subsystem's load-shed discipline — and the caller
  replays them through the on-mesh SMO engine or the host tree's true
  structural-modification path between batches (:func:`drain_splits`).
  This replaces the paper's latch-based SMOs: an SPMD batch cannot take
  per-node latches, but it can refuse the structural change and let the
  SMO ladder replay it.

When a key's destination column's cost group picks the two-sided path
(core/engine.py §6.1 refinement), the same records travel as *offloaded*
tags: the owner walks its own block to the leaf first, then applies the
identical CAS/merge — and an offloaded insert that would split sheds
``STATUS_SPLIT`` exactly like a fetched-path one (the paper's rule that
offloaded writes fall back to the normal path for SMOs).

Cache coherence is **write-through-and-invalidate** with per-leaf versions:
the writing chip refreshes (update) or drops (insert) its *own* cached row
and bumps the leaf's entry in the replicated per-node version table
(``DexState.versions``, pmax-synchronized across the mesh each batch), so
*other* chips' stale rows fail the version check inside ``_cache_probe`` on
their next hit and are re-fetched.

Replica consistency: the pool shards only over the memory axis, so devices
along the route axes hold replicas of each memory column.  The write round
all-gathers the request buffers across the route axes
(:func:`repro.core.routing.gather_route`) so every replica applies the
identical batch.

Result status codes (per lane): ``STATUS_OK`` applied; ``STATUS_MISS``
no-op (update of an absent key / inactive lane); ``STATUS_SHED`` load-shed
by a routing bucket (retryable, counted in ``STAT_DROPS``);
``STATUS_SPLIT`` insert shed to the host SMO path (feed to
:func:`drain_splits`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dex import (
    STAT_DRAINS,
    DexMeshConfig,
    DexState,
    init_state,
)
from repro.core.nodes import FANOUT, KEY_MAX
from repro.core.pool import PoolMeta, build_pool
from repro.kernels.leaf_write import leaf_write
from repro.kernels.ref import leaf_write_ref

STATUS_MISS = 0    # update of an absent key / inactive lane: no-op
STATUS_OK = 1      # write applied by the owning memory column
STATUS_SPLIT = 2   # insert shed to the host SMO path (drain_splits)
STATUS_SHED = -1   # routing-bucket load shed; retry (STAT_DROPS)


def _seg_positions(mask: jax.Array, new_seg: jax.Array) -> jax.Array:
    """Rank of each ``mask``-lane within its segment (segments are runs
    delimited by ``new_seg`` over a sorted lane order)."""
    inc = mask.astype(jnp.int32)
    c = jnp.cumsum(inc)
    base = jax.lax.cummax(jnp.where(new_seg, c - inc, 0), axis=0)
    return c - inc - base


def _apply_leaf_writes(
    pool_keys: jax.Array,    # [S_local, C, F] this memory column's shard
    pool_values: jax.Array,  # [S_local, C, F]
    occupancy: jax.Array,    # [S_local, C]
    meta: PoolMeta,
    cfg: DexMeshConfig,
    gid: jax.Array,          # [N] int64 leaf gids (KEY_MAX = inactive lane)
    key: jax.Array,          # [N] int64
    value: jax.Array,        # [N] int64
    prio: jax.Array,         # [N] int64 globally unique batch priority
    allow_insert: jax.Array,  # [N] bool: absent keys may claim a slack slot
    *,
    use_kernel: bool,
    interpret: bool,
):
    """Apply one flat *mixed* batch of leaf-write requests to the local pool
    shard.  A lane whose key already sits in the leaf becomes an in-place
    value write (CAS-style: the authoritative row is re-searched at apply
    time); an absent key claims a slack slot when ``allow_insert`` (insert
    lanes — fetched-path and offloaded alike) and is a ``STATUS_MISS``
    no-op otherwise (update of an absent key).

    Every route-replica of this memory column calls this with identical
    inputs (see ``gather_route``), so the replicas stay consistent.  Returns
    ``(new_pool_keys, new_pool_values, new_occupancy, status [N] int32,
    rows_v_out [N, F] post-batch value rows, ins_in_leaf [N] bool)`` —
    ``ins_in_leaf`` marks lanes whose target leaf took at least one fresh
    insert this batch (its key set shifted, so an updater's cached copy
    must NOT be version-refreshed in place: the keys plane it holds is
    stale even though the response's value row is authoritative).
    """
    n = gid.shape[0]
    s_per = meta.n_subtrees_padded // cfg.n_memory
    valid = gid != KEY_MAX
    st = jnp.where(valid, (gid // meta.subtree_cap) % s_per, 0).astype(jnp.int32)
    lo = jnp.where(valid, gid % meta.subtree_cap, 0).astype(jnp.int32)
    row_k0 = pool_keys[st, lo]                              # [N, F] pre-batch

    eqk = row_k0 == key[:, None]
    exists = jnp.any(eqk, axis=-1) & valid
    slot32 = jnp.argmax(eqk, axis=-1).astype(jnp.int32)
    live = valid & (exists | allow_insert)
    is_upd = exists  # staged as in-place value write (vs slack-slot insert)

    # ---- conflict resolution: sort by (gid, key, prio); the last writer of
    # each (gid, key) run wins, everything else is superseded (still counts
    # as applied — sequential replay would have applied then overwritten it)
    route_gid = jnp.where(live, gid, KEY_MAX)
    order = jnp.lexsort((prio, key, route_gid))
    g_s = route_gid[order]
    k_s = key[order]
    live_s = live[order]
    diff = (g_s[1:] != g_s[:-1]) | (k_s[1:] != k_s[:-1])
    new_run = jnp.concatenate([jnp.ones((1,), bool), diff])
    run_id = jnp.cumsum(new_run) - 1
    winner = jnp.concatenate([diff, jnp.ones((1,), bool)]) & live_s

    # ---- segments: one per distinct target leaf ---------------------------
    new_seg = jnp.concatenate([jnp.ones((1,), bool), g_s[1:] != g_s[:-1]])
    seg_id = jnp.cumsum(new_seg) - 1
    st_s = st[order]
    lo_s = lo[order]
    seg_st = (
        jnp.zeros((n,), jnp.int32).at[seg_id].max(jnp.where(live_s, st_s, 0))
    )
    seg_lo = (
        jnp.zeros((n,), jnp.int32).at[seg_id].max(jnp.where(live_s, lo_s, 0))
    )

    upd_w = winner & is_upd[order]
    ins_w = winner & live_s & ~is_upd[order]                # insert mode only
    # ---- overflow check: leaves whose fresh keys exceed the slack are shed
    occ_lane = occupancy[st_s, lo_s]                        # [N]
    n_new_seg = (
        jnp.zeros((n,), jnp.int32).at[seg_id].add(ins_w.astype(jnp.int32))
    )
    over_lane = (occ_lane + n_new_seg[seg_id]) > FANOUT
    ins_apply = ins_w & ~over_lane
    upd_apply = upd_w  # in-place updates apply even when the leaf overflows

    # ---- staged write matrices, one row per segment -----------------------
    s_width = FANOUT
    pos_u = _seg_positions(upd_apply, new_seg)
    pos_i = _seg_positions(ins_apply, new_seg)
    v_s = value[order]
    slot_ss = slot32[order]
    ur = jnp.where(upd_apply, seg_id, n)
    uc = jnp.where(upd_apply, pos_u, s_width)
    upd_slot_st = (
        jnp.full((n, s_width), -1, jnp.int32)
        .at[ur, uc].set(slot_ss, mode="drop")
    )
    upd_val_st = (
        jnp.zeros((n, s_width), jnp.int64).at[ur, uc].set(v_s, mode="drop")
    )
    ir = jnp.where(ins_apply, seg_id, n)
    ic = jnp.where(ins_apply, pos_i, s_width)
    ins_key_st = (
        jnp.full((n, s_width), KEY_MAX, jnp.int64)
        .at[ir, ic].set(k_s, mode="drop")
    )
    ins_val_st = (
        jnp.zeros((n, s_width), jnp.int64).at[ir, ic].set(v_s, mode="drop")
    )

    # ---- the masked scatter + merge itself (Pallas kernel or oracle) ------
    rows_k = pool_keys[seg_st, seg_lo]
    rows_v = pool_values[seg_st, seg_lo]
    writer = leaf_write if use_kernel else leaf_write_ref
    kw = {"interpret": interpret} if use_kernel else {}
    new_k, new_v, new_occ = writer(
        rows_k, rows_v, upd_slot_st, upd_val_st, ins_key_st, ins_val_st, **kw
    )

    seg_active = (
        jnp.zeros((n,), bool).at[seg_id].max(upd_apply | ins_apply)
    )
    w_st = jnp.where(seg_active, seg_st, pool_keys.shape[0])  # OOB drop
    out_pk = pool_keys.at[w_st, seg_lo].set(new_k, mode="drop")
    out_pv = pool_values.at[w_st, seg_lo].set(new_v, mode="drop")
    out_occ = occupancy.at[w_st, seg_lo].set(new_occ, mode="drop")

    # ---- per-lane status: every lane inherits its (gid, key) winner's fate
    outcome_w = jnp.where(
        upd_apply | ins_apply,
        STATUS_OK,
        jnp.where(ins_w & over_lane, STATUS_SPLIT, STATUS_MISS),
    ).astype(jnp.int32)
    run_out = (
        jnp.zeros((n,), jnp.int32)
        .at[run_id].max(jnp.where(winner, outcome_w, 0))
    )
    status_s = jnp.where(live_s, run_out[run_id], STATUS_MISS)
    status = jnp.zeros((n,), jnp.int32).at[order].set(status_s)

    rows_v_out = out_pv[st, lo]                             # post-batch rows
    # per-lane: did the lane's target leaf take any fresh insert this batch?
    seg_ins = jnp.zeros((n,), bool).at[seg_id].max(ins_apply)
    ins_lane_s = jnp.where(live_s, seg_ins[seg_id], False)
    ins_in_leaf = jnp.zeros((n,), bool).at[order].set(ins_lane_s)
    return out_pk, out_pv, out_occ, status, rows_v_out, ins_in_leaf


def make_dex_update(meta, cfg, mesh, *, use_kernel=True, interpret=None):
    """Build the sharded in-place update:
    ``(state, keys, values) -> (state, status)``.

    A thin single-opcode wrapper over the unified mixed-op engine
    (:func:`repro.core.engine.make_dex_engine`): route + cached descent are
    shared machinery, and the CAS-style write records travel as tagged
    messages in the engine's one fused request/response ``all_to_all``
    round (offloaded when the key's column's cost group picks the
    two-sided path).  ``keys``/``values`` are [B] globally sharded over all
    mesh axes; ``status`` comes back in the caller's lane order
    (``STATUS_OK`` / ``STATUS_MISS`` / ``STATUS_SHED``).  ``keys ==
    KEY_MAX`` lanes are inactive no-ops (useful for op-type-masked mixed
    batches).  Wrap with ``jax.jit``."""
    from repro.core import engine as engine_mod  # deferred: engine imports us

    eng = engine_mod.make_dex_engine(
        meta, cfg, mesh, ops=("update",),
        use_kernel=use_kernel, interpret=interpret,
    )

    def update(state, keys, values):
        keys = keys.astype(jnp.int64)
        opcodes = jnp.full(keys.shape, engine_mod.OP_UPDATE, jnp.int32)
        new_state, r = eng(state, opcodes, keys, values.astype(jnp.int64))
        return new_state, r.status

    return update


def make_dex_insert(meta, cfg, mesh, *, use_kernel=True, interpret=None):
    """Build the sharded insert: ``(state, keys, values) -> (state, status)``.

    A thin single-opcode wrapper over the unified mixed-op engine (see
    :func:`make_dex_update`).  Fresh keys append into their leaf's slack
    slots (occupancy-tracked); keys that already exist become value
    updates; leaves that would overflow shed their inserts with
    ``STATUS_SPLIT`` (counted in ``STAT_SPLITS``) — resolve them with
    :func:`repro.core.smo.settle_splits` (or :func:`drain_splits`) between
    batches; offloaded inserts that would split shed exactly the same way
    (the paper's SMO fallback rule).  ``keys == KEY_MAX`` lanes are
    inactive no-ops.  Wrap with ``jax.jit``."""
    from repro.core import engine as engine_mod  # deferred: engine imports us

    eng = engine_mod.make_dex_engine(
        meta, cfg, mesh, ops=("insert",),
        use_kernel=use_kernel, interpret=interpret,
    )

    def insert(state, keys, values):
        keys = keys.astype(jnp.int64)
        opcodes = jnp.full(keys.shape, engine_mod.OP_INSERT, jnp.int32)
        new_state, r = eng(state, opcodes, keys, values.astype(jnp.int64))
        return new_state, r.status

    return insert


# ---------------------------------------------------------------------------
# Host-side split replay (the SMO path)
# ---------------------------------------------------------------------------


def host_items(host) -> "tuple[np.ndarray, np.ndarray]":
    """All (key, value) pairs of a :class:`repro.core.sim.HostBTree` in
    sorted key order."""
    lv = np.asarray(host.LV)
    nk = np.asarray(host.NK)
    keys, vals = [], []
    for nid in np.where(lv == 0)[0]:
        m = int(nk[nid])
        keys.append(np.asarray(host.K[nid, :m]))
        vals.append(np.asarray(host.V[nid, :m]))
    k = np.concatenate(keys) if keys else np.zeros((0,), np.int64)
    v = np.concatenate(vals) if vals else np.zeros((0,), np.int64)
    order = np.argsort(k, kind="stable")
    return k[order], v[order]


def drain_splits(
    state: DexState,
    meta: PoolMeta,
    cfg: DexMeshConfig,
    host,
    shed_keys: np.ndarray,
    shed_values: np.ndarray,
    boundaries: np.ndarray,
):
    """Replay shed inserts through the host tree's true eager-split SMO path
    and rebuild the mesh state from the result — the *bottom rung* of the
    SMO fallback ladder (core/smo.py resolves plain leaf splits device-side;
    this path remains for subtree-block overflow, exhausted free-lists and
    top-tree growth, and stays the validation oracle).

    ``host`` is the :class:`repro.core.sim.HostBTree` mirror the caller
    keeps in sync (it must already contain every *applied* mesh write);
    ``shed_keys``/``shed_values`` are the lanes that came back with
    ``STATUS_SPLIT``, in original batch order.  Returns ``(new_state,
    new_meta)`` — a freshly blocked pool (splits change the leaf layout, so
    caches/versions restart cold; accumulated stats carry over, and the
    rebuild is counted in ``STAT_DRAINS`` so benchmarks can report fallback
    frequency).  Ops built by ``make_dex_*`` must be rebuilt against
    ``new_meta``.  With no shed lanes this is a **no-op**: the existing
    state is returned untouched — no rebuild, no cache/version cold
    restart, no drain counted.
    """
    shed_keys = np.asarray(shed_keys)
    shed_values = np.asarray(shed_values)
    if shed_keys.size == 0:
        return state, meta
    for k, v in zip(shed_keys, shed_values):
        host.insert(int(k), int(v))
    items_k, items_v = host_items(host)
    pool, new_meta = build_pool(
        items_k, items_v,
        level_m=meta.level_m,
        fill=meta.per_node / FANOUT,
        n_shards=cfg.n_memory,
        headroom=meta.headroom_frac,
        subtree_leaves=meta.leaves_per_subtree,
    )
    new_state = init_state(pool, new_meta, cfg, boundaries)
    # accumulated stats and the controller's demand counters carry over
    # (their shapes don't depend on the pool layout); the rebuild itself is
    # counted so callers can report how often the fallback fired
    stats = jnp.asarray(state.stats).at[0, STAT_DRAINS].add(1)
    return new_state._replace(
        stats=stats, route_demand=state.route_demand
    ), new_meta
