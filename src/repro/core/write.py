"""Batched writes on the TPU mesh (Plane B): the paper's update/insert
protocols (§7) as SPMD collectives.

Two operations share one dataflow skeleton with the lookup/scan descent
(core/routing.py, ``cached_fetch_level``):

* ``make_dex_update`` — in-place value overwrite.  Route each ``(key,
  value)`` to the partition owning the key, descend through the per-chip
  cache to the target leaf, then issue **one request/response all_to_all
  round over the memory axis** carrying ``(leaf_gid, slot, key, value,
  prio)`` records.  The owning memory column applies them CAS-style: the
  write lands only if ``key`` still sits at ``slot`` (the RDMA-CAS
  analogue), conflicting writers to one slot are resolved by batch priority
  (last-in-batch wins, matching sequential replay), and the response carries
  the leaf's merged post-batch value row.
* ``make_dex_insert`` — append into leaf slack slots.  Same route + descent
  (inner levels only); the owning memory column groups incoming keys by
  target leaf, converts duplicates of existing keys into value updates, and
  merges fresh keys into the leaf's slack via the ``leaf_write`` Pallas
  kernel, bumping the per-leaf occupancy array.  **Leaves that would
  overflow are shed**: none of their staged inserts apply, the lanes come
  back with status ``STATUS_SPLIT`` and are counted in ``STAT_SPLITS`` —
  mirroring the scan subsystem's load-shed discipline — and the caller
  replays them through the host tree's true structural-modification path
  between batches (:func:`drain_splits`).  This replaces the paper's
  latch-based SMOs: an SPMD batch cannot take per-node latches, but it can
  refuse the structural change and let the host replay it.

Cache coherence is **write-through-and-invalidate** with per-leaf versions:
the writing chip refreshes (update) or drops (insert) its *own* cached row
and bumps the leaf's entry in the replicated per-node version table
(``DexState.versions``, pmax-synchronized across the mesh each batch), so
*other* chips' stale rows fail the version check inside ``_cache_probe`` on
their next hit and are re-fetched.

Replica consistency: the pool shards only over the memory axis, so devices
along the route axes hold replicas of each memory column.  The write round
all-gathers the request buffers across the route axes
(:func:`repro.core.routing.gather_route`) so every replica applies the
identical batch.

Result status codes (per lane): ``STATUS_OK`` applied; ``STATUS_MISS``
no-op (update of an absent key / inactive lane); ``STATUS_SHED`` load-shed
by a routing bucket (retryable, counted in ``STAT_DROPS``);
``STATUS_SPLIT`` insert shed to the host SMO path (feed to
:func:`drain_splits`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import routing
from repro.core.dex import (
    N_STATS,
    STAT_DRAINS,
    STAT_DROPS,
    STAT_FETCHES,
    STAT_HITS,
    STAT_OPS,
    STAT_SPLITS,
    STAT_WRITES,
    DexCache,
    DexMeshConfig,
    DexState,
    cached_fetch_level,
    init_state,
)
from repro.core.nodes import FANOUT, KEY_MAX
from repro.core.pool import PoolMeta, SubtreePool, build_pool, top_walk
from repro.kernels.leaf_write import leaf_write
from repro.kernels.ops import use_interpret
from repro.kernels.ref import leaf_write_ref

STATUS_MISS = 0    # update of an absent key / inactive lane: no-op
STATUS_OK = 1      # write applied by the owning memory column
STATUS_SPLIT = 2   # insert shed to the host SMO path (drain_splits)
STATUS_SHED = -1   # routing-bucket load shed; retry (STAT_DROPS)


def _seg_positions(mask: jax.Array, new_seg: jax.Array) -> jax.Array:
    """Rank of each ``mask``-lane within its segment (segments are runs
    delimited by ``new_seg`` over a sorted lane order)."""
    inc = mask.astype(jnp.int32)
    c = jnp.cumsum(inc)
    base = jax.lax.cummax(jnp.where(new_seg, c - inc, 0), axis=0)
    return c - inc - base


def _apply_leaf_writes(
    pool_keys: jax.Array,    # [S_local, C, F] this memory column's shard
    pool_values: jax.Array,  # [S_local, C, F]
    occupancy: jax.Array,    # [S_local, C]
    meta: PoolMeta,
    cfg: DexMeshConfig,
    gid: jax.Array,          # [N] int64 leaf gids (KEY_MAX = inactive lane)
    slot: jax.Array,         # [N] int64 claimed slot (update mode only)
    key: jax.Array,          # [N] int64
    value: jax.Array,        # [N] int64
    prio: jax.Array,         # [N] int64 globally unique batch priority
    *,
    is_insert: bool,
    use_kernel: bool,
    interpret: bool,
):
    """Apply one flat batch of leaf-write requests to the local pool shard.

    Every route-replica of this memory column calls this with identical
    inputs (see ``gather_route``), so the replicas stay consistent.  Returns
    ``(new_pool_keys, new_pool_values, new_occupancy, status [N] int32,
    rows_v_out [N, F] post-batch value rows)``.
    """
    n = gid.shape[0]
    s_per = meta.n_subtrees_padded // cfg.n_memory
    valid = gid != KEY_MAX
    st = jnp.where(valid, (gid // meta.subtree_cap) % s_per, 0).astype(jnp.int32)
    lo = jnp.where(valid, gid % meta.subtree_cap, 0).astype(jnp.int32)
    row_k0 = pool_keys[st, lo]                              # [N, F] pre-batch

    if is_insert:
        eqk = row_k0 == key[:, None]
        exists = jnp.any(eqk, axis=-1) & valid
        slot32 = jnp.argmax(eqk, axis=-1).astype(jnp.int32)
        live = valid
    else:
        # CAS: the key must still sit at the claimed slot
        slot32 = jnp.clip(slot.astype(jnp.int32), 0, FANOUT - 1)
        cur = jnp.take_along_axis(row_k0, slot32[:, None], axis=-1)[:, 0]
        exists = valid & (cur == key)
        live = exists
    is_upd = exists  # staged as in-place value write (vs slack-slot insert)

    # ---- conflict resolution: sort by (gid, key, prio); the last writer of
    # each (gid, key) run wins, everything else is superseded (still counts
    # as applied — sequential replay would have applied then overwritten it)
    route_gid = jnp.where(live, gid, KEY_MAX)
    order = jnp.lexsort((prio, key, route_gid))
    g_s = route_gid[order]
    k_s = key[order]
    live_s = live[order]
    diff = (g_s[1:] != g_s[:-1]) | (k_s[1:] != k_s[:-1])
    new_run = jnp.concatenate([jnp.ones((1,), bool), diff])
    run_id = jnp.cumsum(new_run) - 1
    winner = jnp.concatenate([diff, jnp.ones((1,), bool)]) & live_s

    # ---- segments: one per distinct target leaf ---------------------------
    new_seg = jnp.concatenate([jnp.ones((1,), bool), g_s[1:] != g_s[:-1]])
    seg_id = jnp.cumsum(new_seg) - 1
    st_s = st[order]
    lo_s = lo[order]
    seg_st = (
        jnp.zeros((n,), jnp.int32).at[seg_id].max(jnp.where(live_s, st_s, 0))
    )
    seg_lo = (
        jnp.zeros((n,), jnp.int32).at[seg_id].max(jnp.where(live_s, lo_s, 0))
    )

    upd_w = winner & is_upd[order]
    ins_w = winner & live_s & ~is_upd[order]                # insert mode only
    # ---- overflow check: leaves whose fresh keys exceed the slack are shed
    occ_lane = occupancy[st_s, lo_s]                        # [N]
    n_new_seg = (
        jnp.zeros((n,), jnp.int32).at[seg_id].add(ins_w.astype(jnp.int32))
    )
    over_lane = (occ_lane + n_new_seg[seg_id]) > FANOUT
    ins_apply = ins_w & ~over_lane
    upd_apply = upd_w  # in-place updates apply even when the leaf overflows

    # ---- staged write matrices, one row per segment -----------------------
    s_width = FANOUT
    pos_u = _seg_positions(upd_apply, new_seg)
    pos_i = _seg_positions(ins_apply, new_seg)
    v_s = value[order]
    slot_ss = slot32[order]
    ur = jnp.where(upd_apply, seg_id, n)
    uc = jnp.where(upd_apply, pos_u, s_width)
    upd_slot_st = (
        jnp.full((n, s_width), -1, jnp.int32)
        .at[ur, uc].set(slot_ss, mode="drop")
    )
    upd_val_st = (
        jnp.zeros((n, s_width), jnp.int64).at[ur, uc].set(v_s, mode="drop")
    )
    ir = jnp.where(ins_apply, seg_id, n)
    ic = jnp.where(ins_apply, pos_i, s_width)
    ins_key_st = (
        jnp.full((n, s_width), KEY_MAX, jnp.int64)
        .at[ir, ic].set(k_s, mode="drop")
    )
    ins_val_st = (
        jnp.zeros((n, s_width), jnp.int64).at[ir, ic].set(v_s, mode="drop")
    )

    # ---- the masked scatter + merge itself (Pallas kernel or oracle) ------
    rows_k = pool_keys[seg_st, seg_lo]
    rows_v = pool_values[seg_st, seg_lo]
    writer = leaf_write if use_kernel else leaf_write_ref
    kw = {"interpret": interpret} if use_kernel else {}
    new_k, new_v, new_occ = writer(
        rows_k, rows_v, upd_slot_st, upd_val_st, ins_key_st, ins_val_st, **kw
    )

    seg_active = (
        jnp.zeros((n,), bool).at[seg_id].max(upd_apply | ins_apply)
    )
    w_st = jnp.where(seg_active, seg_st, pool_keys.shape[0])  # OOB drop
    out_pk = pool_keys.at[w_st, seg_lo].set(new_k, mode="drop")
    out_pv = pool_values.at[w_st, seg_lo].set(new_v, mode="drop")
    out_occ = occupancy.at[w_st, seg_lo].set(new_occ, mode="drop")

    # ---- per-lane status: every lane inherits its (gid, key) winner's fate
    outcome_w = jnp.where(
        upd_apply | ins_apply,
        STATUS_OK,
        jnp.where(ins_w & over_lane, STATUS_SPLIT, STATUS_MISS),
    ).astype(jnp.int32)
    run_out = (
        jnp.zeros((n,), jnp.int32)
        .at[run_id].max(jnp.where(winner, outcome_w, 0))
    )
    status_s = jnp.where(live_s, run_out[run_id], STATUS_MISS)
    status = jnp.zeros((n,), jnp.int32).at[order].set(status_s)

    rows_v_out = out_pv[st, lo]                             # post-batch rows
    return out_pk, out_pv, out_occ, status, rows_v_out


def _make_dex_write(
    meta: PoolMeta,
    cfg: DexMeshConfig,
    mesh,
    *,
    is_insert: bool,
    use_kernel: bool = True,
    interpret: "bool | None" = None,
):
    """Shared builder for the two write ops (see module docstring)."""
    levels = meta.levels_in_subtree
    if interpret is None:
        interpret = use_interpret()

    def local_fn(pool, occupancy, cache, boundaries, stats, demand, versions,
                 keys, values):
        b = keys.shape[0]
        n_route = cfg.n_route
        vers = versions[0]

        # --- 1. route to the owning partition, carrying a globally unique
        # batch priority so conflicting writers resolve as sequential replay
        dev = routing.device_linear_index(cfg, mesh)
        prio = dev.astype(jnp.int64) * b + jnp.arange(b, dtype=jnp.int64)
        owner, dem = routing.route_owners(boundaries, keys, n_route)
        new_demand = demand + dem
        cap = routing.route_capacity(b, n_route, cfg.route_capacity_factor)
        payload = jnp.stack([keys, values, prio], axis=-1)  # [B, 3]
        buf, lane, dropped_r = routing.pack_by_dest(payload, owner, n_route, cap)
        # inactive lanes share the OOB sentinel bucket; its overflow is
        # meaningless (see routing.route_owners)
        dropped_r = dropped_r & (keys != KEY_MAX)
        routed = routing.route_exchange(buf, cfg, mesh)     # [n_route, cap, 3]
        q = routed[..., 0].reshape(-1)                      # [Q]
        val = routed[..., 1].reshape(-1)
        pr = routed[..., 2].reshape(-1)
        live = q != KEY_MAX

        # --- 2. cached descent to the target leaf --------------------------
        subtree = top_walk(pool, meta, q)
        subtree = jnp.where(live, subtree, 0)
        local = jnp.zeros(q.shape, jnp.int32)
        new_cache = cache
        n_fetch = jnp.int64(0)
        n_hit = jnp.int64(0)
        shed = jnp.zeros(q.shape, bool)
        found = live
        wslot = jnp.zeros(q.shape, jnp.int32)
        descent_levels = levels if not is_insert else levels - 1
        for lvl in range(descent_levels):
            gid = meta.node_gid(subtree, local)
            if not is_insert and lvl == levels - 1:
                p_ok = routing.leaf_admit_dice(
                    gid, cfg.p_admit_leaf_pct,
                    salt=stats[0, STAT_OPS] + jnp.arange(q.shape[0]),
                )
            else:
                p_ok = jnp.ones(q.shape, bool)
            rows_k, rows_c, _rows_v, hit, miss, f_drop, n_msgs, new_cache = (
                cached_fetch_level(
                    pool, meta, cfg, new_cache, vers, gid, live, p_ok
                )
            )
            shed = shed | f_drop
            n_fetch = n_fetch + n_msgs
            n_hit = n_hit + jnp.sum(hit).astype(jnp.int64)
            if lvl < levels - 1:
                cnt = jnp.sum(rows_k <= q[:, None], axis=-1)
                slot = jnp.maximum(cnt - 1, 0).astype(jnp.int32)
                local = jnp.take_along_axis(rows_c, slot[:, None], axis=-1)[:, 0]
            else:
                # update: locate the slot for the CAS-style write
                eq = rows_k == q[:, None]
                found = jnp.any(eq, axis=-1) & live
                wslot = jnp.argmax(eq, axis=-1).astype(jnp.int32)
        leaf_gid = meta.node_gid(subtree, local)

        # --- 3. one write round to the owning memory column ----------------
        want_w = live & found & ~shed
        s_per = meta.n_subtrees_padded // cfg.n_memory
        w_owner = jnp.where(want_w, subtree // s_per, cfg.n_memory)
        wcap = routing.route_capacity(
            q.shape[0], cfg.n_memory, cfg.route_capacity_factor
        )
        wpayload = jnp.stack(
            [
                jnp.where(want_w, leaf_gid, KEY_MAX),
                wslot.astype(jnp.int64),
                q,
                val,
                pr,
            ],
            axis=-1,
        )                                                   # [Q, 5]
        wbuf, wlane, dropped_w = routing.pack_by_dest(
            wpayload, w_owner.astype(jnp.int32), cfg.n_memory, wcap
        )
        req = routing.a2a(wbuf, cfg.memory_axis)            # [n_mem, wcap, 5]
        # every route-replica of this column applies the identical batch
        req_all = routing.gather_route(req, cfg)            # [R, n_mem, wcap, 5]
        flat = req_all.reshape(-1, 5)
        new_pk, new_pv, new_occ, status_all, rows_v_all = _apply_leaf_writes(
            pool.pool_keys, pool.pool_values, occupancy, meta, cfg,
            flat[:, 0], flat[:, 1], flat[:, 2], flat[:, 3], flat[:, 4],
            is_insert=is_insert, use_kernel=use_kernel, interpret=interpret,
        )
        # respond to this device's own route row
        r_lin = routing.route_linear_index(cfg, mesh)
        status_own = jnp.take(
            status_all.reshape(cfg.n_route, cfg.n_memory, wcap), r_lin, axis=0
        )
        rows_own = jnp.take(
            rows_v_all.reshape(cfg.n_route, cfg.n_memory, wcap, FANOUT),
            r_lin, axis=0,
        )
        resp = jnp.concatenate(
            [status_own[..., None].astype(jnp.int64), rows_own], axis=-1
        )                                                   # [n_mem, wcap, F+1]
        resp = routing.a2a(resp, cfg.memory_axis)
        back = routing.unpack_to_lanes(resp, wlane, q.shape[0], 0)
        wstatus = back[..., 0].astype(jnp.int32)
        wrow_v = back[..., 1:]
        applied = want_w & ~dropped_w & (wstatus == STATUS_OK)

        # --- 4. write-through-and-invalidate + version bump ----------------
        nv = vers[leaf_gid] + 1
        set_idx = (
            routing.hash64(leaf_gid) % jnp.uint64(cfg.cache_sets)
        ).astype(jnp.int32)
        eqt = new_cache.tags[0, set_idx] == leaf_gid[:, None]
        chit = jnp.any(eqt, axis=-1) & applied
        way = jnp.argmax(eqt, axis=-1).astype(jnp.int32)
        sidx = jnp.where(chit, set_idx, cfg.cache_sets)
        if is_insert:
            # drop the chip's own (now key-shifted) cached row
            new_tags = new_cache.tags.at[0, sidx, way].set(-1, mode="drop")
            new_cache = new_cache._replace(tags=new_tags)
        else:
            # refresh the chip's own cached row with the authoritative
            # post-batch values and stamp it with the bumped version
            cvals = new_cache.values.at[0, sidx, way].set(wrow_v, mode="drop")
            cver = new_cache.ver.at[0, sidx, way].set(
                jnp.where(chit, nv, 0), mode="drop"
            )
            new_cache = new_cache._replace(values=cvals, ver=cver)
        gsafe = jnp.where(applied, leaf_gid, vers.shape[0])
        vers2 = vers.at[gsafe].max(nv, mode="drop")
        new_versions = jax.lax.pmax(vers2[None, :], cfg.all_axes)

        # --- 5. stats + result codes back to the requesting lanes ----------
        res = jnp.where(
            applied,
            STATUS_OK,
            jnp.where(
                shed | (want_w & dropped_w),
                STATUS_SHED,
                jnp.where(wstatus == STATUS_SPLIT, STATUS_SPLIT, STATUS_MISS),
            ),
        )
        res = jnp.where(live, res, STATUS_MISS)
        upd = jnp.zeros((1, N_STATS), jnp.int64)
        upd = upd.at[0, STAT_OPS].set(jnp.sum(live).astype(jnp.int64))
        upd = upd.at[0, STAT_HITS].set(n_hit)
        upd = upd.at[0, STAT_FETCHES].set(n_fetch)
        upd = upd.at[0, STAT_WRITES].set(
            jnp.sum(want_w & ~dropped_w).astype(jnp.int64)
        )
        upd = upd.at[0, STAT_DROPS].set(
            (jnp.sum(dropped_r) + jnp.sum(shed & live)
             + jnp.sum(want_w & dropped_w)).astype(jnp.int64)
        )
        upd = upd.at[0, STAT_SPLITS].set(
            jnp.sum(res == STATUS_SPLIT).astype(jnp.int64)
        )
        new_stats = stats + upd

        resp2 = res.astype(jnp.int64).reshape(n_route, cap, 1)
        back2 = routing.route_exchange(resp2, cfg, mesh, reverse=True)
        out = routing.unpack_to_lanes(back2, lane, b, 0)
        out_res = jnp.where(
            dropped_r, STATUS_SHED, out[..., 0].astype(jnp.int32)
        )
        return (new_pk, new_pv, new_occ, new_cache, new_versions, new_stats,
                new_demand, out_res)

    dev = P(cfg.all_axes)
    pool_specs = SubtreePool(
        top_keys=P(),
        top_children=P(),
        pool_keys=P(cfg.memory_axis),
        pool_children=P(cfg.memory_axis),
        pool_values=P(cfg.memory_axis),
    )
    cache_specs = DexCache(tags=dev, keys=dev, children=dev, values=dev,
                           fifo=dev, ver=dev)
    mem = P(cfg.memory_axis)

    sharded = routing.shard_map_compat(
        local_fn,
        mesh=mesh,
        in_specs=(pool_specs, mem, cache_specs, P(), dev, dev, dev,
                  P(cfg.all_axes), P(cfg.all_axes)),
        out_specs=(mem, mem, mem, cache_specs, dev, dev, dev,
                   P(cfg.all_axes)),
    )

    def write(state: DexState, keys: jax.Array, values: jax.Array):
        (new_pk, new_pv, new_occ, new_cache, new_versions, new_stats,
         new_demand, res) = (
            sharded(
                state.pool, state.occupancy, state.cache, state.boundaries,
                state.stats, state.route_demand, state.versions,
                keys.astype(jnp.int64), values.astype(jnp.int64),
            )
        )
        new_pool = state.pool._replace(pool_keys=new_pk, pool_values=new_pv)
        new_state = state._replace(
            pool=new_pool,
            occupancy=new_occ,
            cache=new_cache,
            versions=new_versions,
            stats=new_stats,
            route_demand=new_demand,
        )
        return new_state, res

    return write


def make_dex_update(meta, cfg, mesh, *, use_kernel=True, interpret=None):
    """Build the sharded in-place update:
    ``(state, keys, values) -> (state, status)``.

    ``keys``/``values`` are [B] globally sharded over all mesh axes;
    ``status`` comes back in the caller's lane order (``STATUS_OK`` /
    ``STATUS_MISS`` / ``STATUS_SHED``).  ``keys == KEY_MAX`` lanes are
    inactive no-ops (useful for op-type-masked mixed batches).  Wrap with
    ``jax.jit``."""
    return _make_dex_write(
        meta, cfg, mesh, is_insert=False,
        use_kernel=use_kernel, interpret=interpret,
    )


def make_dex_insert(meta, cfg, mesh, *, use_kernel=True, interpret=None):
    """Build the sharded insert: ``(state, keys, values) -> (state, status)``.

    Fresh keys append into their leaf's slack slots (occupancy-tracked);
    keys that already exist become value updates; leaves that would overflow
    shed their inserts with ``STATUS_SPLIT`` (counted in ``STAT_SPLITS``) —
    replay them with :func:`drain_splits` between batches.  ``keys ==
    KEY_MAX`` lanes are inactive no-ops.  Wrap with ``jax.jit``."""
    return _make_dex_write(
        meta, cfg, mesh, is_insert=True,
        use_kernel=use_kernel, interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Host-side split replay (the SMO path)
# ---------------------------------------------------------------------------


def host_items(host) -> "tuple[np.ndarray, np.ndarray]":
    """All (key, value) pairs of a :class:`repro.core.sim.HostBTree` in
    sorted key order."""
    lv = np.asarray(host.LV)
    nk = np.asarray(host.NK)
    keys, vals = [], []
    for nid in np.where(lv == 0)[0]:
        m = int(nk[nid])
        keys.append(np.asarray(host.K[nid, :m]))
        vals.append(np.asarray(host.V[nid, :m]))
    k = np.concatenate(keys) if keys else np.zeros((0,), np.int64)
    v = np.concatenate(vals) if vals else np.zeros((0,), np.int64)
    order = np.argsort(k, kind="stable")
    return k[order], v[order]


def drain_splits(
    state: DexState,
    meta: PoolMeta,
    cfg: DexMeshConfig,
    host,
    shed_keys: np.ndarray,
    shed_values: np.ndarray,
    boundaries: np.ndarray,
):
    """Replay shed inserts through the host tree's true eager-split SMO path
    and rebuild the mesh state from the result — the *bottom rung* of the
    SMO fallback ladder (core/smo.py resolves plain leaf splits device-side;
    this path remains for subtree-block overflow, exhausted free-lists and
    top-tree growth, and stays the validation oracle).

    ``host`` is the :class:`repro.core.sim.HostBTree` mirror the caller
    keeps in sync (it must already contain every *applied* mesh write);
    ``shed_keys``/``shed_values`` are the lanes that came back with
    ``STATUS_SPLIT``, in original batch order.  Returns ``(new_state,
    new_meta)`` — a freshly blocked pool (splits change the leaf layout, so
    caches/versions restart cold; accumulated stats carry over, and the
    rebuild is counted in ``STAT_DRAINS`` so benchmarks can report fallback
    frequency).  Ops built by ``make_dex_*`` must be rebuilt against
    ``new_meta``.  With no shed lanes this is a **no-op**: the existing
    state is returned untouched — no rebuild, no cache/version cold
    restart, no drain counted.
    """
    shed_keys = np.asarray(shed_keys)
    shed_values = np.asarray(shed_values)
    if shed_keys.size == 0:
        return state, meta
    for k, v in zip(shed_keys, shed_values):
        host.insert(int(k), int(v))
    items_k, items_v = host_items(host)
    pool, new_meta = build_pool(
        items_k, items_v,
        level_m=meta.level_m,
        fill=meta.per_node / FANOUT,
        n_shards=cfg.n_memory,
        headroom=meta.headroom_frac,
        subtree_leaves=meta.leaves_per_subtree,
    )
    new_state = init_state(pool, new_meta, cfg, boundaries)
    # accumulated stats and the controller's demand counters carry over
    # (their shapes don't depend on the pool layout); the rebuild itself is
    # counted so callers can report how often the fallback fired
    stats = jnp.asarray(state.stats).at[0, STAT_DRAINS].add(1)
    return new_state._replace(
        stats=stats, route_demand=state.route_demand
    ), new_meta
