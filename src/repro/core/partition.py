"""Compute-side logical partitioning (paper §4).

Each compute server logically owns a disjoint key range while memory servers
present a globally addressable space.  Partitioning is *logical*: a routing
table of boundaries, not data placement, so repartitioning/elasticity is a
metadata update plus a dirty-cache flush (paper Fig. 10: < 2 s).

Used by:
  * Plane A (event simulator): key -> owning compute server, shared-node
    detection (a node whose fence range crosses a boundary needs RDMA-style
    synchronization).
  * Plane B (mesh): key -> owning (pod, data) shard for all_to_all routing;
    elastic scale-in/out of the serving launcher reuses ``split``/``merge``.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.nodes import KEY_MAX, KEY_MIN


@dataclasses.dataclass(frozen=True)
class LogicalPartitions:
    """Key-range ownership table.

    ``boundaries`` has ``num_partitions + 1`` entries; partition ``p`` owns
    keys in ``[boundaries[p], boundaries[p+1])``.  ``boundaries[0] == KEY_MIN``
    and ``boundaries[-1] == KEY_MAX``.
    """

    boundaries: np.ndarray  # [P+1] int64

    def __post_init__(self):
        b = np.asarray(self.boundaries, dtype=np.int64)
        assert b.ndim == 1 and b.size >= 2
        assert b[0] == KEY_MIN and b[-1] == KEY_MAX
        assert np.all(np.diff(b.astype(object)) > 0), "boundaries must increase"
        object.__setattr__(self, "boundaries", b)

    # -- construction -------------------------------------------------------

    @staticmethod
    def equal_width(num_partitions: int, lo: int, hi: int) -> "LogicalPartitions":
        """Equal key-range widths over [lo, hi) (paper's default setup)."""
        inner = np.linspace(lo, hi, num_partitions + 1).astype(np.int64)[1:-1]
        inner = np.unique(inner)
        b = np.concatenate([[KEY_MIN], inner, [KEY_MAX]]).astype(np.int64)
        return LogicalPartitions(b)

    @staticmethod
    def from_samples(keys: np.ndarray, num_partitions: int) -> "LogicalPartitions":
        """Workload-aware: equal-*frequency* boundaries from sampled keys
        (the paper notes DEX works with any range scheme; boundaries should
        be picked from lowest-inner-node fence keys, which sampled leaf keys
        approximate)."""
        keys = np.sort(np.asarray(keys, dtype=np.int64))
        qs = np.quantile(keys, np.linspace(0, 1, num_partitions + 1)[1:-1])
        inner = np.unique(qs.astype(np.int64))
        b = np.concatenate([[KEY_MIN], inner, [KEY_MAX]]).astype(np.int64)
        return LogicalPartitions(b)

    # -- queries -------------------------------------------------------------

    @property
    def num_partitions(self) -> int:
        return self.boundaries.size - 1

    def owner_of(self, keys) -> np.ndarray:
        """Owning partition id for each key (vectorized)."""
        keys = np.asarray(keys, dtype=np.int64)
        return (np.searchsorted(self.boundaries, keys, side="right") - 1).astype(
            np.int32
        )

    def owner_of_device(self, keys: jnp.ndarray) -> jnp.ndarray:
        """jnp version for use inside jit (Plane B routing)."""
        b = jnp.asarray(self.boundaries)
        return (jnp.searchsorted(b, keys, side="right") - 1).astype(jnp.int32)

    def is_shared_range(self, lo, hi) -> np.ndarray:
        """True when a [lo, hi) fence range crosses a partition boundary —
        such nodes (e.g. the root) are accessible by multiple compute servers
        and need RDMA-style synchronization (paper §4)."""
        lo = np.asarray(lo, dtype=np.int64)
        hi = np.asarray(hi, dtype=np.int64)
        po = self.owner_of(lo)
        # hi is exclusive: probe the last key strictly inside the range.
        ph = (
            np.searchsorted(self.boundaries, hi.astype(object) - 1, side="right") - 1
        ).astype(np.int32)
        return po != ph

    # -- elasticity / rebalancing (paper §4, Fig. 10) ------------------------

    def split_partition(self, p: int, at_key: int) -> "LogicalPartitions":
        """Scale-out: split partition ``p`` at ``at_key`` (adds a server)."""
        lo, hi = self.boundaries[p], self.boundaries[p + 1]
        if not (lo < at_key < hi):
            raise ValueError("split key outside partition range")
        b = np.insert(self.boundaries, p + 1, at_key)
        return LogicalPartitions(b)

    def merge_partitions(self, p: int) -> "LogicalPartitions":
        """Scale-in: merge partition ``p`` with ``p+1`` (removes a server)."""
        if not (0 <= p < self.num_partitions - 1):
            raise ValueError("no right neighbour to merge with")
        b = np.delete(self.boundaries, p + 1)
        return LogicalPartitions(b)

    def rebalance(self, loads: Sequence[float]) -> "LogicalPartitions":
        """Move boundaries toward equal load, assuming load uniform within
        each partition (lightweight logical repartitioning; no data moves)."""
        loads = np.asarray(loads, dtype=np.float64)
        assert loads.size == self.num_partitions
        widths = np.diff(self.boundaries.astype(np.float64))
        density = loads / np.maximum(widths, 1.0)
        total = loads.sum()
        target = total / self.num_partitions
        # walk the key space accumulating load until each target is met
        new_inner = []
        acc = 0.0
        need = target
        for p in range(self.num_partitions):
            seg_lo = float(self.boundaries[p])
            seg_hi = float(self.boundaries[p + 1])
            seg_load = loads[p]
            seg_w = seg_hi - seg_lo
            pos = seg_lo
            while acc + (seg_hi - pos) * density[p] >= need and len(new_inner) < (
                self.num_partitions - 1
            ):
                if density[p] <= 0:
                    break
                step = (need - acc) / density[p]
                pos = pos + step
                new_inner.append(int(pos))
                acc = 0.0
            acc += (seg_hi - pos) * density[p]
        inner = np.unique(np.asarray(new_inner, dtype=np.int64))
        b = np.concatenate([[KEY_MIN], inner, [KEY_MAX]]).astype(np.int64)
        return LogicalPartitions(b)

    def assignment_diff(self, other: "LogicalPartitions") -> float:
        """Fraction of (a large sample of) the key space whose owner changes —
        proxy for cache re-warm volume after repartitioning."""
        lo = max(int(self.boundaries[1]) - 1, -(2**62))
        hi = min(int(self.boundaries[-2]) + 1, 2**62)
        if hi <= lo:
            lo, hi = -(2**32), 2**32
        sample = np.linspace(lo, hi, 4097).astype(np.int64)
        return float(np.mean(self.owner_of(sample) != other.owner_of(sample)))
