"""Compute-side logical partitioning (paper §4).

Each compute server logically owns a disjoint key range while memory servers
present a globally addressable space.  Partitioning is *logical*: a routing
table of boundaries, not data placement, so repartitioning/elasticity is a
metadata update plus a dirty-cache flush (paper Fig. 10: < 2 s).

Used by:
  * Plane A (event simulator): key -> owning compute server, shared-node
    detection (a node whose fence range crosses a boundary needs RDMA-style
    synchronization).
  * Plane B (mesh): key -> owning (pod, data) shard for all_to_all routing;
    elastic scale-in/out of the serving launcher reuses ``split``/``merge``.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.nodes import KEY_MAX, KEY_MIN


def _distinct_inner(candidates, num_partitions: int) -> np.ndarray:
    """Force ``num_partitions - 1`` strictly increasing int64 boundaries in
    the open interval ``(KEY_MIN, KEY_MAX)``.

    A fixed mesh has a fixed server count, so the inner-boundary count is a
    hard invariant: duplicate or colliding candidates are perturbed (forward
    pass pushes collisions up, backward pass resolves clamps at the top),
    and the function raises only when the key space itself cannot hold the
    requested count.  All arithmetic is in Python ints — candidates can sit
    next to the int64 sentinels, where ``+ 1`` would overflow int64.
    """
    n_inner = num_partitions - 1
    inner = sorted(int(c) for c in candidates)
    if len(inner) != n_inner:
        raise ValueError(
            f"expected {n_inner} boundary candidates, got {len(inner)}"
        )
    kmin, kmax = int(KEY_MIN), int(KEY_MAX)
    if n_inner == 0:
        return np.zeros((0,), np.int64)
    if kmax - kmin - 1 < n_inner:
        raise ValueError(
            f"key space cannot hold {n_inner} distinct inner boundaries"
        )
    prev = kmin
    for i in range(n_inner):
        inner[i] = min(max(inner[i], prev + 1), kmax - 1)
        prev = inner[i]
    nxt = kmax
    for i in range(n_inner - 1, -1, -1):
        inner[i] = min(inner[i], nxt - 1)
        nxt = inner[i]
    if inner[0] <= kmin:
        raise ValueError(
            f"cannot fit {n_inner} distinct inner boundaries above KEY_MIN"
        )
    return np.asarray(inner, dtype=np.int64)


@dataclasses.dataclass(frozen=True)
class LogicalPartitions:
    """Key-range ownership table.

    ``boundaries`` has ``num_partitions + 1`` entries; partition ``p`` owns
    keys in ``[boundaries[p], boundaries[p+1])``.  ``boundaries[0] == KEY_MIN``
    and ``boundaries[-1] == KEY_MAX``.
    """

    boundaries: np.ndarray  # [P+1] int64

    def __post_init__(self):
        b = np.asarray(self.boundaries, dtype=np.int64)
        assert b.ndim == 1 and b.size >= 2
        assert b[0] == KEY_MIN and b[-1] == KEY_MAX
        assert np.all(np.diff(b.astype(object)) > 0), "boundaries must increase"
        object.__setattr__(self, "boundaries", b)

    # -- construction -------------------------------------------------------

    @staticmethod
    def equal_width(num_partitions: int, lo: int, hi: int) -> "LogicalPartitions":
        """Equal key-range widths over [lo, hi) (paper's default setup).

        Always produces exactly ``num_partitions`` partitions: a range too
        narrow for distinct boundaries gets them perturbed upward instead of
        silently merged (a fixed mesh needs a fixed server count)."""
        inner = np.linspace(lo, hi, num_partitions + 1).astype(np.int64)[1:-1]
        inner = _distinct_inner(inner, num_partitions)
        b = np.concatenate([[KEY_MIN], inner, [KEY_MAX]]).astype(np.int64)
        return LogicalPartitions(b)

    @staticmethod
    def from_samples(keys: np.ndarray, num_partitions: int) -> "LogicalPartitions":
        """Workload-aware: equal-*frequency* boundaries from sampled keys
        (the paper notes DEX works with any range scheme; boundaries should
        be picked from lowest-inner-node fence keys, which sampled leaf keys
        approximate).  Few distinct samples perturb duplicate quantiles
        instead of collapsing the partition count."""
        keys = np.sort(np.asarray(keys, dtype=np.int64))
        qs = np.quantile(keys, np.linspace(0, 1, num_partitions + 1)[1:-1])
        inner = _distinct_inner(qs.astype(np.int64), num_partitions)
        b = np.concatenate([[KEY_MIN], inner, [KEY_MAX]]).astype(np.int64)
        return LogicalPartitions(b)

    # -- queries -------------------------------------------------------------

    @property
    def num_partitions(self) -> int:
        return self.boundaries.size - 1

    def owner_of(self, keys) -> np.ndarray:
        """Owning partition id for each key (vectorized)."""
        keys = np.asarray(keys, dtype=np.int64)
        return (np.searchsorted(self.boundaries, keys, side="right") - 1).astype(
            np.int32
        )

    def owner_of_device(self, keys: jnp.ndarray) -> jnp.ndarray:
        """jnp version for use inside jit (Plane B routing)."""
        b = jnp.asarray(self.boundaries)
        return (jnp.searchsorted(b, keys, side="right") - 1).astype(jnp.int32)

    def is_shared_range(self, lo, hi) -> np.ndarray:
        """True when a [lo, hi) fence range crosses a partition boundary —
        such nodes (e.g. the root) are accessible by multiple compute servers
        and need RDMA-style synchronization (paper §4)."""
        lo = np.asarray(lo, dtype=np.int64)
        hi = np.asarray(hi, dtype=np.int64)
        po = self.owner_of(lo)
        # hi is exclusive: probe the last key strictly inside the range.
        ph = (
            np.searchsorted(self.boundaries, hi.astype(object) - 1, side="right") - 1
        ).astype(np.int32)
        return po != ph

    # -- elasticity / rebalancing (paper §4, Fig. 10) ------------------------

    def split_partition(self, p: int, at_key: int) -> "LogicalPartitions":
        """Scale-out: split partition ``p`` at ``at_key`` (adds a server)."""
        lo, hi = self.boundaries[p], self.boundaries[p + 1]
        if not (lo < at_key < hi):
            raise ValueError("split key outside partition range")
        b = np.insert(self.boundaries, p + 1, at_key)
        return LogicalPartitions(b)

    def merge_partitions(self, p: int) -> "LogicalPartitions":
        """Scale-in: merge partition ``p`` with ``p+1`` (removes a server)."""
        if not (0 <= p < self.num_partitions - 1):
            raise ValueError("no right neighbour to merge with")
        b = np.delete(self.boundaries, p + 1)
        return LogicalPartitions(b)

    def rebalance(
        self,
        loads: Sequence[float],
        *,
        key_range: "tuple[int, int] | None" = None,
    ) -> "LogicalPartitions":
        """Move boundaries toward equal load, assuming load uniform within
        each partition (lightweight logical repartitioning; no data moves).

        The walk is confined to the *data hull*: the edge partitions
        nominally span to the int64 sentinels, but their load lives in real
        key space, so treating the sentinel widths as populated emits
        boundaries (e.g. ``-6.8e18`` for loads ``[100, 1, 1, 1]``) that own
        no real keys.  ``key_range = (min_key, max_key)`` — sampled from the
        data or the routed workload — bounds the edge partitions exactly;
        without it the edge extents are approximated by the mean inner
        partition width.  With ``num_partitions == 2`` there are no inner
        widths to average, so the no-``key_range`` fallback hull collapses
        to one key around the single boundary and it barely moves — callers
        that want two-partition rebalancing to chase load must supply
        ``key_range`` (the controller does whenever it has observed keys).

        The result always has ``num_partitions`` partitions: zero total load
        returns the table unchanged (no signal, and a fixed mesh needs a
        fixed server count), and colliding boundaries are perturbed rather
        than merged (a degenerate near-zero-width hull may spill the
        perturbed boundaries past its top edge by at most
        ``num_partitions - 2`` keys).
        """
        loads = np.maximum(np.asarray(loads, dtype=np.float64), 0.0)
        assert loads.size == self.num_partitions
        n_parts = self.num_partitions
        total = float(loads.sum())
        if n_parts == 1 or total <= 0.0:
            return self
        inner_b = [int(x) for x in self.boundaries[1:-1]]
        if key_range is not None:
            hull_lo, hull_hi = int(key_range[0]), int(key_range[1])
            if hull_lo > hull_hi:
                hull_lo, hull_hi = hull_hi, hull_lo
        else:
            mean_w = (
                max(1, (inner_b[-1] - inner_b[0]) // (n_parts - 2))
                if n_parts > 2
                else 1
            )
            hull_lo = inner_b[0] - mean_w
            hull_hi = inner_b[-1] + mean_w
        # the hull must enclose the existing inner boundaries (monotone
        # segment edges) and stay off the sentinels
        hull_lo = max(min(hull_lo, inner_b[0]), int(KEY_MIN) + 1)
        hull_hi = min(max(hull_hi, inner_b[-1]), int(KEY_MAX) - 1)
        edges = np.asarray([hull_lo] + inner_b + [hull_hi], dtype=np.float64)
        # piecewise-constant density inverse CDF: cumulative load at the
        # segment edges, equal-load targets interpolated back to key space.
        # The epsilon keeps the CDF strictly increasing through zero-load
        # partitions so interpolation stays well defined.
        eps = total * 1e-9 + 1e-12
        cum = np.concatenate([[0.0], np.cumsum(loads + eps)])
        targets = cum[-1] * np.arange(1, n_parts) / n_parts
        cand = np.floor(np.interp(targets, cum, edges))
        inner = _distinct_inner(cand, n_parts)
        b = np.concatenate([[KEY_MIN], inner, [KEY_MAX]]).astype(np.int64)
        return LogicalPartitions(b)

    def assignment_diff(self, other: "LogicalPartitions") -> float:
        """Fraction of (a large sample of) the key space whose owner changes —
        proxy for cache re-warm volume after repartitioning."""
        lo = max(int(self.boundaries[1]) - 1, -(2**62))
        hi = min(int(self.boundaries[-2]) + 1, 2**62)
        if hi <= lo:
            lo, hi = -(2**32), 2**32
        sample = np.linspace(lo, hi, 4097).astype(np.int64)
        return float(np.mean(self.owner_of(sample) != other.owner_of(sample)))
