"""jax version-compatibility shims shared by every plane (mesh serving in
``core/``, training substrate in ``launch/``/``train/``, models).

Kept dependency-free (imports only jax) so no plane picks up another
plane's modules just to spell ``shard_map``.
"""

from __future__ import annotations

import jax


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions (older releases ship it under
    ``jax.experimental.shard_map`` with ``check_rep`` instead of
    ``check_vma``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


def make_mesh_compat(axis_shapes, axis_names):
    """``jax.make_mesh`` across jax versions: ``axis_types`` where present,
    plain ``jax.make_mesh`` without it, raw ``jax.sharding.Mesh`` on releases
    predating ``jax.make_mesh`` entirely."""
    if hasattr(jax, "make_mesh"):
        try:
            return jax.make_mesh(
                axis_shapes,
                axis_names,
                axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names),
            )
        except (AttributeError, TypeError):
            return jax.make_mesh(axis_shapes, axis_names)
    import math

    n = math.prod(axis_shapes)
    devices = jax.devices()
    if len(devices) < n:
        raise ValueError(
            f"mesh {tuple(axis_shapes)} needs {n} devices, "
            f"have {len(devices)}"
        )
    import numpy as np

    return jax.sharding.Mesh(
        np.asarray(devices[:n]).reshape(axis_shapes), axis_names
    )
