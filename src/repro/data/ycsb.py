"""YCSB-style workload generation (paper §8.1, Table 1 / Table 3).

Zipfian request distribution (theta=0.99 default, matching YCSB) with the
standard scrambled mapping so hot keys are spread over the key space, plus
the paper's five workload mixes and the two extended-version mixes.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

OP_LOOKUP, OP_UPDATE, OP_INSERT, OP_SCAN, OP_DELETE = 0, 1, 2, 3, 4

#: Table 1 + Table 3 mixes: (insert, lookup, update, scan)
WORKLOADS: Dict[str, Tuple[float, float, float, float]] = {
    "read-only": (0.0, 1.0, 0.0, 0.0),
    "read-intensive": (0.0, 0.95, 0.05, 0.0),
    "write-intensive": (0.0, 0.50, 0.50, 0.0),
    "insert-intensive": (0.50, 0.50, 0.0, 0.0),
    "scan-intensive": (0.05, 0.0, 0.0, 0.95),
    "read-intensive-2": (0.05, 0.95, 0.0, 0.0),
    "insert-only": (1.0, 0.0, 0.0, 0.0),
    # standard YCSB-E: 95% short range scans / 5% inserts — identical mix to
    # the paper's scan-intensive, kept as an alias for workload-suite users
    "ycsb-e": (0.05, 0.0, 0.0, 0.95),
    # standard YCSB A/B/D aliases (the paper's mixed read/write mixes of
    # Figs. 6-7); D models "read latest" as read-intensive with inserts
    "ycsb-a": (0.0, 0.50, 0.50, 0.0),
    "ycsb-b": (0.0, 0.95, 0.05, 0.0),
    "ycsb-d": (0.05, 0.95, 0.0, 0.0),
    # YCSB load phase: pure inserts (alias of insert-only) — the trace that
    # drives the on-mesh SMO engine's benchmark (fig14_mesh_load), consumed
    # by both planes
    "ycsb-load": (1.0, 0.0, 0.0, 0.0),
    # insert-heavy D variant (D's mix inverted: 95% insert / 5% read) —
    # models the insert-dominated tail of a "read latest" workload
    "ycsb-d95i": (0.95, 0.05, 0.0, 0.0),
}


@dataclasses.dataclass
class ZipfianGenerator:
    """YCSB's scrambled-Zipfian over ``n`` items (Gray et al. rejection-free
    formulation, vectorized)."""

    n: int
    theta: float = 0.99
    seed: int = 0

    def __post_init__(self):
        n, theta = self.n, self.theta
        self._rng = np.random.default_rng(self.seed)
        if theta <= 0:
            self._uniform = True
            return
        self._uniform = False
        self.zetan = self._zeta(n, theta)
        self.zeta2 = self._zeta(2, theta)
        self.alpha = 1.0 / (1.0 - theta)
        self.eta = (1 - (2.0 / n) ** (1 - theta)) / (1 - self.zeta2 / self.zetan)

    @staticmethod
    def _zeta(n: int, theta: float) -> float:
        # exact for small n; Euler–Maclaurin tail for large n
        if n <= 10_000_000:
            i = np.arange(1, n + 1, dtype=np.float64)
            return float(np.sum(i ** (-theta)))
        i = np.arange(1, 10_000_001, dtype=np.float64)
        head = float(np.sum(i ** (-theta)))
        # integral tail approximation
        tail = (n ** (1 - theta) - 10_000_000 ** (1 - theta)) / (1 - theta)
        return head + tail

    def draw_ranks(self, size: int) -> np.ndarray:
        """Zipfian *ranks* in [0, n): rank 0 is the hottest item."""
        if self._uniform:
            return self._rng.integers(0, self.n, size=size)
        u = self._rng.random(size)
        uz = u * self.zetan
        ranks = (self.n * (self.eta * u - self.eta + 1) ** self.alpha).astype(np.int64)
        ranks = np.where(uz < 1.0, 0, ranks)
        ranks = np.where((uz >= 1.0) & (uz < 1.0 + 0.5**self.theta), 1, ranks)
        return np.clip(ranks, 0, self.n - 1)

    def hottest_fraction(self, size: int = 200_000) -> float:
        """Empirical access share of the single hottest item (drives the
        hot-leaf contention model, Fig. 12b/17)."""
        r = self.draw_ranks(size)
        return float(np.mean(r == 0))


def scramble(ranks: np.ndarray, n: int) -> np.ndarray:
    """FNV-style hash spreading ranks over [0, n) (YCSB ScrambledZipfian)."""
    h = ranks.astype(np.uint64)
    h = (h * np.uint64(0xC6A4A7935BD1E995)) ^ (h >> np.uint64(29))
    h = (h * np.uint64(0xFF51AFD7ED558CCD)) ^ (h >> np.uint64(33))
    return (h % np.uint64(n)).astype(np.int64)


@dataclasses.dataclass
class Workload:
    ops: np.ndarray      # op codes
    keys: np.ndarray     # target keys
    scan_len: int = 100
    #: per-op scan lengths (YCSB-E draws uniform in [1, max]); None = fixed
    scan_lens: "np.ndarray | None" = None


def engine_lanes(
    wl: Workload,
    lo: int = 0,
    hi: "int | None" = None,
    *,
    update_xor: int = 0x5A5A,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Slice ``[lo, hi)`` of a workload as one *interleaved mixed-op batch*
    for the unified engine (core/engine.py): the per-lane opcode plane (the
    ``OP_*`` codes are shared between this module and the engine), the key
    plane, and the overloaded value plane — update lanes carry ``key ^
    update_xor`` (the convention the mesh benchmarks and ``Simulator``
    replay), insert lanes carry the key itself, scan lanes carry their
    record count (``Workload.scan_lens`` when per-op lengths were drawn,
    the fixed ``scan_len`` otherwise), lookup lanes carry 0.  This replaces
    the per-op-type masked splits the pre-engine benchmarks performed: one
    stream, opcodes instead of three KEY_MAX-masked sub-batches.
    """
    hi = wl.ops.size if hi is None else hi
    ops = wl.ops[lo:hi].astype(np.int32)
    keys = wl.keys[lo:hi].astype(np.int64)
    vals = np.zeros(ops.shape, np.int64)
    upd = ops == OP_UPDATE
    vals[upd] = keys[upd] ^ update_xor
    ins = ops == OP_INSERT
    vals[ins] = keys[ins]
    scn = ops == OP_SCAN
    if wl.scan_lens is not None:
        vals[scn] = wl.scan_lens[lo:hi][scn]
    else:
        vals[scn] = wl.scan_len
    return ops, keys, vals


def make_dataset(n_keys: int, *, key_space: int = None, seed: int = 0,
                 key_size_bytes: int = 8) -> np.ndarray:
    """Sorted unique int64 keys to bulk-load (paper: 200M records; benches
    scale down).  ``key_size_bytes`` > 8 models longer string keys by
    reducing effective fanout upstream (Fig. 16)."""
    key_space = key_space or max(4 * n_keys, 1 << 20)
    rng = np.random.default_rng(seed)
    keys = rng.choice(key_space, size=n_keys, replace=False).astype(np.int64) + 1
    return np.sort(keys)


def generate(
    name: str,
    dataset: np.ndarray,
    n_ops: int,
    *,
    theta: float = 0.99,
    seed: int = 1,
    scan_len: int = 100,
    scan_len_dist: str = "fixed",
    hotspot: "float | None" = None,
) -> Workload:
    """Generate ``n_ops`` operations of the named mix over ``dataset``.

    Lookups/updates/scans target existing keys via scrambled-Zipfian ranks;
    inserts draw fresh keys adjacent to existing ones (keeping the key space
    dense, as YCSB's insert order does).

    ``scan_len_dist``: ``"fixed"`` scans all take ``scan_len`` records (the
    paper's Table 1 setup); ``"uniform"`` draws per-op lengths uniformly from
    ``[1, scan_len]`` (standard YCSB workload E) into ``Workload.scan_lens``.

    ``hotspot``: ``None`` keeps YCSB's scrambled mapping (hot ranks spread
    over the whole key space — range partitioning cannot see the skew).  A
    float in ``[0, 1)`` instead centers the zipfian on that *fractional
    position* of the sorted dataset without scrambling, so the hot keys form
    a contiguous range — the spatially localized skew that drives logical
    repartitioning (paper §4 / Fig. 10, benchmarks/fig10_mesh_repartition).
    """
    if name not in WORKLOADS:
        raise KeyError(f"unknown workload {name!r}; options: {list(WORKLOADS)}")
    if scan_len_dist not in ("fixed", "uniform"):
        raise ValueError(f"unknown scan_len_dist {scan_len_dist!r}")
    p_ins, p_look, p_upd, p_scan = WORKLOADS[name]
    rng = np.random.default_rng(seed)
    n = dataset.size
    zipf = ZipfianGenerator(n, theta=theta, seed=seed + 7)

    ops = rng.choice(
        np.array([OP_INSERT, OP_LOOKUP, OP_UPDATE, OP_SCAN]),
        size=n_ops,
        p=[p_ins, p_look, p_upd, p_scan],
    )
    ranks = zipf.draw_ranks(n_ops)
    if hotspot is None:
        idx = scramble(ranks, n)
    else:
        if not (0.0 <= hotspot < 1.0):
            raise ValueError(f"hotspot must be in [0, 1), got {hotspot!r}")
        # rank 0 at the hotspot center, ranks fanning out alternately left
        # and right keeps the hot range contiguous in key space
        offset = np.where(ranks % 2 == 0, ranks // 2, -(ranks // 2 + 1))
        idx = (int(hotspot * n) + offset) % n
    keys = dataset[idx]

    is_ins = ops == OP_INSERT
    n_ins = int(is_ins.sum())
    if n_ins:
        # fresh keys: odd offsets above existing even-spaced keys are unlikely
        # to collide; fall back to random 63-bit keys for any residual dupes
        base = dataset[idx[is_ins]]
        fresh = base + rng.integers(1, 3, size=n_ins)
        keys = keys.copy()
        keys[is_ins] = fresh
    scan_lens = None
    if scan_len_dist == "uniform":
        scan_lens = rng.integers(1, scan_len + 1, size=n_ops).astype(np.int32)
    return Workload(ops=ops.astype(np.int32), keys=keys.astype(np.int64),
                    scan_len=scan_len, scan_lens=scan_lens)
