"""Deterministic, shardable, checkpointable synthetic token pipeline.

Production shape without production data: an infinite stream of pseudo-
random "documents" generated from a counter-based RNG, so (a) every batch is
a pure function of (seed, step) — restart-safe with no state files; (b) each
data shard draws a disjoint counter range — shardable across hosts; (c) the
pipeline state is just an integer, carried inside the checkpoint ``extra``.
The same partition tables as the DEX index route shard -> host (DESIGN.md
§4: one partition mechanism for data, cache and serving)."""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

from repro.models.config import ArchConfig


@dataclasses.dataclass
class PipelineState:
    step: int = 0

    def to_json(self) -> dict:
        return {"step": self.step}

    @staticmethod
    def from_json(d: dict) -> "PipelineState":
        return PipelineState(step=int(d.get("step", 0)))


@dataclasses.dataclass
class TokenPipeline:
    cfg: ArchConfig
    global_batch: int
    seq_len: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0
    state: PipelineState = dataclasses.field(default_factory=PipelineState)

    def __post_init__(self):
        assert self.global_batch % self.n_shards == 0
        self.local_batch = self.global_batch // self.n_shards

    def _batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, step, shard)."""
        # counter-based: one Philox stream keyed by (seed, step, shard)
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard])
        )
        b, s = self.local_batch, self.seq_len
        # synthetic "documents": zipf-ish token frequencies + markov-ish runs
        base = rng.zipf(1.3, size=(b, s)).astype(np.int64)
        tokens = (base % (self.cfg.vocab - 2)) + 1
        runs = rng.integers(0, 4, size=(b, s)) == 0
        tokens = np.where(runs, np.roll(tokens, 1, axis=1), tokens)
        tokens = tokens.astype(np.int32)
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = -100
        out = {"tokens": tokens, "labels": labels}
        if self.cfg.encdec:
            out["enc_emb"] = rng.standard_normal(
                (b, self.cfg.max_source_positions, self.cfg.d_model)
            ).astype(np.float32) * 0.02
        return out

    def next_batch(self) -> Dict[str, np.ndarray]:
        batch = self._batch_at(self.state.step)
        self.state.step += 1
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()

    # -- fault tolerance --------------------------------------------------------

    def snapshot(self) -> dict:
        return self.state.to_json()

    def restore(self, snap: dict) -> None:
        self.state = PipelineState.from_json(snap)

    def reshard(self, n_shards: int, shard: int) -> "TokenPipeline":
        """Elastic re-shard: same global stream, new shard geometry (the
        counter key includes the shard id, so the stream stays deterministic
        per shard; global coverage is preserved because batches are pure
        functions of step)."""
        return TokenPipeline(
            cfg=self.cfg,
            global_batch=self.global_batch,
            seq_len=self.seq_len,
            seed=self.seed,
            n_shards=n_shards,
            shard=shard,
            state=PipelineState(step=self.state.step),
        )
