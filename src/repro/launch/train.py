"""End-to-end training driver.

Wires together: config registry -> model init -> sharded train step ->
deterministic data pipeline -> checkpoint manager -> fault-tolerance hooks
(watchdog, heartbeat, retry-with-restore).  Runs the real thing on however
many devices exist (1 on this CPU container; the production mesh via the
same code path on a pod).

Example (CPU, ~100M-param model, a few hundred steps)::

    PYTHONPATH=src python -m repro.launch.train --arch minitron-4b \
        --reduce --steps 300 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS, get_config
from repro.compat import make_mesh_compat
from repro.data.pipeline import TokenPipeline
from repro.models import model as M
from repro.train import sharding as SH
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import (
    FailureInjector, Heartbeat, RetryPolicy, StepWatchdog,
)
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step


@dataclasses.dataclass
class TrainRun:
    cfg: object
    opt_cfg: OptConfig
    mesh: object
    params: object
    opt_state: object
    pipeline: TokenPipeline
    ckpt: Optional[CheckpointManager]
    step: int = 0


def build_run(
    arch: str,
    *,
    reduce: bool = False,
    batch: int = 8,
    seq: int = 128,
    steps: int = 100,
    ckpt_dir: Optional[str] = None,
    seed: int = 0,
    mesh=None,
) -> TrainRun:
    cfg = get_config(arch)
    if reduce:
        cfg = cfg.reduced(n_layers=4, d_model=128, d_ff=256, vocab=512)
    if mesh is None:
        n = len(jax.devices())
        nd = max(1, n // 2) if n > 1 else 1
        nm = max(1, n // nd)
        mesh = make_mesh_compat((nd, nm), ("data", "model"))
    opt_cfg = OptConfig(total_steps=steps, warmup_steps=max(1, steps // 20))
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = init_opt_state(params, opt_cfg)
    p_sh = SH.param_shardings(params, mesh, cfg)
    params = jax.tree.map(jax.device_put, params, p_sh)
    pipeline = TokenPipeline(cfg=cfg, global_batch=batch, seq_len=seq, seed=seed)
    ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
    return TrainRun(
        cfg=cfg, opt_cfg=opt_cfg, mesh=mesh, params=params,
        opt_state=opt_state, pipeline=pipeline, ckpt=ckpt,
    )


def train(
    run: TrainRun,
    steps: int,
    *,
    microbatches: int = 1,
    ckpt_every: int = 50,
    injector: Optional[FailureInjector] = None,
    log_every: int = 10,
    heartbeat_path: Optional[str] = None,
):
    """The training loop with checkpoint/restart + straggler watchdog."""
    cfg, mesh = run.cfg, run.mesh
    step_fn = jax.jit(
        make_train_step(cfg, run.opt_cfg, microbatches=microbatches),
        donate_argnums=(0, 1),
    )
    watchdog = StepWatchdog()
    heartbeat = Heartbeat(heartbeat_path, interval=5.0) if heartbeat_path else None
    retry = RetryPolicy(max_retries=2)
    losses = []

    # resume if a checkpoint exists
    if run.ckpt is not None and run.ckpt.latest_step() is not None:
        (run.params, run.opt_state), run.step, extra = run.ckpt.restore(
            (run.params, run.opt_state)
        )
        run.pipeline.restore(extra.get("pipeline", {}))
        print(f"[train] resumed from step {run.step}")

    def save():
        if run.ckpt is not None:
            run.ckpt.save(
                run.step, (run.params, run.opt_state),
                extra={"pipeline": run.pipeline.snapshot()},
            )

    def restore():
        if run.ckpt is None or run.ckpt.latest_step() is None:
            return
        (run.params, run.opt_state), run.step, extra = run.ckpt.restore(
            (run.params, run.opt_state)
        )
        run.pipeline.restore(extra.get("pipeline", {}))
        print(f"[train] restored from step {run.step} after failure")

    while run.step < steps:
        batch_np = run.pipeline.next_batch()
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}

        def do_step():
            if injector is not None:
                injector.maybe_fail(run.step)
            t0 = time.time()
            params, opt_state, metrics = step_fn(run.params, run.opt_state, batch)
            loss = float(metrics["loss"])  # blocks; also surfaces NaN early
            dt = time.time() - t0
            return params, opt_state, metrics, dt

        params, opt_state, metrics, dt = retry.run(do_step, on_fatal=restore)
        run.params, run.opt_state = params, opt_state
        run.step += 1
        straggler = watchdog.observe(dt)
        losses.append(float(metrics["loss"]))
        if heartbeat:
            heartbeat.beat(run.step)
        if run.step % log_every == 0:
            print(
                f"[train] step={run.step} loss={losses[-1]:.4f} "
                f"lr={float(metrics['lr']):.2e} gnorm={float(metrics['grad_norm']):.3f} "
                f"dt={dt*1e3:.0f}ms{' STRAGGLER' if straggler else ''}"
            )
        if ckpt_every and run.step % ckpt_every == 0:
            save()
    save()
    return losses, watchdog


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="minitron-4b")
    ap.add_argument("--reduce", action="store_true",
                    help="shrink to a ~CPU-size model of the same family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    run = build_run(
        args.arch, reduce=args.reduce, batch=args.batch, seq=args.seq,
        steps=args.steps, ckpt_dir=args.ckpt_dir, seed=args.seed,
    )
    losses, watchdog = train(
        run, args.steps, microbatches=args.microbatches,
    )
    print(
        f"[train] done: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
        f"({watchdog.steps} steps, straggler rate {watchdog.straggler_rate:.1%})"
    )


if __name__ == "__main__":
    main()
