"""Elastic scaling: checkpoint-mediated mesh resizing + logical repartition.

Two elasticity mechanisms, mirroring the paper's claim that logical
repartitioning makes scale-in/out cheap (§4, Fig. 10):

  * **Training**: a checkpoint taken on mesh A restores onto mesh B —
    ``CheckpointManager.restore(shardings=...)`` re-places every leaf.  The
    data pipeline reshards deterministically (counter-based streams).
    ``reshard_run`` below packages that.
  * **Serving**: request key-ranges move between replicas by adjusting
    ``LogicalPartitions`` boundaries; no page movement (the DEX index keeps
    addressing the same pool), only cache re-warming — exactly the paper's
    repartition cost profile.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.core.partition import LogicalPartitions
from repro.train import sharding as SH
from repro.train.checkpoint import CheckpointManager


def reshard_checkpoint(
    ckpt: CheckpointManager,
    template,
    new_mesh,
    cfg,
    *,
    step: Optional[int] = None,
):
    """Restore (params, opt_state) onto a different mesh geometry."""
    params_t, opt_t = template
    p_sh = SH.param_shardings(params_t, new_mesh, cfg)
    o_sh = type(opt_t)(
        mu=SH.param_shardings(opt_t.mu, new_mesh, cfg),
        nu=SH.param_shardings(opt_t.nu, new_mesh, cfg),
        step=jax.NamedSharding(new_mesh, jax.sharding.PartitionSpec()),
    )
    state, got_step, extra = ckpt.restore(
        (params_t, opt_t), step=step, shardings=(p_sh, o_sh)
    )
    return state, got_step, extra


def scale_serving_partitions(
    parts: LogicalPartitions, *, target_replicas: int, loads=None
) -> Tuple[LogicalPartitions, float]:
    """Grow/shrink the serving replica set by logical repartitioning.

    Returns (new_partitions, fraction_of_keyspace_moved) — the moved
    fraction is the cache re-warm cost, the only data cost of the operation.
    """
    cur = parts.num_partitions
    new = parts
    while new.num_partitions < target_replicas:
        # split the widest (or most loaded) partition at its midpoint
        widths = [
            int(new.boundaries[i + 1]) - int(new.boundaries[i])
            for i in range(new.num_partitions)
        ]
        if loads is not None and len(loads) == new.num_partitions:
            p = max(range(new.num_partitions), key=lambda i: loads[i])
            loads = list(loads[:p]) + [loads[p] / 2, loads[p] / 2] + list(loads[p + 1:])
        else:
            p = max(range(new.num_partitions), key=lambda i: widths[i])
        lo, hi = int(new.boundaries[p]), int(new.boundaries[p + 1])
        mid = lo + (hi - lo) // 2
        new = new.split_partition(p, mid)
    while new.num_partitions > target_replicas:
        p = 0
        if loads is not None and len(loads) == new.num_partitions:
            p = min(
                range(new.num_partitions - 1),
                key=lambda i: loads[i] + loads[i + 1],
            )
            loads = list(loads[:p]) + [loads[p] + loads[p + 1]] + list(loads[p + 2:])
        new = new.merge_partitions(p)
    moved = parts.assignment_diff(new)
    return new, moved
