"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches JAX device state — device count locks at first backend init, and the
dry-run needs to set XLA_FLAGS before that happens.
"""

from __future__ import annotations

from repro.compat import make_mesh_compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (256 chips, one v5e pod-slice) or 2x16x16 (2 pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for local multi-device testing (8 host devices)."""
    return make_mesh_compat((n_data, n_model), ("data", "model"))
