import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers,
SPMD-partitions, compiles, and fits — without real hardware.

The two lines above MUST stay first: JAX locks the device count at backend
init, and the production meshes need 512 placeholder host devices.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun_results
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi   # 2 pods

Per cell this prints ``compiled.memory_analysis()`` (proves it fits) and
``compiled.cost_analysis()`` (FLOPs/bytes for §Roofline), and writes a JSON
blob consumed by benchmarks/lm_roofline.py and EXPERIMENTS.md.
"""

import argparse
import json
import sys
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ARCHS, get_config
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models.config import ArchConfig, SHAPES, ShapeCell, cell_applicable, shape_by_name
from repro.roofline import analysis as RA
from repro.train import sharding as SH
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, cell: ShapeCell, mesh) -> dict:
    """ShapeDtypeStructs for every model input of this cell."""
    b, s = cell.global_batch, cell.seq_len
    bs = SH.batch_shardings(mesh, encdec=cfg.encdec)
    if cell.kind in ("train", "prefill"):
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=bs["tokens"]),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=bs["labels"]),
        }
        if cfg.encdec:
            specs["enc_emb"] = jax.ShapeDtypeStruct(
                (b, cfg.max_source_positions, cfg.d_model),
                jnp.dtype(cfg.dtype),
                sharding=bs["enc_emb"],
            )
        return specs
    # decode: one token, dense sharded cache of length seq_len
    data = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_data = int(np.prod([mesh.shape[a] for a in data]))
    batch_ax = data if b % n_data == 0 else None  # long_500k: global_batch=1
    tok_sh = NamedSharding(mesh, P(batch_ax, None))
    cache_shapes = jax.eval_shape(
        lambda: M.init_decode_cache(cfg, b, s, enc_len=cfg.max_source_positions)
    )
    cache_sh = SH.cache_shardings(cfg, mesh, batch=b)
    cache = {
        k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=cache_sh[k])
        for k, v in cache_shapes.items()
    }
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32, sharding=tok_sh),
        "cache": cache,
        "pos": jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P())),
    }


# microbatch split for the train cell (activation-memory fit); 8 keeps
# 1-2 sequences per chip per microbatch at global_batch=256
TRAIN_MICROBATCHES = {}
DEFAULT_MICROBATCHES = 8


def _act_spec(cfg: ArchConfig, mesh):
    """Residual-stream sharding: sequence parallel for attention stacks.

    SSM/hybrid stacks get no constraint: pinning the carry's channel dim
    trips an SPMD-partitioner verifier bug in the selective-scan backward
    (dynamic-slice across the sharded dim); batch-sharded activations with
    microbatching keep those cells within budget instead."""
    from jax.sharding import NamedSharding

    data = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if cfg.ssm or cfg.hybrid_attn_every:
        return None
    return NamedSharding(mesh, P(data, "model", None))


def _param_specs(cfg: ArchConfig):
    return jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))


# ---------------------------------------------------------------------------
# cell runners
# ---------------------------------------------------------------------------


def lower_cell(cfg: ArchConfig, cell: ShapeCell, mesh, mesh_name: str,
               microbatches: Optional[int] = None):
    """Lower + compile one cell; returns (compiled, lowered)."""
    from repro.models import layers as LY

    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    LY.set_tp_context(mesh, data_axes)
    params_shapes = _param_specs(cfg)
    p_sh = SH.param_shardings(params_shapes, mesh, cfg)
    params_sds = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        params_shapes, p_sh,
    )

    if cell.kind == "train":
        opt_cfg = OptConfig()
        opt_shapes = jax.eval_shape(lambda: init_opt_state(params_shapes, opt_cfg))
        o_sh = type(opt_shapes)(
            mu=SH.param_shardings(opt_shapes.mu, mesh, cfg),
            nu=SH.param_shardings(opt_shapes.nu, mesh, cfg),
            step=NamedSharding(mesh, P()),
        )
        opt_sds = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            opt_shapes, o_sh,
        )
        mb = (
            microbatches
            if microbatches is not None
            else TRAIN_MICROBATCHES.get(cfg.name, DEFAULT_MICROBATCHES)
        )
        step = make_train_step(
            cfg, opt_cfg, microbatches=mb, act_spec=_act_spec(cfg, mesh)
        )
        specs = input_specs(cfg, cell, mesh)
        fn = jax.jit(step, donate_argnums=(0, 1))
        with mesh:
            lowered = fn.lower(params_sds, opt_sds, specs)
    elif cell.kind == "prefill":
        specs = input_specs(cfg, cell, mesh)

        def prefill_step(params, tokens, enc_emb=None):
            hidden, _ = M.forward(
                cfg, params, tokens, enc_emb=enc_emb, return_hidden=True,
                act_spec=_act_spec(cfg, mesh),
            )
            # serving needs only the last token's logits, not [B, S, V]
            head = M._head_of(cfg, params)
            logits = jnp.dot(hidden[:, -1], head, preferred_element_type=jnp.float32)
            return jnp.argmax(logits, axis=-1)

        args = [params_sds, specs["tokens"]]
        if cfg.encdec:
            fn = jax.jit(lambda p, t, e: prefill_step(p, t, e))
            args.append(specs["enc_emb"])
        else:
            fn = jax.jit(prefill_step)
        with mesh:
            lowered = fn.lower(*args)
    else:  # decode
        specs = input_specs(cfg, cell, mesh)

        def serve_step(params, tokens, cache, pos):
            logits, cache = M.decode_step(cfg, params, tokens, cache, pos)
            return jnp.argmax(logits, axis=-1), cache

        fn = jax.jit(serve_step, donate_argnums=(2,))
        with mesh:
            lowered = fn.lower(params_sds, specs["tokens"], specs["cache"], specs["pos"])

    compiled = lowered.compile()
    return compiled, lowered


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir=None,
             verbose=True, calibrate: bool = False) -> dict:
    cfg = get_config(arch)
    cell = shape_by_name(shape_name)
    ok, why = cell_applicable(cfg, cell)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    if not ok:
        result["status"] = "skipped"
        result["reason"] = why
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: {why}")
        return result
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    try:
        compiled, lowered = lower_cell(cfg, cell, mesh, mesh_kind)
    except Exception as e:  # a failure here is a bug in our sharding config
        result["status"] = "FAILED"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: FAILED {e}")
        return result
    dt = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    terms = RA.build_terms(
        arch=arch, shape_cell=cell, mesh_name=mesh_kind, chips=chips,
        cost=cost, mem_stats=mem, hlo_text=hlo, cfg=cfg,
    )
    result.update(terms.to_dict())
    result["status"] = "ok"
    result["compile_seconds"] = dt

    if calibrate:
        # loop-aware totals: XLA counts while bodies once, so the raw
        # cost_analysis above is a per-iteration sample; the two-point
        # layer probe recovers full-step totals (roofline/calibrate.py)
        from repro.roofline import calibrate as CAL

        def lower_probe(pcfg, pcell, pmesh, pmesh_name):
            compiled_p, _ = lower_cell(
                pcfg, pcell, pmesh, pmesh_name, microbatches=1
            )
            return compiled_p

        cal = CAL.calibrated_terms(cfg, cell, mesh, mesh_kind, lower_probe)
        result["cal_flops_per_chip"] = cal["flops"]
        result["cal_bytes_per_chip"] = cal["bytes"]
        result["cal_collective_per_chip"] = cal["collective"]
        from repro.roofline.analysis import HBM_BW, ICI_BW, PEAK_FLOPS

        ct = cal["flops"] / PEAK_FLOPS
        mt = cal["bytes"] / HBM_BW
        lt = cal["collective"] / ICI_BW
        result["cal_compute_term_s"] = ct
        result["cal_memory_term_s"] = mt
        result["cal_collective_term_s"] = lt
        result["cal_dominant"] = max(
            [("compute", ct), ("memory", mt), ("collective", lt)],
            key=lambda kv: kv[1],
        )[0]
        bound = max(ct, mt, lt)
        result["cal_useful_ratio"] = terms.model_flops / max(
            cal["flops"] * terms.chips, 1.0
        )
        result["cal_roofline_fraction"] = (
            terms.model_flops / (terms.chips * PEAK_FLOPS * bound)
            if bound > 0 else float("nan")
        )
        if verbose:
            print(
                f"  calibrated: compute={ct:.3e}s memory={mt:.3e}s "
                f"collective={lt:.3e}s dominant={result['cal_dominant']} "
                f"useful={result['cal_useful_ratio']:.2f} "
                f"roofline={result['cal_roofline_fraction']:.3f}"
            )

    if verbose:
        gb = terms.per_device_memory_bytes / 2**30
        print(
            f"[dryrun] {arch} x {shape_name} x {mesh_kind}: OK "
            f"({dt:.1f}s compile) mem/chip={gb:.2f}GiB "
            f"flops/chip={terms.hlo_flops_per_chip:.3e} "
            f"coll/chip={terms.collective_bytes_per_chip:.3e}B "
            f"dominant={terms.dominant}"
        )
        print(f"  memory_analysis: {mem}")
        if cost:
            keys = {k: v for k, v in cost.items()
                    if k in ("flops", "bytes accessed")}
            print(f"  cost_analysis: {keys}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch}__{shape_name}__{mesh_kind}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(result, f, indent=1)
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=[s.name for s in SHAPES], default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--out", default="dryrun_results")
    ap.add_argument("--calibrate", action="store_true",
                    help="add loop-aware calibrated roofline terms "
                         "(two extra probe compiles per cell)")
    args = ap.parse_args(argv)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = sorted(ARCHS) if args.all or args.arch is None else [args.arch]
    shapes = (
        [s.name for s in SHAPES]
        if args.all or args.shape is None
        else [args.shape]
    )

    failures = []
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                r = run_cell(arch, shape, mesh_kind, out_dir=args.out,
                             calibrate=args.calibrate)
                if r["status"] == "FAILED":
                    failures.append(r)
    if failures:
        print(f"\n{len(failures)} cell(s) FAILED:")
        for f in failures:
            print(f"  {f['arch']} x {f['shape']} x {f['mesh']}: {f['error']}")
        sys.exit(1)
    print("\nall requested dry-run cells passed")


if __name__ == "__main__":
    main()
