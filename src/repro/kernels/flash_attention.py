"""Pallas kernel: blocked flash attention (prefill path).

Standard online-softmax tiling for the LM stack's perf-critical prefill:
grid (batch*heads, q_blocks, kv_blocks) with f32 running max/denominator/
accumulator in VMEM scratch that persists across the sequential kv grid
dimension.  Causal blocks fully above the diagonal are skipped via
``pl.when`` (halving prefill FLOPs).  GQA is handled by an index map that
points each query head at its kv group — no KV replication in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale, causal, bq, bk, nk, q_offset,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    # causal: skip kv blocks strictly above this q block's last row
    if causal:
        run = (ki * bk) <= (qi * bq + bq - 1 + q_offset)
    else:
        run = True

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale        # [bq, D]
        k = k_ref[0].astype(jnp.float32)                # [bk, D]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                               # [bq, bk]
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos + q_offset >= kpos, s, NEG_INF)
        m_prev = m_scr[...]                             # [bq, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                          # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)                 # [bq, 1]
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret", "scale"),
)
def flash_attention(
    q: jax.Array,  # [B, H, Sq, D]
    k: jax.Array,  # [B, HKV, Sk, D]
    v: jax.Array,  # [B, HKV, Sk, D]
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
):
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert h % hkv == 0, "query heads must be a multiple of kv heads"
    group = h // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, "seq lens must tile"
    nq, nk = sq // bq, sk // bk

    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * hkv, sk, d)
    vf = v.reshape(b * hkv, sk, d)

    def kv_index(bh, qi, ki):
        return (bh // h) * hkv + (bh % h) // group, ki, 0

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, bq=bq, bk=bk, nk=nk, q_offset=sk - sq,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)
