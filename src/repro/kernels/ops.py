"""Public jit'd entry points for every Pallas kernel.

``interpret`` defaults to True so the whole framework runs on CPU; the
launcher flips it to False on real TPU backends (see launch/train.py).
Oracles live in kernels/ref.py with identical signatures.
"""

from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention
from repro.kernels.leaf_scan import leaf_scan
from repro.kernels.leaf_split import leaf_split
from repro.kernels.leaf_write import leaf_write
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.node_search import node_search
from repro.kernels.paged_attention import paged_attention
from repro.kernels.subtree_walk import subtree_walk

__all__ = [
    "flash_attention",
    "leaf_scan",
    "leaf_split",
    "leaf_write",
    "mamba_scan",
    "node_search",
    "paged_attention",
    "subtree_walk",
    "use_interpret",
]


def use_interpret() -> bool:
    """Kernels execute their Python bodies (interpret mode) unless a real
    TPU backend is present."""
    return jax.default_backend() != "tpu"
