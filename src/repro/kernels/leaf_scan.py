"""Pallas kernel: fence-key-subdivided multi-leaf range-scan compaction.

The compute core of the mesh-plane range scan (paper §7 Range Query): after
the traversal layer has assembled, per scan lane, a *window* of consecutive
leaf rows (the start leaf plus its successors in global leaf order), this
kernel performs

  1. a vectorized in-leaf lower bound — mask out keys below the start key and
     KEY_MAX padding (empty slots / out-of-range leaves);
  2. a masked gather ("compaction") of up to ``count`` surviving rows into a
     dense [B, max_count] result, preserving ascending key order.

Because leaves are consecutive in key order, the surviving keys are already
sorted in window-slot order, so the gather is rank-based: element with
selection rank ``j`` lands in output column ``j``.  On TPU the rank is a
lane-wise ``cumsum`` and the gather a one-hot compare+reduce over the window
— branchless VPU work, no scatter (DESIGN.md §3).

int64 keys/values travel as (hi, lo) int32 planes like kernels/node_search.py
(the TPU VPU has no native 64-bit lanes).  The pure-jnp oracle is
``kernels/ref.py::leaf_scan_ref``; ``interpret=True`` (the default off-TPU)
runs the same body through the Pallas interpreter.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.nodes import KEY_MAX

BLOCK_B = 8

# KEY_MAX = 0x7FFF_FFFF_FFFF_FFFF as (hi, lo-reinterpreted-signed) planes
_KMAX_HI = np.int32(0x7FFFFFFF)
_KMAX_LO = np.int32(-1)


def _split_i64(x: jax.Array):
    """int64 -> (hi int32, lo uint32-as-int32) planes."""
    hi = (x >> 32).astype(jnp.int32)
    lo = (x & jnp.int64(0xFFFFFFFF)).astype(jnp.uint32).astype(jnp.int32)
    return hi, lo


def _join_i64(hi: jax.Array, lo: jax.Array) -> jax.Array:
    return (hi.astype(jnp.int64) << 32) | lo.astype(jnp.uint32).astype(jnp.int64)


def _geq_planes(khi, klo, qhi, qlo):
    """(khi,klo) >= (qhi,qlo) treating lo as unsigned."""
    flip = jnp.int32(-0x80000000)
    return (khi > qhi) | ((khi == qhi) & ((klo ^ flip) >= (qlo ^ flip)))


def _make_kernel(max_count: int):
    def kernel(
        khi_ref, klo_ref, vhi_ref, vlo_ref, shi_ref, slo_ref, cnt_ref,
        okhi_ref, oklo_ref, ovhi_ref, ovlo_ref, taken_ref,
    ):
        khi = khi_ref[...]                     # [B, W] int32
        klo = klo_ref[...]
        shi = shi_ref[...]                     # [B] int32
        slo = slo_ref[...]
        cnt = cnt_ref[...]                     # [B] int32

        # 1. in-leaf lower bound, vectorized over the whole window: drop
        #    KEY_MAX padding and keys below the start key
        valid = ~((khi == _KMAX_HI) & (klo == _KMAX_LO))
        geq = _geq_planes(khi, klo, shi[:, None], slo[:, None])
        mask = valid & geq
        rank = jnp.cumsum(mask.astype(jnp.int32), axis=-1,
                          dtype=jnp.int32)                   # [B, W]
        sel = mask & (rank <= cnt[:, None])
        taken_ref[...] = jnp.sum(sel, axis=-1, dtype=jnp.int32)

        # 2. rank-based masked gather: window element with selection rank
        #    j+1 -> output column j (one-hot compare + reduce, no scatter)
        srank = jnp.where(sel, rank, 0)                      # [B, W]
        jcol = jax.lax.broadcasted_iota(
            jnp.int32, (1, max_count, 1), 1
        ) + 1                                                # [1, MC, 1]
        pick = srank[:, None, :] == jcol                     # [B, MC, W]
        hit = jnp.any(pick, axis=-1)                         # [B, MC]

        def compact(plane, fill):
            got = jnp.sum(
                jnp.where(pick, plane[:, None, :], 0), axis=-1, dtype=jnp.int32
            )
            return jnp.where(hit, got, fill)

        okhi_ref[...] = compact(khi, _KMAX_HI)
        oklo_ref[...] = compact(klo, _KMAX_LO)
        ovhi_ref[...] = compact(vhi_ref[...], 0)
        ovlo_ref[...] = compact(vlo_ref[...], 0)

    return kernel


@functools.partial(
    jax.jit, static_argnames=("max_count", "interpret", "block_b")
)
def leaf_scan(
    window_keys: jax.Array,    # [B, W] int64, W = hops * FANOUT
    window_values: jax.Array,  # [B, W] int64
    start_keys: jax.Array,     # [B] int64
    counts: jax.Array,         # [B] int32/int64
    *,
    max_count: int,
    interpret: bool = True,
    block_b: int = BLOCK_B,
):
    """Compact up to ``counts[b]`` records with key >= ``start_keys[b]`` out
    of each lane's leaf window.  Returns ``(keys [B, max_count] int64
    KEY_MAX-padded, values [B, max_count] int64, taken [B] int32)``."""
    b, w = window_keys.shape
    counts = jnp.clip(counts.astype(jnp.int32), 0, max_count)
    pad = (-b) % block_b
    if pad:
        window_keys = jnp.pad(window_keys, ((0, pad), (0, 0)),
                              constant_values=KEY_MAX)
        window_values = jnp.pad(window_values, ((0, pad), (0, 0)))
        start_keys = jnp.pad(start_keys, (0, pad))
        counts = jnp.pad(counts, (0, pad))
    bp = window_keys.shape[0]

    khi, klo = _split_i64(window_keys)
    vhi, vlo = _split_i64(window_values)
    shi, slo = _split_i64(start_keys.astype(jnp.int64))

    grid = (bp // block_b,)
    row = pl.BlockSpec((block_b, w), lambda i: (i, 0))
    out_row = pl.BlockSpec((block_b, max_count), lambda i: (i, 0))
    lane = pl.BlockSpec((block_b,), lambda i: (i,))
    okhi, oklo, ovhi, ovlo, taken = pl.pallas_call(
        _make_kernel(max_count),
        grid=grid,
        in_specs=[row, row, row, row, lane, lane, lane],
        out_specs=[out_row, out_row, out_row, out_row, lane],
        out_shape=[
            jax.ShapeDtypeStruct((bp, max_count), jnp.int32),
            jax.ShapeDtypeStruct((bp, max_count), jnp.int32),
            jax.ShapeDtypeStruct((bp, max_count), jnp.int32),
            jax.ShapeDtypeStruct((bp, max_count), jnp.int32),
            jax.ShapeDtypeStruct((bp,), jnp.int32),
        ],
        interpret=interpret,
    )(khi, klo, vhi, vlo, shi, slo, counts)
    out_k = _join_i64(okhi, oklo)
    out_v = _join_i64(ovhi, ovlo)
    return out_k[:b], out_v[:b], taken[:b]
