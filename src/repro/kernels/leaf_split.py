"""Pallas kernel: leaf split + pending-insert merge for the on-mesh SMO
engine (core/smo.py).

Given one leaf row per lane plus the staged inserts that made it overflow,
the kernel rank-merges row keys and staged keys into one sorted sequence of
``m`` records and emits it as **two** rows:

  * ``m <= FANOUT``: everything lands in the *left* row (a plain merge, the
    same result as ``leaf_write`` with no updates staged) and the right row
    comes back empty — the caller applies the left row in place and no
    structural change happens;
  * ``m > FANOUT``: the sequence is cut at ``m // 2`` — the left row keeps
    the lower half (matching ``HostBTree._split_child``), the right row gets
    the upper half, and ``sep`` carries the right row's first key (the
    separator the parent absorbs).  ``did_split`` marks the lane.

The caller (core/smo.py) allocates the sibling slot from the subtree's
free-list headroom, writes the right row there, links the leaf-successor
table and merges ``(sep, sibling)`` into the parent node — the kernel is
purely the in-VMEM cut + merge.

Caller contract (mirroring kernels/leaf_write.py): active staged keys are
strictly ascending within a lane, distinct from each other and from the
row's keys; at most ``FANOUT`` staged keys per lane, so ``m <= 2 * FANOUT``
and one split always absorbs the whole batch.

int64 keys/values travel as (hi, lo) int32 planes (the TPU VPU has no
native 64-bit lanes).  The pure-jnp oracle is
``kernels/ref.py::leaf_split_ref``; ``interpret=True`` (the default off-TPU)
runs the same body through the Pallas interpreter.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.nodes import KEY_MAX
from repro.kernels.leaf_write import (
    _KMAX_HI,
    _KMAX_LO,
    _join_i64,
    _lt_planes,
    _split_i64,
)

BLOCK_B = 8


def _make_kernel(fanout: int):
    def kernel(
        khi_ref, klo_ref, vhi_ref, vlo_ref,
        ikh_ref, ikl_ref, ivh_ref, ivl_ref,
        lkh_ref, lkl_ref, lvh_ref, lvl_ref,
        rkh_ref, rkl_ref, rvh_ref, rvl_ref,
        occl_ref, occr_ref, sep_hi_ref, sep_lo_ref, did_ref,
    ):
        khi = khi_ref[...]                     # [B, F] int32 planes
        klo = klo_ref[...]
        ikh = ikh_ref[...]                     # [B, S]
        ikl = ikl_ref[...]

        col = jax.lax.broadcasted_iota(jnp.int32, (1, 1, fanout), 2)

        # merged rank of every element (same branchless pairwise compares as
        # kernels/leaf_write.py: actives are distinct, KEY_MAX never counts)
        act = ~((ikh == _KMAX_HI) & (ikl == _KMAX_LO))            # [B, S]
        validr = ~((khi == _KMAX_HI) & (klo == _KMAX_LO))         # [B, F]
        ins_below_row = act[:, :, None] & _lt_planes(
            ikh[:, :, None], ikl[:, :, None], khi[:, None, :], klo[:, None, :]
        )                                                         # [B, S, F]
        rank_row = col[0] + jnp.sum(ins_below_row.astype(jnp.int32), axis=1)
        before = jnp.cumsum(act.astype(jnp.int32), axis=1) - act.astype(
            jnp.int32
        )                                                         # [B, S]
        row_below_ins = validr[:, None, :] & _lt_planes(
            khi[:, None, :], klo[:, None, :], ikh[:, :, None], ikl[:, :, None]
        )                                                         # [B, S, F]
        rank_ins = before + jnp.sum(row_below_ins.astype(jnp.int32), axis=2)

        # cut point: m <= F keeps everything left; m > F cuts at m // 2
        m = (
            jnp.sum(validr.astype(jnp.int32), axis=-1)
            + jnp.sum(act.astype(jnp.int32), axis=-1)
        )                                                         # [B]
        split = m > fanout
        left_n = jnp.where(split, m // 2, m)                      # [B]

        out_col = jax.lax.broadcasted_iota(jnp.int32, (1, fanout, 1), 1)
        ln = left_n[:, None, None]

        def gather(sel_rank_row, sel_rank_ins, target):
            """One-hot gather of elements whose shifted rank hits ``target``
            output columns; returns the pick masks [B, F, F|S]."""
            pr = validr[:, None, :] & (sel_rank_row[:, None, :] == target)
            pi = act[:, None, :] & (sel_rank_ins[:, None, :] == target)
            return pr, pi

        # left side: rank < left_n at column rank
        pick_row_l, pick_ins_l = gather(rank_row, rank_ins, out_col)
        keep_l = out_col < ln
        pick_row_l = pick_row_l & keep_l
        pick_ins_l = pick_ins_l & keep_l
        # right side: rank >= left_n at column rank - left_n
        pick_row_r, pick_ins_r = gather(
            rank_row - left_n[:, None], rank_ins - left_n[:, None], out_col
        )
        keep_r = split[:, None, None]
        pick_row_r = pick_row_r & keep_r
        pick_ins_r = pick_ins_r & keep_r

        hit_l = jnp.any(pick_row_l, axis=-1) | jnp.any(pick_ins_l, axis=-1)
        hit_r = jnp.any(pick_row_r, axis=-1) | jnp.any(pick_ins_r, axis=-1)

        def compact(pick_row, pick_ins, hit, plane_row, plane_ins, fill):
            got = jnp.sum(
                jnp.where(pick_row, plane_row[:, None, :], 0), axis=-1,
                dtype=jnp.int32,
            ) + jnp.sum(
                jnp.where(pick_ins, plane_ins[:, None, :], 0), axis=-1,
                dtype=jnp.int32,
            )
            return jnp.where(hit, got, fill)

        vhi = vhi_ref[...]
        vlo = vlo_ref[...]
        ivh = ivh_ref[...]
        ivl = ivl_ref[...]
        lkh_ref[...] = compact(pick_row_l, pick_ins_l, hit_l, khi, ikh, _KMAX_HI)
        lkl_ref[...] = compact(pick_row_l, pick_ins_l, hit_l, klo, ikl, _KMAX_LO)
        lvh_ref[...] = compact(pick_row_l, pick_ins_l, hit_l, vhi, ivh, 0)
        lvl_ref[...] = compact(pick_row_l, pick_ins_l, hit_l, vlo, ivl, 0)
        rkh_ref[...] = compact(pick_row_r, pick_ins_r, hit_r, khi, ikh, _KMAX_HI)
        rkl_ref[...] = compact(pick_row_r, pick_ins_r, hit_r, klo, ikl, _KMAX_LO)
        rvh_ref[...] = compact(pick_row_r, pick_ins_r, hit_r, vhi, ivh, 0)
        rvl_ref[...] = compact(pick_row_r, pick_ins_r, hit_r, vlo, ivl, 0)
        occl_ref[...] = jnp.sum(hit_l, axis=-1, dtype=jnp.int32)
        occr_ref[...] = jnp.sum(hit_r, axis=-1, dtype=jnp.int32)

        # separator = the merged element of rank left_n (right row's head)
        sep_row = validr & (rank_row == left_n[:, None])          # [B, F]
        sep_ins = act & (rank_ins == left_n[:, None])             # [B, S]

        def pick_sep(plane_row, plane_ins, fill):
            got = jnp.sum(
                jnp.where(sep_row, plane_row, 0), axis=-1, dtype=jnp.int32
            ) + jnp.sum(
                jnp.where(sep_ins, plane_ins, 0), axis=-1, dtype=jnp.int32
            )
            has = jnp.any(sep_row, axis=-1) | jnp.any(sep_ins, axis=-1)
            return jnp.where(split & has, got, fill)

        sep_hi_ref[...] = pick_sep(khi, ikh, _KMAX_HI)
        sep_lo_ref[...] = pick_sep(klo, ikl, _KMAX_LO)
        did_ref[...] = split.astype(jnp.int32)

    return kernel


@functools.partial(jax.jit, static_argnames=("interpret", "block_b"))
def leaf_split(
    rows_k: jax.Array,   # [Q, F] int64 leaf key rows (KEY_MAX padding)
    rows_v: jax.Array,   # [Q, F] int64 leaf value rows
    ins_key: jax.Array,  # [Q, S] int64 staged insert keys (KEY_MAX inactive)
    ins_val: jax.Array,  # [Q, S] int64 staged insert values
    *,
    interpret: bool = True,
    block_b: int = BLOCK_B,
):
    """Merge staged inserts into each leaf row, splitting rows that
    overflow.  Returns ``(left_k [Q, F], left_v [Q, F], right_k [Q, F],
    right_v [Q, F], occ_l [Q] int32, occ_r [Q] int32, sep [Q] int64,
    did_split [Q] int32)`` — ``sep`` is ``KEY_MAX`` and the right row empty
    for lanes that did not split."""
    q, f = rows_k.shape
    s = ins_key.shape[1]
    pad = (-q) % block_b
    if pad:
        rows_k = jnp.pad(rows_k, ((0, pad), (0, 0)), constant_values=KEY_MAX)
        rows_v = jnp.pad(rows_v, ((0, pad), (0, 0)))
        ins_key = jnp.pad(ins_key, ((0, pad), (0, 0)), constant_values=KEY_MAX)
        ins_val = jnp.pad(ins_val, ((0, pad), (0, 0)))
    qp = rows_k.shape[0]

    khi, klo = _split_i64(rows_k)
    vhi, vlo = _split_i64(rows_v)
    ikh, ikl = _split_i64(ins_key)
    ivh, ivl = _split_i64(ins_val)

    grid = (qp // block_b,)
    row = pl.BlockSpec((block_b, f), lambda i: (i, 0))
    staged = pl.BlockSpec((block_b, s), lambda i: (i, 0))
    lane = pl.BlockSpec((block_b,), lambda i: (i,))
    outs = pl.pallas_call(
        _make_kernel(f),
        grid=grid,
        in_specs=[row, row, row, row, staged, staged, staged, staged],
        out_specs=[row, row, row, row, row, row, row, row,
                   lane, lane, lane, lane, lane],
        out_shape=[jax.ShapeDtypeStruct((qp, f), jnp.int32)] * 8
        + [jax.ShapeDtypeStruct((qp,), jnp.int32)] * 5,
        interpret=interpret,
    )(khi, klo, vhi, vlo, ikh, ikl, ivh, ivl)
    lkh, lkl, lvh, lvl, rkh, rkl, rvh, rvl, occl, occr, sh, sl, did = outs
    return (
        _join_i64(lkh, lkl)[:q],
        _join_i64(lvh, lvl)[:q],
        _join_i64(rkh, rkl)[:q],
        _join_i64(rvh, rvl)[:q],
        occl[:q],
        occr[:q],
        _join_i64(sh, sl)[:q],
        did[:q],
    )
