"""Pallas kernel: paged decode attention (the DEX-paged KV consumer).

Serving (serve/kv_cache.py) stores KV in fixed-size pages indexed by the DEX
B+-tree; this kernel consumes the resolved page table.  Grid is
(batch, kv_heads, pages_per_request) with the *page table prefetched as
scalars* so each kv block's index map dereferences ``table[b, p]`` — the TPU
idiom for pointer indirection (scalar prefetch + dynamic block index), i.e.
the same "resolve remote pointer, then stream the node" pattern as DEX's
fetch path, one level down the memory hierarchy.

Online softmax runs across the sequential page dimension in VMEM scratch;
positions beyond ``seq_len`` are masked.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(
    table_ref, seqlen_ref,            # scalar prefetch
    q_ref, k_ref, v_ref,
    o_ref,
    m_scr, l_scr, acc_scr,
    *, page, n_pages, scale,
):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    seq_len = seqlen_ref[b]
    # pages beyond the request's length are skipped entirely
    run = (p * page) < seq_len

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # [G, D]
        k = k_ref[0, :, 0].astype(jnp.float32)            # [page, D]
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                  # [G, page]
        pos = p * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < seq_len, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        pr = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(pr, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            pr, v, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(p == n_pages - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(
    q: jax.Array,         # [B, H, D] one decode token per request
    k_pages: jax.Array,   # [P, page, HKV, D]
    v_pages: jax.Array,   # [P, page, HKV, D]
    page_table: jax.Array,  # [B, pages_per_req] int32 (DEX-resolved)
    seq_lens: jax.Array,  # [B] int32
    *,
    interpret: bool = True,
):
    b, h, d = q.shape
    _, page, hkv, _ = k_pages.shape
    assert h % hkv == 0
    group = h // hkv
    ppr = page_table.shape[1]
    scale = 1.0 / np.sqrt(d)

    # [B, HKV, G, D]: queries grouped by kv head
    qg = q.reshape(b, hkv, group, d)

    grid = (b, hkv, ppr)

    def q_index(table, b_, n, p):
        del table, p
        return (b_, n, 0, 0)

    def kv_index(table, b_, n, p):
        return (table[b_, p], 0, n, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, group, d), lambda b_, n, p, table, sl: (b_, n, 0, 0)),
            pl.BlockSpec((1, page, 1, d), lambda b_, n, p, table, sl: (table[b_, p], 0, n, 0)),
            pl.BlockSpec((1, page, 1, d), lambda b_, n, p, table, sl: (table[b_, p], 0, n, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, group, d), lambda b_, n, p, table, sl: (b_, n, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _paged_kernel, page=page, n_pages=ppr, scale=scale
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, d), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), seq_lens.astype(jnp.int32), qg, k_pages, v_pages)
    return out.reshape(b, h, d)
