"""Pallas kernel: batched leaf mutation for the mesh-plane write path.

The compute core of ``core/write.py``: after the owning memory column has
grouped a batch of write requests by target leaf (one row per touched leaf),
this kernel applies, per 1KB leaf row,

  1. a *masked value scatter* — staged in-place updates ``(slot, value)``
     land at their slot via a one-hot compare+reduce (no scatter primitive);
  2. a *rank-based insert merge* — staged new keys (pre-sorted and
     deduplicated by the caller) are merged into the row's slack slots while
     keeping the row sorted: every element's output column is its rank,
     computed with branchless pairwise compares (row-vs-staged both ways),
     then gathered one-hot.  This is the SPMD form of "append into the leaf's
     slack space";
  3. an *occupancy bump* — the new number of live keys per row.

Caller contract (enforced by core/write.py): active staged insert keys are
strictly ascending within a row, distinct from the row's existing keys, and
the row has enough slack (overflowing leaves are shed *before* the kernel —
the host SMO path replays them).  Staged updates target distinct slots.

int64 keys/values travel as (hi, lo) int32 planes like kernels/leaf_scan.py
(the TPU VPU has no native 64-bit lanes).  The pure-jnp oracle is
``kernels/ref.py::leaf_write_ref``; ``interpret=True`` (the default off-TPU)
runs the same body through the Pallas interpreter.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.nodes import KEY_MAX

BLOCK_B = 8

# KEY_MAX = 0x7FFF_FFFF_FFFF_FFFF as (hi, lo-reinterpreted-signed) planes
_KMAX_HI = np.int32(0x7FFFFFFF)
_KMAX_LO = np.int32(-1)


def _split_i64(x: jax.Array):
    """int64 -> (hi int32, lo uint32-as-int32) planes."""
    hi = (x >> 32).astype(jnp.int32)
    lo = (x & jnp.int64(0xFFFFFFFF)).astype(jnp.uint32).astype(jnp.int32)
    return hi, lo


def _join_i64(hi: jax.Array, lo: jax.Array) -> jax.Array:
    return (hi.astype(jnp.int64) << 32) | lo.astype(jnp.uint32).astype(jnp.int64)


def _lt_planes(ahi, alo, bhi, blo):
    """(ahi,alo) < (bhi,blo) treating lo as unsigned."""
    flip = jnp.int32(-0x80000000)
    return (ahi < bhi) | ((ahi == bhi) & ((alo ^ flip) < (blo ^ flip)))


def _make_kernel(fanout: int):
    def kernel(
        khi_ref, klo_ref, vhi_ref, vlo_ref,
        us_ref, uvh_ref, uvl_ref,
        ikh_ref, ikl_ref, ivh_ref, ivl_ref,
        okh_ref, okl_ref, ovh_ref, ovl_ref, occ_ref,
    ):
        khi = khi_ref[...]                     # [B, F] int32 planes
        klo = klo_ref[...]
        vhi = vhi_ref[...]
        vlo = vlo_ref[...]
        us = us_ref[...]                       # [B, S] int32 (-1 inactive)
        ikh = ikh_ref[...]                     # [B, S]
        ikl = ikl_ref[...]

        col = jax.lax.broadcasted_iota(jnp.int32, (1, 1, fanout), 2)

        # 1. masked value scatter: staged update j lands at column us[j]
        #    (one-hot compare + reduce; staged slots are distinct per row)
        umask = us >= 0                        # [B, S]
        onehot = umask[:, :, None] & (us[:, :, None] == col)      # [B, S, F]
        has_u = jnp.any(onehot, axis=1)                           # [B, F]

        def upd_pick(plane):
            return jnp.sum(jnp.where(onehot, plane[:, :, None], 0), axis=1,
                           dtype=jnp.int32)

        v1h = jnp.where(has_u, upd_pick(uvh_ref[...]), vhi)
        v1l = jnp.where(has_u, upd_pick(uvl_ref[...]), vlo)

        # 2. rank-based insert merge.  Active staged keys are distinct from
        #    each other and from the row's keys, so strict compares give a
        #    total order; KEY_MAX padding never participates.
        act = ~((ikh == _KMAX_HI) & (ikl == _KMAX_LO))            # [B, S]
        validr = ~((khi == _KMAX_HI) & (klo == _KMAX_LO))         # [B, F]
        # row element i keeps its index plus the staged keys below it
        ins_below_row = act[:, :, None] & _lt_planes(
            ikh[:, :, None], ikl[:, :, None], khi[:, None, :], klo[:, None, :]
        )                                                         # [B, S, F]
        rank_row = col[0] + jnp.sum(ins_below_row.astype(jnp.int32), axis=1)
        # staged element j: actives before it plus the row keys below it
        before = jnp.cumsum(act.astype(jnp.int32), axis=1) - act.astype(
            jnp.int32
        )                                                         # [B, S]
        row_below_ins = validr[:, None, :] & _lt_planes(
            khi[:, None, :], klo[:, None, :], ikh[:, :, None], ikl[:, :, None]
        )                                                         # [B, S, F]
        rank_ins = before + jnp.sum(row_below_ins.astype(jnp.int32), axis=2)

        # 3. one-hot rank gather into the F output columns + occupancy bump
        out_col = jax.lax.broadcasted_iota(jnp.int32, (1, fanout, 1), 1)
        pick_row = validr[:, None, :] & (rank_row[:, None, :] == out_col)
        pick_ins = act[:, None, :] & (rank_ins[:, None, :] == out_col)
        hit = jnp.any(pick_row, axis=-1) | jnp.any(pick_ins, axis=-1)

        def compact(plane_row, plane_ins, fill):
            got = jnp.sum(
                jnp.where(pick_row, plane_row[:, None, :], 0), axis=-1,
                dtype=jnp.int32,
            ) + jnp.sum(
                jnp.where(pick_ins, plane_ins[:, None, :], 0), axis=-1,
                dtype=jnp.int32,
            )
            return jnp.where(hit, got, fill)

        okh_ref[...] = compact(khi, ikh, _KMAX_HI)
        okl_ref[...] = compact(klo, ikl, _KMAX_LO)
        ovh_ref[...] = compact(v1h, ivh_ref[...], 0)
        ovl_ref[...] = compact(v1l, ivl_ref[...], 0)
        occ_ref[...] = jnp.sum(hit, axis=-1, dtype=jnp.int32)

    return kernel


@functools.partial(jax.jit, static_argnames=("interpret", "block_b"))
def leaf_write(
    rows_k: jax.Array,    # [Q, F] int64 leaf key rows (KEY_MAX padding)
    rows_v: jax.Array,    # [Q, F] int64 leaf value rows
    upd_slot: jax.Array,  # [Q, S] int32 staged update slots (-1 inactive)
    upd_val: jax.Array,   # [Q, S] int64 staged update values
    ins_key: jax.Array,   # [Q, S] int64 staged insert keys (KEY_MAX inactive)
    ins_val: jax.Array,   # [Q, S] int64 staged insert values
    *,
    interpret: bool = True,
    block_b: int = BLOCK_B,
):
    """Apply one batch of staged writes per leaf row.  Returns ``(new_keys
    [Q, F] int64, new_values [Q, F] int64, new_occupancy [Q] int32)``."""
    q, f = rows_k.shape
    s = upd_slot.shape[1]
    pad = (-q) % block_b
    if pad:
        rows_k = jnp.pad(rows_k, ((0, pad), (0, 0)), constant_values=KEY_MAX)
        rows_v = jnp.pad(rows_v, ((0, pad), (0, 0)))
        upd_slot = jnp.pad(upd_slot, ((0, pad), (0, 0)), constant_values=-1)
        upd_val = jnp.pad(upd_val, ((0, pad), (0, 0)))
        ins_key = jnp.pad(ins_key, ((0, pad), (0, 0)), constant_values=KEY_MAX)
        ins_val = jnp.pad(ins_val, ((0, pad), (0, 0)))
    qp = rows_k.shape[0]

    khi, klo = _split_i64(rows_k)
    vhi, vlo = _split_i64(rows_v)
    uvh, uvl = _split_i64(upd_val)
    ikh, ikl = _split_i64(ins_key)
    ivh, ivl = _split_i64(ins_val)

    grid = (qp // block_b,)
    row = pl.BlockSpec((block_b, f), lambda i: (i, 0))
    staged = pl.BlockSpec((block_b, s), lambda i: (i, 0))
    lane = pl.BlockSpec((block_b,), lambda i: (i,))
    okh, okl, ovh, ovl, occ = pl.pallas_call(
        _make_kernel(f),
        grid=grid,
        in_specs=[row, row, row, row,
                  staged, staged, staged,
                  staged, staged, staged, staged],
        out_specs=[row, row, row, row, lane],
        out_shape=[
            jax.ShapeDtypeStruct((qp, f), jnp.int32),
            jax.ShapeDtypeStruct((qp, f), jnp.int32),
            jax.ShapeDtypeStruct((qp, f), jnp.int32),
            jax.ShapeDtypeStruct((qp, f), jnp.int32),
            jax.ShapeDtypeStruct((qp,), jnp.int32),
        ],
        interpret=interpret,
    )(khi, klo, vhi, vlo, upd_slot.astype(jnp.int32), uvh, uvl,
      ikh, ikl, ivh, ivl)
    out_k = _join_i64(okh, okl)
    out_v = _join_i64(ovh, ovl)
    return out_k[:q], out_v[:q], occ[:q]
