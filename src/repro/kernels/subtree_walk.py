"""Pallas kernel: whole-subtree traversal (the offload executor).

This is the memory-server side of the paper's opportunistic offloading (§6):
upon receiving a pushed-down operation, the owner walks the level-M subtree
locally and returns only the result.  On TPU the subtree block (paper: all
nodes below level M, grouped on one server) is staged once into VMEM and a
batch of queries walks it level-synchronously.

TPU adaptation (DESIGN.md §2): the CPU's pointer-chasing loop becomes a
*one-hot matmul gather* on the MXU — selecting node rows via
``onehot([Bq, C]) @ plane([C, F])``.  Because every one-hot row has exactly
one nonzero, f32 accumulation is exact as long as each operand plane fits the
f32 mantissa; int64 keys/values are therefore carried as four 16-bit planes
and int32 children as two.  Pointer dereference -> systolic array work, which
is the idiomatic TPU replacement for irregular memory access.

VMEM budget: a subtree block of C nodes holds 10 f32 planes of [C, 64]:
C=45 (M=1) -> 115 KiB; C=1981 (M=2) -> ~5 MiB.  Both fit v5e VMEM (~16 MiB);
M=3 blocks must stream (not needed: the serving integration uses M<=2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.nodes import FANOUT

BLOCK_B = 128


def _planes16_i64(x: jax.Array):
    """int64 -> four f32 planes of 16 bits each (exact in f32)."""
    x = x.astype(jnp.int64)
    return [
        ((x >> (16 * (3 - i))) & jnp.int64(0xFFFF)).astype(jnp.float32)
        for i in range(4)
    ]


def _planes16_i32(x: jax.Array):
    x = x.astype(jnp.int32)
    return [
        ((x >> (16 * (1 - i))) & jnp.int32(0xFFFF)).astype(jnp.float32)
        for i in range(2)
    ]


def _recombine_i64_hi_lo(p0, p1, p2, p3):
    """Four 16-bit planes -> (hi, lo) int32 with original bit patterns."""
    hi = (p0.astype(jnp.int32) << 16) | p1.astype(jnp.int32)
    lo = (p2.astype(jnp.int32) << 16) | p3.astype(jnp.int32)
    return hi, lo


def _leq_hi_lo(khi, klo, qhi, qlo):
    flip = jnp.int32(-0x80000000)
    return (khi < qhi) | ((khi == qhi) & ((klo ^ flip) <= (qlo ^ flip)))


def _make_kernel(levels: int, c_nodes: int):
    iota_c = None

    def kernel(
        # key planes [C, F] f32 x4, child planes x2, value planes x4
        k0, k1, k2, k3, c0, c1, v0, v1, v2, v3,
        q_hi_ref, q_lo_ref,
        found_ref, val_hi_ref, val_lo_ref,
    ):
        qhi = q_hi_ref[...]                       # [Bq] int32
        qlo = q_lo_ref[...]
        bq = qhi.shape[0]
        local = jnp.zeros((bq,), jnp.int32)       # subtree root = local id 0
        col = jax.lax.broadcasted_iota(jnp.int32, (bq, c_nodes), 1)

        def gather(plane_ref, onehot):
            return jax.lax.dot(
                onehot, plane_ref[...], precision=jax.lax.Precision.HIGHEST
            )

        for lvl in range(levels):
            onehot = (local[:, None] == col).astype(jnp.float32)   # [Bq, C]
            khi, klo = _recombine_i64_hi_lo(
                gather(k0, onehot), gather(k1, onehot),
                gather(k2, onehot), gather(k3, onehot),
            )                                                       # [Bq, F]
            if lvl < levels - 1:
                leq = _leq_hi_lo(khi, klo, qhi[:, None], qlo[:, None])
                cnt = jnp.sum(leq, axis=-1, dtype=jnp.int32)
                slot = jnp.maximum(cnt - 1, 0)                      # [Bq]
                child = (gather(c0, onehot).astype(jnp.int32) << 16) | gather(
                    c1, onehot
                ).astype(jnp.int32)                                 # [Bq, F]
                fcol = jax.lax.broadcasted_iota(jnp.int32, child.shape, 1)
                pick = fcol == slot[:, None]
                local = jnp.sum(jnp.where(pick, child, 0), axis=-1,
                                dtype=jnp.int32)
            else:
                eq = (khi == qhi[:, None]) & (klo == qlo[:, None])
                found_ref[...] = jnp.any(eq, axis=-1)
                vhi, vlo = _recombine_i64_hi_lo(
                    gather(v0, onehot), gather(v1, onehot),
                    gather(v2, onehot), gather(v3, onehot),
                )
                val_hi_ref[...] = jnp.sum(jnp.where(eq, vhi, 0), axis=-1,
                                          dtype=jnp.int32)
                val_lo_ref[...] = jnp.sum(jnp.where(eq, vlo, 0), axis=-1,
                                          dtype=jnp.int32)

    return kernel


@functools.partial(
    jax.jit, static_argnames=("levels", "interpret", "block_b")
)
def subtree_walk(
    block_keys: jax.Array,      # [C, FANOUT] int64
    block_children: jax.Array,  # [C, FANOUT] int32
    block_values: jax.Array,    # [C, FANOUT] int64
    queries: jax.Array,         # [B] int64
    *,
    levels: int,
    interpret: bool = True,
    block_b: int = BLOCK_B,
):
    """Walk one subtree block for a batch of queries.  Returns
    (found [B] bool, values [B] int64)."""
    c_nodes = block_keys.shape[0]
    b = queries.shape[0]
    pad = (-b) % block_b
    if pad:
        queries = jnp.pad(queries, (0, pad), constant_values=-1)
    bp = queries.shape[0]

    kp = _planes16_i64(block_keys)
    cp = _planes16_i32(block_children)
    vp = _planes16_i64(block_values)
    qhi = (queries >> 32).astype(jnp.int32)
    qlo = (queries & jnp.int64(0xFFFFFFFF)).astype(jnp.uint32).astype(jnp.int32)

    grid = (bp // block_b,)
    block_full = pl.BlockSpec((c_nodes, FANOUT), lambda i: (0, 0))
    lane = pl.BlockSpec((block_b,), lambda i: (i,))
    found, vhi, vlo = pl.pallas_call(
        _make_kernel(levels, c_nodes),
        grid=grid,
        in_specs=[block_full] * 10 + [lane, lane],
        out_specs=[lane, lane, lane],
        out_shape=[
            jax.ShapeDtypeStruct((bp,), jnp.bool_),
            jax.ShapeDtypeStruct((bp,), jnp.int32),
            jax.ShapeDtypeStruct((bp,), jnp.int32),
        ],
        interpret=interpret,
    )(*kp, *cp, *vp, qhi, qlo)
    values = (vhi.astype(jnp.int64) << 32) | (
        vlo.astype(jnp.uint32).astype(jnp.int64)
    )
    return found[:b], values[:b]
