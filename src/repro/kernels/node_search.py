"""Pallas kernel: batched in-node lower-bound search.

The innermost compute of every B+-tree traversal (paper Algorithm 1's
``parent.search(key)``): given one 1KB node row per query lane, find the
rightmost separator <= key, plus exact-match hit/value for leaves.

TPU mapping: the 64-wide key row is one VPU vector register row; the
comparison + popcount is branchless lane arithmetic.  We tile the batch over
the grid with BlockSpec so each program works on a [BLOCK_B, FANOUT] VMEM
tile.  int64 keys are carried as (hi, lo) int32 planes because the TPU VPU
has no native 64-bit lanes (DESIGN.md §2: hardware adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.nodes import FANOUT
from repro.core.pool import SEP_SUFFIX_SENTINEL

BLOCK_B = 256


def _split_i64(x: jax.Array):
    """int64 -> (hi int32, lo uint32-as-int32) planes."""
    hi = (x >> 32).astype(jnp.int32)
    lo = (x & jnp.int64(0xFFFFFFFF)).astype(jnp.uint32).astype(jnp.int32)
    return hi, lo


def _leq_planes(khi, klo, qhi, qlo):
    """(khi,klo) <= (qhi,qlo) treating lo as unsigned."""
    # compare lo as unsigned by flipping the sign bit into signed order
    flip = jnp.int32(-0x80000000)
    klo_s = klo ^ flip
    qlo_s = qlo ^ flip
    return (khi < qhi) | ((khi == qhi) & (klo_s <= qlo_s))


def _node_search_kernel(
    keys_hi_ref, keys_lo_ref, q_hi_ref, q_lo_ref, vals_ref,
    slot_ref, found_ref, out_val_ref,
):
    khi = keys_hi_ref[...]            # [B, F] int32
    klo = keys_lo_ref[...]
    qhi = q_hi_ref[...]               # [B] int32
    qlo = q_lo_ref[...]
    leq = _leq_planes(khi, klo, qhi[:, None], qlo[:, None])
    cnt = jnp.sum(leq, axis=-1, dtype=jnp.int32)
    slot_ref[...] = jnp.maximum(cnt - 1, 0).astype(jnp.int32)
    eq = (khi == qhi[:, None]) & (klo == qlo[:, None])
    found_ref[...] = jnp.any(eq, axis=-1)
    vhi = jnp.sum(jnp.where(eq, vals_ref[..., 0], 0), axis=-1, dtype=jnp.int32)
    vlo = jnp.sum(jnp.where(eq, vals_ref[..., 1], 0), axis=-1, dtype=jnp.int32)
    out_val_ref[..., 0] = vhi
    out_val_ref[..., 1] = vlo


@functools.partial(jax.jit, static_argnames=("interpret", "block_b"))
def node_search(
    node_keys: jax.Array,   # [B, FANOUT] int64
    queries: jax.Array,     # [B] int64
    node_values: jax.Array, # [B, FANOUT] int64
    *,
    interpret: bool = True,
    block_b: int = BLOCK_B,
):
    """Batched lower-bound + exact-match.  Returns (slot, found, value)."""
    b = node_keys.shape[0]
    pad = (-b) % block_b
    if pad:
        node_keys = jnp.pad(node_keys, ((0, pad), (0, 0)), constant_values=0)
        node_values = jnp.pad(node_values, ((0, pad), (0, 0)))
        queries = jnp.pad(queries, (0, pad), constant_values=-1)
    bp = node_keys.shape[0]

    khi, klo = _split_i64(node_keys)
    qhi, qlo = _split_i64(queries)
    vhi, vlo = _split_i64(node_values)
    vplanes = jnp.stack([vhi, vlo], axis=-1)  # [B, F, 2]

    grid = (bp // block_b,)
    out = pl.pallas_call(
        _node_search_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, FANOUT), lambda i: (i, 0)),
            pl.BlockSpec((block_b, FANOUT), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b, FANOUT, 2), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b, 2), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp,), jnp.int32),
            jax.ShapeDtypeStruct((bp,), jnp.bool_),
            jax.ShapeDtypeStruct((bp, 2), jnp.int32),
        ],
        interpret=interpret,
    )(khi, klo, qhi, qlo, vplanes)
    slot, found, vpl = out
    value = (vpl[:, 0].astype(jnp.int64) << 32) | (
        vpl[:, 1].astype(jnp.uint32).astype(jnp.int64)
    )
    return slot[:b], found[:b], value[:b]


# ---------------------------------------------------------------------------
# Prefix-compressed separator search (DESIGN.md §13)
# ---------------------------------------------------------------------------


def _prefix_search_kernel(
    p_hi_ref, p_lo_ref, nbits_ref, suffix_ref,
    keys_hi_ref, keys_lo_ref, q_hi_ref, q_lo_ref,
    slot_ref,
):
    phi = p_hi_ref[...]               # [B] int32
    plo = p_lo_ref[...]
    nb = nbits_ref[...]               # [B] int32
    suf = suffix_ref[...]             # [B, F] int32
    qhi = q_hi_ref[...]
    qlo = q_lo_ref[...]
    good = nb >= 0
    nb0 = jnp.maximum(nb, 0)
    # nbits <= 30 < 32, so the retained-bit mask lives entirely in the lo
    # plane: the hi plane carries prefix bits only
    mask = (jnp.int32(1) << nb0) - jnp.int32(1)
    q_suf = qlo & mask                # [0, 2**30): always non-negative
    qp_lo = qlo & ~mask
    flip = jnp.int32(-0x80000000)
    eq = (phi == qhi) & (plo == qp_lo)
    lt = (phi < qhi) | ((phi == qhi) & ((plo ^ flip) < (qp_lo ^ flip)))
    # the pad sentinel exceeds every real (< 2**30) suffix AND every masked
    # query, so both sums count real separators only
    nreal = jnp.sum(
        (suf != SEP_SUFFIX_SENTINEL).astype(jnp.int32), axis=-1
    )
    cnt_sfx = jnp.sum((suf <= q_suf[:, None]).astype(jnp.int32), axis=-1)
    cnt_c = jnp.where(eq, cnt_sfx, jnp.where(lt, nreal, 0))
    # incompressible rows (nbits = -1) fall back to the canonical key row
    leq = _leq_planes(
        keys_hi_ref[...], keys_lo_ref[...], qhi[:, None], qlo[:, None]
    )
    cnt_f = jnp.sum(leq.astype(jnp.int32), axis=-1)
    cnt = jnp.where(good, cnt_c, cnt_f)
    slot_ref[...] = jnp.maximum(cnt - 1, 0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret", "block_b"))
def node_search_prefix(
    prefix: jax.Array,      # [B] int64 per-row shared prefix (low bits 0)
    nbits: jax.Array,       # [B] int32 retained low bits (-1 incompressible)
    suffix: jax.Array,      # [B, FANOUT] int32 truncated separators
    node_keys: jax.Array,   # [B, FANOUT] int64 canonical rows (fallback)
    queries: jax.Array,     # [B] int64
    *,
    interpret: bool = True,
    block_b: int = BLOCK_B,
):
    """Batched lower-bound over prefix-compressed separator rows
    (core/pool.py ``SepPlanes``; one gathered row triple per query lane).

    Matches ``pool._slot`` bit-for-bit for queries below KEY_MAX (the
    inactive-lane sentinel): a compressible row reduces the 64-wide int64
    compare to one 64-bit prefix compare plus a 64-wide *int32* suffix
    compare — half the separator bytes per row; rows whose span needs more
    than SEP_MAX_NBITS low bits take the canonical comparison.  Returns
    ``slot [B] int32``.
    """
    b = prefix.shape[0]
    pad = (-b) % block_b
    if pad:
        prefix = jnp.pad(prefix, (0, pad), constant_values=0)
        nbits = jnp.pad(nbits, (0, pad), constant_values=0)
        suffix = jnp.pad(
            suffix, ((0, pad), (0, 0)),
            constant_values=int(SEP_SUFFIX_SENTINEL),
        )
        node_keys = jnp.pad(node_keys, ((0, pad), (0, 0)), constant_values=0)
        queries = jnp.pad(queries, (0, pad), constant_values=-1)
    bp = prefix.shape[0]

    phi, plo = _split_i64(prefix)
    khi, klo = _split_i64(node_keys)
    qhi, qlo = _split_i64(queries)

    grid = (bp // block_b,)
    slot = pl.pallas_call(
        _prefix_search_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b, FANOUT), lambda i: (i, 0)),
            pl.BlockSpec((block_b, FANOUT), lambda i: (i, 0)),
            pl.BlockSpec((block_b, FANOUT), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bp,), jnp.int32),
        interpret=interpret,
    )(phi, plo, nbits.astype(jnp.int32), suffix, khi, klo, qhi, qlo)
    return slot[:b]
