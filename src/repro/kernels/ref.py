"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each function mirrors its kernel's signature exactly; kernel tests sweep
shapes/dtypes and ``assert_allclose`` kernel-vs-oracle (interpret=True)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.nodes import KEY_MAX
from repro.core.pool import SEP_SUFFIX_SENTINEL
from repro.core.pool import subtree_walk_ref  # noqa: F401  (re-export)


def leaf_scan_ref(window_keys, window_values, start_keys, counts, *, max_count):
    """Oracle for kernels/leaf_scan.py.

    ``window_keys``/``window_values``: [B, W] consecutive leaf rows in global
    leaf order (KEY_MAX padding).  Selects up to ``counts[b]`` keys >=
    ``start_keys[b]`` per lane and compacts them into [B, max_count],
    preserving window-slot order (the kernel's rank-based gather); for real
    leaf windows slot order == ascending key order.
    """
    k = window_keys.astype(jnp.int64)
    v = window_values.astype(jnp.int64)
    start = start_keys.astype(jnp.int64)
    counts = jnp.clip(counts.astype(jnp.int32), 0, max_count)
    mask = (k != KEY_MAX) & (k >= start[:, None])
    rank = jnp.cumsum(mask.astype(jnp.int32), axis=-1)
    sel = mask & (rank <= counts[:, None])
    taken = jnp.sum(sel.astype(jnp.int32), axis=-1)
    w = k.shape[1]
    # stable sort by selection rank compacts selected slots to the front in
    # slot order; non-selected slots sink to the back
    order = jnp.argsort(jnp.where(sel, rank, w + 1), axis=-1, stable=True)
    out_k = jnp.take_along_axis(jnp.where(sel, k, KEY_MAX), order, axis=-1)
    out_v = jnp.take_along_axis(jnp.where(sel, v, 0), order, axis=-1)
    return out_k[:, :max_count], out_v[:, :max_count], taken


def leaf_write_ref(rows_k, rows_v, upd_slot, upd_val, ins_key, ins_val):
    """Oracle for kernels/leaf_write.py.

    Applies staged in-place value updates ``(upd_slot, upd_val)`` (slot -1 =
    inactive) then merges staged inserts ``(ins_key, ins_val)`` (KEY_MAX =
    inactive) into the sorted leaf rows.  Active staged insert keys must be
    distinct from each other and from the row's keys, and must fit in the
    row's slack (core/write.py sheds overflowing leaves first).  Returns
    ``(new_keys [Q, F], new_values [Q, F], new_occupancy [Q] int32)``.
    """
    k = rows_k.astype(jnp.int64)
    v = rows_v.astype(jnp.int64)
    f = k.shape[1]
    upd_slot = upd_slot.astype(jnp.int32)
    umask = upd_slot >= 0
    onehot = umask[:, :, None] & (
        upd_slot[:, :, None] == jnp.arange(f, dtype=jnp.int32)
    )
    has_u = jnp.any(onehot, axis=1)
    uv = jnp.sum(
        jnp.where(onehot, upd_val.astype(jnp.int64)[:, :, None], 0), axis=1
    )
    v1 = jnp.where(has_u, uv, v)
    act = ins_key != KEY_MAX
    merged_k = jnp.concatenate([k, jnp.where(act, ins_key, KEY_MAX)], axis=-1)
    merged_v = jnp.concatenate(
        [jnp.where(k != KEY_MAX, v1, 0), jnp.where(act, ins_val, 0)], axis=-1
    )
    order = jnp.argsort(merged_k, axis=-1, stable=True)
    out_k = jnp.take_along_axis(merged_k, order, axis=-1)[:, :f]
    out_v = jnp.take_along_axis(merged_v, order, axis=-1)[:, :f]
    out_v = jnp.where(out_k != KEY_MAX, out_v, 0)
    occ = jnp.sum(out_k != KEY_MAX, axis=-1).astype(jnp.int32)
    return out_k, out_v, occ


def leaf_split_ref(rows_k, rows_v, ins_key, ins_val):
    """Oracle for kernels/leaf_split.py.

    Rank-merges staged inserts ``(ins_key, ins_val)`` (KEY_MAX = inactive)
    into the sorted leaf rows; rows whose merged count ``m`` exceeds FANOUT
    are cut at ``m // 2`` (left keeps the lower half, matching
    ``HostBTree._split_child``), others come back whole in the left row.
    Active staged keys must be distinct from each other and from the row's
    keys.  Returns ``(left_k, left_v, right_k, right_v, occ_l, occ_r, sep,
    did_split)``; ``sep`` is the right row's first key (KEY_MAX when the
    lane did not split).
    """
    k = rows_k.astype(jnp.int64)
    v = rows_v.astype(jnp.int64)
    f = k.shape[1]
    act = ins_key != KEY_MAX
    merged_k = jnp.concatenate([k, jnp.where(act, ins_key, KEY_MAX)], axis=-1)
    merged_v = jnp.concatenate(
        [jnp.where(k != KEY_MAX, v, 0), jnp.where(act, ins_val, 0)], axis=-1
    )
    order = jnp.argsort(merged_k, axis=-1, stable=True)
    mk = jnp.take_along_axis(merged_k, order, axis=-1)
    mv = jnp.take_along_axis(merged_v, order, axis=-1)
    m = jnp.sum(mk != KEY_MAX, axis=-1).astype(jnp.int32)
    split = m > f
    left_n = jnp.where(split, m // 2, m)
    col = jnp.arange(mk.shape[1], dtype=jnp.int32)[None, :]
    in_left = col < left_n[:, None]
    lk = jnp.where(in_left, mk, KEY_MAX)[:, :f]
    lv = jnp.where(in_left & (mk != KEY_MAX), mv, 0)[:, :f]
    # right side: shift the tail down by left_n
    idx = jnp.clip(col[:, :f] + left_n[:, None], 0, mk.shape[1] - 1)
    rk_full = jnp.take_along_axis(mk, idx, axis=-1)
    rv_full = jnp.take_along_axis(mv, idx, axis=-1)
    in_right = split[:, None] & (col[:, :f] < (m - left_n)[:, None])
    rk = jnp.where(in_right, rk_full, KEY_MAX)
    rv = jnp.where(in_right & (rk_full != KEY_MAX), rv_full, 0)
    occ_l = jnp.sum(lk != KEY_MAX, axis=-1).astype(jnp.int32)
    occ_r = jnp.sum(rk != KEY_MAX, axis=-1).astype(jnp.int32)
    sep = jnp.where(split, rk[:, 0], KEY_MAX)
    return lk, lv, rk, rv, occ_l, occ_r, sep, split.astype(jnp.int32)


def node_search_ref(node_keys, queries, node_values):
    """Oracle for kernels/node_search.py."""
    queries = queries.astype(jnp.int64)
    leq = node_keys <= queries[:, None]
    cnt = jnp.sum(leq, axis=-1)
    slot = jnp.maximum(cnt - 1, 0).astype(jnp.int32)
    eq = node_keys == queries[:, None]
    found = jnp.any(eq, axis=-1)
    value = jnp.sum(jnp.where(eq, node_values, 0), axis=-1)
    return slot, found, value


def node_search_prefix_ref(prefix, nbits, suffix, node_keys, queries):
    """Oracle for kernels/node_search.py ``node_search_prefix``.

    Pure-int64 restatement of the compressed comparison: a row's keys all
    share the bits above ``nbits``, so ``key <= q`` collapses to comparing
    the query's masked prefix against the row prefix, with the int32
    suffix compare breaking the tie.  Incompressible rows (``nbits = -1``)
    use the canonical key row.  Agrees with ``pool._slot`` on the full
    rows for every query below KEY_MAX."""
    q = queries.astype(jnp.int64)
    good = nbits >= 0
    nb = jnp.maximum(nbits, 0).astype(jnp.int64)
    mask = (jnp.int64(1) << nb) - 1
    q_suf = (q & mask).astype(jnp.int32)
    q_pref = q & ~mask
    nreal = jnp.sum((suffix != SEP_SUFFIX_SENTINEL).astype(jnp.int32), axis=-1)
    cnt_sfx = jnp.sum((suffix <= q_suf[:, None]).astype(jnp.int32), axis=-1)
    cnt_c = jnp.where(
        q_pref == prefix, cnt_sfx, jnp.where(prefix < q_pref, nreal, 0)
    )
    cnt_f = jnp.sum((node_keys <= q[:, None]).astype(jnp.int32), axis=-1)
    cnt = jnp.where(good, cnt_c, cnt_f)
    return jnp.maximum(cnt - 1, 0).astype(jnp.int32)


def flash_attention_ref(q, k, v, *, causal: bool = True, scale=None):
    """Oracle for kernels/flash_attention.py.

    q: [B, H, Sq, D]; k, v: [B, HKV, Sk, D] with H % HKV == 0 (GQA).
    Computation in f32; returns q.dtype.
    """
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    qf = q.astype(jnp.float32) * scale
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    if causal:
        sk = k.shape[2]
        mask = jnp.arange(sq)[:, None] + (sk - sq) >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vf)
    return o.astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, page_table, seq_lens):
    """Oracle for kernels/paged_attention.py (decode: one query token).

    q: [B, H, D]; k_pages/v_pages: [P, page, HKV, D];
    page_table: [B, pages_per_req] int32; seq_lens: [B] int32.
    """
    b, h, d = q.shape
    hkv = k_pages.shape[2]
    group = h // hkv
    page = k_pages.shape[1]
    ppr = page_table.shape[1]
    scale = 1.0 / np.sqrt(d)
    k = k_pages[page_table]            # [B, ppr, page, HKV, D]
    v = v_pages[page_table]
    k = k.reshape(b, ppr * page, hkv, d)
    v = v.reshape(b, ppr * page, hkv, d)
    pos = jnp.arange(ppr * page)[None, :]
    valid = pos < seq_lens[:, None]    # [B, S]
    qf = q.astype(jnp.float32).reshape(b, hkv, group, d) * scale
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bngd,bsnd->bngs", qf, kf)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngs,bsnd->bngd", p, v.astype(jnp.float32))
    return o.reshape(b, h, d).astype(q.dtype)


def mamba_scan_ref(delta, A, Bmat, C, x):
    """Oracle for kernels/mamba_scan.py (selective scan, diagonal A).

    delta: [B, L, D] (post-softplus); A: [D, N] (negative);
    Bmat, C: [B, L, N]; x: [B, L, D].  Returns y: [B, L, D] (f32).
    """
    delta = delta.astype(jnp.float32)
    A = A.astype(jnp.float32)
    Bmat = Bmat.astype(jnp.float32)
    C = C.astype(jnp.float32)
    x = x.astype(jnp.float32)
    dA = jnp.exp(delta[..., None] * A[None, None])          # [B, L, D, N]
    dBx = delta[..., None] * Bmat[:, :, None, :] * x[..., None]

    def step(h, inp):
        da, dbx = inp
        h = da * h + dbx
        return h, h

    def scan_one(da_seq, dbx_seq):
        h0 = jnp.zeros(da_seq.shape[1:], jnp.float32)
        _, hs = jax.lax.scan(step, h0, (da_seq, dbx_seq))
        return hs

    hs = jax.vmap(scan_one)(dA, dBx)                        # [B, L, D, N]
    y = jnp.einsum("bldn,bln->bld", hs, C)
    return y
