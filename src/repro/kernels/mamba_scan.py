"""Pallas kernel: selective-scan recurrence (Mamba-1 style, diagonal A).

Used by the ``falcon-mamba-7b`` / ``zamba2-2.7b`` architectures.  Each
program owns a [block_d] slice of channels for one batch element and runs
the time recurrence with the state held in VMEM:

    h_t = exp(delta_t * A) * h_{t-1} + delta_t * x_t * B_t
    y_t = <h_t, C_t>

The time loop is sequential (``lax.fori_loop``) with all chunk operands
staged in VMEM — the TPU-native layout puts channels on lanes so each step
is a [block_d, N] VPU update.  (Training uses the chunked associative-scan
jnp path in models/mamba.py; this kernel is the fused decode/short-sequence
executor and the oracle target.)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_D = 128


def _mamba_kernel(delta_ref, a_ref, b_ref, c_ref, x_ref, y_ref, h_scr, *, length):
    h_scr[...] = jnp.zeros_like(h_scr[...])
    a = a_ref[0].astype(jnp.float32)                  # [bd, N]

    def step(t, _):
        dt = delta_ref[0, t].astype(jnp.float32)      # [bd]
        bt = b_ref[0, t].astype(jnp.float32)          # [N]
        ct = c_ref[0, t].astype(jnp.float32)          # [N]
        xt = x_ref[0, t].astype(jnp.float32)          # [bd]
        da = jnp.exp(dt[:, None] * a)                 # [bd, N]
        h = da * h_scr[...] + (dt * xt)[:, None] * bt[None, :]
        h_scr[...] = h
        y_ref[0, t] = jnp.sum(h * ct[None, :], axis=-1).astype(y_ref.dtype)
        return ()

    jax.lax.fori_loop(0, length, step, ())


@functools.partial(jax.jit, static_argnames=("interpret", "block_d"))
def mamba_scan(
    delta: jax.Array,  # [B, L, D] f32 (post-softplus)
    A: jax.Array,      # [D, N]
    Bmat: jax.Array,   # [B, L, N]
    C: jax.Array,      # [B, L, N]
    x: jax.Array,      # [B, L, D]
    *,
    interpret: bool = True,
    block_d: int = DEFAULT_BLOCK_D,
):
    b, l, d = x.shape
    n = A.shape[1]
    bd = min(block_d, d)
    assert d % bd == 0
    nd = d // bd

    # channel-major layouts: [B, L, D] kept, A tiled per block
    grid = (b, nd)
    out = pl.pallas_call(
        functools.partial(_mamba_kernel, length=l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, l, bd), lambda i, j: (i, 0, j)),   # delta
            pl.BlockSpec((1, bd, n), lambda i, j: (0, j, 0)),   # A (broadcast B)
            pl.BlockSpec((1, l, n), lambda i, j: (i, 0, 0)),    # B
            pl.BlockSpec((1, l, n), lambda i, j: (i, 0, 0)),    # C
            pl.BlockSpec((1, l, bd), lambda i, j: (i, 0, j)),   # x
        ],
        out_specs=pl.BlockSpec((1, l, bd), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((b, l, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
    )(delta, A[None], Bmat, C, x)
    return out
