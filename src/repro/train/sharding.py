"""Parameter/activation sharding rules (GSPMD specs).

Strategy (DESIGN.md §5):
  * TP over the ``model`` axis: attention heads / ffn width / experts /
    vocab dims.
  * ZeRO-3/FSDP over the ``data`` axes (and ``pod`` when present): the other
    large dim of every stacked weight.  With scan-over-layers, GSPMD
    all-gathers one layer's weights per scan step — exactly FSDP semantics.
  * Norm scales and other small vectors are replicated.

Rules are generic (shape-driven) with name overrides for orientation, so new
architectures inherit sensible shardings without per-arch tables.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig


def _data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def spec_for_param(
    path: str, shape: Tuple[int, ...], mesh: Mesh, cfg: ArchConfig
) -> P:
    """Sharding spec for one parameter leaf."""
    data = _data_axes(mesh)
    n_data = _axis_size(mesh, data)
    n_model = mesh.shape["model"]

    is_stacked = len(shape) >= 2 and shape[0] in (cfg.n_layers, cfg.enc_layers)
    dims = list(shape)
    start = 1 if is_stacked else 0
    spec = [None] * len(shape)

    # name-specific orientation: "row parallel" weights put model on dim -2
    row_parallel = any(s in path for s in ("wo", "out_proj", "dt_proj"))
    # embedding: shard d_model (a vocab-sharded table makes every token
    # gather an all-gather of the whole table under GSPMD — measured 4GB+
    # of temps per chip at 128k vocab).  head: vocab col-parallel.
    if path.endswith("embed"):
        return P(None, "model") if shape[1] % n_model == 0 else P(None, None)
    if path.endswith("lm_head"):
        return P(None, "model") if shape[1] % n_model == 0 else P(None, None)
    if "router" in path:
        return P(None, *([None] * (len(shape) - 1)))
    if "moe" in path and len(shape) == 4:
        # [L, E, d_in, d_out].  Many experts: shard the expert axis (EP).
        # Few wide experts (E < model axis, e.g. grok's 8x32768): TP inside
        # the expert FFN instead — col-parallel wi, row-parallel wo —
        # otherwise every chip all-gathers multi-GB expert weights per layer.
        s = [None, None, None, None]
        if shape[1] % n_model == 0:
            s[1] = "model"
            if n_data > 1 and shape[2] % n_data == 0:
                s[2] = data
        elif row_parallel:  # wo: [L, E, ffe, d]
            if shape[2] % n_model == 0:
                s[2] = "model"
            if n_data > 1 and shape[3] % n_data == 0:
                s[3] = data
        else:               # wi: [L, E, d, ffx]
            if shape[3] % n_model == 0:
                s[3] = "model"
            if n_data > 1 and shape[2] % n_data == 0:
                s[2] = data
        return P(*s)

    big = [i for i in range(start, len(shape)) if dims[i] > 1]
    if len(big) >= 2:
        a, b = big[-2], big[-1]
        if row_parallel:
            model_dim, data_dim = a, b
        else:
            model_dim, data_dim = b, a
        if dims[model_dim] % n_model == 0:
            spec[model_dim] = "model"
        if n_data > 1 and dims[data_dim] % n_data == 0:
            spec[data_dim] = data
        return P(*spec)
    if len(big) == 1 and dims[big[0]] % n_model == 0 and dims[big[0]] >= 1024:
        spec[big[0]] = "model"
        return P(*spec)
    return P(*spec)


def param_shardings(params_shape: Any, mesh: Mesh, cfg: ArchConfig):
    """NamedShardings for a params pytree (of arrays or ShapeDtypeStructs)."""

    def walk(tree, prefix):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}/{k}") for k, v in tree.items()}
        spec = spec_for_param(prefix, tuple(tree.shape), mesh, cfg)
        return NamedSharding(mesh, spec)

    return walk(params_shape, "")


def batch_shardings(mesh: Mesh, *, encdec: bool = False):
    data = _data_axes(mesh)
    b = {
        "tokens": NamedSharding(mesh, P(data, None)),
        "labels": NamedSharding(mesh, P(data, None)),
    }
    if encdec:
        b["enc_emb"] = NamedSharding(mesh, P(data, None, "model"))
    return b


def cache_shardings(cfg: ArchConfig, mesh: Mesh, *, batch: Optional[int] = None):
    """Decode-cache specs: batch over data; heads (or state) over model;
    S always unsharded (see the in-place append note below).  ``batch=1``
    (long-context single-request decode) drops the data axis from the batch
    dim — the sequence dim takes it instead where one exists."""
    data = _data_axes(mesh)
    n_model = mesh.shape["model"]
    n_data = _axis_size(mesh, data)
    if batch is not None and batch % n_data != 0:
        data = None
    out: Dict[str, NamedSharding] = {}

    def ns(spec):
        return NamedSharding(mesh, spec)

    if cfg.ssm or cfg.hybrid_attn_every:
        out["ssm"] = ns(P(None, data, "model", None))
        out["conv"] = ns(P(None, data, None, "model"))
        if cfg.hybrid_attn_every:
            # [G, B, S, HKV, Dh]
            if cfg.n_kv_heads % n_model == 0:
                out["shared_k"] = ns(P(None, data, None, "model", None))
            else:
                out["shared_k"] = ns(P(None, data, "model", None, None))
            out["shared_v"] = out["shared_k"]
        return out
    if cfg.attention == "mla":
        # [L, B, S, kvlr] / [L, B, S, ropeD]: decode appends along S with a
        # dynamic slice, so S must stay unsharded — shard the feature dim.
        out["c_kv"] = ns(
            P(None, data, None, "model" if cfg.kv_lora_rank % n_model == 0 else None)
        )
        out["k_rope"] = ns(
            P(None, data, None, "model" if cfg.qk_rope_dim % n_model == 0 else None)
        )
        return out
    # [L, B, S, HKV, Dh]: NEVER shard S (decode's dynamic_update_slice at a
    # runtime position would force a per-step all-gather of the cache);
    # shard kv heads when divisible, else head_dim.
    if cfg.n_kv_heads % n_model == 0:
        kv = ns(P(None, data, None, "model", None))
    elif cfg.head_dim % n_model == 0:
        kv = ns(P(None, data, None, None, "model"))
    else:
        kv = ns(P(None, data, None, None, None))
    out["k"] = kv
    out["v"] = kv
    if cfg.encdec:
        out["xk"] = kv
        out["xv"] = kv
    return out
