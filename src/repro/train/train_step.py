"""Training step: loss + grad (+ microbatch accumulation) + AdamW, built for
pjit/GSPMD execution on the production mesh.

Gradient accumulation runs as a ``lax.scan`` over microbatches so activation
memory is one microbatch deep while arithmetic matches the global batch.
Buffers are donated (params/opt state update in place).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ArchConfig
from repro.train.optimizer import OptConfig, OptState, adamw_update

F32 = jnp.float32


def make_train_step(
    cfg: ArchConfig,
    opt_cfg: OptConfig,
    *,
    microbatches: int = 1,
    act_spec=None,
):
    """Returns ``train_step(params, opt_state, batch) ->
    (params', opt_state', metrics)`` ready for jax.jit with shardings."""

    def grads_of(params, batch):
        def loss(p):
            total, metrics = M.loss_fn(cfg, p, batch, act_spec=act_spec)
            return total, metrics

        (val, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
        return val, metrics, grads

    def train_step(params, opt_state: OptState, batch: Dict[str, jax.Array]):
        if microbatches == 1:
            val, metrics, grads = grads_of(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            micro = jax.tree.map(split, batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)

            def acc_fn(carry, mb):
                g_acc, l_acc = carry
                val, _, grads = grads_of(params, mb)
                g_acc = jax.tree.map(lambda a, g: a + g.astype(F32), g_acc, grads)
                return (g_acc, l_acc + val), ()

            (g_acc, l_sum), _ = jax.lax.scan(acc_fn, (zero, jnp.zeros((), F32)), micro)
            grads = jax.tree.map(lambda g: g / microbatches, g_acc)
            val = l_sum / microbatches
            metrics = {}

        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, params, grads, opt_state
        )
        out_metrics = {"loss": val, **opt_metrics}
        if metrics:
            out_metrics.update({k: v for k, v in metrics.items() if v.ndim == 0})
        return new_params, new_opt, out_metrics

    return train_step


def jit_train_step(
    cfg: ArchConfig,
    opt_cfg: OptConfig,
    mesh,
    params_shapes,
    *,
    microbatches: int = 1,
):
    """jit the step with explicit in/out shardings for the mesh."""
    from repro.train.sharding import batch_shardings, param_shardings

    p_sh = param_shardings(params_shapes, mesh, cfg)
    o_sh = OptState(
        mu=p_sh, nu=p_sh,
        step=jax.NamedSharding(mesh, jax.sharding.PartitionSpec()),
    )
    b_sh = batch_shardings(mesh, encdec=cfg.encdec)
    step = make_train_step(cfg, opt_cfg, microbatches=microbatches)
    metric_sh = jax.NamedSharding(mesh, jax.sharding.PartitionSpec())
    return jax.jit(
        step,
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1),
    )
