"""Fault-tolerant checkpointing: atomic, keep-K, shard-aware, elastic.

Layout (one directory per step)::

    <root>/step_000123/
        manifest.json        # tree structure, dtypes, shapes, data-state
        arrays/<leaf-id>.npy # one file per pytree leaf

Writes go to ``step_XXX.tmp`` and are atomically renamed, so a killed writer
never leaves a half checkpoint (restore scans only committed directories).
``restore(..., mesh=...)`` re-places every leaf with the target mesh's
shardings — this is the *elastic reshard* path: a checkpoint taken on N
chips restores onto any other mesh (launch/elastic.py), the same way DEX's
logical repartitioning moves ownership without moving the index (§4).
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree: Any, prefix: str = "") -> List[Tuple[str, Any]]:
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree):
            out.extend(_flatten_with_paths(tree[k], f"{prefix}/{k}"))
        return out
    if isinstance(tree, (tuple, list)) and not hasattr(tree, "_fields"):
        out = []
        for i, v in enumerate(tree):
            out.extend(_flatten_with_paths(v, f"{prefix}/{i}"))
        return out
    if hasattr(tree, "_fields"):  # NamedTuple
        out = []
        for name in tree._fields:
            out.extend(_flatten_with_paths(getattr(tree, name), f"{prefix}/{name}"))
        return out
    return [(prefix, tree)]


def _unflatten_like(template: Any, values: Dict[str, Any], prefix: str = ""):
    if isinstance(template, dict):
        return {
            k: _unflatten_like(v, values, f"{prefix}/{k}")
            for k, v in template.items()
        }
    if hasattr(template, "_fields"):
        return type(template)(
            *[
                _unflatten_like(getattr(template, n), values, f"{prefix}/{n}")
                for n in template._fields
            ]
        )
    if isinstance(template, (tuple, list)):
        vals = [
            _unflatten_like(v, values, f"{prefix}/{i}")
            for i, v in enumerate(template)
        ]
        return type(template)(vals) if isinstance(template, list) else tuple(vals)
    return values[prefix]


@dataclasses.dataclass
class CheckpointManager:
    root: str
    keep: int = 3

    def __post_init__(self):
        os.makedirs(self.root, exist_ok=True)

    # -- write -----------------------------------------------------------------

    def save(self, step: int, state: Any, extra: Optional[Dict] = None) -> str:
        """Atomic save.  ``state`` is any pytree of arrays; ``extra`` is a
        JSON-serializable dict (e.g. data-pipeline position)."""
        final = os.path.join(self.root, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(os.path.join(tmp, "arrays"))
        leaves = _flatten_with_paths(state)
        manifest = {"step": step, "extra": extra or {}, "leaves": []}
        for i, (path, leaf) in enumerate(leaves):
            arr = np.asarray(leaf)
            fname = f"{i:06d}.npy"
            true_dtype = str(arr.dtype)
            if arr.dtype.kind == "V" or true_dtype == "bfloat16":
                # numpy can't serialize ml_dtypes (bf16 etc.) natively:
                # store the raw bits, record the logical dtype
                arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
            np.save(os.path.join(tmp, "arrays", fname), arr, allow_pickle=False)
            manifest["leaves"].append(
                {"path": path, "file": fname, "dtype": true_dtype,
                 "shape": list(arr.shape)}
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.isdir(final):  # re-save of the same step (e.g. final save
            shutil.rmtree(final)  # landing on a ckpt_every boundary)
        os.replace(tmp, final)  # atomic commit
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"), ignore_errors=True)

    # -- read ------------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.root, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        template: Any,
        *,
        step: Optional[int] = None,
        shardings: Any = None,
    ) -> Tuple[Any, int, Dict]:
        """Restore into ``template``'s structure.  When ``shardings`` is
        given (pytree of NamedShardings matching template), every leaf is
        device_put with the *target* sharding — elastic reshard onto any
        mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        values = {}
        for leaf in manifest["leaves"]:
            arr = np.load(
                os.path.join(d, "arrays", leaf["file"]), allow_pickle=False
            )
            want = leaf["dtype"]
            if str(arr.dtype) != want:
                import ml_dtypes  # jax dependency; provides bf16 et al.

                arr = arr.view(np.dtype(getattr(ml_dtypes, want)))
            values[leaf["path"]] = arr
        state = _unflatten_like(template, values)
        if shardings is not None:
            sh_leaves = dict(_flatten_with_paths(shardings))
            state = _unflatten_like(
                template,
                {
                    p: jax.device_put(v, sh_leaves[p])
                    for p, v in _flatten_with_paths(state)
                },
            )
        return state, step, manifest["extra"]
