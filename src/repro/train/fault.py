"""Fault tolerance & straggler mitigation for the training launcher.

Pieces (wired together in launch/train.py):

  * ``StepWatchdog`` — EMA of step wall-time; flags stragglers (step >
    ``threshold`` x EMA).  On real pods the launcher reacts by excluding the
    slow host at the next elastic boundary; here the hook records and
    reports (single-host container).
  * ``RetryPolicy`` — bounded retries with exponential backoff around the
    step call; distinguishes transient errors (retry in place) from fatal
    ones (restore-from-checkpoint, possibly on a smaller mesh — DEX's
    logical-repartition elasticity, §4, reused for compute failures).
  * ``Heartbeat`` — a mtime-touched file an external orchestrator watches;
    missing heartbeats trigger preemption/replacement upstream.
  * ``FailureInjector`` — deterministic fault injection for tests.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Optional


class TransientError(RuntimeError):
    """Worth retrying in place (network blip, preempted collective)."""


class FatalError(RuntimeError):
    """Requires restore (device loss, corrupted state)."""


@dataclasses.dataclass
class StepWatchdog:
    ema_decay: float = 0.9
    straggler_factor: float = 2.5
    ema: Optional[float] = None
    stragglers: int = 0
    steps: int = 0

    def observe(self, seconds: float) -> bool:
        """Record one step; returns True if it was a straggler step."""
        self.steps += 1
        is_straggler = (
            self.ema is not None and seconds > self.straggler_factor * self.ema
        )
        if is_straggler:
            self.stragglers += 1
        # stragglers do not poison the EMA
        if self.ema is None:
            self.ema = seconds
        elif not is_straggler:
            self.ema = self.ema_decay * self.ema + (1 - self.ema_decay) * seconds
        return is_straggler

    @property
    def straggler_rate(self) -> float:
        return self.stragglers / max(self.steps, 1)


@dataclasses.dataclass
class Heartbeat:
    path: str
    interval: float = 10.0
    _last: float = 0.0

    def beat(self, step: int) -> None:
        now = time.time()
        if now - self._last >= self.interval:
            tmp = self.path + ".tmp"
            with open(tmp, "w") as f:
                f.write(f"{step} {now}\n")
            os.replace(tmp, self.path)
            self._last = now


@dataclasses.dataclass
class RetryPolicy:
    max_retries: int = 3
    backoff_base: float = 0.1

    def run(
        self,
        fn: Callable[[], object],
        *,
        on_fatal: Optional[Callable[[], None]] = None,
    ):
        """Run ``fn`` with bounded retries.  TransientError -> retry with
        backoff; FatalError (or retries exhausted) -> invoke ``on_fatal``
        (checkpoint restore / elastic downsize) once, then one final try."""
        attempt = 0
        while True:
            try:
                return fn()
            except TransientError:
                attempt += 1
                if attempt > self.max_retries:
                    if on_fatal is not None:
                        on_fatal()
                        on_fatal = None
                        attempt = 0
                        continue
                    raise
                time.sleep(self.backoff_base * (2 ** (attempt - 1)))
            except FatalError:
                if on_fatal is not None:
                    on_fatal()
                    on_fatal = None
                    attempt = 0
                    continue
                raise


@dataclasses.dataclass
class FailureInjector:
    """Deterministic fault schedule for tests: {step: exception_type}."""

    schedule: dict

    def maybe_fail(self, step: int) -> None:
        exc = self.schedule.pop(step, None)
        if exc is not None:
            raise exc(f"injected failure at step {step}")
