"""AdamW with sharded state, global-norm clipping, cosine schedule, and an
int8 error-feedback gradient compressor for cross-pod reductions.

State dtype is configurable: bf16 moments make llama3-405b fit 512 chips
(DESIGN.md §5) at a documented optimizer-quality cost.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "bfloat16"   # bf16 moments: ZeRO-3 fit for 405B


class OptState(NamedTuple):
    mu: Any        # first moment (pytree, moment_dtype)
    nu: Any        # second moment (pytree, moment_dtype)
    step: jax.Array


def init_opt_state(params: Any, cfg: OptConfig) -> OptState:
    dt = jnp.dtype(cfg.moment_dtype)

    def zeros(p):
        return jnp.zeros(p.shape, dt)

    return OptState(
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step.astype(F32) / max(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step.astype(F32) - cfg.warmup_steps)
        / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(np.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(F32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    cfg: OptConfig, params: Any, grads: Any, state: OptState
) -> Tuple[Any, OptState, dict]:
    """One AdamW step.  Returns (params', state', metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(F32)
    bc2 = 1 - b2 ** step.astype(F32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd_one(p, g, m, v):
        g = g.astype(F32) * scale
        m_new = b1 * m.astype(F32) + (1 - b1) * g
        v_new = b2 * v.astype(F32) + (1 - b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(F32)
        p_new = p.astype(F32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(mdt), v_new.astype(mdt)

    def upd(p, g, m, v):
        # stacked [L, ...] leaves update one layer-slice at a time: the f32
        # staging tensors of a monolithic update were ~2 GB per leaf per chip
        # at 405B scale
        if p.ndim >= 3 and p.shape[0] > 1:
            return jax.lax.map(lambda a: upd_one(*a), (p, g, m, v))
        return upd_one(p, g, m, v)

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return (
        new_params,
        OptState(mu=new_mu, nu=new_nu, step=step),
        {"grad_norm": gnorm, "lr": lr},
    )


# ---------------------------------------------------------------------------
# int8 error-feedback gradient compression (cross-pod all-reduce trick)
# ---------------------------------------------------------------------------


def compress_int8(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize g+err to int8 with a per-tensor scale.  Returns
    (q int8, scale f32, new_err).  The residual (error feedback) is carried
    so quantization noise cancels over steps instead of biasing training."""
    gf = g.astype(F32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(F32) * scale
    return q, scale, gf - deq


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(F32) * scale


def compressed_psum(g: jax.Array, err: jax.Array, axis_name: str):
    """Error-feedback int8 all-reduce over ``axis_name`` (use inside
    shard_map for the cross-pod gradient reduction; 4x fewer bytes on the
    slowest links).  Returns (g_reduced f32, new_err)."""
    q, scale, new_err = compress_int8(g, err)
    # sum int8 payloads in int32 to avoid overflow across the axis
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
    # scales differ per participant: reduce them too (max keeps dequant safe)
    scale_sum = jax.lax.pmax(scale, axis_name)
    return summed.astype(F32) * scale_sum, new_err
