"""Root pytest config: make ``src/`` importable without an install and
register custom markers (also declared in pyproject.toml for installed
runs)."""

import pathlib
import sys

_SRC = str(pathlib.Path(__file__).parent / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running multi-device subprocess tests "
        "(deselect with -m 'not slow')",
    )
