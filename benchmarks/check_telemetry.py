"""CI guard for the telemetry plane: validate bench_results.json + traces.

Fails (exit 1) when:

* a mesh benchmark module that is expected to emit telemetry stopped doing
  so (its ``telemetry`` block is missing or empty),
* any registered mesh/derived metric disappeared from a timeline's counter
  snapshot schema (the registry is the source of truth — a renamed or
  dropped counter must show up here, not in a dashboard weeks later),
* a timeline named in the results has no ``{name}.metrics_timeline.json``
  or ``{name}.trace.json`` in the trace dir, or the trace file is not
  trace-event JSON,
* a fig19 latency-ledger export breaks its schema: bucket edges not
  strictly monotone, histogram counts not conserved against the summed
  STAT_OPS deltas, an outcome-path label missing, or the gated arm's
  cost audit absent.

Usage::

    PYTHONPATH=src python -m benchmarks.check_telemetry \
        bench_results.json traces/
"""

from __future__ import annotations

import json
import pathlib
import sys

from repro.obs import latency, registry

#: modules whose run() must register at least one timeline
MESH_MODULES = ("fig15mesh", "fig6mesh", "fig10meshrep", "fig14meshload",
                "fig13engine", "fig19tails", "fig20leafdirect")

#: every timeline counter snapshot must carry these names
EXPECTED_METRICS = frozenset(
    [m.name for m in registry.MESH_SLOTS]
    + [m.name for m in registry.METRICS if m.kind == "derived"]
)

#: the pipelined engine's timeline and its two overlap-phase tracks
PIPELINE_TIMELINE = "fig13engine_pipeline"
OVERLAP_PHASES = ("pipe/front", "pipe/back")


def _check_pipeline(results, timelines, tdir, problems):
    """The double-buffered service must keep exporting its overlap story:
    both phase tracks in the timeline AND the trace, plus collective
    parity — a pipelined step issues exactly the synchronous engine's
    per-batch collectives (pipelining buys overlap, not extra rounds)."""
    mod = results.get("fig13engine")
    if mod is None or "error" in mod:
        return  # module absent from this subset / already reported
    tl = timelines.get(PIPELINE_TIMELINE)
    if tl is None:
        problems.append(
            f"fig13engine: pipelined timeline '{PIPELINE_TIMELINE}' missing"
        )
        return
    phases = tl.get("phases") or {}
    for ph in OVERLAP_PHASES:
        if not (phases.get(ph) or {}).get("count"):
            problems.append(
                f"{PIPELINE_TIMELINE}: overlap phase track '{ph}' missing"
            )
    meta = tl.get("meta") or {}
    if not (meta.get("plan") or {}).get("pipeline"):
        problems.append(f"{PIPELINE_TIMELINE}: meta.plan.pipeline unset")
    by_phase = meta.get("collectives_by_phase") or {}
    if set(by_phase) != set(OVERLAP_PHASES):
        problems.append(
            f"{PIPELINE_TIMELINE}: collectives_by_phase tracks "
            f"{sorted(by_phase)} != {sorted(OVERLAP_PHASES)}"
        )
    sync = timelines.get("fig13engine_ycsb-a")
    if sync is not None:
        sync_counts = (sync.get("meta") or {}).get("collectives_per_batch")
        pipe_counts = meta.get("collectives_per_batch")
        if sync_counts != pipe_counts:
            problems.append(
                f"pipelining changed the per-batch collective structure: "
                f"sync {sync_counts} vs pipelined {pipe_counts}"
            )
    tr_file = tdir / f"{PIPELINE_TIMELINE}.trace.json"
    if tr_file.is_file():
        try:
            events = json.loads(tr_file.read_text()).get("traceEvents") or []
        except json.JSONDecodeError:
            events = []  # the generic loop already reports non-JSON traces
        names = {e.get("name") for e in events}
        missing = set(OVERLAP_PHASES) - names
        if missing:
            problems.append(
                f"{PIPELINE_TIMELINE}: trace export lacks overlap span(s) "
                f"{sorted(missing)}"
            )


#: every fig19 timeline must carry the latency ledger; the gated YCSB-A
#: arm must additionally carry the offload cost audit
LATENCY_TIMELINE_PREFIX = "fig19tails_"
AUDITED_TIMELINE = "fig19tails_ycsb-a"


def _check_latency(name, summary, problems):
    """Schema guard for one timeline's ``latency`` (and ``cost_audit``)
    section: bucket monotonicity, label completeness, count conservation
    against the timeline's own summed STAT_OPS deltas."""
    lat = summary.get("latency")
    if not lat:
        problems.append(f"{name}: latency section missing from summary")
        return
    edges = lat.get("bucket_edges_s") or []
    if len(edges) != latency.N_BUCKETS + 1:
        problems.append(
            f"{name}: {len(edges)} bucket edges != {latency.N_BUCKETS + 1}")
    if any(b <= a for a, b in zip(edges, edges[1:])):
        problems.append(f"{name}: bucket edges not strictly monotone")
    if tuple(lat.get("paths") or ()) != latency.PATHS:
        problems.append(
            f"{name}: outcome paths {lat.get('paths')} != "
            f"{list(latency.PATHS)}")
    if tuple(lat.get("op_classes") or ()) != latency.OP_CLASSES:
        problems.append(
            f"{name}: op classes {lat.get('op_classes')} != "
            f"{list(latency.OP_CLASSES)}")
    hist = lat.get("hist") or []
    try:
        total = sum(sum(sum(cell) for cell in cls) for cls in hist)
    except TypeError:
        problems.append(f"{name}: histogram is not a 3-level nested list")
        return
    if total != lat.get("total"):
        problems.append(
            f"{name}: histogram self-total {total} != declared "
            f"{lat.get('total')}")
    # exact conservation: one binned lane per served op — the per-batch
    # counter deltas sum to the measured window's STAT_OPS
    ops = (summary.get("counters") or {}).get("ops")
    if ops is not None and total != int(ops):
        problems.append(
            f"{name}: {total} binned lanes != {int(ops)} served ops — "
            f"the ledger lost or double-binned lanes")
    for cls, led in (lat.get("ledger") or {}).items():
        for pname in latency.PATHS:
            if pname not in (led.get("paths") or {}):
                problems.append(
                    f"{name}: ledger[{cls}] lacks path '{pname}'")
                break
    if name == AUDITED_TIMELINE:
        audit = summary.get("cost_audit")
        if not audit:
            problems.append(f"{name}: cost_audit section missing")
        elif not audit.get("cells"):
            problems.append(f"{name}: cost_audit has no priced cells")


#: fig20's leaf-direct arms export one timeline per mix; each must declare
#: its table config and carry the route-table counters
LEAF_DIRECT_TIMELINE_PREFIX = "fig20leafdirect_"
LEAF_DIRECT_META_KEYS = ("slots", "entries", "poisoned")


def _check_leaf_direct(name, summary, problems):
    """Schema guard for one leaf-direct timeline: ``meta.leaf_direct``
    declares the trained table (slot budget, live entries, poison flag) and
    the counter snapshots carry the rt_skips/rt_mispredicts pair the
    benchmark's reduction claim is audited against."""
    meta = summary.get("meta") or {}
    ld = meta.get("leaf_direct")
    if not isinstance(ld, dict):
        problems.append(f"{name}: meta.leaf_direct section missing")
        return
    missing = [k for k in LEAF_DIRECT_META_KEYS if k not in ld]
    if missing:
        problems.append(f"{name}: meta.leaf_direct lacks {missing}")
    if not ld.get("entries"):
        problems.append(f"{name}: route table trained zero live entries")
    counters = summary.get("counters") or {}
    for k in ("rt_skips", "rt_mispredicts"):
        if k not in counters:
            problems.append(f"{name}: counter '{k}' missing from snapshot")


def _fail(problems):
    print("telemetry guard: FAIL")
    for p in problems:
        print(f"  - {p}")
    return 1


def check(results_path: str, trace_dir: str) -> int:
    problems = []
    with open(results_path) as f:
        results = json.load(f)["results"]
    tdir = pathlib.Path(trace_dir)

    timelines = {}
    for key in MESH_MODULES:
        mod = results.get(key)
        if mod is None:
            continue  # module not in this run's --only subset
        if "error" in mod:
            problems.append(f"{key}: module errored: {mod['error']}")
            continue
        tel = mod.get("telemetry") or {}
        if not tel:
            problems.append(f"{key}: no telemetry block — timelines lost")
        timelines.update(tel)

    for name, summary in sorted(timelines.items()):
        if name.startswith(LATENCY_TIMELINE_PREFIX):
            _check_latency(name, summary, problems)
        if name.startswith(LEAF_DIRECT_TIMELINE_PREFIX):
            _check_leaf_direct(name, summary, problems)
        counters = summary.get("counters") or {}
        missing = EXPECTED_METRICS - set(counters)
        if missing:
            problems.append(
                f"{name}: registered metrics missing from snapshot schema: "
                f"{sorted(missing)}"
            )
        if not summary.get("n_batches"):
            problems.append(f"{name}: timeline recorded zero batches")

        tl_file = tdir / f"{name}.metrics_timeline.json"
        tr_file = tdir / f"{name}.trace.json"
        for path in (tl_file, tr_file):
            if not path.is_file():
                problems.append(f"{name}: missing export {path}")
        if tr_file.is_file():
            try:
                doc = json.loads(tr_file.read_text())
                if not doc.get("traceEvents"):
                    problems.append(f"{name}: {tr_file} has no traceEvents")
            except json.JSONDecodeError as e:
                problems.append(f"{name}: {tr_file} is not JSON: {e}")
        if tl_file.is_file():
            batches = json.loads(tl_file.read_text()).get("batches") or []
            with_counters = [b for b in batches if b.get("counters")]
            if not with_counters:
                problems.append(
                    f"{name}: no batch in {tl_file} carries counters"
                )
            for b in with_counters:
                missing = EXPECTED_METRICS - set(b["counters"])
                if missing:
                    problems.append(
                        f"{name}: batch {b['index']} counters missing "
                        f"{sorted(missing)}"
                    )
                    break

    _check_pipeline(results, timelines, tdir, problems)

    if not timelines:
        problems.append("no timelines found in any mesh module")
    if problems:
        return _fail(problems)
    print(
        f"telemetry guard: OK — {len(timelines)} timeline(s), "
        f"{len(EXPECTED_METRICS)} registered metrics each, exports in "
        f"{trace_dir}"
    )
    return 0


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) != 2:
        print(__doc__)
        sys.exit(2)
    sys.exit(check(argv[0], argv[1]))


if __name__ == "__main__":
    main()
