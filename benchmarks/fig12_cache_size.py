"""Fig. 12: cache-size sensitivity.

Paper claims: (a) read-intensive — DEX improves steeply with cache ratio
while Sherman/SMART flatline (they never cache leaves); (b) write-intensive
— DEX improves up to ~8%, then *degrades* at large caches under skew because
hot-leaf optimistic-lock contention (NUMA) becomes the bottleneck; 18
threads on one socket do not collapse."""

from benchmarks.common import HEADER, run_one, seed_kwargs

RATIOS = [0.01, 0.02, 0.04, 0.08, 0.16, 0.32]


def run(quick: bool = False, seed: "int | None" = None):
    skw = seed_kwargs(seed)
    rows = [HEADER]
    summary = {}
    ratios = RATIOS[::2] if quick else RATIOS
    curve = {}
    for ratio in ratios:
        for system in ["dex", "sherman", "smart"]:
            r = run_one(system, "read-intensive", cache_ratio=ratio,
                        **skw)
            rows.append(f"{system}@{ratio:.0%}," + r.row().split(",", 1)[1])
            curve.setdefault(system, []).append(r.report.mops())
    summary["dex_gain_small_to_big"] = curve["dex"][-1] / max(curve["dex"][0], 1e-9)
    summary["sherman_gain_small_to_big"] = (
        curve["sherman"][-1] / max(curve["sherman"][0], 1e-9)
    )
    # write-intensive collapse at large cache under skew (hot-leaf locks)
    for ratio in ([0.08] if quick else [0.08, 0.32]):
        for threads, label in [(144, "144thr"), (18, "18thr-1socket")]:
            r = run_one("dex", "write-intensive", cache_ratio=ratio,
                        threads=threads)
            rows.append(
                f"dex-wi@{ratio:.0%}-{label}," + r.row().split(",", 1)[1]
            )
            summary[f"wi@{ratio:.0%}-{label}"] = r.report.mops()
    return rows, summary


def main():
    rows, summary = run()
    print("\n".join(rows))
    for k, v in summary.items():
        print(f"# {k}: {v:.2f}")


if __name__ == "__main__":
    main()
