"""Figs. 6-7 companion: the mixed read/write YCSB mixes (A, B, D)
end-to-end on the *mesh plane* (Plane B), next to the event simulator's
counter-based numbers on identical traces.

Each workload batch is split by op type into three masked sub-batches
(inactive lanes carry KEY_MAX) and driven through ``make_dex_lookup``,
``make_dex_update`` and ``make_dex_insert`` — real collectives, real cache
state, real Pallas leaf-write merges — with shed inserts replayed through
the host SMO path (``drain_splits``) between batches.  Lanes load-shed by a
routing bucket are replayed with a bounded retry loop (MAX_RETRIES) and the
throughput figure counts only completed ops — dropped lanes never silently
vanish from the op count under zipfian skew.  Results are
cross-validated per batch against a ``HostBTree`` mirror that replays the
same ops, and the mesh plane's remote read/write counters are compared
against the simulator running the *write-through* DEX preset (``dex-wt``,
the exact protocol the mesh implements) on the very same op/key arrays.

Run with ``PYTHONPATH=src python benchmarks/fig6_mesh_mixed.py [--quick]``
or via the suite: ``PYTHONPATH=src python -m benchmarks.run --only
fig6mesh``.  On hosts without accelerators it forces an 8-device CPU mesh
(2 route x 4 memory) when devices allow, the same topology as
tests/mesh_check.py.
"""

from __future__ import annotations

import os
import pathlib
import sys
import time

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

_ROOT = str(pathlib.Path(__file__).resolve().parent.parent)
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import baselines  # noqa: E402
from repro.core import dex as dex_mod  # noqa: E402
from repro.core import pool as pool_mod  # noqa: E402
from repro.core import write as write_mod  # noqa: E402
from repro.core.nodes import KEY_MAX, KEY_MIN  # noqa: E402
from repro.compat import make_mesh_compat  # noqa: E402
from repro.core.sim import HostBTree, Simulator  # noqa: E402
from repro.data import ycsb  # noqa: E402

from repro.obs import drift, registry  # noqa: E402
from repro.obs.timeline import obs_phase  # noqa: E402
from benchmarks import common  # noqa: E402
from benchmarks.common import (  # noqa: E402
    lookup_with_retries,
    write_with_retries,
)

BATCH = 1024
UPDATE_XOR = 0x5A5A  # update value = key ^ 0x5A5A, matching Simulator._op_update
MAX_RETRIES = 4      # bounded replay of load-shed lanes

MIXES = ("ycsb-a", "ycsb-b", "ycsb-d")


def _build_ops(meta, cfg, mesh):
    lookup = jax.jit(dex_mod.make_dex_lookup(meta, cfg, mesh))
    update = jax.jit(write_mod.make_dex_update(meta, cfg, mesh))
    insert = jax.jit(write_mod.make_dex_insert(meta, cfg, mesh))
    return lookup, update, insert


def _run_mix(name, dataset, n_batches, n_warm_batches, rng):
    vals = dataset * 7
    pool, meta = pool_mod.build_pool(dataset, vals, level_m=1, fill=0.7,
                                     n_shards=4)
    host = HostBTree(dataset, vals, fill=0.7)

    if len(jax.devices()) >= 8:
        shape, n_route, n_memory = (2, 4), 2, 4
        mid = int(dataset[dataset.size // 2])
        bounds = np.array([KEY_MIN, mid, KEY_MAX], dtype=np.int64)
    else:
        shape, n_route, n_memory = (1, 1), 1, 1
        bounds = np.array([KEY_MIN, KEY_MAX], dtype=np.int64)
    mesh = make_mesh_compat(shape, ("data", "model"))
    cfg = dex_mod.DexMeshConfig(
        route_axes=("data",), memory_axis="model",
        n_route=n_route, n_memory=n_memory,
        cache_sets=512, cache_ways=4,
        policy="fetch",  # the protocol dex-wt prices: one-sided reads+writes
        route_capacity_factor=float(max(2, n_memory)),
    )
    state = dex_mod.init_state(pool, meta, cfg, bounds)
    shardings = dex_mod.state_shardings(mesh, cfg)
    state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, shardings)
    sharding = NamedSharding(mesh, P(("data", "model")))
    lookup, update, insert = _build_ops(meta, cfg, mesh)

    n_total = n_warm_batches + n_batches
    wl = ycsb.generate(name, dataset, n_total * BATCH, theta=0.99, seed=11)
    ops, keys = wl.ops, wl.keys

    def put(x):
        return jax.device_put(jnp.asarray(x), sharding)

    tl = common.new_timeline(f"fig6mesh_{name}",
                             devices=len(jax.devices()), batch=BATCH)
    n_drains = 0
    stats_warm = None
    completed = 0        # measured-phase ops that finished (not load-shed)
    shed_residual = 0    # lanes still shed after MAX_RETRIES
    t_start = time.perf_counter()
    for b in range(n_total):
        measured = b >= n_warm_batches
        if b == n_warm_batches:
            # warm phase over (paper §8.1): snapshot counters, restart clock
            jax.block_until_ready(state.stats)
            stats_warm = np.asarray(state.stats).sum(axis=0)
            tl.prime(state.stats)
            completed = 0
            shed_residual = 0
            t_start = time.perf_counter()
        bo = ops[b * BATCH : (b + 1) * BATCH]
        bk = keys[b * BATCH : (b + 1) * BATCH]
        lk = np.where(bo == ycsb.OP_LOOKUP, bk, KEY_MAX)
        uk = np.where(bo == ycsb.OP_UPDATE, bk, KEY_MAX)
        ik = np.where(bo == ycsb.OP_INSERT, bk, KEY_MAX)
        uv = uk ^ UPDATE_XOR
        ob = tl.batch(name) if measured else None
        if ob is not None:
            ob.__enter__()
        # shed lanes are replayed (bounded), never silently dropped from
        # the op count — only completed ops enter the throughput figure
        state, found, got_v, lk_done = lookup_with_retries(
            lookup, state, put, lk, max_retries=MAX_RETRIES, obs=ob
        )
        state, ru = write_with_retries(update, state, put, uk, uv,
                                       max_retries=MAX_RETRIES, obs=ob,
                                       op_class="update")
        state, ri = write_with_retries(insert, state, put, ik, ik,
                                       max_retries=MAX_RETRIES, obs=ob,
                                       op_class="insert")
        if ob is not None:
            ob.counters(state.stats)
            ob.__exit__(None, None, None)
        completed += int(
            (lk_done & (lk != KEY_MAX)).sum()
            + ((uk != KEY_MAX) & (ru != write_mod.STATUS_SHED)).sum()
            + ((ik != KEY_MAX) & (ri != write_mod.STATUS_SHED)).sum()
        )
        shed_residual += int(
            (~lk_done).sum()
            + ((uk != KEY_MAX) & (ru == write_mod.STATUS_SHED)).sum()
            + ((ik != KEY_MAX) & (ri == write_mod.STATUS_SHED)).sum()
        )
        # cross-validate a sample of this batch's lookups against the mirror
        # BEFORE replaying its writes (the lookup phase precedes them)
        lanes = np.where((bo == ycsb.OP_LOOKUP) & lk_done)[0]
        for i in rng.choice(lanes, size=min(16, lanes.size), replace=False):
            hv = host.get(int(bk[i]))
            assert bool(found[i]) == (hv is not None), (name, b, i)
            if hv is not None:
                assert int(got_v[i]) == hv, (name, b, i, int(got_v[i]), hv)
        # host mirror replays exactly what the mesh applied
        upd_ok = (bo == ycsb.OP_UPDATE) & (ru == write_mod.STATUS_OK)
        for k in bk[upd_ok]:
            host.update(int(k), int(k) ^ UPDATE_XOR)
        ins_mask = bo == ycsb.OP_INSERT
        for k, r in zip(bk[ins_mask], ri[ins_mask]):
            if r == write_mod.STATUS_OK:
                host.insert(int(k), int(k))
        shed = ins_mask & (ri == write_mod.STATUS_SPLIT)
        if shed.any():
            n_drains += 1
            with obs_phase(ob, "smo/drain"):
                state, meta = write_mod.drain_splits(
                    state, meta, cfg, host, bk[shed], bk[shed], bounds
                )
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s),
                state, dex_mod.state_shardings(mesh, cfg),
            )
            lookup, update, insert = _build_ops(meta, cfg, mesh)
    jax.block_until_ready(state)  # full tree: the clock may not leak work
    dt = time.perf_counter() - t_start
    common.finish_timeline(tl)

    stats = np.asarray(state.stats).sum(axis=0) - stats_warm
    meas = slice(n_warm_batches * BATCH, None)
    n_ops = int(stats[dex_mod.STAT_OPS])
    n_write_ops = int(np.sum(
        (ops[meas] == ycsb.OP_UPDATE) | (ops[meas] == ycsb.OP_INSERT)
    ))
    mesh_reads = stats[dex_mod.STAT_FETCHES] / max(n_ops, 1)
    mesh_writes = stats[dex_mod.STAT_WRITES] / max(n_ops, 1)

    # Plane A on the *identical* trace: write-through DEX preset, matched
    # topology (one cache per mesh chip, within-row dispersion), matched
    # per-traversal cache capacity (sets x ways nodes) and P_A, same
    # warm/measure split
    sim_tree = HostBTree(dataset, vals, fill=0.7, level_m=1,
                         n_mem_servers=n_memory)
    sim_cfg = baselines.dex_write_through(
        n_compute=n_route * n_memory,
        route_dispersion=n_memory,
        coherence_batch=BATCH,
        n_mem_servers=n_memory,
        level_m=1,
        p_admit_leaf=cfg.p_admit_leaf_pct / 100.0,
        cache_bytes=cfg.cache_sets * cfg.cache_ways * 1024,
    )
    sim = Simulator(sim_tree, sim_cfg, seed=3)
    warm = slice(0, n_warm_batches * BATCH)
    sim.run(ops[warm], keys[warm])
    sim.reset_counters()
    sim.run(ops[meas], keys[meas])
    per_op = sim.totals().per_op()
    sim_reads = per_op["node_reads"]
    sim_writes = per_op["writes"]

    rows = [
        f"mesh,{name},ops_per_s,{completed / dt:.1f}",
        f"mesh,{name},completed_ops,{completed}",
        f"mesh,{name},shed_residual,{shed_residual}",
        f"mesh,{name},remote_reads_per_op,{mesh_reads:.4f}",
        f"mesh,{name},remote_writes_per_op,{mesh_writes:.4f}",
        f"mesh,{name},splits_shed,{stats[dex_mod.STAT_SPLITS]}",
        f"mesh,{name},drains,{n_drains}",
        # per-attempt shed events (a lane re-shed on retry recounts);
        # shed_residual above is the distinct-lane count that never completed
        f"mesh,{name},drop_events,{stats[dex_mod.STAT_DROPS]}",
        f"sim,{name},node_reads_per_op,{sim_reads:.4f}",
        f"sim,{name},writes_per_op,{sim_writes:.4f}",
    ]
    summary = {
        f"{name}_mesh_writes_per_op": float(mesh_writes),
        f"{name}_sim_writes_per_op": float(sim_writes),
        f"{name}_mesh_reads_per_op": float(mesh_reads),
        f"{name}_sim_reads_per_op": float(sim_reads),
        f"{name}_write_ops_frac": n_write_ops / ops.size,
    }
    # both planes price the identical protocol on the identical trace with
    # matched cache topology: the per-op remote verb counters must agree
    # (registry-named mesh snapshot vs sim Counters, per-op relative
    # tolerance, via the shared drift helper)
    tolerances = {"fetches": drift.rel(0.10, per_op=True)}
    if n_write_ops:
        tolerances["writes"] = drift.rel(0.10, per_op=True)
    drift.assert_plane_agreement(
        registry.snapshot(stats[None, :]), sim.totals(), tolerances,
        label=f"fig6mesh {name}",
    )
    return rows, summary


def run(quick: bool = False, seed: "int | None" = None):
    s = 0 if seed is None else int(seed)
    n_keys = 30_000 if quick else 100_000
    n_batches = 4 if quick else 8
    n_warm_batches = 2 if quick else 4
    rng = np.random.default_rng(s + 5)
    dataset = ycsb.make_dataset(n_keys, seed=s)
    rows = ["plane,workload,metric,value"]
    summary = {}
    for name in MIXES:
        r, s = _run_mix(name, dataset, n_batches, n_warm_batches, rng)
        rows += r
        summary.update(s)
    return rows, summary


def main():
    quick = "--quick" in sys.argv
    rows, summary = run(quick=quick)
    print("\n".join(rows))
    for k, v in summary.items():
        print(f"# {k} = {v:.4f}")


if __name__ == "__main__":
    main()
