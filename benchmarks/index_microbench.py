"""Wall-clock microbenchmarks of the device index ops (CPU backend):
bulk lookup / insert / scan / update on the flat tree, plus the Pallas
kernels in interpret mode.  Emits ``name,us_per_call,derived`` rows."""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import btree
from repro.data import ycsb
from repro.kernels import ops as kops


def _time(fn, *args, warmup=2, iters=5, **kw):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / iters * 1e6, out


def run(quick: bool = False, seed: "int | None" = None):
    s = 0 if seed is None else int(seed)
    n = 100_000 if quick else 400_000
    b = 4096
    dataset = ycsb.make_dataset(n, seed=s)
    tree, meta = btree.bulk_build(dataset, dataset * 2)
    rng = np.random.default_rng(s + 1)
    q = rng.choice(dataset, size=b).astype(np.int64)

    rows = ["name,us_per_call,derived"]

    us, _ = _time(
        lambda: btree.bulk_lookup(tree, q, height=meta.height)
    )
    rows.append(f"bulk_lookup_b{b},{us:.1f},{b/us:.2f}Mops")

    us, _ = _time(
        lambda: btree.bulk_update(tree, q, q, height=meta.height)
    )
    rows.append(f"bulk_update_b{b},{us:.1f},{b/us:.2f}Mops")

    starts = q[:256]
    us, _ = _time(
        lambda: btree.bulk_scan(tree, starts, height=meta.height, count=100)
    )
    rows.append(f"bulk_scan100_b256,{us:.1f},{256*100/us:.2f}Mrec/s")

    rows_k = np.asarray(tree.keys)[:b]
    vals_k = np.asarray(tree.values)[:b]
    us, _ = _time(lambda: kops.node_search(rows_k, q, vals_k))
    rows.append(f"kernel_node_search_b{b},{us:.1f},{b/us:.2f}Mops")
    return rows, {}


def main():
    rows, _ = run()
    print("\n".join(rows))


if __name__ == "__main__":
    main()
