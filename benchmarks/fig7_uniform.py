"""Fig. 7: throughput under uniform workloads (worst case for caching).

Paper claims: DEX still beats Sherman/SMART/P-SMART; the gap narrows; DEX is
close to P-Sherman because uniform traffic defeats leaf caching."""

from benchmarks.common import HEADER, seed_kwargs, sweep_threads

SYSTEMS = ["dex", "sherman", "p-sherman", "smart", "p-smart"]
WORKLOADS = ["read-only", "read-intensive", "write-intensive"]
THREADS = [18, 72, 144]


def run(quick: bool = False, seed: "int | None" = None):
    skw = seed_kwargs(seed)
    workloads = WORKLOADS[:1] if quick else WORKLOADS
    rows = [HEADER]
    summary = {}
    for wl in workloads:
        at_max = {}
        for system in SYSTEMS:
            for r in sweep_threads(system, wl, THREADS, theta=0.0, **skw):
                rows.append(r.row())
                if r.threads == THREADS[-1]:
                    at_max[system] = r.report.mops()
        for s in SYSTEMS[1:]:
            summary[f"uniform-{wl}:dex/{s}"] = at_max["dex"] / max(at_max[s], 1e-9)
    return rows, summary


def main():
    rows, summary = run()
    print("\n".join(rows))
    for k, v in summary.items():
        print(f"# {k} = {v:.2f}x")


if __name__ == "__main__":
    main()
