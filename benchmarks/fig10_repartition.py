"""Fig. 10: cost of logical repartitioning during write-intensive load.

Paper claims: repartitioning finishes < 2 s for 256MB-1GB caches; the cost
is (1) flushing dirty cache pages, (2) moving a range boundary (metadata);
after it, throughput dips only for cache re-warm."""

from benchmarks.common import N_KEYS
from repro.core import baselines
from repro.core.partition import LogicalPartitions
from repro.core.sim import HostBTree, Simulator
from repro.data import ycsb


def run(quick: bool = False, seed: "int | None" = None):
    s = 0 if seed is None else int(seed)
    rows = ["cache_ratio,dirty_pages,flush_seconds,keyspace_moved_frac"]
    summary = {}
    ratios = [0.08] if quick else [0.08, 0.16, 0.32]  # 256MB..1GB analogue
    for ratio in ratios:
        dataset = ycsb.make_dataset(N_KEYS, seed=s)
        tree = HostBTree(dataset, fill=0.7, level_m=3, n_mem_servers=4)
        cfg = baselines.dex(
            cache_bytes=max(64, int(ratio * tree.num_nodes)) * 1024,
            n_compute=3,  # paper: three compute servers, then scale out
        )
        sim = Simulator(tree, cfg, seed=s + 5)
        wl = ycsb.generate("write-intensive", dataset, 40_000, seed=s + 6)
        sim.run(wl.ops, wl.keys)
        newp = LogicalPartitions.equal_width(
            4, int(dataset.min()), int(dataset.max()) + 1
        )
        cost = sim.repartition(newp)
        # scale flush seconds to paper scale (1000x dataset, same ratio)
        scaled = cost["flush_seconds_single_thread"] * 1000
        rows.append(
            f"{ratio:.2f},{cost['dirty_pages_flushed']:.0f},"
            f"{scaled:.3f},{cost['fraction_keyspace_moved']:.3f}"
        )
        summary[f"flush_s@{ratio:.0%}"] = scaled
    return rows, summary


def main():
    rows, summary = run()
    print("\n".join(rows))
    for k, v in summary.items():
        ok = "OK(<2s)" if v < 2.0 else "SLOW"
        print(f"# {k}: {v:.3f}s {ok} (paper: <2s)")


if __name__ == "__main__":
    main()
