"""Fig. 15 companion: the scan-intensive mix (YCSB-E) end-to-end on the
*mesh plane* (Plane B), next to the simulator's counter-based numbers.

The event simulator (Plane A) prices every remote verb of a fence-key
subdivided scan; this benchmark runs the same workload class through
``core/scan.py`` — real collectives, real cache state, real Pallas leaf-scan
compaction — and reports measured batch throughput plus the mesh plane's own
remote-read counters, cross-validated against ``HostBTree.scan``.

Run with ``PYTHONPATH=src python benchmarks/fig15_mesh_scan.py [--quick]``
(the repo root is added to sys.path automatically) or via the suite:
``PYTHONPATH=src python -m benchmarks.run --only fig15mesh``.  On hosts
without accelerators it forces an 8-device CPU mesh (2 route x 4 memory),
the same topology as tests/mesh_check.py.
"""

from __future__ import annotations

import os
import pathlib
import sys
import time

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

# direct-file execution puts benchmarks/ (not the repo root) on sys.path;
# add the root so `from benchmarks.common import ...` resolves either way
_ROOT = str(pathlib.Path(__file__).resolve().parent.parent)
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import dex as dex_mod  # noqa: E402
from repro.core import pool as pool_mod  # noqa: E402
from repro.core import scan as scan_mod  # noqa: E402
from repro.core.nodes import KEY_MAX, KEY_MIN  # noqa: E402
from repro.compat import make_mesh_compat  # noqa: E402
from repro.core.sim import HostBTree  # noqa: E402
from repro.data import ycsb  # noqa: E402

from benchmarks import common  # noqa: E402
from benchmarks.common import run_one  # noqa: E402

MAX_SCAN = 100
BATCH = 1024


def run(quick: bool = False, seed: "int | None" = None):
    s = 0 if seed is None else int(seed)
    n_keys = 50_000 if quick else 200_000
    n_batches = 4 if quick else 8
    rng = np.random.default_rng(s + 3)

    dataset = ycsb.make_dataset(n_keys, seed=s)
    vals = dataset * 7
    pool, meta = pool_mod.build_pool(dataset, vals, level_m=1, fill=0.7, n_shards=4)
    host = HostBTree(dataset, vals, fill=0.7)

    # 2 compute partitions x 4 memory columns when 8 devices are available
    # (standalone run / real mesh); single-device topology otherwise (e.g.
    # invoked from benchmarks.run after jax already initialized)
    if len(jax.devices()) >= 8:
        shape, n_route, n_memory = (2, 4), 2, 4
        mid = int(dataset[dataset.size // 2])
        bounds = np.array([KEY_MIN, mid, KEY_MAX], dtype=np.int64)
    else:
        shape, n_route, n_memory = (1, 1), 1, 1
        bounds = np.array([KEY_MIN, KEY_MAX], dtype=np.int64)
    mesh = make_mesh_compat(shape, ("data", "model"))
    # capacity factor sized for zipfian skew: cold-cache hop fetches
    # concentrate on the hot subtree's memory column, so provision buckets
    # for a full batch (factor >= n_memory); under-provisioned buckets
    # load-shed lanes, reported honestly as taken == -1 in `dropped`
    cfg = dex_mod.DexMeshConfig(
        route_axes=("data",), memory_axis="model",
        n_route=n_route, n_memory=n_memory,
        cache_sets=512, cache_ways=4,
        route_capacity_factor=float(max(2, n_memory)),
    )
    state = dex_mod.init_state(pool, meta, cfg, bounds)
    state = jax.tree.map(
        lambda x, s: jax.device_put(x, s), state, dex_mod.state_shardings(mesh, cfg)
    )
    sharding = NamedSharding(mesh, P(("data", "model")))
    scan = jax.jit(scan_mod.make_dex_scan(meta, cfg, mesh, max_count=MAX_SCAN))

    # YCSB-E traffic: zipfian start keys, uniform lengths in [1, MAX_SCAN]
    wl = ycsb.generate(
        "ycsb-e", dataset, n_batches * BATCH, theta=0.99, seed=s + 11,
        scan_len=MAX_SCAN, scan_len_dist="uniform",
    )
    is_scan = wl.ops == ycsb.OP_SCAN
    starts = wl.keys[is_scan]
    lens = wl.scan_lens[is_scan]
    n_full = (starts.size // BATCH) * BATCH
    starts, lens = starts[:n_full], lens[:n_full]

    def put(x):
        return jax.device_put(jnp.asarray(x), sharding)

    # warmup batch: compile + warm the per-chip caches (paper §8.1)
    state, k0, _, t0 = scan(state, put(starts[:BATCH]), put(lens[:BATCH]))
    jax.block_until_ready(k0)

    # cross-validate a sample against the host ground truth
    k0 = np.asarray(k0)
    t0 = np.asarray(t0)
    for i in rng.choice(BATCH, size=32, replace=False):
        if t0[i] < 0:
            continue  # load-shed lane: explicit failure, no data to compare
        expect = [
            k for _, ks in host.scan(int(starts[i]), int(lens[i])) for k in ks
        ][: int(lens[i])]
        got = k0[i][k0[i] != KEY_MAX].tolist()
        assert got == expect, f"mesh scan diverges from HostBTree.scan at {i}"

    # stage inputs and keep results on device inside the timed loop — one
    # sync at the end, so dt measures scan dispatch, not per-batch transfers
    batches = [
        (put(starts[b * BATCH : (b + 1) * BATCH]),
         put(lens[b * BATCH : (b + 1) * BATCH]))
        for b in range(n_full // BATCH)
    ]
    jax.block_until_ready(batches)

    # per-batch telemetry pass (repro/obs): the throughput loop below
    # deliberately streams batches with ONE end fence, so the fenced
    # per-batch timeline runs the same staged batches separately — counter
    # deltas and phase times per batch without perturbing the async
    # throughput measurement
    tl = common.new_timeline("fig15mesh_ycsb_e",
                             devices=len(jax.devices()), batch=BATCH)
    tl.prime(state.stats)
    scan_obs = tl.instrument(scan, label="scan")
    for bs, bl in batches:
        state, _k, _v, _t = scan_obs(state, bs, bl)
    common.finish_timeline(tl)

    stats_before = np.asarray(state.stats).sum(axis=0)
    takens = []
    t_start = time.perf_counter()
    for bs, bl in batches:
        state, kk, vv, tk = scan(state, bs, bl)
        takens.append(tk)
    jax.block_until_ready((state.stats, takens))
    tk = np.concatenate([np.asarray(t) for t in takens])
    # snapshot the first-pass counters before retrying: a lane shed in
    # every retry round would otherwise recount in STAT_DROPS once per
    # attempt, making the reported drop rate depend on the retry cap
    stats_first = np.asarray(state.stats).sum(axis=0) - stats_before
    # bounded replay of load-shed lanes (taken == -1), still on the clock:
    # serving a scan includes retrying it, and only completed scans enter
    # the throughput figure
    shed_idx = np.where(tk < 0)[0]
    for _ in range(4):
        if shed_idx.size == 0:
            break
        pad = (-shed_idx.size) % BATCH
        rs = np.concatenate(
            [starts[shed_idx], np.full(pad, KEY_MAX, np.int64)]
        )
        rl = np.concatenate([lens[shed_idx], np.zeros(pad, np.int64)])
        retks = []
        for b in range(rs.size // BATCH):
            sl = slice(b * BATCH, (b + 1) * BATCH)
            state, _k, _v, rtk = scan(state, put(rs[sl]), put(rl[sl]))
            retks.append(rtk)
        rtk = np.concatenate([np.asarray(t) for t in retks])[: shed_idx.size]
        ok = rtk >= 0
        tk[shed_idx[ok]] = rtk[ok]
        shed_idx = shed_idx[~ok]
    jax.block_until_ready(state.stats)
    dt = time.perf_counter() - t_start
    total_records = int(np.maximum(tk, 0).sum())
    completed = int((tk >= 0).sum())
    shed_scans = int((tk < 0).sum())
    stats = np.asarray(state.stats).sum(axis=0) - stats_before

    scans_per_s = completed / dt
    fetches_per_scan = stats[dex_mod.STAT_FETCHES] / max(stats[dex_mod.STAT_OPS], 1)
    hit_rate = stats[dex_mod.STAT_HITS] / max(
        stats[dex_mod.STAT_HITS] + stats[dex_mod.STAT_FETCHES], 1
    )

    # Plane A: the simulator's counter-based numbers for the *same* workload
    # (YCSB-E with uniform scan lengths in [1, MAX_SCAN], not fixed-100)
    sim_res = run_one(
        "dex", "ycsb-e", n_keys=n_keys,
        n_ops=4_000 if quick else 10_000,
        n_warm=4_000 if quick else 10_000,
        scan_len=MAX_SCAN, scan_len_dist="uniform",
    )

    rows = [
        "plane,metric,value",
        f"mesh,batch_scans_per_s,{scans_per_s:.1f}",
        f"mesh,records_per_s,{total_records / dt:.1f}",
        f"mesh,remote_fetches_per_scan,{fetches_per_scan:.3f}",
        f"mesh,cache_hit_rate,{hit_rate:.3f}",
        f"mesh,shed_scans,{shed_scans}",
        f"mesh,dropped_first_pass,{stats_first[dex_mod.STAT_DROPS]}",
        f"sim,mops,{sim_res.report.mops():.3f}",
        f"sim,node_reads_per_op,{sim_res.per_op['node_reads']:.3f}",
        f"sim,local_accesses_per_op,{sim_res.per_op['local_accesses']:.3f}",
    ]
    summary = {
        "mesh_scans_per_s": scans_per_s,
        "mesh_fetches_per_scan": float(fetches_per_scan),
        "sim_node_reads_per_op": sim_res.per_op["node_reads"],
    }
    return rows, summary


def main():
    quick = "--quick" in sys.argv
    rows, summary = run(quick=quick)
    print("\n".join(rows))
    for k, v in summary.items():
        print(f"# {k} = {v:.2f}")


if __name__ == "__main__":
    main()
